//! Reference-vs-optimized planner identity: the workspace-backed CWD and
//! CORAL entry points must emit plans **byte-identical** to the retained
//! naive implementations in `coordinator::reference`, over fuzzed
//! clusters, pipelines, telemetry, and parameter variants — with one
//! `PlannerWorkspace` reused across every case, so any state leaking
//! between rounds shows up as a divergence.

use octopinf::cluster::{Cluster, Device, DeviceClass};
use octopinf::coordinator::coral::{coral_repair_ws, coral_ws};
use octopinf::coordinator::cwd::{cwd_subset_ws, cwd_ws, CwdParams};
use octopinf::coordinator::reference::{
    coral_reference, coral_repair_reference, cwd_reference,
    cwd_subset_reference,
};
use octopinf::coordinator::{PlannerWorkspace, SchedEnv, StageCfg};
use octopinf::pipeline::{standard_pipelines, PipelineDag};
use octopinf::profiles::ProfileStore;
use octopinf::util::prop::{check, forall};
use octopinf::util::Rng;

const EDGE_CLASSES: [DeviceClass; 3] =
    [DeviceClass::JetsonAgx, DeviceClass::XavierNx, DeviceClass::OrinNano];

#[derive(Debug)]
struct PlannerInput {
    edge_classes: Vec<usize>,
    n_pipelines: usize,
    sources: Vec<usize>,
    fps: f64,
    bws: Vec<f64>,
    rate_scale: Vec<f64>,
    /// 0 = default, 1 = server_only, 2 = static_batch.
    params_kind: usize,
    /// Pipeline whose telemetry surges before the subset replan.
    drift_target: usize,
    surge: f64,
}

fn gen_input(r: &mut Rng) -> PlannerInput {
    let n_edge = 1 + r.below(5);
    let edge_classes = (0..n_edge).map(|_| r.below(3)).collect();
    let n_pipelines = 1 + r.below(6);
    let sources = (0..n_pipelines).map(|_| 1 + r.below(n_edge)).collect();
    let bws = (0..n_edge + 1).map(|_| r.range(1.0, 200.0)).collect();
    let rate_scale = (0..n_pipelines).map(|_| r.range(0.2, 4.0)).collect();
    PlannerInput {
        edge_classes,
        n_pipelines,
        sources,
        fps: r.range(5.0, 30.0),
        bws,
        rate_scale,
        params_kind: r.below(3),
        drift_target: r.below(n_pipelines),
        surge: r.range(0.3, 5.0),
    }
}

fn build_cluster(inp: &PlannerInput) -> Cluster {
    let mut devices = vec![Device::new(0, "server", DeviceClass::Server)];
    for (i, &c) in inp.edge_classes.iter().enumerate() {
        devices.push(Device::new(1 + i, &format!("edge{i}"), EDGE_CLASSES[c]));
    }
    let cl = Cluster { devices };
    assert!(cl.validate().is_ok());
    cl
}

fn build_pipelines(inp: &PlannerInput) -> Vec<PipelineDag> {
    standard_pipelines(inp.n_pipelines)
        .into_iter()
        .enumerate()
        .map(|(i, mut p)| {
            p.source_device = inp.sources[i];
            p.source_fps = inp.fps;
            p
        })
        .collect()
}

fn params_for(inp: &PlannerInput) -> CwdParams {
    match inp.params_kind {
        1 => CwdParams { server_only: true, ..Default::default() },
        2 => CwdParams { static_batch: Some((4, 8, 2)), ..Default::default() },
        _ => CwdParams::default(),
    }
}

/// All four entry points — full CWD, full CORAL, CWD subset, CORAL
/// repair — against their naive references, one shared workspace across
/// every fuzzed case.
#[test]
fn prop_workspace_planner_is_bit_identical_to_reference() {
    let profiles = ProfileStore::analytic();
    let mut ws = PlannerWorkspace::new();
    let mut out: Vec<(usize, Vec<StageCfg>)> = Vec::new();
    forall(9041, 48, gen_input, |inp| {
        let cluster = build_cluster(inp);
        let pipelines = build_pipelines(inp);
        let mut env =
            SchedEnv::bootstrap(&cluster, &profiles, &pipelines, inp.bws.clone());
        for (p, row) in env.obs.iter_mut().enumerate() {
            for o in row.iter_mut() {
                o.rate_qps *= inp.rate_scale[p];
            }
        }
        let params = params_for(inp);

        // Full CWD round.
        cwd_ws(&env, &params, &mut ws, &mut out);
        let naive = cwd_reference(&env, &params);
        check(out.len() == naive.len(), "cwd result count")?;
        for (i, ((p, cfg), r)) in out.iter().zip(&naive).enumerate() {
            check(
                *p == i && *cfg == r.cfg,
                format!("cwd diverged on pipeline {p}: {cfg:?} vs {:?}", r.cfg),
            )?;
        }
        let cfgs: Vec<Vec<StageCfg>> =
            out.iter().map(|(_, c)| c.clone()).collect();

        // Full CORAL placement.
        let plan_fast = coral_ws(&env, &cfgs, &mut ws);
        let plan_naive = coral_reference(&env, &cfgs);
        check(plan_fast.bit_eq(&plan_naive), "coral plan diverged")?;

        // Drift: surge one pipeline, replan only it with the rest kept.
        let t = inp.drift_target;
        let mut surged = SchedEnv::bootstrap(
            &cluster,
            &profiles,
            &pipelines,
            inp.bws.clone(),
        );
        for (p, row) in surged.obs.iter_mut().enumerate() {
            let s = inp.rate_scale[p] * if p == t { inp.surge } else { 1.0 };
            for o in row.iter_mut() {
                o.rate_qps *= s;
            }
        }
        let kept: Vec<(usize, Vec<StageCfg>)> = cfgs
            .iter()
            .enumerate()
            .filter(|&(p, _)| p != t)
            .map(|(p, c)| (p, c.clone()))
            .collect();
        let targets = [t];
        cwd_subset_ws(&surged, &params, &targets, &kept, &mut ws, &mut out);
        let naive_sub =
            cwd_subset_reference(&surged, &params, &targets, &kept);
        check(out == naive_sub, "cwd_subset diverged")?;

        // CORAL repair of the full plan for the drifted subset.
        let rep_fast = coral_repair_ws(&surged, &plan_fast, &out, &mut ws);
        let rep_naive = coral_repair_reference(&surged, &plan_naive, &out);
        check(rep_fast.bit_eq(&rep_naive), "coral_repair diverged")
    });
}
