//! Engine-split acceptance: the timing wheel against the old global-heap
//! discipline, partition-merge determinism across `--sim-jobs`, and the
//! long-horizon fuzz family surviving hundreds of replan rounds with the
//! invariant engine armed.

use std::collections::BinaryHeap;

use octopinf::coordinator::{ReplanMode, SchedulerKind};
use octopinf::sim::wheel::{mix64, EventWheel, WheelEntry};
use octopinf::sim::{preset, run_checked_with, run_with, FuzzSpec, Scenario};
use octopinf::util::prop::{check, forall, vec_of};
use octopinf::util::Rng;

/// One step of a random interleaving: `Some(t)` pushes at time `t`,
/// `None` pops from both queues and compares.
fn gen_steps(r: &mut Rng) -> Vec<Option<f64>> {
    vec_of(r, 20, 400, |r| {
        if r.chance(0.35) {
            None
        } else if r.chance(0.1) {
            // Far future: exercises the overflow heap and its migration
            // back into the window as the wheel advances.
            Some(r.range(0.0, 1_000_000.0))
        } else {
            // Coarse grid: forces exact same-time ties (the `:order=K`
            // battleground) and same-bucket neighbors.
            Some((r.below(64) as f64) * 8.0)
        }
    })
}

/// The wheel's contract: for any interleaving of pushes and pops, pop
/// order is bit-for-bit the old `BinaryHeap` order on `(t, tie, seq)` —
/// under insertion-order ties (`K = 0`) and seeded permutations alike.
#[test]
fn prop_wheel_pops_exactly_like_the_old_heap() {
    for order_k in [0u64, 0x9E37_79B9_7F4A_7C15, 0x0DD_BA11_5EED] {
        forall(0x911 ^ order_k, 40, gen_steps, |steps| {
            let mut wheel: EventWheel<u64> = EventWheel::new();
            let mut heap: BinaryHeap<WheelEntry<u64>> = BinaryHeap::new();
            let mut seq = 0u64;
            let compare = |a: Option<WheelEntry<u64>>,
                               b: Option<WheelEntry<u64>>|
             -> Result<(), String> {
                match (a, b) {
                    (None, None) => Ok(()),
                    (Some(x), Some(y)) => {
                        check(
                            x.t.to_bits() == y.t.to_bits()
                                && x.tie == y.tie
                                && x.seq == y.seq
                                && x.ev == y.ev,
                            format!(
                                "pop diverged: wheel ({}, {}, {}) vs heap ({}, {}, {})",
                                x.t, x.tie, x.seq, y.t, y.tie, y.seq
                            ),
                        )
                    }
                    (a, b) => Err(format!(
                        "one queue drained early: wheel {:?} heap {:?}",
                        a.map(|e| e.seq),
                        b.map(|e| e.seq)
                    )),
                }
            };
            for step in steps {
                match *step {
                    Some(t) => {
                        let tie =
                            if order_k == 0 { seq } else { mix64(order_k ^ seq) };
                        wheel.push(t, tie, seq, seq);
                        heap.push(WheelEntry { t, tie, seq, ev: seq });
                        seq += 1;
                    }
                    None => compare(wheel.pop(), heap.pop())?,
                }
                check(wheel.len() == heap.len(), "length drift")?;
            }
            loop {
                let (a, b) = (wheel.pop(), heap.pop());
                let done = a.is_none() && b.is_none();
                compare(a, b)?;
                if done {
                    return Ok(());
                }
            }
        });
    }
}

/// `--sim-jobs` is a pure wall-clock knob: a 4-partition run produces a
/// byte-identical digest (and timeline) at every worker count.
#[test]
fn digests_identical_across_sim_jobs() {
    let mut cfg = preset("smoke").unwrap();
    cfg.clusters = 4;
    let sc = Scenario::build(cfg);
    let base = run_with(&sc, SchedulerKind::OctopInf, 1);
    assert!(base.on_time > 0, "smoke run produced no on-time work");
    for jobs in [2usize, 4, 8] {
        let m = run_with(&sc, SchedulerKind::OctopInf, jobs);
        assert_eq!(
            m.digest(),
            base.digest(),
            "--sim-jobs {jobs} changed the run digest"
        );
        assert_eq!(m.timeline, base.timeline, "--sim-jobs {jobs} timeline");
    }
}

/// Same sweep with the invariant engine armed: every partition's census
/// closes, the merged report is identical, and arming changes no metrics.
#[test]
fn invariants_stay_armed_across_partition_barriers() {
    let mut cfg = preset("smoke").unwrap();
    cfg.clusters = 4;
    let sc = Scenario::build(cfg);
    let plain = run_with(&sc, SchedulerKind::OctopInf, 1).digest();
    let (m1, r1) = run_checked_with(&sc, SchedulerKind::OctopInf, 1);
    assert!(r1.ok(), "violations:\n{}", r1.violations.join("\n"));
    assert_eq!(m1.digest(), plain, "arming invariants changed the run");
    let (m8, r8) = run_checked_with(&sc, SchedulerKind::OctopInf, 8);
    assert!(r8.ok(), "violations:\n{}", r8.violations.join("\n"));
    assert_eq!(m8.digest(), plain, "sim-jobs 8 diverged under invariants");
    assert_eq!(r8.completed_queries, r1.completed_queries);
    assert_eq!(r8.plans, r1.plans);
}

/// The long-haul fuzz family: an hour-plus composite horizon driven from
/// its repro string, drift-triggered replanning layered on the 6-minute
/// clock, invariants armed end to end.
#[test]
fn long_haul_repro_runs_many_replan_rounds_clean() {
    let mut spec = FuzzSpec::from_repro("fuzz:v1:seed=4242:horizon=3600")
        .expect("long-haul repro parses");
    spec.cfg.replan = ReplanMode::Drift;
    let (m, r) = run_checked_with(&spec.build(), SchedulerKind::OctopInf, 2);
    assert!(
        r.ok(),
        "{}: invariant violations:\n{}",
        spec.repro(),
        r.violations.join("\n")
    );
    assert!(m.on_time + m.late > 0, "long-haul run completed nothing");
    // 3600 s = 10 fixed six-minute rounds; drift triggers fire on top of
    // them through the diurnal swing, so the floor is conservative.
    assert!(r.plans >= 8, "only {} plans over an hour-long horizon", r.plans);
}
