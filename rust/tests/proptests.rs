//! Property-based tests over coordinator invariants, using the in-tree
//! `util::prop` mini-framework (offline registry has no proptest).

use octopinf::cluster::Cluster;
use octopinf::coordinator::coral::coral;
use octopinf::coordinator::cwd::{cwd, CwdParams};
use octopinf::coordinator::estimator::est_latency;
use octopinf::coordinator::stream::{FreePortion, GpuStreams, Portion, Stream};
use octopinf::coordinator::{GpuId, SchedEnv, StageCfg};
use octopinf::network::BwTrace;
use octopinf::pipeline::{standard_pipelines, PipelineDag};
use octopinf::profiles::{ProfileStore, BATCH_SIZES};
use octopinf::serving::DynamicBatcher;
use octopinf::sim::FifoLink;
use octopinf::util::prop::{check, forall};
use octopinf::util::stats::{burstiness, Percentiles, QuantileSketch};
use octopinf::util::Rng;
use octopinf::workload::ArrivalWindow;

/// Random scheduling environment: pipelines, rates, bandwidths.
struct EnvInput {
    n_pipelines: usize,
    fps: f64,
    bw: f64,
    rate_scale: f64,
}

impl std::fmt::Debug for EnvInput {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "EnvInput(n={}, fps={:.1}, bw={:.1}, scale={:.2})",
            self.n_pipelines, self.fps, self.bw, self.rate_scale
        )
    }
}

fn gen_env_input(r: &mut Rng) -> EnvInput {
    EnvInput {
        n_pipelines: 1 + r.below(6),
        fps: r.range(5.0, 30.0),
        bw: r.range(2.0, 200.0),
        rate_scale: r.range(0.2, 4.0),
    }
}

fn build_pipelines(inp: &EnvInput) -> Vec<PipelineDag> {
    standard_pipelines(inp.n_pipelines)
        .into_iter()
        .map(|mut p| {
            p.source_device += 1;
            p.source_fps = inp.fps;
            p
        })
        .collect()
}

#[test]
fn prop_cwd_respects_slo_guard_and_batch_domain() {
    let cluster = Cluster::paper_testbed();
    let profiles = ProfileStore::analytic();
    forall(101, 40, gen_env_input, |inp| {
        let pipelines = build_pipelines(inp);
        let mut env = SchedEnv::bootstrap(
            &cluster,
            &profiles,
            &pipelines,
            vec![inp.bw; cluster.devices.len()],
        );
        for row in env.obs.iter_mut() {
            for o in row.iter_mut() {
                o.rate_qps *= inp.rate_scale;
            }
        }
        for (p, r) in cwd(&env, &CwdParams::default()).iter().enumerate() {
            for c in &r.cfg {
                check(BATCH_SIZES.contains(&c.batch), format!("batch {}", c.batch))?;
                check(c.instances >= 1 && c.instances <= 16, "instances bound")?;
                check(
                    c.device < cluster.devices.len(),
                    format!("device {}", c.device),
                )?;
            }
            // CWD's guard: the result meets SLO/2, OR the environment is
            // such that even the minimal all-server fallback cannot (an
            // overloaded cluster / dead network / IO-ratio revert) — in
            // which case CWD must not be *worse* than that fallback.
            let lat = est_latency(&env, p, &r.cfg);
            let fallback: Vec<StageCfg> = (0..r.cfg.len())
                .map(|_| StageCfg { device: 0, batch: 1, instances: 16 })
                .collect();
            let fb_lat = est_latency(&env, p, &fallback);
            check(
                lat <= (pipelines[p].slo_ms / 2.0).max(fb_lat) + 1e-6
                    || lat.is_infinite(),
                format!("pipeline {p} est latency {lat} > max(SLO/2, fallback {fb_lat})"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_coral_memory_util_and_device_affinity() {
    let cluster = Cluster::paper_testbed();
    let profiles = ProfileStore::analytic();
    forall(202, 30, gen_env_input, |inp| {
        let pipelines = build_pipelines(inp);
        let env = SchedEnv::bootstrap(
            &cluster,
            &profiles,
            &pipelines,
            vec![inp.bw; cluster.devices.len()],
        );
        let cfgs: Vec<Vec<StageCfg>> =
            cwd(&env, &CwdParams::default()).into_iter().map(|r| r.cfg).collect();
        let plan = coral(&env, &cfgs);
        // Recompute per-GPU budgets from the plan's reserved bindings.
        use std::collections::HashMap;
        let mut weight: HashMap<GpuId, f64> = HashMap::new();
        let mut inter: HashMap<(GpuId, usize), f64> = HashMap::new();
        let mut width: HashMap<(GpuId, usize), f64> = HashMap::new();
        for a in &plan.assignments {
            check(a.cfg.instances as usize == a.bindings.len(), "binding count")?;
            let spec = &pipelines[a.pipeline].models[a.model].spec;
            for b in &a.bindings {
                check(b.gpu.device == a.cfg.device, "binding on wrong device")?;
                if let Some(t) = b.temporal {
                    *weight.entry(b.gpu).or_default() += spec.weight_mem_mb;
                    let e = inter.entry((b.gpu, t.stream)).or_default();
                    *e = e.max(spec.inter_mem_mb * a.cfg.batch as f64);
                    let w = width.entry((b.gpu, t.stream)).or_default();
                    *w = w.max(b.width);
                }
            }
        }
        for d in &cluster.devices {
            for (gi, g) in d.gpus.iter().enumerate() {
                let id = GpuId { device: d.id, gpu: gi };
                let wsum = weight.get(&id).copied().unwrap_or(0.0);
                let isum: f64 = inter
                    .iter()
                    .filter(|((g2, _), _)| *g2 == id)
                    .map(|(_, v)| v)
                    .sum();
                check(
                    wsum + isum <= g.mem_mb + 1e-6,
                    format!("{id:?} memory {wsum}+{isum} > {}", g.mem_mb),
                )?;
                let usum: f64 = width
                    .iter()
                    .filter(|((g2, _), _)| *g2 == id)
                    .map(|(_, v)| v)
                    .sum();
                check(usum <= g.util_cap + 1e-6, format!("{id:?} util {usum}"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_stream_portions_never_overlap() {
    forall(
        303,
        200,
        |r| {
            // Random portion insert sequence into one stream.
            let n = 1 + r.below(20);
            (0..n)
                .map(|_| (r.range(0.0, 200.0), r.range(0.5, 30.0), r.range(0.05, 0.5)))
                .collect::<Vec<_>>()
        },
        |reqs| {
            let gpu = GpuId { device: 0, gpu: 0 };
            let mut s = Stream::new(gpu, 0);
            s.duty_cycle_ms = 250.0;
            for &(start, dur, w) in reqs {
                // Only insert via a fitting free portion, like CORAL does.
                let free = s.free_portions(250.0);
                if let Some(f) = free.iter().find_map(|f| {
                    f.fit(start, dur).map(|st| FreePortion {
                        start_ms: st,
                        ..*f
                    })
                }) {
                    s.insert(Portion {
                        start_ms: f.start_ms,
                        end_ms: f.start_ms + dur,
                        width: w,
                        inter_mb: 1.0,
                        owner: (0, 0, 0),
                    });
                }
            }
            // Invariant: sorted portions are disjoint.
            let mut ps = s.portions.clone();
            ps.sort_by(|a, b| a.start_ms.partial_cmp(&b.start_ms).unwrap());
            for w in ps.windows(2) {
                check(
                    w[0].end_ms <= w[1].start_ms + 1e-9,
                    format!("overlap {:?} {:?}", w[0], w[1]),
                )?;
            }
            // Free time + occupied time == duty cycle.
            let occ = s.occupancy_ms();
            let free: f64 = s.free_portions(250.0).iter().map(|f| f.len()).sum();
            check(
                (occ + free - 250.0).abs() < 1e-6,
                format!("time leak: occ {occ} + free {free} != 250"),
            )
        },
    );
}

#[test]
fn prop_gpu_admits_is_monotone() {
    forall(
        404,
        200,
        |r| {
            (
                r.range(10.0, 1000.0),  // mem cap
                r.range(0.0, 500.0),    // weight
                r.range(0.0, 300.0),    // inter
                r.range(0.0, 1.0),      // width
            )
        },
        |&(cap, w, i, wd)| {
            let gpu = GpuId { device: 0, gpu: 0 };
            let g = GpuStreams::new(gpu, cap, 1.0, 2);
            let admit = g.admits(0, w, i, wd);
            // Anything strictly smaller must also be admitted.
            if admit {
                check(
                    g.admits(0, w * 0.5, i * 0.5, wd * 0.5),
                    "smaller request rejected while larger admitted",
                )?;
            }
            // Anything beyond the caps must be rejected.
            check(!g.admits(0, cap + 1.0, 0.0, 0.1), "over-memory admitted")?;
            check(!g.admits(0, 0.0, 0.0, 1.5), "over-util admitted")
        },
    );
}

#[test]
fn prop_batcher_conserves_requests_in_fifo_order() {
    forall(
        505,
        150,
        |r| {
            let batch = 1 + r.below(8);
            let wait = r.range(1.0, 50.0);
            let n = 1 + r.below(100);
            let arrivals: Vec<f64> = {
                let mut t = 0.0;
                (0..n)
                    .map(|_| {
                        t += r.exp(0.2);
                        t
                    })
                    .collect()
            };
            (batch, wait, arrivals)
        },
        |(batch, wait, arrivals)| {
            let mut b: DynamicBatcher<usize> = DynamicBatcher::new(*batch, *wait);
            let mut out = Vec::new();
            for (id, &t) in arrivals.iter().enumerate() {
                if let Some(batch) = b.push(id, t) {
                    out.extend(batch);
                }
                if let Some(batch) = b.poll(t) {
                    out.extend(batch);
                }
            }
            if let Some(rest) = b.flush() {
                out.extend(rest);
            }
            check(out.len() == arrivals.len(), "lost or duplicated requests")?;
            check(
                out.windows(2).all(|w| w[0] < w[1]),
                "FIFO order violated",
            )
        },
    );
}

#[test]
fn prop_bw_traces_nonnegative_and_deterministic() {
    forall(
        606,
        50,
        |r| (r.next_u64(), r.range(10_000.0, 600_000.0)),
        |&(seed, dur)| {
            let a = BwTrace::generate(
                octopinf::network::TraceKind::Lte,
                dur,
                &mut Rng::new(seed),
            );
            let b = BwTrace::generate(
                octopinf::network::TraceKind::Lte,
                dur,
                &mut Rng::new(seed),
            );
            for i in 0..(dur / 1000.0) as usize {
                let t = i as f64 * 1000.0;
                check(a.bandwidth_mbps(t) >= 0.0, "negative bandwidth")?;
                check(
                    a.bandwidth_mbps(t) == b.bandwidth_mbps(t),
                    "trace not deterministic",
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_arrival_window_matches_batch_reference() {
    // The incremental (eviction-aware running-aggregate) ArrivalWindow
    // must agree with an exact batch recomputation over the retained
    // arrivals, across window sizes and arrival processes that force
    // heavy eviction churn.
    forall(
        808,
        60,
        |r| {
            let window_ms = r.range(50.0, 5_000.0);
            let rate = r.range(0.01, 0.5); // mean gap 2..100 ms
            let n = 3 + r.below(800);
            let mut t = r.range(0.0, 1_000.0);
            let arrivals: Vec<f64> = (0..n)
                .map(|_| {
                    t += r.exp(rate);
                    t
                })
                .collect();
            (window_ms, arrivals)
        },
        |(window_ms, arrivals)| {
            let mut w = ArrivalWindow::new(*window_ms);
            for &t in arrivals {
                w.record(t);
            }
            let cutoff = arrivals[arrivals.len() - 1] - window_ms;
            let kept: Vec<f64> =
                arrivals.iter().copied().filter(|&x| x >= cutoff).collect();
            check(w.len() == kept.len(), "retained count mismatch")?;
            let ref_rate = if kept.len() < 2 {
                0.0
            } else {
                let span = kept[kept.len() - 1] - kept[0];
                if span <= 0.0 {
                    0.0
                } else {
                    (kept.len() - 1) as f64 * 1000.0 / span
                }
            };
            let ref_cv = burstiness(&kept);
            check(
                (w.rate_qps() - ref_rate).abs() <= 1e-6 * ref_rate.max(1.0),
                format!("rate {} vs {}", w.rate_qps(), ref_rate),
            )?;
            check(
                (w.burstiness() - ref_cv).abs() <= 1e-6 * ref_cv.max(1.0),
                format!("cv {} vs {}", w.burstiness(), ref_cv),
            )
        },
    );
}

#[test]
fn prop_quantile_sketch_brackets_exact_quantiles() {
    // The streaming log-bucket sketch must land within the exact order
    // statistics bracketing the target rank, expanded by its bucket
    // resolution (< 1 % relative).
    forall(
        909,
        80,
        |r| {
            let n = 2 + r.below(3_000);
            // Mix of scales: uniform, exponential, or heavy-tailed.
            let mode = r.below(3);
            (0..n)
                .map(|_| match mode {
                    0 => r.range(0.1, 500.0),
                    1 => r.exp(0.02),
                    _ => r.exp(0.02) * r.exp(0.02),
                })
                .collect::<Vec<f64>>()
        },
        |samples| {
            let mut sketch = QuantileSketch::new();
            let mut exact = Percentiles::new();
            for &x in samples {
                sketch.push(x);
                exact.push(x);
            }
            check(
                (sketch.mean() - exact.mean()).abs()
                    <= 1e-9 * exact.mean().abs().max(1.0),
                "mean mismatch",
            )?;
            let mut sorted = samples.clone();
            sorted.sort_by(|a, b| a.total_cmp(b));
            for q in [0.0, 0.1, 0.5, 0.9, 0.95, 0.99, 1.0] {
                let s = sketch.quantile(q);
                let pos = q * (samples.len() - 1) as f64;
                let lo = sorted[pos.floor() as usize];
                let hi = sorted[pos.ceil() as usize];
                check(
                    s >= lo * (1.0 - 0.01) - 1e-9 && s <= hi * (1.0 + 0.01) + 1e-9,
                    format!("q={q}: sketch {s} outside [{lo}, {hi}]"),
                )?;
            }
            Ok(())
        },
    );
}

/// Random 1-second bandwidth trace with occasional forced blackout
/// windows — the regime `FifoLink::send` must survive (Obs. 2: unstable
/// networks become the bottleneck). Length is kept under the link's
/// 600-second outage scan so "some second has bandwidth" implies
/// "every transfer is eventually delivered".
fn gen_blackout_samples(r: &mut Rng) -> Vec<f64> {
    let n = 20 + r.below(180);
    let mut s: Vec<f64> = (0..n)
        .map(|_| if r.chance(0.15) { 0.0 } else { r.range(0.5, 120.0) })
        .collect();
    if r.chance(0.7) {
        let a = r.below(n);
        let len = 1 + r.below(12);
        for x in s[a..(a + len).min(n)].iter_mut() {
            *x = 0.0;
        }
    }
    s
}

#[test]
fn prop_fifo_link_ordering_and_no_loss() {
    forall(
        910,
        150,
        |r| {
            let samples = gen_blackout_samples(r);
            let rtt = r.range(0.0, 40.0);
            let n_sends = 1 + r.below(60);
            let mut t = 0.0;
            let sends: Vec<(f64, f64)> = (0..n_sends)
                .map(|_| {
                    t += r.exp(0.01); // mean 100 ms between sends
                    (t, r.range(100.0, 500_000.0))
                })
                .collect();
            (samples, rtt, sends)
        },
        |(samples, rtt, sends)| {
            let any_bw = samples.iter().any(|&b| b > 0.0);
            let mut link = FifoLink::new(BwTrace::from_samples(samples.clone()), *rtt);
            let mut prev = f64::NEG_INFINITY;
            for &(now, bytes) in sends {
                let a = link.send(now, bytes);
                if any_bw {
                    check(a.is_finite(), format!("transfer lost at t={now}"))?;
                    check(a >= now, format!("arrival {a} before send {now}"))?;
                    check(
                        a >= prev,
                        format!("FIFO order violated: {a} < previous {prev}"),
                    )?;
                    prev = a;
                } else {
                    check(a.is_infinite(), "all-dark link delivered a transfer")?;
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_fifo_link_blackout_defers_to_reopen() {
    forall(
        911,
        150,
        |r| {
            (
                1 + r.below(5),            // good seconds before the blackout
                1 + r.below(8),            // blackout length, seconds
                r.range(1.0, 100.0),       // bandwidth while up
                r.range(10.0, 100_000.0),  // payload bytes
            )
        },
        |&(pre, dark, bw, bytes)| {
            let mut samples = vec![bw; pre];
            samples.extend(std::iter::repeat(0.0).take(dark));
            samples.push(bw);
            let mut link = FifoLink::new(BwTrace::from_samples(samples), 0.0);
            // Send mid-blackout on an idle link: delivery must wait for the
            // first second with bandwidth, not drop or deliver early.
            let t0 = (pre as f64 + 0.5) * 1000.0;
            let a = link.send(t0, bytes);
            let reopen = (pre + dark) as f64 * 1000.0;
            check(a.is_finite(), "transfer lost across blackout")?;
            check(
                a >= reopen,
                format!("arrival {a} before the link reopened at {reopen}"),
            )
        },
    );
}

#[test]
fn prop_fifo_link_serialization_conserved() {
    // Back-to-back sends on a constant link: total serialization time must
    // equal sum(bytes)*8/bw exactly (FIFO backlog accounting loses
    // nothing), and each arrival is spaced by its own serialization time.
    forall(
        912,
        100,
        |r| {
            let bw = r.range(1.0, 500.0);
            let n = 1 + r.below(30);
            let sizes: Vec<f64> =
                (0..n).map(|_| r.range(1_000.0, 200_000.0)).collect();
            (bw, sizes)
        },
        |(bw, sizes)| {
            let mut link = FifoLink::new(BwTrace::constant(*bw), 0.0);
            let mut expect = 0.0;
            for &bytes in sizes {
                let a = link.send(0.0, bytes);
                expect += bytes * 8.0 / (bw * 1000.0);
                check(
                    (a - expect).abs() <= 1e-6 * expect.max(1.0),
                    format!("arrival {a} != cumulative serialization {expect}"),
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_plan_split_points_bounded_by_depth() {
    let cluster = Cluster::paper_testbed();
    let profiles = ProfileStore::analytic();
    forall(707, 30, gen_env_input, |inp| {
        let pipelines = build_pipelines(inp);
        let env = SchedEnv::bootstrap(
            &cluster,
            &profiles,
            &pipelines,
            vec![inp.bw; cluster.devices.len()],
        );
        let cfgs: Vec<Vec<StageCfg>> =
            cwd(&env, &CwdParams::default()).into_iter().map(|r| r.cfg).collect();
        let plan = coral(&env, &cfgs);
        for (p, dag) in pipelines.iter().enumerate() {
            let splits = plan.split_points(p, dag);
            // Insight 3: splits are minimized; a 3-stage DAG never needs
            // more than 2 and CWD should not zig-zag.
            check(splits <= 2, format!("pipeline {p}: {splits} splits"))?;
        }
        Ok(())
    });
}
