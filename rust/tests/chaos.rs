//! Fault-injection subsystem: acceptance tests.
//!
//! The storms here are the PR's contract: failure-aware recovery
//! (crash-edge replanning, post-outage catch-up) must not lose to running
//! open-loop through the same faults; every storm must close the
//! fault-aware conservation census (`admitted == sink + routed + dropped +
//! lost_to_fault + in_flight`) under the invariant engine; the same repro
//! must be byte-identical at any job count and under any same-time event
//! permutation seed; and a fault that touches nothing must change nothing.

use octopinf::coordinator::{ReplanMode, SchedulerKind};
use octopinf::experiments::chaos::{chaos_comparison, storm_specs};
use octopinf::metrics::RunMetrics;
use octopinf::sim::{
    preset, run_checked, CrashPolicy, FaultEv, FaultPlan, FuzzSpec, Scenario,
    Simulator,
};
use octopinf::util::prop::{check, forall};

/// Root seed for the chaos sweeps (distinct from the conformance and
/// drift corpora so the three suites don't share scenarios).
const CHAOS_SEED0: u64 = 0xC4A0_5EED;

/// Mirror of the engine's fault-plan sampling for a fuzz spec: how many
/// device-crash windows this storm actually schedules.
fn crash_count(spec: &FuzzSpec) -> usize {
    let sc = spec.build();
    FaultPlan::sample(
        sc.cfg.seed,
        sc.cfg.faults,
        sc.cfg.duration_ms,
        &sc.cluster,
        sc.cfg.n_sources,
    )
    .events
    .iter()
    .filter(|(_, e)| matches!(e, FaultEv::DeviceCrash { .. }))
    .count()
}

#[test]
fn recovery_replanning_beats_open_loop_on_fault_storms() {
    // Same storms, recovery on vs off, invariants armed in every run.
    // Periodic mode gives the cleanest contrast: the 6-minute replan clock
    // never fires inside a fuzz horizon, so the no-recovery arm runs its
    // whole storm on the initial plan and only fault-edge replanning
    // separates the arms.
    let n = 6;
    let cmps = chaos_comparison(CHAOS_SEED0, n, 0, ReplanMode::Periodic);
    assert_eq!(cmps.len(), SchedulerKind::all_main().len());
    for c in &cmps {
        assert_eq!(
            c.violations,
            0,
            "{}: invariant violations under fault storms",
            c.kind.label()
        );
        assert_eq!(c.scenarios, n);
    }
    let oct = cmps
        .iter()
        .find(|c| c.kind == SchedulerKind::OctopInf)
        .unwrap();
    assert!(
        oct.recovery.attainment() >= oct.no_recovery.attainment(),
        "recovery {:.4} must not lose to open-loop {:.4} (on_time {} vs {})",
        oct.recovery.attainment(),
        oct.no_recovery.attainment(),
        oct.recovery.on_time,
        oct.no_recovery.on_time,
    );
    // If any storm crashes a device, frames captured during the window are
    // destroyed — the sweep must have accounted (not hidden) those losses.
    let crashes: usize = storm_specs(CHAOS_SEED0, n).iter().map(crash_count).sum();
    if crashes > 0 {
        let lost: u64 = cmps
            .iter()
            .map(|c| c.recovery.lost_to_fault + c.no_recovery.lost_to_fault)
            .sum();
        assert!(
            lost > 0,
            "{crashes} crash windows sampled but no query was lost to a fault"
        );
        assert!(
            oct.recovery.plans >= oct.no_recovery.plans,
            "recovery installed fewer plans ({} vs {}) despite fault edges",
            oct.recovery.plans,
            oct.no_recovery.plans,
        );
    }
}

#[test]
fn chaos_comparison_is_identical_at_any_job_count() {
    let a = chaos_comparison(CHAOS_SEED0 ^ 0x10B5, 2, 1, ReplanMode::Periodic);
    let b = chaos_comparison(CHAOS_SEED0 ^ 0x10B5, 2, 4, ReplanMode::Periodic);
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.kind, y.kind);
        assert_eq!(x.violations, y.violations);
        for (p, q) in [(&x.recovery, &y.recovery), (&x.no_recovery, &y.no_recovery)]
        {
            assert_eq!(p.on_time, q.on_time, "{}: jobs changed on_time", x.kind.label());
            assert_eq!(p.late, q.late);
            assert_eq!(p.dropped, q.dropped);
            assert_eq!(p.lost_to_fault, q.lost_to_fault);
            assert_eq!(p.plans, q.plans);
        }
    }
}

/// Run one storm spec under OctopInf and return its metrics, asserting
/// the invariant census closed.
fn run_storm(spec: &FuzzSpec) -> RunMetrics {
    let (m, r) = run_checked(&spec.build(), SchedulerKind::OctopInf);
    assert!(
        r.ok(),
        "{}: invariant violations:\n{}",
        spec.repro(),
        r.violations.join("\n")
    );
    m
}

#[test]
fn order_permutation_is_seeded_and_deterministic() {
    // The `:order=K` axis permutes same-time event execution. Every
    // permutation must hold conservation, and each seed must replay
    // byte-identically — including K = 0, the legacy insertion order.
    let base = FuzzSpec::sample_storm(CHAOS_SEED0 ^ 0x0DE2);
    for order in [0u64, 0x1234_5678_9ABC_DEF0, 0xDEAD_BEEF_CAFE_F00D] {
        let mut spec = base.clone();
        spec.cfg.order_seed = order;
        let m1 = run_storm(&spec);
        let m2 = run_storm(&spec);
        assert_eq!(m1.on_time, m2.on_time, "order={order}: on_time diverged");
        assert_eq!(m1.late, m2.late, "order={order}: late diverged");
        assert_eq!(m1.dropped, m2.dropped, "order={order}: dropped diverged");
        assert_eq!(
            m1.lost_to_fault, m2.lost_to_fault,
            "order={order}: lost_to_fault diverged"
        );
        assert_eq!(m1.timeline, m2.timeline, "order={order}: timeline diverged");
        assert!(
            m1.on_time + m1.late > 0,
            "order={order}: storm produced no completions"
        );
    }
}

#[test]
fn random_storms_never_lose_a_query_unaccounted() {
    // Property: for any storm — random base family, fault count, ordering
    // seed, crash policy, recovery setting, scheduler — the armed checker
    // closes its census. Conservation and the metrics reconciliation
    // (including `lost_to_fault`) are all inside `report.ok()`.
    let kinds = SchedulerKind::all_main();
    forall(
        CHAOS_SEED0 ^ 0xF0A1,
        12,
        |rng| {
            let mut spec = FuzzSpec::sample_storm(rng.next_u64());
            spec.cfg.faults = 1 + rng.below(6) as u32;
            spec.cfg.order_seed = if rng.chance(0.5) { rng.next_u64() } else { 0 };
            spec.cfg.recovery = rng.chance(0.5);
            spec.cfg.crash_policy = if rng.chance(0.5) {
                CrashPolicy::Drop
            } else {
                CrashPolicy::Reroute
            };
            (spec, kinds[rng.below(kinds.len())])
        },
        |(spec, kind)| {
            let (_m, r) = run_checked(&spec.build(), *kind);
            check(
                r.ok(),
                format!(
                    "{} on {}: {}",
                    spec.repro(),
                    kind.label(),
                    r.violations.join("; ")
                ),
            )
        },
    );
}

#[test]
fn idle_device_crash_and_recover_changes_nothing() {
    // The smoke preset places sources on devices 1 and 2 only; device 5
    // hosts nothing. Crashing and recovering it mid-run must be invisible:
    // the crash-edge replan finds no affected pipeline and returns the old
    // plan, the recover-side dispatch kick finds every healthy queue
    // already scheduled, and no query is anywhere near the dead hardware.
    let sc = Scenario::build(preset("smoke").unwrap());
    let run = |plan: Option<FaultPlan>| {
        let mut sim = Simulator::new(&sc, SchedulerKind::OctopInf);
        if let Some(p) = plan {
            sim.set_fault_plan(p);
        }
        sim.enable_invariants();
        let m = sim.run();
        let r = sim.take_invariant_report().unwrap();
        assert!(r.ok(), "invariant violations:\n{}", r.violations.join("\n"));
        m
    };
    let baseline = run(None);
    let faulted = run(Some(FaultPlan {
        events: vec![
            (10_123.0, FaultEv::DeviceCrash { device: 5 }),
            (24_777.0, FaultEv::DeviceRecover { device: 5 }),
        ],
    }));
    assert!(baseline.on_time > 0, "smoke run produced no on-time work");
    assert_eq!(faulted.lost_to_fault, 0, "idle-device crash destroyed work");
    assert_eq!(faulted.on_time, baseline.on_time);
    assert_eq!(faulted.late, baseline.late);
    assert_eq!(faulted.dropped, baseline.dropped);
    assert_eq!(faulted.timeline, baseline.timeline);
}
