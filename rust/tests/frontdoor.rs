//! Integration tests for the serving front door: the threaded serve path
//! over a synthetic backend (no PJRT, no artifacts), the logical-clock
//! harness, and the sim-side frontend. These are the regression tests for
//! the three serving-path bugs this layer fixed: whole-queue shutdown
//! flushes, unknown-model batcher leaks, and unreachable backpressure.

use std::collections::HashMap;
use std::time::Instant;

use octopinf::experiments::{
    isolation_comparison, run_front_harness, HarnessCfg, TenantLoad,
};
use octopinf::serving::{
    serve_with, FilterCfg, FrontDoor, FrontDoorCfg, ModelServeCfg, Offer,
    Request, Response, SyntheticExec,
};
use octopinf::coordinator::SchedulerKind;
use octopinf::sim::{preset, run_checked, Scenario};

fn req(id: u64, model: &str, slo_ms: f64, data: Vec<f32>) -> Request {
    Request {
        id,
        model: model.into(),
        data,
        slo_ms,
        tenant: 0,
        stream: id,
        submitted: Instant::now(),
    }
}

/// Shutdown with a backlog bigger than the batch size: every queued
/// request must still be answered, in engine-legal (≤ batch) chunks.
/// Regression for the whole-queue `flush()` that handed the engine an
/// 11-deep batch compiled for 4.
#[test]
fn shutdown_backlog_larger_than_batch_answers_everyone() {
    let mut ex = SyntheticExec::new().with_model("det", 4, 2, 0.0);
    let mut cfgs = HashMap::new();
    // Enormous max-wait: nothing flushes on a deadline, so the backlog is
    // still queued when the channel closes.
    cfgs.insert("det".to_string(), ModelServeCfg::new(4, 1e6));
    let (req_tx, req_rx) = std::sync::mpsc::channel();
    let (resp_tx, resp_rx) = std::sync::mpsc::channel::<Response>();
    for i in 0..11 {
        req_tx.send(req(i, "det", 1e9, vec![0.1; 4])).unwrap();
    }
    drop(req_tx);
    let report =
        serve_with(&mut ex, &cfgs, FrontDoorCfg::default(), req_rx, resp_tx)
            .unwrap();
    assert_eq!(report.submitted, 11);
    assert_eq!(report.served, 11, "shutdown must drain the whole backlog");
    assert_eq!(report.failed, 0, "no chunk may exceed the engine batch");
    assert_eq!(report.accounted(), report.submitted);
    assert!(
        report.batch_hist.keys().all(|&b| b <= 4),
        "batches {:?} exceed the compiled size",
        report.batch_hist
    );
    let answers: Vec<Response> = resp_rx.try_iter().collect();
    assert_eq!(answers.len(), 11, "every client heard back");
    assert!(answers.iter().all(|r| r.error.is_none()));
}

/// End-to-end backpressure: a slow executor + bounded queues must reject
/// overflow with a non-zero retry hint while answering every request.
/// Regression for the unreachable `retry_after_ms` ("retry after 0 ms")
/// on a full queue.
#[test]
fn overload_rejects_with_nonzero_retry_hint() {
    let mut ex = SyntheticExec::new().with_model("det", 4, 2, 20.0);
    ex.sleep = true; // a genuinely slow engine, so the ring backs up
    let mut cfgs = HashMap::new();
    cfgs.insert("det".to_string(), ModelServeCfg::new(4, 5.0)); // cap 32
    let (req_tx, req_rx) = std::sync::mpsc::channel();
    let (resp_tx, resp_rx) = std::sync::mpsc::channel::<Response>();
    const N: u64 = 500;
    for i in 0..N {
        req_tx.send(req(i, "det", 1e9, vec![0.5; 4])).unwrap();
    }
    drop(req_tx);
    let report =
        serve_with(&mut ex, &cfgs, FrontDoorCfg::default(), req_rx, resp_tx)
            .unwrap();
    assert_eq!(report.submitted, N);
    assert!(report.rejected > 0, "overload must reach the queue cap");
    assert!(report.served > 0, "the engine still makes progress");
    assert_eq!(
        report.accounted(),
        report.submitted,
        "conservation: {}",
        report.digest()
    );
    let answers: Vec<Response> = resp_rx.try_iter().collect();
    assert_eq!(answers.len() as u64, N, "every request is answered");
    let rejects: Vec<&str> = answers
        .iter()
        .filter_map(|r| r.error.as_deref())
        .filter(|e| e.contains("queue full"))
        .collect();
    assert!(!rejects.is_empty());
    for e in &rejects {
        assert!(e.contains("retry after"), "{e}");
        assert!(!e.contains("after 0 ms"), "useless hint: {e}");
    }
}

/// Two tenants flooding the same overloaded model: equal weights split
/// service ~evenly, a 3:1 weight tilts it. The queue cap is raised so
/// nothing is rejected — the split is decided purely by weighted-fair
/// batch assembly (FIFO would keep the weighted case even).
#[test]
fn fair_dequeue_shares_an_overloaded_model_by_weight() {
    let mk_load = |tenant| TenantLoad {
        tenant,
        streams: 4,
        fps: 50.0,
        model: "det".to_string(),
        slo_ms: 300.0, // overload resolves by shedding, never rejection
        start_ms: 0.0,
        stop_ms: 2_000.0,
        static_scene: false,
    };
    let mk_hc = || {
        let mut cfgs = HashMap::new();
        let mut c = ModelServeCfg::new(4, 5.0);
        c.queue_cap = 2048; // larger than the whole offered load
        cfgs.insert("det".to_string(), c);
        HarnessCfg {
            cfgs,
            front: FrontDoorCfg::default(), // isolation on, unlimited rate
            duration_ms: 2_000.0,
            service_ms: 20.0, // ~200 req/s capacity vs 400 req/s offered
        }
    };
    let r = run_front_harness(&mk_hc(), &[mk_load(1), mk_load(2)], 3);
    assert_eq!(r.accounted(), r.submitted, "{}", r.digest());
    assert_eq!(r.rejected, 0, "the cap must not bind in this test");
    assert!(r.shed > 0, "the load must actually exceed capacity");
    let a = r.per_tenant[&1].served as f64;
    let b = r.per_tenant[&2].served as f64;
    assert!(a > 0.0 && b > 0.0);
    assert!(
        (a - b).abs() / a.max(b) < 0.15,
        "equal-weight split skewed: {a} vs {b}"
    );
    let mut hc = mk_hc();
    hc.front.tenants.weights.insert(1, 3.0);
    let r = run_front_harness(&hc, &[mk_load(1), mk_load(2)], 3);
    assert_eq!(r.accounted(), r.submitted, "{}", r.digest());
    let a = r.per_tenant[&1].served as f64;
    let b = r.per_tenant[&2].served as f64;
    assert!(b > 0.0, "the light tenant still gets its share");
    assert!(a > 2.0 * b, "weight 3 vs 1 must tilt the split: {a} vs {b}");
}

/// The full isolation experiment: the steady tenant survives the flood
/// only when isolation is on.
#[test]
fn isolation_experiment_protects_tenant_b() {
    let (no_iso, iso) = isolation_comparison(true);
    assert_eq!(no_iso.accounted(), no_iso.submitted, "{}", no_iso.digest());
    assert_eq!(iso.accounted(), iso.submitted, "{}", iso.digest());
    let b_iso = iso.per_tenant.get(&2).unwrap().attainment();
    let b_open = no_iso.per_tenant.get(&2).unwrap().attainment();
    assert!(
        b_iso > b_open + 0.15,
        "isolation must visibly protect B: {b_iso:.3} vs {b_open:.3}"
    );
}

/// Content frontend: a repeated frame on the same stream is answered by
/// frame-diff; identical content on a *different* stream is answered by
/// the content-hash cache.
#[test]
fn filter_and_cache_answer_without_engine_work() {
    let mut cfgs = HashMap::new();
    cfgs.insert("det".to_string(), ModelServeCfg::new(4, 5.0));
    let mut front = FrontDoorCfg::default();
    front.filter = Some(FilterCfg::default());
    let mut door = FrontDoor::new(&cfgs, &front);
    let payload: Vec<f32> = (0..16).map(|i| i as f32 / 16.0).collect();
    let mk = |id, stream| Request {
        id,
        model: "det".into(),
        data: payload.clone(),
        slo_ms: 1e9,
        tenant: 0,
        stream,
        submitted: Instant::now(),
    };
    // First frame of stream 1: no reference yet — queued for the engine.
    assert!(matches!(door.offer(mk(1, 1), 0.0), Offer::Queued));
    door.record_result(1, &[9.0, 9.0], 1.0);
    // Same stream, same scene: frame-diff answer from the last result.
    match door.offer(mk(2, 1), 2.0) {
        Offer::Answered { output, cached, .. } => {
            assert_eq!(output, vec![9.0, 9.0]);
            assert!(!cached, "same-stream hits are frame-diff, not cache");
        }
        _ => panic!("expected a frame-diff answer"),
    }
    // Different stream, identical content: cross-stream cache answer.
    match door.offer(mk(3, 2), 3.0) {
        Offer::Answered { cached, .. } => assert!(cached),
        _ => panic!("expected a cache answer"),
    }
}

/// The sharded front door is deterministic under a fixed seed: three
/// models hashed across three shards, three tenants, two identical runs,
/// identical digests.
#[test]
fn sharded_path_is_deterministic_under_fixed_seed() {
    let mut cfgs = HashMap::new();
    for m in ["det", "classifier", "embedder"] {
        cfgs.insert(m.to_string(), ModelServeCfg::new(4, 5.0));
    }
    let mut front = FrontDoorCfg::default();
    front.shards = 3;
    let loads: Vec<TenantLoad> = ["det", "classifier", "embedder"]
        .iter()
        .enumerate()
        .map(|(i, m)| TenantLoad {
            tenant: i as u32,
            streams: 3,
            fps: 40.0,
            model: m.to_string(),
            slo_ms: 500.0,
            start_ms: 0.0,
            stop_ms: 3_000.0,
            static_scene: i == 0,
        })
        .collect();
    let hc = HarnessCfg {
        cfgs,
        front,
        duration_ms: 3_000.0,
        service_ms: 8.0,
    };
    let a = run_front_harness(&hc, &loads, 42);
    let b = run_front_harness(&hc, &loads, 42);
    assert_eq!(a.digest(), b.digest());
    assert_eq!(a.accounted(), a.submitted, "{}", a.digest());
    assert!(a.per_model.len() == 3, "all three shards saw work");
}

/// Sim-side frontend on the `static` preset: invariants hold, the
/// workload fingerprint is identical with the frontend on or off, and
/// the frontend actually filters.
#[test]
fn sim_frontend_keeps_the_workload_fingerprint() {
    let mut on = preset("static").expect("static preset");
    on.duration_ms = 60_000.0;
    on.n_sources = 2;
    let mut off = on.clone();
    off.frontend = false;
    let (m_off, inv_off) =
        run_checked(&Scenario::build(off), SchedulerKind::OctopInf);
    let (m_on, inv_on) =
        run_checked(&Scenario::build(on), SchedulerKind::OctopInf);
    assert!(inv_off.ok(), "{:?}", inv_off.violations);
    assert!(inv_on.ok(), "{:?}", inv_on.violations);
    assert_eq!(
        inv_off.workload_fingerprint(),
        inv_on.workload_fingerprint(),
        "the frontend changes admission, never the scene"
    );
    assert_eq!(m_off.filtered, 0);
    assert!(m_on.filtered > 0, "static scenes must filter");
    assert_eq!(inv_on.filtered_units, m_on.filtered);
}
