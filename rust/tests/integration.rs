//! Cross-module integration tests: scheduler → plan → simulator paths for
//! every system, runtime loading of the real AOT artifacts, and the
//! paper-shape assertions that tie the reproduction together.

use octopinf::cluster::Cluster;
use octopinf::config::ExperimentConfig;
use octopinf::coordinator::controller::make_scheduler;
use octopinf::coordinator::{SchedEnv, SchedulerKind};
use octopinf::pipeline::standard_pipelines;
use octopinf::profiles::ProfileStore;
use octopinf::sim::{preset, run, Scenario};

fn edge_pipelines(n: usize) -> Vec<octopinf::pipeline::PipelineDag> {
    standard_pipelines(n)
        .into_iter()
        .map(|mut p| {
            p.source_device += 1;
            p
        })
        .collect()
}

#[test]
fn every_scheduler_produces_complete_plans() {
    let cluster = Cluster::paper_testbed();
    let profiles = ProfileStore::analytic();
    let pipelines = edge_pipelines(9);
    let env = SchedEnv::bootstrap(&cluster, &profiles, &pipelines, vec![25.0; 10]);
    for kind in [
        SchedulerKind::OctopInf,
        SchedulerKind::OctopInfNoCoral,
        SchedulerKind::OctopInfStaticBatch,
        SchedulerKind::OctopInfServerOnly,
        SchedulerKind::Distream,
        SchedulerKind::Jellyfish,
        SchedulerKind::Rim,
    ] {
        let plan = make_scheduler(kind, 1).plan(&env);
        // One assignment per (pipeline, model), each with >= 1 binding.
        assert_eq!(plan.assignments.len(), 9 * 3, "{kind:?}");
        for a in &plan.assignments {
            assert!(!a.bindings.is_empty(), "{kind:?} {}/{}", a.pipeline, a.model);
            assert!(a.cfg.instances >= 1);
            for b in &a.bindings {
                assert_eq!(b.gpu.device, a.cfg.device, "{kind:?}");
                assert!(
                    b.gpu.gpu < cluster.device(a.cfg.device).gpus.len(),
                    "{kind:?} bad gpu index"
                );
            }
        }
    }
}

#[test]
fn octopinf_beats_every_baseline_on_standard_scenario() {
    // The paper's headline (Fig. 6a): highest effective throughput and a
    // slim latency distribution. 6 sim-minutes keeps CI fast while still
    // crossing a full scheduling period.
    let mut cfg = ExperimentConfig::default();
    cfg.duration_ms = 6.0 * 60_000.0;
    let sc = Scenario::build(cfg);
    let octo = run(&sc, SchedulerKind::OctopInf);
    for kind in [SchedulerKind::Distream, SchedulerKind::Jellyfish, SchedulerKind::Rim] {
        let base = run(&sc, kind);
        assert!(
            octo.effective_throughput() > base.effective_throughput(),
            "{kind:?}: {} >= octopinf {}",
            base.effective_throughput(),
            octo.effective_throughput()
        );
    }
}

#[test]
fn octopinf_violation_rate_is_low() {
    let mut cfg = ExperimentConfig::default();
    cfg.duration_ms = 6.0 * 60_000.0;
    let sc = Scenario::build(cfg);
    let m = run(&sc, SchedulerKind::OctopInf);
    assert!(m.violation_rate() < 0.10, "violations {}", m.violation_rate());
}

#[test]
fn jellyfish_collapses_under_lte() {
    // Fig. 7 context: centralized serving cannot survive LTE uplinks.
    let mut cfg = preset("lte").unwrap();
    cfg.duration_ms = 5.0 * 60_000.0;
    let sc = Scenario::build(cfg);
    let octo = run(&sc, SchedulerKind::OctopInf);
    let jf = run(&sc, SchedulerKind::Jellyfish);
    assert!(
        jf.effective_throughput() < octo.effective_throughput() * 0.5,
        "jellyfish {} vs octopinf {}",
        jf.effective_throughput(),
        octo.effective_throughput()
    );
}

#[test]
fn doubled_workload_degrades_baselines_more() {
    // Fig. 8: effective ratio of baselines collapses at 2x workload.
    let mut cfg = preset("double").unwrap();
    cfg.duration_ms = 5.0 * 60_000.0;
    let sc = Scenario::build(cfg);
    let octo = run(&sc, SchedulerKind::OctopInf);
    let rim = run(&sc, SchedulerKind::Rim);
    assert!(octo.effective_throughput() > 1.5 * rim.effective_throughput());
}

#[test]
fn ablations_rank_as_in_fig10() {
    let mut cfg = ExperimentConfig::default();
    cfg.duration_ms = 6.0 * 60_000.0;
    let sc = Scenario::build(cfg);
    let full = run(&sc, SchedulerKind::OctopInf).effective_throughput();
    let no_coral = run(&sc, SchedulerKind::OctopInfNoCoral).effective_throughput();
    let server_only =
        run(&sc, SchedulerKind::OctopInfServerOnly).effective_throughput();
    assert!(full > no_coral, "full {full} vs no-coral {no_coral}");
    assert!(
        no_coral > server_only,
        "no-coral {no_coral} vs server-only {server_only}"
    );
    // The paper reports ~10% for w/o CORAL; accept a loose band.
    assert!(no_coral > full * 0.5, "no-coral too weak: {no_coral} vs {full}");
}

#[test]
fn timeline_tracks_workload() {
    // Fig. 6d: OctopInf's per-minute effective throughput follows the
    // offered workload within a reasonable margin.
    let mut cfg = ExperimentConfig::default();
    cfg.duration_ms = 6.0 * 60_000.0;
    let sc = Scenario::build(cfg);
    let m = run(&sc, SchedulerKind::OctopInf);
    assert!(m.timeline.len() >= 5);
    let tracked = m
        .timeline
        .iter()
        .skip(1) // warmup minute
        .filter(|(w, e)| *e >= 0.5 * w)
        .count();
    assert!(
        tracked * 10 >= (m.timeline.len() - 1) * 7,
        "workload tracked only {tracked}/{} minutes",
        m.timeline.len() - 1
    );
}

// ---------------------------------------------------------------------------
// Parallel experiment runner: parallelism must never change results.
// ---------------------------------------------------------------------------

#[test]
fn parallel_runner_reproduces_sequential_metrics() {
    use octopinf::experiments::{run_grid, RunSpec};
    let cfg = preset("smoke").unwrap();
    let specs: Vec<RunSpec> = SchedulerKind::all_main()
        .iter()
        .map(|&k| RunSpec::new(k.label(), cfg.clone(), k))
        .collect();
    let seq = run_grid(&specs, 1);
    let par = run_grid(&specs, specs.len());
    for (spec, (a, b)) in specs.iter().zip(seq.iter().zip(&par)) {
        assert_eq!(a.on_time, b.on_time, "{}", spec.label);
        assert_eq!(a.late, b.late, "{}", spec.label);
        assert_eq!(a.dropped, b.dropped, "{}", spec.label);
        assert_eq!(a.peak_memory_mb, b.peak_memory_mb, "{}", spec.label);
        assert_eq!(a.mean_gpu_util, b.mean_gpu_util, "{}", spec.label);
        assert_eq!(a.timeline, b.timeline, "{}", spec.label);
        for q in [0.5, 0.95, 0.99] {
            assert_eq!(
                a.latency.quantile(q),
                b.latency.quantile(q),
                "{} q={q}",
                spec.label
            );
        }
    }
}

#[test]
fn figure_tables_are_byte_identical_across_job_counts() {
    // The acceptance bar for the parallel runner: regenerated tables with
    // --jobs N must match --jobs 1 byte for byte.
    let seq = octopinf::experiments::fig6_overall(true, 1).to_markdown();
    let par = octopinf::experiments::fig6_overall(true, 4).to_markdown();
    assert_eq!(seq, par);
}

// ---------------------------------------------------------------------------
// Real PJRT runtime over the AOT artifacts (skipped when absent).
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = octopinf::runtime::default_artifacts_dir();
    dir.join("manifest.tsv").exists().then_some(dir)
}

#[cfg(feature = "pjrt")]
#[test]
fn runtime_loads_and_executes_all_model_families() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let mut rt = octopinf::runtime::Runtime::new(&dir).unwrap();
    let models: Vec<String> = rt.models().into_iter().map(String::from).collect();
    assert_eq!(models.len(), 5, "expected 5 model families");
    for model in &models {
        let meta = rt.manifest.get(model, 1).unwrap().clone();
        let per_in: usize = meta.input_shape.iter().product();
        let input = vec![0.25f32; per_in];
        let out = rt.execute_padded(model, 1, 1, &input).unwrap();
        let per_out: usize = meta.output_shape.iter().product();
        assert_eq!(out.len(), per_out, "{model}");
        assert!(out.iter().all(|x| x.is_finite()), "{model} non-finite");
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn runtime_padding_preserves_real_rows() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let mut rt = octopinf::runtime::Runtime::new(&dir).unwrap();
    let meta = rt.manifest.get("classifier", 4).unwrap().clone();
    let per_in: usize = meta.input_shape.iter().product();
    // 2 real rows in a batch-4 engine must match a full batch-4 run of the
    // same rows (padding rows can't change real outputs).
    let rows: Vec<f32> = (0..2 * per_in).map(|i| (i % 17) as f32 * 0.01).collect();
    let padded = rt.execute_padded("classifier", 4, 2, &rows).unwrap();
    let mut full = rows.clone();
    full.resize(4 * per_in, 0.0);
    let direct = rt.engine("classifier", 4).unwrap().execute(&full).unwrap();
    let per_out: usize = meta.output_shape.iter().product();
    assert_eq!(&padded[..], &direct[..2 * per_out]);
}

#[cfg(feature = "pjrt")]
#[test]
fn detector_outputs_decoded_boxes() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let mut rt = octopinf::runtime::Runtime::new(&dir).unwrap();
    let meta = rt.manifest.get("det_s", 1).unwrap().clone();
    let per_in: usize = meta.input_shape.iter().product();
    let input = vec![0.5f32; per_in];
    let out = rt.execute_padded("det_s", 1, 1, &input).unwrap();
    // Decoded rows are [x, y, w, h, scores...]: w/h positive, scores in
    // (0,1) — proves the Pallas decode kernel survived lowering.
    let ch = meta.output_shape[1];
    for row in out.chunks(ch) {
        assert!(row[2] > 0.0 && row[3] > 0.0, "w/h must be positive");
        for &s in &row[4..] {
            assert!((0.0..=1.0).contains(&s), "score {s}");
        }
    }
}
