//! Differential scheduler conformance suite: randomized adversarial
//! scenarios (flash crowds, bandwidth blackouts, device churn, SLO
//! pressure, skewed fan-out) through every scheduler under the invariant
//! engine, plus bit-exact cross-scheduler checks of the
//! scheduler-independent quantities.
//!
//! Every failure message leads with a one-line repro string; replay it with
//! `cargo run --release -- fuzz --repro fuzz:v1:seed=N`.

use std::collections::HashSet;

use octopinf::coordinator::SchedulerKind;
use octopinf::experiments::fuzz::run_conformance;
use octopinf::sim::{preset, run_checked, FuzzSpec, Scenario, ScenarioGen};

/// Root seed of the CI sweep; bump deliberately (it re-rolls the corpus).
const FUZZ_SEED0: u64 = 0x0C70_91FF;

fn sweep_size() -> usize {
    std::env::var("CONFORMANCE_SCENARIOS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(50)
}

#[test]
fn fuzzed_scenarios_hold_invariants_across_all_schedulers() {
    let n = sweep_size();
    let outcomes = run_conformance(FUZZ_SEED0, n, 0);
    assert_eq!(outcomes.len(), n);
    let mut failures = Vec::new();
    let mut total_runs = 0;
    let mut total_completions = 0u64;
    for o in &outcomes {
        total_runs += o.runs;
        total_completions += o.total_completions;
        if !o.ok() {
            failures.push(o.describe_failures());
        }
    }
    assert_eq!(total_runs, n * SchedulerKind::conformance_set().len());
    // Aggregate, not per-scenario: a fully-blacked-out corpus member may
    // legitimately complete nothing, but the sweep as a whole must work.
    assert!(total_completions > 0, "sweep completed zero queries");
    assert!(
        failures.is_empty(),
        "{} of {n} fuzzed scenarios failed; replay each with \
         `octopinf fuzz --repro <string>`:\n{}",
        failures.len(),
        failures.join("\n")
    );
}

#[test]
fn fuzz_corpus_is_diverse() {
    // The default CI sweep must actually exercise several adversarial
    // families, not collapse onto one — otherwise the suite silently
    // loses power. Fixed at the default sweep size on purpose: the
    // CONFORMANCE_SCENARIOS knob shrinks the expensive sweep above, and a
    // 3-scenario quick run drawing at most 3 classes must not fail here.
    let classes: HashSet<&'static str> = ScenarioGen::new(FUZZ_SEED0)
        .take(50)
        .map(|s| s.class.label())
        .collect();
    assert!(classes.len() >= 4, "corpus collapsed to {classes:?}");
}

#[test]
fn repro_string_replays_bit_identically() {
    let spec = FuzzSpec::sample(FUZZ_SEED0 ^ 0x1234);
    let replay = FuzzSpec::from_repro(&spec.repro()).expect("repro parses");
    for kind in [SchedulerKind::OctopInf, SchedulerKind::Rim] {
        let (m1, r1) = run_checked(&spec.build(), kind);
        let (m2, r2) = run_checked(&replay.build(), kind);
        assert_eq!(m1.on_time, m2.on_time, "{kind:?}");
        assert_eq!(m1.late, m2.late, "{kind:?}");
        assert_eq!(m1.dropped, m2.dropped, "{kind:?}");
        assert_eq!(r1.frames, r2.frames, "{kind:?}");
        assert_eq!(r1.objects_total, r2.objects_total, "{kind:?}");
        assert_eq!(r1.created, r2.created, "{kind:?}");
        assert_eq!(r1.in_flight, r2.in_flight, "{kind:?}");
    }
}

#[test]
fn paper_presets_hold_invariants_for_every_scheduler() {
    // The invariant engine is not only for fuzzed scenarios: the paper's
    // own smoke preset must be conserving under all seven variants.
    let sc = Scenario::build(preset("smoke").unwrap());
    for kind in [
        SchedulerKind::OctopInf,
        SchedulerKind::OctopInfNoCoral,
        SchedulerKind::OctopInfStaticBatch,
        SchedulerKind::OctopInfServerOnly,
        SchedulerKind::Distream,
        SchedulerKind::Jellyfish,
        SchedulerKind::Rim,
    ] {
        let (m, r) = run_checked(&sc, kind);
        assert!(
            r.ok(),
            "{kind:?} violated invariants on the smoke preset:\n{}",
            r.violations.join("\n")
        );
        assert_eq!(m.completed(), r.completed_objects, "{kind:?}");
        assert!(r.events > 0 && r.frames > 0, "{kind:?} ran nothing");
    }
}

#[test]
fn checked_run_matches_unchecked_run() {
    // Arming the invariant engine must not perturb simulation results.
    let sc = Scenario::build(preset("smoke").unwrap());
    for kind in SchedulerKind::conformance_set() {
        let plain = octopinf::sim::run(&sc, kind);
        let (checked, _) = run_checked(&sc, kind);
        assert_eq!(plain.on_time, checked.on_time, "{kind:?}");
        assert_eq!(plain.late, checked.late, "{kind:?}");
        assert_eq!(plain.dropped, checked.dropped, "{kind:?}");
        assert_eq!(plain.timeline, checked.timeline, "{kind:?}");
        for q in [0.5, 0.95, 0.99] {
            assert_eq!(
                plain.latency.quantile(q),
                checked.latency.quantile(q),
                "{kind:?} q={q}"
            );
        }
    }
}
