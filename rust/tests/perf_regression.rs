//! Hot-path bench regression gate (ROADMAP open perf item).
//!
//! `cargo bench --bench hotpath` writes `BENCH_hotpath.json`, and
//! `cargo bench --bench planner` merges its control-plane entries into the
//! same file; the committed baseline lives in `BENCH_hotpath.baseline.json`
//! (first toolchain run of `./ci.sh` captures it). The gate test is
//! `#[ignore]` by default — timing is meaningless under `cargo test`'s
//! load — and is run explicitly by `ci.sh` after the benches:
//!
//! ```sh
//! cargo bench --bench hotpath
//! cargo bench --bench planner
//! cargo test -q --test perf_regression -- --ignored
//! ```
//!
//! It fails if any entry regresses more than 25 % in ns/iter vs the
//! baseline. Entries present on one side only are reported but don't fail
//! (benches get added/renamed); refresh the baseline by deleting it and
//! re-running `ci.sh`.

use std::collections::HashMap;

/// Allowed slowdown before the gate trips.
const REGRESSION_FACTOR: f64 = 1.25;

/// Parse the `common::Recorder` JSON (one result object per line) without
/// serde: extract (name, ns_per_iter) pairs.
fn parse_bench_json(text: &str) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    for line in text.lines() {
        let Some(npos) = line.find("\"name\": \"") else { continue };
        let rest = &line[npos + 9..];
        let Some(endq) = rest.find('"') else { continue };
        let name = rest[..endq].to_string();
        let Some(vpos) = line.find("\"ns_per_iter\": ") else { continue };
        let tail = &line[vpos + 15..];
        let num: String = tail
            .chars()
            .take_while(|c| c.is_ascii_digit() || *c == '.' || *c == '-')
            .collect();
        if let Ok(v) = num.parse::<f64>() {
            out.push((name, v));
        }
    }
    out
}

#[test]
fn bench_json_parser_reads_recorder_format() {
    let text = r#"{
  "bench": "hotpath",
  "results": [
    {"name": "arrival window rate+cv", "iters": 20000, "ns_per_iter": 41.5},
    {"name": "percentiles 500k samples", "iters": 5, "ns_per_iter": 2500000.0}
  ]
}
"#;
    let parsed = parse_bench_json(text);
    assert_eq!(parsed.len(), 2);
    assert_eq!(parsed[0].0, "arrival window rate+cv");
    assert!((parsed[0].1 - 41.5).abs() < 1e-9);
    assert!((parsed[1].1 - 2_500_000.0).abs() < 1e-6);
}

#[test]
#[ignore = "perf gate: run `cargo bench --bench hotpath` first (ci.sh does)"]
fn hotpath_no_entry_regresses_beyond_25_percent() {
    let baseline = match std::fs::read_to_string("BENCH_hotpath.baseline.json") {
        Ok(t) => t,
        Err(_) => {
            eprintln!(
                "no committed baseline (BENCH_hotpath.baseline.json); \
                 ci.sh captures one from the first bench run — skipping gate"
            );
            return;
        }
    };
    let fresh = std::fs::read_to_string("BENCH_hotpath.json").expect(
        "BENCH_hotpath.json missing — run `cargo bench --bench hotpath` first",
    );
    let base = parse_bench_json(&baseline);
    assert!(!base.is_empty(), "baseline parsed to zero entries");
    let cur: HashMap<String, f64> = parse_bench_json(&fresh).into_iter().collect();
    // The planner bench merges into the same file; a fresh run with no
    // "planner ..." entries means ci.sh skipped `cargo bench --bench
    // planner` and the gate would silently stop covering the control plane.
    assert!(
        cur.keys().any(|n| n.starts_with("planner ")),
        "no planner entries in BENCH_hotpath.json — \
         run `cargo bench --bench planner` after the hotpath bench"
    );
    let mut regressions = Vec::new();
    for (name, b) in base {
        match cur.get(&name) {
            Some(&c) if c > b * REGRESSION_FACTOR => regressions.push(format!(
                "{name}: {b:.0} -> {c:.0} ns/iter (+{:.0}%)",
                (c / b - 1.0) * 100.0
            )),
            Some(_) => {}
            None => eprintln!("note: baseline entry {name:?} not in fresh run"),
        }
    }
    assert!(
        regressions.is_empty(),
        "hot paths regressed >{:.0}% vs BENCH_hotpath.baseline.json:\n{}",
        (REGRESSION_FACTOR - 1.0) * 100.0,
        regressions.join("\n")
    );
}
