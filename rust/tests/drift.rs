//! Drift-triggered incremental replanning: acceptance tests.
//!
//! The control plane used to replan only on the fixed 6-minute clock, so
//! every reactive fuzz family (flash crowds, blackouts, churn) ran its
//! whole horizon on the stale initial plan with only the inline
//! autoscaler reacting. These tests pin the PR's claims: drift mode beats
//! fixed-period OctopInf on SLO attainment in the reactive families (same
//! seeds), and every mid-run plan migration conserves in-flight queries
//! under the invariant engine.

use octopinf::coordinator::{ReplanMode, SchedulerKind};
use octopinf::experiments::drift::drift_comparison;
use octopinf::sim::{run_checked, FuzzClass, ScenarioGen};

/// Root seed for the comparison sweeps (distinct from the conformance
/// corpus so the two suites don't share scenarios).
const DRIFT_SEED0: u64 = 0x0D21_F7ED;

#[test]
fn drift_beats_fixed_period_on_reactive_families() {
    // Same fuzzed seeds, both modes, invariants armed in every run. The
    // acceptance bar: flash crowds and blackouts — the families whose
    // whole point is mid-run change — must do better with drift-triggered
    // replanning, and nothing may violate an invariant anywhere.
    let cmps = drift_comparison(DRIFT_SEED0, 6, 0);
    for c in &cmps {
        assert_eq!(
            c.violations,
            0,
            "{}: invariant violations during the comparison",
            c.class.label()
        );
    }
    for class in [FuzzClass::FlashCrowd, FuzzClass::Blackout] {
        let c = cmps.iter().find(|c| c.class == class).unwrap();
        assert!(c.scenarios > 0, "{}: no scenarios sampled", class.label());
        assert!(
            c.drift.attainment() > c.periodic.attainment(),
            "{}: drift {:.4} must beat periodic {:.4} (on_time {} vs {})",
            class.label(),
            c.drift.attainment(),
            c.periodic.attainment(),
            c.drift.on_time,
            c.periodic.on_time,
        );
        assert!(
            c.drift.plans > c.periodic.plans,
            "{}: drift mode installed no extra plans ({} vs {})",
            class.label(),
            c.drift.plans,
            c.periodic.plans,
        );
    }
}

#[test]
fn flash_crowd_plan_swaps_conserve_in_flight_queries() {
    // A flash-crowd scenario must straddle at least one drift-triggered
    // plan swap, and the checker's before/after census around every swap
    // must balance (no query lost or double-counted in migration).
    let mut straddled = false;
    let mut tried = 0;
    for spec in ScenarioGen::new(DRIFT_SEED0 ^ 0xF1A5).take(400) {
        if spec.class != FuzzClass::FlashCrowd {
            continue;
        }
        let mut spec = spec;
        spec.cfg.replan = ReplanMode::Drift;
        let (_m, r) = run_checked(&spec.build(), SchedulerKind::OctopInf);
        assert!(
            r.ok(),
            "{}: invariant violations across plan swaps:\n{}",
            spec.repro(),
            r.violations.join("\n")
        );
        if r.migrations >= 1 {
            straddled = true;
        }
        tried += 1;
        if tried >= 5 {
            break;
        }
    }
    assert!(tried > 0, "no flash-crowd specs sampled");
    assert!(
        straddled,
        "no flash-crowd scenario triggered a mid-run plan migration"
    );
}

#[test]
fn drift_mode_holds_invariants_across_all_schedulers() {
    // The drift axis must not break conformance for any scheduler:
    // baselines take the default full-replan path, OctopInf the repair
    // path, and the differential cross-checks still have to agree.
    use octopinf::experiments::fuzz::run_conformance_mode;
    let outcomes = run_conformance_mode(DRIFT_SEED0, 8, 0, ReplanMode::Drift);
    let failures: Vec<String> = outcomes
        .iter()
        .filter(|o| !o.ok())
        .map(|o| o.describe_failures())
        .collect();
    assert!(
        failures.is_empty(),
        "{} drift-mode scenarios failed:\n{}",
        failures.len(),
        failures.join("\n")
    );
    assert!(outcomes.iter().map(|o| o.total_completions).sum::<u64>() > 0);
}
