//! Observability-layer acceptance: the exported trace is a pure function
//! of the scenario (byte-identical at any `--sim-jobs`), every query lane
//! balances its Begin/End spans, SLO-miss attribution sums to the
//! reported latency bit-for-bit under the invariant engine, arming the
//! tracer never perturbs a run, and the serve report's Prometheus
//! exposition round-trips through the in-tree parser.

use std::collections::HashMap;

use octopinf::coordinator::SchedulerKind;
use octopinf::experiments::fuzz::traced_replay;
use octopinf::experiments::{run_front_harness, HarnessCfg, TenantLoad};
use octopinf::obs::{
    check_balanced, chrome_trace, promtext, validate_json, TraceEvent,
};
use octopinf::serving::{FrontDoorCfg, ModelServeCfg};
use octopinf::sim::{preset, run_traced_with, run_with, FuzzSpec, Scenario};

/// The 2-cluster fuzz scenario the byte-identity tests replay.
fn two_cluster_spec() -> FuzzSpec {
    FuzzSpec::from_repro("fuzz:v1:seed=11:clusters=2")
        .expect("repro string parses")
}

/// The exported Chrome-trace JSON is byte-identical at any `--sim-jobs`:
/// per-partition logs merge in partition order and timestamps are
/// sim-clock, so the worker count can leave no fingerprint.
#[test]
fn trace_bytes_identical_across_sim_jobs() {
    let spec = two_cluster_spec();
    let (m1, r1, parts1) = traced_replay(&spec, 1);
    let (m4, r4, parts4) = traced_replay(&spec, 4);
    assert!(r1.ok(), "violations:\n{}", r1.violations.join("\n"));
    assert!(r4.ok(), "violations:\n{}", r4.violations.join("\n"));
    assert_eq!(m1.digest(), m4.digest(), "--sim-jobs changed the metrics");
    assert_eq!(parts1.len(), 2, "two clusters, two partition logs");
    let n: usize = parts1.iter().map(Vec::len).sum();
    assert!(n > 0, "traced replay recorded no events");
    let json1 = chrome_trace(&parts1);
    let json4 = chrome_trace(&parts4);
    assert_eq!(json1, json4, "--sim-jobs changed the exported trace bytes");
}

/// Every query lane's Begin/End spans balance in every partition, the
/// export parses as JSON, and the control lane carries the planner
/// rounds (at least the initial plan per partition).
#[test]
fn trace_spans_balance_and_export_validates() {
    let spec = two_cluster_spec();
    let (_m, report, parts) = traced_replay(&spec, 2);
    assert!(report.ok(), "violations:\n{}", report.violations.join("\n"));
    for (k, events) in parts.iter().enumerate() {
        check_balanced(events)
            .unwrap_or_else(|e| panic!("partition {k}: unbalanced spans: {e}"));
        let plans = events
            .iter()
            .filter(|ev| matches!(ev, TraceEvent::Plan { .. }))
            .count();
        assert!(plans >= 1, "partition {k} traced no planner rounds");
    }
    let json = chrome_trace(&parts);
    validate_json(&json).expect("exporter emitted invalid JSON");
    assert!(json.contains("\"cat\":\"query\""), "no query spans exported");
    assert!(json.contains("\"trigger\":\"initial\""), "no initial plan");
}

/// With the invariant engine armed (invariant #8), every completed
/// query's transfer/queue/exec components fold to its end-to-end latency
/// bit-for-bit, the attribution sketches cover exactly the completed
/// units, and the dominant-cause miss buckets tile `late` exactly.
#[test]
fn attribution_components_sum_bit_for_bit() {
    for repro in ["fuzz:v1:seed=11:clusters=2", "fuzz:v1:seed=77:faults=2"] {
        let spec = FuzzSpec::from_repro(repro).expect("repro parses");
        let (m, report, _parts) = traced_replay(&spec, 1);
        assert!(
            report.ok(),
            "{repro}: violations:\n{}",
            report.violations.join("\n")
        );
        assert!(m.completed() > 0, "{repro}: replay completed nothing");
        assert_eq!(
            m.attrib.transfer.count(),
            m.completed(),
            "{repro}: attribution misses completed units"
        );
        assert_eq!(
            m.attrib.misses(),
            m.late,
            "{repro}: dominant-cause buckets do not tile the misses"
        );
    }
}

/// Arming the full tracer changes nothing: metrics digests (and the
/// timeline) with tracing on equal the plain run byte-for-byte.
#[test]
fn tracing_never_perturbs_the_digest() {
    let mut cfg = preset("smoke").unwrap();
    cfg.clusters = 2;
    let sc = Scenario::build(cfg);
    let plain = run_with(&sc, SchedulerKind::OctopInf, 1);
    let (traced, parts) = run_traced_with(&sc, SchedulerKind::OctopInf, 1);
    assert!(parts.iter().map(Vec::len).sum::<usize>() > 0);
    assert_eq!(traced.digest(), plain.digest(), "tracing changed the run");
    assert_eq!(traced.timeline, plain.timeline);
}

/// The serve report's Prometheus text exposition round-trips: parsed
/// samples match the report's counters and re-rendering is
/// byte-identical (the `--metrics-out` contract).
#[test]
fn serve_report_prometheus_round_trip() {
    let hc = {
        let mut cfgs = HashMap::new();
        cfgs.insert("det".to_string(), ModelServeCfg::new(4, 5.0));
        HarnessCfg {
            cfgs,
            front: FrontDoorCfg::default(),
            duration_ms: 1_000.0,
            service_ms: 5.0,
        }
    };
    let loads = vec![TenantLoad {
        tenant: 1,
        streams: 2,
        fps: 30.0,
        model: "det".to_string(),
        slo_ms: 200.0,
        start_ms: 0.0,
        stop_ms: 1_000.0,
        static_scene: false,
    }];
    let report = run_front_harness(&hc, &loads, 0xB0B);
    assert!(report.submitted > 0 && report.served > 0);
    let text = promtext::render_serve_report(&report);
    let samples = promtext::parse(&text).expect("exposition parses");
    let get = |name: &str, key: &str, val: &str| -> f64 {
        samples
            .iter()
            .find(|s| s.name == name && s.label(key) == Some(val))
            .unwrap_or_else(|| panic!("missing {name}{{{key}={val}}}"))
            .value
    };
    assert_eq!(
        get("octopinf_requests_total", "outcome", "submitted"),
        report.submitted as f64
    );
    assert_eq!(
        get("octopinf_requests_total", "outcome", "served"),
        report.served as f64
    );
    assert_eq!(
        get("octopinf_tenant_requests_total", "tenant", "1"),
        report.submitted as f64,
        "single-tenant load: the tenant lane carries every submission"
    );
    assert_eq!(text, promtext::render_serve_report(&report));
}
