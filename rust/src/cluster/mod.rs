//! Edge-cluster substrate: heterogeneous devices and GPUs (paper §IV-A1).
//!
//! The paper's testbed (4×RTX-3090 server + 1 AGX + 5 Xavier NX + 3 Orin
//! Nano) is modelled as device classes with a compute scale (latency
//! multiplier vs. the server GPU), GPU memory, and a utilization capacity —
//! exactly the quantities the schedulers consume (Eq. 4/5).

mod device;
mod topology;

pub use device::{Device, DeviceClass, Gpu};
pub use topology::Cluster;
