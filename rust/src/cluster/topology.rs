//! Cluster topology: the server + edge devices, with lookup helpers.

use super::device::{Device, DeviceClass};

/// The whole deployment. Device 0 is always the server (paper convention:
/// the Controller runs there).
#[derive(Clone, Debug)]
pub struct Cluster {
    pub devices: Vec<Device>,
}

impl Cluster {
    /// The paper's testbed: 1 server (4×3090) + 1 AGX + 5 Xavier NX +
    /// 3 Orin Nano (§IV-A1). Devices 1..=9 host one camera each.
    pub fn paper_testbed() -> Cluster {
        let mut devices = vec![Device::new(0, "server", DeviceClass::Server)];
        devices.push(Device::new(1, "agx0", DeviceClass::JetsonAgx));
        for i in 0..5 {
            devices.push(Device::new(
                2 + i,
                &format!("nx{i}"),
                DeviceClass::XavierNx,
            ));
        }
        for i in 0..3 {
            devices.push(Device::new(
                7 + i,
                &format!("orin{i}"),
                DeviceClass::OrinNano,
            ));
        }
        Cluster { devices }
    }

    /// Small cluster for unit tests / quickstart: server + 2 edge devices.
    pub fn small() -> Cluster {
        Cluster {
            devices: vec![
                Device::new(0, "server", DeviceClass::Server),
                Device::new(1, "nx0", DeviceClass::XavierNx),
                Device::new(2, "orin0", DeviceClass::OrinNano),
            ],
        }
    }

    pub fn server(&self) -> &Device {
        &self.devices[0]
    }

    pub fn edge_devices(&self) -> impl Iterator<Item = &Device> {
        self.devices.iter().filter(|d| !d.is_server())
    }

    pub fn n_edge(&self) -> usize {
        self.edge_devices().count()
    }

    /// Total GPU count across the cluster.
    pub fn n_gpus(&self) -> usize {
        self.devices.iter().map(|d| d.gpus.len()).sum()
    }

    /// Map a data-source device id (1-based edge hosts) safely.
    pub fn device(&self, id: usize) -> &Device {
        &self.devices[id]
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.devices.is_empty() {
            return Err("empty cluster".into());
        }
        if !self.devices[0].is_server() {
            return Err("device 0 must be the server".into());
        }
        for (i, d) in self.devices.iter().enumerate() {
            if d.id != i {
                return Err(format!("device {i} has mismatched id {}", d.id));
            }
            if d.gpus.is_empty() {
                return Err(format!("device {i} has no GPU"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_testbed_shape() {
        let c = Cluster::paper_testbed();
        assert_eq!(c.devices.len(), 10);
        assert_eq!(c.n_edge(), 9);
        assert_eq!(c.n_gpus(), 4 + 9);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn device_zero_is_server() {
        assert!(Cluster::paper_testbed().server().is_server());
        assert!(Cluster::small().server().is_server());
    }

    #[test]
    fn validate_rejects_id_mismatch() {
        let mut c = Cluster::small();
        c.devices[1].id = 9;
        assert!(c.validate().is_err());
    }
}
