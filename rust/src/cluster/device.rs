//! Device and GPU models.

/// Hardware class of a host (paper testbed §IV-A1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DeviceClass {
    /// Edge server: 4× RTX 3090 class GPUs.
    Server,
    /// NVIDIA Jetson AGX Xavier.
    JetsonAgx,
    /// NVIDIA Jetson Xavier NX.
    XavierNx,
    /// NVIDIA Jetson Orin Nano.
    OrinNano,
}

impl DeviceClass {
    /// Latency multiplier relative to a server GPU (calibrated against
    /// published MLPerf-style ratios for these parts; the schedulers only
    /// need the *ordering and rough magnitude* to reproduce the paper).
    pub fn compute_scale(&self) -> f64 {
        match self {
            DeviceClass::Server => 1.0,
            DeviceClass::JetsonAgx => 2.5,
            DeviceClass::XavierNx => 4.0,
            DeviceClass::OrinNano => 5.0,
        }
    }

    /// GPU memory per device (MB) available to inference.
    pub fn gpu_mem_mb(&self) -> f64 {
        match self {
            DeviceClass::Server => 24_000.0, // per 3090
            DeviceClass::JetsonAgx => 16_000.0,
            DeviceClass::XavierNx => 6_000.0,
            DeviceClass::OrinNano => 4_000.0,
        }
    }

    /// Number of GPUs on the device.
    pub fn gpu_count(&self) -> usize {
        match self {
            DeviceClass::Server => 4,
            _ => 1,
        }
    }

    /// Concurrent inference streams the hardware sustains without
    /// co-location interference (CORAL's spatial capacity).
    pub fn streams_per_gpu(&self) -> usize {
        match self {
            DeviceClass::Server => 4,
            DeviceClass::JetsonAgx => 3,
            DeviceClass::XavierNx => 2,
            DeviceClass::OrinNano => 2,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            DeviceClass::Server => "server",
            DeviceClass::JetsonAgx => "agx",
            DeviceClass::XavierNx => "xavier_nx",
            DeviceClass::OrinNano => "orin_nano",
        }
    }
}

/// One physical GPU.
#[derive(Clone, Debug)]
pub struct Gpu {
    pub mem_mb: f64,
    /// Max aggregate utilization before co-location interference (Eq. 5).
    pub util_cap: f64,
    pub streams: usize,
}

/// One host in the cluster.
#[derive(Clone, Debug)]
pub struct Device {
    pub id: usize,
    pub name: String,
    pub class: DeviceClass,
    pub gpus: Vec<Gpu>,
}

impl Device {
    pub fn new(id: usize, name: &str, class: DeviceClass) -> Device {
        let gpus = (0..class.gpu_count())
            .map(|_| Gpu {
                mem_mb: class.gpu_mem_mb(),
                util_cap: 1.0,
                streams: class.streams_per_gpu(),
            })
            .collect();
        Device { id, name: name.to_string(), class, gpus }
    }

    pub fn is_server(&self) -> bool {
        self.class == DeviceClass::Server
    }

    pub fn total_mem_mb(&self) -> f64 {
        self.gpus.iter().map(|g| g.mem_mb).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn server_has_four_gpus() {
        let d = Device::new(0, "server", DeviceClass::Server);
        assert_eq!(d.gpus.len(), 4);
        assert!(d.is_server());
        assert!((d.total_mem_mb() - 96_000.0).abs() < 1e-6);
    }

    #[test]
    fn edge_ordering_slower_than_server() {
        assert!(DeviceClass::Server.compute_scale() < DeviceClass::JetsonAgx.compute_scale());
        assert!(DeviceClass::JetsonAgx.compute_scale() < DeviceClass::XavierNx.compute_scale());
        assert!(DeviceClass::XavierNx.compute_scale() < DeviceClass::OrinNano.compute_scale());
    }

    #[test]
    fn orin_has_fewest_streams() {
        let d = Device::new(3, "orin", DeviceClass::OrinNano);
        assert_eq!(d.gpus.len(), 1);
        assert!(d.gpus[0].streams <= DeviceClass::Server.streams_per_gpu());
    }
}
