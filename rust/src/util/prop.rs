//! Property-testing mini-framework (no proptest in the offline registry).
//!
//! `forall(seed, cases, gen, prop)` runs `prop` on `cases` random inputs; on
//! failure it re-runs a simple shrink loop (halving numeric fields via the
//! `Shrink` trait if implemented) and panics with the seed + case index so
//! failures replay deterministically.

use super::rng::Rng;

/// Run `prop` over `cases` random inputs drawn by `gen`.
///
/// Panics (with reproduction info) on the first failing case.
pub fn forall<T, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let mut case_rng = rng.fork(case as u64);
        let input = gen(&mut case_rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed (seed={seed}, case={case}):\n  {msg}\n  input: {input:?}"
            );
        }
    }
}

/// Convenience: assert-style check inside a property.
pub fn check(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Draw a random vector of length in [lo, hi] with elements from `f`.
pub fn vec_of<T>(
    rng: &mut Rng,
    lo: usize,
    hi: usize,
    mut f: impl FnMut(&mut Rng) -> T,
) -> Vec<T> {
    let n = lo + rng.below(hi - lo + 1);
    (0..n).map(|_| f(rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(
            1,
            50,
            |r| r.below(100),
            |_| {
                count += 1;
                Ok(())
            },
        );
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        forall(
            2,
            100,
            |r| r.below(10),
            |&x| check(x < 5, format!("{x} >= 5")),
        );
    }

    #[test]
    fn vec_of_respects_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..100 {
            let v = vec_of(&mut r, 2, 7, |r| r.f64());
            assert!((2..=7).contains(&v.len()));
        }
    }
}
