//! ASCII / markdown table rendering for benchmark and figure output.
//! Every `figure N` subcommand prints its paper-table through this.

/// Column-aligned table with a header row.
#[derive(Clone, Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width != header width"
        );
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// GitHub-flavored markdown.
    pub fn to_markdown(&self) -> String {
        let w = self.widths();
        let fmt_row = |cells: &[String]| {
            let body: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<width$}", c, width = w[i]))
                .collect();
            format!("| {} |", body.join(" | "))
        };
        let sep: Vec<String> = w.iter().map(|&n| "-".repeat(n)).collect();
        let mut out = vec![fmt_row(&self.header), format!("| {} |", sep.join(" | "))];
        out.extend(self.rows.iter().map(|r| fmt_row(r)));
        out.join("\n")
    }

    /// Tab-separated (for piping into plotting tools).
    pub fn to_tsv(&self) -> String {
        let mut out = vec![self.header.join("\t")];
        out.extend(self.rows.iter().map(|r| r.join("\t")));
        out.join("\n")
    }
}

/// Format a float with fixed decimals, trimming "-0.0".
pub fn fnum(x: f64, decimals: usize) -> String {
    let s = format!("{:.*}", decimals, x);
    if s.starts_with("-0.") && s[1..].parse::<f64>() == Ok(0.0) {
        s[1..].to_string()
    } else {
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_markdown() {
        let mut t = Table::new(vec!["sys", "thpt"]);
        t.row(vec!["octopinf", "123.4"]);
        t.row(vec!["rim", "55.1"]);
        let md = t.to_markdown();
        assert!(md.contains("| sys      | thpt  |"));
        assert!(md.lines().count() == 4);
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn tsv_roundtrip_columns() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["1", "2"]);
        assert_eq!(t.to_tsv(), "a\tb\n1\t2");
    }

    #[test]
    fn fnum_trims_negative_zero() {
        assert_eq!(fnum(-0.0001, 2), "0.00");
        assert_eq!(fnum(1.256, 2), "1.26");
    }
}
