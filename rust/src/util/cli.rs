//! Minimal CLI argument parser (no clap in the offline registry).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::HashMap;

/// Parsed command-line arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (tests) — tokens exclude argv[0].
    pub fn parse_from<I: IntoIterator<Item = String>>(tokens: I) -> Args {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.options.insert(stripped.to_string(), v);
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Worker count for parallel experiment grids (`--jobs N`).
    ///
    /// Defaults to 0, which the runner resolves to one worker per
    /// hardware thread; `--jobs 1` forces the sequential path.
    pub fn jobs(&self) -> usize {
        self.get_usize("jobs", 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn positional_and_options() {
        let a = parse("figure 6 --scenario standard --seed 42");
        assert_eq!(a.positional, vec!["figure", "6"]);
        assert_eq!(a.get("scenario"), Some("standard"));
        assert_eq!(a.get_u64("seed", 0), 42);
    }

    #[test]
    fn equals_form_and_flags() {
        let a = parse("serve --duration=30 --verbose");
        assert_eq!(a.get_f64("duration", 0.0), 30.0);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn trailing_option_without_value_is_flag() {
        let a = parse("run --fast");
        assert!(a.flag("fast"));
        assert_eq!(a.get("fast"), None);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("x");
        assert_eq!(a.get_or("mode", "sim"), "sim");
        assert_eq!(a.get_usize("n", 7), 7);
    }

    #[test]
    fn jobs_flag_parses_with_auto_default() {
        assert_eq!(parse("figure 6 --jobs 4").jobs(), 4);
        assert_eq!(parse("figure 6 --jobs=2").jobs(), 2);
        assert_eq!(parse("figure 6").jobs(), 0); // auto
    }
}
