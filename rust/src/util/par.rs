//! Deterministic fan-out across scoped worker threads.
//!
//! The one parallelism discipline the whole crate uses: work-stealing
//! over an atomic cursor, results merged back **in input order**, so any
//! `--jobs` / `--sim-jobs` value is byte-identical to sequential —
//! parallelism changes wall-clock only, never output. Shared by the
//! experiment grids (`experiments::runner`), the conformance fuzzer, and
//! the sim driver's partition fan-out (`sim::Simulator`).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Resolve a jobs request: 0 means "one per hardware thread", and the
/// worker count never exceeds the number of cells.
pub fn effective_jobs(jobs: usize, n_cells: usize) -> usize {
    let hw = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let j = if jobs == 0 { hw } else { jobs };
    j.clamp(1, n_cells.max(1))
}

/// Map `f` over `0..n` across `jobs` scoped worker threads (`0` = one per
/// hardware thread), returning results **in index order** regardless of
/// completion order. Work-stealing over an atomic cursor: long items
/// (e.g. the 13-hour diurnal run) don't leave siblings idle behind a
/// static partition.
pub fn par_map<T, F>(n: usize, jobs: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let jobs = effective_jobs(jobs, n);
    if jobs <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<T>> = Vec::new();
    slots.resize_with(n, || None);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..jobs)
            .map(|_| {
                let next = &next;
                let f = &f;
                scope.spawn(move || {
                    let mut done = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        done.push((i, f(i)));
                    }
                    done
                })
            })
            .collect();
        for h in handles {
            for (i, v) in h.join().expect("parallel worker panicked") {
                slots[i] = Some(v);
            }
        }
    });
    slots
        .into_iter()
        .enumerate()
        .map(|(i, s)| s.unwrap_or_else(|| panic!("cell {i} never ran")))
        .collect()
}
