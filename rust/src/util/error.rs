//! Minimal `anyhow`-flavoured error type (the offline registry carries no
//! general error crate): a message plus a stack of context strings.
//!
//! Supports the subset the runtime/serving paths use: the [`anyhow!`] and
//! [`ensure!`](crate::ensure) macros, a [`Context`] extension trait with
//! `.context(..)` / `.with_context(..)`, a `From` blanket over
//! `std::error::Error` so `?` works on io/parse/XLA errors, and an
//! alternate `{:#}` display that prints the whole context chain.
//!
//! [`anyhow!`]: crate::anyhow

use std::fmt;

/// An error with optional layered context (outermost last).
pub struct Error {
    msg: String,
    /// Context strings, innermost first (pushed as the error propagates).
    context: Vec<String>,
}

/// Crate-wide result type, defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build from a plain message (what the `anyhow!` macro expands to).
    pub fn msg(msg: impl Into<String>) -> Error {
        Error { msg: msg.into(), context: Vec::new() }
    }

    fn add_context(mut self, ctx: String) -> Error {
        self.context.push(ctx);
        self
    }

    /// All layers, outermost first, ending at the root message.
    fn chain(&self) -> impl Iterator<Item = &str> {
        self.context
            .iter()
            .rev()
            .map(String::as_str)
            .chain(std::iter::once(self.msg.as_str()))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — full chain, anyhow-style.
            let mut first = true;
            for layer in self.chain() {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{layer}")?;
                first = false;
            }
            Ok(())
        } else {
            // `{}` — outermost layer only.
            write!(f, "{}", self.chain().next().unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `.unwrap()` / `fn main() -> Result<..>` show the full chain.
        write!(f, "{self:#}")
    }
}

// Mirrors anyhow's blanket conversion. `Error` itself deliberately does
// NOT implement `std::error::Error`, which keeps this impl coherent next
// to the reflexive `From<Error> for Error`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::msg(e.to_string())
    }
}

/// Extension trait adding context to any compatible `Result`.
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context(self, msg: impl Into<String>) -> Result<T>;
    /// Wrap the error with a lazily-built context message.
    fn with_context<S, F>(self, f: F) -> Result<T>
    where
        S: Into<String>,
        F: FnOnce() -> S;
}

impl<T, E> Context<T> for Result<T, E>
where
    E: Into<Error>,
{
    fn context(self, msg: impl Into<String>) -> Result<T> {
        self.map_err(|e| e.into().add_context(msg.into()))
    }

    fn with_context<S, F>(self, f: F) -> Result<T>
    where
        S: Into<String>,
        F: FnOnce() -> S,
    {
        self.map_err(|e| e.into().add_context(f().into()))
    }
}

/// Build an [`Error`](crate::util::error::Error) from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`](crate::util::error::Error) unless the
/// condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*).into());
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_number(s: &str) -> Result<u32> {
        s.parse::<u32>().context("parsing number")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let err = parse_number("nope").unwrap_err();
        assert_eq!(format!("{err}"), "parsing number");
        let full = format!("{err:#}");
        assert!(full.starts_with("parsing number: "), "{full}");
    }

    #[test]
    fn context_layers_stack_outermost_first() {
        let e: Result<()> = Err(Error::msg("root"));
        let e = e.context("inner").with_context(|| format!("outer {}", 7));
        let err = e.unwrap_err();
        assert_eq!(format!("{err}"), "outer 7");
        assert_eq!(format!("{err:#}"), "outer 7: inner: root");
        assert_eq!(format!("{err:?}"), "outer 7: inner: root");
    }

    #[test]
    fn macros_build_errors() {
        fn check(x: u32) -> Result<u32> {
            crate::ensure!(x < 10, "x too big: {x}");
            Err(crate::anyhow!("fell through with {x}"))
        }
        assert_eq!(format!("{}", check(42).unwrap_err()), "x too big: 42");
        assert_eq!(format!("{}", check(1).unwrap_err()), "fell through with 1");
    }
}
