//! Deterministic PRNG: xoshiro256** seeded via SplitMix64.
//!
//! Every stochastic component in the repo (workload generator, network
//! traces, Distream's stochastic search, simulation jitter) draws from this
//! so whole experiments replay bit-identically from a single seed.

/// xoshiro256** — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically; any u64 works, including 0.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = splitmix64(&mut sm);
        }
        Rng { s }
    }

    /// Derive an independent stream (for per-source / per-link RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        // Multiply-shift; bias is negligible for n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    pub fn gauss(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with the given rate (events per unit time).
    pub fn exp(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0, "Rng::exp rate must be > 0");
        -self.f64().max(1e-300).ln() / rate
    }

    /// Poisson(lambda) via Knuth (small lambda) / normal approx (large).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 64.0 {
            return self.gauss(lambda, lambda.sqrt()).round().max(0.0) as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn poisson_mean_matches_lambda() {
        let mut r = Rng::new(13);
        for &lambda in &[0.5, 3.0, 20.0, 100.0] {
            let n = 20_000;
            let s: u64 = (0..n).map(|_| r.poisson(lambda)).sum();
            let mean = s as f64 / n as f64;
            assert!(
                (mean - lambda).abs() < lambda.sqrt() * 0.1 + 0.05,
                "lambda {lambda} mean {mean}"
            );
        }
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(17);
        let n = 50_000;
        let mean = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
    }
}
