//! Descriptive statistics: streaming summaries, percentile estimation,
//! fixed-bucket histograms, and the burstiness measure (coefficient of
//! variation of inter-arrival times) that drives CWD's Insight 1.

/// Streaming mean/variance/min/max (Welford).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.mean }
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Coefficient of variation — the paper's burstiness measure (§III-B).
    pub fn cv(&self) -> f64 {
        if self.mean().abs() < 1e-12 { 0.0 } else { self.std() / self.mean() }
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }
}

/// Exact percentiles over a retained sample (fine for experiment scale).
#[derive(Clone, Debug, Default)]
pub struct Percentiles {
    xs: Vec<f64>,
    sorted: bool,
}

impl Percentiles {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// q in [0, 1]; linear interpolation between order statistics.
    pub fn quantile(&mut self, q: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
        let pos = q.clamp(0.0, 1.0) * (self.xs.len() - 1) as f64;
        let (lo, hi) = (pos.floor() as usize, pos.ceil() as usize);
        let frac = pos - lo as f64;
        self.xs[lo] * (1.0 - frac) + self.xs[hi] * frac
    }

    pub fn p50(&mut self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p95(&mut self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&mut self) -> f64 {
        self.quantile(0.99)
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            0.0
        } else {
            self.xs.iter().sum::<f64>() / self.xs.len() as f64
        }
    }
}

/// Fixed-width bucket histogram for latency distributions (Fig. 6b/10b).
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    width: f64,
    buckets: Vec<u64>,
    overflow: u64,
    underflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbuckets: usize) -> Self {
        assert!(hi > lo && nbuckets > 0);
        Histogram {
            lo,
            width: (hi - lo) / nbuckets as f64,
            buckets: vec![0; nbuckets],
            overflow: 0,
            underflow: 0,
        }
    }

    pub fn push(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
            return;
        }
        let idx = ((x - self.lo) / self.width) as usize;
        if idx >= self.buckets.len() {
            self.overflow += 1;
        } else {
            self.buckets[idx] += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.buckets.iter().sum::<u64>() + self.overflow + self.underflow
    }

    pub fn buckets(&self) -> &[u64] {
        &self.buckets
    }

    pub fn bucket_edges(&self, i: usize) -> (f64, f64) {
        (self.lo + i as f64 * self.width, self.lo + (i + 1) as f64 * self.width)
    }

    /// Render a compact ASCII sparkline of bucket densities.
    pub fn sparkline(&self) -> String {
        const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self.buckets.iter().copied().max().unwrap_or(0).max(1);
        self.buckets
            .iter()
            .map(|&b| GLYPHS[(b * 7 / max) as usize])
            .collect()
    }
}

/// Burstiness of an arrival process: CV of inter-arrival gaps.
pub fn burstiness(arrivals_ms: &[f64]) -> f64 {
    if arrivals_ms.len() < 3 {
        return 0.0;
    }
    let mut s = Summary::new();
    for w in arrivals_ms.windows(2) {
        s.push((w[1] - w[0]).max(0.0));
    }
    s.cv()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.variance() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn summary_empty_is_zero() {
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.cv(), 0.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let mut p = Percentiles::new();
        for x in 1..=100 {
            p.push(x as f64);
        }
        assert!((p.p50() - 50.5).abs() < 1e-9);
        assert!((p.quantile(0.0) - 1.0).abs() < 1e-9);
        assert!((p.quantile(1.0) - 100.0).abs() < 1e-9);
        assert!(p.p95() > p.p50());
    }

    #[test]
    fn histogram_counts() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for x in [0.5, 1.5, 1.6, 9.9, -1.0, 20.0] {
            h.push(x);
        }
        assert_eq!(h.total(), 6);
        assert_eq!(h.buckets()[0], 1);
        assert_eq!(h.buckets()[1], 2);
        assert_eq!(h.buckets()[9], 1);
    }

    #[test]
    fn burstiness_regular_vs_bursty() {
        // Perfectly regular arrivals: CV = 0.
        let regular: Vec<f64> = (0..100).map(|i| i as f64 * 10.0).collect();
        assert!(burstiness(&regular) < 1e-9);
        // Bursty arrivals: clusters separated by long gaps → CV > 1.
        let mut bursty = Vec::new();
        for burst in 0..10 {
            for j in 0..10 {
                bursty.push(burst as f64 * 1000.0 + j as f64);
            }
        }
        assert!(burstiness(&bursty) > 1.5);
    }

    #[test]
    fn burstiness_poisson_near_one() {
        let mut rng = crate::util::Rng::new(3);
        let mut t = 0.0;
        let arrivals: Vec<f64> = (0..20_000)
            .map(|_| {
                t += rng.exp(0.1);
                t
            })
            .collect();
        let b = burstiness(&arrivals);
        assert!((b - 1.0).abs() < 0.05, "poisson CV {b}");
    }
}
