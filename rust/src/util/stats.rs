//! Descriptive statistics: streaming summaries, percentile estimation,
//! fixed-bucket histograms, and the burstiness measure (coefficient of
//! variation of inter-arrival times) that drives CWD's Insight 1.

/// FNV-1a offset basis — seed for the digest accumulators below and for
/// [`crate::metrics::RunMetrics::digest`].
pub(crate) const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// One FNV-1a accumulation step over a 64-bit word (byte-at-a-time, so
/// digests are identical across endianness of the accumulating order).
pub(crate) fn fnv1a(mut h: u64, word: u64) -> u64 {
    for b in word.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Streaming mean/variance/min/max (Welford).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.mean }
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Coefficient of variation — the paper's burstiness measure (§III-B).
    pub fn cv(&self) -> f64 {
        if self.mean().abs() < 1e-12 { 0.0 } else { self.std() / self.mean() }
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }
}

/// Exact percentiles over a retained sample — the reference implementation
/// the [`QuantileSketch`] is property-tested against. O(n log n) per
/// quantile refresh; use the sketch on hot paths.
#[derive(Clone, Debug, Default)]
pub struct Percentiles {
    xs: Vec<f64>,
    sorted: bool,
}

impl Percentiles {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// q in [0, 1]; linear interpolation between order statistics.
    pub fn quantile(&mut self, q: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        if !self.sorted {
            // total_cmp: NaN samples sort to the end instead of panicking.
            self.xs.sort_by(|a, b| a.total_cmp(b));
            self.sorted = true;
        }
        let pos = q.clamp(0.0, 1.0) * (self.xs.len() - 1) as f64;
        let (lo, hi) = (pos.floor() as usize, pos.ceil() as usize);
        let frac = pos - lo as f64;
        self.xs[lo] * (1.0 - frac) + self.xs[hi] * frac
    }

    pub fn p50(&mut self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p95(&mut self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&mut self) -> f64 {
        self.quantile(0.99)
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            0.0
        } else {
            self.xs.iter().sum::<f64>() / self.xs.len() as f64
        }
    }
}

/// Streaming quantile sketch: a fixed-resolution log-bucket histogram
/// (HDR-histogram style) with O(1) push and bounded relative error.
///
/// Buckets subdivide each power-of-two octave into 128 linear sub-buckets
/// (top 7 mantissa bits), giving ≤ ~0.4 % relative error per quantile —
/// far below run-to-run simulation noise — while `push` costs a couple of
/// integer ops instead of the sort-per-quantile of [`Percentiles`].
/// Covered range: [2⁻²⁰, 2⁴⁰) ≈ [1 µs, 34 years] in ms; values outside
/// are clamped (non-positive/NaN samples land in an underflow bucket).
/// Min/max/sum are tracked exactly, so `quantile(0.0)`/`quantile(1.0)`
/// and `mean()` are exact. Everything is deterministic: identical push
/// sequences yield identical quantiles.
#[derive(Clone, Debug)]
pub struct QuantileSketch {
    /// Lazily allocated on first push (`NBUCKETS` entries).
    counts: Vec<u64>,
    /// Samples below the covered range (incl. zero/negative/NaN).
    low: u64,
    /// Occupied bounds into `counts`: every non-zero bucket lies in
    /// `blo..=bhi` (`blo > bhi` = none yet). Quantile, digest, and merge
    /// walk only this range instead of all `NBUCKETS` buckets — skipped
    /// buckets are zero, so outputs are unchanged.
    blo: usize,
    bhi: usize,
    n: u64,
    sum: f64,
    min: f64,
    max: f64,
}

/// Sub-buckets per octave (top `SUB_BITS` mantissa bits).
const SUB_BITS: u32 = 7;
const SUB: usize = 1 << SUB_BITS;
/// Lowest covered biased exponent: 2^-20.
const EXP_LO: u64 = 1023 - 20;
/// Number of covered octaves: [2^-20, 2^40).
const OCTAVES: usize = 60;
const NBUCKETS: usize = OCTAVES * SUB;

impl Default for QuantileSketch {
    fn default() -> Self {
        QuantileSketch::new()
    }
}

impl QuantileSketch {
    pub fn new() -> Self {
        QuantileSketch {
            counts: Vec::new(),
            low: 0,
            blo: usize::MAX,
            bhi: 0,
            n: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Bucket index for a positive in-range value, `None` for underflow.
    #[inline]
    fn index(x: f64) -> Option<usize> {
        if !(x > 0.0) {
            return None; // non-positive or NaN
        }
        let bits = x.to_bits();
        let eb = bits >> 52; // biased exponent (sign bit is 0 here)
        if eb < EXP_LO {
            return None; // subnormal or below 2^-20
        }
        let sub = ((bits >> (52 - SUB_BITS)) & (SUB as u64 - 1)) as usize;
        let idx = (eb - EXP_LO) as usize * SUB + sub;
        Some(idx.min(NBUCKETS - 1))
    }

    /// Midpoint of bucket `idx`'s value range.
    #[inline]
    fn bucket_value(idx: usize) -> f64 {
        let octave = (idx / SUB) as i32 - 20;
        let sub = (idx % SUB) as f64;
        2f64.powi(octave) * (1.0 + (sub + 0.5) / SUB as f64)
    }

    pub fn push(&mut self, x: f64) {
        self.push_n(x, 1);
    }

    /// Record `k` samples of value `x` in O(1).
    pub fn push_n(&mut self, x: f64, k: u64) {
        if k == 0 {
            return;
        }
        self.n += k;
        self.sum += x * k as f64;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        match Self::index(x) {
            Some(idx) => {
                if self.counts.is_empty() {
                    self.counts = vec![0; NBUCKETS];
                }
                self.counts[idx] += k;
                self.blo = self.blo.min(idx);
                self.bhi = self.bhi.max(idx);
            }
            None => self.low += k,
        }
    }

    /// Fold another sketch into this one (exact: bucket counts add, and
    /// min/max/sum/low combine losslessly). Lets per-thread sketches
    /// merge into one session report.
    pub fn merge(&mut self, other: &QuantileSketch) {
        if other.n == 0 {
            return;
        }
        if other.blo <= other.bhi {
            if self.counts.is_empty() {
                self.counts = vec![0; NBUCKETS];
            }
            for i in other.blo..=other.bhi {
                let c = other.counts[i];
                if c > 0 {
                    self.counts[i] += c;
                }
            }
            self.blo = self.blo.min(other.blo);
            self.bhi = self.bhi.max(other.bhi);
        }
        self.low += other.low;
        self.n += other.n;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn len(&self) -> usize {
        self.n as usize
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Nearest-rank quantile, `q` in [0, 1]; endpoints are exact.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        if !self.min.is_finite() {
            return 0.0; // only NaN samples recorded
        }
        let target = (q.clamp(0.0, 1.0) * (self.n - 1) as f64).round() as u64;
        if target == 0 {
            return self.min;
        }
        if target == self.n - 1 {
            return self.max;
        }
        let mut cum = self.low;
        if target < cum {
            return self.min;
        }
        if self.blo <= self.bhi {
            for idx in self.blo..=self.bhi {
                cum += self.counts[idx];
                if cum > target {
                    return Self::bucket_value(idx).clamp(self.min, self.max);
                }
            }
        }
        self.max
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    /// Order-independent 64-bit fingerprint of the sketch contents. Only
    /// non-empty buckets are hashed, so a never-pushed sketch and one
    /// whose bucket array was allocated but stayed zero digest equal.
    pub fn digest(&self) -> u64 {
        let mut h = FNV_OFFSET;
        h = fnv1a(h, self.n);
        h = fnv1a(h, self.low);
        h = fnv1a(h, self.sum.to_bits());
        h = fnv1a(h, self.min.to_bits());
        h = fnv1a(h, self.max.to_bits());
        if self.blo <= self.bhi {
            for i in self.blo..=self.bhi {
                let c = self.counts[i];
                if c > 0 {
                    h = fnv1a(h, i as u64);
                    h = fnv1a(h, c);
                }
            }
        }
        h
    }
}

/// Fixed-width bucket histogram for latency distributions (Fig. 6b/10b).
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    width: f64,
    buckets: Vec<u64>,
    overflow: u64,
    underflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbuckets: usize) -> Self {
        assert!(hi > lo && nbuckets > 0);
        Histogram {
            lo,
            width: (hi - lo) / nbuckets as f64,
            buckets: vec![0; nbuckets],
            overflow: 0,
            underflow: 0,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.push_n(x, 1);
    }

    /// Record `k` samples of value `x` in O(1) (bulk drop/fanout paths).
    pub fn push_n(&mut self, x: f64, k: u64) {
        if x < self.lo {
            self.underflow += k;
            return;
        }
        let idx = ((x - self.lo) / self.width) as usize;
        if idx >= self.buckets.len() {
            self.overflow += k;
        } else {
            self.buckets[idx] += k;
        }
    }

    pub fn total(&self) -> u64 {
        self.buckets.iter().sum::<u64>() + self.overflow + self.underflow
    }

    pub fn buckets(&self) -> &[u64] {
        &self.buckets
    }

    /// Samples above the bucketed range. They are counted (in
    /// [`total`](Self::total), [`digest`](Self::digest), merges) but land
    /// in no bucket — callers rendering the distribution must surface
    /// this, or seconds-scale latencies silently vanish from a histogram
    /// whose range ends at 1 s.
    pub fn overflow(&self) -> u64 {
        self.overflow
    }

    /// Samples below `lo` (counterpart of [`overflow`](Self::overflow)).
    pub fn underflow(&self) -> u64 {
        self.underflow
    }

    pub fn bucket_edges(&self, i: usize) -> (f64, f64) {
        (self.lo + i as f64 * self.width, self.lo + (i + 1) as f64 * self.width)
    }

    /// Fold another histogram of the identical shape into this one
    /// (bucket counts add exactly; fleet-metric merging).
    pub fn merge(&mut self, other: &Histogram) {
        assert!(
            self.lo == other.lo
                && self.width == other.width
                && self.buckets.len() == other.buckets.len(),
            "histogram shapes differ"
        );
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.overflow += other.overflow;
        self.underflow += other.underflow;
    }

    /// 64-bit fingerprint of the full bucket state (shape included).
    pub fn digest(&self) -> u64 {
        let mut h = FNV_OFFSET;
        h = fnv1a(h, self.lo.to_bits());
        h = fnv1a(h, self.width.to_bits());
        h = fnv1a(h, self.underflow);
        h = fnv1a(h, self.overflow);
        for &b in &self.buckets {
            h = fnv1a(h, b);
        }
        h
    }

    /// Render a compact ASCII sparkline of bucket densities. Out-of-range
    /// mass is appended explicitly — a 5 s latency in a 1 s-wide
    /// histogram must be visible, not folded away unreported.
    pub fn sparkline(&self) -> String {
        use std::fmt::Write;
        const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self.buckets.iter().copied().max().unwrap_or(0).max(1);
        let mut s: String = self
            .buckets
            .iter()
            .map(|&b| GLYPHS[(b * 7 / max) as usize])
            .collect();
        if self.underflow > 0 {
            let _ = write!(s, " (+{} < {})", self.underflow, self.lo);
        }
        if self.overflow > 0 {
            let hi = self.lo + self.width * self.buckets.len() as f64;
            let _ = write!(s, " (+{} > {hi})", self.overflow);
        }
        s
    }
}

/// Burstiness of an arrival process: CV of inter-arrival gaps.
pub fn burstiness(arrivals_ms: &[f64]) -> f64 {
    if arrivals_ms.len() < 3 {
        return 0.0;
    }
    let mut s = Summary::new();
    for w in arrivals_ms.windows(2) {
        s.push((w[1] - w[0]).max(0.0));
    }
    s.cv()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.variance() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn summary_empty_is_zero() {
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.cv(), 0.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let mut p = Percentiles::new();
        for x in 1..=100 {
            p.push(x as f64);
        }
        assert!((p.p50() - 50.5).abs() < 1e-9);
        assert!((p.quantile(0.0) - 1.0).abs() < 1e-9);
        assert!((p.quantile(1.0) - 100.0).abs() < 1e-9);
        assert!(p.p95() > p.p50());
    }

    #[test]
    fn sketch_matches_exact_on_uniform() {
        let mut rng = crate::util::Rng::new(7);
        let mut sketch = QuantileSketch::new();
        let mut exact = Percentiles::new();
        for _ in 0..50_000 {
            let x = rng.range(0.5, 400.0);
            sketch.push(x);
            exact.push(x);
        }
        for q in [0.1, 0.5, 0.9, 0.95, 0.99] {
            let (s, e) = (sketch.quantile(q), exact.quantile(q));
            assert!((s - e).abs() <= 0.01 * e, "q={q}: sketch {s} exact {e}");
        }
    }

    #[test]
    fn sketch_endpoints_and_mean_are_exact() {
        let mut s = QuantileSketch::new();
        for x in [3.0, 1.5, 9.0, 4.5] {
            s.push(x);
        }
        assert_eq!(s.quantile(0.0), 1.5);
        assert_eq!(s.quantile(1.0), 9.0);
        assert_eq!(s.min(), 1.5);
        assert_eq!(s.max(), 9.0);
        assert!((s.mean() - 4.5).abs() < 1e-12);
        assert_eq!(s.count(), 4);
    }

    #[test]
    fn sketch_handles_degenerate_inputs_without_panicking() {
        let mut s = QuantileSketch::new();
        assert_eq!(s.quantile(0.5), 0.0);
        s.push(0.0);
        s.push(-5.0);
        s.push(f64::NAN);
        s.push(1e30); // beyond the covered range: clamped, not lost
        assert_eq!(s.len(), 4);
        let p50 = s.quantile(0.5);
        assert!(p50 >= -5.0, "p50 {p50}");
        assert_eq!(s.quantile(1.0), 1e30);
    }

    #[test]
    fn sketch_push_n_equals_repeated_push() {
        let mut a = QuantileSketch::new();
        let mut b = QuantileSketch::new();
        for _ in 0..7 {
            a.push(42.0);
        }
        b.push_n(42.0, 7);
        assert_eq!(a.count(), b.count());
        for q in [0.0, 0.25, 0.5, 1.0] {
            assert_eq!(a.quantile(q), b.quantile(q));
        }
    }

    #[test]
    fn sketch_merge_equals_single_sketch() {
        let mut whole = QuantileSketch::new();
        let mut a = QuantileSketch::new();
        let mut b = QuantileSketch::new();
        let mut rng = crate::util::Rng::new(5);
        for i in 0..500 {
            let x = rng.range(1e-2, 1e4);
            whole.push(x);
            if i % 2 == 0 { a.push(x) } else { b.push(x) }
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert_eq!(a.min(), whole.min());
        assert_eq!(a.max(), whole.max());
        assert_eq!(a.mean(), whole.mean());
        for q in [0.1, 0.5, 0.9, 0.99] {
            assert_eq!(a.quantile(q), whole.quantile(q));
        }
        // Merging into an empty sketch is a copy; merging empty is a no-op.
        let mut empty = QuantileSketch::new();
        empty.merge(&whole);
        assert_eq!(empty.quantile(0.5), whole.quantile(0.5));
        let before = whole.count();
        whole.merge(&QuantileSketch::new());
        assert_eq!(whole.count(), before);
    }

    #[test]
    fn sketch_relative_error_is_bounded() {
        // Every representable value must round-trip within half a bucket.
        let mut rng = crate::util::Rng::new(11);
        for _ in 0..2000 {
            let x = rng.range(1e-3, 1e6);
            let mut s = QuantileSketch::new();
            s.push(x * 0.5);
            s.push(x);
            s.push(x * 2.0);
            let mid = s.quantile(0.5);
            assert!(
                (mid - x).abs() <= x * (1.0 / 128.0),
                "x {x} -> {mid}"
            );
        }
    }

    #[test]
    fn sketch_bucket_bounds_cover_all_occupied_buckets() {
        // Extremes of the covered range plus an underflow sample: the
        // occupied-range walk must see both ends.
        let mut s = QuantileSketch::new();
        s.push(2e-6); // near the 2^-20 floor
        s.push(1e11); // clamped into the top bucket
        s.push(-1.0); // underflow
        assert_eq!(s.quantile(0.0), -1.0);
        assert_eq!(s.quantile(1.0), 1e11);
        let mid = s.quantile(0.5);
        assert!(mid > 0.0 && mid <= 4e-6, "mid {mid}");
        // Merging a mid-range sketch widens the bounds; result tracks a
        // single sketch fed the same pushes in the same order.
        let mut t = QuantileSketch::new();
        t.push(100.0);
        s.merge(&t);
        let mut whole = QuantileSketch::new();
        for x in [2e-6, 1e11, -1.0, 100.0] {
            whole.push(x);
        }
        assert_eq!(s.digest(), whole.digest());
        for q in [0.0, 0.3, 0.5, 0.8, 1.0] {
            assert_eq!(s.quantile(q), whole.quantile(q));
        }
    }

    #[test]
    fn percentiles_survive_nan_samples() {
        let mut p = Percentiles::new();
        p.push(1.0);
        p.push(f64::NAN);
        p.push(3.0);
        // total_cmp sorts NaN last; quantile(0.5) stays finite.
        assert!(p.quantile(0.0).is_finite());
    }

    #[test]
    fn histogram_push_n_bulk() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.push_n(1.5, 5);
        h.push_n(-1.0, 2);
        h.push_n(20.0, 3);
        assert_eq!(h.total(), 10);
        assert_eq!(h.buckets()[1], 5);
    }

    #[test]
    fn histogram_counts() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for x in [0.5, 1.5, 1.6, 9.9, -1.0, 20.0] {
            h.push(x);
        }
        assert_eq!(h.total(), 6);
        assert_eq!(h.buckets()[0], 1);
        assert_eq!(h.buckets()[1], 2);
        assert_eq!(h.buckets()[9], 1);
    }

    #[test]
    fn histogram_merge_equals_single_histogram() {
        let mut whole = Histogram::new(0.0, 100.0, 20);
        let mut a = Histogram::new(0.0, 100.0, 20);
        let mut b = Histogram::new(0.0, 100.0, 20);
        let mut rng = crate::util::Rng::new(17);
        for i in 0..300 {
            let x = rng.range(-10.0, 150.0);
            whole.push(x);
            if i % 3 == 0 { a.push(x) } else { b.push(x) }
        }
        a.merge(&b);
        assert_eq!(a.total(), whole.total());
        assert_eq!(a.buckets(), whole.buckets());
        assert_eq!(a.digest(), whole.digest());
    }

    #[test]
    #[should_panic(expected = "histogram shapes differ")]
    fn histogram_merge_rejects_mismatched_shapes() {
        let mut a = Histogram::new(0.0, 100.0, 20);
        a.merge(&Histogram::new(0.0, 100.0, 10));
    }

    #[test]
    fn out_of_range_mass_is_reported_not_clipped() {
        // Regression: the run-metrics latency histogram spans [0, 1000) ms;
        // a 5 s latency must stay visible through the accessors and the
        // rendered sparkline, not fold into the top bucket unreported.
        let mut h = Histogram::new(0.0, 1000.0, 50);
        h.push(5000.0);
        h.push_n(250.0, 4);
        assert_eq!(h.overflow(), 1);
        assert_eq!(h.underflow(), 0);
        assert_eq!(h.total(), 5, "overflow counts toward the total");
        assert_eq!(h.buckets().iter().sum::<u64>(), 4, "but lands in no bucket");
        let line = h.sparkline();
        assert!(line.contains("(+1 > 1000)"), "{line}");
        h.push(-3.0);
        assert!(h.sparkline().contains("(+1 < 0)"), "{}", h.sparkline());
        // In-range-only histograms render with no suffix.
        let mut clean = Histogram::new(0.0, 10.0, 5);
        clean.push(1.0);
        assert!(!clean.sparkline().contains('('));
    }

    #[test]
    fn digests_are_stable_and_content_sensitive() {
        let mut a = QuantileSketch::new();
        let mut b = QuantileSketch::new();
        assert_eq!(a.digest(), b.digest(), "empty sketches digest equal");
        a.push(42.0);
        b.push(42.0);
        assert_eq!(a.digest(), b.digest());
        b.push(43.0);
        assert_ne!(a.digest(), b.digest());

        let mut h1 = Histogram::new(0.0, 10.0, 10);
        let mut h2 = Histogram::new(0.0, 10.0, 10);
        assert_eq!(h1.digest(), h2.digest());
        h1.push(1.0);
        h2.push(2.0);
        assert_ne!(h1.digest(), h2.digest(), "different buckets, same total");
    }

    #[test]
    fn burstiness_regular_vs_bursty() {
        // Perfectly regular arrivals: CV = 0.
        let regular: Vec<f64> = (0..100).map(|i| i as f64 * 10.0).collect();
        assert!(burstiness(&regular) < 1e-9);
        // Bursty arrivals: clusters separated by long gaps → CV > 1.
        let mut bursty = Vec::new();
        for burst in 0..10 {
            for j in 0..10 {
                bursty.push(burst as f64 * 1000.0 + j as f64);
            }
        }
        assert!(burstiness(&bursty) > 1.5);
    }

    #[test]
    fn burstiness_poisson_near_one() {
        let mut rng = crate::util::Rng::new(3);
        let mut t = 0.0;
        let arrivals: Vec<f64> = (0..20_000)
            .map(|_| {
                t += rng.exp(0.1);
                t
            })
            .collect();
        let b = burstiness(&arrivals);
        assert!((b - 1.0).abs() < 0.05, "poisson CV {b}");
    }
}
