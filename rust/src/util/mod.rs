//! In-tree substrates for functionality that would normally come from
//! crates.io (the offline registry only carries the `xla` closure):
//! deterministic RNG, descriptive statistics, ASCII/markdown tables, a tiny
//! CLI argument parser, an anyhow-style error type, and a property-testing
//! mini-framework.

pub mod cli;
pub mod error;
pub mod par;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;

pub use rng::Rng;
pub use stats::{Histogram, QuantileSketch, Summary};
