//! Network substrate: time-varying bandwidth between edge devices and the
//! server (paper §IV-A5 uses Irish 5G/LTE traces [22]; we substitute a
//! regime-switching process matched to that dataset's statistics, plus a
//! CSV loader for real traces — see DESIGN.md §Substitutions).

mod trace;

pub use trace::{BwTrace, LinkQuality, TraceKind};

use crate::{Bytes, Ms};

/// A device<->server link with a bandwidth trace.
#[derive(Clone, Debug)]
pub struct Link {
    pub trace: BwTrace,
    /// Fixed propagation delay, ms.
    pub rtt_ms: Ms,
}

impl Link {
    pub fn new(trace: BwTrace, rtt_ms: Ms) -> Link {
        Link { trace, rtt_ms }
    }

    /// Bandwidth at absolute time `t_ms`, Mbit/s.
    pub fn bandwidth_mbps(&self, t_ms: Ms) -> f64 {
        self.trace.bandwidth_mbps(t_ms)
    }

    /// Transfer latency for `bytes` at time `t_ms` (paper L_m^io =
    /// size(In_m)/BW), including half-RTT handshake.
    pub fn transfer_ms(&self, bytes: Bytes, t_ms: Ms) -> Ms {
        let bw = self.bandwidth_mbps(t_ms);
        if bw <= 0.0 {
            return f64::INFINITY; // outage
        }
        let bits = bytes * 8.0;
        self.rtt_ms / 2.0 + bits / (bw * 1000.0) // Mbit/s == kbit/ms
    }
}

/// On-device transfers are effectively free (paper: bandwidth `ε` is a
/// large hardware constant); we model a fixed small copy cost.
pub const LOCAL_TRANSFER_MS: Ms = 0.05;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_scales_with_bytes() {
        let link = Link::new(BwTrace::constant(100.0), 10.0);
        let small = link.transfer_ms(10_000.0, 0.0);
        let big = link.transfer_ms(1_000_000.0, 0.0);
        assert!(big > small);
        // 1 MB at 100 Mbit/s = 80 ms + 5 ms half-RTT.
        assert!((big - 85.0).abs() < 1.0);
    }

    #[test]
    fn outage_is_infinite() {
        let link = Link::new(BwTrace::constant(0.0), 10.0);
        assert!(link.transfer_ms(1000.0, 0.0).is_infinite());
    }
}
