//! Bandwidth traces: regime-switching synthetic process (5G / LTE presets
//! matched to the Irish dataset's reported statistics) and a CSV loader.

use crate::util::Rng;
use crate::Ms;

/// Connectivity regime at an instant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkQuality {
    Good,
    Degraded,
    Outage,
}

/// Trace flavor presets.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceKind {
    /// 5G: high mean, high variance, rare short outages.
    FiveG,
    /// LTE: lower mean, moderate variance, occasional outages (Fig. 7 shows
    /// disconnections under the LTE traces).
    Lte,
    /// Fixed bandwidth (tests, ablations).
    Constant,
}

/// Piecewise-constant bandwidth over fixed steps, pre-generated so lookups
/// during simulation are O(1) and deterministic.
#[derive(Clone, Debug)]
pub struct BwTrace {
    step_ms: Ms,
    samples_mbps: Vec<f64>,
    kind: TraceKind,
}

impl BwTrace {
    pub fn constant(mbps: f64) -> BwTrace {
        BwTrace { step_ms: 1000.0, samples_mbps: vec![mbps], kind: TraceKind::Constant }
    }

    /// Generate a synthetic trace of `duration_ms` with 1 s resolution.
    ///
    /// Markov regimes: Good <-> Degraded <-> Outage with dwell times and
    /// per-regime lognormal-ish bandwidth draws. Parameters per kind follow
    /// the Irish dataset's published summary stats (5G: mean ≈ 150 Mbit/s
    /// heavy-tailed; LTE: mean ≈ 25 Mbit/s with outage episodes).
    pub fn generate(kind: TraceKind, duration_ms: Ms, rng: &mut Rng) -> BwTrace {
        let step_ms = 1000.0;
        let steps = (duration_ms / step_ms).ceil().max(1.0) as usize;
        // Means model the *uplink* (cameras upload): the Irish dataset's
        // 5G uplink averages ~25-30 Mbit/s, LTE ~8-10, both with degraded
        // episodes and (LTE especially) outages — Fig. 7's disconnections.
        let (mean, jitter, p_degrade, p_outage, degraded_frac) = match kind {
            TraceKind::FiveG => (28.0, 0.45, 0.02, 0.004, 0.3),
            TraceKind::Lte => (9.0, 0.35, 0.05, 0.012, 0.35),
            TraceKind::Constant => {
                return BwTrace::constant(100.0);
            }
        };
        let mut samples = Vec::with_capacity(steps);
        let mut quality = LinkQuality::Good;
        let mut dwell = 0usize;
        for _ in 0..steps {
            if dwell == 0 {
                quality = match quality {
                    LinkQuality::Good => {
                        if rng.chance(p_outage) {
                            dwell = 2 + rng.below(6); // 2-7 s outages
                            LinkQuality::Outage
                        } else if rng.chance(p_degrade) {
                            dwell = 5 + rng.below(20);
                            LinkQuality::Degraded
                        } else {
                            dwell = 1;
                            LinkQuality::Good
                        }
                    }
                    LinkQuality::Degraded => {
                        if rng.chance(0.3) {
                            dwell = 1;
                            LinkQuality::Good
                        } else {
                            dwell = 1 + rng.below(4);
                            LinkQuality::Degraded
                        }
                    }
                    LinkQuality::Outage => {
                        dwell = 1;
                        LinkQuality::Good
                    }
                };
            }
            dwell -= 1;
            let bw = match quality {
                LinkQuality::Good => {
                    (mean * (1.0 + jitter * rng.normal())).max(mean * 0.2)
                }
                LinkQuality::Degraded => {
                    (mean * degraded_frac * (1.0 + jitter * rng.normal()))
                        .max(mean * 0.05)
                }
                LinkQuality::Outage => 0.0,
            };
            samples.push(bw);
        }
        BwTrace { step_ms, samples_mbps: samples, kind }
    }

    /// Load from CSV (`t_s,bw_mbps` rows) — for replaying real traces.
    pub fn from_csv(text: &str) -> Result<BwTrace, String> {
        let mut samples = Vec::new();
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') || line.starts_with("t") {
                continue;
            }
            let cols: Vec<&str> = line.split(',').collect();
            if cols.len() < 2 {
                return Err(format!("row {}: expected t,bw", i + 1));
            }
            let bw: f64 =
                cols[1].trim().parse().map_err(|e| format!("row {}: {e}", i + 1))?;
            samples.push(bw.max(0.0));
        }
        if samples.is_empty() {
            return Err("empty trace".into());
        }
        Ok(BwTrace { step_ms: 1000.0, samples_mbps: samples, kind: TraceKind::Constant })
    }

    /// Construct directly from 1-second samples (fuzzer, property tests).
    /// Negative samples are clamped to 0 (outage).
    pub fn from_samples(samples: Vec<f64>) -> BwTrace {
        assert!(!samples.is_empty(), "empty trace");
        BwTrace {
            step_ms: 1000.0,
            samples_mbps: samples.into_iter().map(|s| s.max(0.0)).collect(),
            kind: TraceKind::Constant,
        }
    }

    /// Force an outage over seconds `[from_s, to_s)` (clamped to the trace
    /// length) — the fuzzer's blackout/churn mutation.
    pub fn zero_window(&mut self, from_s: usize, to_s: usize) {
        let n = self.samples_mbps.len();
        for s in self.samples_mbps[from_s.min(n)..to_s.min(n)].iter_mut() {
            *s = 0.0;
        }
    }

    /// Σ samples (Mbit/s · s over the trace) — a scheduler-independent
    /// quantity the conformance harness cross-checks bit-for-bit.
    pub fn integral_mbps_s(&self) -> f64 {
        self.samples_mbps.iter().sum()
    }

    /// The raw 1-second sample array (outage-skip tables, analysis).
    pub fn samples(&self) -> &[f64] {
        &self.samples_mbps
    }

    pub fn bandwidth_mbps(&self, t_ms: Ms) -> f64 {
        let idx = (t_ms / self.step_ms).max(0.0) as usize;
        // Loop the trace if simulation outlives it (13 h runs on 30 min
        // traces in tests).
        self.samples_mbps[idx % self.samples_mbps.len()]
    }

    pub fn quality(&self, t_ms: Ms) -> LinkQuality {
        let bw = self.bandwidth_mbps(t_ms);
        if bw <= 0.0 {
            LinkQuality::Outage
        } else if bw < self.mean() * 0.4 {
            LinkQuality::Degraded
        } else {
            LinkQuality::Good
        }
    }

    pub fn mean(&self) -> f64 {
        self.samples_mbps.iter().sum::<f64>() / self.samples_mbps.len() as f64
    }

    pub fn kind(&self) -> TraceKind {
        self.kind
    }

    pub fn len_ms(&self) -> Ms {
        self.samples_mbps.len() as f64 * self.step_ms
    }

    /// Fraction of time in outage.
    pub fn outage_fraction(&self) -> f64 {
        self.samples_mbps.iter().filter(|&&b| b <= 0.0).count() as f64
            / self.samples_mbps.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fiveg_stats_in_band() {
        let mut rng = Rng::new(100);
        let t = BwTrace::generate(TraceKind::FiveG, 3600_000.0, &mut rng);
        let mean = t.mean();
        assert!((18.0..40.0).contains(&mean), "5G uplink mean {mean}");
        assert!(t.outage_fraction() < 0.05);
    }

    #[test]
    fn lte_slower_with_more_outage() {
        let mut rng = Rng::new(101);
        let g5 = BwTrace::generate(TraceKind::FiveG, 3600_000.0, &mut rng);
        let lte = BwTrace::generate(TraceKind::Lte, 3600_000.0, &mut rng);
        assert!(lte.mean() < g5.mean() / 2.0);
        assert!(lte.outage_fraction() > g5.outage_fraction());
    }

    #[test]
    fn deterministic_given_seed() {
        let a = BwTrace::generate(TraceKind::Lte, 60_000.0, &mut Rng::new(7));
        let b = BwTrace::generate(TraceKind::Lte, 60_000.0, &mut Rng::new(7));
        assert_eq!(a.samples_mbps, b.samples_mbps);
    }

    #[test]
    fn trace_loops_beyond_end() {
        let t = BwTrace::constant(50.0);
        assert_eq!(t.bandwidth_mbps(10_000_000.0), 50.0);
    }

    #[test]
    fn from_samples_and_zero_window() {
        let mut t = BwTrace::from_samples(vec![10.0, 20.0, -5.0, 30.0]);
        assert_eq!(t.bandwidth_mbps(2_500.0), 0.0); // negative clamped
        assert_eq!(t.integral_mbps_s(), 60.0);
        t.zero_window(1, 99); // clamped past the end
        assert_eq!(t.bandwidth_mbps(500.0), 10.0);
        assert_eq!(t.bandwidth_mbps(1_500.0), 0.0);
        assert_eq!(t.bandwidth_mbps(3_500.0), 0.0);
        assert_eq!(t.integral_mbps_s(), 10.0);
        assert!(t.outage_fraction() > 0.7);
    }

    #[test]
    fn csv_roundtrip() {
        let t = BwTrace::from_csv("t,bw\n0,10\n1,20\n2,0\n").unwrap();
        assert_eq!(t.bandwidth_mbps(0.0), 10.0);
        assert_eq!(t.bandwidth_mbps(1500.0), 20.0);
        assert_eq!(t.quality(2500.0), LinkQuality::Outage);
        assert!(BwTrace::from_csv("").is_err());
        assert!(BwTrace::from_csv("0,abc\n").is_err());
    }
}
