//! Video-content workload substrate (paper §IV-A3: nine 13-hour real CCTV
//! streams). We substitute a seeded content-dynamics generator exposing the
//! same scheduler-visible dials: per-frame object counts with a circadian
//! (diurnal) intensity curve, Markov-modulated burst episodes (rush hour /
//! crowd events), and per-class object mixes.

mod content;

pub use content::{
    ContentDynamics, ContentProfile, DiurnalShape, SceneFilter,
    SCENE_REFRESH_FRAMES,
};

/// Sliding window of arrival timestamps used to estimate per-model request
/// rate and burstiness (CV of inter-arrival gaps) — CWD's Insight 1 inputs.
///
/// `rate_qps()` and `burstiness()` are O(1): alongside the timestamp ring
/// the window maintains eviction-aware running aggregates (Σgap, Σgap²)
/// over the inter-arrival gaps of the retained arrivals. Both queries run
/// per instance-group on every autoscaler tick, arrival, and reschedule,
/// so they must cost ~nothing at high frame rates. Gap aggregates are
/// rebuilt exactly every [`REBUILD_EVICTIONS`] evictions to keep
/// floating-point drift from the incremental subtractions bounded (O(n)
/// then, O(1) amortized).
#[derive(Clone, Debug)]
pub struct ArrivalWindow {
    window_ms: f64,
    arrivals: std::collections::VecDeque<f64>,
    /// Σ of the `len-1` inter-arrival gaps between retained arrivals.
    gap_sum: f64,
    /// Σ of squared gaps.
    gap_sq: f64,
    /// Evictions since the aggregates were last rebuilt exactly.
    evictions: u32,
}

/// Rebuild the gap aggregates exactly after this many incremental
/// evictions (amortized O(1), bounds fp drift to ~4096 subtractions).
const REBUILD_EVICTIONS: u32 = 4096;

impl ArrivalWindow {
    pub fn new(window_ms: f64) -> Self {
        ArrivalWindow {
            window_ms,
            arrivals: Default::default(),
            gap_sum: 0.0,
            gap_sq: 0.0,
            evictions: 0,
        }
    }

    pub fn record(&mut self, t_ms: f64) {
        if let Some(&back) = self.arrivals.back() {
            let g = (t_ms - back).max(0.0);
            self.gap_sum += g;
            self.gap_sq += g * g;
        }
        self.arrivals.push_back(t_ms);
        let cutoff = t_ms - self.window_ms;
        while self.arrivals.front().is_some_and(|&f| f < cutoff) {
            let f = self.arrivals.pop_front().unwrap();
            // Subtract exactly the gap that was added when the (now new)
            // front arrival was recorded after `f`.
            if let Some(&nf) = self.arrivals.front() {
                let g = (nf - f).max(0.0);
                self.gap_sum -= g;
                self.gap_sq -= g * g;
            }
            self.evictions += 1;
        }
        if self.arrivals.len() <= 1 {
            // No gaps left: reset aggregates exactly.
            self.gap_sum = 0.0;
            self.gap_sq = 0.0;
            self.evictions = 0;
        } else if self.evictions >= REBUILD_EVICTIONS {
            self.rebuild();
        }
    }

    /// Recompute the gap aggregates exactly from the retained arrivals.
    fn rebuild(&mut self) {
        let (mut sum, mut sq) = (0.0, 0.0);
        let mut prev: Option<f64> = None;
        for &t in &self.arrivals {
            if let Some(p) = prev {
                let g = (t - p).max(0.0);
                sum += g;
                sq += g * g;
            }
            prev = Some(t);
        }
        self.gap_sum = sum;
        self.gap_sq = sq;
        self.evictions = 0;
    }

    /// Arrivals per second over the window. O(1).
    pub fn rate_qps(&self) -> f64 {
        if self.arrivals.len() < 2 {
            return 0.0;
        }
        let span =
            self.arrivals.back().unwrap() - self.arrivals.front().unwrap();
        if span <= 0.0 {
            return 0.0;
        }
        (self.arrivals.len() - 1) as f64 * 1000.0 / span
    }

    /// Coefficient of variation of inter-arrival gaps. O(1), from the
    /// running aggregates (sample variance, matching `Summary::cv`).
    pub fn burstiness(&self) -> f64 {
        if self.arrivals.len() < 3 {
            return 0.0;
        }
        let k = (self.arrivals.len() - 1) as f64;
        let mean = self.gap_sum / k;
        if mean.abs() < 1e-12 {
            return 0.0;
        }
        let var =
            ((self.gap_sq - self.gap_sum * self.gap_sum / k) / (k - 1.0)).max(0.0);
        var.sqrt() / mean
    }

    pub fn len(&self) -> usize {
        self.arrivals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arrivals.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_evicts_old() {
        let mut w = ArrivalWindow::new(1000.0);
        for i in 0..100 {
            w.record(i as f64 * 100.0);
        }
        assert!(w.len() <= 11);
    }

    #[test]
    fn rate_estimates_regular_stream() {
        let mut w = ArrivalWindow::new(10_000.0);
        for i in 0..50 {
            w.record(i as f64 * 100.0); // 10/s
        }
        assert!((w.rate_qps() - 10.0).abs() < 0.5);
    }

    #[test]
    fn burstiness_zero_for_regular() {
        let mut w = ArrivalWindow::new(10_000.0);
        for i in 0..50 {
            w.record(i as f64 * 100.0);
        }
        assert!(w.burstiness() < 1e-9);
    }

    /// Exact batch references over the retained arrivals.
    fn reference(kept: &[f64]) -> (f64, f64) {
        let rate = if kept.len() < 2 {
            0.0
        } else {
            let span = kept[kept.len() - 1] - kept[0];
            if span <= 0.0 {
                0.0
            } else {
                (kept.len() - 1) as f64 * 1000.0 / span
            }
        };
        (rate, crate::util::stats::burstiness(kept))
    }

    #[test]
    fn incremental_matches_batch_under_heavy_eviction() {
        // Poisson arrivals across >> window span: every record evicts,
        // crossing several exact-rebuild boundaries.
        let mut rng = crate::util::Rng::new(99);
        let mut w = ArrivalWindow::new(500.0);
        let mut all = Vec::new();
        let mut t = 0.0;
        for i in 0..30_000 {
            t += rng.exp(0.2); // ~5 ms mean gap, ~100 retained
            all.push(t);
            w.record(t);
            if i % 5000 == 0 {
                let cutoff = t - 500.0;
                let kept: Vec<f64> =
                    all.iter().copied().filter(|&x| x >= cutoff).collect();
                assert_eq!(w.len(), kept.len());
                let (rr, rb) = reference(&kept);
                assert!((w.rate_qps() - rr).abs() <= 1e-6 * rr.max(1.0));
                assert!(
                    (w.burstiness() - rb).abs() <= 1e-6 * rb.max(1.0),
                    "incremental {} batch {}",
                    w.burstiness(),
                    rb
                );
            }
        }
    }

    #[test]
    fn aggregates_reset_when_window_drains_to_one() {
        let mut w = ArrivalWindow::new(100.0);
        for i in 0..10 {
            w.record(i as f64 * 10.0);
        }
        // A far-future arrival evicts everything else.
        w.record(1e7);
        assert_eq!(w.len(), 1);
        assert_eq!(w.rate_qps(), 0.0);
        assert_eq!(w.burstiness(), 0.0);
        // Window keeps working after the drain.
        w.record(1e7 + 10.0);
        w.record(1e7 + 20.0);
        assert!(w.burstiness() < 1e-9);
        assert!((w.rate_qps() - 100.0).abs() < 1e-6);
    }
}
