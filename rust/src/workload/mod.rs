//! Video-content workload substrate (paper §IV-A3: nine 13-hour real CCTV
//! streams). We substitute a seeded content-dynamics generator exposing the
//! same scheduler-visible dials: per-frame object counts with a circadian
//! (diurnal) intensity curve, Markov-modulated burst episodes (rush hour /
//! crowd events), and per-class object mixes.

mod content;

pub use content::{ContentDynamics, ContentProfile, DiurnalShape};

use crate::util::stats::burstiness;

/// Sliding window of arrival timestamps used to estimate per-model request
/// rate and burstiness (CV of inter-arrival gaps) — CWD's Insight 1 inputs.
#[derive(Clone, Debug)]
pub struct ArrivalWindow {
    window_ms: f64,
    arrivals: std::collections::VecDeque<f64>,
}

impl ArrivalWindow {
    pub fn new(window_ms: f64) -> Self {
        ArrivalWindow { window_ms, arrivals: Default::default() }
    }

    pub fn record(&mut self, t_ms: f64) {
        self.arrivals.push_back(t_ms);
        let cutoff = t_ms - self.window_ms;
        while self.arrivals.front().is_some_and(|&f| f < cutoff) {
            self.arrivals.pop_front();
        }
    }

    /// Arrivals per second over the window.
    pub fn rate_qps(&self) -> f64 {
        if self.arrivals.len() < 2 {
            return 0.0;
        }
        let span =
            self.arrivals.back().unwrap() - self.arrivals.front().unwrap();
        if span <= 0.0 {
            return 0.0;
        }
        (self.arrivals.len() - 1) as f64 * 1000.0 / span
    }

    /// Coefficient of variation of inter-arrival gaps.
    ///
    /// Computed directly over the ring buffer (no allocation): this runs
    /// per instance-group on every autoscaler tick and scheduler round.
    pub fn burstiness(&self) -> f64 {
        if self.arrivals.len() < 3 {
            return 0.0;
        }
        let mut s = crate::util::stats::Summary::new();
        let mut prev: Option<f64> = None;
        for &t in &self.arrivals {
            if let Some(p) = prev {
                s.push((t - p).max(0.0));
            }
            prev = Some(t);
        }
        s.cv()
    }

    pub fn len(&self) -> usize {
        self.arrivals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arrivals.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_evicts_old() {
        let mut w = ArrivalWindow::new(1000.0);
        for i in 0..100 {
            w.record(i as f64 * 100.0);
        }
        assert!(w.len() <= 11);
    }

    #[test]
    fn rate_estimates_regular_stream() {
        let mut w = ArrivalWindow::new(10_000.0);
        for i in 0..50 {
            w.record(i as f64 * 100.0); // 10/s
        }
        assert!((w.rate_qps() - 10.0).abs() < 0.5);
    }

    #[test]
    fn burstiness_zero_for_regular() {
        let mut w = ArrivalWindow::new(10_000.0);
        for i in 0..50 {
            w.record(i as f64 * 100.0);
        }
        assert!(w.burstiness() < 1e-9);
    }
}
