//! Content-dynamics generator: per-frame object counts over wall time.
//!
//! Three multiplicative components (matching what the paper's footage
//! exhibits — Fig. 1, Fig. 11):
//!   1. circadian curve: low at night, ramp through the morning, peak
//!      mid-afternoon (the paper observes a 3:30 PM peak), taper by 8 PM;
//!   2. burst regime (MMPP): calm <-> burst Markov states; bursts multiply
//!      intensity (rush hour, a crowd entering the scene);
//!   3. frame-level Poisson noise around the instantaneous mean.

use crate::util::Rng;
use crate::Ms;

/// Shape of the day-scale intensity curve.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DiurnalShape {
    /// Traffic cameras: strong afternoon peak.
    Traffic,
    /// Building surveillance: flatter, lunchtime + evening bumps.
    Surveillance,
    /// No diurnal modulation (short experiments).
    Flat,
}

/// Parameters for one camera's content process.
#[derive(Clone, Debug)]
pub struct ContentProfile {
    pub shape: DiurnalShape,
    /// Mean objects per frame at the diurnal peak.
    pub peak_objects: f64,
    /// Burst multiplier while in the burst regime.
    pub burst_factor: f64,
    /// Mean dwell in calm state, ms.
    pub calm_dwell_ms: Ms,
    /// Mean dwell in burst state, ms.
    pub burst_dwell_ms: Ms,
    /// Start-of-experiment offset into the day, ms (9 AM in the paper).
    pub day_offset_ms: Ms,
}

impl ContentProfile {
    pub fn traffic() -> ContentProfile {
        ContentProfile {
            shape: DiurnalShape::Traffic,
            peak_objects: 9.0,
            burst_factor: 2.6,
            calm_dwell_ms: 90_000.0,
            burst_dwell_ms: 25_000.0,
            day_offset_ms: 9.0 * 3_600_000.0,
        }
    }

    pub fn surveillance() -> ContentProfile {
        ContentProfile {
            shape: DiurnalShape::Surveillance,
            peak_objects: 5.0,
            burst_factor: 3.2,
            calm_dwell_ms: 140_000.0,
            burst_dwell_ms: 15_000.0,
            day_offset_ms: 9.0 * 3_600_000.0,
        }
    }

    pub fn flat(mean_objects: f64) -> ContentProfile {
        ContentProfile {
            shape: DiurnalShape::Flat,
            peak_objects: mean_objects,
            burst_factor: 2.0,
            calm_dwell_ms: 60_000.0,
            burst_dwell_ms: 20_000.0,
            day_offset_ms: 0.0,
        }
    }

    /// Flash-crowd stress profile: flat base intensity with frequent,
    /// strong burst episodes — the fuzzer's workload-spike class (a crowd
    /// entering the scene, rush-hour onset).
    pub fn flash_crowd(mean_objects: f64, burst_factor: f64) -> ContentProfile {
        ContentProfile {
            shape: DiurnalShape::Flat,
            peak_objects: mean_objects,
            burst_factor,
            calm_dwell_ms: 15_000.0,
            burst_dwell_ms: 8_000.0,
            day_offset_ms: 0.0,
        }
    }
}

/// Stateful per-camera object-count process.
#[derive(Clone, Debug)]
pub struct ContentDynamics {
    profile: ContentProfile,
    rng: Rng,
    in_burst: bool,
    regime_until_ms: Ms,
}

impl ContentDynamics {
    pub fn new(profile: ContentProfile, rng: Rng) -> ContentDynamics {
        ContentDynamics { profile, rng, in_burst: false, regime_until_ms: 0.0 }
    }

    /// Diurnal multiplier in [0.1, 1.0] at absolute experiment time `t_ms`.
    pub fn diurnal(&self, t_ms: Ms) -> f64 {
        let day_ms = 24.0 * 3_600_000.0;
        let tod = (self.profile.day_offset_ms + t_ms) % day_ms; // time of day
        let hour = tod / 3_600_000.0;
        match self.profile.shape {
            DiurnalShape::Flat => 1.0,
            DiurnalShape::Traffic => {
                // Ramp 6AM->peak 15.5 (3:30 PM, paper Fig. 11)->taper by 20.
                let peak_h = 15.5;
                let width = 5.5;
                let x = (hour - peak_h) / width;
                (0.12 + 0.88 * (-x * x).exp()).min(1.0)
            }
            DiurnalShape::Surveillance => {
                // Two bumps: lunch (12.5) and evening (18).
                let b1 = (-((hour - 12.5) / 2.5f64).powi(2)).exp();
                let b2 = (-((hour - 18.0) / 2.0f64).powi(2)).exp();
                (0.2 + 0.5 * b1 + 0.45 * b2).min(1.0)
            }
        }
    }

    /// Advance burst regime and return the mean object intensity at `t_ms`.
    pub fn intensity(&mut self, t_ms: Ms) -> f64 {
        if t_ms >= self.regime_until_ms {
            // Flip regime with exponential dwell.
            self.in_burst = !self.in_burst && {
                // Entering burst is likelier when diurnal intensity is high
                // (rush hour amplification, paper §IV-C3).
                let p = 0.35 + 0.4 * self.diurnal(t_ms);
                self.rng.chance(p)
            };
            let dwell = if self.in_burst {
                self.profile.burst_dwell_ms
            } else {
                self.profile.calm_dwell_ms
            };
            self.regime_until_ms = t_ms + self.rng.exp(1.0 / dwell);
        }
        let base = self.profile.peak_objects * self.diurnal(t_ms);
        if self.in_burst {
            base * self.profile.burst_factor
        } else {
            base
        }
    }

    /// Draw the object count for a frame at `t_ms`.
    pub fn objects_in_frame(&mut self, t_ms: Ms) -> u32 {
        let lambda = self.intensity(t_ms);
        self.rng.poisson(lambda) as u32
    }

    pub fn in_burst(&self) -> bool {
        self.in_burst
    }
}

/// During a static run, every this-many-th frame is forced through the
/// pipeline anyway — the same staleness bound the serving-path filter
/// applies ([`serving::filter::REFRESH_EVERY`](crate::serving::filter)).
pub const SCENE_REFRESH_FRAMES: u32 = 30;

/// Scene-level stand-in for the serving path's frame-difference filter:
/// alternating *static* runs (consecutive near-identical frames, which a
/// frontend answers from the previous result) and *active* runs (content
/// changed — every frame needs inference). Run lengths are geometric via
/// exponential draws, so the process is memoryless like the MMPP above.
///
/// The sim has no pixels, so the filter is modelled at the decision
/// level: [`filter_frame`](SceneFilter::filter_frame) says whether the
/// frame would have been skipped. Drawing from a dedicated RNG stream
/// (not the content RNG) keeps filter decisions scheduler-independent —
/// the workload fingerprint is identical with the frontend on or off.
#[derive(Clone, Debug)]
pub struct SceneFilter {
    /// Mean frames per static run; <= 0 disables filtering entirely.
    mean_static_frames: f64,
    /// Mean frames per active run.
    mean_active_frames: f64,
    rng: Rng,
    in_static: bool,
    /// Frames left in the current run.
    run_left: u32,
    /// Consecutive filtered frames since the last refresh pass.
    hits_since_refresh: u32,
}

impl SceneFilter {
    pub fn new(mean_static_frames: f64, rng: Rng) -> SceneFilter {
        SceneFilter {
            mean_static_frames,
            mean_active_frames: 15.0,
            rng,
            // `filter_frame` flips the regime when a run ends, so seeding
            // "static, 0 left" makes the first run *active*: the first
            // frames always reach the engine (the serving filter has no
            // reference frame yet either).
            in_static: true,
            run_left: 0,
            hits_since_refresh: 0,
        }
    }

    fn draw_run(&mut self, mean: f64) -> u32 {
        // rng.exp takes a *rate*; mean M frames -> rate 1/M.
        (self.rng.exp(1.0 / mean.max(1.0)).round() as u32).max(1)
    }

    /// Advance one frame; `true` means the frontend would answer it from
    /// the previous result (no engine work).
    pub fn filter_frame(&mut self) -> bool {
        if self.mean_static_frames <= 0.0 {
            return false;
        }
        if self.run_left == 0 {
            self.in_static = !self.in_static;
            let mean = if self.in_static {
                self.mean_static_frames
            } else {
                self.mean_active_frames
            };
            self.run_left = self.draw_run(mean);
        }
        self.run_left -= 1;
        if !self.in_static {
            self.hits_since_refresh = 0;
            return false;
        }
        // Staleness cap: periodically refresh the reference frame.
        if self.hits_since_refresh >= SCENE_REFRESH_FRAMES {
            self.hits_since_refresh = 0;
            return false;
        }
        self.hits_since_refresh += 1;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen(shape: fn() -> ContentProfile, seed: u64) -> ContentDynamics {
        ContentDynamics::new(shape(), Rng::new(seed))
    }

    #[test]
    fn traffic_peaks_mid_afternoon() {
        let d = gen(ContentProfile::traffic, 1);
        // t offsets from 9 AM start: 3:30 PM = +6.5h; 3 AM = +18h.
        let peak = d.diurnal(6.5 * 3_600_000.0);
        let night = d.diurnal(18.0 * 3_600_000.0);
        assert!(peak > 0.95);
        assert!(night < 0.3);
    }

    #[test]
    fn flat_has_no_modulation() {
        let d = gen(|| ContentProfile::flat(4.0), 2);
        assert_eq!(d.diurnal(0.0), 1.0);
        assert_eq!(d.diurnal(12.0 * 3_600_000.0), 1.0);
    }

    #[test]
    fn bursts_raise_mean_count() {
        let mut d = gen(ContentProfile::traffic, 3);
        let mut calm = Vec::new();
        let mut burst = Vec::new();
        for i in 0..200_000 {
            let t = i as f64 * 66.7; // 15 fps over ~3.7h
            let c = d.objects_in_frame(t);
            if d.in_burst() {
                burst.push(c as f64);
            } else {
                calm.push(c as f64);
            }
        }
        assert!(!burst.is_empty() && !calm.is_empty());
        let m = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        assert!(m(&burst) > 1.6 * m(&calm), "burst {} calm {}", m(&burst), m(&calm));
    }

    #[test]
    fn deterministic_replay() {
        let mut a = gen(ContentProfile::surveillance, 9);
        let mut b = gen(ContentProfile::surveillance, 9);
        for i in 0..1000 {
            let t = i as f64 * 66.7;
            assert_eq!(a.objects_in_frame(t), b.objects_in_frame(t));
        }
    }

    #[test]
    fn scene_filter_mixes_static_and_active_runs() {
        let mut f = SceneFilter::new(120.0, Rng::new(77));
        let n = 50_000;
        let filtered = (0..n).filter(|_| f.filter_frame()).count();
        let frac = filtered as f64 / n as f64;
        // Static runs mean 120 vs active mean 15, minus refresh passes:
        // the filtered fraction should be high but never total.
        assert!(frac > 0.6, "filtered fraction {frac}");
        assert!(frac < 0.97, "refresh passes must leak frames: {frac}");
    }

    #[test]
    fn scene_filter_first_frame_reaches_the_engine() {
        let mut f = SceneFilter::new(1e6, Rng::new(1));
        assert!(!f.filter_frame(), "no reference frame yet: engine pass");
    }

    #[test]
    fn scene_filter_refresh_bounds_consecutive_hits() {
        let mut f = SceneFilter::new(1e9, Rng::new(3));
        let mut consecutive = 0u32;
        let mut max_run = 0u32;
        for _ in 0..10_000 {
            if f.filter_frame() {
                consecutive += 1;
                max_run = max_run.max(consecutive);
            } else {
                consecutive = 0;
            }
        }
        assert!(max_run <= SCENE_REFRESH_FRAMES, "run {max_run}");
        assert!(max_run >= SCENE_REFRESH_FRAMES - 1, "cap should bind: {max_run}");
    }

    #[test]
    fn scene_filter_disabled_below_zero_mean() {
        let mut f = SceneFilter::new(0.0, Rng::new(4));
        assert!((0..1000).all(|_| !f.filter_frame()));
    }

    #[test]
    fn scene_filter_is_deterministic_per_seed() {
        let mut a = SceneFilter::new(120.0, Rng::new(9));
        let mut b = SceneFilter::new(120.0, Rng::new(9));
        for _ in 0..5000 {
            assert_eq!(a.filter_frame(), b.filter_frame());
        }
    }

    #[test]
    fn burstiness_of_generated_arrivals_exceeds_poisson() {
        // Downstream arrivals (object-driven) should be bursty: CV > 1.
        let mut d = gen(ContentProfile::traffic, 11);
        let mut arrivals = Vec::new();
        for i in 0..50_000 {
            let t = i as f64 * 66.7;
            for _ in 0..d.objects_in_frame(t) {
                arrivals.push(t);
            }
        }
        let b = crate::util::stats::burstiness(&arrivals);
        assert!(b > 1.0, "CV {b}");
    }
}
