//! Batch-latency profiles `L(m | bz, d, g, t)` — the quantity every
//! scheduler decision consumes (paper Eq. 2, Table II).
//!
//! Two sources compose:
//! 1. **Measured**: the `octopinf profile` subcommand executes the real AOT
//!    artifacts through PJRT on this host and writes a TSV of per-batch
//!    latencies; [`ProfileStore::load_tsv`] ingests it as the server-class
//!    profile.
//! 2. **Analytic**: for device classes we cannot run (Jetsons), latency is
//!    the server profile scaled by [`DeviceClass::compute_scale`], the same
//!    substitution DESIGN.md documents.
//!
//! Profiles are piecewise-linear in batch size: `lat(bz) = base + slope*bz`
//! fit from measurements, which matches the near-affine batch curves the
//! serving literature reports (and our PJRT measurements reproduce).

use std::collections::HashMap;
use std::path::Path;

use crate::cluster::DeviceClass;
use crate::pipeline::ModelSpec;
use crate::Ms;

/// Batch sizes every model is compiled for (mirrors python BATCH_SIZES).
pub const BATCH_SIZES: [u32; 6] = [1, 2, 4, 8, 16, 32];

/// Affine batch-latency curve for one (model family, device class).
#[derive(Clone, Copy, Debug)]
pub struct BatchCurve {
    /// Fixed per-launch cost, ms.
    pub base_ms: Ms,
    /// Marginal per-sample cost, ms.
    pub per_sample_ms: Ms,
}

impl BatchCurve {
    /// Latency of one batch execution.
    pub fn batch_latency(&self, bz: u32) -> Ms {
        self.base_ms + self.per_sample_ms * bz as f64
    }

    /// Average per-query latency inside a batch (paper: L_m^infer =
    /// L(bz)/bz — all queries in a batch complete together).
    pub fn per_query_latency(&self, bz: u32) -> Ms {
        self.batch_latency(bz) / bz.max(1) as f64
    }

    /// Max sustainable throughput at batch `bz` (queries/s).
    pub fn throughput(&self, bz: u32) -> f64 {
        1000.0 * bz as f64 / self.batch_latency(bz)
    }

    /// Least-squares fit from (batch, latency) samples.
    pub fn fit(samples: &[(u32, Ms)]) -> BatchCurve {
        let n = samples.len() as f64;
        if samples.len() < 2 {
            let l = samples.first().map(|&(b, l)| l / b.max(1) as f64).unwrap_or(1.0);
            return BatchCurve { base_ms: 0.0, per_sample_ms: l };
        }
        let sx: f64 = samples.iter().map(|&(b, _)| b as f64).sum();
        let sy: f64 = samples.iter().map(|&(_, l)| l).sum();
        let sxx: f64 = samples.iter().map(|&(b, _)| (b as f64) * (b as f64)).sum();
        let sxy: f64 = samples.iter().map(|&(b, l)| b as f64 * l).sum();
        let denom = n * sxx - sx * sx;
        let slope = ((n * sxy - sx * sy) / denom).max(1e-6);
        let base = ((sy - slope * sx) / n).max(0.0);
        BatchCurve { base_ms: base, per_sample_ms: slope }
    }
}

/// Profile registry: (family, device class) -> curve.
#[derive(Clone, Debug)]
pub struct ProfileStore {
    curves: HashMap<(String, DeviceClass), BatchCurve>,
}

/// Key for a model spec: its artifact family name.
fn family(spec: &ModelSpec) -> String {
    spec.kind.artifact_family(spec.variant).to_string()
}

impl ProfileStore {
    /// Analytic defaults calibrated to the repo's PJRT CPU measurements for
    /// the server class; edge classes are scaled (see module docs).
    pub fn analytic() -> ProfileStore {
        let mut curves = HashMap::new();
        // Server-class base curves (ms), calibrated so the paper testbed
        // (4x3090 + 9 Jetsons) is meaningfully loaded by 9 cameras at
        // 15 fps — matching the contention regime of §IV. Ratios between
        // detector variants follow their FLOP ratio; crop models are
        // cheaper but launch-bound.
        let base: &[(&str, f64, f64)] = &[
            ("det_s", 6.0, 3.0),
            ("det_m", 8.0, 4.0),
            ("det_l", 12.0, 6.0),
            ("classifier", 2.2, 0.50),
            ("embedder", 2.5, 0.55),
        ];
        for &(fam, b, s) in base {
            for class in [
                DeviceClass::Server,
                DeviceClass::JetsonAgx,
                DeviceClass::XavierNx,
                DeviceClass::OrinNano,
            ] {
                let k = class.compute_scale();
                curves.insert(
                    (fam.to_string(), class),
                    BatchCurve { base_ms: b * k, per_sample_ms: s * k },
                );
            }
        }
        ProfileStore { curves }
    }

    /// Ingest measured per-batch latencies (TSV: family batch lat_ms) as the
    /// server profile, rescaling edge classes from the new fit.
    pub fn load_tsv(&mut self, path: &Path) -> Result<usize, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let mut samples: HashMap<String, Vec<(u32, Ms)>> = HashMap::new();
        for (ln, line) in text.lines().enumerate() {
            if ln == 0 && line.starts_with("family") {
                continue; // header
            }
            if line.trim().is_empty() {
                continue;
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() < 3 {
                return Err(format!("bad TSV row {}: {line:?}", ln + 1));
            }
            let batch: u32 =
                cols[1].parse().map_err(|e| format!("row {}: {e}", ln + 1))?;
            let lat: f64 =
                cols[2].parse().map_err(|e| format!("row {}: {e}", ln + 1))?;
            samples.entry(cols[0].to_string()).or_default().push((batch, lat));
        }
        let n = samples.len();
        for (fam, pts) in samples {
            let fit = BatchCurve::fit(&pts);
            for class in [
                DeviceClass::Server,
                DeviceClass::JetsonAgx,
                DeviceClass::XavierNx,
                DeviceClass::OrinNano,
            ] {
                let k = class.compute_scale();
                self.curves.insert(
                    (fam.clone(), class),
                    BatchCurve {
                        base_ms: fit.base_ms * k,
                        per_sample_ms: fit.per_sample_ms * k,
                    },
                );
            }
        }
        Ok(n)
    }

    /// Curve lookup; panics on unknown family (programming error: presets
    /// and profiles are defined together).
    pub fn curve(&self, spec: &ModelSpec, class: DeviceClass) -> BatchCurve {
        *self
            .curves
            .get(&(family(spec), class))
            .unwrap_or_else(|| panic!("no profile for {}/{:?}", family(spec), class))
    }

    /// Batch latency for a spec on a device class.
    pub fn batch_latency(&self, spec: &ModelSpec, class: DeviceClass, bz: u32) -> Ms {
        self.curve(spec, class).batch_latency(bz)
    }

    /// GPU utilization rate of one instance at batch `bz` and request rate
    /// `rate` (Eq. 5): busy fraction = rate * batch_latency / (bz * 1000).
    pub fn utilization(
        &self,
        spec: &ModelSpec,
        class: DeviceClass,
        bz: u32,
        rate_qps: f64,
    ) -> f64 {
        let busy_frac =
            rate_qps * self.batch_latency(spec, class, bz) / (bz as f64 * 1000.0);
        busy_frac.min(1.0) * spec.util_width.max(0.05) / 0.35
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::ModelSpec;

    #[test]
    fn batching_increases_throughput_but_latency() {
        let c = BatchCurve { base_ms: 3.0, per_sample_ms: 1.0 };
        assert!(c.throughput(8) > c.throughput(1));
        assert!(c.batch_latency(8) > c.batch_latency(1));
        // Per-query latency *drops* with batch under an affine curve.
        assert!(c.per_query_latency(8) < c.per_query_latency(1));
    }

    #[test]
    fn fit_recovers_affine() {
        let truth = BatchCurve { base_ms: 2.5, per_sample_ms: 0.8 };
        let samples: Vec<(u32, f64)> =
            BATCH_SIZES.iter().map(|&b| (b, truth.batch_latency(b))).collect();
        let fit = BatchCurve::fit(&samples);
        assert!((fit.base_ms - 2.5).abs() < 1e-6);
        assert!((fit.per_sample_ms - 0.8).abs() < 1e-6);
    }

    #[test]
    fn edge_slower_than_server() {
        let ps = ProfileStore::analytic();
        let det = ModelSpec::detector("d", 1, 128);
        let server = ps.batch_latency(&det, DeviceClass::Server, 8);
        let orin = ps.batch_latency(&det, DeviceClass::OrinNano, 8);
        assert!(orin > 3.0 * server);
    }

    #[test]
    fn utilization_monotone_in_rate() {
        let ps = ProfileStore::analytic();
        let det = ModelSpec::detector("d", 1, 128);
        let lo = ps.utilization(&det, DeviceClass::Server, 8, 10.0);
        let hi = ps.utilization(&det, DeviceClass::Server, 8, 100.0);
        assert!(hi > lo);
    }

    #[test]
    fn load_tsv_overrides() {
        let dir = std::env::temp_dir().join("octopinf_prof_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("prof.tsv");
        std::fs::write(
            &path,
            "family\tbatch\tlat_ms\ndet_m\t1\t10.0\ndet_m\t2\t12.0\ndet_m\t4\t16.0\n",
        )
        .unwrap();
        let mut ps = ProfileStore::analytic();
        let n = ps.load_tsv(&path).unwrap();
        assert_eq!(n, 1);
        let det = ModelSpec::detector("d", 1, 128);
        let c = ps.curve(&det, DeviceClass::Server);
        assert!((c.base_ms - 8.0).abs() < 1e-6);
        assert!((c.per_sample_ms - 2.0).abs() < 1e-6);
    }

    #[test]
    fn load_tsv_rejects_garbage() {
        let dir = std::env::temp_dir().join("octopinf_prof_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.tsv");
        std::fs::write(&path, "det_m\tnot_a_number\t1.0\n").unwrap();
        let mut ps = ProfileStore::analytic();
        assert!(ps.load_tsv(&path).is_err());
    }
}
