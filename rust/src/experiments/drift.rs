//! Fixed-period vs drift-triggered replanning, compared across the
//! scenario fuzzer's adversarial families (`octopinf drift`).
//!
//! For every family the same fuzzed seeds run OctopInf twice — once with
//! the paper's fixed 6-minute scheduling clock only, once with
//! drift-triggered incremental replanning layered on top — with the
//! invariant engine armed in both runs, so every mid-run plan migration
//! is conservation-checked while the SLO numbers are gathered. This is
//! the evaluation behind the PR's claim that reacting to workload/network
//! drift at the *scheduling* layer (not just the autoscaler) is where the
//! SLO-attainment headroom is.

use crate::coordinator::{ReplanMode, SchedulerKind};
use crate::sim::{run_checked_with, FuzzClass, FuzzSpec, ScenarioGen};
use crate::util::table::{fnum, Table};

use super::runner::par_map;

/// Aggregate of one (family, mode) cell across its scenarios.
#[derive(Clone, Copy, Debug, Default)]
pub struct ModeAgg {
    pub on_time: u64,
    pub late: u64,
    pub dropped: u64,
    /// Plans installed across the family's runs (drift mode installs more).
    pub plans: u64,
    /// Live-deployment migrations among those installs.
    pub migrations: u64,
}

impl ModeAgg {
    /// SLO attainment over everything the runs admitted: on-time
    /// completions / (completions + drops).
    pub fn attainment(&self) -> f64 {
        let total = self.on_time + self.late + self.dropped;
        if total == 0 {
            0.0
        } else {
            self.on_time as f64 / total as f64
        }
    }
}

/// Periodic-vs-drift outcome for one fuzz family.
#[derive(Clone, Debug)]
pub struct FamilyComparison {
    pub class: FuzzClass,
    pub scenarios: usize,
    pub periodic: ModeAgg,
    pub drift: ModeAgg,
    /// Invariant violations across *all* runs of the family (must be 0).
    pub violations: usize,
}

/// Collect the first `per_family` specs of every fuzz family starting at
/// `seed0` (deterministic: same seeds for both modes by construction).
fn family_specs(seed0: u64, per_family: usize) -> Vec<(FuzzClass, Vec<FuzzSpec>)> {
    let mut buckets: Vec<(FuzzClass, Vec<FuzzSpec>)> =
        FuzzClass::ALL.iter().map(|&c| (c, Vec::new())).collect();
    // Seven families, geometric-ish fill: a bounded scan is plenty.
    for spec in ScenarioGen::new(seed0).take(per_family * 64) {
        let b = buckets.iter_mut().find(|(c, _)| *c == spec.class).unwrap();
        if b.1.len() < per_family {
            b.1.push(spec);
        }
        if buckets.iter().all(|(_, v)| v.len() >= per_family) {
            break;
        }
    }
    buckets
}

/// Run the comparison: `per_family` scenarios per family, both modes,
/// fanned across `jobs` workers. Results are deterministic and in family
/// order regardless of the job count.
pub fn drift_comparison(
    seed0: u64,
    per_family: usize,
    jobs: usize,
) -> Vec<FamilyComparison> {
    drift_comparison_with(seed0, per_family, jobs, 1)
}

/// [`drift_comparison`] with `sim_jobs` partition worker threads inside
/// every simulation (pure wall-clock knob; results byte-identical).
pub fn drift_comparison_with(
    seed0: u64,
    per_family: usize,
    jobs: usize,
    sim_jobs: usize,
) -> Vec<FamilyComparison> {
    let buckets = family_specs(seed0, per_family);
    // Flatten to independent (spec, mode) cells.
    let cells: Vec<(usize, FuzzSpec, ReplanMode)> = buckets
        .iter()
        .enumerate()
        .flat_map(|(fi, (_, specs))| {
            specs.iter().flat_map(move |s| {
                [ReplanMode::Periodic, ReplanMode::Drift]
                    .into_iter()
                    .map(move |m| (fi, s.clone(), m))
            })
        })
        .collect();
    let results = par_map(cells.len(), jobs, |i| {
        let (fi, spec, mode) = &cells[i];
        let mut spec = spec.clone();
        spec.cfg.replan = *mode;
        let (m, report) =
            run_checked_with(&spec.build(), SchedulerKind::OctopInf, sim_jobs);
        (
            *fi,
            *mode,
            ModeAgg {
                on_time: m.on_time,
                late: m.late,
                dropped: m.dropped,
                plans: report.plans,
                migrations: report.migrations,
            },
            report.violations.len() + report.suppressed as usize,
        )
    });
    let mut out: Vec<FamilyComparison> = buckets
        .iter()
        .map(|(c, specs)| FamilyComparison {
            class: *c,
            scenarios: specs.len(),
            periodic: ModeAgg::default(),
            drift: ModeAgg::default(),
            violations: 0,
        })
        .collect();
    for (fi, mode, agg, violations) in results {
        let f = &mut out[fi];
        let slot = match mode {
            ReplanMode::Periodic => &mut f.periodic,
            ReplanMode::Drift => &mut f.drift,
        };
        slot.on_time += agg.on_time;
        slot.late += agg.late;
        slot.dropped += agg.dropped;
        slot.plans += agg.plans;
        slot.migrations += agg.migrations;
        f.violations += violations;
    }
    out
}

/// Render the comparison for the CLI.
pub fn drift_table(cmps: &[FamilyComparison]) -> Table {
    let mut t = Table::new(vec![
        "family",
        "scenarios",
        "periodic_slo%",
        "drift_slo%",
        "delta_pp",
        "drift_replans",
        "violations",
    ]);
    for c in cmps {
        let p = 100.0 * c.periodic.attainment();
        let d = 100.0 * c.drift.attainment();
        t.row(vec![
            c.class.label().to_string(),
            c.scenarios.to_string(),
            fnum(p, 1),
            fnum(d, 1),
            fnum(d - p, 1),
            // Installs beyond the per-run initial plan are the replans the
            // drift triggers added (fixed-period fires none inside these
            // short fuzz horizons).
            c.drift.plans.saturating_sub(c.scenarios as u64).to_string(),
            c.violations.to_string(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn family_specs_are_deterministic_and_filled() {
        let a = family_specs(1234, 2);
        let b = family_specs(1234, 2);
        assert_eq!(a.len(), FuzzClass::ALL.len());
        for ((ca, va), (cb, vb)) in a.iter().zip(&b) {
            assert_eq!(ca, cb);
            assert_eq!(va.len(), 2, "{}", ca.label());
            for (x, y) in va.iter().zip(vb) {
                assert_eq!(x.seed, y.seed);
            }
        }
    }

    #[test]
    fn comparison_table_has_one_row_per_family() {
        // One scenario per family keeps this a smoke test; the full
        // assertion (drift beats periodic on the reactive families, zero
        // violations) lives in rust/tests/drift.rs.
        let cmps = drift_comparison(77, 1, 0);
        assert_eq!(cmps.len(), FuzzClass::ALL.len());
        let t = drift_table(&cmps);
        assert_eq!(t.n_rows(), FuzzClass::ALL.len());
        for c in &cmps {
            assert_eq!(c.violations, 0, "{}: invariant violations", c.class.label());
        }
    }
}
