//! Figure/table regenerators: one function per evaluation artifact of the
//! paper (§IV, Fig. 6-11). Each runs the simulator over the relevant
//! scenario + scheduler set and renders the same rows/series the paper
//! reports. Shared by `octopinf figure N` and the bench harness.

use crate::config::ExperimentConfig;
use crate::coordinator::SchedulerKind;
use crate::metrics::RunMetrics;
use crate::network::TraceKind;
use crate::sim::{run, Scenario};
use crate::util::table::{fnum, Table};

/// Duration used when `quick` (benches/smoke): 5 simulated minutes.
fn dur(quick: bool, full_min: f64) -> f64 {
    if quick { 5.0 * 60_000.0 } else { full_min * 60_000.0 }
}

fn run_kind(cfg: &ExperimentConfig, kind: SchedulerKind) -> RunMetrics {
    let sc = Scenario::build(cfg.clone());
    run(&sc, kind)
}

/// Fig. 6a-c: overall comparison — effective vs total throughput, latency
/// distribution stats, and total memory, per system.
pub fn fig6_overall(quick: bool) -> Table {
    let cfg = ExperimentConfig {
        duration_ms: dur(quick, 30.0),
        ..Default::default()
    };
    let mut t = Table::new(vec![
        "system",
        "eff_thpt(obj/s)",
        "total_thpt",
        "violation%",
        "lat_p50(ms)",
        "lat_p95(ms)",
        "memory(MB)",
    ]);
    for kind in SchedulerKind::all_main() {
        let mut m = run_kind(&cfg, kind);
        t.row(vec![
            kind.label().to_string(),
            fnum(m.effective_throughput(), 1),
            fnum(m.total_throughput(), 1),
            fnum(100.0 * m.violation_rate(), 1),
            fnum(m.latency.p50(), 1),
            fnum(m.latency.p95(), 1),
            fnum(m.peak_memory_mb, 0),
        ]);
    }
    t
}

/// Fig. 6d: OctopInf throughput vs workload over the run (per minute).
pub fn fig6_timeline(quick: bool) -> Table {
    let cfg = ExperimentConfig {
        duration_ms: dur(quick, 30.0),
        ..Default::default()
    };
    let m = run_kind(&cfg, SchedulerKind::OctopInf);
    let mut t = Table::new(vec!["minute", "workload(obj/s)", "effective(obj/s)"]);
    for (i, (w, e)) in m.timeline.iter().enumerate() {
        t.row(vec![format!("{}", i + 1), fnum(*w, 1), fnum(*e, 1)]);
    }
    t
}

/// Fig. 7: per-source adaptivity under LTE traces — workload, bandwidth,
/// and throughput per minute for each individual source.
pub fn fig7_adaptivity(quick: bool) -> Vec<(String, Table)> {
    let n_sources = if quick { 2 } else { 4 };
    let mut out = Vec::new();
    for s in 0..n_sources {
        let cfg = ExperimentConfig {
            n_sources: 1,
            trace: TraceKind::Lte,
            duration_ms: dur(quick, 30.0),
            seed: 42 + s as u64,
            ..Default::default()
        };
        let sc = Scenario::build(cfg);
        let label = sc.pipelines[0].name.clone();
        let m = run(&sc, SchedulerKind::OctopInf);
        let mut t =
            Table::new(vec!["minute", "workload(obj/s)", "throughput(obj/s)", "bw(Mbps)"]);
        for (i, (w, e)) in m.timeline.iter().enumerate() {
            let bw = sc.traces[1].bandwidth_mbps((i as f64 + 0.5) * 60_000.0);
            t.row(vec![
                format!("{}", i + 1),
                fnum(*w, 1),
                fnum(*e, 1),
                fnum(bw, 1),
            ]);
        }
        out.push((format!("source_{s}_{label}"), t));
    }
    out
}

/// Fig. 8: doubled per-device workload — effective ratio + hardware usage.
pub fn fig8_scale(quick: bool) -> Table {
    let cfg = ExperimentConfig {
        cameras_per_device: 2,
        duration_ms: dur(quick, 30.0),
        ..Default::default()
    };
    let mut t = Table::new(vec![
        "system",
        "eff_thpt(obj/s)",
        "eff/total%",
        "completion%",
        "gpu_util%",
    ]);
    for kind in SchedulerKind::all_main() {
        let m = run_kind(&cfg, kind);
        t.row(vec![
            kind.label().to_string(),
            fnum(m.effective_throughput(), 1),
            fnum(100.0 * m.effective_ratio(), 1),
            fnum(100.0 * m.completion_rate(), 1),
            fnum(100.0 * m.mean_gpu_util, 1),
        ]);
    }
    t
}

/// Fig. 9: stricter SLOs — effective throughput at -0/-50/-100 ms.
pub fn fig9_slo(quick: bool) -> Table {
    let mut t = Table::new(vec![
        "slo_reduction",
        "octopinf",
        "distream",
        "jellyfish",
        "rim",
    ]);
    for red in [0.0, 50.0, 100.0] {
        let cfg = ExperimentConfig {
            slo_reduction_ms: red,
            duration_ms: dur(quick, 30.0),
            ..Default::default()
        };
        let vals: Vec<String> = SchedulerKind::all_main()
            .iter()
            .map(|&k| fnum(run_kind(&cfg, k).effective_throughput(), 1))
            .collect();
        let mut row = vec![format!("-{red}ms")];
        row.extend(vals);
        t.row(row);
    }
    t
}

/// Fig. 10: ablation — full OctopInf vs w/o CORAL vs static batch vs
/// server-only, plus the two relevant baselines.
pub fn fig10_ablation(quick: bool) -> Table {
    let cfg = ExperimentConfig {
        duration_ms: dur(quick, 30.0),
        ..Default::default()
    };
    let kinds = [
        SchedulerKind::OctopInf,
        SchedulerKind::OctopInfNoCoral,
        SchedulerKind::OctopInfStaticBatch,
        SchedulerKind::OctopInfServerOnly,
        SchedulerKind::Distream,
        SchedulerKind::Jellyfish,
    ];
    let mut t = Table::new(vec![
        "variant",
        "eff_thpt(obj/s)",
        "lat_p50(ms)",
        "lat_p95(ms)",
    ]);
    for kind in kinds {
        let mut m = run_kind(&cfg, kind);
        t.row(vec![
            kind.label().to_string(),
            fnum(m.effective_throughput(), 1),
            fnum(m.latency.p50(), 1),
            fnum(m.latency.p95(), 1),
        ]);
    }
    t
}

/// Fig. 11: 13-hour diurnal run — per-30-minute effective throughput vs
/// workload for traffic and surveillance pipelines together.
pub fn fig11_longterm(quick: bool) -> Table {
    let cfg = ExperimentConfig {
        diurnal: true,
        duration_ms: if quick {
            2.0 * 3600.0 * 1000.0
        } else {
            13.0 * 3600.0 * 1000.0
        },
        n_sources: if quick { 3 } else { 9 },
        ..Default::default()
    };
    let m = run_kind(&cfg, SchedulerKind::OctopInf);
    let mut t = Table::new(vec!["half_hour", "workload(obj/s)", "effective(obj/s)"]);
    // Aggregate the per-minute timeline into 30-minute buckets.
    for (i, chunk) in m.timeline.chunks(30).enumerate() {
        let w: f64 = chunk.iter().map(|c| c.0).sum::<f64>() / chunk.len() as f64;
        let e: f64 = chunk.iter().map(|c| c.1).sum::<f64>() / chunk.len() as f64;
        t.row(vec![format!("{}", i + 1), fnum(w, 1), fnum(e, 1)]);
    }
    t
}

/// Table I (qualitative) — rendered for completeness.
pub fn table1() -> Table {
    let mut t = Table::new(vec![
        "system",
        "workload_distribution",
        "dynamic_batching",
        "spatiotemporal_gpu_sched",
    ]);
    t.row(vec!["jellyfish", "centralized", "single tasks", "no"]);
    t.row(vec!["distream", "distributed", "no", "no"]);
    t.row(vec!["rim", "distributed", "no", "no"]);
    t.row(vec!["octopinf", "distributed", "pipeline", "yes"]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full-figure runs are exercised by the bench harness; here we only
    // smoke the cheapest paths to keep `cargo test` fast.

    #[test]
    fn table1_has_four_systems() {
        assert_eq!(table1().n_rows(), 4);
    }

    #[test]
    fn fig6_timeline_quick_produces_rows() {
        let t = fig6_timeline(true);
        assert!(t.n_rows() >= 4, "rows {}", t.n_rows());
    }
}
