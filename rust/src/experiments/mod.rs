//! Figure/table regenerators: one function per evaluation artifact of the
//! paper (§IV, Fig. 6-11). Each builds the relevant (scheduler, seed,
//! scenario) grid, fans it across worker threads via [`runner::run_grid`]
//! (`jobs = 0` → all hardware threads, `1` → sequential), and renders the
//! same rows/series the paper reports. Cells are independent and seeded,
//! so tables are byte-identical at any job count. Shared by
//! `octopinf figure N [--jobs N]` and the bench harness.

pub mod chaos;
pub mod drift;
pub mod frontdoor;
pub mod fuzz;
pub mod runner;

pub use chaos::{
    chaos_comparison, chaos_comparison_with, chaos_digest, chaos_table,
    storm_specs, ChaosComparison,
};
pub use drift::{
    drift_comparison, drift_comparison_with, drift_table, FamilyComparison,
};
pub use frontdoor::{
    filter_comparison, frontdoor_outcome, isolation_comparison,
    run_front_harness, FrontdoorOutcome, HarnessCfg, TenantLoad,
};
pub use fuzz::{
    conformance_digest, conformance_round, conformance_round_mode,
    conformance_round_with, run_conformance, run_conformance_mode,
    run_conformance_with, ConformanceOutcome,
};
pub use runner::{run_grid, run_one, RunSpec};

use crate::config::ExperimentConfig;
use crate::coordinator::SchedulerKind;
use crate::network::TraceKind;
use crate::sim::Scenario;
use crate::util::table::{fnum, Table};

/// Duration used when `quick` (benches/smoke): 5 simulated minutes.
fn dur(quick: bool, full_min: f64) -> f64 {
    if quick { 5.0 * 60_000.0 } else { full_min * 60_000.0 }
}

/// Grid of all main systems over one shared config.
fn main_grid(cfg: &ExperimentConfig) -> Vec<RunSpec> {
    SchedulerKind::all_main()
        .iter()
        .map(|&k| RunSpec::new(k.label(), cfg.clone(), k))
        .collect()
}

/// Fig. 6a-c: overall comparison — effective vs total throughput, latency
/// distribution stats, and total memory, per system.
pub fn fig6_overall(quick: bool, jobs: usize) -> Table {
    let cfg = ExperimentConfig {
        duration_ms: dur(quick, 30.0),
        ..Default::default()
    };
    let results = run_grid(&main_grid(&cfg), jobs);
    let mut t = Table::new(vec![
        "system",
        "eff_thpt(obj/s)",
        "total_thpt",
        "violation%",
        "lat_p50(ms)",
        "lat_p95(ms)",
        "memory(MB)",
    ]);
    for (kind, m) in SchedulerKind::all_main().iter().zip(&results) {
        t.row(vec![
            kind.label().to_string(),
            fnum(m.effective_throughput(), 1),
            fnum(m.total_throughput(), 1),
            fnum(100.0 * m.violation_rate(), 1),
            fnum(m.latency.p50(), 1),
            fnum(m.latency.p95(), 1),
            fnum(m.peak_memory_mb, 0),
        ]);
    }
    t
}

/// Fig. 6d: OctopInf throughput vs workload over the run (per minute).
pub fn fig6_timeline(quick: bool) -> Table {
    let cfg = ExperimentConfig {
        duration_ms: dur(quick, 30.0),
        ..Default::default()
    };
    let m = run_one(&RunSpec::new("fig6d", cfg, SchedulerKind::OctopInf));
    let mut t = Table::new(vec!["minute", "workload(obj/s)", "effective(obj/s)"]);
    for (i, (w, e)) in m.timeline.iter().enumerate() {
        t.row(vec![format!("{}", i + 1), fnum(*w, 1), fnum(*e, 1)]);
    }
    t
}

/// Fig. 7: per-source adaptivity under LTE traces — workload, bandwidth,
/// and throughput per minute for each individual source.
pub fn fig7_adaptivity(quick: bool, jobs: usize) -> Vec<(String, Table)> {
    let n_sources = if quick { 2 } else { 4 };
    let specs: Vec<RunSpec> = (0..n_sources)
        .map(|s| {
            let cfg = ExperimentConfig {
                n_sources: 1,
                trace: TraceKind::Lte,
                duration_ms: dur(quick, 30.0),
                seed: 42 + s as u64,
                ..Default::default()
            };
            RunSpec::new(format!("fig7 source {s}"), cfg, SchedulerKind::OctopInf)
        })
        .collect();
    let results = run_grid(&specs, jobs);
    let mut out = Vec::new();
    for (s, (spec, m)) in specs.iter().zip(&results).enumerate() {
        // Rebuild the (cheap, deterministic) scenario for the trace and
        // pipeline name; the simulation itself ran on the grid above.
        let sc = Scenario::build(spec.cfg.clone());
        let label = sc.pipelines[0].name.clone();
        let mut t =
            Table::new(vec!["minute", "workload(obj/s)", "throughput(obj/s)", "bw(Mbps)"]);
        for (i, (w, e)) in m.timeline.iter().enumerate() {
            let bw = sc.traces[1].bandwidth_mbps((i as f64 + 0.5) * 60_000.0);
            t.row(vec![
                format!("{}", i + 1),
                fnum(*w, 1),
                fnum(*e, 1),
                fnum(bw, 1),
            ]);
        }
        out.push((format!("source_{s}_{label}"), t));
    }
    out
}

/// Fig. 8: doubled per-device workload — effective ratio + hardware usage.
pub fn fig8_scale(quick: bool, jobs: usize) -> Table {
    let cfg = ExperimentConfig {
        cameras_per_device: 2,
        duration_ms: dur(quick, 30.0),
        ..Default::default()
    };
    let results = run_grid(&main_grid(&cfg), jobs);
    let mut t = Table::new(vec![
        "system",
        "eff_thpt(obj/s)",
        "eff/total%",
        "completion%",
        "gpu_util%",
    ]);
    for (kind, m) in SchedulerKind::all_main().iter().zip(&results) {
        t.row(vec![
            kind.label().to_string(),
            fnum(m.effective_throughput(), 1),
            fnum(100.0 * m.effective_ratio(), 1),
            fnum(100.0 * m.completion_rate(), 1),
            fnum(100.0 * m.mean_gpu_util, 1),
        ]);
    }
    t
}

/// Fig. 9: stricter SLOs — effective throughput at -0/-50/-100 ms.
/// The full 3×4 grid runs as one fan-out.
pub fn fig9_slo(quick: bool, jobs: usize) -> Table {
    const REDUCTIONS: [f64; 3] = [0.0, 50.0, 100.0];
    let mut specs = Vec::new();
    for red in REDUCTIONS {
        let cfg = ExperimentConfig {
            slo_reduction_ms: red,
            duration_ms: dur(quick, 30.0),
            ..Default::default()
        };
        specs.extend(main_grid(&cfg).into_iter().map(|mut s| {
            s.label = format!("-{red}ms {}", s.label);
            s
        }));
    }
    let results = run_grid(&specs, jobs);
    let mut t = Table::new(vec![
        "slo_reduction",
        "octopinf",
        "distream",
        "jellyfish",
        "rim",
    ]);
    let width = SchedulerKind::all_main().len();
    for (i, red) in REDUCTIONS.iter().enumerate() {
        let mut row = vec![format!("-{red}ms")];
        row.extend(
            results[i * width..(i + 1) * width]
                .iter()
                .map(|m| fnum(m.effective_throughput(), 1)),
        );
        t.row(row);
    }
    t
}

/// Fig. 10: ablation — full OctopInf vs w/o CORAL vs static batch vs
/// server-only, plus the two relevant baselines.
pub fn fig10_ablation(quick: bool, jobs: usize) -> Table {
    let cfg = ExperimentConfig {
        duration_ms: dur(quick, 30.0),
        ..Default::default()
    };
    let kinds = [
        SchedulerKind::OctopInf,
        SchedulerKind::OctopInfNoCoral,
        SchedulerKind::OctopInfStaticBatch,
        SchedulerKind::OctopInfServerOnly,
        SchedulerKind::Distream,
        SchedulerKind::Jellyfish,
    ];
    let specs: Vec<RunSpec> = kinds
        .iter()
        .map(|&k| RunSpec::new(k.label(), cfg.clone(), k))
        .collect();
    let results = run_grid(&specs, jobs);
    let mut t = Table::new(vec![
        "variant",
        "eff_thpt(obj/s)",
        "lat_p50(ms)",
        "lat_p95(ms)",
    ]);
    for (kind, m) in kinds.iter().zip(&results) {
        t.row(vec![
            kind.label().to_string(),
            fnum(m.effective_throughput(), 1),
            fnum(m.latency.p50(), 1),
            fnum(m.latency.p95(), 1),
        ]);
    }
    t
}

/// Fig. 11: 13-hour diurnal run — per-30-minute effective throughput vs
/// workload for traffic and surveillance pipelines together.
pub fn fig11_longterm(quick: bool) -> Table {
    let cfg = ExperimentConfig {
        diurnal: true,
        duration_ms: if quick {
            2.0 * 3600.0 * 1000.0
        } else {
            13.0 * 3600.0 * 1000.0
        },
        n_sources: if quick { 3 } else { 9 },
        ..Default::default()
    };
    let m = run_one(&RunSpec::new("fig11", cfg, SchedulerKind::OctopInf));
    let mut t = Table::new(vec!["half_hour", "workload(obj/s)", "effective(obj/s)"]);
    // Aggregate the per-minute timeline into 30-minute buckets.
    for (i, chunk) in m.timeline.chunks(30).enumerate() {
        let w: f64 = chunk.iter().map(|c| c.0).sum::<f64>() / chunk.len() as f64;
        let e: f64 = chunk.iter().map(|c| c.1).sum::<f64>() / chunk.len() as f64;
        t.row(vec![format!("{}", i + 1), fnum(w, 1), fnum(e, 1)]);
    }
    t
}

/// Table I (qualitative) — rendered for completeness.
pub fn table1() -> Table {
    let mut t = Table::new(vec![
        "system",
        "workload_distribution",
        "dynamic_batching",
        "spatiotemporal_gpu_sched",
    ]);
    t.row(vec!["jellyfish", "centralized", "single tasks", "no"]);
    t.row(vec!["distream", "distributed", "no", "no"]);
    t.row(vec!["rim", "distributed", "no", "no"]);
    t.row(vec!["octopinf", "distributed", "pipeline", "yes"]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full-figure runs are exercised by the bench harness; here we only
    // smoke the cheapest paths to keep `cargo test` fast.

    #[test]
    fn table1_has_four_systems() {
        assert_eq!(table1().n_rows(), 4);
    }

    #[test]
    fn fig6_timeline_quick_produces_rows() {
        let t = fig6_timeline(true);
        assert!(t.n_rows() >= 4, "rows {}", t.n_rows());
    }
}
