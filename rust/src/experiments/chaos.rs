//! Chaos evaluation (`octopinf chaos`): every scheduler across seeded
//! `FaultStorm` scenarios, run twice — with failure-aware recovery
//! (crash/recover replanning, post-outage catch-up rounds) enabled and
//! disabled — with the invariant engine armed on every run, so graceful
//! degradation is measured while fault-aware conservation is enforced:
//! no storm may lose a query unaccounted.
//!
//! Recovery-policy knobs (config / repro-string level):
//! - `faults = M` (`:faults=M`) — number of sampled fault windows
//! - `order = K` (`:order=K`) — same-time event permutation seed
//! - `recovery = on|off` — failure-aware replanning on fault edges
//! - `crash_policy = reroute|drop` — crashed device's queued queries
//!   survive for migration, or die with the hardware

use crate::coordinator::{ReplanMode, SchedulerKind};
use crate::sim::{run_checked_with, FuzzSpec};
use crate::util::stats::{fnv1a, FNV_OFFSET};
use crate::util::table::{fnum, Table};

use super::runner::par_map;

/// Aggregate of one (scheduler, recovery) cell across its storms.
#[derive(Clone, Copy, Debug, Default)]
pub struct ChaosAgg {
    pub on_time: u64,
    pub late: u64,
    pub dropped: u64,
    /// Queries destroyed by injected faults (exactly reconciled by the
    /// invariant engine — the census closes or the run is a violation).
    pub lost_to_fault: u64,
    /// Plans installed across the cell's runs (recovery installs more).
    pub plans: u64,
}

impl ChaosAgg {
    /// SLO attainment over everything admitted: on-time completions /
    /// (completions + drops + fault losses). Fault losses stay in the
    /// denominator — a storm that destroys work must cost attainment.
    pub fn attainment(&self) -> f64 {
        let total = self.on_time + self.late + self.dropped + self.lost_to_fault;
        if total == 0 {
            0.0
        } else {
            self.on_time as f64 / total as f64
        }
    }
}

/// Recovery-on vs recovery-off outcome for one scheduler.
#[derive(Clone, Debug)]
pub struct ChaosComparison {
    pub kind: SchedulerKind,
    pub scenarios: usize,
    pub recovery: ChaosAgg,
    pub no_recovery: ChaosAgg,
    /// Invariant violations across *all* runs of the cell (must be 0).
    pub violations: usize,
}

/// The first `n` FaultStorm specs from `seed0` (deterministic; both
/// recovery arms replay the same storms by construction).
pub fn storm_specs(seed0: u64, n: usize) -> Vec<FuzzSpec> {
    (0..n)
        .map(|i| FuzzSpec::sample_storm(seed0.wrapping_add(i as u64)))
        .collect()
}

/// Run the comparison: `n` storms per scheduler, recovery on and off,
/// fanned across `jobs` workers. Deterministic at any job count.
pub fn chaos_comparison(
    seed0: u64,
    n: usize,
    jobs: usize,
    mode: ReplanMode,
) -> Vec<ChaosComparison> {
    chaos_comparison_with(seed0, n, jobs, mode, 1, 1)
}

/// [`chaos_comparison`] with `clusters` partitions per storm and
/// `sim_jobs` partition workers inside every simulation. Both job axes
/// are pure wall-clock knobs — the comparisons and [`chaos_digest`] over
/// them are byte-identical at any combination.
pub fn chaos_comparison_with(
    seed0: u64,
    n: usize,
    jobs: usize,
    mode: ReplanMode,
    sim_jobs: usize,
    clusters: usize,
) -> Vec<ChaosComparison> {
    let kinds = SchedulerKind::all_main();
    let mut specs = storm_specs(seed0, n);
    for s in &mut specs {
        s.cfg.clusters = clusters.max(1);
    }
    // Flatten to independent (scheduler, spec, recovery) cells.
    let cells: Vec<(usize, FuzzSpec, bool)> = kinds
        .iter()
        .enumerate()
        .flat_map(|(ki, _)| {
            specs.iter().flat_map(move |s| {
                [true, false].into_iter().map(move |rec| (ki, s.clone(), rec))
            })
        })
        .collect();
    let results = par_map(cells.len(), jobs, |i| {
        let (ki, spec, rec) = &cells[i];
        let mut spec = spec.clone();
        spec.cfg.replan = mode;
        spec.cfg.recovery = *rec;
        let (m, report) = run_checked_with(&spec.build(), kinds[*ki], sim_jobs);
        (
            *ki,
            *rec,
            ChaosAgg {
                on_time: m.on_time,
                late: m.late,
                dropped: m.dropped,
                lost_to_fault: m.lost_to_fault,
                plans: report.plans,
            },
            report.violations.len() + report.suppressed as usize,
        )
    });
    let mut out: Vec<ChaosComparison> = kinds
        .iter()
        .map(|&k| ChaosComparison {
            kind: k,
            scenarios: specs.len(),
            recovery: ChaosAgg::default(),
            no_recovery: ChaosAgg::default(),
            violations: 0,
        })
        .collect();
    for (ki, rec, agg, violations) in results {
        let c = &mut out[ki];
        let slot = if rec { &mut c.recovery } else { &mut c.no_recovery };
        slot.on_time += agg.on_time;
        slot.late += agg.late;
        slot.dropped += agg.dropped;
        slot.lost_to_fault += agg.lost_to_fault;
        slot.plans += agg.plans;
        c.violations += violations;
    }
    out
}

/// One 64-bit line for a whole chaos run: every cell's counters in
/// scheduler order, recovery and no-recovery arms both folded. CI runs
/// the same storms at `--sim-jobs 1` and `--sim-jobs 4` and fails on any
/// difference.
pub fn chaos_digest(cmps: &[ChaosComparison]) -> u64 {
    let mut h = FNV_OFFSET;
    for (i, c) in cmps.iter().enumerate() {
        h = fnv1a(h, i as u64);
        h = fnv1a(h, c.scenarios as u64);
        h = fnv1a(h, c.violations as u64);
        for agg in [&c.recovery, &c.no_recovery] {
            h = fnv1a(h, agg.on_time);
            h = fnv1a(h, agg.late);
            h = fnv1a(h, agg.dropped);
            h = fnv1a(h, agg.lost_to_fault);
            h = fnv1a(h, agg.plans);
        }
    }
    h
}

/// Render the comparison for the CLI.
pub fn chaos_table(cmps: &[ChaosComparison]) -> Table {
    let mut t = Table::new(vec![
        "system",
        "storms",
        "no_recovery_slo%",
        "recovery_slo%",
        "delta_pp",
        "lost_to_fault",
        "recovery_replans",
        "violations",
    ]);
    for c in cmps {
        let off = 100.0 * c.no_recovery.attainment();
        let on = 100.0 * c.recovery.attainment();
        t.row(vec![
            c.kind.label().to_string(),
            c.scenarios.to_string(),
            fnum(off, 1),
            fnum(on, 1),
            fnum(on - off, 1),
            format!("{}/{}", c.recovery.lost_to_fault, c.no_recovery.lost_to_fault),
            // Installs beyond the per-run initial plan: the fault-edge
            // replans recovery added (both arms share the drift/periodic
            // clocks, so the difference is the recovery reaction).
            c.recovery
                .plans
                .saturating_sub(c.no_recovery.plans)
                .to_string(),
            c.violations.to_string(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn storm_specs_are_deterministic() {
        let a = storm_specs(99, 4);
        let b = storm_specs(99, 4);
        assert_eq!(a.len(), 4);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.seed, y.seed);
            assert_eq!(x.cfg.faults, y.cfg.faults);
            assert!(x.cfg.faults > 0, "storm without faults");
        }
    }

    #[test]
    fn comparison_table_has_one_row_per_scheduler() {
        // One storm keeps this a smoke test; the full assertion (recovery
        // >= no-recovery for OctopInf, zero violations, losses accounted)
        // lives in rust/tests/chaos.rs.
        let cmps = chaos_comparison(31, 1, 0, ReplanMode::Periodic);
        assert_eq!(cmps.len(), SchedulerKind::all_main().len());
        let t = chaos_table(&cmps);
        assert_eq!(t.n_rows(), cmps.len());
        for c in &cmps {
            assert_eq!(c.violations, 0, "{}: invariant violations", c.kind.label());
        }
    }

    #[test]
    fn chaos_digest_is_invariant_to_sim_jobs() {
        let base =
            chaos_comparison_with(57, 1, 0, ReplanMode::Periodic, 1, 2);
        let d0 = chaos_digest(&base);
        let par = chaos_comparison_with(57, 1, 0, ReplanMode::Periodic, 4, 2);
        assert_eq!(chaos_digest(&par), d0, "sim-jobs changed chaos results");
        let other =
            chaos_comparison_with(58, 1, 0, ReplanMode::Periodic, 1, 2);
        assert_ne!(chaos_digest(&other), d0, "digest ignores the storms");
    }
}
