//! Parallel experiment runner: fans a grid of independent
//! (scenario-config, scheduler) simulation cells across scoped worker
//! threads.
//!
//! Every cell is fully self-contained — it builds its own `Scenario` from
//! its config (deterministic from the seed) and runs its own simulator —
//! so cells can execute in any order on any thread. Results are merged
//! back **in input order**, which makes `--jobs N` output byte-identical
//! to `--jobs 1`: parallelism changes wall-clock only, never tables.

use crate::config::ExperimentConfig;
use crate::coordinator::SchedulerKind;
use crate::metrics::RunMetrics;
use crate::sim::{run, Scenario};

// The deterministic fan-out itself lives in `util::par` now (the sim
// driver's partition loop shares it); re-exported here because every
// experiment module — and external callers — historically import it from
// the runner.
pub use crate::util::par::{effective_jobs, par_map};

/// One cell of an experiment grid.
#[derive(Clone, Debug)]
pub struct RunSpec {
    /// Human label carried through to error messages / progress output.
    pub label: String,
    pub cfg: ExperimentConfig,
    pub kind: SchedulerKind,
}

impl RunSpec {
    pub fn new(
        label: impl Into<String>,
        cfg: ExperimentConfig,
        kind: SchedulerKind,
    ) -> RunSpec {
        RunSpec { label: label.into(), cfg, kind }
    }
}

/// Run one cell: build its scenario and simulate.
pub fn run_one(spec: &RunSpec) -> RunMetrics {
    let sc = Scenario::build(spec.cfg.clone());
    run(&sc, spec.kind)
}

/// Execute every cell, `jobs` at a time (`0` = all hardware threads), and
/// return metrics **in input order** regardless of completion order.
pub fn run_grid(specs: &[RunSpec], jobs: usize) -> Vec<RunMetrics> {
    par_map(specs.len(), jobs, |i| run_one(&specs[i]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::preset;

    fn smoke_grid() -> Vec<RunSpec> {
        let cfg = preset("smoke").unwrap();
        SchedulerKind::all_main()
            .iter()
            .map(|&k| RunSpec::new(k.label(), cfg.clone(), k))
            .collect()
    }

    #[test]
    fn par_map_preserves_index_order() {
        for jobs in [1, 3, 8] {
            let out = par_map(17, jobs, |i| i * i);
            assert_eq!(out, (0..17).map(|i| i * i).collect::<Vec<_>>(), "jobs={jobs}");
        }
        assert!(par_map(0, 4, |i| i).is_empty());
    }

    #[test]
    fn effective_jobs_bounds() {
        assert_eq!(effective_jobs(3, 8), 3);
        assert_eq!(effective_jobs(16, 4), 4);
        assert_eq!(effective_jobs(5, 0), 1);
        assert!(effective_jobs(0, 100) >= 1);
    }

    #[test]
    fn parallel_grid_matches_sequential_bit_for_bit() {
        let specs = smoke_grid();
        let seq = run_grid(&specs, 1);
        let par = run_grid(&specs, 4);
        assert_eq!(seq.len(), par.len());
        for (i, (a, b)) in seq.iter().zip(&par).enumerate() {
            assert_eq!(a.on_time, b.on_time, "cell {i}");
            assert_eq!(a.late, b.late, "cell {i}");
            assert_eq!(a.dropped, b.dropped, "cell {i}");
            assert_eq!(a.peak_memory_mb, b.peak_memory_mb, "cell {i}");
            assert_eq!(a.mean_gpu_util, b.mean_gpu_util, "cell {i}");
            assert_eq!(a.timeline, b.timeline, "cell {i}");
            for q in [0.5, 0.95, 0.99] {
                assert_eq!(
                    a.latency.quantile(q),
                    b.latency.quantile(q),
                    "cell {i} q={q}"
                );
            }
        }
    }
}
