//! Differential scheduler conformance over fuzzed scenarios.
//!
//! One *round* takes a [`FuzzSpec`], rebuilds its scenario independently
//! for every scheduler in [`SchedulerKind::conformance_set`], runs each
//! under the invariant engine ([`crate::sim::invariants`]), and then
//! cross-checks the scheduler-independent quantities — source frames,
//! content-process object totals, and the uplink traces' bandwidth
//! integrals — bit-for-bit across the five runs. Any violation or
//! divergence is reported with the spec's one-line repro string, so
//! `octopinf fuzz --repro fuzz:v1:seed=N` replays it deterministically.
//!
//! Rounds are independent, so sweeps fan out across scoped worker threads
//! via [`super::runner::par_map`] (results merged in seed order;
//! `jobs = 0` means one worker per hardware thread).

use crate::coordinator::{ReplanMode, SchedulerKind};
use crate::sim::{run_checked, FuzzSpec, Scenario, ScenarioGen};

use super::runner::par_map;

/// Everything one conformance round learned about one fuzzed scenario.
#[derive(Clone, Debug)]
pub struct ConformanceOutcome {
    pub spec: FuzzSpec,
    /// Invariant violations, tagged with the scheduler that produced them.
    pub violations: Vec<(SchedulerKind, String)>,
    /// Cross-scheduler divergences in scheduler-independent quantities.
    pub divergences: Vec<String>,
    /// Total completed queries across all runs (sanity: the round did work).
    pub total_completions: u64,
    pub runs: usize,
}

impl ConformanceOutcome {
    pub fn ok(&self) -> bool {
        self.violations.is_empty() && self.divergences.is_empty()
    }

    /// Multi-line failure description headed by the repro string.
    pub fn describe_failures(&self) -> String {
        let mut out = format!("{}", self.spec);
        for (kind, v) in &self.violations {
            out.push_str(&format!("\n  [{}] {v}", kind.label()));
        }
        for d in &self.divergences {
            out.push_str(&format!("\n  [differential] {d}"));
        }
        out
    }
}

/// Bit-exact fingerprint of the scenario's uplink traces: XOR of the
/// per-trace bandwidth integrals' IEEE-754 bit patterns, position-salted.
fn trace_fingerprint(sc: &Scenario) -> u64 {
    sc.traces.iter().enumerate().fold(0u64, |acc, (i, t)| {
        acc ^ t.integral_mbps_s().to_bits().rotate_left((i % 63) as u32)
    })
}

/// Run every conformance scheduler over `spec`'s scenario and collect
/// violations plus differential mismatches.
pub fn conformance_round(spec: &FuzzSpec) -> ConformanceOutcome {
    conformance_round_mode(spec, ReplanMode::Periodic)
}

/// [`conformance_round`] under an explicit replan mode (the `--replan`
/// axis): drift mode exercises mid-run incremental replans and plan
/// migrations under the same invariant engine and differential checks.
pub fn conformance_round_mode(
    spec: &FuzzSpec,
    mode: ReplanMode,
) -> ConformanceOutcome {
    let mut spec = spec.clone();
    spec.cfg.replan = mode;
    let spec = &spec;
    let mut outcome = ConformanceOutcome {
        spec: spec.clone(),
        violations: Vec::new(),
        divergences: Vec::new(),
        total_completions: 0,
        runs: 0,
    };
    // (kind, frames, objects, trace bits) per run; each run rebuilds the
    // scenario from the spec so generator determinism is itself under test.
    let mut prints: Vec<(SchedulerKind, u64, u64, u64)> = Vec::new();
    for kind in SchedulerKind::conformance_set() {
        let sc = spec.build();
        let bits = trace_fingerprint(&sc);
        let (_metrics, report) = run_checked(&sc, kind);
        outcome.runs += 1;
        outcome.total_completions += report.completed_queries;
        for v in &report.violations {
            outcome.violations.push((kind, v.clone()));
        }
        if report.suppressed > 0 {
            outcome
                .violations
                .push((kind, format!("+{} suppressed violations", report.suppressed)));
        }
        let (frames, objects) = report.workload_fingerprint();
        prints.push((kind, frames, objects, bits));
    }
    if let Some(&(k0, f0, o0, b0)) = prints.first() {
        for &(k, f, o, b) in &prints[1..] {
            if f != f0 {
                outcome.divergences.push(format!(
                    "frames diverge: {}={f0} vs {}={f}",
                    k0.label(),
                    k.label()
                ));
            }
            if o != o0 {
                outcome.divergences.push(format!(
                    "content objects diverge: {}={o0} vs {}={o}",
                    k0.label(),
                    k.label()
                ));
            }
            if b != b0 {
                outcome.divergences.push(format!(
                    "trace integrals diverge: {}={b0:#x} vs {}={b:#x}",
                    k0.label(),
                    k.label()
                ));
            }
        }
    }
    outcome
}

/// Sweep `n` fuzzed scenarios (seeds `seed0..seed0+n`) across `jobs`
/// workers; outcomes return in seed order regardless of completion order.
pub fn run_conformance(seed0: u64, n: usize, jobs: usize) -> Vec<ConformanceOutcome> {
    run_conformance_mode(seed0, n, jobs, ReplanMode::Periodic)
}

/// [`run_conformance`] under an explicit replan mode.
pub fn run_conformance_mode(
    seed0: u64,
    n: usize,
    jobs: usize,
    mode: ReplanMode,
) -> Vec<ConformanceOutcome> {
    let specs: Vec<FuzzSpec> = ScenarioGen::new(seed0).take(n).collect();
    par_map(specs.len(), jobs, |i| conformance_round_mode(&specs[i], mode))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_round_is_clean_and_deterministic() {
        let spec = FuzzSpec::sample(11);
        let a = conformance_round(&spec);
        assert!(a.ok(), "{}", a.describe_failures());
        assert_eq!(a.runs, 5);
        assert!(a.total_completions > 0, "round did no work");
        let b = conformance_round(&spec);
        assert_eq!(a.total_completions, b.total_completions);
    }

    #[test]
    fn parallel_sweep_matches_sequential() {
        let seq = run_conformance(400, 4, 1);
        let par = run_conformance(400, 4, 4);
        assert_eq!(seq.len(), par.len());
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.spec.seed, b.spec.seed);
            assert_eq!(a.total_completions, b.total_completions);
            assert_eq!(a.ok(), b.ok());
        }
    }
}
