//! Differential scheduler conformance over fuzzed scenarios.
//!
//! One *round* takes a [`FuzzSpec`], rebuilds its scenario independently
//! for every scheduler in [`SchedulerKind::conformance_set`], runs each
//! under the invariant engine ([`crate::sim::invariants`]), and then
//! cross-checks the scheduler-independent quantities — source frames,
//! content-process object totals, and the uplink traces' bandwidth
//! integrals — bit-for-bit across the five runs. Any violation or
//! divergence is reported with the spec's one-line repro string, so
//! `octopinf fuzz --repro fuzz:v1:seed=N` replays it deterministically.
//!
//! Rounds are independent, so sweeps fan out across scoped worker threads
//! via [`super::runner::par_map`] (results merged in seed order;
//! `jobs = 0` means one worker per hardware thread).

use crate::coordinator::{ReplanMode, SchedulerKind};
use crate::metrics::RunMetrics;
use crate::obs::TraceEvent;
use crate::sim::{
    run_checked_with, FuzzSpec, InvariantReport, Scenario, ScenarioGen,
    Simulator,
};
use crate::util::stats::{fnv1a, FNV_OFFSET};

use super::runner::par_map;

/// Everything one conformance round learned about one fuzzed scenario.
#[derive(Clone, Debug)]
pub struct ConformanceOutcome {
    pub spec: FuzzSpec,
    /// Invariant violations, tagged with the scheduler that produced them.
    pub violations: Vec<(SchedulerKind, String)>,
    /// Cross-scheduler divergences in scheduler-independent quantities.
    pub divergences: Vec<String>,
    /// Total completed queries across all runs (sanity: the round did work).
    pub total_completions: u64,
    pub runs: usize,
    /// FNV fold of every run's full [`RunMetrics::digest`], in scheduler
    /// order — the bit-exact summary the `--sim-jobs` determinism gate in
    /// `ci.sh` diffs.
    pub metrics_digest: u64,
}

impl ConformanceOutcome {
    pub fn ok(&self) -> bool {
        self.violations.is_empty() && self.divergences.is_empty()
    }

    /// Multi-line failure description headed by the repro string.
    pub fn describe_failures(&self) -> String {
        let mut out = format!("{}", self.spec);
        for (kind, v) in &self.violations {
            out.push_str(&format!("\n  [{}] {v}", kind.label()));
        }
        for d in &self.divergences {
            out.push_str(&format!("\n  [differential] {d}"));
        }
        out
    }
}

/// Bit-exact fingerprint of the scenario's uplink traces: XOR of the
/// per-trace bandwidth integrals' IEEE-754 bit patterns, position-salted.
fn trace_fingerprint(sc: &Scenario) -> u64 {
    sc.traces.iter().enumerate().fold(0u64, |acc, (i, t)| {
        acc ^ t.integral_mbps_s().to_bits().rotate_left((i % 63) as u32)
    })
}

/// Run every conformance scheduler over `spec`'s scenario and collect
/// violations plus differential mismatches.
pub fn conformance_round(spec: &FuzzSpec) -> ConformanceOutcome {
    conformance_round_mode(spec, ReplanMode::Periodic)
}

/// [`conformance_round`] under an explicit replan mode (the `--replan`
/// axis): drift mode exercises mid-run incremental replans and plan
/// migrations under the same invariant engine and differential checks.
pub fn conformance_round_mode(
    spec: &FuzzSpec,
    mode: ReplanMode,
) -> ConformanceOutcome {
    conformance_round_with(spec, mode, 1)
}

/// [`conformance_round_mode`] with `sim_jobs` partition worker threads
/// inside every simulation (a pure wall-clock knob — the outcome,
/// including `metrics_digest`, is byte-identical at any value).
pub fn conformance_round_with(
    spec: &FuzzSpec,
    mode: ReplanMode,
    sim_jobs: usize,
) -> ConformanceOutcome {
    let mut spec = spec.clone();
    spec.cfg.replan = mode;
    let spec = &spec;
    let mut outcome = ConformanceOutcome {
        spec: spec.clone(),
        violations: Vec::new(),
        divergences: Vec::new(),
        total_completions: 0,
        runs: 0,
        metrics_digest: FNV_OFFSET,
    };
    // (kind, frames, objects, trace bits) per run; each run rebuilds the
    // scenario from the spec so generator determinism is itself under test.
    let mut prints: Vec<(SchedulerKind, u64, u64, u64)> = Vec::new();
    for kind in SchedulerKind::conformance_set() {
        let sc = spec.build();
        let bits = trace_fingerprint(&sc);
        let (metrics, report) = run_checked_with(&sc, kind, sim_jobs);
        outcome.runs += 1;
        outcome.metrics_digest = fnv1a(outcome.metrics_digest, metrics.digest());
        outcome.total_completions += report.completed_queries;
        for v in &report.violations {
            outcome.violations.push((kind, v.clone()));
        }
        if report.suppressed > 0 {
            outcome
                .violations
                .push((kind, format!("+{} suppressed violations", report.suppressed)));
        }
        let (frames, objects) = report.workload_fingerprint();
        prints.push((kind, frames, objects, bits));
    }
    if let Some(&(k0, f0, o0, b0)) = prints.first() {
        for &(k, f, o, b) in &prints[1..] {
            if f != f0 {
                outcome.divergences.push(format!(
                    "frames diverge: {}={f0} vs {}={f}",
                    k0.label(),
                    k.label()
                ));
            }
            if o != o0 {
                outcome.divergences.push(format!(
                    "content objects diverge: {}={o0} vs {}={o}",
                    k0.label(),
                    k.label()
                ));
            }
            if b != b0 {
                outcome.divergences.push(format!(
                    "trace integrals diverge: {}={b0:#x} vs {}={b:#x}",
                    k0.label(),
                    k.label()
                ));
            }
        }
    }
    outcome
}

/// Deterministic traced replay of one fuzzed spec under the reference
/// scheduler — the `octopinf fuzz --trace` / `octopinf why` postmortem
/// entry. Arms the invariant engine *and* the full tracer, wiring the
/// spec's exact repro string into every partition's flight recorder (so
/// a violation mid-replay dumps with the same one-liner that started
/// it). Metrics, report, and per-partition trace logs are all
/// byte-identical at any `sim_jobs`.
pub fn traced_replay(
    spec: &FuzzSpec,
    sim_jobs: usize,
) -> (RunMetrics, InvariantReport, Vec<Vec<TraceEvent>>) {
    let sc = spec.build();
    let mut sim = Simulator::new(&sc, SchedulerKind::OctopInf);
    sim.set_sim_jobs(sim_jobs);
    sim.enable_invariants();
    sim.enable_tracing();
    sim.set_repro(&spec.repro());
    let metrics = sim.run();
    let report = sim
        .take_invariant_report()
        .expect("invariants were enabled before run");
    let trace = sim.take_trace();
    (metrics, report, trace)
}

/// Sweep `n` fuzzed scenarios (seeds `seed0..seed0+n`) across `jobs`
/// workers; outcomes return in seed order regardless of completion order.
pub fn run_conformance(seed0: u64, n: usize, jobs: usize) -> Vec<ConformanceOutcome> {
    run_conformance_mode(seed0, n, jobs, ReplanMode::Periodic)
}

/// [`run_conformance`] under an explicit replan mode.
pub fn run_conformance_mode(
    seed0: u64,
    n: usize,
    jobs: usize,
    mode: ReplanMode,
) -> Vec<ConformanceOutcome> {
    run_conformance_with(seed0, n, jobs, mode, 1, 1)
}

/// Full-knob sweep: `clusters` partitions per scenario (> 1 makes every
/// spec a multi-cluster workload, recorded in its repro string) and
/// `sim_jobs` partition workers inside each simulation. The outcome
/// vector — and [`conformance_digest`] over it — is byte-identical at any
/// `jobs`/`sim_jobs` combination.
pub fn run_conformance_with(
    seed0: u64,
    n: usize,
    jobs: usize,
    mode: ReplanMode,
    sim_jobs: usize,
    clusters: usize,
) -> Vec<ConformanceOutcome> {
    let specs: Vec<FuzzSpec> = ScenarioGen::new(seed0)
        .take(n)
        .map(|mut s| {
            s.cfg.clusters = clusters.max(1);
            s
        })
        .collect();
    par_map(specs.len(), jobs, |i| {
        conformance_round_with(&specs[i], mode, sim_jobs)
    })
}

/// One 64-bit line for a whole conformance sweep: folds every outcome's
/// seed, run/violation/divergence counts, completions, and full metrics
/// digest. CI runs the same sweep at `--sim-jobs 1` and `--sim-jobs 4`
/// and fails on any difference.
pub fn conformance_digest(outcomes: &[ConformanceOutcome]) -> u64 {
    let mut h = FNV_OFFSET;
    for o in outcomes {
        h = fnv1a(h, o.spec.seed);
        h = fnv1a(h, o.runs as u64);
        h = fnv1a(h, o.total_completions);
        h = fnv1a(h, o.violations.len() as u64);
        h = fnv1a(h, o.divergences.len() as u64);
        h = fnv1a(h, o.metrics_digest);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_round_is_clean_and_deterministic() {
        let spec = FuzzSpec::sample(11);
        let a = conformance_round(&spec);
        assert!(a.ok(), "{}", a.describe_failures());
        assert_eq!(a.runs, 5);
        assert!(a.total_completions > 0, "round did no work");
        let b = conformance_round(&spec);
        assert_eq!(a.total_completions, b.total_completions);
        assert_eq!(a.metrics_digest, b.metrics_digest);
    }

    #[test]
    fn sweep_digest_is_invariant_to_both_job_axes() {
        // Grid workers (jobs) and partition workers (sim_jobs) are both
        // pure wall-clock knobs; two clusters make the partition axis
        // actually fan out.
        let base = run_conformance_with(700, 3, 1, ReplanMode::Periodic, 1, 2);
        let d0 = conformance_digest(&base);
        for (jobs, sim_jobs) in [(4, 1), (1, 4), (2, 2)] {
            let alt = run_conformance_with(
                700,
                3,
                jobs,
                ReplanMode::Periodic,
                sim_jobs,
                2,
            );
            assert_eq!(
                conformance_digest(&alt),
                d0,
                "jobs={jobs} sim_jobs={sim_jobs} diverged"
            );
        }
        // The digest is content-sensitive: a different corpus digests
        // differently.
        let other = run_conformance_with(701, 3, 1, ReplanMode::Periodic, 1, 2);
        assert_ne!(conformance_digest(&other), d0);
    }

    #[test]
    fn parallel_sweep_matches_sequential() {
        let seq = run_conformance(400, 4, 1);
        let par = run_conformance(400, 4, 4);
        assert_eq!(seq.len(), par.len());
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.spec.seed, b.spec.seed);
            assert_eq!(a.total_completions, b.total_completions);
            assert_eq!(a.ok(), b.ok());
        }
    }
}
