//! Front-door experiments: what the production serving path's content
//! filter and per-tenant isolation actually buy, measured.
//!
//! Two serving-path comparisons run on a **logical-clock harness** (the
//! real [`FrontDoor`] + [`SyntheticExec`], no threads, no wall-clock — so
//! results are deterministic and CI-stable):
//!
//! 1. **Static-scene filtering** — a surveillance-style load whose frames
//!    barely change, far above engine capacity. With the filter off the
//!    engine saturates; with it on, repeat frames are answered from the
//!    previous result and effective throughput multiplies.
//! 2. **Two-tenant flash crowd** — tenant A floods mid-run while tenant B
//!    streams steadily. With isolation on (token buckets + weighted-fair
//!    dequeue) B keeps its SLO attainment; with it off, A's flood starves
//!    B through the shared queues.
//!
//! A third comparison runs the sim's scene-level frontend (`--scenario
//! static`, frontend on vs off) under the invariant engine, checking the
//! workload fingerprint is identical either way — the filter changes what
//! is *admitted*, never what *happened* in the scene.

use std::collections::{HashMap, VecDeque};

use crate::metrics::RunMetrics;
use crate::serving::shard::Offer;
use crate::serving::{
    settle_offer, FrontDoor, FrontDoorCfg, ModelServeCfg, Request, Response,
    ServeReport, SyntheticExec,
};
use crate::serving::exec::ExecBackend;
use crate::sim::{preset, run_checked, InvariantReport, Scenario};
use crate::coordinator::SchedulerKind;
use crate::util::table::{fnum, Table};
use crate::util::Rng;

/// One tenant's offered load in the harness.
#[derive(Clone, Debug)]
pub struct TenantLoad {
    pub tenant: u32,
    /// Independent source streams (each its own filter state).
    pub streams: u64,
    /// Frames per second per stream.
    pub fps: f64,
    pub model: String,
    pub slo_ms: f64,
    /// Active window [start, stop) in harness ms.
    pub start_ms: f64,
    pub stop_ms: f64,
    /// `true` = every frame of a stream is identical (filterable);
    /// `false` = every frame is fresh content.
    pub static_scene: bool,
}

/// Harness-wide knobs.
#[derive(Clone, Debug)]
pub struct HarnessCfg {
    pub cfgs: HashMap<String, ModelServeCfg>,
    pub front: FrontDoorCfg,
    /// Load-generation horizon, ms (the drain tail runs past it).
    pub duration_ms: f64,
    /// Engine service time per batch, ms (logical).
    pub service_ms: f64,
}

/// Input width every harness model uses.
const PER_IN: usize = 64;
/// Hard cap on the post-horizon drain (a stuck queue fails loudly in the
/// report instead of hanging the harness).
const DRAIN_CAP_MS: f64 = 60_000.0;

/// Drive the real [`FrontDoor`] with a deterministic 1 ms logical clock:
/// admission, filtering, fair assembly, a bounded ring, and a single
/// synthetic executor. Latencies are logical (completion minus submit
/// tick), so SLO attainment measures *queueing*, not host speed.
pub fn run_front_harness(
    hc: &HarnessCfg,
    loads: &[TenantLoad],
    seed: u64,
) -> ServeReport {
    let mut door = FrontDoor::new(&hc.cfgs, &hc.front);
    let mut report = ServeReport::default();
    // Responses from terminal front-door decisions are accounted in the
    // report; the payloads themselves are not needed here.
    let (tx, _keep_rx) = std::sync::mpsc::channel::<Response>();

    let mut ex = SyntheticExec::new();
    for m in hc.cfgs.keys() {
        ex = ex.with_model(m, PER_IN, 2, hc.service_ms);
    }

    // Per-stream frame payloads: static streams reuse one base vector,
    // dynamic streams redraw every frame from their own fork.
    let mut rng = Rng::new(seed);
    let mut stream_rng: HashMap<u64, Rng> = HashMap::new();
    let mut static_base: HashMap<u64, Vec<f32>> = HashMap::new();
    let mut next_emit: Vec<Vec<f64>> = loads
        .iter()
        .map(|l| (0..l.streams).map(|_| l.start_ms).collect())
        .collect();

    let ring_depth = hc.front.ring_depth.max(1);
    let mut ring: VecDeque<(String, Vec<Request>)> = VecDeque::new();
    // Executor occupancy: a started batch completes at `.0`.
    let mut running: Option<(f64, String, Vec<Request>)> = None;
    let mut submit_ms: HashMap<u64, f64> = HashMap::new();
    let mut next_id: u64 = 0;

    let mut t = 0.0;
    let end = hc.duration_ms + DRAIN_CAP_MS;
    loop {
        // 1. Finish the running batch if its completion tick arrived.
        if let Some((done_at, model, batch)) = running.take() {
            if done_at <= t {
                complete_logical(
                    &mut ex, &mut door, &mut report, &hc.cfgs, &model, batch,
                    &mut submit_ms, done_at,
                );
            } else {
                running = Some((done_at, model, batch));
            }
        }
        // 2. Generate this tick's arrivals.
        if t < hc.duration_ms {
            for (li, l) in loads.iter().enumerate() {
                let gap = 1000.0 / l.fps.max(1e-6);
                for s in 0..l.streams {
                    let stream = (li as u64) * 100_000 + s;
                    while next_emit[li][s as usize] <= t
                        && next_emit[li][s as usize] < l.stop_ms
                    {
                        next_emit[li][s as usize] += gap;
                        let data = frame_payload(
                            l.static_scene,
                            stream,
                            &mut rng,
                            &mut stream_rng,
                            &mut static_base,
                        );
                        next_id += 1;
                        let id = next_id;
                        let req = Request {
                            id,
                            model: l.model.clone(),
                            data,
                            slo_ms: l.slo_ms,
                            tenant: l.tenant,
                            stream,
                            submitted: std::time::Instant::now(),
                        };
                        report.note_submitted(l.tenant);
                        let offer = door.offer(req, t);
                        if matches!(offer, Offer::Queued) {
                            submit_ms.insert(id, t);
                        }
                        settle_offer(offer, &tx, &mut report);
                    }
                }
            }
        }
        // 3. Fill the bounded ring (assembly stalls when it is full — the
        //    same backpressure the threaded path gets from `sync_channel`).
        while ring.len() < ring_depth {
            match door.poll(t) {
                Some(b) => ring.push_back(b),
                None => break,
            }
        }
        // 4. Start the executor on the next batch if it is idle.
        if running.is_none() {
            if let Some((model, batch)) = ring.pop_front() {
                running = Some((t + hc.service_ms, model, batch));
            }
        }
        // 5. Advance / terminate.
        let drained = t >= hc.duration_ms
            && running.is_none()
            && ring.is_empty()
            && door.is_empty();
        if drained || t >= end {
            break;
        }
        t += 1.0;
        // Past the horizon, force partial batches out (their max-wait
        // deadlines would fire anyway; this just skips the idle ticks).
        if t >= hc.duration_ms && running.is_none() && ring.is_empty() {
            if let Some(b) = door.poll(t).or_else(|| door.flush()) {
                ring.push_back(b);
            }
        }
    }
    report.wall_ms = hc.duration_ms.max(t.min(end));
    report
}

/// Account one executed batch with logical latency = completion tick −
/// submit tick (mirrors `run_batch` + `complete_batch`, minus wall-clock).
fn complete_logical(
    ex: &mut SyntheticExec,
    door: &mut FrontDoor,
    report: &mut ServeReport,
    cfgs: &HashMap<String, ModelServeCfg>,
    model: &str,
    batch: Vec<Request>,
    submit_ms: &mut HashMap<u64, f64>,
    now: f64,
) {
    // Shed requests whose deadline passed while queued (logical clock).
    let mut live = Vec::with_capacity(batch.len());
    for req in batch {
        let waited = now - submit_ms.remove(&req.id).unwrap_or(now);
        if waited > req.slo_ms {
            report.shed += 1;
            report.lane(req.tenant).shed += 1;
            door.abandon_result(req.id);
        } else {
            live.push((waited, req));
        }
    }
    if live.is_empty() {
        return;
    }
    let bz = cfgs.get(model).map(|c| c.batch).unwrap_or(1);
    let n = live.len();
    let mut input = Vec::with_capacity(n * PER_IN);
    for (_, r) in &live {
        input.extend_from_slice(&r.data);
    }
    match ex.execute_padded(model, bz, n, &input) {
        Ok(out) => {
            let per_out = out.len() / n;
            *report.batch_hist.entry(n).or_default() += 1;
            for (i, (waited, req)) in live.into_iter().enumerate() {
                let on_time = waited <= req.slo_ms;
                report.served += 1;
                if on_time {
                    report.on_time += 1;
                }
                let lane = report.lane(req.tenant);
                lane.served += 1;
                if on_time {
                    lane.on_time += 1;
                }
                *report.per_model.entry(req.model.clone()).or_default() += 1;
                report.latency.push(waited);
                door.record_result(req.id, &out[i * per_out..(i + 1) * per_out], now);
            }
        }
        Err(e) => {
            let msg = e.to_string();
            for (_, req) in live {
                report.failed += 1;
                report.lane(req.tenant).failed += 1;
                door.abandon_result(req.id);
            }
            debug_assert!(false, "synthetic engine failed: {msg}");
        }
    }
}

/// Deterministic per-stream frame content.
fn frame_payload(
    static_scene: bool,
    stream: u64,
    rng: &mut Rng,
    stream_rng: &mut HashMap<u64, Rng>,
    static_base: &mut HashMap<u64, Vec<f32>>,
) -> Vec<f32> {
    if static_scene {
        static_base
            .entry(stream)
            .or_insert_with(|| {
                let mut r = rng.fork(stream);
                (0..PER_IN).map(|_| r.f64() as f32).collect()
            })
            .clone()
    } else {
        let r = stream_rng.entry(stream).or_insert_with(|| rng.fork(stream));
        (0..PER_IN).map(|_| r.f64() as f32).collect()
    }
}

fn det_cfgs(batch: usize) -> HashMap<String, ModelServeCfg> {
    let mut cfgs = HashMap::new();
    let mut c = ModelServeCfg::new(batch, 5.0);
    c.queue_cap = 64;
    cfgs.insert("det".to_string(), c);
    cfgs
}

/// Static-scene filtering comparison: same load, filter off vs on.
/// Offered: 40 streams × 30 fps = 1200 req/s of near-identical frames
/// against ~320 req/s of engine capacity (batch 8 / 25 ms).
pub fn filter_comparison(quick: bool) -> (ServeReport, ServeReport) {
    // 40 streams in quick mode too: the 3x bar needs offered load to be
    // >= ~4x engine capacity, since filter-off still serves ~capacity.
    let duration = if quick { 4_000.0 } else { 10_000.0 };
    let loads = [TenantLoad {
        tenant: 0,
        streams: 40,
        fps: 30.0,
        model: "det".to_string(),
        slo_ms: 400.0,
        start_ms: 0.0,
        stop_ms: duration,
        static_scene: true,
    }];
    let mut cfgs = det_cfgs(8);
    cfgs.get_mut("det").unwrap().max_wait_ms = 15.0;
    let base = HarnessCfg {
        cfgs,
        front: FrontDoorCfg::default(),
        duration_ms: duration,
        service_ms: 25.0,
    };
    let off = run_front_harness(&base, &loads, 7);
    let mut hc = base;
    hc.front.filter = Some(crate::serving::FilterCfg::default());
    let on = run_front_harness(&hc, &loads, 7);
    (off, on)
}

/// Two-tenant flash crowd: A floods mid-run, B streams steadily.
/// Isolation on = per-tenant token buckets (A capped) + fair dequeue;
/// off = open admission + FIFO.
pub fn isolation_comparison(quick: bool) -> (ServeReport, ServeReport) {
    let duration = if quick { 6_000.0 } else { 10_000.0 };
    let loads = [
        TenantLoad {
            tenant: 1, // the flood
            streams: 8,
            fps: 100.0,
            model: "det".to_string(),
            slo_ms: 150.0,
            start_ms: duration * 0.15,
            stop_ms: duration * 0.85,
            static_scene: false,
        },
        TenantLoad {
            tenant: 2, // the steady customer
            streams: 2,
            fps: 25.0,
            model: "det".to_string(),
            slo_ms: 150.0,
            start_ms: 0.0,
            stop_ms: duration,
            static_scene: false,
        },
    ];
    let hc_for = |isolation: bool| {
        let mut front = FrontDoorCfg::default();
        front.tenants.isolation = isolation;
        if isolation {
            front.tenants.rate_per_s = 160.0;
            front.tenants.burst = 32.0;
        }
        HarnessCfg {
            cfgs: det_cfgs(4),
            front,
            duration_ms: duration,
            service_ms: 10.0,
        }
    };
    let no_iso = run_front_harness(&hc_for(false), &loads, 11);
    let iso = run_front_harness(&hc_for(true), &loads, 11);
    (no_iso, iso)
}

/// Sim-side frontend comparison on the `static` preset: frontend on vs
/// off under the invariant engine. The workload fingerprint (frames,
/// objects) must be identical — the frontend changes admission, not the
/// scene.
pub fn sim_frontend_comparison(
    quick: bool,
) -> ((RunMetrics, InvariantReport), (RunMetrics, InvariantReport)) {
    let mut on = preset("static").expect("static preset exists");
    if quick {
        on.duration_ms = 60_000.0;
        on.n_sources = 2;
    }
    let mut off = on.clone();
    off.frontend = false;
    let sc_on = Scenario::build(on);
    let sc_off = Scenario::build(off);
    (
        run_checked(&sc_off, SchedulerKind::OctopInf),
        run_checked(&sc_on, SchedulerKind::OctopInf),
    )
}

/// Everything `octopinf frontdoor` prints, plus the pass verdict the CLI
/// exit code (and the CI smoke) keys off.
pub struct FrontdoorOutcome {
    pub table: Table,
    /// Filter on/off effective-throughput ratio.
    pub filter_gain: f64,
    /// Tenant-B attainment with and without isolation.
    pub iso_b: f64,
    pub no_iso_b: f64,
    pub pass: bool,
    pub failures: Vec<String>,
}

fn conserved(tag: &str, r: &ServeReport, failures: &mut Vec<String>) {
    if r.accounted() != r.submitted {
        failures.push(format!(
            "{tag}: accounted {} != submitted {}",
            r.accounted(),
            r.submitted
        ));
    }
}

/// Run all three comparisons and score them.
pub fn frontdoor_outcome(quick: bool) -> FrontdoorOutcome {
    let (f_off, f_on) = filter_comparison(quick);
    let (no_iso, iso) = isolation_comparison(quick);
    let ((sim_off_m, sim_off_inv), (sim_on_m, sim_on_inv)) =
        sim_frontend_comparison(quick);

    let mut failures = Vec::new();
    conserved("filter-off", &f_off, &mut failures);
    conserved("filter-on", &f_on, &mut failures);
    conserved("no-isolation", &no_iso, &mut failures);
    conserved("isolation", &iso, &mut failures);

    let filter_gain = if f_off.effective_throughput() > 0.0 {
        f_on.effective_throughput() / f_off.effective_throughput()
    } else {
        f64::INFINITY
    };
    if filter_gain < 3.0 {
        failures.push(format!(
            "filter gain {:.2}x below the 3x bar",
            filter_gain
        ));
    }
    if f_on.slo_attainment() + 1e-9 < f_off.slo_attainment() {
        failures.push(format!(
            "filter traded SLO attainment away: {:.3} -> {:.3}",
            f_off.slo_attainment(),
            f_on.slo_attainment()
        ));
    }
    let iso_b = iso.per_tenant.get(&2).map_or(0.0, |l| l.attainment());
    let no_iso_b = no_iso.per_tenant.get(&2).map_or(0.0, |l| l.attainment());
    if iso_b < 0.9 {
        failures.push(format!("isolated tenant-B attainment {iso_b:.3} < 0.9"));
    }
    if no_iso_b > 0.75 {
        failures.push(format!(
            "flood failed to hurt the no-isolation baseline (B at {no_iso_b:.3})"
        ));
    }
    if iso_b < no_iso_b + 0.15 {
        failures.push(format!(
            "isolation margin too thin: {iso_b:.3} vs {no_iso_b:.3}"
        ));
    }
    if !sim_off_inv.ok() || !sim_on_inv.ok() {
        failures.push(format!(
            "sim invariants violated: off={:?} on={:?}",
            sim_off_inv.violations, sim_on_inv.violations
        ));
    }
    if sim_off_inv.workload_fingerprint() != sim_on_inv.workload_fingerprint() {
        failures.push(format!(
            "frontend changed the workload fingerprint: {:?} vs {:?}",
            sim_off_inv.workload_fingerprint(),
            sim_on_inv.workload_fingerprint()
        ));
    }
    if sim_on_m.filtered == 0 {
        failures.push("sim frontend filtered nothing on the static preset".into());
    }

    let mut table = Table::new(vec![
        "experiment",
        "eff_thpt(req/s)",
        "attain",
        "filtered",
        "throttled",
        "rejected",
        "tenantB_attain",
    ]);
    let row = |tag: &str, r: &ServeReport| {
        vec![
            tag.to_string(),
            fnum(r.effective_throughput(), 1),
            fnum(r.slo_attainment(), 3),
            r.filtered.to_string(),
            r.throttled.to_string(),
            r.rejected.to_string(),
            r.per_tenant
                .get(&2)
                .map_or("-".to_string(), |l| fnum(l.attainment(), 3)),
        ]
    };
    table.row(row("filter off", &f_off));
    table.row(row("filter on", &f_on));
    table.row(row("no isolation", &no_iso));
    table.row(row("isolation", &iso));
    table.row(vec![
        "sim frontend off".into(),
        fnum(sim_off_m.effective_throughput(), 1),
        fnum(1.0 - sim_off_m.violation_rate(), 3),
        sim_off_m.filtered.to_string(),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);
    table.row(vec![
        "sim frontend on".into(),
        fnum(sim_on_m.effective_throughput(), 1),
        fnum(1.0 - sim_on_m.violation_rate(), 3),
        sim_on_m.filtered.to_string(),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);

    FrontdoorOutcome {
        table,
        filter_gain,
        iso_b,
        no_iso_b,
        pass: failures.is_empty(),
        failures,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn harness_is_deterministic() {
        let (a_off, a_on) = filter_comparison(true);
        let (b_off, b_on) = filter_comparison(true);
        assert_eq!(a_off.digest(), b_off.digest());
        assert_eq!(a_on.digest(), b_on.digest());
    }

    #[test]
    fn harness_conserves_every_request() {
        let (off, on) = filter_comparison(true);
        assert_eq!(off.accounted(), off.submitted, "{}", off.digest());
        assert_eq!(on.accounted(), on.submitted, "{}", on.digest());
        assert!(on.filtered > 0, "static scenes must filter");
    }

    #[test]
    fn isolation_protects_the_steady_tenant() {
        let (no_iso, iso) = isolation_comparison(true);
        let b_iso = iso.per_tenant.get(&2).unwrap().attainment();
        let b_no = no_iso.per_tenant.get(&2).unwrap().attainment();
        assert!(b_iso > b_no, "iso {b_iso:.3} vs {b_no:.3}");
        assert!(iso.throttled > 0, "the flood must hit the bucket");
        assert_eq!(no_iso.throttled, 0, "open admission never throttles");
    }
}
