//! Evaluation metrics (paper §IV-B): effective throughput (on-time objects
//! per second), end-to-end latency distributions, and total GPU memory
//! allocation — plus the per-minute timelines behind Fig. 6d/7/11.

use crate::obs::attrib::Attribution;
use crate::util::stats::{Histogram, QuantileSketch};
use crate::Ms;

/// Outcome of one query at the sink.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    OnTime,
    Late,
    Dropped,
}

/// Aggregated run metrics for one system under one scenario.
#[derive(Clone, Debug)]
pub struct RunMetrics {
    pub duration_ms: Ms,
    pub on_time: u64,
    pub late: u64,
    pub dropped: u64,
    /// Latency distribution of completed (on-time + late) queries —
    /// a streaming sketch, so recording stays O(1) and allocation-free.
    pub latency: QuantileSketch,
    pub latency_hist: Histogram,
    /// Queries destroyed by an injected fault (in-flight batches on a
    /// crashed device, queues lost under `CrashPolicy::Drop`, frames
    /// captured while their source device was down). Kept separate from
    /// `dropped` — these are system failures, not scheduling decisions —
    /// and reconciled exactly by the invariant engine.
    pub lost_to_fault: u64,
    /// Work units answered by the content-aware frontend (frame-diff
    /// filter / result cache) without touching the pipeline — on time by
    /// construction and never admitted, so kept out of the latency
    /// sketches; reconciled exactly by the invariant engine.
    pub filtered: u64,
    /// Peak total GPU memory allocated, MB.
    pub peak_memory_mb: f64,
    /// Per-minute (workload objects/s, effective objects/s) timeline.
    pub timeline: Vec<(f64, f64)>,
    /// Mean GPU utilization across the run, [0,1] of cluster capacity.
    pub mean_gpu_util: f64,
    /// Exact per-component latency decomposition (transfer / queue wait /
    /// GPU exec) plus the dominant-cause breakdown of SLO misses. The
    /// component terms of every sample fold bit-for-bit to the latency
    /// recorded alongside it (see `obs::attrib`), which the invariant
    /// engine reconciles. Deliberately **excluded from `digest()`**:
    /// digests predating this field must stay byte-identical.
    pub attrib: Attribution,
}

impl RunMetrics {
    pub fn new(duration_ms: Ms) -> RunMetrics {
        RunMetrics {
            duration_ms,
            on_time: 0,
            late: 0,
            dropped: 0,
            lost_to_fault: 0,
            filtered: 0,
            latency: QuantileSketch::new(),
            latency_hist: Histogram::new(0.0, 1000.0, 50),
            peak_memory_mb: 0.0,
            timeline: Vec::new(),
            mean_gpu_util: 0.0,
            attrib: Attribution::default(),
        }
    }

    pub fn record(&mut self, outcome: Outcome, latency_ms: Ms) {
        self.record_n(outcome, latency_ms, 1);
    }

    /// Bulk path: record `n` queries sharing one outcome/latency in O(1)
    /// (lazy-drop sweeps, per-object sink fanout).
    pub fn record_n(&mut self, outcome: Outcome, latency_ms: Ms, n: u64) {
        if n == 0 {
            return;
        }
        match outcome {
            Outcome::OnTime => self.on_time += n,
            Outcome::Late => self.late += n,
            Outcome::Dropped => {
                self.dropped += n;
                return;
            }
        }
        self.latency.push_n(latency_ms, n);
        self.latency_hist.push_n(latency_ms, n);
    }

    /// Record `n` work units the frontend answered from a previous result
    /// (no pipeline admission, no engine work, no latency sample).
    pub fn record_filtered(&mut self, n: u64) {
        self.filtered += n;
    }

    /// Record the exact component decomposition of one completed query
    /// (`n` work units). Callers must pass terms already closed with
    /// [`crate::obs::close_exact`] so `(transfer + queue) + exec` equals
    /// the latency recorded via [`record_n`](Self::record_n) bit-for-bit.
    pub fn record_attrib(
        &mut self,
        transfer_ms: Ms,
        queue_ms: Ms,
        exec_ms: Ms,
        n: u64,
        missed: bool,
    ) {
        if n == 0 {
            return;
        }
        self.attrib.record(transfer_ms, queue_ms, exec_ms, n, missed);
    }

    /// Completed queries (on-time + late) — the conservation-side
    /// complement of `dropped`, cross-checked by the invariant engine.
    pub fn completed(&self) -> u64 {
        self.on_time + self.late
    }

    /// Effective throughput: usefully-answered work units per second —
    /// on-time completions plus frontend answers (which are instant).
    pub fn effective_throughput(&self) -> f64 {
        (self.on_time + self.filtered) as f64 * 1000.0 / self.duration_ms
    }

    /// Total throughput: all answers per second (the gap to effective
    /// is the paper's "wasted computation").
    pub fn total_throughput(&self) -> f64 {
        (self.on_time + self.late + self.filtered) as f64 * 1000.0
            / self.duration_ms
    }

    /// Fraction of completions violating the SLO.
    pub fn violation_rate(&self) -> f64 {
        let done = self.on_time + self.late;
        if done == 0 {
            0.0
        } else {
            self.late as f64 / done as f64
        }
    }

    /// Effective/total ratio (Fig. 8's "throughput ratio").
    pub fn effective_ratio(&self) -> f64 {
        let t = self.total_throughput();
        if t <= 0.0 {
            0.0
        } else {
            self.effective_throughput() / t
        }
    }

    /// Completion rate vs all answered-or-dropped work (frontend answers
    /// count as completions — the client got a result).
    pub fn completion_rate(&self) -> f64 {
        let all = self.on_time + self.late + self.dropped + self.filtered;
        if all == 0 {
            0.0
        } else {
            (self.on_time + self.late + self.filtered) as f64 / all as f64
        }
    }

    /// Fold another cluster's metrics into this fleet view: counters add,
    /// latency sketch/histogram merge exactly (bucket counts add), memory
    /// peaks **sum** (each cluster owns its own GPUs, so the fleet peak is
    /// the sum of per-cluster peaks), and timelines add element-wise with
    /// the shorter one zero-padded. `duration_ms` is the shared horizon
    /// and stays as-is; `mean_gpu_util` is a fleet *mean*, which the sim
    /// driver recomputes after merging — this method leaves it untouched.
    pub fn merge(&mut self, other: &RunMetrics) {
        debug_assert_eq!(
            self.duration_ms.to_bits(),
            other.duration_ms.to_bits(),
            "merging runs with different horizons"
        );
        self.on_time += other.on_time;
        self.late += other.late;
        self.dropped += other.dropped;
        self.lost_to_fault += other.lost_to_fault;
        self.filtered += other.filtered;
        self.latency.merge(&other.latency);
        self.latency_hist.merge(&other.latency_hist);
        self.peak_memory_mb += other.peak_memory_mb;
        if self.timeline.len() < other.timeline.len() {
            self.timeline.resize(other.timeline.len(), (0.0, 0.0));
        }
        for (i, &(w, e)) in other.timeline.iter().enumerate() {
            self.timeline[i].0 += w;
            self.timeline[i].1 += e;
        }
        self.attrib.merge(&other.attrib);
    }

    /// 64-bit fingerprint of every field — counters, the exact bit
    /// patterns of all floats, and the full latency sketch/histogram
    /// contents. Two runs digest equal iff their metrics are
    /// byte-identical; the determinism gates (`--sim-jobs` sweeps,
    /// fuzz/chaos digest diffs in CI) compare these.
    pub fn digest(&self) -> u64 {
        use crate::util::stats::{fnv1a, FNV_OFFSET};
        let mut h = FNV_OFFSET;
        for w in [
            self.duration_ms.to_bits(),
            self.on_time,
            self.late,
            self.dropped,
            self.lost_to_fault,
            self.filtered,
            self.latency.digest(),
            self.latency_hist.digest(),
            self.peak_memory_mb.to_bits(),
            self.mean_gpu_util.to_bits(),
            self.timeline.len() as u64,
        ] {
            h = fnv1a(h, w);
        }
        for &(w, e) in &self.timeline {
            h = fnv1a(h, w.to_bits());
            h = fnv1a(h, e.to_bits());
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_accounting() {
        let mut m = RunMetrics::new(10_000.0); // 10 s
        for _ in 0..50 {
            m.record(Outcome::OnTime, 100.0);
        }
        for _ in 0..10 {
            m.record(Outcome::Late, 400.0);
        }
        for _ in 0..5 {
            m.record(Outcome::Dropped, 0.0);
        }
        assert!((m.effective_throughput() - 5.0).abs() < 1e-9);
        assert!((m.total_throughput() - 6.0).abs() < 1e-9);
        assert!((m.violation_rate() - 10.0 / 60.0).abs() < 1e-9);
        assert!((m.completion_rate() - 60.0 / 65.0).abs() < 1e-9);
        assert_eq!(m.latency.len(), 60);
    }

    #[test]
    fn dropped_has_no_latency_sample() {
        let mut m = RunMetrics::new(1000.0);
        m.record(Outcome::Dropped, 123.0);
        assert!(m.latency.is_empty());
        assert_eq!(m.dropped, 1);
    }

    #[test]
    fn record_n_matches_repeated_record() {
        let mut a = RunMetrics::new(10_000.0);
        let mut b = RunMetrics::new(10_000.0);
        for _ in 0..9 {
            a.record(Outcome::OnTime, 42.0);
        }
        a.record(Outcome::Dropped, 0.0);
        b.record_n(Outcome::OnTime, 42.0, 9);
        b.record_n(Outcome::Dropped, 0.0, 1);
        b.record_n(Outcome::Late, 1.0, 0); // no-op
        assert_eq!(a.on_time, b.on_time);
        assert_eq!(a.dropped, b.dropped);
        assert_eq!(a.late, b.late);
        assert_eq!(a.latency.p50(), b.latency.p50());
        assert_eq!(a.latency_hist.total(), b.latency_hist.total());
    }

    #[test]
    fn filtered_counts_toward_effective_but_not_latency() {
        let mut m = RunMetrics::new(10_000.0);
        m.record_n(Outcome::OnTime, 50.0, 10);
        m.record_filtered(30);
        assert_eq!(m.filtered, 30);
        assert!((m.effective_throughput() - 4.0).abs() < 1e-9, "10+30 in 10 s");
        assert!((m.total_throughput() - 4.0).abs() < 1e-9);
        assert_eq!(m.latency.len(), 10, "filtered units have no latency");
        assert_eq!(m.completed(), 10, "filtered is not an engine completion");
        assert_eq!(m.completion_rate(), 1.0);
    }

    #[test]
    fn merge_sums_counters_and_pads_timelines() {
        let mut a = RunMetrics::new(10_000.0);
        a.record_n(Outcome::OnTime, 50.0, 5);
        a.record_n(Outcome::Dropped, 0.0, 2);
        a.peak_memory_mb = 100.0;
        a.timeline = vec![(10.0, 8.0), (12.0, 9.0)];
        let mut b = RunMetrics::new(10_000.0);
        b.record_n(Outcome::Late, 400.0, 3);
        b.lost_to_fault = 4;
        b.record_filtered(6);
        b.peak_memory_mb = 40.0;
        b.timeline = vec![(1.0, 1.0), (1.0, 1.0), (1.0, 1.0)];
        a.merge(&b);
        assert_eq!(a.on_time, 5);
        assert_eq!(a.late, 3);
        assert_eq!(a.dropped, 2);
        assert_eq!(a.lost_to_fault, 4);
        assert_eq!(a.filtered, 6);
        assert_eq!(a.peak_memory_mb, 140.0, "fleet memory is a sum of peaks");
        assert_eq!(a.timeline, vec![(11.0, 9.0), (13.0, 10.0), (1.0, 1.0)]);
        assert_eq!(a.latency.count(), 8);
        assert_eq!(a.latency_hist.total(), 8);
    }

    #[test]
    fn digest_detects_any_field_change() {
        let mk = || {
            let mut m = RunMetrics::new(10_000.0);
            m.record_n(Outcome::OnTime, 50.0, 5);
            m.timeline = vec![(10.0, 8.0)];
            m.mean_gpu_util = 0.5;
            m
        };
        assert_eq!(mk().digest(), mk().digest(), "digest is deterministic");
        let mut m = mk();
        m.mean_gpu_util = 0.5000001;
        assert_ne!(m.digest(), mk().digest());
        let mut m = mk();
        m.timeline[0].1 += 1.0;
        assert_ne!(m.digest(), mk().digest());
        let mut m = mk();
        m.record(Outcome::Dropped, 0.0);
        assert_ne!(m.digest(), mk().digest());
    }

    #[test]
    fn attribution_rides_along_without_touching_the_digest() {
        let mk = || {
            let mut m = RunMetrics::new(10_000.0);
            m.record_n(Outcome::OnTime, 80.0, 3);
            m.record_n(Outcome::Late, 900.0, 2);
            m
        };
        let base = mk().digest();
        let mut m = mk();
        m.record_attrib(10.0, 20.0, 50.0, 3, false);
        m.record_attrib(100.0, 700.0, 100.0, 2, true);
        assert_eq!(
            m.digest(),
            base,
            "attribution must never perturb pre-existing digests"
        );
        assert_eq!(m.attrib.transfer.count(), 5);
        assert_eq!(m.attrib.misses(), 2);
        assert_eq!(m.attrib.miss_queue, 2, "queue was the dominant term");
        // Merge folds the attribution too.
        let mut a = mk();
        a.record_attrib(1.0, 1.0, 78.0, 1, false);
        a.merge(&m);
        assert_eq!(a.attrib.exec.count(), 6);
        assert_eq!(a.attrib.misses(), 2);
        assert_eq!(a.digest(), base, "merged digest still attribution-blind");
    }

    #[test]
    fn seconds_scale_latency_is_visible_in_the_histogram() {
        // Regression for the 1 s-range latency histogram: a 5 s latency
        // must surface through the overflow counter, not vanish.
        let mut m = RunMetrics::new(10_000.0);
        m.record(Outcome::Late, 5000.0);
        assert_eq!(m.latency_hist.overflow(), 1);
        assert_eq!(m.latency_hist.total(), 1);
        assert!(m.latency_hist.sparkline().contains("(+1 > 1000)"));
    }

    #[test]
    fn ratio_bounds() {
        let mut m = RunMetrics::new(1000.0);
        assert_eq!(m.effective_ratio(), 0.0);
        m.record(Outcome::OnTime, 50.0);
        assert_eq!(m.effective_ratio(), 1.0);
    }
}
