//! The *inference stream* abstraction (paper §III-C1, Fig. 5).
//!
//! A GPU's capacity is divided into concurrently executing **streams**; each
//! stream is a temporal sequence of **portions**. A portion's length is the
//! batch execution time of the instance occupying it; its width is the
//! compute fraction the instance needs. Each stream carries a **duty
//! cycle** (= SLO/2 of the pipeline that first claimed it): after the last
//! portion, GPU access cycles back to the first.

use super::types::GpuId;
use crate::Ms;

/// A scheduled execution portion within a stream.
#[derive(Clone, Copy, Debug)]
pub struct Portion {
    pub start_ms: Ms,
    pub end_ms: Ms,
    pub width: f64,
    /// Intermediate memory the occupying instance needs (MB) — kept per
    /// portion so stream peaks can be recomputed when portions are
    /// released (drift repair).
    pub inter_mb: f64,
    /// (pipeline, model, instance) owning the portion.
    pub owner: (usize, usize, u32),
}

impl Portion {
    pub fn duration(&self) -> Ms {
        self.end_ms - self.start_ms
    }

    pub fn overlaps(&self, other: &Portion) -> bool {
        self.start_ms < other.end_ms - 1e-9 && other.start_ms < self.end_ms - 1e-9
    }
}

/// A free interval available for placement within a stream.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FreePortion {
    pub gpu: GpuId,
    pub stream: usize,
    pub start_ms: Ms,
    pub end_ms: Ms,
}

impl FreePortion {
    pub fn len(&self) -> Ms {
        self.end_ms - self.start_ms
    }

    /// Can a portion of `dur` starting no earlier than `earliest` fit?
    /// Returns the feasible start time (Algorithm 2 line 16 check).
    pub fn fit(&self, earliest: Ms, dur: Ms) -> Option<Ms> {
        let start = self.start_ms.max(earliest);
        (start + dur <= self.end_ms + 1e-9).then_some(start)
    }
}

/// One inference stream.
#[derive(Clone, Debug)]
pub struct Stream {
    pub gpu: GpuId,
    pub index: usize,
    /// 0 until the first instance claims the stream (line 19-20).
    pub duty_cycle_ms: Ms,
    /// Invariant: sorted by `start_ms`, equal starts in insertion order
    /// ([`Stream::insert`] maintains this; `release_pipeline` preserves
    /// it). Placement walks this directly as the free-gap cursor, so
    /// mutate portions only through the methods here.
    pub portions: Vec<Portion>,
    /// Peak concurrent width of the stream (for the GPU util sum, Eq. 5).
    pub max_width: f64,
    /// Peak intermediate memory of any portion (temporal sharing, Eq. 4).
    pub max_inter_mb: f64,
}

impl Stream {
    pub fn new(gpu: GpuId, index: usize) -> Stream {
        Stream {
            gpu,
            index,
            duty_cycle_ms: 0.0,
            portions: Vec::new(),
            max_width: 0.0,
            max_inter_mb: 0.0,
        }
    }

    /// Free intervals within the horizon (duty cycle if set, else `horizon`).
    /// Portions are kept sorted by start, so this is a single cursor walk
    /// (CORAL's hot path inlines the same walk without materializing the
    /// list — see `coordinator::coral::place_instance`).
    pub fn free_portions(&self, horizon: Ms) -> Vec<FreePortion> {
        let end = if self.duty_cycle_ms > 0.0 { self.duty_cycle_ms } else { horizon };
        let mut free = Vec::new();
        let mut cursor = 0.0;
        for p in &self.portions {
            if p.start_ms > cursor + 1e-9 {
                free.push(FreePortion {
                    gpu: self.gpu,
                    stream: self.index,
                    start_ms: cursor,
                    end_ms: p.start_ms,
                });
            }
            cursor = cursor.max(p.end_ms);
        }
        if cursor + 1e-9 < end {
            free.push(FreePortion {
                gpu: self.gpu,
                stream: self.index,
                start_ms: cursor,
                end_ms: end,
            });
        }
        free
    }

    /// Insert a portion at its sorted position; panics if it overlaps an
    /// existing one (scheduler bug — CORAL must only place into free
    /// portions). Equal starts land *after* their peers, so the sequence
    /// matches what a stable sort of insertion order would produce.
    /// Checking only the two neighbors suffices: existing portions are
    /// pairwise disjoint with positive durations, so any overlap with a
    /// farther portion implies one with the adjacent portion first.
    pub fn insert(&mut self, p: Portion) {
        let i = self.portions.partition_point(|q| q.start_ms <= p.start_ms);
        for q in self.portions[..i].last().into_iter().chain(self.portions.get(i)) {
            assert!(
                !p.overlaps(q),
                "portion overlap on {:?}/{}: {:?} vs {:?}",
                self.gpu,
                self.index,
                p,
                q
            );
        }
        self.max_width = self.max_width.max(p.width);
        self.max_inter_mb = self.max_inter_mb.max(p.inter_mb);
        self.portions.insert(i, p);
    }

    /// Reset to the just-constructed empty state, keeping the portion
    /// buffer's capacity (workspace recycling across planning rounds).
    pub fn reset(&mut self, gpu: GpuId, index: usize) {
        self.gpu = gpu;
        self.index = index;
        self.duty_cycle_ms = 0.0;
        self.portions.clear();
        self.max_width = 0.0;
        self.max_inter_mb = 0.0;
    }

    /// Release every portion owned by `pipeline` back into free stream
    /// time (drift repair: the drifted pipeline's reservations are
    /// reclaimed before its new configuration is re-placed). Peaks are
    /// recomputed exactly from the survivors, and an emptied stream
    /// forgets its duty cycle so a different SLO class may claim it.
    /// Returns the number of portions released.
    pub fn release_pipeline(&mut self, pipeline: usize) -> usize {
        let before = self.portions.len();
        self.portions.retain(|p| p.owner.0 != pipeline);
        let released = before - self.portions.len();
        if released > 0 {
            self.max_width =
                self.portions.iter().map(|p| p.width).fold(0.0, f64::max);
            self.max_inter_mb =
                self.portions.iter().map(|p| p.inter_mb).fold(0.0, f64::max);
            if self.portions.is_empty() {
                self.duty_cycle_ms = 0.0;
            }
        }
        released
    }

    /// Total occupied time within the duty cycle.
    pub fn occupancy_ms(&self) -> Ms {
        self.portions.iter().map(|p| p.duration()).sum()
    }

    /// Occupancy fraction of the duty cycle (1.0 = full).
    pub fn occupancy(&self) -> f64 {
        if self.duty_cycle_ms <= 0.0 {
            return 0.0;
        }
        self.occupancy_ms() / self.duty_cycle_ms
    }
}

/// All streams of one GPU plus its spatial budgets (Eq. 4/5 state).
#[derive(Clone, Debug)]
pub struct GpuStreams {
    pub gpu: GpuId,
    pub mem_mb: f64,
    pub util_cap: f64,
    pub streams: Vec<Stream>,
    /// Total persistent weight memory of placed instances (W_g).
    pub weight_mb: f64,
}

impl GpuStreams {
    pub fn new(gpu: GpuId, mem_mb: f64, util_cap: f64, n_streams: usize) -> GpuStreams {
        GpuStreams {
            gpu,
            mem_mb,
            util_cap,
            streams: (0..n_streams).map(|i| Stream::new(gpu, i)).collect(),
            weight_mb: 0.0,
        }
    }

    /// Reset to freshly-built empty streams, recycling every stream's
    /// portion buffer. The per-call `inter_mb`/`util` folds stay as folds
    /// on purpose: caching running sums would re-associate the float
    /// additions and break bit-identity with the naive planner.
    pub fn reset(&mut self, gpu: GpuId, mem_mb: f64, util_cap: f64, n_streams: usize) {
        self.gpu = gpu;
        self.mem_mb = mem_mb;
        self.util_cap = util_cap;
        self.weight_mb = 0.0;
        self.streams.truncate(n_streams);
        for (i, s) in self.streams.iter_mut().enumerate() {
            s.reset(gpu, i);
        }
        while self.streams.len() < n_streams {
            let i = self.streams.len();
            self.streams.push(Stream::new(gpu, i));
        }
    }

    /// Current intermediate memory (Σ per-stream max — temporal sharing).
    pub fn inter_mb(&self) -> f64 {
        self.streams.iter().map(|s| s.max_inter_mb).sum()
    }

    /// Current aggregate utilization (Σ per-stream peak width, Eq. 5 as the
    /// paper's line 15 evaluates it).
    pub fn util(&self) -> f64 {
        self.streams.iter().map(|s| s.max_width).sum()
    }

    /// Would adding (weight, inter, width) on stream `s` stay within caps?
    pub fn admits(&self, s: usize, weight_mb: f64, inter_mb: f64, width: f64) -> bool {
        let st = &self.streams[s];
        let new_inter = self.inter_mb() - st.max_inter_mb + st.max_inter_mb.max(inter_mb);
        let new_util = self.util() - st.max_width + st.max_width.max(width);
        self.weight_mb + weight_mb + new_inter <= self.mem_mb + 1e-9
            && new_util <= self.util_cap + 1e-9
    }

    /// Release every reservation `pipeline` holds on this GPU: its
    /// portions leave their streams (freeing that stream time and the
    /// shared intermediate peaks) and `weight_of(model)` MB of weight
    /// memory is returned per released portion. Returns the portion count.
    pub fn release_pipeline(
        &mut self,
        pipeline: usize,
        weight_of: &dyn Fn(usize) -> f64,
    ) -> usize {
        let mut released = 0;
        for s in self.streams.iter_mut() {
            let owners: Vec<usize> = s
                .portions
                .iter()
                .filter(|p| p.owner.0 == pipeline)
                .map(|p| p.owner.1)
                .collect();
            released += s.release_pipeline(pipeline);
            for model in owners {
                self.weight_mb = (self.weight_mb - weight_of(model)).max(0.0);
            }
        }
        released
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gpu() -> GpuId {
        GpuId { device: 0, gpu: 0 }
    }

    fn portion(s: f64, e: f64) -> Portion {
        Portion { start_ms: s, end_ms: e, width: 0.3, inter_mb: 0.0, owner: (0, 0, 0) }
    }

    fn owned(s: f64, e: f64, pipeline: usize, width: f64, inter: f64) -> Portion {
        Portion {
            start_ms: s,
            end_ms: e,
            width,
            inter_mb: inter,
            owner: (pipeline, 0, 0),
        }
    }

    #[test]
    fn free_portions_of_empty_stream() {
        let mut s = Stream::new(gpu(), 0);
        s.duty_cycle_ms = 100.0;
        let free = s.free_portions(1000.0);
        assert_eq!(free.len(), 1);
        assert_eq!(free[0].start_ms, 0.0);
        assert_eq!(free[0].end_ms, 100.0);
    }

    #[test]
    fn free_portions_between_occupied() {
        let mut s = Stream::new(gpu(), 0);
        s.duty_cycle_ms = 100.0;
        s.insert(Portion { inter_mb: 5.0, ..portion(10.0, 30.0) });
        s.insert(Portion { inter_mb: 8.0, ..portion(50.0, 60.0) });
        let free = s.free_portions(1000.0);
        assert_eq!(free.len(), 3);
        assert_eq!((free[0].start_ms, free[0].end_ms), (0.0, 10.0));
        assert_eq!((free[1].start_ms, free[1].end_ms), (30.0, 50.0));
        assert_eq!((free[2].start_ms, free[2].end_ms), (60.0, 100.0));
        assert_eq!(s.max_inter_mb, 8.0);
    }

    #[test]
    #[should_panic(expected = "overlap")]
    fn overlapping_insert_panics() {
        let mut s = Stream::new(gpu(), 0);
        s.duty_cycle_ms = 100.0;
        s.insert(portion(10.0, 30.0));
        s.insert(portion(20.0, 40.0));
    }

    #[test]
    fn fit_respects_earliest() {
        let f = FreePortion { gpu: gpu(), stream: 0, start_ms: 10.0, end_ms: 50.0 };
        assert_eq!(f.fit(0.0, 20.0), Some(10.0));
        assert_eq!(f.fit(25.0, 20.0), Some(25.0));
        assert_eq!(f.fit(35.0, 20.0), None);
        assert_eq!(f.fit(0.0, 45.0), None);
    }

    #[test]
    fn admits_memory_and_util() {
        let mut g = GpuStreams::new(gpu(), 100.0, 1.0, 2);
        assert!(g.admits(0, 50.0, 20.0, 0.5));
        g.weight_mb = 50.0;
        g.streams[0].max_inter_mb = 20.0;
        g.streams[0].max_width = 0.5;
        // Same stream, smaller new portion: shares the stream peak.
        assert!(g.admits(0, 20.0, 10.0, 0.3));
        // Other stream: adds to both sums.
        assert!(g.admits(1, 20.0, 10.0, 0.3));
        assert!(!g.admits(1, 40.0, 0.0, 0.3)); // 50+40+20 > 100
        assert!(!g.admits(1, 0.0, 0.0, 0.6)); // 0.5+0.6 > 1.0
    }

    #[test]
    fn occupancy_tracks_portions() {
        let mut s = Stream::new(gpu(), 0);
        s.duty_cycle_ms = 100.0;
        s.insert(portion(0.0, 25.0));
        assert!((s.occupancy() - 0.25).abs() < 1e-9);
    }

    #[test]
    fn release_frees_stream_time_and_recomputes_peaks() {
        let mut s = Stream::new(gpu(), 0);
        s.duty_cycle_ms = 100.0;
        s.insert(owned(0.0, 20.0, 0, 0.5, 10.0));
        s.insert(owned(30.0, 50.0, 1, 0.3, 4.0));
        s.insert(owned(60.0, 80.0, 0, 0.4, 7.0));
        assert_eq!(s.release_pipeline(0), 2);
        // Survivor (pipeline 1) now defines both peaks.
        assert_eq!(s.portions.len(), 1);
        assert!((s.max_width - 0.3).abs() < 1e-9);
        assert!((s.max_inter_mb - 4.0).abs() < 1e-9);
        // The freed intervals are placeable again.
        let free = s.free_portions(1000.0);
        assert_eq!((free[0].start_ms, free[0].end_ms), (0.0, 30.0));
        assert_eq!((free[1].start_ms, free[1].end_ms), (50.0, 100.0));
    }

    #[test]
    fn emptied_stream_forgets_its_duty_cycle() {
        let mut s = Stream::new(gpu(), 0);
        s.duty_cycle_ms = 150.0;
        s.insert(owned(0.0, 10.0, 2, 0.2, 1.0));
        assert_eq!(s.release_pipeline(2), 1);
        assert_eq!(s.duty_cycle_ms, 0.0);
        assert_eq!(s.max_width, 0.0);
        assert_eq!(s.max_inter_mb, 0.0);
    }

    #[test]
    fn insert_keeps_portions_sorted() {
        let mut s = Stream::new(gpu(), 0);
        s.duty_cycle_ms = 100.0;
        s.insert(portion(50.0, 60.0));
        s.insert(portion(10.0, 30.0));
        s.insert(portion(70.0, 80.0));
        s.insert(portion(35.0, 45.0));
        let starts: Vec<f64> = s.portions.iter().map(|p| p.start_ms).collect();
        assert_eq!(starts, vec![10.0, 35.0, 50.0, 70.0]);
        // Out-of-order inserts still yield in-order free gaps.
        let free = s.free_portions(1000.0);
        assert_eq!((free[0].start_ms, free[0].end_ms), (0.0, 10.0));
        assert_eq!((free[1].start_ms, free[1].end_ms), (30.0, 35.0));
        assert_eq!(free.last().map(|f| f.end_ms), Some(100.0));
    }

    #[test]
    #[should_panic(expected = "overlap")]
    fn sorted_insert_still_catches_overlap_with_predecessor() {
        let mut s = Stream::new(gpu(), 0);
        s.duty_cycle_ms = 100.0;
        s.insert(portion(10.0, 40.0));
        s.insert(portion(20.0, 30.0)); // contained in predecessor
    }

    #[test]
    fn reset_recycles_to_empty_state() {
        let mut g = GpuStreams::new(gpu(), 100.0, 1.0, 3);
        g.weight_mb = 30.0;
        g.streams[1].duty_cycle_ms = 100.0;
        g.streams[1].insert(owned(0.0, 10.0, 0, 0.4, 5.0));
        let other = GpuId { device: 2, gpu: 0 };
        g.reset(other, 64.0, 0.9, 2);
        assert_eq!(g.gpu, other);
        assert_eq!(g.streams.len(), 2);
        assert_eq!(g.weight_mb, 0.0);
        for (i, s) in g.streams.iter().enumerate() {
            assert_eq!(s.gpu, other);
            assert_eq!(s.index, i);
            assert!(s.portions.is_empty());
            assert_eq!(s.duty_cycle_ms, 0.0);
            assert_eq!(s.max_width, 0.0);
            assert_eq!(s.max_inter_mb, 0.0);
        }
        // Growing back re-adds streams with correct indices.
        g.reset(gpu(), 100.0, 1.0, 4);
        assert_eq!(g.streams.len(), 4);
        assert_eq!(g.streams[3].index, 3);
    }

    #[test]
    fn gpu_release_returns_weight_memory() {
        let mut g = GpuStreams::new(gpu(), 100.0, 1.0, 2);
        g.streams[0].duty_cycle_ms = 100.0;
        g.streams[1].duty_cycle_ms = 100.0;
        g.weight_mb = 30.0;
        g.streams[0].insert(owned(0.0, 10.0, 0, 0.2, 5.0));
        g.streams[1].insert(owned(0.0, 10.0, 1, 0.2, 5.0));
        let released = g.release_pipeline(0, &|_model| 10.0);
        assert_eq!(released, 1);
        assert!((g.weight_mb - 20.0).abs() < 1e-9);
        assert!((g.inter_mb() - 5.0).abs() < 1e-9);
        // Releasing a pipeline with no reservations is a no-op.
        assert_eq!(g.release_pipeline(7, &|_| 10.0), 0);
        assert!((g.weight_mb - 20.0).abs() < 1e-9);
    }
}
