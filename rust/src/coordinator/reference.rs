//! Retained naive reference planner.
//!
//! Verbatim copies of the CWD/CORAL implementations as they stood before
//! the incremental `PlannerWorkspace` rework: per-candidate feasibility
//! checks rebuild the full scheduled-config vec and rescan every placed
//! pipeline; CORAL's placement linearly scans all GPUs and recomputes
//! free-portion lists per instance. Deliberately kept O(P²·B·S) — the
//! point of this module is to be the *oracle*: the plan-identity proptest
//! (`rust/tests/planner.rs`) and `benches/planner.rs` run both planners
//! over the same environments and require bit-identical plans. Any change
//! to the optimized planner's arithmetic, iteration order, or tie-breaks
//! shows up as a bit diff against this module.
//!
//! Pure per-pipeline helpers with no aggregate state (`instances_needed`,
//! `explore_batches`, the estimator) are shared with the live planner —
//! they were not restructured, and sharing them keeps the oracle honest
//! about what actually changed.

use std::collections::HashMap;

use super::coral::build_gpu_state;
use super::cwd::{explore_batches, instances_needed, input_overhead, output_overhead};
use super::cwd::{CwdParams, CwdResult};
use super::estimator::{est_gpu_cost, est_latency, est_throughput, stage_memory_mb};
use super::stream::{GpuStreams, Portion};
use super::types::{
    Assignment, GpuBinding, GpuId, Plan, SchedEnv, StageCfg, TemporalSlot,
};
use crate::profiles::BATCH_SIZES;
use crate::Ms;

/// Remaining GPU memory on a device given config already assigned there.
pub(crate) fn device_mem_headroom(
    env: &SchedEnv,
    device: usize,
    cfg_all: &[(usize, Vec<StageCfg>)],
) -> f64 {
    let total: f64 = env.cluster.device(device).gpus.iter().map(|g| g.mem_mb).sum();
    let mut used = 0.0;
    for (p, cfg) in cfg_all {
        for (m, c) in cfg.iter().enumerate() {
            if c.device == device {
                used += stage_memory_mb(env, *p, m, *c);
            }
        }
    }
    total - used
}

/// Total stream-time demand (ms per duty cycle) already committed on a
/// device across all scheduled pipelines plus the one being built.
pub(crate) fn device_stream_time(
    env: &SchedEnv,
    device: usize,
    cfg_all: &[(usize, Vec<StageCfg>)],
) -> f64 {
    let class = env.cluster.device(device).class;
    let mut total = 0.0;
    for (p, cfg) in cfg_all {
        let dag = &env.pipelines[*p];
        for (m, c) in cfg.iter().enumerate() {
            if c.device == device {
                let lat = env.profiles.batch_latency(&dag.models[m].spec, class, c.batch);
                total += lat * c.instances as f64;
            }
        }
    }
    total
}

/// Stream-time budget of a device per duty cycle.
pub(crate) fn device_stream_budget(env: &SchedEnv, device: usize, duty_ms: f64) -> f64 {
    let d = env.cluster.device(device);
    let streams: usize = d.gpus.iter().map(|g| g.streams).sum();
    streams as f64 * duty_ms * 0.9
}

/// Naive full CWD (reference twin of [`super::cwd::cwd`]).
pub fn cwd_reference(env: &SchedEnv, params: &CwdParams) -> Vec<CwdResult> {
    let targets: Vec<usize> = (0..env.pipelines.len()).collect();
    cwd_subset_reference(env, params, &targets, &[])
        .into_iter()
        .map(|(_, cfg)| CwdResult { cfg })
        .collect()
}

/// Naive incremental CWD (reference twin of [`super::cwd::cwd_subset`]).
pub fn cwd_subset_reference(
    env: &SchedEnv,
    params: &CwdParams,
    targets: &[usize],
    kept: &[(usize, Vec<StageCfg>)],
) -> Vec<(usize, Vec<StageCfg>)> {
    let mut scheduled: Vec<(usize, Vec<StageCfg>)> = kept.to_vec();
    let n_kept = scheduled.len();

    for &p in targets {
        let dag = &env.pipelines[p];
        let slo_budget = dag.slo_ms * params.slo_fraction;

        let mut cfg: Vec<StageCfg> = (0..dag.len())
            .map(|m| StageCfg {
                device: 0,
                batch: 1,
                instances: instances_needed(env, p, m, 0, 1),
            })
            .collect();

        let mut order: Vec<usize> = (0..dag.len()).collect();
        order.sort_by(|&a, &b| {
            env.burstiness(p, b)
                .partial_cmp(&env.burstiness(p, a))
                .unwrap()
        });

        if let Some((_, server_bz, det_bz)) = params.static_batch {
            for (m, c) in cfg.iter_mut().enumerate() {
                c.batch = if m == 0 { det_bz } else { server_bz };
                c.instances = instances_needed(env, p, m, 0, c.batch);
            }
        } else {
            explore_batches(env, params, p, &order, slo_budget, &mut cfg);
        }

        if !params.server_only {
            let mut ctx = ToEdgeCtx { env, params, pipeline: p, scheduled: &scheduled };
            to_edge(&mut ctx, 0, &mut cfg);
            if params.static_batch.is_none() {
                explore_batches(env, params, p, &order, slo_budget, &mut cfg);
            }
        }

        scheduled.push((p, cfg));
    }

    scheduled.split_off(n_kept)
}

struct ToEdgeCtx<'a, 'b> {
    env: &'a SchedEnv<'b>,
    params: &'a CwdParams,
    pipeline: usize,
    scheduled: &'a [(usize, Vec<StageCfg>)],
}

fn to_edge(ctx: &mut ToEdgeCtx, m: usize, cfg: &mut Vec<StageCfg>) {
    let env = ctx.env;
    let p = ctx.pipeline;
    let dag = &env.pipelines[p];
    let edge_dev = dag.source_device;
    if edge_dev == 0 {
        return;
    }
    let slo_budget = dag.slo_ms * ctx.params.slo_fraction;

    let old = cfg[m];
    let batches: Vec<u32> = match ctx.params.static_batch {
        Some((edge_bz, _, det_bz)) => {
            vec![if m == 0 { det_bz } else { edge_bz }]
        }
        None => BATCH_SIZES.to_vec(),
    };
    let mut best: Option<(StageCfg, f64, f64)> = None;
    for &bz in &batches {
        let cand = StageCfg {
            device: edge_dev,
            batch: bz,
            instances: instances_needed(env, p, m, edge_dev, bz),
        };
        let mem = stage_memory_mb(env, p, m, cand);
        let mut all = ctx.scheduled.to_vec();
        all.push((p, cfg.clone()));
        if mem > device_mem_headroom(env, edge_dev, &all) {
            continue;
        }
        let duty = dag.slo_ms * ctx.params.slo_fraction;
        let class = env.cluster.device(edge_dev).class;
        let cand_time = env
            .profiles
            .batch_latency(&dag.models[m].spec, class, cand.batch)
            * cand.instances as f64;
        if device_stream_time(env, edge_dev, &all) + cand_time
            > device_stream_budget(env, edge_dev, duty)
        {
            continue;
        }
        cfg[m] = cand;
        if est_latency(env, p, cfg) <= slo_budget {
            let thrpt = est_throughput(env, p, cfg);
            let cost = est_gpu_cost(env, p, cfg);
            let better = match &best {
                None => true,
                Some((_, bt, bc)) => {
                    thrpt > bt + 1e-9 || (thrpt >= bt - 1e-9 && cost < bc - 1e-9)
                }
            };
            if better {
                best = Some((cand, thrpt, cost));
            }
        }
        cfg[m] = old;
    }
    let Some((cand, _, _)) = best else {
        return;
    };
    cfg[m] = cand;

    let mut downs = dag.models[m].downstream.clone();
    downs.sort_by(|&a, &b| {
        env.burstiness(p, a).partial_cmp(&env.burstiness(p, b)).unwrap()
    });
    for d in downs {
        to_edge(ctx, d, cfg);
    }

    let in_oh = input_overhead(env, p, m);
    let out_oh = output_overhead(env, p, m);
    let downstreams_on_edge = dag.models[m]
        .downstream
        .iter()
        .any(|&d| cfg[d].device == edge_dev);
    if in_oh * ctx.env.alpha < out_oh && !downstreams_on_edge {
        cfg[m] = old;
    }
}

/// Naive CORAL (reference twin of [`super::coral::coral`]).
pub fn coral_reference(env: &SchedEnv, cfgs: &[Vec<StageCfg>]) -> Plan {
    let mut gpus = build_gpu_state(env);
    let work: Vec<(usize, &[StageCfg])> =
        cfgs.iter().enumerate().map(|(p, c)| (p, c.as_slice())).collect();
    let (assignments, unplaced) = place_pipelines(env, &mut gpus, &work);
    Plan { assignments, unplaced }
}

fn place_pipelines(
    env: &SchedEnv,
    gpus: &mut [GpuStreams],
    work: &[(usize, &[StageCfg])],
) -> (Vec<Assignment>, usize) {
    let mut stage_end: HashMap<(usize, usize), Ms> = HashMap::new();

    let mut assignments: Vec<Assignment> = work
        .iter()
        .flat_map(|&(p, cfg)| {
            cfg.iter().enumerate().map(move |(m, &c)| Assignment {
                pipeline: p,
                model: m,
                cfg: c,
                bindings: Vec::new(),
            })
        })
        .collect();
    let mut unplaced = 0usize;

    let max_instances = work
        .iter()
        .flat_map(|(_, c)| c.iter())
        .map(|c| c.instances)
        .max()
        .unwrap_or(0);
    for instance in 0..max_instances {
        for &(p, cfg) in work {
            let dag = &env.pipelines[p];
            let duty = dag.slo_ms / 2.0;
            for m in dag.topo_order() {
                let c = cfg[m];
                if instance >= c.instances {
                    continue;
                }
                let spec = &dag.models[m].spec;
                let class = env.cluster.device(c.device).class;
                let dur = env.profiles.batch_latency(spec, class, c.batch);
                let earliest = dag
                    .upstream(m)
                    .and_then(|u| stage_end.get(&(p, u)).copied())
                    .unwrap_or(0.0);
                let weight = spec.weight_mem_mb;
                let inter = spec.inter_mem_mb * c.batch as f64;
                let width = spec.util_width;

                let slot = place_instance(
                    gpus, c.device, earliest, dur, duty, weight, inter, width,
                    (p, m, instance),
                );
                let a = assignments
                    .iter_mut()
                    .find(|a| a.pipeline == p && a.model == m)
                    .unwrap();
                match slot {
                    Some((gpu, t)) => {
                        stage_end
                            .entry((p, m))
                            .and_modify(|e| *e = e.max(t.start_ms + dur))
                            .or_insert(t.start_ms + dur);
                        a.bindings.push(GpuBinding {
                            gpu,
                            width,
                            temporal: Some(t),
                        });
                    }
                    None => {
                        unplaced += 1;
                        let gpu = least_loaded_gpu(gpus, c.device);
                        if let Some(g) =
                            gpus.iter_mut().find(|g| g.gpu == gpu)
                        {
                            g.weight_mb += weight;
                        }
                        a.bindings.push(GpuBinding {
                            gpu,
                            width,
                            temporal: None,
                        });
                    }
                }
            }
        }
    }

    (assignments, unplaced)
}

/// Naive CORAL repair (reference twin of [`super::coral::coral_repair`]).
pub fn coral_repair_reference(
    env: &SchedEnv,
    old: &Plan,
    new_cfgs: &[(usize, Vec<StageCfg>)],
) -> Plan {
    let mut gpus = build_gpu_state(env);
    let drifted: Vec<usize> = new_cfgs.iter().map(|&(p, _)| p).collect();
    let is_drifted = |p: usize| drifted.contains(&p);

    for a in &old.assignments {
        let spec = &env.pipelines[a.pipeline].models[a.model].spec;
        for (i, b) in a.bindings.iter().enumerate() {
            let Some(g) = gpus.iter_mut().find(|g| g.gpu == b.gpu) else {
                continue;
            };
            g.weight_mb += spec.weight_mem_mb;
            let Some(t) = b.temporal else { continue };
            if t.stream >= g.streams.len() {
                continue;
            }
            if g.streams[t.stream].duty_cycle_ms <= 0.0 {
                g.streams[t.stream].duty_cycle_ms = t.duty_cycle_ms;
            }
            g.streams[t.stream].insert(Portion {
                start_ms: t.start_ms,
                end_ms: t.start_ms + t.duration_ms,
                width: b.width,
                inter_mb: spec.inter_mem_mb * a.cfg.batch as f64,
                owner: (a.pipeline, a.model, i as u32),
            });
        }
    }

    for &p in &drifted {
        for g in gpus.iter_mut() {
            g.release_pipeline(p, &|model| {
                env.pipelines[p].models[model].spec.weight_mem_mb
            });
        }
    }
    for a in old.assignments.iter().filter(|a| is_drifted(a.pipeline)) {
        let spec = &env.pipelines[a.pipeline].models[a.model].spec;
        for b in a.bindings.iter().filter(|b| b.temporal.is_none()) {
            if let Some(g) = gpus.iter_mut().find(|g| g.gpu == b.gpu) {
                g.weight_mb = (g.weight_mb - spec.weight_mem_mb).max(0.0);
            }
        }
    }

    let mut assignments: Vec<Assignment> = old
        .assignments
        .iter()
        .filter(|a| !is_drifted(a.pipeline))
        .cloned()
        .collect();
    let kept_unplaced: usize = assignments
        .iter()
        .flat_map(|a| a.bindings.iter())
        .filter(|b| b.temporal.is_none())
        .count();

    let work: Vec<(usize, &[StageCfg])> =
        new_cfgs.iter().map(|(p, c)| (*p, c.as_slice())).collect();
    let (mut repaired, new_unplaced) = place_pipelines(env, &mut gpus, &work);
    assignments.append(&mut repaired);
    assignments.sort_by_key(|a| (a.pipeline, a.model));
    Plan { assignments, unplaced: kept_unplaced + new_unplaced }
}

fn least_loaded_gpu(gpus: &[GpuStreams], device: usize) -> GpuId {
    gpus.iter()
        .filter(|g| g.gpu.device == device)
        .min_by(|a, b| {
            (a.weight_mb + a.inter_mb())
                .partial_cmp(&(b.weight_mb + b.inter_mb()))
                .unwrap()
        })
        .map(|g| g.gpu)
        .unwrap_or(GpuId { device, gpu: 0 })
}

#[allow(clippy::too_many_arguments)]
fn place_instance(
    gpus: &mut [GpuStreams],
    device: usize,
    earliest: Ms,
    dur: Ms,
    duty: Ms,
    weight_mb: f64,
    inter_mb: f64,
    width: f64,
    owner: (usize, usize, u32),
) -> Option<(GpuId, TemporalSlot)> {
    let mut best: Option<(usize, usize, Ms, Ms)> = None;
    for (gi, g) in gpus.iter().enumerate() {
        if g.gpu.device != device {
            continue;
        }
        for s in &g.streams {
            if s.duty_cycle_ms > 0.0 && s.duty_cycle_ms > duty + 1e-9 {
                continue;
            }
            if !g.admits(s.index, weight_mb, inter_mb, width) {
                continue;
            }
            let horizon = if s.duty_cycle_ms > 0.0 { s.duty_cycle_ms } else { duty };
            for f in s.free_portions(horizon) {
                if f.end_ms > horizon + 1e-9 {
                    continue;
                }
                if let Some(start) = f.fit(earliest, dur) {
                    let slack = f.len() - dur;
                    let better = match best {
                        None => true,
                        Some((_, _, bstart, bslack)) => {
                            slack < bslack - 1e-9
                                || (slack - bslack).abs() <= 1e-9 && start < bstart
                        }
                    };
                    if better {
                        best = Some((gi, s.index, start, slack));
                    }
                }
            }
        }
    }
    let (gi, si, start, _) = best?;
    let g = &mut gpus[gi];
    if g.streams[si].duty_cycle_ms <= 0.0 {
        g.streams[si].duty_cycle_ms = duty;
    }
    g.weight_mb += weight_mb;
    g.streams[si].insert(Portion {
        start_ms: start,
        end_ms: start + dur,
        width,
        inter_mb,
        owner,
    });
    Some((
        g.gpu,
        TemporalSlot {
            stream: si,
            start_ms: start,
            duration_ms: dur,
            duty_cycle_ms: g.streams[si].duty_cycle_ms,
        },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::coordinator::{coral, cwd};
    use crate::pipeline::standard_pipelines;
    use crate::profiles::ProfileStore;

    /// The reference and the workspace-backed planner must agree bit for
    /// bit on the standard fixture (the proptest in rust/tests/planner.rs
    /// covers fuzzed shapes; this is the fast in-tree smoke).
    #[test]
    fn reference_matches_optimized_on_fixture() {
        let cl = Cluster::paper_testbed();
        let pf = ProfileStore::analytic();
        let pl: Vec<_> = standard_pipelines(4)
            .into_iter()
            .map(|mut p| {
                p.source_device += 1;
                p
            })
            .collect();
        let env = SchedEnv::bootstrap(&cl, &pf, &pl, vec![40.0; cl.devices.len()]);
        let params = CwdParams::default();

        let fast: Vec<Vec<StageCfg>> =
            cwd::cwd(&env, &params).into_iter().map(|r| r.cfg).collect();
        let naive: Vec<Vec<StageCfg>> =
            cwd_reference(&env, &params).into_iter().map(|r| r.cfg).collect();
        assert_eq!(fast, naive, "CWD diverged from reference");

        let plan_fast = coral::coral(&env, &fast);
        let plan_naive = coral_reference(&env, &naive);
        assert!(plan_fast.bit_eq(&plan_naive), "CORAL diverged from reference");

        // Subset + repair path: replan pipeline 1 against the rest.
        let kept: Vec<(usize, Vec<StageCfg>)> = [0usize, 2, 3]
            .iter()
            .map(|&p| (p, fast[p].clone()))
            .collect();
        let sub_fast = cwd::cwd_subset(&env, &params, &[1], &kept);
        let sub_naive = cwd_subset_reference(&env, &params, &[1], &kept);
        assert_eq!(sub_fast, sub_naive, "cwd_subset diverged from reference");
        let rep_fast = coral::coral_repair(&env, &plan_fast, &sub_fast);
        let rep_naive = coral_repair_reference(&env, &plan_naive, &sub_naive);
        assert!(rep_fast.bit_eq(&rep_naive), "coral_repair diverged");
    }
}
