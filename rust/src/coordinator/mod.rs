//! The paper's system contribution (§III): CWD cross-device workload
//! distribution, CORAL co-location spatiotemporal scheduling, the runtime
//! horizontal autoscaler, and the controller loop that drives them —
//! plus the three SOTA baselines (§IV-A4) implemented on the same
//! substrate, and a brute-force ILP reference for tiny instances.
//!
//! # Planner workspace
//!
//! The control plane is incremental: the [`Controller`] owns a
//! [`PlannerWorkspace`] and threads it through every `*_ws` entry point
//! (`cwd::cwd_ws`, `cwd::cwd_subset_ws`, `coral::coral_ws`,
//! `coral::coral_repair_ws`). The workspace carries per-device running
//! aggregates (so CWD's per-candidate feasibility checks are O(stages of
//! the current pipeline) instead of rescanning every scheduled pipeline),
//! a per-device GPU index for CORAL's placement scans and O(1) plan
//! replay, and recycled scratch buffers so steady-state replans allocate
//! nothing beyond the returned `Plan`. The contract: reusing one
//! workspace across rounds yields plans **bit-identical** to fresh
//! throwaway workspaces — and to the retained naive implementations in
//! [`reference`] — enforced by `rust/tests/planner.rs` and the ci.sh
//! determinism gates.

pub mod autoscaler;
pub mod baselines;
pub mod controller;
pub mod coral;
pub mod cwd;
pub mod drift;
pub mod estimator;
pub mod ilp;
pub mod reference;
pub mod stream;
pub mod types;
pub mod workspace;

pub use controller::Controller;
pub use drift::{DriftDetector, DriftParams, PlanEnvelope, ReplanMode};
pub use types::{
    Assignment, GpuBinding, GpuId, ModelObs, Plan, SchedEnv, Scheduler,
    SchedulerKind, StageCfg, TemporalSlot,
};
pub use workspace::PlannerWorkspace;
