//! The paper's system contribution (§III): CWD cross-device workload
//! distribution, CORAL co-location spatiotemporal scheduling, the runtime
//! horizontal autoscaler, and the controller loop that drives them —
//! plus the three SOTA baselines (§IV-A4) implemented on the same
//! substrate, and a brute-force ILP reference for tiny instances.

pub mod autoscaler;
pub mod baselines;
pub mod controller;
pub mod coral;
pub mod cwd;
pub mod drift;
pub mod estimator;
pub mod ilp;
pub mod stream;
pub mod types;

pub use controller::Controller;
pub use drift::{DriftDetector, DriftParams, PlanEnvelope, ReplanMode};
pub use types::{
    Assignment, GpuBinding, GpuId, ModelObs, Plan, SchedEnv, Scheduler,
    SchedulerKind, StageCfg, TemporalSlot,
};
