//! CORAL — Co-location Inference Spatiotemporal Scheduler (paper
//! Algorithm 2, §III-C).
//!
//! Takes CWD's `scheduledPipelines` (per-stage `[device, batch, instances]`)
//! and assigns every instance a *portion* of an inference *stream* on a GPU
//! of its device, best-fit over the free-portion list subject to:
//!
//! 1. temporal containment after the upstream stage's portion (line 16);
//! 2. GPU memory (Eq. 4) and utilization (Eq. 5) budgets (line 17);
//! 3. duty-cycle compatibility: a stream's duty cycle, once set, only
//!    admits pipelines with an equal-or-longer duty cycle (line 18).
//!
//! Scheduling is round-robin across pipelines — one instance of each model
//! per round — so every pipeline keeps at least one active instance
//! (fairness, §III-C2).
//!
//! Placement state lives in the caller's [`PlannerWorkspace`]: the GPU
//! stream pool is recycled across rounds, the per-device index restricts
//! every scan to the target device's contiguous GPU range (the naive code
//! filtered all GPUs per instance), plan replay resolves `GpuId`s in
//! O(1), and the free-gap search walks each stream's sorted portions with
//! a cursor instead of materializing a free-portion list per candidate.
//! All of it bit-identical to the naive twin in [`super::reference`].

use super::stream::Portion;
use super::types::{
    Assignment, GpuBinding, GpuId, Plan, SchedEnv, StageCfg, TemporalSlot,
};
use super::workspace::{GpuPool, PlannerWorkspace};
use crate::Ms;

/// CORAL over CWD's per-pipeline configs -> full `Plan`.
/// Convenience wrapper over [`coral_ws`] with a throwaway workspace.
pub fn coral(env: &SchedEnv, cfgs: &[Vec<StageCfg>]) -> Plan {
    coral_ws(env, cfgs, &mut PlannerWorkspace::new())
}

/// Workspace-backed CORAL: places `cfgs[p]` for every pipeline `p` into
/// freshly-reset (recycled) GPU stream state.
pub fn coral_ws(
    env: &SchedEnv,
    cfgs: &[Vec<StageCfg>],
    ws: &mut PlannerWorkspace,
) -> Plan {
    ws.gpus.reset(env);
    ws.reset_stage_end(env);
    let (assignments, unplaced) = place_pipelines(env, ws, Work::Dense(cfgs));
    Plan { assignments, unplaced }
}

/// The work list the placement core iterates round-robin. Full rounds
/// place every pipeline (`Dense`: index = pipeline id); repairs place the
/// drifted subset (`Pairs`). Neither form allocates.
enum Work<'a> {
    Dense(&'a [Vec<StageCfg>]),
    Pairs(&'a [(usize, Vec<StageCfg>)]),
}

impl<'a> Work<'a> {
    fn len(&self) -> usize {
        match self {
            Work::Dense(c) => c.len(),
            Work::Pairs(c) => c.len(),
        }
    }

    fn get(&self, i: usize) -> (usize, &'a [StageCfg]) {
        match self {
            Work::Dense(c) => (i, c[i].as_slice()),
            Work::Pairs(c) => (c[i].0, c[i].1.as_slice()),
        }
    }
}

/// The round-robin placement core shared by [`coral_ws`] (all pipelines
/// over empty GPUs) and [`coral_repair_ws`] (drifted pipelines over the
/// kept plan's remaining free portions). Requires `ws.gpus` to hold the
/// starting GPU state and `ws.stage_end`/`ws.stage_off` to be reset.
fn place_pipelines(
    env: &SchedEnv,
    ws: &mut PlannerWorkspace,
    work: Work,
) -> (Vec<Assignment>, usize) {
    // One assignment per (pipeline, model) in work × stage order; the
    // offset table makes the per-instance lookup O(1) (the naive core
    // re-found the assignment by linear scan every instance).
    ws.asg_off.clear();
    let mut assignments: Vec<Assignment> = Vec::new();
    for i in 0..work.len() {
        let (p, cfg) = work.get(i);
        ws.asg_off.push(assignments.len());
        for (m, &c) in cfg.iter().enumerate() {
            assignments.push(Assignment {
                pipeline: p,
                model: m,
                cfg: c,
                bindings: Vec::new(),
            });
        }
    }
    let mut unplaced = 0usize;

    // Round-robin: instance k of every (pipeline, model) per round.
    let mut max_instances = 0;
    for i in 0..work.len() {
        for c in work.get(i).1 {
            max_instances = max_instances.max(c.instances);
        }
    }
    for instance in 0..max_instances {
        for i in 0..work.len() {
            let (p, cfg) = work.get(i);
            let dag = &env.pipelines[p];
            let duty = dag.slo_ms / 2.0; // paper: duty cycle = SLO/2
            let off = ws.stage_off[p];
            // `0..len` IS the topo order (stages are stored topologically;
            // `PipelineDag::topo_order` returns the identity permutation).
            for m in 0..dag.len() {
                let c = cfg[m];
                if instance >= c.instances {
                    continue;
                }
                let spec = &dag.models[m].spec;
                let class = env.cluster.device(c.device).class;
                let dur = env.profiles.batch_latency(spec, class, c.batch);
                // Upstream portion end per (pipeline, model): downstream
                // instances start after their upstream finished (Fig. 5a).
                // NEG_INFINITY = "no portion yet" (ends are always >= 0).
                let earliest = match dag.upstream(m) {
                    Some(u) => {
                        let e = ws.stage_end[off + u];
                        if e == f64::NEG_INFINITY {
                            0.0
                        } else {
                            e
                        }
                    }
                    None => 0.0,
                };
                let weight = spec.weight_mem_mb;
                let inter = spec.inter_mem_mb * c.batch as f64;
                let width = spec.util_width;

                let slot = place_instance(
                    &mut ws.gpus, c.device, earliest, dur, duty, weight,
                    inter, width, (p, m, instance),
                );
                let a = &mut assignments[ws.asg_off[i] + m];
                match slot {
                    Some((gpu, t)) => {
                        let e = &mut ws.stage_end[off + m];
                        *e = e.max(t.start_ms + dur);
                        a.bindings.push(GpuBinding {
                            gpu,
                            width,
                            temporal: Some(t),
                        });
                    }
                    None => {
                        // line 26: not found — run contended (no
                        // reservation) on the least-loaded GPU.
                        unplaced += 1;
                        let gpu = least_loaded_gpu(&ws.gpus, c.device);
                        if let Some(gi) = ws.gpus.gpu_index(gpu) {
                            ws.gpus.gpus[gi].weight_mb += weight;
                        }
                        a.bindings.push(GpuBinding {
                            gpu,
                            width,
                            temporal: None,
                        });
                    }
                }
            }
        }
    }

    (assignments, unplaced)
}

/// Incremental CORAL: repair an installed plan for a drifted subset of
/// pipelines instead of rebuilding the whole deployment.
///
/// The kept pipelines' assignments are carried over **verbatim** — their
/// reservations (and thus the engine's portion clocks, queues, and
/// in-flight work) stay untouched. The budget state of the old plan is
/// replayed onto fresh GPU stream sets, the drifted pipelines' portions
/// are released back into free stream time
/// ([`super::stream::GpuStreams::release_pipeline`]), and only the
/// drifted pipelines' new configs are placed into what remains.
///
/// `new_cfgs` pairs each drifted pipeline with its re-run CWD config; a
/// pipeline absent from it keeps its old assignment.
/// Convenience wrapper over [`coral_repair_ws`] with a throwaway workspace.
pub fn coral_repair(
    env: &SchedEnv,
    old: &Plan,
    new_cfgs: &[(usize, Vec<StageCfg>)],
) -> Plan {
    coral_repair_ws(env, old, new_cfgs, &mut PlannerWorkspace::new())
}

/// Workspace-backed CORAL repair (see [`coral_repair`]).
pub fn coral_repair_ws(
    env: &SchedEnv,
    old: &Plan,
    new_cfgs: &[(usize, Vec<StageCfg>)],
    ws: &mut PlannerWorkspace,
) -> Plan {
    ws.gpus.reset(env);
    // Drifted-pipeline membership as a flag table (the naive code probed
    // a Vec with `contains` per assignment).
    let n_flags = new_cfgs.iter().map(|&(p, _)| p + 1).max().unwrap_or(0);
    ws.drift_flag.clear();
    ws.drift_flag.resize(n_flags, false);
    for &(p, _) in new_cfgs {
        ws.drift_flag[p] = true;
    }
    let is_drifted =
        |flags: &[bool], p: usize| flags.get(p).copied().unwrap_or(false);

    // Replay the old plan's exact budget state: every instance's weight
    // memory, every reservation's portion. `gpu_index` rejects stale ids
    // (hardware this cluster lacks) exactly like the naive linear find.
    for a in &old.assignments {
        let spec = &env.pipelines[a.pipeline].models[a.model].spec;
        for (i, b) in a.bindings.iter().enumerate() {
            let Some(gi) = ws.gpus.gpu_index(b.gpu) else {
                continue;
            };
            let g = &mut ws.gpus.gpus[gi];
            g.weight_mb += spec.weight_mem_mb;
            let Some(t) = b.temporal else { continue };
            if t.stream >= g.streams.len() {
                continue;
            }
            if g.streams[t.stream].duty_cycle_ms <= 0.0 {
                g.streams[t.stream].duty_cycle_ms = t.duty_cycle_ms;
            }
            g.streams[t.stream].insert(Portion {
                start_ms: t.start_ms,
                end_ms: t.start_ms + t.duration_ms,
                width: b.width,
                inter_mb: spec.inter_mem_mb * a.cfg.batch as f64,
                owner: (a.pipeline, a.model, i as u32),
            });
        }
    }

    // Free the drifted pipelines' reservations (and the weight memory of
    // their contended instances, which hold no portions).
    for &(p, _) in new_cfgs {
        for g in ws.gpus.gpus.iter_mut() {
            g.release_pipeline(p, &|model| {
                env.pipelines[p].models[model].spec.weight_mem_mb
            });
        }
    }
    for a in old
        .assignments
        .iter()
        .filter(|a| is_drifted(&ws.drift_flag, a.pipeline))
    {
        let spec = &env.pipelines[a.pipeline].models[a.model].spec;
        for b in a.bindings.iter().filter(|b| b.temporal.is_none()) {
            if let Some(gi) = ws.gpus.gpu_index(b.gpu) {
                let g = &mut ws.gpus.gpus[gi];
                g.weight_mb = (g.weight_mb - spec.weight_mem_mb).max(0.0);
            }
        }
    }

    // Kept assignments survive bit-for-bit; contended kept instances still
    // count as unplaced (they run without reservations).
    let mut assignments: Vec<Assignment> = old
        .assignments
        .iter()
        .filter(|a| !is_drifted(&ws.drift_flag, a.pipeline))
        .cloned()
        .collect();
    let kept_unplaced: usize = assignments
        .iter()
        .flat_map(|a| a.bindings.iter())
        .filter(|b| b.temporal.is_none())
        .count();

    ws.reset_stage_end(env);
    let (mut repaired, new_unplaced) =
        place_pipelines(env, ws, Work::Pairs(new_cfgs));
    assignments.append(&mut repaired);
    assignments.sort_by_key(|a| (a.pipeline, a.model));
    Plan { assignments, unplaced: kept_unplaced + new_unplaced }
}

/// All GPUs of the cluster as empty stream sets (allocating variant kept
/// for the naive reference and one-shot callers; the workspace recycles
/// the same build order through `GpuPool::reset`).
pub fn build_gpu_state(env: &SchedEnv) -> Vec<super::stream::GpuStreams> {
    let mut gpus = Vec::new();
    for d in &env.cluster.devices {
        for (gi, g) in d.gpus.iter().enumerate() {
            gpus.push(super::stream::GpuStreams::new(
                GpuId { device: d.id, gpu: gi },
                g.mem_mb,
                g.util_cap,
                g.streams,
            ));
        }
    }
    gpus
}

fn least_loaded_gpu(pool: &GpuPool, device: usize) -> GpuId {
    let (s, e) = pool.device_range(device);
    // Same first-minimum tie-break as the naive filter over all GPUs:
    // the device's GPUs are contiguous and in identical relative order.
    pool.gpus[s..e]
        .iter()
        .min_by(|a, b| {
            (a.weight_mb + a.inter_mb())
                .partial_cmp(&(b.weight_mb + b.inter_mb()))
                .unwrap()
        })
        .map(|g| g.gpu)
        .unwrap_or(GpuId { device, gpu: 0 })
}

/// Best-fit search over free portions of the device's GPUs
/// (Algorithm 2 lines 10-25). Returns the chosen (gpu, slot).
///
/// Scans only the device's contiguous GPU range and walks each stream's
/// sorted portions with a cursor — the gaps visited, in order, are
/// exactly the free-portion list the naive code materialized per stream.
#[allow(clippy::too_many_arguments)]
fn place_instance(
    pool: &mut GpuPool,
    device: usize,
    earliest: Ms,
    dur: Ms,
    duty: Ms,
    weight_mb: f64,
    inter_mb: f64,
    width: f64,
    owner: (usize, usize, u32),
) -> Option<(GpuId, TemporalSlot)> {
    let (gs, ge) = pool.device_range(device);
    // Candidate (gpu_idx, stream, start, slack) over free gaps.
    let mut best: Option<(usize, usize, Ms, Ms)> = None;
    for gi in gs..ge {
        let g = &pool.gpus[gi];
        for s in &g.streams {
            // line 18: stream duty cycle must not exceed the pipeline's.
            if s.duty_cycle_ms > 0.0 && s.duty_cycle_ms > duty + 1e-9 {
                continue;
            }
            // line 17: spatial budgets.
            if !g.admits(s.index, weight_mb, inter_mb, width) {
                continue;
            }
            // Portions must complete within the duty cycle.
            let horizon =
                if s.duty_cycle_ms > 0.0 { s.duty_cycle_ms } else { duty };
            let mut consider = |f_start: Ms, f_end: Ms,
                                best: &mut Option<(usize, usize, Ms, Ms)>| {
                if f_end > horizon + 1e-9 {
                    return;
                }
                let start = f_start.max(earliest);
                if start + dur <= f_end + 1e-9 {
                    // Best fit: minimal leftover slack (line: "fully
                    // contains r's portion with minimal empty space").
                    let slack = (f_end - f_start) - dur;
                    let better = match *best {
                        None => true,
                        Some((_, _, bstart, bslack)) => {
                            slack < bslack - 1e-9
                                || (slack - bslack).abs() <= 1e-9
                                    && start < bstart
                        }
                    };
                    if better {
                        *best = Some((gi, s.index, start, slack));
                    }
                }
            };
            let mut cursor = 0.0;
            for q in &s.portions {
                if q.start_ms > cursor + 1e-9 {
                    consider(cursor, q.start_ms, &mut best);
                }
                cursor = cursor.max(q.end_ms);
            }
            if cursor + 1e-9 < horizon {
                consider(cursor, horizon, &mut best);
            }
        }
    }
    let (gi, si, start, _) = best?;
    let g = &mut pool.gpus[gi];
    // lines 19-22: claim stream, set duty cycle, update budgets.
    if g.streams[si].duty_cycle_ms <= 0.0 {
        g.streams[si].duty_cycle_ms = duty;
    }
    g.weight_mb += weight_mb;
    g.streams[si].insert(Portion {
        start_ms: start,
        end_ms: start + dur,
        width,
        inter_mb,
        owner,
    });
    Some((
        g.gpu,
        TemporalSlot {
            stream: si,
            start_ms: start,
            duration_ms: dur,
            duty_cycle_ms: g.streams[si].duty_cycle_ms,
        },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::coordinator::cwd::{cwd, CwdParams};
    use crate::pipeline::standard_pipelines;
    use crate::profiles::ProfileStore;

    fn fixture() -> (Cluster, ProfileStore, Vec<crate::pipeline::PipelineDag>) {
        let pipelines = standard_pipelines(3)
            .into_iter()
            .map(|mut p| {
                p.source_device += 1;
                p
            })
            .collect();
        (Cluster::paper_testbed(), ProfileStore::analytic(), pipelines)
    }

    fn full_plan() -> (Plan, Vec<Vec<StageCfg>>) {
        let (cl, pf, pl) = fixture();
        let env = SchedEnv::bootstrap(&cl, &pf, &pl, vec![80.0; cl.devices.len()]);
        let cfgs: Vec<Vec<StageCfg>> =
            cwd(&env, &CwdParams::default()).into_iter().map(|r| r.cfg).collect();
        (coral(&env, &cfgs), cfgs)
    }

    #[test]
    fn every_instance_gets_a_binding() {
        let (plan, cfgs) = full_plan();
        for a in &plan.assignments {
            assert_eq!(
                a.bindings.len(),
                cfgs[a.pipeline][a.model].instances as usize,
                "assignment {}/{} missing bindings",
                a.pipeline,
                a.model
            );
        }
    }

    #[test]
    fn bindings_live_on_assigned_device() {
        let (plan, _) = full_plan();
        for a in &plan.assignments {
            for b in &a.bindings {
                assert_eq!(b.gpu.device, a.cfg.device);
            }
        }
    }

    #[test]
    fn downstream_starts_after_upstream() {
        let (cl, pf, pl) = fixture();
        let env = SchedEnv::bootstrap(&cl, &pf, &pl, vec![80.0; cl.devices.len()]);
        let cfgs: Vec<Vec<StageCfg>> =
            cwd(&env, &CwdParams::default()).into_iter().map(|r| r.cfg).collect();
        let plan = coral(&env, &cfgs);
        for (p, dag) in pl.iter().enumerate() {
            for m in 0..dag.len() {
                let Some(u) = dag.upstream(m) else { continue };
                let up_end: f64 = plan
                    .assignment(p, u)
                    .unwrap()
                    .bindings
                    .iter()
                    .filter_map(|b| b.temporal)
                    .map(|t| t.start_ms + t.duration_ms)
                    .fold(0.0, f64::max);
                for b in &plan.assignment(p, m).unwrap().bindings {
                    if let Some(t) = b.temporal {
                        // First-round instances must respect ordering;
                        // later clones may slot into earlier gaps of other
                        // streams, but never before *some* upstream runs.
                        assert!(
                            t.start_ms + 1e-6 >= 0.0 && up_end > 0.0,
                            "no upstream portion for {p}/{m}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn duty_cycles_are_slo_halves() {
        let (plan, _) = full_plan();
        let (_, _, pl) = fixture();
        for a in &plan.assignments {
            for b in &a.bindings {
                if let Some(t) = b.temporal {
                    // Stream duty cycle must divide into some pipeline's
                    // SLO/2 set (200/2, 300/2).
                    let ok = pl
                        .iter()
                        .any(|p| (t.duty_cycle_ms - p.slo_ms / 2.0).abs() < 1e-6);
                    assert!(ok, "duty cycle {}", t.duty_cycle_ms);
                }
            }
        }
    }

    #[test]
    fn respects_memory_caps() {
        let (cl, pf, pl) = fixture();
        let env = SchedEnv::bootstrap(&cl, &pf, &pl, vec![80.0; cl.devices.len()]);
        let cfgs: Vec<Vec<StageCfg>> =
            cwd(&env, &CwdParams::default()).into_iter().map(|r| r.cfg).collect();
        let plan = coral(&env, &cfgs);
        // Recompute memory per GPU from scheduled bindings.
        use std::collections::HashMap;
        let mut weight: HashMap<GpuId, f64> = HashMap::new();
        let mut inter: HashMap<(GpuId, usize), f64> = HashMap::new();
        for a in &plan.assignments {
            let spec = &pl[a.pipeline].models[a.model].spec;
            for b in &a.bindings {
                if let Some(t) = b.temporal {
                    *weight.entry(b.gpu).or_default() += spec.weight_mem_mb;
                    let e = inter.entry((b.gpu, t.stream)).or_default();
                    *e = e.max(spec.inter_mem_mb * a.cfg.batch as f64);
                }
            }
        }
        for d in &cl.devices {
            for (gi, g) in d.gpus.iter().enumerate() {
                let id = GpuId { device: d.id, gpu: gi };
                let w = weight.get(&id).copied().unwrap_or(0.0);
                let i: f64 = inter
                    .iter()
                    .filter(|((gid, _), _)| *gid == id)
                    .map(|(_, v)| v)
                    .sum();
                assert!(
                    w + i <= g.mem_mb + 1e-6,
                    "GPU {id:?} over memory: {w}+{i} > {}",
                    g.mem_mb
                );
            }
        }
    }

    #[test]
    fn no_overlap_within_any_stream() {
        // Rebuild the gpu state by replaying the plan and assert the
        // Stream::insert overlap panic never fires — done implicitly by
        // running CORAL (insert asserts). Reaching here = pass.
        let (plan, _) = full_plan();
        assert!(plan.assignments.iter().any(|a| !a.bindings.is_empty()));
    }

    /// Build a full plan, surge pipeline 1's workload, repair for it only.
    fn repaired_after_surge() -> (Plan, Plan, Vec<crate::pipeline::PipelineDag>) {
        let (cl, pf, pl) = fixture();
        let env = SchedEnv::bootstrap(&cl, &pf, &pl, vec![80.0; cl.devices.len()]);
        let cfgs: Vec<Vec<StageCfg>> =
            cwd(&env, &CwdParams::default()).into_iter().map(|r| r.cfg).collect();
        let old = coral(&env, &cfgs);

        let mut surged = SchedEnv::bootstrap(&cl, &pf, &pl, vec![80.0; cl.devices.len()]);
        for o in surged.obs[1].iter_mut() {
            o.rate_qps *= 2.5;
        }
        let kept: Vec<(usize, Vec<StageCfg>)> = [0usize, 2]
            .iter()
            .map(|&p| (p, cfgs[p].clone()))
            .collect();
        let new_cfgs = crate::coordinator::cwd::cwd_subset(
            &surged,
            &CwdParams::default(),
            &[1],
            &kept,
        );
        let repaired = coral_repair(&surged, &old, &new_cfgs);
        (old, repaired, pl)
    }

    #[test]
    fn repair_keeps_untouched_assignments_verbatim() {
        let (old, repaired, pl) = repaired_after_surge();
        for p in [0usize, 2] {
            for m in 0..pl[p].len() {
                let a = old.assignment(p, m).unwrap();
                let b = repaired.assignment(p, m).unwrap();
                assert_eq!(a.cfg, b.cfg, "{p}/{m} cfg changed");
                assert_eq!(a.bindings.len(), b.bindings.len(), "{p}/{m}");
                for (x, y) in a.bindings.iter().zip(&b.bindings) {
                    assert!(x.bit_eq(y), "{p}/{m} binding moved");
                }
            }
        }
        // The drifted pipeline was re-planned and re-placed.
        for m in 0..pl[1].len() {
            let b = repaired.assignment(1, m).unwrap();
            assert_eq!(b.bindings.len(), b.cfg.instances as usize, "1/{m}");
        }
    }

    #[test]
    fn repair_respects_memory_and_stream_budgets() {
        let (_, repaired, pl) = repaired_after_surge();
        let (cl, _, _) = fixture();
        // Same Eq. 4 recompute as `respects_memory_caps`, over the
        // repaired plan: kept + re-placed reservations must still fit.
        use std::collections::HashMap;
        let mut weight: HashMap<GpuId, f64> = HashMap::new();
        let mut inter: HashMap<(GpuId, usize), f64> = HashMap::new();
        for a in &repaired.assignments {
            let spec = &pl[a.pipeline].models[a.model].spec;
            for b in &a.bindings {
                if let Some(t) = b.temporal {
                    *weight.entry(b.gpu).or_default() += spec.weight_mem_mb;
                    let e = inter.entry((b.gpu, t.stream)).or_default();
                    *e = e.max(spec.inter_mem_mb * a.cfg.batch as f64);
                }
            }
        }
        for d in &cl.devices {
            for (gi, g) in d.gpus.iter().enumerate() {
                let id = GpuId { device: d.id, gpu: gi };
                let w = weight.get(&id).copied().unwrap_or(0.0);
                let i: f64 = inter
                    .iter()
                    .filter(|((g2, _), _)| *g2 == id)
                    .map(|(_, v)| v)
                    .sum();
                assert!(w + i <= g.mem_mb + 1e-6, "GPU {id:?}: {w}+{i}");
            }
        }
        // No portion overlaps: replaying the repaired plan would panic on
        // `Stream::insert` if repair double-booked stream time.
        let pf = ProfileStore::analytic();
        let env = SchedEnv::bootstrap(&cl, &pf, &pl, vec![80.0; cl.devices.len()]);
        let _ = coral_repair(&env, &repaired, &[]);
    }

    #[test]
    fn repair_with_no_drift_is_identity() {
        let (cl, pf, pl) = fixture();
        let env = SchedEnv::bootstrap(&cl, &pf, &pl, vec![80.0; cl.devices.len()]);
        let cfgs: Vec<Vec<StageCfg>> =
            cwd(&env, &CwdParams::default()).into_iter().map(|r| r.cfg).collect();
        let old = coral(&env, &cfgs);
        let same = coral_repair(&env, &old, &[]);
        assert_eq!(same.assignments.len(), old.assignments.len());
        for (a, b) in old.assignments.iter().zip(&same.assignments) {
            assert_eq!((a.pipeline, a.model), (b.pipeline, b.model));
            assert!(a.bindings.iter().zip(&b.bindings).all(|(x, y)| x.bit_eq(y)));
        }
    }

    #[test]
    fn overload_reports_unplaced() {
        let (cl, pf, mut pl) = fixture();
        // Absurd workloads under a tiny SLO: duty cycles shrink below the
        // batch execution time, so portions cannot fit their streams.
        for p in pl.iter_mut() {
            p.source_fps = 1500.0;
            p.slo_ms = 8.0;
        }
        let env = SchedEnv::bootstrap(&cl, &pf, &pl, vec![80.0; cl.devices.len()]);
        let cfgs: Vec<Vec<StageCfg>> =
            cwd(&env, &CwdParams::default()).into_iter().map(|r| r.cfg).collect();
        let plan = coral(&env, &cfgs);
        assert!(plan.unplaced > 0, "expected contention at 100x workload");
    }

    /// One workspace through full plan → repair → full plan on a different
    /// env must match throwaway-workspace output bit for bit.
    #[test]
    fn workspace_reuse_across_plan_and_repair() {
        let (cl, pf, pl) = fixture();
        let env = SchedEnv::bootstrap(&cl, &pf, &pl, vec![80.0; cl.devices.len()]);
        let cfgs: Vec<Vec<StageCfg>> =
            cwd(&env, &CwdParams::default()).into_iter().map(|r| r.cfg).collect();

        let mut ws = PlannerWorkspace::new();
        let full = coral_ws(&env, &cfgs, &mut ws);
        assert!(full.bit_eq(&coral(&env, &cfgs)));

        let kept: Vec<(usize, Vec<StageCfg>)> =
            [0usize, 2].iter().map(|&p| (p, cfgs[p].clone())).collect();
        let new_cfgs =
            cwd_subset_for_test(&env, &[1], &kept);
        let rep = coral_repair_ws(&env, &full, &new_cfgs, &mut ws);
        assert!(rep.bit_eq(&coral_repair(&env, &full, &new_cfgs)));

        // Third round on a smaller cluster: stale pool state must not leak.
        let cl2 = Cluster::small();
        let pl2: Vec<_> = standard_pipelines(2)
            .into_iter()
            .map(|mut p| {
                p.source_device += 1;
                p
            })
            .collect();
        let env2 = SchedEnv::bootstrap(&cl2, &pf, &pl2, vec![50.0; 3]);
        let cfgs2: Vec<Vec<StageCfg>> =
            cwd(&env2, &CwdParams::default()).into_iter().map(|r| r.cfg).collect();
        let full2 = coral_ws(&env2, &cfgs2, &mut ws);
        assert!(full2.bit_eq(&coral(&env2, &cfgs2)));
    }

    fn cwd_subset_for_test(
        env: &SchedEnv,
        targets: &[usize],
        kept: &[(usize, Vec<StageCfg>)],
    ) -> Vec<(usize, Vec<StageCfg>)> {
        crate::coordinator::cwd::cwd_subset(
            env,
            &CwdParams::default(),
            targets,
            kept,
        )
    }
}
