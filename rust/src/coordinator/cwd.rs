//! CWD — Cross-device Workload Distributor (paper Algorithm 1, §III-B).
//!
//! Workload-aware greedy search over per-stage `[batch, device, instances]`:
//!
//! 1. Initialize every model on the server at batch 1 with enough instances
//!    to absorb the incoming rate (lines 3-5).
//! 2. Sort models by burstiness (descending) and greedily double batch
//!    sizes, reducing instances as throughput-per-instance rises; a step is
//!    kept only if estimated pipeline latency stays within SLO/2 and
//!    estimated effective throughput improves (lines 6-17; Insight 1).
//! 3. `ToEdge()` DFS pushes a prefix of the pipeline to the data source's
//!    edge device, keeping a stage there only if its output traffic is
//!    lighter than its input traffic by factor α and no downstream serves
//!    as a better split (lines 21-28; Insights 2-3).
//!
//! The feasibility filters (device memory headroom, stream-time budget)
//! run against [`DeviceLoads`] running aggregates instead of rescanning
//! every already-placed pipeline per candidate: committed pipelines fold
//! into the per-device totals once, in commit order, and each candidate
//! continues that exact fold over only the current pipeline's stages —
//! O(stages) instead of O(all placed stages), with bit-identical floats
//! (the naive twin lives in [`super::reference`] and the identity is
//! enforced by `rust/tests/planner.rs`).

use super::estimator::{est_gpu_cost, est_latency, est_throughput, stage_memory_mb};
use super::types::{SchedEnv, StageCfg};
use super::workspace::{DeviceLoads, PlannerWorkspace};
use crate::profiles::BATCH_SIZES;

/// Result of CWD for one pipeline.
#[derive(Clone, Debug)]
pub struct CwdResult {
    pub cfg: Vec<StageCfg>,
}

/// Tuning knobs (paper defaults).
#[derive(Clone, Copy, Debug)]
pub struct CwdParams {
    /// SLO guard fraction for batch exploration (paper: SLO/2 — the other
    /// half is CORAL's duty cycle).
    pub slo_fraction: f64,
    /// Max batch size considered.
    pub max_batch: u32,
    /// Static batch override (Fig. 10 "Static Batch" ablation).
    pub static_batch: Option<(u32, u32, u32)>, // (edge, server, detector)
    /// Disable ToEdge (Fig. 10 "Server Only" ablation).
    pub server_only: bool,
}

impl Default for CwdParams {
    fn default() -> Self {
        CwdParams {
            slo_fraction: 0.5,
            max_batch: *BATCH_SIZES.last().unwrap(),
            static_batch: None,
            server_only: false,
        }
    }
}

/// Instances needed on `device` at batch `bz` to absorb the model's rate.
///
/// Under CORAL each instance executes once per duty cycle (SLO/2), so its
/// sustainable rate is `bz / duty` — usually tighter than the raw batch
/// curve's `bz / L(bz)`. CWD sizes for the duty-cycled capacity so the
/// temporal plan is feasible.
pub(crate) fn instances_needed(
    env: &SchedEnv,
    pipeline: usize,
    model: usize,
    device: usize,
    bz: u32,
) -> u32 {
    let dag = &env.pipelines[pipeline];
    let spec = &dag.models[model].spec;
    let class = env.cluster.device(device).class;
    // A reserved instance chains full batches through its stream's free
    // time when backlogged (CORAL stacks portions to minimize gaps), so
    // sustained capacity approaches the batch curve; the 0.8 discount
    // reserves slack for the portion-clocked partial batches.
    let cap = env.profiles.curve(spec, class).throughput(bz) * 0.8;
    // Burst headroom (Insight 1): bursty models see instantaneous rates
    // far above the mean; size capacity for the burst envelope. CV is
    // clamped — batched upstream completions clump arrivals, inflating
    // raw inter-arrival CV beyond what capacity planning should chase.
    let cv = env.burstiness(pipeline, model).min(2.0);
    let rate = env.rate(pipeline, model) * (1.0 + 0.5 * cv);
    ((rate / cap.max(1e-9)).ceil() as u32).clamp(1, 16)
}

/// Network overhead (bytes/s) of a stage's *input* crossing the link.
pub(crate) fn input_overhead(env: &SchedEnv, pipeline: usize, model: usize) -> f64 {
    let spec = &env.pipelines[pipeline].models[model].spec;
    env.rate(pipeline, model) * spec.input_bytes
}

/// Network overhead (bytes/s) of a stage's *output* crossing the link.
pub(crate) fn output_overhead(env: &SchedEnv, pipeline: usize, model: usize) -> f64 {
    let spec = &env.pipelines[pipeline].models[model].spec;
    env.rate(pipeline, model) * spec.fanout_mean * spec.output_bytes
}

/// Run CWD for every pipeline; `scheduled[p]` is the per-stage config.
/// Convenience wrapper over [`cwd_ws`] with a throwaway workspace.
pub fn cwd(env: &SchedEnv, params: &CwdParams) -> Vec<CwdResult> {
    let mut ws = PlannerWorkspace::new();
    let mut out = Vec::new();
    cwd_ws(env, params, &mut ws, &mut out);
    out.into_iter().map(|(_, cfg)| CwdResult { cfg }).collect()
}

/// Full CWD round into a caller-supplied buffer, reusing `ws` scratch.
pub fn cwd_ws(
    env: &SchedEnv,
    params: &CwdParams,
    ws: &mut PlannerWorkspace,
    out: &mut Vec<(usize, Vec<StageCfg>)>,
) {
    let mut targets = std::mem::take(&mut ws.full_targets);
    targets.clear();
    targets.extend(0..env.pipelines.len());
    cwd_subset_ws(env, params, &targets, &[], ws, out);
    ws.full_targets = targets;
}

/// Incremental CWD: re-plan only `targets`, treating `kept` — the
/// untouched pipelines' live (pipeline, per-stage config) pairs — as
/// already-committed load for the device memory and stream-time
/// feasibility filters. Returns (pipeline, cfg) pairs for the targets in
/// the order given. This is the drift-replan entry: drifted pipelines get
/// fresh workload-aware configs while everything else stays put.
/// Convenience wrapper over [`cwd_subset_ws`] with a throwaway workspace.
pub fn cwd_subset(
    env: &SchedEnv,
    params: &CwdParams,
    targets: &[usize],
    kept: &[(usize, Vec<StageCfg>)],
) -> Vec<(usize, Vec<StageCfg>)> {
    let mut ws = PlannerWorkspace::new();
    let mut out = Vec::new();
    cwd_subset_ws(env, params, targets, kept, &mut ws, &mut out);
    out
}

/// Workspace-backed subset CWD. `kept` pipelines fold into the committed
/// [`DeviceLoads`] once; each target is planned against the aggregates,
/// then committed in turn (targets see earlier targets as committed load,
/// exactly like the scheduled-vec the naive planner grows). Rows for the
/// output come from `ws.row_pool` — return them there when done to keep
/// steady-state replans allocation-free.
pub fn cwd_subset_ws(
    env: &SchedEnv,
    params: &CwdParams,
    targets: &[usize],
    kept: &[(usize, Vec<StageCfg>)],
    ws: &mut PlannerWorkspace,
    out: &mut Vec<(usize, Vec<StageCfg>)>,
) {
    out.clear();
    ws.loads.reset(env);
    for (p, cfg) in kept {
        ws.loads.commit(env, *p, cfg);
    }

    for &p in targets {
        let dag = &env.pipelines[p];
        let slo_budget = dag.slo_ms * params.slo_fraction;

        // ---- lines 3-5: minimal config, all on server, rate-matched ----
        let mut cfg = ws.take_row();
        for m in 0..dag.len() {
            cfg.push(StageCfg {
                device: 0,
                batch: 1,
                instances: instances_needed(env, p, m, 0, 1),
            });
        }

        // ---- line 6: sort by burstiness, descending (Insight 1) ----
        ws.order.clear();
        ws.order.extend(0..dag.len());
        ws.order.sort_by(|&a, &b| {
            env.burstiness(p, b)
                .partial_cmp(&env.burstiness(p, a))
                .unwrap()
        });

        if let Some((_, server_bz, det_bz)) = params.static_batch {
            // Fig. 10 ablation: fixed batches, skip exploration.
            for (m, c) in cfg.iter_mut().enumerate() {
                c.batch = if m == 0 { det_bz } else { server_bz };
                c.instances = instances_needed(env, p, m, 0, c.batch);
            }
        } else {
            // ---- lines 7-17: greedy batch doubling ----
            explore_batches(env, params, p, &ws.order, slo_budget, &mut cfg);
        }

        // ---- line 18: ToEdge(p[0]) ----
        if !params.server_only {
            let ctx = ToEdgeCtx { env, params, pipeline: p, loads: &ws.loads };
            to_edge(&ctx, &mut ws.downs_pool, 0, &mut cfg);
            // Refinement: re-run batch exploration under the final
            // placement — models that could not batch while the pipeline
            // was (infeasibly) server-bound get their real batch sizes now
            // ("exploration continues until no better configuration is
            // found", line 17).
            if params.static_batch.is_none() {
                explore_batches(env, params, p, &ws.order, slo_budget, &mut cfg);
            }
        }

        // The finished target becomes committed load for the next one.
        ws.loads.commit(env, p, &cfg);
        out.push((p, cfg));
    }
}

/// Greedy batch-doubling pass (Algorithm 1 lines 7-17). Objective:
/// effective throughput, tie-broken by GPU cost — batching that frees GPU
/// time without hurting throughput is adopted (resource efficiency).
pub(crate) fn explore_batches(
    env: &SchedEnv,
    params: &CwdParams,
    p: usize,
    order: &[usize],
    slo_budget: f64,
    cfg: &mut [StageCfg],
) {
    let mut best_thrpt = est_throughput(env, p, cfg);
    let mut best_cost = est_gpu_cost(env, p, cfg);
    loop {
        let mut improved = false;
        for &m in order {
            let old = cfg[m];
            let next_bz = old.batch * 2;
            if next_bz > params.max_batch {
                continue;
            }
            cfg[m].batch = next_bz;
            cfg[m].instances = instances_needed(env, p, m, cfg[m].device, next_bz);
            if est_latency(env, p, cfg) > slo_budget {
                cfg[m] = old; // line 12: violates SLO guard
                continue;
            }
            let thrpt = est_throughput(env, p, cfg);
            let cost = est_gpu_cost(env, p, cfg);
            if thrpt > best_thrpt + 1e-9
                || (thrpt >= best_thrpt - 1e-9 && cost < best_cost - 1e-9)
            {
                best_thrpt = best_thrpt.max(thrpt); // lines 14-16
                best_cost = cost;
                improved = true;
            } else {
                cfg[m] = old;
            }
        }
        if !improved {
            break; // line 17
        }
    }
}

struct ToEdgeCtx<'a, 'b> {
    env: &'a SchedEnv<'b>,
    params: &'a CwdParams,
    pipeline: usize,
    /// Committed per-device aggregates: kept pipelines plus the targets
    /// already finished this round.
    loads: &'a DeviceLoads,
}

/// DFS move of model `m` (and transitively its downstreams) to the edge
/// device hosting the pipeline's source (Algorithm 1 lines 21-28).
///
/// `downs_pool` recycles the per-level downstream sort buffers of the DFS.
fn to_edge(
    ctx: &ToEdgeCtx,
    downs_pool: &mut Vec<Vec<usize>>,
    m: usize,
    cfg: &mut Vec<StageCfg>,
) {
    let env = ctx.env;
    let p = ctx.pipeline;
    let dag = &env.pipelines[p];
    let edge_dev = dag.source_device;
    if edge_dev == 0 {
        return; // source is the server itself; nothing to distribute
    }
    let slo_budget = dag.slo_ms * ctx.params.slo_fraction;

    // ---- line 22: find the best feasible edge configuration for m ----
    let old = cfg[m];
    // Static-batch ablation pins the edge batch too.
    let static_one;
    let batches: &[u32] = match ctx.params.static_batch {
        Some((edge_bz, _, det_bz)) => {
            static_one = [if m == 0 { det_bz } else { edge_bz }];
            &static_one
        }
        None => &BATCH_SIZES,
    };
    // The committed-load context is loop-invariant: whenever the naive
    // planner ran these checks, cfg[m] held `old` (candidates are applied
    // only for the SLO estimate and reverted), so the fold over committed
    // pipelines + the in-progress cfg is the same for every candidate.
    // Continue the committed fold once instead of rescanning per batch.
    let duty = dag.slo_ms * ctx.params.slo_fraction;
    let class = env.cluster.device(edge_dev).class;
    let headroom = ctx.loads.mem_headroom(env, edge_dev, p, cfg);
    let committed_time = ctx.loads.stream_time(env, edge_dev, p, cfg);
    let budget = ctx.loads.stream_budget(edge_dev, duty);

    let mut best: Option<(StageCfg, f64, f64)> = None; // (cfg, thrpt, cost)
    for &bz in batches {
        let cand = StageCfg {
            device: edge_dev,
            batch: bz,
            instances: instances_needed(env, p, m, edge_dev, bz),
        };
        // Edge memory feasibility (coarse Eq. 4 check; CORAL is exact).
        let mem = stage_memory_mb(env, p, m, cand);
        if mem > headroom {
            continue;
        }
        // Stream-time feasibility: the device must have enough reservable
        // portion time per duty cycle for CORAL to schedule everything.
        let cand_time = env
            .profiles
            .batch_latency(&dag.models[m].spec, class, cand.batch)
            * cand.instances as f64;
        if committed_time + cand_time > budget {
            continue;
        }
        cfg[m] = cand;
        if est_latency(env, p, cfg) <= slo_budget {
            let thrpt = est_throughput(env, p, cfg);
            let cost = est_gpu_cost(env, p, cfg);
            let better = match &best {
                None => true,
                Some((_, bt, bc)) => {
                    thrpt > bt + 1e-9 || (thrpt >= bt - 1e-9 && cost < bc - 1e-9)
                }
            };
            if better {
                best = Some((cand, thrpt, cost));
            }
        }
        cfg[m] = old;
    }
    let Some((cand, _, _)) = best else {
        return; // line 23-24: no feasible edge config, stop the DFS here
    };
    cfg[m] = cand;

    // ---- lines 25-26: recurse downstream, least bursty first (Insight 1)
    let mut downs = downs_pool.pop().unwrap_or_default();
    downs.clear();
    downs.extend_from_slice(&dag.models[m].downstream);
    downs.sort_by(|&a, &b| {
        env.burstiness(p, a).partial_cmp(&env.burstiness(p, b)).unwrap()
    });
    for i in 0..downs.len() {
        to_edge(ctx, downs_pool, downs[i], cfg);
    }
    downs_pool.push(downs);

    // ---- line 27-28: IO-ratio test on the return path (Insight 2) ----
    let in_oh = input_overhead(env, p, m);
    let out_oh = output_overhead(env, p, m);
    let downstreams_on_edge = dag.models[m]
        .downstream
        .iter()
        .any(|&d| cfg[d].device == edge_dev);
    if in_oh * ctx.env.alpha < out_oh && !downstreams_on_edge {
        cfg[m] = old; // revert: m would amplify network traffic
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::pipeline::{standard_pipelines, PipelineDag};
    use crate::profiles::ProfileStore;

    struct Fix {
        cluster: Cluster,
        profiles: ProfileStore,
        pipelines: Vec<PipelineDag>,
    }

    fn fixture(n: usize) -> Fix {
        Fix {
            cluster: Cluster::paper_testbed(),
            profiles: ProfileStore::analytic(),
            pipelines: standard_pipelines(n).into_iter()
                .map(|mut p| {
                    // paper: sources live on edge devices 1..=9
                    p.source_device += 1;
                    p
                })
                .collect(),
        }
    }

    fn env(f: &Fix, bw: f64) -> SchedEnv {
        SchedEnv::bootstrap(
            &f.cluster,
            &f.profiles,
            &f.pipelines,
            vec![bw; f.cluster.devices.len()],
        )
    }

    #[test]
    fn respects_slo_guard() {
        let f = fixture(3);
        let e = env(&f, 100.0);
        let results = cwd(&e, &CwdParams::default());
        for (p, r) in results.iter().enumerate() {
            let lat = est_latency(&e, p, &r.cfg);
            assert!(
                lat <= e.pipelines[p].slo_ms * 0.5 + 1e-6,
                "pipeline {p}: est latency {lat} > SLO/2"
            );
        }
    }

    #[test]
    fn batches_grow_beyond_one() {
        let f = fixture(3);
        let e = env(&f, 100.0);
        let results = cwd(&e, &CwdParams::default());
        let any_batched = results
            .iter()
            .flat_map(|r| r.cfg.iter())
            .any(|c| c.batch > 1);
        assert!(any_batched, "greedy exploration never increased a batch");
    }

    #[test]
    fn batch_sizes_are_powers_of_two_in_range() {
        let f = fixture(5);
        let e = env(&f, 50.0);
        for r in cwd(&e, &CwdParams::default()) {
            for c in &r.cfg {
                assert!(BATCH_SIZES.contains(&c.batch), "batch {}", c.batch);
                assert!(c.instances >= 1);
            }
        }
    }

    #[test]
    fn weak_network_pushes_detector_to_edge() {
        let f = fixture(3);
        // Starved uplink: sending raw frames to the server is hopeless.
        let e = env(&f, 3.0);
        let results = cwd(&e, &CwdParams::default());
        for (p, r) in results.iter().enumerate() {
            let src = e.pipelines[p].source_device;
            assert_eq!(
                r.cfg[0].device, src,
                "pipeline {p}: detector must move to its edge device"
            );
        }
    }

    #[test]
    fn rich_network_keeps_split_minimal() {
        let f = fixture(3);
        let e = env(&f, 10_000.0);
        for (p, r) in cwd(&e, &CwdParams::default()).iter().enumerate() {
            // Count device changes along upstream->downstream edges.
            let dag = &e.pipelines[p];
            let mut splits = 0;
            for m in 0..dag.len() {
                if let Some(u) = dag.upstream(m) {
                    if r.cfg[u].device != r.cfg[m].device {
                        splits += 1;
                    }
                }
            }
            assert!(splits <= 2, "pipeline {p} has {splits} splits");
        }
    }

    #[test]
    fn server_only_ablation_stays_on_server() {
        let f = fixture(3);
        let e = env(&f, 3.0); // even under weak network
        let params = CwdParams { server_only: true, ..Default::default() };
        for r in cwd(&e, &params) {
            assert!(r.cfg.iter().all(|c| c.device == 0));
        }
    }

    #[test]
    fn static_batch_ablation_pins_batches() {
        let f = fixture(2);
        let e = env(&f, 100.0);
        let params = CwdParams {
            static_batch: Some((4, 8, 2)),
            ..Default::default()
        };
        for r in cwd(&e, &params) {
            assert_eq!(r.cfg[0].batch, 2); // detector
            for c in &r.cfg[1..] {
                assert!(c.batch == 8 || c.batch == 4);
            }
        }
    }

    #[test]
    fn deterministic() {
        let f = fixture(4);
        let e = env(&f, 25.0);
        let a = cwd(&e, &CwdParams::default());
        let b = cwd(&e, &CwdParams::default());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.cfg, y.cfg);
        }
    }

    #[test]
    fn subset_replans_only_the_targets() {
        let f = fixture(3);
        let e = env(&f, 100.0);
        let full = cwd(&e, &CwdParams::default());
        // Re-plan pipeline 1 with the others held as committed load: the
        // subset must cover exactly the target, and under identical
        // observations reproduce the full run's config (determinism of
        // the greedy search given the same feasibility context).
        let kept: Vec<(usize, Vec<StageCfg>)> = [0usize, 2]
            .iter()
            .map(|&p| (p, full[p].cfg.clone()))
            .collect();
        let subset = cwd_subset(&e, &CwdParams::default(), &[1], &kept);
        assert_eq!(subset.len(), 1);
        assert_eq!(subset[0].0, 1);
        assert_eq!(subset[0].1.len(), e.pipelines[1].len());
        for c in &subset[0].1 {
            assert!(BATCH_SIZES.contains(&c.batch));
            assert!(c.instances >= 1);
        }
    }

    /// A single workspace reused across rounds (and across different
    /// environments) must not leak state between them.
    #[test]
    fn workspace_reuse_matches_fresh_workspace() {
        let f = fixture(4);
        let params = CwdParams::default();
        let mut shared = PlannerWorkspace::new();
        for &bw in &[3.0, 100.0, 10_000.0, 25.0] {
            let e = env(&f, bw);
            let mut reused = Vec::new();
            cwd_ws(&e, &params, &mut shared, &mut reused);
            let mut fresh = Vec::new();
            cwd_ws(&e, &params, &mut PlannerWorkspace::new(), &mut fresh);
            assert_eq!(reused, fresh, "bw {bw}: reused workspace diverged");
        }
    }
}
