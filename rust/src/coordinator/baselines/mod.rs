//! The three SOTA baselines the paper evaluates against (§IV-A4), each
//! implemented on the same substrate and given the paper's tuned settings:
//!
//! - [`distream`]: workload-adaptive split point, static batches
//!   (4 edge / 8 server / 2 detector), lazy late-dropping.
//! - [`jellyfish`]: centralized serving with detector-version selection by
//!   network latency (DP) and per-version dynamic batching.
//! - [`rim`]: maximize edge placement / concurrent execution, static
//!   batches, lazy late-dropping.
//!
//! None performs temporal GPU scheduling; all receive the same best-fit
//! spatial GPU spreader ([`bestfit`]) the paper grants them.

pub mod bestfit;
pub mod distream;
pub mod jellyfish;
pub mod rim;

pub use distream::Distream;
pub use jellyfish::Jellyfish;
pub use rim::Rim;

/// Static batch sizes the paper tunes for Distream and Rim (§IV-A4).
pub const STATIC_EDGE_BATCH: u32 = 4;
pub const STATIC_SERVER_BATCH: u32 = 8;
pub const STATIC_DETECTOR_BATCH: u32 = 2;
