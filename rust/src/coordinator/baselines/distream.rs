//! Distream baseline (Zeng et al., SenSys'20) as the paper implements it
//! (§IV-A4): workload-adaptive *split point* between the edge device and
//! the server, found by stochastic local search balancing edge load against
//! edge capacity; static batch sizes (4 edge / 8 server / 2 detector);
//! lazy dropping of late requests (granted by the paper).

use super::{STATIC_DETECTOR_BATCH, STATIC_EDGE_BATCH, STATIC_SERVER_BATCH};
use super::bestfit::spread;
use crate::coordinator::types::{Plan, SchedEnv, Scheduler, StageCfg};
use crate::util::Rng;

pub struct Distream {
    rng: Rng,
    /// Current split per pipeline (stages < split run on the edge).
    splits: Vec<usize>,
}

impl Distream {
    pub fn new(seed: u64) -> Distream {
        Distream { rng: Rng::new(seed), splits: Vec::new() }
    }

    /// Edge compute load (normalized busy fraction) if stages [0, split)
    /// run on the pipeline's edge device at the static batches.
    fn edge_load(&self, env: &SchedEnv, p: usize, split: usize) -> f64 {
        let dag = &env.pipelines[p];
        let class = env.cluster.device(dag.source_device).class;
        (0..split)
            .map(|m| {
                let spec = &dag.models[m].spec;
                let bz = if m == 0 { STATIC_DETECTOR_BATCH } else { STATIC_EDGE_BATCH };
                let cap = env.profiles.curve(spec, class).throughput(bz);
                env.rate(p, m) / cap.max(1e-9)
            })
            .sum()
    }

    /// Distream's balance objective: edge busy fraction should sit near a
    /// target utilization (workload-adaptive partitioning).
    fn objective(&self, env: &SchedEnv, p: usize, split: usize) -> f64 {
        const TARGET: f64 = 0.75;
        (self.edge_load(env, p, split) - TARGET).abs()
    }
}

impl Scheduler for Distream {
    fn name(&self) -> &'static str {
        "distream"
    }

    fn plan(&mut self, env: &SchedEnv) -> Plan {
        if self.splits.len() != env.pipelines.len() {
            self.splits = vec![1; env.pipelines.len()];
        }
        let mut cfgs = Vec::new();
        for p in 0..env.pipelines.len() {
            let dag = &env.pipelines[p];
            // Stochastic local search over the split point: evaluate the
            // current split and a random neighbor, keep the better; with
            // small probability take the neighbor anyway (exploration).
            let cur = self.splits[p].min(dag.len());
            let neighbor = if self.rng.chance(0.5) {
                (cur + 1).min(dag.len())
            } else {
                cur.saturating_sub(1)
            };
            let (oc, on) =
                (self.objective(env, p, cur), self.objective(env, p, neighbor));
            let chosen = if on < oc || self.rng.chance(0.1) { neighbor } else { cur };
            self.splits[p] = chosen;

            let cfg: Vec<StageCfg> = (0..dag.len())
                .map(|m| {
                    let on_edge = m < chosen && dag.source_device != 0;
                    let device = if on_edge { dag.source_device } else { 0 };
                    let batch = if m == 0 {
                        STATIC_DETECTOR_BATCH
                    } else if on_edge {
                        STATIC_EDGE_BATCH
                    } else {
                        STATIC_SERVER_BATCH
                    };
                    let class = env.cluster.device(device).class;
                    let spec = &dag.models[m].spec;
                    let cap = env.profiles.curve(spec, class).throughput(batch);
                    let instances =
                        ((env.rate(p, m) / cap.max(1e-9)).ceil() as u32).clamp(1, 16);
                    StageCfg { device, batch, instances }
                })
                .collect();
            cfgs.push(cfg);
        }
        spread(env, &cfgs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::pipeline::standard_pipelines;
    use crate::profiles::ProfileStore;

    fn env_fixture() -> (Cluster, ProfileStore, Vec<crate::pipeline::PipelineDag>) {
        let pipelines = standard_pipelines(3)
            .into_iter()
            .map(|mut p| {
                p.source_device += 1;
                p
            })
            .collect();
        (Cluster::paper_testbed(), ProfileStore::analytic(), pipelines)
    }

    #[test]
    fn static_batches_enforced() {
        let (cl, pf, pl) = env_fixture();
        let env = SchedEnv::bootstrap(&cl, &pf, &pl, vec![80.0; 10]);
        let plan = Distream::new(1).plan(&env);
        for a in &plan.assignments {
            let expect = if a.model == 0 {
                STATIC_DETECTOR_BATCH
            } else if a.cfg.device != 0 {
                STATIC_EDGE_BATCH
            } else {
                STATIC_SERVER_BATCH
            };
            assert_eq!(a.cfg.batch, expect);
        }
    }

    #[test]
    fn split_moves_with_workload() {
        let (cl, pf, mut pl) = env_fixture();
        // Tiny workload -> split should drift edge-ward over rounds.
        for p in pl.iter_mut() {
            p.source_fps = 2.0;
        }
        let env = SchedEnv::bootstrap(&cl, &pf, &pl, vec![80.0; 10]);
        let mut ds = Distream::new(2);
        let mut last_edge_stages = 0;
        for _ in 0..30 {
            let plan = ds.plan(&env);
            last_edge_stages = plan
                .assignments
                .iter()
                .filter(|a| a.cfg.device != 0)
                .count();
        }
        assert!(last_edge_stages > 0, "Distream never offloaded to edge");
    }

    #[test]
    fn no_temporal_scheduling() {
        let (cl, pf, pl) = env_fixture();
        let env = SchedEnv::bootstrap(&cl, &pf, &pl, vec![80.0; 10]);
        let plan = Distream::new(3).plan(&env);
        assert!(plan
            .assignments
            .iter()
            .all(|a| a.bindings.iter().all(|b| b.temporal.is_none())));
    }
}
