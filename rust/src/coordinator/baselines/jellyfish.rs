//! Jellyfish baseline (Nigade et al., RTSS'22) as the paper implements it
//! (§IV-A4): fully centralized — every model runs on the server; raw
//! (resized) frames always cross the network. Adapts to network latency by
//! picking among detector *versions* (resolutions) via a DP over the
//! latency budget, and dynamically batches each version. Downstream models
//! get static batch 8, one instance per detector version.

use super::STATIC_SERVER_BATCH;
use super::bestfit::spread;
use crate::coordinator::estimator::transfer_latency;
use crate::coordinator::types::{Plan, SchedEnv, Scheduler, StageCfg};
use crate::pipeline::ModelKind;
use crate::profiles::BATCH_SIZES;

pub struct Jellyfish;

impl Jellyfish {
    pub fn new() -> Jellyfish {
        Jellyfish
    }

    /// Jellyfish's DP reduced to our 3-version ladder: pick the largest
    /// detector variant + batch whose (transfer + batch exec + fill) fits
    /// the latency budget; degrade resolution as bandwidth drops.
    fn pick_version_and_batch(env: &SchedEnv, p: usize) -> (usize, u32) {
        let dag = &env.pipelines[p];
        let budget = dag.slo_ms * 0.6; // detector's share of the SLO
        let rate = env.rate(p, 0).max(0.01);
        // Try large -> small variants, big -> small batches.
        for variant in (0..3usize).rev() {
            // Input bytes scale with the variant's stream resolution.
            let bytes = 80_000.0 + 30_000.0 * variant as f64;
            let tx = transfer_latency(env, dag.source_device, 0, bytes, rate);
            let mut spec = dag.models[0].spec.clone();
            spec.variant = variant;
            let class = env.cluster.device(0).class;
            for &bz in BATCH_SIZES.iter().rev() {
                let fill = (bz - 1) as f64 * 1000.0 / rate;
                let exec = env.profiles.batch_latency(&spec, class, bz);
                if tx + fill + exec <= budget {
                    return (variant, bz);
                }
            }
        }
        (0, 1) // worst case: smallest version, no batching
    }
}

impl Default for Jellyfish {
    fn default() -> Self {
        Jellyfish::new()
    }
}

impl Scheduler for Jellyfish {
    fn name(&self) -> &'static str {
        "jellyfish"
    }

    fn plan(&mut self, env: &SchedEnv) -> Plan {
        let mut cfgs = Vec::new();
        for p in 0..env.pipelines.len() {
            let dag = &env.pipelines[p];
            let (variant, det_bz) = Self::pick_version_and_batch(env, p);
            let cfg: Vec<StageCfg> = (0..dag.len())
                .map(|m| {
                    let spec = &dag.models[m].spec;
                    let batch = if spec.kind == ModelKind::Detector {
                        det_bz
                    } else {
                        STATIC_SERVER_BATCH
                    };
                    let mut eff_spec = spec.clone();
                    if eff_spec.kind == ModelKind::Detector {
                        eff_spec.variant = variant;
                    }
                    let class = env.cluster.device(0).class;
                    let cap =
                        env.profiles.curve(&eff_spec, class).throughput(batch);
                    let instances =
                        ((env.rate(p, m) / cap.max(1e-9)).ceil() as u32).clamp(1, 16);
                    StageCfg { device: 0, batch, instances }
                })
                .collect();
            cfgs.push(cfg);
        }
        spread(env, &cfgs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::pipeline::standard_pipelines;
    use crate::profiles::ProfileStore;

    fn fixture() -> (Cluster, ProfileStore, Vec<crate::pipeline::PipelineDag>) {
        let pipelines = standard_pipelines(3)
            .into_iter()
            .map(|mut p| {
                p.source_device += 1;
                p
            })
            .collect();
        (Cluster::paper_testbed(), ProfileStore::analytic(), pipelines)
    }

    #[test]
    fn everything_on_server() {
        let (cl, pf, pl) = fixture();
        let env = SchedEnv::bootstrap(&cl, &pf, &pl, vec![80.0; 10]);
        let plan = Jellyfish::new().plan(&env);
        assert!(plan.assignments.iter().all(|a| a.cfg.device == 0));
    }

    #[test]
    fn degrades_version_under_weak_network() {
        let (cl, pf, pl) = fixture();
        let rich = SchedEnv::bootstrap(&cl, &pf, &pl, vec![500.0; 10]);
        let poor = SchedEnv::bootstrap(&cl, &pf, &pl, vec![4.0; 10]);
        let (v_rich, _) = Jellyfish::pick_version_and_batch(&rich, 0);
        let (v_poor, _) = Jellyfish::pick_version_and_batch(&poor, 0);
        assert!(
            v_poor <= v_rich,
            "poor network must not pick a larger version ({v_poor} > {v_rich})"
        );
    }

    #[test]
    fn detector_batch_adapts_to_rate() {
        let (cl, pf, mut pl) = fixture();
        for p in pl.iter_mut() {
            p.source_fps = 60.0; // heavy rate -> larger batch pays off
        }
        let env = SchedEnv::bootstrap(&cl, &pf, &pl, vec![500.0; 10]);
        let (_, bz_hi) = Jellyfish::pick_version_and_batch(&env, 0);
        assert!(bz_hi >= 2, "high rate should allow batching, got {bz_hi}");
    }
}
