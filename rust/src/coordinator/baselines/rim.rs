//! Rim baseline (Hu et al., IoTDI'21) as the paper implements it (§IV-A4):
//! offload as much of the pipeline as possible to the edge, maximizing
//! concurrent model execution / hardware utilization; static batches; no
//! temporal scheduling (the paper notes Rim amplifies co-location
//! interference at the edge, and its latency is the worst — Fig. 6b).

use super::{STATIC_DETECTOR_BATCH, STATIC_EDGE_BATCH, STATIC_SERVER_BATCH};
use super::bestfit::spread;
use crate::coordinator::estimator::stage_memory_mb;
use crate::coordinator::types::{Plan, SchedEnv, Scheduler, StageCfg};

pub struct Rim;

impl Rim {
    pub fn new() -> Rim {
        Rim
    }
}

impl Default for Rim {
    fn default() -> Self {
        Rim::new()
    }
}

impl Scheduler for Rim {
    fn name(&self) -> &'static str {
        "rim"
    }

    fn plan(&mut self, env: &SchedEnv) -> Plan {
        // Per-device running memory use, so edge stuffing stops at capacity.
        let mut edge_mem_left: Vec<f64> = env
            .cluster
            .devices
            .iter()
            .map(|d| d.gpus.iter().map(|g| g.mem_mb).sum::<f64>())
            .collect();

        let mut cfgs = Vec::new();
        for p in 0..env.pipelines.len() {
            let dag = &env.pipelines[p];
            let edge = dag.source_device;
            let cfg: Vec<StageCfg> = (0..dag.len())
                .map(|m| {
                    let batch = if m == 0 {
                        STATIC_DETECTOR_BATCH
                    } else {
                        STATIC_EDGE_BATCH
                    };
                    // Greedily keep the stage at the edge while memory
                    // lasts (maximize edge concurrency).
                    let try_edge = StageCfg {
                        device: edge,
                        batch,
                        instances: 1,
                    };
                    let mem = stage_memory_mb(env, p, m, try_edge);
                    if edge != 0 && mem <= edge_mem_left[edge] {
                        edge_mem_left[edge] -= mem;
                        let class = env.cluster.device(edge).class;
                        let spec = &dag.models[m].spec;
                        let cap =
                            env.profiles.curve(spec, class).throughput(batch);
                        let instances = ((env.rate(p, m) / cap.max(1e-9)).ceil()
                            as u32)
                            .clamp(1, 4); // edge devices can't host many
                        StageCfg { device: edge, batch, instances }
                    } else {
                        let batch = if m == 0 {
                            STATIC_DETECTOR_BATCH
                        } else {
                            STATIC_SERVER_BATCH
                        };
                        let class = env.cluster.device(0).class;
                        let spec = &dag.models[m].spec;
                        let cap =
                            env.profiles.curve(spec, class).throughput(batch);
                        let instances = ((env.rate(p, m) / cap.max(1e-9)).ceil()
                            as u32)
                            .clamp(1, 16);
                        StageCfg { device: 0, batch, instances }
                    }
                })
                .collect();
            cfgs.push(cfg);
        }
        spread(env, &cfgs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::pipeline::standard_pipelines;
    use crate::profiles::ProfileStore;

    fn fixture() -> (Cluster, ProfileStore, Vec<crate::pipeline::PipelineDag>) {
        let pipelines = standard_pipelines(3)
            .into_iter()
            .map(|mut p| {
                p.source_device += 1;
                p
            })
            .collect();
        (Cluster::paper_testbed(), ProfileStore::analytic(), pipelines)
    }

    #[test]
    fn maximizes_edge_placement() {
        let (cl, pf, pl) = fixture();
        let env = SchedEnv::bootstrap(&cl, &pf, &pl, vec![80.0; 10]);
        let plan = Rim::new().plan(&env);
        let edge_stages =
            plan.assignments.iter().filter(|a| a.cfg.device != 0).count();
        let total = plan.assignments.len();
        assert!(
            edge_stages * 2 > total,
            "Rim should push most stages edge-ward: {edge_stages}/{total}"
        );
    }

    #[test]
    fn respects_edge_memory() {
        let (cl, pf, pl) = fixture();
        let env = SchedEnv::bootstrap(&cl, &pf, &pl, vec![80.0; 10]);
        let plan = Rim::new().plan(&env);
        // Recompute per-device memory and compare with capacity.
        for d in env.cluster.devices.iter().skip(1) {
            let used: f64 = plan
                .assignments
                .iter()
                .filter(|a| a.cfg.device == d.id)
                .map(|a| {
                    let spec = &pl[a.pipeline].models[a.model].spec;
                    a.cfg.instances as f64 * spec.memory_mb(a.cfg.batch)
                })
                .sum();
            let cap: f64 = d.gpus.iter().map(|g| g.mem_mb).sum();
            assert!(used <= cap + 1e-6, "device {} over memory", d.id);
        }
    }
}
