//! Spatial best-fit GPU spreader: the paper equips all baselines (which
//! have no GPU scheduling of their own) with a best-fit algorithm that
//! spreads models across GPUs by resource consumption (§IV-A4). No
//! temporal dimension — bindings carry `temporal: None`, so the simulator
//! applies co-location interference when executions overlap.

use std::collections::HashMap;

use crate::coordinator::types::{
    Assignment, GpuBinding, GpuId, Plan, SchedEnv, StageCfg,
};

/// Spread every instance across its device's GPUs, least-loaded first.
pub fn spread(env: &SchedEnv, cfgs: &[Vec<StageCfg>]) -> Plan {
    // Track (memory, util) load per GPU.
    let mut load: HashMap<GpuId, (f64, f64)> = HashMap::new();
    for d in &env.cluster.devices {
        for gi in 0..d.gpus.len() {
            load.insert(GpuId { device: d.id, gpu: gi }, (0.0, 0.0));
        }
    }

    let mut assignments = Vec::new();
    for (p, cfg) in cfgs.iter().enumerate() {
        for (m, &c) in cfg.iter().enumerate() {
            let spec = &env.pipelines[p].models[m].spec;
            let mut bindings = Vec::new();
            for _ in 0..c.instances {
                // Least-loaded GPU of the device by memory, then util.
                let gpu = env
                    .cluster
                    .device(c.device)
                    .gpus
                    .iter()
                    .enumerate()
                    .map(|(gi, _)| GpuId { device: c.device, gpu: gi })
                    .min_by(|a, b| {
                        let (ma, ua) = load[a];
                        let (mb, ub) = load[b];
                        (ma + 1000.0 * ua)
                            .partial_cmp(&(mb + 1000.0 * ub))
                            .unwrap()
                    })
                    .expect("device has at least one GPU");
                let e = load.get_mut(&gpu).unwrap();
                e.0 += spec.memory_mb(c.batch);
                e.1 += spec.util_width;
                bindings.push(GpuBinding {
                    gpu,
                    width: spec.util_width,
                    temporal: None,
                });
            }
            assignments.push(Assignment { pipeline: p, model: m, cfg: c, bindings });
        }
    }
    Plan { assignments, unplaced: 0 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::pipeline::standard_pipelines;
    use crate::profiles::ProfileStore;

    #[test]
    fn spreads_across_server_gpus() {
        let cluster = Cluster::paper_testbed();
        let profiles = ProfileStore::analytic();
        let pipelines = standard_pipelines(4);
        let env =
            SchedEnv::bootstrap(&cluster, &profiles, &pipelines, vec![100.0; 10]);
        let cfgs: Vec<Vec<StageCfg>> = (0..4)
            .map(|_| {
                vec![StageCfg { device: 0, batch: 8, instances: 2 }; 3]
            })
            .collect();
        let plan = spread(&env, &cfgs);
        let gpus_used: std::collections::HashSet<GpuId> = plan
            .assignments
            .iter()
            .flat_map(|a| a.bindings.iter().map(|b| b.gpu))
            .collect();
        assert!(gpus_used.len() >= 4, "used {} GPUs", gpus_used.len());
        // All spatial-only.
        assert!(plan
            .assignments
            .iter()
            .all(|a| a.bindings.iter().all(|b| b.temporal.is_none())));
    }
}
