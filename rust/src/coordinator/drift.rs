//! Drift detection for incremental replanning.
//!
//! The paper's control plane replans on a fixed clock (§IV-A5: 6 min),
//! which leaves a stale plan in place for up to a full period when the
//! workload or the network moves — exactly the regimes the scenario
//! fuzzer stresses (flash crowds, bandwidth blackouts, device churn).
//! The adaptive edge-serving literature (arXiv 2304.09961, EdgeVision
//! arXiv 2211.03102) reacts to such drift at the *scheduling* layer, not
//! just the scaling layer; this module supplies the trigger.
//!
//! At plan-install time the engine captures a [`PlanEnvelope`]: the
//! per-(pipeline, model) request rates the plan was sized for, the
//! per-link bandwidth snapshot it assumed, and the transfer budget its
//! cross-device hops require (ToEdge's traffic commitment). A
//! [`DriftDetector`] then compares live observations against that
//! envelope on a short cadence and names the pipelines whose assumptions
//! broke; the controller replans *only those* (CWD subset + CORAL
//! repair) while untouched pipelines keep their reservations and clocks.

use super::types::{ModelObs, Plan};
use crate::pipeline::PipelineDag;
use crate::Ms;

/// When the control plane replans.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReplanMode {
    /// Full CWD+CORAL rounds on the fixed scheduling period only.
    Periodic,
    /// Periodic rounds *plus* drift-triggered incremental replans of the
    /// drifted pipelines between rounds.
    Drift,
}

impl Default for ReplanMode {
    fn default() -> Self {
        ReplanMode::Periodic
    }
}

impl ReplanMode {
    pub fn label(&self) -> &'static str {
        match self {
            ReplanMode::Periodic => "periodic",
            ReplanMode::Drift => "drift",
        }
    }

    pub fn parse(s: &str) -> Option<ReplanMode> {
        Some(match s {
            "periodic" | "fixed" => ReplanMode::Periodic,
            "drift" => ReplanMode::Drift,
            _ => return None,
        })
    }
}

/// The envelope a plan is considered valid within (the drift knobs).
#[derive(Clone, Copy, Debug)]
pub struct DriftParams {
    /// Relative band around the planned rate: |observed - planned| beyond
    /// `rate_band * planned` flags the stage as drifted.
    pub rate_band: f64,
    /// Rates below this floor (both planned and observed) are noise and
    /// never trigger.
    pub min_rate_qps: f64,
    /// A watched link whose bandwidth moved by more than this factor in
    /// either direction (vs the plan-time snapshot) is drifted. Must sit
    /// well above the traces' natural per-second jitter.
    pub bw_change_ratio: f64,
    /// A link that drops below this fraction of the plan's transfer
    /// budget (min of required and plan-time bandwidth) is drifted.
    pub bw_budget_frac: f64,
    /// Cadence of `Ev::DriftCheck` in the engine.
    pub check_period_ms: Ms,
    /// Minimum spacing between drift-triggered replans (hysteresis).
    pub cooldown_ms: Ms,
}

impl Default for DriftParams {
    fn default() -> Self {
        DriftParams {
            rate_band: 0.35,
            min_rate_qps: 1.0,
            bw_change_ratio: 4.0,
            bw_budget_frac: 0.6,
            check_period_ms: 5_000.0,
            cooldown_ms: 15_000.0,
        }
    }
}

/// Why a pipeline was flagged (reporting / debug).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DriftKind {
    /// A stage's observed rate left the planned-rate band.
    Rate,
    /// A watched link collapsed below the plan's transfer budget or moved
    /// by more than the change ratio.
    Bandwidth,
}

/// One drifted pipeline and the dominant reason.
#[derive(Clone, Copy, Debug)]
pub struct DriftEvent {
    pub pipeline: usize,
    pub kind: DriftKind,
}

/// Workload/network assumptions captured when a plan is installed.
#[derive(Clone, Debug, Default)]
pub struct PlanEnvelope {
    /// Rate (qps) each (pipeline, model) was planned for.
    planned_rate: Vec<Vec<f64>>,
    /// Bandwidth snapshot (Mbit/s per device) at plan time.
    planned_bw: Vec<f64>,
    /// Mbit/s the plan's cross-device hops commit per device (ToEdge's
    /// transfer budget; 0 for links the plan never crosses).
    required_bw: Vec<f64>,
    /// Devices each pipeline's health depends on: its source device plus
    /// every device its plan crosses a link of. Recovery of a dark source
    /// link is drift too — the pipeline may deserve a better placement.
    watched: Vec<Vec<usize>>,
}

impl PlanEnvelope {
    /// Capture the envelope of `plan` given the observations and the
    /// bandwidth snapshot the scheduler planned against.
    pub fn capture(
        plan: &Plan,
        pipelines: &[PipelineDag],
        obs: &[Vec<ModelObs>],
        bw: &[f64],
    ) -> PlanEnvelope {
        let mut e = PlanEnvelope::default();
        e.capture_into(plan, pipelines, obs, bw);
        e
    }

    /// Fill this envelope from `plan` in place — the buffer-reusing twin
    /// of [`Self::capture`]. The engine recycles one envelope across
    /// replans (via [`DriftDetector::rearm`]) so steady-state drift
    /// rounds stop allocating envelope rows.
    pub fn capture_into(
        &mut self,
        plan: &Plan,
        pipelines: &[PipelineDag],
        obs: &[Vec<ModelObs>],
        bw: &[f64],
    ) {
        self.planned_rate.resize_with(obs.len(), Vec::new);
        for (row, o) in self.planned_rate.iter_mut().zip(obs) {
            row.clear();
            row.extend(o.iter().map(|o| o.rate_qps));
        }
        self.planned_bw.clear();
        self.planned_bw.extend_from_slice(bw);
        self.required_bw.clear();
        self.required_bw.resize(bw.len(), 0.0);
        self.watched.resize_with(pipelines.len(), Vec::new);
        // (from, to, model) hop scratch, hoisted out of the pipeline loop.
        let mut hops: Vec<(usize, usize, usize)> = Vec::new();
        for (p, dag) in pipelines.iter().enumerate() {
            let device_of = |m: usize| {
                plan.assignment(p, m).map(|a| a.cfg.device).unwrap_or(0)
            };
            let links = &mut self.watched[p];
            links.clear();
            if dag.source_device != 0 {
                links.push(dag.source_device);
            }
            // Source -> detector hop.
            hops.clear();
            hops.push((dag.source_device, device_of(0), 0));
            for m in 0..dag.len() {
                if let Some(u) = dag.upstream(m) {
                    hops.push((device_of(u), device_of(m), m));
                }
            }
            for &(from, to, m) in &hops {
                if from == to {
                    continue;
                }
                // Star topology: cross-device traffic rides the edge
                // endpoint's uplink (see `estimator::transfer_latency`).
                let edge = if from == 0 { to } else { from };
                let rate = obs
                    .get(p)
                    .and_then(|row| row.get(m))
                    .map(|o| o.rate_qps)
                    .unwrap_or(0.0);
                let bytes = dag.models[m].spec.input_bytes;
                if let Some(slot) = self.required_bw.get_mut(edge) {
                    *slot += rate * bytes * 8.0 / 1e6;
                }
                if !links.contains(&edge) {
                    links.push(edge);
                }
            }
            links.sort_unstable();
        }
    }

    /// Pipelines whose live observations left the envelope, sorted and
    /// deduplicated (at most one event per pipeline; rate drift wins the
    /// label when both fire).
    pub fn drifted(
        &self,
        obs: &[Vec<ModelObs>],
        bw: &[f64],
        params: &DriftParams,
    ) -> Vec<DriftEvent> {
        let mut out: Vec<DriftEvent> = Vec::new();
        for (p, planned_row) in self.planned_rate.iter().enumerate() {
            let Some(obs_row) = obs.get(p) else { continue };
            let rate_drift = planned_row.iter().zip(obs_row).any(|(&planned, o)| {
                let seen = o.rate_qps;
                planned.max(seen) >= params.min_rate_qps
                    && (seen - planned).abs()
                        > params.rate_band * planned.max(params.min_rate_qps)
            });
            let bw_drift = !rate_drift
                && self.watched.get(p).is_some_and(|links| {
                    links.iter().any(|&d| {
                        let now = bw.get(d).copied().unwrap_or(0.0);
                        let planned = self.planned_bw.get(d).copied().unwrap_or(0.0);
                        // Budget breach: the link can no longer carry what
                        // the plan routes over it (and could at plan time).
                        let required =
                            self.required_bw.get(d).copied().unwrap_or(0.0);
                        let budget = required.min(planned).max(0.0);
                        let breached =
                            budget > 0.5 && now < params.bw_budget_frac * budget;
                        // Regime change: collapse or recovery beyond the
                        // change ratio (dark links use a 0.5 Mbit/s floor
                        // so recovery from zero still registers).
                        let base = planned.max(0.5);
                        let moved = now > base * params.bw_change_ratio
                            || now < base / params.bw_change_ratio;
                        breached || moved
                    })
                });
            if rate_drift {
                out.push(DriftEvent { pipeline: p, kind: DriftKind::Rate });
            } else if bw_drift {
                out.push(DriftEvent { pipeline: p, kind: DriftKind::Bandwidth });
            }
        }
        out
    }
}

/// Stateful detector the engine drives on every `Ev::DriftCheck`.
#[derive(Clone, Debug)]
pub struct DriftDetector {
    pub params: DriftParams,
    envelope: Option<PlanEnvelope>,
    last_trigger_ms: Ms,
}

impl DriftDetector {
    pub fn new(params: DriftParams) -> DriftDetector {
        DriftDetector { params, envelope: None, last_trigger_ms: f64::NEG_INFINITY }
    }

    /// Install the envelope of the plan that just went live.
    pub fn arm(&mut self, envelope: PlanEnvelope) {
        self.envelope = Some(envelope);
    }

    /// Capture-and-arm in place: recompute the armed envelope for a
    /// just-installed plan, reusing the previous envelope's buffers.
    /// Equivalent to `arm(PlanEnvelope::capture(..))` without the
    /// allocations.
    pub fn rearm(
        &mut self,
        plan: &Plan,
        pipelines: &[PipelineDag],
        obs: &[Vec<ModelObs>],
        bw: &[f64],
    ) {
        self.envelope
            .get_or_insert_with(PlanEnvelope::default)
            .capture_into(plan, pipelines, obs, bw);
    }

    /// Check live observations; returns the sorted drifted pipeline ids
    /// (empty within the cooldown or while no envelope is armed). A
    /// non-empty return consumes the cooldown.
    pub fn check(&mut self, now_ms: Ms, obs: &[Vec<ModelObs>], bw: &[f64]) -> Vec<usize> {
        if now_ms - self.last_trigger_ms < self.params.cooldown_ms {
            return Vec::new();
        }
        let Some(env) = &self.envelope else { return Vec::new() };
        let events = env.drifted(obs, bw, &self.params);
        if events.is_empty() {
            return Vec::new();
        }
        self.last_trigger_ms = now_ms;
        events.iter().map(|e| e.pipeline).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::coordinator::controller::Controller;
    use crate::coordinator::{Scheduler, SchedEnv, SchedulerKind};
    use crate::pipeline::standard_pipelines;
    use crate::profiles::ProfileStore;

    fn fixture() -> (Cluster, ProfileStore, Vec<PipelineDag>) {
        let pipelines = standard_pipelines(3)
            .into_iter()
            .map(|mut p| {
                p.source_device += 1;
                p
            })
            .collect();
        (Cluster::paper_testbed(), ProfileStore::analytic(), pipelines)
    }

    fn captured() -> (PlanEnvelope, Vec<Vec<ModelObs>>, Vec<f64>) {
        let (cl, pf, pl) = fixture();
        let env = SchedEnv::bootstrap(&cl, &pf, &pl, vec![80.0; cl.devices.len()]);
        let plan = Controller::new(SchedulerKind::OctopInf).plan(&env);
        let e = PlanEnvelope::capture(&plan, &pl, &env.obs, &env.bw_mbps);
        (e, env.obs, env.bw_mbps)
    }

    #[test]
    fn replan_mode_parses() {
        assert_eq!(ReplanMode::parse("drift"), Some(ReplanMode::Drift));
        assert_eq!(ReplanMode::parse("periodic"), Some(ReplanMode::Periodic));
        assert_eq!(ReplanMode::parse("bogus"), None);
        assert_eq!(ReplanMode::Drift.label(), "drift");
    }

    #[test]
    fn steady_state_does_not_drift() {
        let (e, obs, bw) = captured();
        assert!(e.drifted(&obs, &bw, &DriftParams::default()).is_empty());
    }

    #[test]
    fn rate_surge_flags_the_surging_pipeline_only() {
        let (e, mut obs, bw) = captured();
        for o in obs[1].iter_mut() {
            o.rate_qps *= 3.0; // flash crowd on pipeline 1
        }
        let events = e.drifted(&obs, &bw, &DriftParams::default());
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].pipeline, 1);
        assert_eq!(events[0].kind, DriftKind::Rate);
    }

    #[test]
    fn rate_collapse_also_drifts() {
        let (e, mut obs, bw) = captured();
        for o in obs[0].iter_mut() {
            o.rate_qps *= 0.2;
        }
        let events = e.drifted(&obs, &bw, &DriftParams::default());
        assert!(events.iter().any(|ev| ev.pipeline == 0));
    }

    #[test]
    fn blackout_on_source_link_drifts_its_pipeline() {
        let (e, obs, mut bw) = captured();
        // Pipeline 0 sources on device 1.
        bw[1] = 0.0;
        let events = e.drifted(&obs, &bw, &DriftParams::default());
        assert!(
            events
                .iter()
                .any(|ev| ev.pipeline == 0 && ev.kind == DriftKind::Bandwidth),
            "{events:?}"
        );
        // Other pipelines (devices 2, 3) stay calm.
        assert!(events.iter().all(|ev| ev.pipeline == 0));
    }

    #[test]
    fn link_recovery_from_dark_drifts() {
        let (cl, pf, pl) = fixture();
        // Plan while device 1 is dark; then the link comes alive.
        let mut bw = vec![80.0; cl.devices.len()];
        bw[1] = 0.0;
        let env = SchedEnv::bootstrap(&cl, &pf, &pl, bw);
        let plan = Controller::new(SchedulerKind::OctopInf).plan(&env);
        let e = PlanEnvelope::capture(&plan, &pl, &env.obs, &env.bw_mbps);
        let mut live = env.bw_mbps.clone();
        live[1] = 25.0;
        let events = e.drifted(&env.obs, &live, &DriftParams::default());
        assert!(events.iter().any(|ev| ev.pipeline == 0), "{events:?}");
    }

    #[test]
    fn ordinary_jitter_stays_inside_the_envelope() {
        let (e, mut obs, mut bw) = captured();
        for row in obs.iter_mut() {
            for o in row.iter_mut() {
                o.rate_qps *= 1.2; // within the ±35% band
            }
        }
        for b in bw.iter_mut() {
            *b *= 0.8; // well inside the 4x change ratio
        }
        assert!(e.drifted(&obs, &bw, &DriftParams::default()).is_empty());
    }

    #[test]
    fn detector_cooldown_suppresses_retriggers() {
        let (e, mut obs, bw) = captured();
        for o in obs[0].iter_mut() {
            o.rate_qps *= 5.0;
        }
        let mut d = DriftDetector::new(DriftParams::default());
        d.arm(e.clone());
        assert_eq!(d.check(5_000.0, &obs, &bw), vec![0]);
        // Still drifted, but inside the cooldown window.
        assert!(d.check(10_000.0, &obs, &bw).is_empty());
        assert_eq!(d.check(25_000.0, &obs, &bw), vec![0]);
    }

    #[test]
    fn capture_into_on_a_dirty_envelope_matches_fresh_capture() {
        let (cl, pf, pl) = fixture();
        let env = SchedEnv::bootstrap(&cl, &pf, &pl, vec![80.0; cl.devices.len()]);
        let plan = Controller::new(SchedulerKind::OctopInf).plan(&env);
        let fresh = PlanEnvelope::capture(&plan, &pl, &env.obs, &env.bw_mbps);
        // Dirty a reused envelope with a different plan/telemetry first.
        let mut bw2 = vec![80.0; cl.devices.len()];
        bw2[1] = 0.0;
        let env2 = SchedEnv::bootstrap(&cl, &pf, &pl, bw2);
        let plan2 = Controller::new(SchedulerKind::OctopInf).plan(&env2);
        let mut reused =
            PlanEnvelope::capture(&plan2, &pl, &env2.obs, &env2.bw_mbps);
        reused.capture_into(&plan, &pl, &env.obs, &env.bw_mbps);
        // Identical drift verdicts on perturbed telemetry: a surge, a
        // blackout, and a calm reading.
        let params = DriftParams::default();
        let mut surge = env.obs.clone();
        for o in surge[1].iter_mut() {
            o.rate_qps *= 3.0;
        }
        let mut dark = env.bw_mbps.clone();
        dark[1] = 0.0;
        for (obs, bw) in [
            (&env.obs, &env.bw_mbps),
            (&surge, &env.bw_mbps),
            (&env.obs, &dark),
        ] {
            let a = fresh.drifted(obs, bw, &params);
            let b = reused.drifted(obs, bw, &params);
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.pipeline, y.pipeline);
                assert_eq!(x.kind, y.kind);
            }
        }
    }

    #[test]
    fn unarmed_detector_never_fires() {
        let (_, obs, bw) = captured();
        let mut d = DriftDetector::new(DriftParams::default());
        assert!(d.check(5_000.0, &obs, &bw).is_empty());
    }
}
