//! Reusable planner state: the allocation story of the control plane.
//!
//! A [`PlannerWorkspace`] is owned by the [`Controller`](super::Controller)
//! and threaded through every CWD/CORAL entry point (`cwd_ws`,
//! `cwd_subset_ws`, `coral_ws`, `coral_repair_ws`). It carries two kinds of
//! state:
//!
//! * **Running aggregates** ([`DeviceLoads`]) — per-device committed memory
//!   and stream-time folds that replace the O(P) rescans the naive planner
//!   performs per batch candidate. Bit-identity with the naive fold is
//!   guaranteed by construction: the aggregate is the *prefix* of the exact
//!   fold sequence the naive code runs (pipelines in commit order, stages
//!   in index order), and per-candidate checks continue that fold over the
//!   current pipeline's stages only. No float is ever re-associated.
//! * **Recycled buffers** — the GPU stream pool ([`GpuPool`]), flat
//!   stage-end table, sort scratch, and config-row pool, all reused across
//!   `Reschedule`/`DriftCheck`/`on_fault` rounds so steady-state replans
//!   allocate nothing beyond their returned `Plan`.
//!
//! The reuse contract is documented on [`PlannerWorkspace`]; the
//! reference-vs-optimized identity proptest (`rust/tests/planner.rs`)
//! exercises a single workspace across many fuzzed environments to prove
//! no state leaks between rounds.

use super::estimator::stage_memory_mb;
use super::stream::GpuStreams;
use super::types::{GpuId, SchedEnv, StageCfg};
use crate::Ms;

/// Per-device committed-load aggregates for CWD's feasibility filters.
///
/// `mem_used[d]` / `time_used[d]` are the exact running folds the naive
/// `device_mem_headroom` / `device_stream_time` scans would produce over
/// every committed pipeline, in the same order. Committing a pipeline is
/// O(stages); evaluating a candidate is O(stages of the current pipeline)
/// instead of O(all scheduled stages).
#[derive(Clone, Debug, Default)]
pub struct DeviceLoads {
    /// Σ gpu.mem_mb per device (same fold order as the naive total).
    mem_total: Vec<f64>,
    /// Committed stage memory per device (prefix of the naive fold).
    mem_used: Vec<f64>,
    /// Committed stream time per device (prefix of the naive fold).
    time_used: Vec<f64>,
    /// Σ gpu.streams per device (integer — exact).
    streams: Vec<usize>,
}

impl DeviceLoads {
    /// Reset for a new planning round over `env`'s cluster.
    pub fn reset(&mut self, env: &SchedEnv) {
        let n = env.cluster.devices.len();
        self.mem_total.clear();
        self.streams.clear();
        for d in &env.cluster.devices {
            self.mem_total.push(d.gpus.iter().map(|g| g.mem_mb).sum());
            self.streams.push(d.gpus.iter().map(|g| g.streams).sum());
        }
        self.mem_used.clear();
        self.mem_used.resize(n, 0.0);
        self.time_used.clear();
        self.time_used.resize(n, 0.0);
    }

    /// Fold one scheduled pipeline into the committed aggregates — the
    /// incremental equivalent of the naive scans seeing one more entry of
    /// `cfg_all`. Stages are folded in index order, exactly as the naive
    /// loop visits them.
    pub fn commit(&mut self, env: &SchedEnv, p: usize, cfg: &[StageCfg]) {
        let dag = &env.pipelines[p];
        for (m, c) in cfg.iter().enumerate() {
            self.mem_used[c.device] += stage_memory_mb(env, p, m, *c);
            let class = env.cluster.device(c.device).class;
            let lat = env.profiles.batch_latency(&dag.models[m].spec, class, c.batch);
            self.time_used[c.device] += lat * c.instances as f64;
        }
    }

    /// Remaining GPU memory on `device` given the committed pipelines plus
    /// the in-progress pipeline `p` with config `cfg`. Continues the
    /// committed fold over `cfg`'s stages — bit-identical to the naive
    /// full rescan.
    pub fn mem_headroom(
        &self,
        env: &SchedEnv,
        device: usize,
        p: usize,
        cfg: &[StageCfg],
    ) -> f64 {
        let mut used = self.mem_used[device];
        for (m, c) in cfg.iter().enumerate() {
            if c.device == device {
                used += stage_memory_mb(env, p, m, *c);
            }
        }
        self.mem_total[device] - used
    }

    /// Committed + in-progress stream-time demand on `device` (ms per duty
    /// cycle). Same prefix-fold continuation as [`Self::mem_headroom`].
    pub fn stream_time(
        &self,
        env: &SchedEnv,
        device: usize,
        p: usize,
        cfg: &[StageCfg],
    ) -> f64 {
        let class = env.cluster.device(device).class;
        let dag = &env.pipelines[p];
        let mut total = self.time_used[device];
        for (m, c) in cfg.iter().enumerate() {
            if c.device == device {
                let lat = env.profiles.batch_latency(&dag.models[m].spec, class, c.batch);
                total += lat * c.instances as f64;
            }
        }
        total
    }

    /// Stream-time budget of a device per duty cycle (streams × duty, with
    /// the portion-packing safety margin).
    pub fn stream_budget(&self, device: usize, duty_ms: f64) -> f64 {
        self.streams[device] as f64 * duty_ms * 0.9
    }
}

/// Recycled GPU stream state for CORAL, with a per-device index so
/// placement scans touch only the target device's contiguous GPU range
/// and plan replay resolves a `GpuId` in O(1).
#[derive(Clone, Debug, Default)]
pub struct GpuPool {
    pub(super) gpus: Vec<GpuStreams>,
    /// `range[device] = (start, end)` into `gpus` (build order: devices in
    /// cluster order, GPUs per device in index order — same as the naive
    /// `build_gpu_state`, so relative iteration order is preserved).
    range: Vec<(usize, usize)>,
}

impl GpuPool {
    /// Rebuild the pool as empty stream sets for `env`'s cluster, reusing
    /// every allocation from the previous round.
    pub fn reset(&mut self, env: &SchedEnv) {
        self.range.clear();
        let mut idx = 0;
        for d in &env.cluster.devices {
            let start = idx;
            for (gi, g) in d.gpus.iter().enumerate() {
                let id = GpuId { device: d.id, gpu: gi };
                if idx < self.gpus.len() {
                    self.gpus[idx].reset(id, g.mem_mb, g.util_cap, g.streams);
                } else {
                    self.gpus.push(GpuStreams::new(id, g.mem_mb, g.util_cap, g.streams));
                }
                idx += 1;
            }
            self.range.push((start, idx));
        }
        self.gpus.truncate(idx);
    }

    /// Contiguous `gpus` index range of a device ((0, 0) when unknown).
    pub fn device_range(&self, device: usize) -> (usize, usize) {
        self.range.get(device).copied().unwrap_or((0, 0))
    }

    /// O(1) index of a GPU id; `None` for ids outside the pool (stale
    /// plans referencing hardware this cluster does not have — the same
    /// ids the naive linear `find` would fail to match).
    pub fn gpu_index(&self, id: GpuId) -> Option<usize> {
        let &(start, end) = self.range.get(id.device)?;
        let idx = start + id.gpu;
        (idx < end).then_some(idx)
    }
}

/// Reusable planner state owned by the Controller.
///
/// # Reuse contract
///
/// * A workspace may be reused across arbitrarily many planning rounds
///   (full plans, subset replans, repairs) over the **same or different**
///   environments; every entry point resets the state it reads before
///   using it. Plans produced with a reused workspace are bit-identical
///   to plans produced with a fresh one (enforced by
///   `rust/tests/planner.rs`).
/// * A workspace must not be shared between concurrent planning calls —
///   it is exclusive scratch, not shared state. `Controller` (and thus
///   each sim partition) owns exactly one.
/// * Dropping a workspace between rounds is always safe; it only costs
///   the recycled capacity.
#[derive(Clone, Debug, Default)]
pub struct PlannerWorkspace {
    // ---- CWD ----
    pub(super) loads: DeviceLoads,
    /// Burstiness sort scratch (Algorithm 1 line 6).
    pub(super) order: Vec<usize>,
    /// Pool of downstream-id vecs for ToEdge's DFS recursion.
    pub(super) downs_pool: Vec<Vec<usize>>,
    /// Target-id scratch for full rounds (`cwd_ws`).
    pub(super) full_targets: Vec<usize>,
    // ---- CORAL ----
    pub(super) gpus: GpuPool,
    /// Flat offsets: `stage_off[p]` indexes `stage_end` for pipeline `p`.
    pub(super) stage_off: Vec<usize>,
    /// Upstream portion end per stage; `NEG_INFINITY` = no portion yet
    /// (legitimate ends are ≥ 0, so the sentinel never collides).
    pub(super) stage_end: Vec<Ms>,
    /// Offset of each work item's first assignment in the output vec.
    pub(super) asg_off: Vec<usize>,
    /// Drifted-pipeline membership for `coral_repair_ws`.
    pub(super) drift_flag: Vec<bool>,
    // ---- Controller replan ----
    /// The full round's CWD configs, kept so the feasibility-feedback
    /// re-run and the next round's row recycling reuse them.
    pub(super) plan_cfgs: Vec<Vec<StageCfg>>,
    pub(super) replan_targets: Vec<usize>,
    pub(super) kept: Vec<(usize, Vec<StageCfg>)>,
    pub(super) new_cfgs: Vec<(usize, Vec<StageCfg>)>,
    /// Recycled per-pipeline config rows.
    pub(super) row_pool: Vec<Vec<StageCfg>>,
}

impl PlannerWorkspace {
    pub fn new() -> PlannerWorkspace {
        PlannerWorkspace::default()
    }

    /// Reset the flat stage-end table for a placement round over `env`.
    pub(super) fn reset_stage_end(&mut self, env: &SchedEnv) {
        self.stage_off.clear();
        let mut off = 0;
        for dag in env.pipelines {
            self.stage_off.push(off);
            off += dag.len();
        }
        self.stage_end.clear();
        self.stage_end.resize(off, f64::NEG_INFINITY);
    }

    /// Return a cleared config row from the pool (or a fresh one).
    pub(super) fn take_row(&mut self) -> Vec<StageCfg> {
        let mut row = self.row_pool.pop().unwrap_or_default();
        row.clear();
        row
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::pipeline::standard_pipelines;
    use crate::profiles::ProfileStore;

    #[test]
    fn gpu_pool_indexes_match_build_order() {
        let cl = Cluster::paper_testbed();
        let pf = ProfileStore::analytic();
        let pl = standard_pipelines(2);
        let env = SchedEnv::bootstrap(&cl, &pf, &pl, vec![80.0; cl.devices.len()]);
        let mut pool = GpuPool::default();
        pool.reset(&env);
        let naive = super::super::coral::build_gpu_state(&env);
        assert_eq!(pool.gpus.len(), naive.len());
        for (a, b) in pool.gpus.iter().zip(&naive) {
            assert_eq!(a.gpu, b.gpu);
            assert_eq!(a.streams.len(), b.streams.len());
        }
        for (i, g) in pool.gpus.iter().enumerate() {
            assert_eq!(pool.gpu_index(g.gpu), Some(i));
        }
        assert_eq!(pool.gpu_index(GpuId { device: 99, gpu: 0 }), None);
        assert_eq!(pool.gpu_index(GpuId { device: 0, gpu: 99 }), None);
        // Reuse across a different cluster shape leaves no stale GPUs.
        let cl2 = Cluster::small();
        let pl2 = standard_pipelines(1);
        let env2 = SchedEnv::bootstrap(&cl2, &pf, &pl2, vec![80.0; 3]);
        pool.reset(&env2);
        assert_eq!(pool.gpus.len(), cl2.n_gpus());
    }

    #[test]
    fn device_loads_match_naive_scans() {
        let cl = Cluster::paper_testbed();
        let pf = ProfileStore::analytic();
        let pl: Vec<_> = standard_pipelines(3)
            .into_iter()
            .map(|mut p| {
                p.source_device += 1;
                p
            })
            .collect();
        let env = SchedEnv::bootstrap(&cl, &pf, &pl, vec![80.0; cl.devices.len()]);
        let cfgs: Vec<Vec<StageCfg>> =
            super::super::cwd::cwd(&env, &super::super::cwd::CwdParams::default())
                .into_iter()
                .map(|r| r.cfg)
                .collect();
        let mut loads = DeviceLoads::default();
        loads.reset(&env);
        let committed: Vec<(usize, Vec<StageCfg>)> =
            cfgs.iter().take(2).cloned().enumerate().collect();
        for (p, cfg) in &committed {
            loads.commit(&env, *p, cfg);
        }
        // Continue the fold over pipeline 2 and compare against the naive
        // rescan of committed + current.
        let mut all = committed.clone();
        all.push((2, cfgs[2].clone()));
        for d in 0..cl.devices.len() {
            let fast = loads.mem_headroom(&env, d, 2, &cfgs[2]);
            let naive = super::super::reference::device_mem_headroom(&env, d, &all);
            assert_eq!(fast.to_bits(), naive.to_bits(), "mem device {d}");
            let fast_t = loads.stream_time(&env, d, 2, &cfgs[2]);
            let naive_t = super::super::reference::device_stream_time(&env, d, &all);
            assert_eq!(fast_t.to_bits(), naive_t.to_bits(), "time device {d}");
        }
    }
}
