//! `EstLat` / `EstThrpt` — the pipeline latency and effective-throughput
//! estimators CWD's greedy search queries (Algorithm 1 lines 11, 14).
//!
//! Latency follows the paper's Eq. 2 plus the worst-case batch-fill wait of
//! Eq. 3 (the first query in a batch waits for the batch to fill); the IO
//! term uses the current bandwidth snapshot, inflated by an M/M/1-style
//! factor when offered network load approaches capacity (Obs. 2).

use super::types::{SchedEnv, StageCfg};
use crate::network::LOCAL_TRANSFER_MS;
use crate::Ms;

/// Per-query estimated latency of stage `m` under `cfg` (Eq. 2 + fill wait).
pub fn stage_latency(
    env: &SchedEnv,
    pipeline: usize,
    model: usize,
    cfg: &[StageCfg],
) -> Ms {
    let dag = &env.pipelines[pipeline];
    let spec = &dag.models[model].spec;
    let c = cfg[model];
    let class = env.cluster.device(c.device).class;
    let rate = env.rate(pipeline, model).max(0.01);
    let rate_per_inst = rate / c.instances.max(1) as f64;

    // Worst-case fill wait: first query waits (bz-1) further arrivals.
    let fill_ms = (c.batch.saturating_sub(1)) as f64 * 1000.0 / rate_per_inst.max(0.01);
    // Burstiness shortens the *expected* fill (Insight 1): bursty arrivals
    // fill batches in clumps. Scale the wait by 1/(1+CV).
    let cv = env.burstiness(pipeline, model);
    // Portion clocking bounds waiting at one duty cycle (worst case);
    // the expected wait is half a duty — Eq. 3's worst-case analysis
    // leaves the other half for execution.
    let fill_ms = (fill_ms / (1.0 + cv)).min(dag.slo_ms / 4.0);

    let exec_ms = env.profiles.batch_latency(spec, class, c.batch);

    // Queueing when the stage is near saturation (soft penalty; the fill
    // term already covers the duty-bounded waiting of healthy stages).
    let cap_qps = c.instances as f64 * env.profiles.curve(spec, class).throughput(c.batch);
    let rho = (rate / cap_qps.max(1e-9)).min(0.999);
    let queue_ms = if rho > 0.85 { exec_ms * rho / (1.0 - rho) * 0.15 } else { 0.0 };

    // IO: transfer from upstream's device (Eq. 2 second term).
    let up_dev = dag.upstream(model).map(|u| cfg[u].device).unwrap_or(dag.source_device);
    let io_ms = transfer_latency(env, up_dev, c.device, spec.input_bytes, rate);

    fill_ms + exec_ms + queue_ms + io_ms
}

/// Expected per-query transfer latency between two devices for payloads of
/// `bytes` at aggregate rate `rate_qps`.
pub fn transfer_latency(
    env: &SchedEnv,
    from: usize,
    to: usize,
    bytes: f64,
    rate_qps: f64,
) -> Ms {
    if from == to {
        return LOCAL_TRANSFER_MS;
    }
    // All cross-device traffic traverses the edge<->server link of the edge
    // endpoint (star topology around the server).
    let edge = if from == 0 { to } else { from };
    let bw = env.bw_mbps.get(edge).copied().unwrap_or(0.0);
    if bw <= 0.0 {
        return f64::INFINITY;
    }
    let per_query = bytes * 8.0 / (bw * 1000.0); // ms
    let offered = rate_qps * bytes * 8.0 / 1e6; // Mbit/s
    let rho = (offered / bw).min(0.999);
    // M/M/1-flavored inflation as the link saturates.
    per_query * (1.0 + rho / (1.0 - rho))
}

/// End-to-end worst-path latency of the pipeline (sum over the critical
/// path of the DAG).
pub fn est_latency(env: &SchedEnv, pipeline: usize, cfg: &[StageCfg]) -> Ms {
    let dag = &env.pipelines[pipeline];
    // Latency to *finish* each node, DAG-propagated.
    let mut finish = vec![0.0f64; dag.len()];
    for m in 0..dag.len() {
        let own = stage_latency(env, pipeline, m, cfg);
        let up = dag.upstream(m).map(|u| finish[u]).unwrap_or(0.0);
        finish[m] = up + own;
    }
    finish.iter().copied().fold(0.0, f64::max)
}

/// Effective-throughput estimate (objects/s reaching sinks on time):
/// bottleneck capacity ratio along the pipeline applied to the offered
/// sink rate (compute AND network bottlenecks, Obs. 2).
pub fn est_throughput(env: &SchedEnv, pipeline: usize, cfg: &[StageCfg]) -> f64 {
    let dag = &env.pipelines[pipeline];
    let mut min_ratio: f64 = 1.0;
    for m in 0..dag.len() {
        let spec = &dag.models[m].spec;
        let c = cfg[m];
        let class = env.cluster.device(c.device).class;
        let rate = env.rate(pipeline, m).max(1e-9);
        // Chained-reservation capacity (see cwd::instances_needed).
        let per_inst =
            env.profiles.curve(spec, class).throughput(c.batch) * 0.8;
        let cap = c.instances as f64 * per_inst;
        min_ratio = min_ratio.min(cap / rate);

        // Network capacity of the inbound hop.
        let up_dev =
            dag.upstream(m).map(|u| cfg[u].device).unwrap_or(dag.source_device);
        if up_dev != c.device {
            let edge = if up_dev == 0 { c.device } else { up_dev };
            let bw = env.bw_mbps.get(edge).copied().unwrap_or(0.0);
            let offered = rate * spec.input_bytes * 8.0 / 1e6;
            if offered > 0.0 {
                min_ratio = min_ratio.min(bw / offered);
            }
        }
    }
    let sink_rate: f64 = (0..dag.len())
        .filter(|&m| dag.models[m].downstream.is_empty())
        .map(|m| env.rate(pipeline, m))
        .sum();
    sink_rate * min_ratio.clamp(0.0, 1.0)
}

/// Aggregate GPU busy time (ms per second of wall time) the pipeline's
/// config consumes — CWD's tie-break objective: configurations that hold
/// throughput while freeing GPU time are preferred (resource efficiency).
pub fn est_gpu_cost(env: &SchedEnv, pipeline: usize, cfg: &[StageCfg]) -> f64 {
    let dag = &env.pipelines[pipeline];
    (0..dag.len())
        .map(|m| {
            let spec = &dag.models[m].spec;
            let c = cfg[m];
            let class = env.cluster.device(c.device).class;
            let lat = env.profiles.batch_latency(spec, class, c.batch);
            env.rate(pipeline, m) * lat / c.batch.max(1) as f64
        })
        .sum()
}

/// Estimated GPU memory demand of a stage config on its device (Eq. 4 input
/// for CWD's coarse feasibility check; CORAL enforces exactly).
pub fn stage_memory_mb(env: &SchedEnv, pipeline: usize, model: usize, c: StageCfg) -> f64 {
    let spec = &env.pipelines[pipeline].models[model].spec;
    c.instances as f64 * spec.memory_mb(c.batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::pipeline::standard_pipelines;
    use crate::profiles::ProfileStore;

    fn fixture() -> (Cluster, ProfileStore, Vec<crate::pipeline::PipelineDag>) {
        (Cluster::small(), ProfileStore::analytic(), standard_pipelines(2))
    }

    fn cfg_all(dag_len: usize, device: usize, batch: u32) -> Vec<StageCfg> {
        vec![StageCfg { device, batch, instances: 1 }; dag_len]
    }

    #[test]
    fn bigger_batch_adds_fill_latency() {
        let (cl, pf, pl) = fixture();
        let env = SchedEnv::bootstrap(&cl, &pf, &pl, vec![100.0; 3]);
        let lat1 = est_latency(&env, 0, &cfg_all(3, 0, 1));
        let lat32 = est_latency(&env, 0, &cfg_all(3, 0, 32));
        assert!(lat32 > lat1, "fill wait must grow: {lat1} vs {lat32}");
    }

    #[test]
    fn outage_makes_latency_infinite() {
        let (cl, pf, pl) = fixture();
        let env = SchedEnv::bootstrap(&cl, &pf, &pl, vec![0.0; 3]);
        // Pipeline 0's source is device 0 == server in `standard_pipelines`
        // fixture? source_device = 0 => local. Use pipeline 1 (device 1).
        let lat = est_latency(&env, 1, &cfg_all(3, 0, 4));
        assert!(lat.is_infinite());
    }

    #[test]
    fn throughput_capped_by_network() {
        let (cl, pf, pl) = fixture();
        let rich = SchedEnv::bootstrap(&cl, &pf, &pl, vec![1000.0; 3]);
        let poor = SchedEnv::bootstrap(&cl, &pf, &pl, vec![1.0; 3]);
        let cfg = cfg_all(3, 0, 8);
        // Pipeline 1 sources on device 1 -> server placement crosses link.
        let t_rich = est_throughput(&rich, 1, &cfg);
        let t_poor = est_throughput(&poor, 1, &cfg);
        assert!(t_poor < t_rich * 0.2, "rich {t_rich} poor {t_poor}");
    }

    #[test]
    fn more_instances_more_throughput_when_saturated() {
        let (cl, pf, pl) = fixture();
        let mut env = SchedEnv::bootstrap(&cl, &pf, &pl, vec![1000.0; 3]);
        // Crank the workload so one instance saturates.
        for o in env.obs[0].iter_mut() {
            o.rate_qps *= 50.0;
        }
        let mut one = cfg_all(3, 0, 8);
        let mut four = cfg_all(3, 0, 8);
        for c in four.iter_mut() {
            c.instances = 4;
        }
        let _ = &mut one;
        assert!(est_throughput(&env, 0, &four) > est_throughput(&env, 0, &one));
    }

    #[test]
    fn local_transfer_is_cheap() {
        let (cl, pf, pl) = fixture();
        let env = SchedEnv::bootstrap(&cl, &pf, &pl, vec![10.0; 3]);
        assert!(transfer_latency(&env, 1, 1, 1e6, 10.0) < 0.1);
        assert!(transfer_latency(&env, 1, 0, 1e6, 10.0) > 100.0);
    }

    #[test]
    fn edge_placement_avoids_network_term() {
        let (cl, pf, pl) = fixture();
        let env = SchedEnv::bootstrap(&cl, &pf, &pl, vec![5.0; 3]);
        // Pipeline 1 (source device 1): detector on edge vs on server under
        // a weak link — edge placement must estimate lower latency despite
        // slower compute.
        let mut on_server = cfg_all(3, 0, 2);
        let mut on_edge = cfg_all(3, 0, 2);
        on_edge[0].device = 1;
        on_server[0].instances = 1;
        let ls = est_latency(&env, 1, &on_server);
        let le = est_latency(&env, 1, &on_edge);
        assert!(le < ls, "edge {le} server {ls}");
    }
}
