//! Shared scheduling types: the configuration vocabulary of the ILP
//! (paper §II: `[bz, d, g, t]` per model) and the `Plan` all schedulers
//! produce for the simulator / serving stack to execute.

use crate::cluster::Cluster;
use crate::pipeline::PipelineDag;
use crate::profiles::ProfileStore;
use crate::Ms;

/// Globally unique GPU identifier.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct GpuId {
    pub device: usize,
    pub gpu: usize,
}

/// CORAL temporal placement of one instance (paper §III-C: a *portion* of
/// an inference *stream*).
#[derive(Clone, Copy, Debug)]
pub struct TemporalSlot {
    pub stream: usize,
    /// Offset of the portion within the stream's duty cycle, ms.
    pub start_ms: Ms,
    /// Portion length = batch execution latency, ms.
    pub duration_ms: Ms,
    /// Stream duty cycle this instance executes under (= SLO/2), ms.
    pub duty_cycle_ms: Ms,
}

/// One instance's GPU binding. Baselines produce spatial-only bindings
/// (`temporal: None`) — exactly the gap the paper's Table I highlights.
#[derive(Clone, Copy, Debug)]
pub struct GpuBinding {
    pub gpu: GpuId,
    pub width: f64,
    pub temporal: Option<TemporalSlot>,
}

impl GpuBinding {
    /// Bit-exact equality (floats compared by bit pattern). Plans are
    /// deterministic, so an unchanged assignment reproduces identical
    /// bits — this is what the engine's plan-diff migration and the CORAL
    /// repair tests mean by "unchanged".
    pub fn bit_eq(&self, other: &GpuBinding) -> bool {
        self.gpu == other.gpu
            && self.width.to_bits() == other.width.to_bits()
            && match (self.temporal, other.temporal) {
                (None, None) => true,
                (Some(x), Some(y)) => {
                    x.stream == y.stream
                        && x.start_ms.to_bits() == y.start_ms.to_bits()
                        && x.duration_ms.to_bits() == y.duration_ms.to_bits()
                        && x.duty_cycle_ms.to_bits() == y.duty_cycle_ms.to_bits()
                }
                _ => false,
            }
    }
}

/// Per-stage configuration chosen by workload distribution.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StageCfg {
    pub device: usize,
    pub batch: u32,
    pub instances: u32,
}

/// Scheduled deployment of one (pipeline, model).
#[derive(Clone, Debug)]
pub struct Assignment {
    pub pipeline: usize,
    pub model: usize,
    pub cfg: StageCfg,
    /// One binding per instance (len == cfg.instances when fully placed).
    pub bindings: Vec<GpuBinding>,
}

/// Full deployment plan for the cluster.
#[derive(Clone, Debug, Default)]
pub struct Plan {
    pub assignments: Vec<Assignment>,
    /// Instances CORAL could not fit (run contended, without reservation).
    pub unplaced: usize,
}

impl Plan {
    pub fn assignment(&self, pipeline: usize, model: usize) -> Option<&Assignment> {
        self.assignments
            .iter()
            .find(|a| a.pipeline == pipeline && a.model == model)
    }

    /// Exact equality, with float fields compared by bits — the identity
    /// the workspace-backed planner promises against its naive reference
    /// (see `coordinator::reference` and `rust/tests/planner.rs`).
    pub fn bit_eq(&self, other: &Plan) -> bool {
        self.unplaced == other.unplaced
            && self.assignments.len() == other.assignments.len()
            && self.assignments.iter().zip(&other.assignments).all(|(a, b)| {
                a.pipeline == b.pipeline
                    && a.model == b.model
                    && a.cfg == b.cfg
                    && a.bindings.len() == b.bindings.len()
                    && a.bindings
                        .iter()
                        .zip(&b.bindings)
                        .all(|(x, y)| x.bit_eq(y))
            })
    }

    /// Number of edge/server split points of a pipeline in this plan
    /// (Insight 3: fewer is better).
    pub fn split_points(&self, pipeline: usize, dag: &PipelineDag) -> usize {
        let device_of = |m: usize| {
            self.assignment(pipeline, m).map(|a| a.cfg.device).unwrap_or(0)
        };
        let mut splits = 0;
        for m in 0..dag.len() {
            if let Some(up) = dag.upstream(m) {
                if device_of(up) != device_of(m) {
                    splits += 1;
                }
            }
        }
        splits
    }

    /// Total GPU memory the plan allocates (Fig. 6c metric). Temporal
    /// sharing means instances in the same stream share intermediate
    /// memory (max instead of sum) — the paper's key memory win.
    pub fn total_memory_mb(&self, pipelines: &[PipelineDag]) -> f64 {
        use std::collections::HashMap;
        let mut weights = 0.0;
        // (gpu, stream) -> max intermediate; spatial-only bindings get a
        // unique pseudo-stream so they sum (no sharing).
        let mut inter: HashMap<(GpuId, usize), f64> = HashMap::new();
        let mut pseudo = 10_000usize;
        for a in &self.assignments {
            let spec = &pipelines[a.pipeline].models[a.model].spec;
            for b in &a.bindings {
                weights += spec.weight_mem_mb;
                let im = spec.inter_mem_mb * a.cfg.batch as f64;
                let key = match b.temporal {
                    Some(t) => (b.gpu, t.stream),
                    None => {
                        pseudo += 1;
                        (b.gpu, pseudo)
                    }
                };
                let e = inter.entry(key).or_insert(0.0);
                *e = e.max(im);
            }
        }
        weights + inter.values().sum::<f64>()
    }
}

/// Observed per-model workload statistics (from the KB in live runs).
#[derive(Clone, Copy, Debug)]
pub struct ModelObs {
    /// Request rate entering the model, queries/s.
    pub rate_qps: f64,
    /// CV of inter-arrival gaps (paper's burstiness, Insight 1).
    pub burstiness: f64,
}

/// Everything a scheduler sees when planning (paper step 1-2 inputs).
pub struct SchedEnv<'a> {
    pub cluster: &'a Cluster,
    pub profiles: &'a ProfileStore,
    pub pipelines: &'a [PipelineDag],
    /// obs[p][m] — per pipeline, per model.
    pub obs: Vec<Vec<ModelObs>>,
    /// Current bandwidth device <-> server, Mbit/s (index = device id).
    pub bw_mbps: Vec<f64>,
    /// IO-ratio slack factor α in ToEdge's test (paper line 27).
    pub alpha: f64,
}

impl<'a> SchedEnv<'a> {
    /// Build with rates derived from pipeline structure (no KB yet): the
    /// cold-start estimate the Controller uses on round one.
    pub fn bootstrap(
        cluster: &'a Cluster,
        profiles: &'a ProfileStore,
        pipelines: &'a [PipelineDag],
        bw_mbps: Vec<f64>,
    ) -> SchedEnv<'a> {
        let obs = pipelines
            .iter()
            .map(|p| {
                let rates = p.request_rates(1.0);
                rates
                    .iter()
                    .enumerate()
                    .map(|(m, &r)| ModelObs {
                        rate_qps: r,
                        // Downstream stages inherit detector-driven
                        // burstiness; entry stage is clocked (low CV).
                        burstiness: if m == 0 { 0.1 } else { 1.2 },
                    })
                    .collect()
            })
            .collect();
        SchedEnv { cluster, profiles, pipelines, obs, bw_mbps, alpha: 1.2 }
    }

    pub fn rate(&self, pipeline: usize, model: usize) -> f64 {
        self.obs[pipeline][model].rate_qps
    }

    pub fn burstiness(&self, pipeline: usize, model: usize) -> f64 {
        self.obs[pipeline][model].burstiness
    }
}

/// Scheduler interface all five systems implement. `Send` because sim
/// partitions (each owning a boxed scheduler) migrate across the driver's
/// worker threads between epoch barriers.
pub trait Scheduler: Send {
    fn name(&self) -> &'static str;
    fn plan(&mut self, env: &SchedEnv) -> Plan;

    /// Drift-triggered incremental replan: revise `old` for the `drifted`
    /// pipelines only, leaving the rest in place. The default is a full
    /// replan (baselines have no incremental path); OctopInf's
    /// `Controller` overrides this with CWD-subset + CORAL repair.
    fn replan(&mut self, env: &SchedEnv, old: &Plan, drifted: &[usize]) -> Plan {
        let _ = (old, drifted);
        self.plan(env)
    }

    /// Failure-aware replan after `device` crashed (its `env.bw_mbps`
    /// entry arrives zeroed, and on recovery, restored). The default is a
    /// full survivor replan; OctopInf's `Controller` overrides this with
    /// a targeted re-placement of the pipelines that had stages on the
    /// dead device, keeping everything unaffected bit-for-bit in place.
    fn on_fault(&mut self, env: &SchedEnv, old: &Plan, device: usize) -> Plan {
        let _ = (old, device);
        self.plan(env)
    }

    /// Which path produced the *last* plan this scheduler returned: a
    /// full solve or an incremental repair of the previous plan.
    /// Report-only (the tracer's planner-round lane) — the engine never
    /// branches on it. Baselines only ever solve from scratch, hence the
    /// default; OctopInf's `Controller` overrides it.
    fn round_path(&self) -> crate::obs::RoundPath {
        crate::obs::RoundPath::Full
    }
}

/// Selector used by the CLI / bench harness.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulerKind {
    OctopInf,
    /// Ablation: CWD without CORAL (spatial best-fit only) — Fig. 10.
    OctopInfNoCoral,
    /// Ablation: static batches + CORAL — Fig. 10.
    OctopInfStaticBatch,
    /// Ablation: server-only dynamic batching + CORAL — Fig. 10.
    OctopInfServerOnly,
    Distream,
    Jellyfish,
    Rim,
}

impl SchedulerKind {
    pub fn label(&self) -> &'static str {
        match self {
            SchedulerKind::OctopInf => "octopinf",
            SchedulerKind::OctopInfNoCoral => "octopinf-no-coral",
            SchedulerKind::OctopInfStaticBatch => "octopinf-static-batch",
            SchedulerKind::OctopInfServerOnly => "octopinf-server-only",
            SchedulerKind::Distream => "distream",
            SchedulerKind::Jellyfish => "jellyfish",
            SchedulerKind::Rim => "rim",
        }
    }

    pub fn parse(s: &str) -> Option<SchedulerKind> {
        Some(match s {
            "octopinf" => SchedulerKind::OctopInf,
            "octopinf-no-coral" | "no-coral" => SchedulerKind::OctopInfNoCoral,
            "octopinf-static-batch" | "static-batch" => {
                SchedulerKind::OctopInfStaticBatch
            }
            "octopinf-server-only" | "server-only" => {
                SchedulerKind::OctopInfServerOnly
            }
            "distream" => SchedulerKind::Distream,
            "jellyfish" => SchedulerKind::Jellyfish,
            "rim" => SchedulerKind::Rim,
            _ => return None,
        })
    }

    pub fn all_main() -> [SchedulerKind; 4] {
        [
            SchedulerKind::OctopInf,
            SchedulerKind::Distream,
            SchedulerKind::Jellyfish,
            SchedulerKind::Rim,
        ]
    }

    /// The five-system differential conformance set: CWD+CORAL (full
    /// OctopInf), CWD over the spatial best-fit spreader (the no-CORAL
    /// ablation), and the three baselines. Every fuzzed scenario runs
    /// through all five under the invariant engine.
    pub fn conformance_set() -> [SchedulerKind; 5] {
        [
            SchedulerKind::OctopInf,
            SchedulerKind::OctopInfNoCoral,
            SchedulerKind::Distream,
            SchedulerKind::Jellyfish,
            SchedulerKind::Rim,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::standard_pipelines;

    #[test]
    fn bootstrap_env_rates_match_dag() {
        let cluster = Cluster::small();
        let profiles = ProfileStore::analytic();
        let pipelines = standard_pipelines(2);
        let env = SchedEnv::bootstrap(&cluster, &profiles, &pipelines, vec![1000.0; 3]);
        assert_eq!(env.obs.len(), 2);
        assert!((env.rate(0, 0) - 15.0).abs() < 1e-9);
        assert!(env.rate(0, 1) > env.rate(0, 0)); // fanout amplifies
    }

    #[test]
    fn scheduler_kind_roundtrip() {
        for k in [
            SchedulerKind::OctopInf,
            SchedulerKind::Distream,
            SchedulerKind::Jellyfish,
            SchedulerKind::Rim,
            SchedulerKind::OctopInfNoCoral,
        ] {
            assert_eq!(SchedulerKind::parse(k.label()), Some(k));
        }
        assert_eq!(SchedulerKind::parse("nope"), None);
    }

    #[test]
    fn split_point_count() {
        let pipelines = standard_pipelines(1);
        let mk = |devices: [usize; 3]| Plan {
            assignments: (0..3)
                .map(|m| Assignment {
                    pipeline: 0,
                    model: m,
                    cfg: StageCfg { device: devices[m], batch: 1, instances: 1 },
                    bindings: vec![],
                })
                .collect(),
            unplaced: 0,
        };
        assert_eq!(mk([0, 0, 0]).split_points(0, &pipelines[0]), 0);
        assert_eq!(mk([1, 0, 0]).split_points(0, &pipelines[0]), 2);
        assert_eq!(mk([1, 1, 1]).split_points(0, &pipelines[0]), 0);
    }

    #[test]
    fn temporal_sharing_reduces_memory() {
        let pipelines = standard_pipelines(1);
        let gpu = GpuId { device: 0, gpu: 0 };
        let slot = |s| TemporalSlot {
            stream: s,
            start_ms: 0.0,
            duration_ms: 5.0,
            duty_cycle_ms: 100.0,
        };
        let mk = |temporal: bool| Plan {
            assignments: (0..3)
                .map(|m| Assignment {
                    pipeline: 0,
                    model: m,
                    cfg: StageCfg { device: 0, batch: 8, instances: 1 },
                    bindings: vec![GpuBinding {
                        gpu,
                        width: 0.2,
                        temporal: temporal.then(|| slot(0)),
                    }],
                })
                .collect(),
            unplaced: 0,
        };
        let shared = mk(true).total_memory_mb(&pipelines);
        let unshared = mk(false).total_memory_mb(&pipelines);
        assert!(shared < unshared, "shared {shared} unshared {unshared}");
    }
}
