//! Brute-force reference solver for *tiny* instances of the paper's ILP
//! (§II, Eq. 1-5). The full problem is NP-hard with complexity
//! O(D·(BZ·G)^M) (§V-1); this enumerator is only usable for M ≤ ~4 and is
//! used in tests to certify that CWD's greedy result is within a bounded
//! factor of the true optimum — an assurance the paper argues but
//! does not ship.

use super::estimator::{est_latency, est_throughput};
use super::types::{SchedEnv, StageCfg};
use crate::profiles::BATCH_SIZES;

/// Exhaustive search over (device, batch) per stage with rate-matched
/// instance counts; returns the best config and its throughput.
/// `devices` restricts the candidate hosts (usually [0, source_device]).
pub fn optimal_config(
    env: &SchedEnv,
    pipeline: usize,
    devices: &[usize],
) -> Option<(Vec<StageCfg>, f64)> {
    let dag = &env.pipelines[pipeline];
    let n = dag.len();
    assert!(n <= 5, "brute force limited to tiny pipelines (got {n})");

    let per_stage: Vec<Vec<StageCfg>> = (0..n)
        .map(|m| {
            let mut opts = Vec::new();
            for &d in devices {
                for &bz in BATCH_SIZES.iter() {
                    let spec = &dag.models[m].spec;
                    let class = env.cluster.device(d).class;
                    let cap = env.profiles.curve(spec, class).throughput(bz);
                    let instances = ((env.rate(pipeline, m) / cap.max(1e-9))
                        .ceil() as u32)
                        .clamp(1, 16);
                    opts.push(StageCfg { device: d, batch: bz, instances });
                }
            }
            opts
        })
        .collect();

    let mut best: Option<(Vec<StageCfg>, f64)> = None;
    let mut idx = vec![0usize; n];
    loop {
        let cfg: Vec<StageCfg> =
            (0..n).map(|m| per_stage[m][idx[m]]).collect();
        if est_latency(env, pipeline, &cfg) <= dag.slo_ms / 2.0 {
            let thrpt = est_throughput(env, pipeline, &cfg);
            if best.as_ref().map(|(_, b)| thrpt > *b).unwrap_or(true) {
                best = Some((cfg, thrpt));
            }
        }
        // Odometer increment.
        let mut k = 0;
        loop {
            idx[k] += 1;
            if idx[k] < per_stage[k].len() {
                break;
            }
            idx[k] = 0;
            k += 1;
            if k == n {
                return best;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::coordinator::cwd::{cwd, CwdParams};
    use crate::pipeline::standard_pipelines;
    use crate::profiles::ProfileStore;

    #[test]
    fn greedy_within_bounded_factor_of_optimal() {
        let cluster = Cluster::paper_testbed();
        let profiles = ProfileStore::analytic();
        let pipelines: Vec<_> = standard_pipelines(1)
            .into_iter()
            .map(|mut p| {
                p.source_device = 2;
                p
            })
            .collect();
        for bw in [5.0, 25.0, 100.0] {
            let env = crate::coordinator::types::SchedEnv::bootstrap(
                &cluster,
                &profiles,
                &pipelines,
                vec![bw; cluster.devices.len()],
            );
            let greedy = &cwd(&env, &CwdParams::default())[0];
            let greedy_thrpt = est_throughput(&env, 0, &greedy.cfg);
            let (_, opt_thrpt) =
                optimal_config(&env, 0, &[0, 2]).expect("feasible optimum");
            assert!(
                greedy_thrpt >= 0.55 * opt_thrpt,
                "bw={bw}: greedy {greedy_thrpt:.2} < 55% of optimal {opt_thrpt:.2}"
            );
        }
    }

    #[test]
    fn optimum_respects_slo() {
        let cluster = Cluster::paper_testbed();
        let profiles = ProfileStore::analytic();
        let pipelines: Vec<_> = standard_pipelines(1)
            .into_iter()
            .map(|mut p| {
                p.source_device = 1;
                p
            })
            .collect();
        let env = crate::coordinator::types::SchedEnv::bootstrap(
            &cluster,
            &profiles,
            &pipelines,
            vec![50.0; cluster.devices.len()],
        );
        let (cfg, _) = optimal_config(&env, 0, &[0, 1]).unwrap();
        assert!(est_latency(&env, 0, &cfg) <= pipelines[0].slo_ms / 2.0 + 1e-9);
    }
}
