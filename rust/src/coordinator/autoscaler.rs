//! Run-time Horizontal AutoScaler (paper §III-D): between full scheduling
//! rounds, react to workload surges/dips by cloning or reclaiming
//! container instances and placing the clones temporally via CORAL's
//! placement primitive.

use crate::coordinator::types::{Plan, SchedEnv};
use crate::Ms;

/// Scale decision for one (pipeline, model).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleAction {
    Up,
    Down,
    Hold,
}

/// Thresholds (fractions of instance-group capacity).
#[derive(Clone, Copy, Debug)]
pub struct AutoScalerParams {
    /// Scale up when observed rate exceeds this fraction of capacity.
    pub surge_frac: f64,
    /// Scale down when rate falls below this fraction (and instances > 1).
    pub dip_frac: f64,
    /// Minimum ms between actions on the same model (hysteresis).
    pub cooldown_ms: Ms,
}

impl Default for AutoScalerParams {
    fn default() -> Self {
        // The cooldown must exceed the 10 s autoscale tick or it is
        // vacuous (every decision would land exactly at the cooldown
        // boundary): 25 s = hold for two ticks after acting, then react.
        AutoScalerParams { surge_frac: 0.85, dip_frac: 0.35, cooldown_ms: 25_000.0 }
    }
}

/// Stateful autoscaler: remembers last action time per (pipeline, model).
#[derive(Clone, Debug, Default)]
pub struct AutoScaler {
    params: AutoScalerParams,
    last_action: std::collections::HashMap<(usize, usize), Ms>,
}

impl AutoScaler {
    pub fn new(params: AutoScalerParams) -> AutoScaler {
        AutoScaler { params, last_action: Default::default() }
    }

    /// Decide for one model given observed rate and current capacity.
    pub fn decide(
        &mut self,
        key: (usize, usize),
        now_ms: Ms,
        rate_qps: f64,
        capacity_qps: f64,
        instances: u32,
    ) -> ScaleAction {
        if let Some(&t) = self.last_action.get(&key) {
            if now_ms - t < self.params.cooldown_ms {
                return ScaleAction::Hold;
            }
        }
        let frac = rate_qps / capacity_qps.max(1e-9);
        let action = if frac > self.params.surge_frac {
            ScaleAction::Up
        } else if frac < self.params.dip_frac && instances > 1 {
            ScaleAction::Down
        } else {
            ScaleAction::Hold
        };
        if action != ScaleAction::Hold {
            self.last_action.insert(key, now_ms);
        }
        action
    }

    /// The caller could not apply the action `decide` just returned (e.g.
    /// the only removable instance is busy or holds a reservation): give
    /// the cooldown back so a phantom action cannot suppress a legitimate
    /// scale-up for the next `cooldown_ms`.
    pub fn cancel(&mut self, key: (usize, usize)) {
        self.last_action.remove(&key);
    }

    /// Apply scaling over a whole plan in place; returns (#up, #down).
    /// `rates[p][m]` are the currently observed request rates.
    pub fn rescale(
        &mut self,
        env: &SchedEnv,
        plan: &mut Plan,
        rates: &[Vec<f64>],
        now_ms: Ms,
    ) -> (usize, usize) {
        let (mut ups, mut downs) = (0, 0);
        for a in plan.assignments.iter_mut() {
            let spec = &env.pipelines[a.pipeline].models[a.model].spec;
            let class = env.cluster.device(a.cfg.device).class;
            let per_inst =
                env.profiles.curve(spec, class).throughput(a.cfg.batch);
            let cap = a.cfg.instances as f64 * per_inst;
            let rate = rates[a.pipeline][a.model];
            match self.decide(
                (a.pipeline, a.model),
                now_ms,
                rate,
                cap,
                a.cfg.instances,
            ) {
                ScaleAction::Up => {
                    a.cfg.instances += 1;
                    // Clone the last binding's GPU spatially; CORAL will
                    // re-place temporally at the next scheduling round —
                    // until then the clone runs contended (paper: scheduled
                    // "as described earlier" at the next opportunity).
                    if let Some(last) = a.bindings.last().copied() {
                        a.bindings.push(crate::coordinator::types::GpuBinding {
                            temporal: None,
                            ..last
                        });
                    }
                    ups += 1;
                }
                ScaleAction::Down => {
                    a.cfg.instances -= 1;
                    a.bindings.pop(); // reclaim the portion (line: removed)
                    downs += 1;
                }
                ScaleAction::Hold => {}
            }
        }
        (ups, downs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scaler() -> AutoScaler {
        AutoScaler::new(AutoScalerParams::default())
    }

    #[test]
    fn surge_scales_up() {
        let mut s = scaler();
        assert_eq!(s.decide((0, 0), 0.0, 95.0, 100.0, 1), ScaleAction::Up);
    }

    #[test]
    fn dip_scales_down_only_above_one_instance() {
        let mut s = scaler();
        assert_eq!(s.decide((0, 0), 0.0, 10.0, 100.0, 2), ScaleAction::Down);
        assert_eq!(s.decide((0, 1), 0.0, 10.0, 100.0, 1), ScaleAction::Hold);
    }

    #[test]
    fn cooldown_suppresses_flapping() {
        let mut s = scaler();
        assert_eq!(s.decide((0, 0), 0.0, 95.0, 100.0, 1), ScaleAction::Up);
        assert_eq!(s.decide((0, 0), 1000.0, 95.0, 100.0, 2), ScaleAction::Hold);
        // Two 10 s ticks later: still inside the 25 s cooldown.
        assert_eq!(s.decide((0, 0), 20_000.0, 95.0, 100.0, 2), ScaleAction::Hold);
        assert_eq!(s.decide((0, 0), 30_000.0, 95.0, 100.0, 2), ScaleAction::Up);
    }

    #[test]
    fn mid_band_holds() {
        let mut s = scaler();
        assert_eq!(s.decide((0, 0), 0.0, 60.0, 100.0, 2), ScaleAction::Hold);
    }

    #[test]
    fn cancel_returns_the_cooldown() {
        let mut s = scaler();
        // A Down the caller could not apply must not block the surge that
        // follows it.
        assert_eq!(s.decide((0, 0), 0.0, 10.0, 100.0, 2), ScaleAction::Down);
        s.cancel((0, 0));
        assert_eq!(s.decide((0, 0), 10_000.0, 95.0, 100.0, 2), ScaleAction::Up);
        // Without the cancel the same sequence holds.
        assert_eq!(s.decide((0, 1), 0.0, 10.0, 100.0, 2), ScaleAction::Down);
        assert_eq!(s.decide((0, 1), 10_000.0, 95.0, 100.0, 2), ScaleAction::Hold);
    }
}
