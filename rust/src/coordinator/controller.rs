//! The Controller: the paper's 5-step operation cycle (§III-A, Fig. 3).
//!
//! 1. Collect network/workload statistics (from the KB / snapshots).
//! 2. Run CWD to select batch sizes, hosts, and instance counts.
//! 3. Run CORAL for spatiotemporal placement.
//! 4. Communicate the plan to Device Agents (the simulator / serving
//!    stack consumes the `Plan` directly).
//! 5. Metrics flow back into the KB; the AutoScaler reacts between rounds.
//!
//! The Controller owns a [`PlannerWorkspace`] and threads it through every
//! CWD/CORAL call, so successive rounds (full plans, drift replans, fault
//! replans) recycle all planner scratch. Plans are bit-identical to what
//! the throwaway-workspace wrappers produce.

use super::autoscaler::{AutoScaler, AutoScalerParams};
use super::baselines::bestfit::spread;
use super::coral::{coral_repair_ws, coral_ws};
use super::cwd::{cwd_subset_ws, cwd_ws, CwdParams};
use super::types::{Plan, SchedEnv, Scheduler, SchedulerKind};
use super::workspace::PlannerWorkspace;
use crate::obs::RoundPath;
use crate::Ms;

/// Scheduling period between full CWD+CORAL rounds (paper §IV-A5: 6 min).
pub const SCHEDULING_PERIOD_MS: Ms = 6.0 * 60.0 * 1000.0;

/// OctopInf controller (also hosts the Fig. 10 ablation variants).
pub struct Controller {
    kind: SchedulerKind,
    pub autoscaler: AutoScaler,
    /// Reusable planner scratch; every plan/replan round resets what it
    /// reads and recycles the rest (see [`PlannerWorkspace`]).
    ws: PlannerWorkspace,
    /// Which path produced the last returned plan (full solve vs CORAL
    /// repair) — observability state for the tracer's planner lane, never
    /// consulted by planning itself.
    last_path: RoundPath,
}

impl Controller {
    pub fn new(kind: SchedulerKind) -> Controller {
        Controller {
            kind,
            autoscaler: AutoScaler::new(AutoScalerParams::default()),
            ws: PlannerWorkspace::new(),
            last_path: RoundPath::Full,
        }
    }

    fn cwd_params(&self) -> CwdParams {
        match self.kind {
            SchedulerKind::OctopInfStaticBatch => CwdParams {
                static_batch: Some((4, 8, 2)),
                ..Default::default()
            },
            SchedulerKind::OctopInfServerOnly => {
                CwdParams { server_only: true, ..Default::default() }
            }
            _ => CwdParams::default(),
        }
    }

    fn use_coral(&self) -> bool {
        !matches!(self.kind, SchedulerKind::OctopInfNoCoral)
    }
}

impl Scheduler for Controller {
    fn name(&self) -> &'static str {
        self.kind.label()
    }

    fn plan(&mut self, env: &SchedEnv) -> Plan {
        self.last_path = RoundPath::Full;
        let params = self.cwd_params();
        // Step 2: CWD, into recycled rows.
        let mut pairs = std::mem::take(&mut self.ws.new_cfgs);
        for (_, row) in pairs.drain(..) {
            self.ws.row_pool.push(row);
        }
        cwd_ws(env, &params, &mut self.ws, &mut pairs);
        // Re-shape the (p, cfg) pairs — emitted in pipeline order — into
        // the dense per-pipeline table CORAL indexes, recycling last
        // round's rows.
        let mut cfgs = std::mem::take(&mut self.ws.plan_cfgs);
        for row in cfgs.drain(..) {
            self.ws.row_pool.push(row);
        }
        for (_, row) in pairs.drain(..) {
            cfgs.push(row);
        }
        self.ws.new_cfgs = pairs;
        // Step 3: CORAL (or the spatial spreader for the ablation).
        if !self.use_coral() {
            let plan = spread(env, &cfgs);
            self.ws.plan_cfgs = cfgs;
            return plan;
        }
        let mut plan = coral_ws(env, &cfgs, &mut self.ws);
        // Feasibility feedback: if CORAL could not reserve portions for
        // some edge-placed stages (stream time exhausted), pull those
        // stages back to the server and re-run CORAL once. This is the
        // Controller revising CWD's coarse placement against CORAL's
        // exact spatiotemporal budgets.
        if plan.unplaced > 0 {
            let mut changed = false;
            for a in &plan.assignments {
                let fully_placed =
                    a.bindings.iter().all(|b| b.temporal.is_some());
                if !fully_placed && a.cfg.device != 0 {
                    let c = &mut cfgs[a.pipeline][a.model];
                    c.device = 0;
                    changed = true;
                }
            }
            if changed {
                plan = coral_ws(env, &cfgs, &mut self.ws);
            }
        }
        self.ws.plan_cfgs = cfgs;
        plan
    }

    /// Incremental replan for drift triggers: re-run CWD only for the
    /// drifted pipelines (with the kept pipelines' configs as committed
    /// load) and repair the plan through CORAL so untouched bindings —
    /// and with them the engine's portion clocks and queues — survive
    /// verbatim. Falls back to a full round when the repair cannot do at
    /// least as well as the old plan on reservations, or when the old
    /// plan is missing assignments to keep.
    fn replan(&mut self, env: &SchedEnv, old: &Plan, drifted: &[usize]) -> Plan {
        if drifted.is_empty() {
            self.last_path = RoundPath::Repair;
            return old.clone();
        }
        if !self.use_coral() {
            return self.plan(env); // spatial-only ablation: rounds are cheap
        }
        let mut targets = std::mem::take(&mut self.ws.replan_targets);
        targets.clear();
        targets.extend_from_slice(drifted);
        targets.sort_unstable();
        targets.dedup();
        let mut kept = std::mem::take(&mut self.ws.kept);
        for (_, row) in kept.drain(..) {
            self.ws.row_pool.push(row);
        }
        // A kept pipeline missing from the old plan means the plan is
        // stale/partial; flag it and fall through to a full round with all
        // scratch restored (never early-return with buffers taken out).
        let mut stale = false;
        'keep: for p in 0..env.pipelines.len() {
            if targets.binary_search(&p).is_ok() {
                continue;
            }
            let mut cfg = self.ws.take_row();
            for m in 0..env.pipelines[p].len() {
                match old.assignment(p, m) {
                    Some(a) => cfg.push(a.cfg),
                    None => {
                        self.ws.row_pool.push(cfg);
                        stale = true;
                        break 'keep;
                    }
                }
            }
            kept.push((p, cfg));
        }
        if stale {
            for (_, row) in kept.drain(..) {
                self.ws.row_pool.push(row);
            }
            self.ws.kept = kept;
            self.ws.replan_targets = targets;
            return self.plan(env);
        }
        let params = self.cwd_params();
        let mut new_cfgs = std::mem::take(&mut self.ws.new_cfgs);
        for (_, row) in new_cfgs.drain(..) {
            self.ws.row_pool.push(row);
        }
        cwd_subset_ws(env, &params, &targets, &kept, &mut self.ws, &mut new_cfgs);
        // Capacity ratchet: between full rounds an incremental replan
        // never shrinks a stage that keeps its device and batch. Drift
        // checks sample the arrival window mid-burst-cycle; sizing down to
        // a calm reading would trade away exactly the headroom the next
        // burst needs (the autoscaler's dip path and the 6-min round do
        // the deliberate right-sizing).
        for (p, cfg) in new_cfgs.iter_mut() {
            for (m, c) in cfg.iter_mut().enumerate() {
                if let Some(a) = old.assignment(*p, m) {
                    if a.cfg.device == c.device && a.cfg.batch == c.batch {
                        c.instances = c.instances.max(a.cfg.instances);
                    }
                }
            }
        }
        let repaired = coral_repair_ws(env, old, &new_cfgs, &mut self.ws);
        for (_, row) in kept.drain(..) {
            self.ws.row_pool.push(row);
        }
        self.ws.kept = kept;
        self.ws.replan_targets = targets;
        self.ws.new_cfgs = new_cfgs;
        if repaired.unplaced > old.unplaced {
            self.plan(env)
        } else {
            self.last_path = RoundPath::Repair;
            repaired
        }
    }

    /// Survivor re-placement after a device fault: exactly the pipelines
    /// with a stage on the faulted device are re-planned (the crash
    /// notification zeroes the device's bandwidth in `env`, steering
    /// CWD's feasibility tests elsewhere; recovery restores it and the
    /// same hook moves work back). Everything else rides the incremental
    /// path, so unaffected groups keep their queues and portion clocks
    /// bit-for-bit. A fault on a device hosting nothing is the identity.
    fn on_fault(&mut self, env: &SchedEnv, old: &Plan, device: usize) -> Plan {
        // Affected: stages currently on the device (crash side), plus
        // pipelines sourced there (recover side — after the crash replan
        // evacuated the device, these are the ones that may move back).
        let affected: Vec<usize> = (0..env.pipelines.len())
            .filter(|&p| {
                env.pipelines[p].source_device == device
                    || (0..env.pipelines[p].len()).any(|m| {
                        old.assignment(p, m)
                            .map_or(true, |a| a.cfg.device == device)
                    })
            })
            .collect();
        if affected.is_empty() {
            self.last_path = RoundPath::Repair;
            return old.clone();
        }
        self.replan(env, old, &affected)
    }

    fn round_path(&self) -> RoundPath {
        self.last_path
    }
}

/// Factory covering OctopInf variants and all baselines.
pub fn make_scheduler(kind: SchedulerKind, seed: u64) -> Box<dyn Scheduler> {
    use super::baselines::{Distream, Jellyfish, Rim};
    match kind {
        SchedulerKind::Distream => Box::new(Distream::new(seed)),
        SchedulerKind::Jellyfish => Box::new(Jellyfish::new()),
        SchedulerKind::Rim => Box::new(Rim::new()),
        _ => Box::new(Controller::new(kind)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::pipeline::standard_pipelines;
    use crate::profiles::ProfileStore;

    fn fixture() -> (Cluster, ProfileStore, Vec<crate::pipeline::PipelineDag>) {
        let pipelines = standard_pipelines(3)
            .into_iter()
            .map(|mut p| {
                p.source_device += 1;
                p
            })
            .collect();
        (Cluster::paper_testbed(), ProfileStore::analytic(), pipelines)
    }

    #[test]
    fn octopinf_plan_is_temporal() {
        let (cl, pf, pl) = fixture();
        let env = SchedEnv::bootstrap(&cl, &pf, &pl, vec![80.0; 10]);
        let plan = Controller::new(SchedulerKind::OctopInf).plan(&env);
        let temporal = plan
            .assignments
            .iter()
            .flat_map(|a| a.bindings.iter())
            .filter(|b| b.temporal.is_some())
            .count();
        assert!(temporal > 0, "OctopInf must temporally schedule");
    }

    #[test]
    fn no_coral_ablation_is_spatial_only() {
        let (cl, pf, pl) = fixture();
        let env = SchedEnv::bootstrap(&cl, &pf, &pl, vec![80.0; 10]);
        let plan = Controller::new(SchedulerKind::OctopInfNoCoral).plan(&env);
        assert!(plan
            .assignments
            .iter()
            .all(|a| a.bindings.iter().all(|b| b.temporal.is_none())));
    }

    #[test]
    fn server_only_ablation_never_uses_edge() {
        let (cl, pf, pl) = fixture();
        let env = SchedEnv::bootstrap(&cl, &pf, &pl, vec![80.0; 10]);
        let plan = Controller::new(SchedulerKind::OctopInfServerOnly).plan(&env);
        assert!(plan.assignments.iter().all(|a| a.cfg.device == 0));
    }

    #[test]
    fn incremental_replan_keeps_undrifted_pipelines() {
        let (cl, pf, pl) = fixture();
        let env = SchedEnv::bootstrap(&cl, &pf, &pl, vec![80.0; 10]);
        let mut ctl = Controller::new(SchedulerKind::OctopInf);
        let old = ctl.plan(&env);
        // Pipeline 2's workload triples; replan just that pipeline.
        let mut surged = SchedEnv::bootstrap(&cl, &pf, &pl, vec![80.0; 10]);
        for o in surged.obs[2].iter_mut() {
            o.rate_qps *= 3.0;
        }
        let new = ctl.replan(&surged, &old, &[2]);
        // Coverage is intact and the kept pipelines' configs are identical.
        for p in [0usize, 1] {
            for m in 0..pl[p].len() {
                assert_eq!(
                    old.assignment(p, m).unwrap().cfg,
                    new.assignment(p, m).unwrap().cfg,
                    "kept {p}/{m} changed"
                );
            }
        }
        for m in 0..pl[2].len() {
            assert!(new.assignment(2, m).is_some(), "drifted 2/{m} missing");
        }
        // Empty drift set is the identity.
        let same = ctl.replan(&env, &old, &[]);
        assert_eq!(same.assignments.len(), old.assignments.len());
    }

    #[test]
    fn round_path_reports_repair_vs_full() {
        let (cl, pf, pl) = fixture();
        let env = SchedEnv::bootstrap(&cl, &pf, &pl, vec![80.0; 10]);
        let mut ctl = Controller::new(SchedulerKind::OctopInf);
        assert_eq!(ctl.round_path(), RoundPath::Full, "before any round");
        let old = ctl.plan(&env);
        assert_eq!(ctl.round_path(), RoundPath::Full);
        // An accepted incremental repair reports Repair; the fixture's
        // single-pipeline drift never regresses reservations, so the
        // fallback-to-full branch is not taken here.
        let new = ctl.replan(&env, &old, &[2]);
        assert!(new.unplaced <= old.unplaced);
        assert_eq!(ctl.round_path(), RoundPath::Repair);
        // Full rounds flip it back...
        let _ = ctl.plan(&env);
        assert_eq!(ctl.round_path(), RoundPath::Full);
        // ...and the empty-drift identity is an (extreme) repair.
        let _ = ctl.replan(&env, &old, &[]);
        assert_eq!(ctl.round_path(), RoundPath::Repair);
        // Baselines only ever solve from scratch: trait default.
        let mut base = make_scheduler(SchedulerKind::Jellyfish, 1);
        let _ = base.plan(&env);
        assert_eq!(base.round_path(), RoundPath::Full);
    }

    #[test]
    fn on_fault_evacuates_the_dead_device_and_keeps_the_rest() {
        let (cl, pf, pl) = fixture();
        let env = SchedEnv::bootstrap(&cl, &pf, &pl, vec![80.0; 10]);
        let mut ctl = Controller::new(SchedulerKind::OctopInf);
        let old = ctl.plan(&env);
        // Crash device 1 (pipeline 0's source): its bandwidth snapshot
        // arrives zeroed, exactly as the engine delivers it.
        let mut bw = vec![80.0; 10];
        bw[1] = 0.0;
        let crashed = SchedEnv::bootstrap(&cl, &pf, &pl, bw);
        let new = ctl.on_fault(&crashed, &old, 1);
        for a in &new.assignments {
            assert_ne!(a.cfg.device, 1, "stage {}/{} left on dead device", a.pipeline, a.model);
        }
        // Pipelines with no stake in device 1 keep their configs verbatim.
        for p in 1..pl.len() {
            if pl[p].source_device == 1 {
                continue;
            }
            let untouched = (0..pl[p].len()).all(|m| {
                old.assignment(p, m).map_or(false, |a| a.cfg.device != 1)
            });
            if untouched {
                for m in 0..pl[p].len() {
                    assert_eq!(
                        old.assignment(p, m).unwrap().cfg,
                        new.assignment(p, m).unwrap().cfg,
                        "unaffected {p}/{m} changed"
                    );
                }
            }
        }
        // A fault on a device hosting nothing is the identity.
        let idle = ctl.on_fault(&env, &old, 6);
        assert_eq!(idle.assignments.len(), old.assignments.len());
        for a in &old.assignments {
            assert_eq!(
                idle.assignment(a.pipeline, a.model).unwrap().cfg,
                a.cfg
            );
        }
    }

    #[test]
    fn factory_builds_every_kind() {
        for kind in [
            SchedulerKind::OctopInf,
            SchedulerKind::OctopInfNoCoral,
            SchedulerKind::OctopInfStaticBatch,
            SchedulerKind::OctopInfServerOnly,
            SchedulerKind::Distream,
            SchedulerKind::Jellyfish,
            SchedulerKind::Rim,
        ] {
            let mut s = make_scheduler(kind, 7);
            assert_eq!(s.name(), kind.label());
            let (cl, pf, pl) = fixture();
            let env = SchedEnv::bootstrap(&cl, &pf, &pl, vec![80.0; 10]);
            let plan = s.plan(&env);
            assert!(!plan.assignments.is_empty());
        }
    }

    /// A controller that has already been through full plan + surge replan
    /// + fault replan (workspace warm and full of recycled state) must
    /// produce rounds bit-identical to a freshly-built controller's.
    #[test]
    fn warm_controller_matches_fresh_controller_bit_for_bit() {
        let (cl, pf, pl) = fixture();
        let env = SchedEnv::bootstrap(&cl, &pf, &pl, vec![80.0; 10]);
        let mut warm = Controller::new(SchedulerKind::OctopInf);
        let old = warm.plan(&env);
        let mut surged = SchedEnv::bootstrap(&cl, &pf, &pl, vec![80.0; 10]);
        for o in surged.obs[1].iter_mut() {
            o.rate_qps *= 2.5;
        }
        let warm_replan = warm.replan(&surged, &old, &[1]);
        let warm_full = warm.plan(&env);

        let fresh_full = Controller::new(SchedulerKind::OctopInf).plan(&env);
        assert!(warm_full.bit_eq(&fresh_full), "warm full round diverged");
        let mut fresh = Controller::new(SchedulerKind::OctopInf);
        let fresh_old = fresh.plan(&env);
        let fresh_replan = fresh.replan(&surged, &fresh_old, &[1]);
        assert!(warm_replan.bit_eq(&fresh_replan), "warm replan diverged");
    }
}
