//! `octopinf` CLI — leader entrypoint.
//!
//! Subcommands:
//!   profile   — execute every AOT artifact via PJRT, write profiles.tsv
//!   simulate  — run one scheduler over a scenario, print metrics
//!   figure N  — regenerate a paper figure/table (6..11, or `1` for Tab. I)
//!   serve     — stand up the real PJRT serving stack on synthetic traffic

use std::collections::HashMap;
use std::path::Path;

use octopinf::anyhow;
use octopinf::util::error::Result;

use octopinf::config::ExperimentConfig;
use octopinf::coordinator::SchedulerKind;
use octopinf::experiments;
use octopinf::runtime::{default_artifacts_dir, Runtime};
use octopinf::serving::{
    serve_front, FilterCfg, FrontDoorCfg, ModelServeCfg, Request,
};
use octopinf::sim::Scenario;
use octopinf::util::cli::Args;
use octopinf::util::table::{fnum, Table};

const USAGE: &str = "usage: octopinf <profile|simulate|figure|fuzz|drift|chaos|why|serve|frontdoor> [options]
  profile  [--reps 5] [--out artifacts/profiles.tsv]
  simulate [--scenario standard|lte|double|slo50|slo100|longterm|smoke|static]
           [--scheduler octopinf|distream|jellyfish|rim|no-coral|static-batch|server-only]
           [--seed 42] [--duration-min N] [--replan periodic|drift]
           [--clusters N]  independent edge clusters (sim partitions;
                           part of the workload, default 1)
           [--sim-jobs N]  worker threads ticking the partitions (0 = all
                           cores; pure wall-clock knob — metrics and the
                           printed digest are byte-identical at any value)
           [--trace FILE]  export per-query spans / GPU lanes / planner
                           rounds as Chrome-trace JSON (chrome://tracing;
                           sim-clock stamps, byte-identical at any
                           --sim-jobs)
  figure   <1|6|7|8|9|10|11> [--quick] [--jobs N]   (N=0: all cores)
  fuzz     [--scenarios 50] [--seed0 3735928559] [--jobs N]
           [--replan periodic|drift] [--sim-jobs N] [--clusters N]
           [--repro fuzz:v1:seed=N[:replan=drift][:faults=M][:order=K][:horizon=H][:clusters=C]]
           [--trace FILE]  (requires --repro: traced replay of that one
                           scenario under the reference scheduler)
  drift    [--per-family 4] [--seed0 3735928559] [--jobs N] [--sim-jobs N]
           (fixed-period vs drift-triggered OctopInf per fuzz family)
  chaos    [--storms 8] [--seed0 3299893997] [--jobs N]
           [--replan periodic|drift] [--sim-jobs N] [--clusters N] [--help]
           (recovery on/off across fault storms; see `chaos --help`)
  why      --repro fuzz:v1:seed=N[...] [--sim-jobs N] [--trace FILE]
           (postmortem for one repro: SLO-miss attribution by component,
            dominant-cause breakdown, plan-round provenance, invariants)
  serve    [--duration-s 10] [--fps 30] [--slo-ms 200] [--shards 2]
           [--tenants 1] [--tenant-rate R] [--filter on|off]
           [--metrics-out FILE] [--help]
  frontdoor [--quick] [--help]
           (front-door evidence: filter gain, tenant isolation, sim
            frontend conformance; non-zero exit if any bar is missed)";

/// Serving knobs behind `octopinf serve` (satisfies `--help`).
const SERVE_HELP: &str = "octopinf serve — real PJRT serving stack on synthetic camera traffic
Client threads stream detector frames plus fanned-out crops through the
production front door (sharded fair batchers -> bounded ring -> executor).

options:
  --duration-s S      traffic duration (default 10)
  --fps N             frames per second (default 30)
  --slo-ms MS         request SLO (default 200)
  --shards N          batcher shards models hash across (default 2)
  --tenants N         spread the synthetic clients over N tenant ids
                      (default 1; >1 exercises weighted-fair dequeue)
  --tenant-rate R     per-tenant admission rate, requests/s (default
                      unlimited; excess answered `throttled` with a
                      retry-after hint)
  --filter on|off     content-aware frontend: frame-diff filter + result
                      cache in front of admission (default off)
  --metrics-out FILE  write the final ServeReport as Prometheus text
                      exposition (counters, per-model/tenant series,
                      latency + queue-wait + exec-time quantiles)";

/// What `octopinf frontdoor` measures (satisfies `--help`).
const FRONTDOOR_HELP: &str = "octopinf frontdoor — front-door isolation & filtering evidence
Three deterministic comparisons, no PJRT required:
  1. static-scene load, content filter off vs on (logical-clock harness
     over the real FrontDoor): effective throughput must gain >= 3x at
     no loss of SLO attainment;
  2. two-tenant flash crowd, isolation off vs on: the steady tenant's
     attainment must stay >= 0.9 isolated and the un-isolated baseline
     must demonstrably collapse;
  3. sim `static` scenario, frontend off vs on under the invariant
     engine: identical workload fingerprint, zero violations.
Exits non-zero (listing the missed bars) if any check fails.

options:
  --quick             smaller loads / shorter horizons (CI smoke)";

/// Recovery-policy knobs behind `octopinf chaos` (satisfies `--help`).
const CHAOS_HELP: &str = "octopinf chaos — fault-injection comparison
Runs every main scheduler across seeded FaultStorm scenarios twice:
with failure-aware recovery enabled and disabled. Invariants are armed
on every run — a storm that loses a query unaccounted fails the sweep.

options:
  --storms N          fault-storm scenarios per scheduler (default 8)
  --seed0 N           base seed for the storm specs (default 0xC4A0_5EED)
  --jobs N            worker threads over storm cells (0 = all cores);
                      output is byte-identical at any job count
  --sim-jobs N        worker threads over cluster partitions *inside*
                      each simulation (0 = all cores); equally
                      byte-identical at any value — CI diffs the digest
                      line across --sim-jobs 1 and 4
  --clusters N        independent edge clusters per storm (default 1;
                      part of the workload and of each repro string)
  --replan MODE       periodic|drift — replan clock both arms run under

recovery-policy knobs (config file `[experiment]` / repro string):
  faults = M          fault windows sampled over the run (`:faults=M`);
                      M in 1..=64, 0 disables injection
  order = K           same-time event permutation seed (`:order=K`);
                      0 = insertion order, any K is replayable
  recovery = on|off   failure-aware replanning: crash/recover plan
                      repair + post-outage catch-up round (default on;
                      the chaos command sweeps both)
  crash_policy = reroute|drop
                      reroute: a crashed device's queued queries survive
                      for live migration to survivors (default)
                      drop: the queue dies with the device, accounted as
                      lost_to_fault";

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().cloned().unwrap_or_default();
    let code = match cmd.as_str() {
        "profile" => cmd_profile(&args),
        "simulate" => cmd_simulate(&args),
        "figure" => cmd_figure(&args),
        "fuzz" => cmd_fuzz(&args),
        "drift" => cmd_drift(&args),
        "chaos" => cmd_chaos(&args),
        "why" => cmd_why(&args),
        "serve" => cmd_serve(&args),
        "frontdoor" => cmd_frontdoor(&args),
        _ => {
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = code {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Measure real PJRT batch latencies for every artifact.
fn cmd_profile(args: &Args) -> Result<()> {
    let dir = default_artifacts_dir();
    let reps = args.get_usize("reps", 3);
    // Interpret-mode detector convs are slow on CPU at large batches; the
    // affine fit only needs a few points (BatchCurve::fit extrapolates).
    let max_batch = args.get_usize("max-batch", 8);
    let out = args.get_or("out", "artifacts/profiles.tsv").to_string();
    let mut rt = Runtime::new(&dir)?;
    let models: Vec<String> =
        rt.models().into_iter().map(String::from).collect();
    let mut t = Table::new(vec!["family", "batch", "lat_ms"]);
    let mut tsv = String::from("family\tbatch\tlat_ms\n");
    for model in &models {
        let batches: Vec<usize> = rt
            .manifest
            .batches(model)
            .into_iter()
            .filter(|&b| b <= max_batch)
            .collect();
        for batch in batches {
            let ms = rt.profile(model, batch, reps)?;
            t.row(vec![model.clone(), batch.to_string(), fnum(ms, 3)]);
            tsv.push_str(&format!("{model}\t{batch}\t{ms:.4}\n"));
        }
    }
    std::fs::write(&out, tsv)?;
    println!("{}", t.to_markdown());
    println!("\nwrote {out}");
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let scen_name = args.get_or("scenario", "standard");
    let mut cfg: ExperimentConfig = octopinf::sim::scenario::preset(scen_name)
        .ok_or_else(|| anyhow!("unknown scenario {scen_name:?}"))?;
    cfg.seed = args.get_u64("seed", cfg.seed);
    if let Some(d) = args.get("duration-min") {
        cfg.duration_ms = d.parse::<f64>()? * 60_000.0;
    }
    cfg.replan = parse_replan(args)?;
    cfg.clusters = args.get_usize("clusters", cfg.clusters);
    cfg.validate().map_err(|e| anyhow!("{e}"))?;
    let sim_jobs = args.get_usize("sim-jobs", 1);
    let kind = SchedulerKind::parse(args.get_or("scheduler", "octopinf"))
        .ok_or_else(|| anyhow!("unknown scheduler"))?;
    let replan = cfg.replan;
    let clusters = cfg.clusters;
    let sc = Scenario::build(cfg);
    let m = if let Some(path) = args.get("trace") {
        let (m, parts) = octopinf::sim::run_traced_with(&sc, kind, sim_jobs);
        write_trace(path, &parts)?;
        m
    } else {
        octopinf::sim::run_with(&sc, kind, sim_jobs)
    };
    let mut t = Table::new(vec!["metric", "value"]);
    t.row(vec!["scheduler".to_string(), kind.label().to_string()]);
    t.row(vec!["replan".into(), replan.label().to_string()]);
    t.row(vec!["clusters".into(), clusters.to_string()]);
    t.row(vec!["effective_thpt(obj/s)".into(), fnum(m.effective_throughput(), 2)]);
    t.row(vec!["total_thpt(obj/s)".into(), fnum(m.total_throughput(), 2)]);
    t.row(vec!["violation_rate".into(), fnum(m.violation_rate(), 3)]);
    t.row(vec!["latency_p50(ms)".into(), fnum(m.latency.p50(), 1)]);
    t.row(vec!["latency_p95(ms)".into(), fnum(m.latency.p95(), 1)]);
    t.row(vec!["latency_p99(ms)".into(), fnum(m.latency.p99(), 1)]);
    t.row(vec!["peak_memory(MB)".into(), fnum(m.peak_memory_mb, 0)]);
    t.row(vec!["mean_gpu_util".into(), fnum(m.mean_gpu_util, 3)]);
    t.row(vec!["dropped".into(), m.dropped.to_string()]);
    t.row(vec!["filtered".into(), m.filtered.to_string()]);
    println!("{}", t.to_markdown());
    println!("\nlatency histogram: {}", m.latency_hist.sparkline());
    print_attribution(&m);
    // Bit-exact run fingerprint — must not move across --sim-jobs values.
    println!("digest: {:016x}", m.digest());
    Ok(())
}

/// Render the per-component latency decomposition (always on in the
/// engine; empty only when nothing completed).
fn print_attribution(m: &octopinf::metrics::RunMetrics) {
    let a = &m.attrib;
    if a.transfer.is_empty() {
        return;
    }
    println!(
        "attribution p50/p95 (ms): transfer {}/{}  queue {}/{}  exec {}/{}",
        fnum(a.transfer.p50(), 1),
        fnum(a.transfer.p95(), 1),
        fnum(a.queue.p50(), 1),
        fnum(a.queue.p95(), 1),
        fnum(a.exec.p50(), 1),
        fnum(a.exec.p95(), 1),
    );
    if a.misses() > 0 {
        println!("slo-miss dominant causes: {}", a.miss_breakdown());
    }
}

/// Export per-partition traces as Chrome-trace JSON, re-validating the
/// bytes before they land on disk.
fn write_trace(path: &str, parts: &[Vec<octopinf::obs::TraceEvent>]) -> Result<()> {
    let json = octopinf::obs::chrome_trace(parts);
    octopinf::obs::validate_json(&json)
        .map_err(|e| anyhow!("trace export produced invalid JSON: {e}"))?;
    std::fs::write(path, &json)?;
    let n: usize = parts.iter().map(Vec::len).sum();
    println!("wrote {path} ({n} trace events, {} partitions)", parts.len());
    Ok(())
}

fn cmd_figure(args: &Args) -> Result<()> {
    let which = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow!("figure number required (1, 6..11)"))?;
    let quick = args.flag("quick");
    // Grid cells fan out across `jobs` workers (0 = all hardware
    // threads); tables are byte-identical at any job count.
    let jobs = args.jobs();
    match which.as_str() {
        "1" => println!("{}", experiments::table1().to_markdown()),
        "6" => {
            println!("## Fig. 6a-c: overall comparison\n");
            println!("{}", experiments::fig6_overall(quick, jobs).to_markdown());
            println!("\n## Fig. 6d: OctopInf workload tracking\n");
            println!("{}", experiments::fig6_timeline(quick).to_markdown());
        }
        "7" => {
            for (name, t) in experiments::fig7_adaptivity(quick, jobs) {
                println!("## Fig. 7: {name}\n\n{}\n", t.to_markdown());
            }
        }
        "8" => println!("{}", experiments::fig8_scale(quick, jobs).to_markdown()),
        "9" => println!("{}", experiments::fig9_slo(quick, jobs).to_markdown()),
        "10" => {
            println!("{}", experiments::fig10_ablation(quick, jobs).to_markdown())
        }
        "11" => println!("{}", experiments::fig11_longterm(quick).to_markdown()),
        other => return Err(anyhow!("unknown figure {other:?}")),
    }
    Ok(())
}

/// Differential conformance fuzzing: randomized adversarial scenarios
/// through every scheduler under the invariant engine. Exits non-zero on
/// any violation; each row carries its one-line repro string.
fn cmd_fuzz(args: &Args) -> Result<()> {
    use octopinf::experiments::fuzz::{
        conformance_digest, conformance_round_with, run_conformance_with,
    };
    use octopinf::sim::FuzzSpec;

    let mode = parse_replan(args)?;
    let sim_jobs = args.get_usize("sim-jobs", 1);
    if let Some(r) = args.get("repro") {
        let spec = FuzzSpec::from_repro(r).ok_or_else(|| {
            anyhow!(
                "bad repro string {r:?} (expected fuzz:v1:seed=N\
                 [:replan=drift][:faults=M][:order=K][:horizon=H][:clusters=C])"
            )
        })?;
        // A mode embedded in the repro string wins over the --replan flag:
        // the string must replay exactly the failing configuration.
        let mode = if r.contains(":replan=") { spec.cfg.replan } else { mode };
        println!("replaying {spec} [{}]\n", mode.label());
        if let Some(path) = args.get("trace") {
            let mut tspec = spec.clone();
            tspec.cfg.replan = mode;
            let (tm, treport, parts) =
                octopinf::experiments::fuzz::traced_replay(&tspec, sim_jobs);
            write_trace(path, &parts)?;
            println!(
                "traced replay [octopinf]: {} completions, digest {:016x}",
                tm.completed(),
                tm.digest()
            );
            if !treport.ok() {
                return Err(anyhow!(
                    "invariant violations during traced replay:\n{}",
                    treport.violations.join("\n")
                ));
            }
        }
        let out = conformance_round_with(&spec, mode, sim_jobs);
        if out.ok() {
            println!(
                "OK: {} schedulers, {} completions, no violations",
                out.runs, out.total_completions
            );
            println!("digest: {:016x}", out.metrics_digest);
            return Ok(());
        }
        return Err(anyhow!("conformance failed:\n{}", out.describe_failures()));
    }

    if args.get("trace").is_some() {
        return Err(anyhow!(
            "--trace requires --repro (trace one scenario, not a sweep)"
        ));
    }
    let n = args.get_usize("scenarios", 50);
    let seed0 = args.get_u64("seed0", 0xDEAD_BEEF);
    let clusters = args.get_usize("clusters", 1);
    let outcomes =
        run_conformance_with(seed0, n, args.jobs(), mode, sim_jobs, clusters);
    let mut t = Table::new(vec!["repro", "class", "completions", "result"]);
    let mut failures = Vec::new();
    for o in &outcomes {
        let result = if o.ok() {
            "ok".to_string()
        } else {
            failures.push(o.describe_failures());
            format!(
                "{} violations, {} divergences",
                o.violations.len(),
                o.divergences.len()
            )
        };
        t.row(vec![
            o.spec.repro(),
            o.spec.class.label().to_string(),
            o.total_completions.to_string(),
            result,
        ]);
    }
    println!("{}", t.to_markdown());
    println!(
        "\n{} scenarios x {} schedulers: {} failed",
        outcomes.len(),
        octopinf::coordinator::SchedulerKind::conformance_set().len(),
        failures.len()
    );
    // Bit-exact sweep fingerprint; ci.sh diffs this line across
    // --sim-jobs values.
    println!("digest: {:016x}", conformance_digest(&outcomes));
    if !failures.is_empty() {
        return Err(anyhow!(
            "conformance failures (replay with `octopinf fuzz --repro <string>`):\n{}",
            failures.join("\n")
        ));
    }
    Ok(())
}

/// Shared `--replan` axis parser (default: the paper's periodic clock).
fn parse_replan(args: &Args) -> Result<octopinf::coordinator::ReplanMode> {
    let raw = args.get_or("replan", "periodic");
    octopinf::coordinator::ReplanMode::parse(raw)
        .ok_or_else(|| anyhow!("unknown replan mode {raw:?} (periodic|drift)"))
}

/// Graceful-degradation comparison: every scheduler across fault storms,
/// recovery enabled vs disabled, invariants armed on every run.
fn cmd_chaos(args: &Args) -> Result<()> {
    if args.flag("help") {
        println!("{CHAOS_HELP}");
        return Ok(());
    }
    let n = args.get_usize("storms", 8);
    let seed0 = args.get_u64("seed0", 0xC4A0_5EED);
    let mode = parse_replan(args)?;
    let sim_jobs = args.get_usize("sim-jobs", 1);
    let clusters = args.get_usize("clusters", 1);
    let cmps = experiments::chaos_comparison_with(
        seed0,
        n,
        args.jobs(),
        mode,
        sim_jobs,
        clusters,
    );
    println!("{}", experiments::chaos_table(&cmps).to_markdown());
    let violations: usize = cmps.iter().map(|c| c.violations).sum();
    let lost: u64 = cmps
        .iter()
        .map(|c| c.recovery.lost_to_fault + c.no_recovery.lost_to_fault)
        .sum();
    println!(
        "\n{} schedulers x {n} storms x 2 recovery modes [{}]; \
         {lost} queries lost to faults (every one accounted); \
         {violations} invariant violations",
        cmps.len(),
        mode.label(),
    );
    if violations > 0 {
        return Err(anyhow!("invariant violations during chaos comparison"));
    }
    // Bit-exact run fingerprint; ci.sh diffs this line across --sim-jobs
    // values.
    println!("digest: {:016x}", experiments::chaos_digest(&cmps));
    Ok(())
}

/// Postmortem for one repro string: traced replay under the reference
/// scheduler, latency decomposed per component, SLO misses attributed to
/// their dominant cause, plan rounds tallied by trigger and path.
fn cmd_why(args: &Args) -> Result<()> {
    use octopinf::experiments::fuzz::traced_replay;
    use octopinf::obs::{RoundPath, TraceEvent};
    use octopinf::sim::FuzzSpec;

    let r = args.get("repro").ok_or_else(|| {
        anyhow!(
            "why requires --repro fuzz:v1:seed=N\
             [:replan=drift][:faults=M][:order=K][:horizon=H][:clusters=C]"
        )
    })?;
    let spec = FuzzSpec::from_repro(r)
        .ok_or_else(|| anyhow!("bad repro string {r:?}"))?;
    let sim_jobs = args.get_usize("sim-jobs", 1);
    println!("postmortem for {spec}\n");
    let (m, report, parts) = traced_replay(&spec, sim_jobs);

    let mut t = Table::new(vec!["metric", "value"]);
    t.row(vec!["completed(obj)".to_string(), m.completed().to_string()]);
    t.row(vec!["on_time".into(), m.on_time.to_string()]);
    t.row(vec!["late".into(), m.late.to_string()]);
    t.row(vec!["dropped".into(), m.dropped.to_string()]);
    t.row(vec!["lost_to_fault".into(), m.lost_to_fault.to_string()]);
    t.row(vec!["violation_rate".into(), fnum(m.violation_rate(), 3)]);
    t.row(vec!["latency_p50(ms)".into(), fnum(m.latency.p50(), 1)]);
    t.row(vec!["latency_p95(ms)".into(), fnum(m.latency.p95(), 1)]);
    t.row(vec!["latency_p99(ms)".into(), fnum(m.latency.p99(), 1)]);
    println!("{}", t.to_markdown());
    println!();
    print_attribution(&m);
    if m.attrib.misses() == 0 {
        println!("no SLO misses: every completed query met its deadline");
    }

    // Control-plane provenance straight from the trace's Plan events.
    let mut rounds = 0usize;
    let mut repairs = 0usize;
    let mut migrations = 0u64;
    let mut by_trigger: std::collections::BTreeMap<&str, usize> =
        std::collections::BTreeMap::new();
    for ev in parts.iter().flatten() {
        if let TraceEvent::Plan { trigger, path, migrations: mig, .. } = ev {
            rounds += 1;
            if *path == RoundPath::Repair {
                repairs += 1;
            }
            migrations += u64::from(*mig);
            *by_trigger.entry(trigger.label()).or_insert(0) += 1;
        }
    }
    let triggers: Vec<String> = by_trigger
        .iter()
        .map(|(k, v)| format!("{k} {v}"))
        .collect();
    println!(
        "control plane: {rounds} plan rounds ({repairs} repair, {} full), \
         {migrations} group migrations; triggers: {}",
        rounds - repairs,
        triggers.join(" / ")
    );

    if let Some(path) = args.get("trace") {
        write_trace(path, &parts)?;
    }
    if !report.ok() {
        return Err(anyhow!(
            "invariant violations during replay (flight recorder dumped above):\n{}",
            report.violations.join("\n")
        ));
    }
    println!("invariants: clean ({} completions)", report.completed_queries);
    Ok(())
}

/// Fixed-period vs drift-triggered OctopInf across the fuzz families,
/// same seeds, invariants armed on every run.
fn cmd_drift(args: &Args) -> Result<()> {
    let per_family = args.get_usize("per-family", 4);
    let seed0 = args.get_u64("seed0", 0xDEAD_BEEF);
    let cmps = experiments::drift_comparison_with(
        seed0,
        per_family,
        args.jobs(),
        args.get_usize("sim-jobs", 1),
    );
    println!("{}", experiments::drift_table(&cmps).to_markdown());
    let violations: usize = cmps.iter().map(|c| c.violations).sum();
    println!(
        "\n{} families x {per_family} scenarios x 2 modes; {} invariant violations",
        cmps.len(),
        violations
    );
    if violations > 0 {
        return Err(anyhow!("invariant violations during drift comparison"));
    }
    Ok(())
}

/// Real serving demo: synthetic camera traffic through the PJRT stack.
fn cmd_serve(args: &Args) -> Result<()> {
    if args.flag("help") {
        println!("{SERVE_HELP}");
        return Ok(());
    }
    let duration_s = args.get_f64("duration-s", 10.0);
    let fps = args.get_f64("fps", 30.0);
    let slo_ms = args.get_f64("slo-ms", 200.0);
    let n_tenants = args.get_u64("tenants", 1).max(1) as u32;
    let mut front = FrontDoorCfg::default();
    front.shards = args.get_usize("shards", front.shards).max(1);
    if let Some(r) = args.get("tenant-rate") {
        front.tenants.rate_per_s = r.parse::<f64>()?;
    }
    match args.get_or("filter", "off") {
        "on" => front.filter = Some(FilterCfg::default()),
        "off" => {}
        other => return Err(anyhow!("--filter {other:?} (expected on|off)")),
    }
    let dir = default_artifacts_dir();
    if !Path::new(&dir).join("manifest.tsv").exists() {
        return Err(anyhow!("artifacts missing — run `make artifacts`"));
    }

    let mut cfgs = HashMap::new();
    cfgs.insert("det_m".to_string(), ModelServeCfg::new(4, 25.0));
    cfgs.insert("classifier".to_string(), ModelServeCfg::new(8, 15.0));
    cfgs.insert("embedder".to_string(), ModelServeCfg::new(8, 15.0));

    let (req_tx, req_rx) = std::sync::mpsc::channel::<Request>();
    let (resp_tx, resp_rx) = std::sync::mpsc::channel();

    // Client thread: frames at `fps`, plus crops fanned out per frame.
    // Frames round-robin across tenants; each tenant owns one camera
    // stream (the filter's unit of state).
    let gen = std::thread::spawn(move || {
        let mut rng = octopinf::util::Rng::new(7);
        let frame_px = 128 * 128 * 3;
        let crop_px = 32 * 32 * 3;
        let n_frames = (duration_s * fps) as u64;
        let mut id = 0u64;
        for f in 0..n_frames {
            let t0 = std::time::Instant::now();
            let tenant = (f % n_tenants as u64) as u32;
            id += 1;
            let _ = req_tx.send(Request {
                id,
                model: "det_m".into(),
                data: (0..frame_px).map(|_| rng.f64() as f32).collect(),
                slo_ms,
                tenant,
                stream: tenant as u64,
                submitted: std::time::Instant::now(),
            });
            for _ in 0..rng.poisson(4.0) {
                id += 1;
                let model =
                    if rng.chance(0.6) { "classifier" } else { "embedder" };
                let _ = req_tx.send(Request {
                    id,
                    model: model.into(),
                    data: (0..crop_px).map(|_| rng.f64() as f32).collect(),
                    slo_ms,
                    tenant,
                    stream: id,
                    submitted: std::time::Instant::now(),
                });
            }
            let frame_period = std::time::Duration::from_secs_f64(1.0 / fps);
            if let Some(rest) = frame_period.checked_sub(t0.elapsed()) {
                std::thread::sleep(rest);
            }
        }
        // Dropping req_tx closes the stream.
    });

    // Drain responses concurrently so the channel never backs up.
    let drain = std::thread::spawn(move || {
        let mut n = 0u64;
        while resp_rx.recv().is_ok() {
            n += 1;
        }
        n
    });

    let report = serve_front(&dir, &cfgs, front, req_rx, resp_tx)?;
    gen.join().unwrap();
    let delivered = drain.join().unwrap();

    let mut t = Table::new(vec!["metric", "value"]);
    t.row(vec!["submitted".to_string(), report.submitted.to_string()]);
    t.row(vec!["served".into(), report.served.to_string()]);
    t.row(vec!["delivered".into(), delivered.to_string()]);
    t.row(vec!["on_time".into(), report.on_time.to_string()]);
    t.row(vec!["filtered".into(), report.filtered.to_string()]);
    t.row(vec!["cache_hits".into(), report.cache_hits.to_string()]);
    t.row(vec!["throttled".into(), report.throttled.to_string()]);
    t.row(vec!["rejected".into(), report.rejected.to_string()]);
    t.row(vec!["shed".into(), report.shed.to_string()]);
    t.row(vec!["slo_attainment".into(), fnum(report.slo_attainment(), 3)]);
    t.row(vec!["eff_thpt(req/s)".into(), fnum(report.effective_throughput(), 1)]);
    t.row(vec!["latency_p50(ms)".into(), fnum(report.latency.p50(), 2)]);
    t.row(vec!["latency_p95(ms)".into(), fnum(report.latency.p95(), 2)]);
    t.row(vec!["latency_p99(ms)".into(), fnum(report.latency.p99(), 2)]);
    println!("{}", t.to_markdown());
    if n_tenants > 1 {
        let mut tt = Table::new(vec![
            "tenant", "submitted", "served", "on_time", "throttled", "attain",
        ]);
        for (id, l) in &report.per_tenant {
            tt.row(vec![
                id.to_string(),
                l.submitted.to_string(),
                l.served.to_string(),
                l.on_time.to_string(),
                l.throttled.to_string(),
                fnum(l.attainment(), 3),
            ]);
        }
        println!("\n{}", tt.to_markdown());
    }
    if let Some(path) = args.get("metrics-out") {
        let text = octopinf::obs::promtext::render_serve_report(&report);
        std::fs::write(path, &text)?;
        println!("\nwrote {path} (Prometheus text exposition)");
    }
    Ok(())
}

/// Front-door evidence run: filter gain, tenant isolation, and sim
/// frontend conformance — exits non-zero when a bar is missed.
fn cmd_frontdoor(args: &Args) -> Result<()> {
    if args.flag("help") {
        println!("{FRONTDOOR_HELP}");
        return Ok(());
    }
    let out = experiments::frontdoor_outcome(args.flag("quick"));
    println!("{}", out.table.to_markdown());
    println!(
        "\nfilter gain {:.2}x; tenant-B attainment {:.3} isolated vs {:.3} open",
        out.filter_gain, out.iso_b, out.no_iso_b
    );
    if !out.pass {
        return Err(anyhow!(
            "front-door bars missed:\n  {}",
            out.failures.join("\n  ")
        ));
    }
    println!("all front-door bars met");
    Ok(())
}
