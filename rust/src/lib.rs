//! # OctopInf — workload-aware inference serving for Edge Video Analytics
//!
//! From-scratch reproduction of *OCTOPINF: Workload-Aware Inference Serving
//! for Edge Video Analytics* (Nguyen et al., IEEE PerCom 2025) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! - **Layer 3 (this crate)** — the paper's coordination contribution: the
//!   [`coordinator`] (CWD workload distributor, CORAL spatiotemporal GPU
//!   scheduler, horizontal autoscaler, controller loop), the baselines it is
//!   evaluated against, plus every substrate the evaluation needs
//!   ([`cluster`], [`network`], [`workload`], [`profiles`], [`sim`], [`kb`]).
//! - **Layer 2** — JAX models (`python/compile/model.py`) AOT-lowered to HLO
//!   text in `artifacts/`, loaded at runtime by [`runtime`].
//! - **Layer 1** — Pallas kernels (`python/compile/kernels/`) that carry the
//!   models' FLOPs.
//!
//! Python never runs on the request path: [`serving`] drives real inference
//! purely through PJRT-compiled artifacts.

pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod kb;
pub mod metrics;
pub mod network;
pub mod obs;
pub mod pipeline;
pub mod profiles;
pub mod runtime;
pub mod serving;
pub mod sim;
pub mod util;
pub mod workload;

/// Milliseconds, the time unit used across the scheduler and simulator.
pub type Ms = f64;
/// Bytes, the data-size unit used for IO-ratio and transfer modelling.
pub type Bytes = f64;
