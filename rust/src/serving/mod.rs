//! The real serving path: a front door (content filter → per-tenant
//! admission → sharded fair batchers) feeding a PJRT executor over a
//! bounded ring, all in Rust, driven purely by the AOT artifacts. This is
//! what `examples/e2e_serve.rs` and `octopinf serve` run — Python is
//! never involved.
//!
//! Threading: clients submit [`Request`]s over an mpsc channel from any
//! thread. A *front* thread owns the [`FrontDoor`] — it admits, filters,
//! and assembles batches, pushing them into a bounded ring
//! (`sync_channel`) so admission runs ahead of execution by at most
//! `ring_depth` batches. The *executor* thread (the caller of
//! [`serve_with`]) owns the [`ExecBackend`] (XLA handles are not `Send`)
//! and drains the ring; engine outputs flow back to the front thread so
//! the content filter can reuse them. When the ring is full, shard
//! queues fill, and admission rejects with retry-after hints —
//! backpressure is real, not theoretical.

pub mod admission;
pub mod batcher;
pub mod exec;
pub mod fair;
pub mod filter;
pub mod shard;

pub use admission::{TenantPolicy, MAX_TENANTS, OVERFLOW_TENANT};
pub use batcher::DynamicBatcher;
pub use exec::{ExecBackend, SyntheticExec};
pub use fair::FairBatcher;
pub use filter::{ContentFilter, FilterCfg};
pub use shard::{FrontDoor, FrontDoorCfg, Offer};

use std::collections::{BTreeMap, HashMap};
use std::path::Path;
use std::sync::mpsc::{Receiver, Sender, SyncSender, TrySendError};
use std::time::Instant;

use crate::runtime::Runtime;
use crate::util::error::Result;
use crate::util::stats::QuantileSketch;

/// One inference request (a frame or a crop, row-major f32).
pub struct Request {
    pub id: u64,
    pub model: String,
    pub data: Vec<f32>,
    pub slo_ms: f64,
    /// Owning tenant: admission tokens, fair-dequeue weight, and report
    /// accounting are all per tenant.
    pub tenant: u32,
    /// Source stream id — the frame-difference filter's unit of state.
    pub stream: u64,
    pub submitted: Instant,
}

/// Completion record returned to the client.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub model: String,
    pub output: Vec<f32>,
    pub latency_ms: f64,
    pub batch_size: usize,
    pub on_time: bool,
    /// `Some` when the request failed (unknown model, engine error,
    /// throttle/rejection): the request is answered and dropped instead
    /// of killing the session.
    pub error: Option<String>,
}

/// Per-model serving configuration (CWD's chosen batch + wait bound).
#[derive(Clone, Debug)]
pub struct ModelServeCfg {
    pub batch: usize,
    pub max_wait_ms: f64,
    /// Admission cap of the model's batcher queue: requests arriving at a
    /// full queue are rejected with a retry-after hint instead of queueing
    /// unboundedly (graceful degradation under overload).
    pub queue_cap: usize,
}

impl ModelServeCfg {
    /// Standard config: queue bounded at 8 assembled batches.
    pub fn new(batch: usize, max_wait_ms: f64) -> ModelServeCfg {
        ModelServeCfg { batch, max_wait_ms, queue_cap: batch.max(1) * 8 }
    }
}

/// Per-tenant slice of a [`ServeReport`] — the isolation evidence.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TenantLane {
    pub submitted: u64,
    pub served: u64,
    pub on_time: u64,
    pub filtered: u64,
    pub throttled: u64,
    pub rejected: u64,
    pub shed: u64,
    pub failed: u64,
}

impl TenantLane {
    /// On-time fraction over everything the tenant submitted (filtered
    /// answers count as on time — they are returned instantly).
    pub fn attainment(&self) -> f64 {
        if self.submitted == 0 {
            0.0
        } else {
            (self.on_time + self.filtered) as f64 / self.submitted as f64
        }
    }
}

/// Aggregate report of one serving session.
#[derive(Debug, Default)]
pub struct ServeReport {
    /// Everything that arrived at the front door.
    pub submitted: u64,
    pub served: u64,
    pub on_time: u64,
    /// Requests answered with an error `Response` (unknown model / engine
    /// failure) — isolated per batch, never fatal to the session.
    pub failed: u64,
    /// Requests shed at dequeue because their SLO deadline had already
    /// passed — executing them could only waste a batch slot.
    pub shed: u64,
    /// Requests rejected at admission (queue full): answered with an
    /// explicit retry-after error instead of queueing unboundedly.
    pub rejected: u64,
    /// Requests throttled by their tenant's token bucket.
    pub throttled: u64,
    /// Requests answered by the content frontend (frame-diff or cache)
    /// without any engine work.
    pub filtered: u64,
    /// Of `filtered`, how many came from the cross-stream result cache
    /// (the rest were same-stream frame-diff hits).
    pub cache_hits: u64,
    pub per_model: HashMap<String, u64>,
    /// Per-tenant accounting (BTreeMap: deterministic iteration order).
    pub per_tenant: BTreeMap<u32, TenantLane>,
    /// Streaming latency sketch: O(1) recording on the executor thread.
    pub latency: QuantileSketch,
    /// Executed batches by size: one count per *batch*, not per request
    /// (a batch of 8 adds 1 to bucket 8).
    pub batch_hist: HashMap<usize, u64>,
    pub wall_ms: f64,
    /// Per-request wait outside the engine (answer latency minus its
    /// batch's execute time) — wall-clock timing, so excluded from
    /// [`digest`](Self::digest) like every other timing field.
    pub queue_wait: QuantileSketch,
    /// Engine `execute_padded` wall time, one sample per executed batch.
    pub exec_time: QuantileSketch,
    /// Peak queued requests observed per batcher shard over the session.
    pub peak_shard_depth: Vec<u64>,
}

impl ServeReport {
    /// Requests/s answered usefully: engine completions that met their
    /// SLO plus frontend answers (which cost no engine work at all) —
    /// the EVA-survey "effective throughput" the filter is buying.
    pub fn effective_throughput(&self) -> f64 {
        if self.wall_ms <= 0.0 {
            0.0
        } else {
            (self.on_time + self.filtered) as f64 * 1000.0 / self.wall_ms
        }
    }

    /// On-time fraction of everything *answered with a result* (served
    /// through the engine or by the frontend).
    pub fn slo_attainment(&self) -> f64 {
        let answered = self.served + self.filtered;
        if answered == 0 {
            0.0
        } else {
            (self.on_time + self.filtered) as f64 / answered as f64
        }
    }

    /// Every submitted request terminates in exactly one of these
    /// counters — `accounted() == submitted` is the session-level
    /// conservation law the integration tests enforce.
    pub fn accounted(&self) -> u64 {
        self.served
            + self.filtered
            + self.rejected
            + self.throttled
            + self.shed
            + self.failed
    }

    /// Per-tenant lane, folding ids beyond [`MAX_TENANTS`] distinct
    /// tenants onto [`OVERFLOW_TENANT`] so report state stays bounded.
    pub fn lane(&mut self, tenant: u32) -> &mut TenantLane {
        let key = if self.per_tenant.len() >= MAX_TENANTS
            && !self.per_tenant.contains_key(&tenant)
        {
            OVERFLOW_TENANT
        } else {
            tenant
        };
        self.per_tenant.entry(key).or_default()
    }

    /// Count one arrival (total + tenant lane). Called before the front
    /// door decides anything, so conservation has a stable left side.
    pub fn note_submitted(&mut self, tenant: u32) {
        self.submitted += 1;
        self.lane(tenant).submitted += 1;
    }

    /// Fold another report into this one (the front-thread and executor
    /// partial reports merge into the session report).
    pub fn absorb(&mut self, other: ServeReport) {
        self.submitted += other.submitted;
        self.served += other.served;
        self.on_time += other.on_time;
        self.failed += other.failed;
        self.shed += other.shed;
        self.rejected += other.rejected;
        self.throttled += other.throttled;
        self.filtered += other.filtered;
        self.cache_hits += other.cache_hits;
        for (m, c) in other.per_model {
            *self.per_model.entry(m).or_default() += c;
        }
        for (t, l) in other.per_tenant {
            let lane = self.lane(t);
            lane.submitted += l.submitted;
            lane.served += l.served;
            lane.on_time += l.on_time;
            lane.filtered += l.filtered;
            lane.throttled += l.throttled;
            lane.rejected += l.rejected;
            lane.shed += l.shed;
            lane.failed += l.failed;
        }
        for (b, c) in other.batch_hist {
            *self.batch_hist.entry(b).or_default() += c;
        }
        self.latency.merge(&other.latency);
        self.queue_wait.merge(&other.queue_wait);
        self.exec_time.merge(&other.exec_time);
        if self.peak_shard_depth.len() < other.peak_shard_depth.len() {
            self.peak_shard_depth.resize(other.peak_shard_depth.len(), 0);
        }
        for (s, d) in other.peak_shard_depth.into_iter().enumerate() {
            self.peak_shard_depth[s] = self.peak_shard_depth[s].max(d);
        }
    }

    /// Deterministic one-line fingerprint of every counter (sorted maps,
    /// no timing-dependent fields) — what the sharded-path determinism
    /// tests compare across runs.
    pub fn digest(&self) -> String {
        use std::fmt::Write;
        let mut s = format!(
            "sub={} srv={} ot={} fil={} ch={} thr={} rej={} shed={} fail={}",
            self.submitted,
            self.served,
            self.on_time,
            self.filtered,
            self.cache_hits,
            self.throttled,
            self.rejected,
            self.shed,
            self.failed,
        );
        let mut models: Vec<_> = self.per_model.iter().collect();
        models.sort();
        for (m, c) in models {
            let _ = write!(s, " m:{m}={c}");
        }
        for (t, l) in &self.per_tenant {
            let _ = write!(
                s,
                " t:{t}={}/{}/{}/{}/{}/{}/{}/{}",
                l.submitted, l.served, l.on_time, l.filtered, l.throttled,
                l.rejected, l.shed, l.failed
            );
        }
        let mut hist: Vec<_> = self.batch_hist.iter().collect();
        hist.sort();
        for (b, c) in hist {
            let _ = write!(s, " b:{b}={c}");
        }
        s
    }
}

/// The production entry point: compile the PJRT runtime over an artifacts
/// directory and serve with the default front door (2 shards, isolation
/// on with unlimited rates, no content filter).
pub fn serve(
    artifacts_dir: &Path,
    cfgs: &HashMap<String, ModelServeCfg>,
    rx: Receiver<Request>,
    tx: Sender<Response>,
) -> Result<ServeReport> {
    serve_front(artifacts_dir, cfgs, FrontDoorCfg::default(), rx, tx)
}

/// [`serve`] with an explicit front-door configuration (tenancy, filter,
/// shard count) — the `octopinf serve` CLI surface.
pub fn serve_front(
    artifacts_dir: &Path,
    cfgs: &HashMap<String, ModelServeCfg>,
    front: FrontDoorCfg,
    rx: Receiver<Request>,
    tx: Sender<Response>,
) -> Result<ServeReport> {
    let mut rt = Runtime::new(artifacts_dir)?;
    // Pre-compile engines so the first request doesn't eat compile time.
    for (m, c) in cfgs {
        rt.engine(m, c.batch)?;
    }
    serve_with(&mut rt, cfgs, front, rx, tx)
}

/// Engine result fed back to the front thread: `Some(row)` installs the
/// content filter's stream reference + cache entry, `None` abandons the
/// pending entry (the request was shed or failed).
type DoneMsg = (u64, Option<Vec<f32>>);

/// Serve over any [`ExecBackend`] — the testable core of the path.
///
/// The caller's thread becomes the executor (it owns `backend`, which is
/// not required to be `Send`); a scoped front thread owns the
/// [`FrontDoor`] and the request stream. Returns when `rx` closes and
/// every queued request has been answered.
pub fn serve_with(
    backend: &mut dyn ExecBackend,
    cfgs: &HashMap<String, ModelServeCfg>,
    front: FrontDoorCfg,
    rx: Receiver<Request>,
    tx: Sender<Response>,
) -> Result<ServeReport> {
    let session_start = Instant::now();
    let ring_depth = front.ring_depth.max(1);
    let filter_on = front.filter.is_some();
    let (ring_tx, ring_rx) =
        std::sync::mpsc::sync_channel::<(String, Vec<Request>)>(ring_depth);
    let (done_tx, done_rx) = std::sync::mpsc::channel::<DoneMsg>();
    let front_tx = tx.clone();

    let mut exec_report = ServeReport::default();
    let front_report = std::thread::scope(|scope| {
        let front_handle = scope.spawn(move || {
            front_loop(cfgs, front, rx, front_tx, ring_tx, done_rx, session_start)
        });
        // Executor: drain the ring until the front thread closes it.
        while let Ok((model, batch)) = ring_rx.recv() {
            run_batch(
                backend,
                &model,
                cfgs,
                batch,
                &tx,
                &mut exec_report,
                filter_on.then_some(&done_tx),
            );
        }
        drop(done_tx);
        front_handle.join().expect("front-door thread panicked")
    });

    let mut report = front_report;
    report.absorb(exec_report);
    report.wall_ms = session_start.elapsed().as_secs_f64() * 1e3;
    Ok(report)
}

/// Receive wait when no flush deadline is pending (bounds how long a
/// disconnect or a misestimated deadline can stall the loop).
const IDLE_WAIT_MS: f64 = 50.0;
/// Receive wait while a batch is parked on a full ring: short, so the
/// retry happens as soon as the executor frees a slot.
const RING_RETRY_MS: f64 = 2.0;

fn now_ms(start: Instant) -> f64 {
    start.elapsed().as_secs_f64() * 1e3
}

/// The front thread: admission, filtering, batch assembly, and the
/// admission-side half of the session report.
fn front_loop(
    cfgs: &HashMap<String, ModelServeCfg>,
    front: FrontDoorCfg,
    rx: Receiver<Request>,
    tx: Sender<Response>,
    ring_tx: SyncSender<(String, Vec<Request>)>,
    done_rx: Receiver<DoneMsg>,
    session_start: Instant,
) -> ServeReport {
    let mut door = FrontDoor::new(cfgs, &front);
    let mut report = ServeReport::default();
    // A batch that found the ring full: held (not re-queued) and retried
    // until a slot frees. While it is parked, no further assembly runs,
    // so shard queues fill and admission starts rejecting — backpressure.
    let mut parked: Option<(String, Vec<Request>)> = None;
    let mut open = true;
    while open {
        // Feed engine results back into the content filter.
        let now = now_ms(session_start);
        for (id, out) in done_rx.try_iter() {
            match out {
                Some(o) => door.record_result(id, &o, now),
                None => door.abandon_result(id),
            }
        }
        // Move ready batches into the ring without ever blocking.
        loop {
            let candidate = match parked.take() {
                Some(b) => Some(b),
                None => door.poll(now_ms(session_start)),
            };
            let Some(b) = candidate else { break };
            match ring_tx.try_send(b) {
                Ok(()) => {}
                Err(TrySendError::Full(b)) => {
                    parked = Some(b);
                    break;
                }
                // Executor died (panic downstream): stop assembling.
                Err(TrySendError::Disconnected(_)) => {
                    report.peak_shard_depth = door.peak_shard_depths();
                    return report;
                }
            }
        }
        // Wait for the next request, bounded by the earliest batch
        // deadline (or a short retry tick while parked on a full ring).
        let now = now_ms(session_start);
        let wait_ms = if parked.is_some() {
            RING_RETRY_MS
        } else {
            door.next_deadline_ms()
                .map(|d| (d - now).max(0.0))
                .unwrap_or(IDLE_WAIT_MS)
                .min(IDLE_WAIT_MS)
        };
        match rx.recv_timeout(std::time::Duration::from_secs_f64(wait_ms / 1e3)) {
            Ok(req) => {
                report.note_submitted(req.tenant);
                let offer = door.offer(req, now_ms(session_start));
                settle_offer(offer, &tx, &mut report);
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => open = false,
        }
    }
    // Shutdown drain: every queued request still gets an engine pass —
    // in ≤ batch chunks (the engine errors on n > batch) — with blocking
    // sends now that no new work can arrive.
    loop {
        let b = parked
            .take()
            .or_else(|| door.poll(now_ms(session_start)))
            .or_else(|| door.flush());
        let Some(b) = b else { break };
        if ring_tx.send(b).is_err() {
            break;
        }
    }
    report.peak_shard_depth = door.peak_shard_depths();
    report
}

/// Account one front-door decision and answer the client where the
/// decision already terminates the request. Shared by the threaded
/// serve path and the logical-clock harness in `experiments::frontdoor`,
/// so both account identically. (`Queued` requests terminate later, on
/// the executor side.)
pub fn settle_offer(offer: Offer, tx: &Sender<Response>, report: &mut ServeReport) {
    match offer {
        Offer::Queued => {}
        Offer::Answered { req, output, cached } => {
            report.filtered += 1;
            if cached {
                report.cache_hits += 1;
            }
            report.lane(req.tenant).filtered += 1;
            let latency_ms = req.submitted.elapsed().as_secs_f64() * 1e3;
            let _ = tx.send(Response {
                id: req.id,
                model: req.model,
                output,
                latency_ms,
                batch_size: 0,
                on_time: latency_ms <= req.slo_ms,
                error: None,
            });
        }
        Offer::Throttled { req, retry_after_ms } => {
            report.throttled += 1;
            report.lane(req.tenant).throttled += 1;
            let _ = tx.send(Response {
                id: req.id,
                model: req.model,
                output: Vec::new(),
                latency_ms: req.submitted.elapsed().as_secs_f64() * 1e3,
                batch_size: 0,
                on_time: false,
                error: Some(format!(
                    "throttled: tenant over admission rate; retry after {:.0} ms",
                    retry_after_ms.ceil().min(1e6)
                )),
            });
        }
        Offer::QueueFull { req, retry_after_ms } => {
            reject_request(req, retry_after_ms, tx, report);
        }
        Offer::Unknown { req } => {
            // Unconfigured model: answered and counted, but NEVER given a
            // batcher — the old path grew the batcher map per unknown
            // name, an adversarial-client memory leak.
            report.failed += 1;
            report.lane(req.tenant).failed += 1;
            let _ = tx.send(Response {
                id: req.id,
                model: req.model.clone(),
                output: Vec::new(),
                latency_ms: req.submitted.elapsed().as_secs_f64() * 1e3,
                batch_size: 0,
                on_time: false,
                error: Some(format!(
                    "unknown model {:?}: not in the serving config",
                    req.model
                )),
            });
        }
    }
}

/// Execute one batch. Engine failures (a model absent from the manifest,
/// a PJRT error) are isolated to this batch: its requests are answered
/// with error `Response`s and the session keeps serving everyone else —
/// they used to propagate out of `serve` and kill every client.
pub fn run_batch(
    backend: &mut dyn ExecBackend,
    model: &str,
    cfgs: &HashMap<String, ModelServeCfg>,
    batch: Vec<Request>,
    tx: &Sender<Response>,
    report: &mut ServeReport,
    done: Option<&Sender<DoneMsg>>,
) {
    // Deadline-aware shedding before any engine work: a request whose SLO
    // already expired at dequeue time cannot be served on time — running
    // it would only delay everyone behind it.
    let batch = shed_expired(batch, tx, report, done);
    if batch.is_empty() {
        return;
    }
    let bz = cfgs.get(model).map(|c| c.batch).unwrap_or(1);
    let n = batch.len();
    let per_in: usize = match backend.per_in(model, bz) {
        Ok(p) => p,
        Err(e) => return fail_batch(batch, &e.to_string(), tx, report, done),
    };
    let mut input = Vec::with_capacity(n * per_in);
    for r in &batch {
        debug_assert_eq!(r.data.len(), per_in);
        input.extend_from_slice(&r.data);
    }
    let exec_start = Instant::now();
    let out = match backend.execute_padded(model, bz, n, &input) {
        Ok(o) => o,
        Err(e) => return fail_batch(batch, &e.to_string(), tx, report, done),
    };
    let exec_ms = exec_start.elapsed().as_secs_f64() * 1e3;
    report.exec_time.push(exec_ms);
    complete_batch(batch, &out, exec_ms, tx, report, done);
}

/// Account one *successful* executed batch and answer its requests.
fn complete_batch(
    batch: Vec<Request>,
    out: &[f32],
    exec_ms: f64,
    tx: &Sender<Response>,
    report: &mut ServeReport,
    done: Option<&Sender<DoneMsg>>,
) {
    let n = batch.len();
    let per_out = out.len() / n.max(1);
    // One histogram entry per executed batch — not per request (the old
    // per-request increment made a batch of 8 add 8 to bucket 8).
    *report.batch_hist.entry(n).or_default() += 1;
    for (i, req) in batch.into_iter().enumerate() {
        let latency_ms = req.submitted.elapsed().as_secs_f64() * 1e3;
        let on_time = latency_ms <= req.slo_ms;
        report.served += 1;
        if on_time {
            report.on_time += 1;
        }
        {
            let lane = report.lane(req.tenant);
            lane.served += 1;
            if on_time {
                lane.on_time += 1;
            }
        }
        *report.per_model.entry(req.model.clone()).or_default() += 1;
        report.latency.push(latency_ms);
        report.queue_wait.push((latency_ms - exec_ms).max(0.0));
        let row = out[i * per_out..(i + 1) * per_out].to_vec();
        if let Some(d) = done {
            // Feed the content filter's pending entry (front thread).
            let _ = d.send((req.id, Some(row.clone())));
        }
        // Client may be gone (fire-and-forget benchmarks) — ignore errors.
        let _ = tx.send(Response {
            id: req.id,
            model: req.model,
            output: row,
            latency_ms,
            batch_size: n,
            on_time,
            error: None,
        });
    }
}

/// Drop already-expired requests from a dequeued batch, answering each
/// with an error `Response` (counted in `report.shed`), and return the
/// still-viable remainder.
fn shed_expired(
    batch: Vec<Request>,
    tx: &Sender<Response>,
    report: &mut ServeReport,
    done: Option<&Sender<DoneMsg>>,
) -> Vec<Request> {
    let mut live = Vec::with_capacity(batch.len());
    for req in batch {
        let latency_ms = req.submitted.elapsed().as_secs_f64() * 1e3;
        if latency_ms > req.slo_ms {
            report.shed += 1;
            report.lane(req.tenant).shed += 1;
            if let Some(d) = done {
                let _ = d.send((req.id, None));
            }
            let _ = tx.send(Response {
                id: req.id,
                model: req.model,
                output: Vec::new(),
                latency_ms,
                batch_size: 0,
                on_time: false,
                error: Some("shed: deadline exceeded".to_string()),
            });
        } else {
            live.push(req);
        }
    }
    live
}

/// Answer a request rejected at admission (full queue) with an explicit
/// retry-after hint — bounded queues are the serving path's backpressure.
fn reject_request(
    req: Request,
    retry_after_ms: f64,
    tx: &Sender<Response>,
    report: &mut ServeReport,
) {
    report.rejected += 1;
    report.lane(req.tenant).rejected += 1;
    let _ = tx.send(Response {
        id: req.id,
        model: req.model,
        output: Vec::new(),
        latency_ms: req.submitted.elapsed().as_secs_f64() * 1e3,
        batch_size: 0,
        on_time: false,
        error: Some(format!(
            "queue full; retry after {:.0} ms",
            retry_after_ms.ceil()
        )),
    });
}

/// Answer every request of a failed batch with an error `Response`.
fn fail_batch(
    batch: Vec<Request>,
    err: &str,
    tx: &Sender<Response>,
    report: &mut ServeReport,
    done: Option<&Sender<DoneMsg>>,
) {
    let n = batch.len();
    for req in batch {
        let latency_ms = req.submitted.elapsed().as_secs_f64() * 1e3;
        report.failed += 1;
        report.lane(req.tenant).failed += 1;
        if let Some(d) = done {
            let _ = d.send((req.id, None));
        }
        let _ = tx.send(Response {
            id: req.id,
            model: req.model,
            output: Vec::new(),
            latency_ms,
            batch_size: n,
            on_time: false,
            error: Some(err.to_string()),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, model: &str, slo_ms: f64) -> Request {
        Request {
            id,
            model: model.into(),
            data: vec![0.0; 4],
            slo_ms,
            tenant: 0,
            stream: id,
            submitted: Instant::now(),
        }
    }

    #[test]
    fn batch_hist_counts_batches_not_requests() {
        let (tx, rx) = std::sync::mpsc::channel();
        let mut report = ServeReport::default();
        let batch: Vec<Request> =
            (0..8).map(|i| req(i, "classifier", 1e9)).collect();
        let out = vec![0.5f32; 8 * 2];
        complete_batch(batch, &out, 0.0, &tx, &mut report, None);
        assert_eq!(report.batch_hist.get(&8), Some(&1), "one batch, bucket 8");
        assert_eq!(report.served, 8);
        assert_eq!(report.on_time, 8);
        assert_eq!(rx.try_iter().count(), 8);

        let batch: Vec<Request> = (0..3).map(|i| req(i, "embedder", 1e9)).collect();
        complete_batch(batch, &vec![0.0f32; 3 * 2], 0.0, &tx, &mut report, None);
        assert_eq!(report.batch_hist.get(&3), Some(&1));
        assert_eq!(report.batch_hist.values().sum::<u64>(), 2, "two batches total");
        assert_eq!(report.latency.count(), report.served);
        assert_eq!(report.per_tenant.get(&0).unwrap().served, 11);
    }

    #[test]
    fn failed_batch_answers_clients_without_killing_the_session() {
        let (tx, rx) = std::sync::mpsc::channel();
        let mut report = ServeReport::default();
        let batch: Vec<Request> = (0..4).map(|i| req(i, "no_such_model", 50.0)).collect();
        fail_batch(batch, "engine missing", &tx, &mut report, None);
        assert_eq!(report.failed, 4);
        assert_eq!(report.served, 0, "failures are not completions");
        assert_eq!(report.latency.count(), 0);
        assert!(report.batch_hist.is_empty(), "failed batches never executed");
        let responses: Vec<Response> = rx.try_iter().collect();
        assert_eq!(responses.len(), 4, "every client must still get an answer");
        for r in &responses {
            assert!(!r.on_time);
            assert!(r.output.is_empty());
            assert_eq!(r.error.as_deref(), Some("engine missing"));
        }
    }

    #[test]
    fn expired_requests_are_shed_with_an_answer() {
        let (tx, rx) = std::sync::mpsc::channel();
        let mut report = ServeReport::default();
        // Negative SLO: expired the instant it was created.
        let batch = vec![req(1, "det", -1.0), req(2, "det", 1e9)];
        let live = shed_expired(batch, &tx, &mut report, None);
        assert_eq!(live.len(), 1);
        assert_eq!(live[0].id, 2);
        assert_eq!(report.shed, 1);
        assert_eq!(report.served, 0, "shed requests are not completions");
        let r: Vec<Response> = rx.try_iter().collect();
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].id, 1);
        assert!(!r[0].on_time);
        assert_eq!(r[0].error.as_deref(), Some("shed: deadline exceeded"));
    }

    #[test]
    fn rejected_request_carries_retry_after() {
        let (tx, rx) = std::sync::mpsc::channel();
        let mut report = ServeReport::default();
        reject_request(req(7, "det", 100.0), 12.3, &tx, &mut report);
        assert_eq!(report.rejected, 1);
        let r: Vec<Response> = rx.try_iter().collect();
        assert_eq!(r.len(), 1, "rejected client must still get an answer");
        let err = r[0].error.as_deref().unwrap();
        assert!(err.contains("queue full"), "{err}");
        assert!(err.contains("13 ms"), "{err}");
    }

    #[test]
    fn unknown_model_offer_is_failed_and_answered() {
        let (tx, rx) = std::sync::mpsc::channel();
        let mut report = ServeReport::default();
        report.note_submitted(3);
        let mut r = req(9, "ghost", 100.0);
        r.tenant = 3;
        settle_offer(Offer::Unknown { req: r }, &tx, &mut report);
        assert_eq!(report.failed, 1);
        assert_eq!(report.accounted(), report.submitted, "conservation");
        let resp: Vec<Response> = rx.try_iter().collect();
        assert_eq!(resp.len(), 1);
        assert!(resp[0].error.as_deref().unwrap().contains("unknown model"));
        assert_eq!(report.per_tenant.get(&3).unwrap().failed, 1);
    }

    #[test]
    fn absorb_merges_every_counter_and_digest_is_stable() {
        let (tx, _rx) = std::sync::mpsc::channel();
        let mut a = ServeReport::default();
        a.note_submitted(1);
        complete_batch(vec![req(1, "det", 1e9)], &[1.0], 0.0, &tx, &mut a, None);
        a.peak_shard_depth = vec![3, 9];
        let mut b = ServeReport::default();
        b.note_submitted(2);
        reject_request(req(2, "det", 1.0), 5.0, &tx, &mut b);
        b.exec_time.push(4.5);
        b.peak_shard_depth = vec![7, 2, 1];
        a.absorb(b);
        assert_eq!(a.submitted, 2);
        assert_eq!(a.served, 1);
        assert_eq!(a.rejected, 1);
        assert_eq!(a.accounted(), a.submitted);
        assert_eq!(a.per_tenant.len(), 2);
        assert_eq!(a.latency.count(), 1);
        assert_eq!(a.queue_wait.count(), 1, "wait recorded per completion");
        assert_eq!(a.exec_time.count(), 1);
        assert_eq!(a.peak_shard_depth, vec![7, 9, 1], "element-wise peak");
        let d = a.digest();
        assert!(d.contains("sub=2"), "{d}");
        assert!(d.contains("t:1="), "{d}");
        assert!(d.contains("t:2="), "{d}");
        assert_eq!(d, a.digest(), "digest is a pure function of counters");
        // Timing-derived fields stay out of the digest by construction.
        let mut c = ServeReport::default();
        c.note_submitted(1);
        complete_batch(vec![req(1, "det", 1e9)], &[1.0], 0.0, &tx, &mut c, None);
        c.absorb(ServeReport::default());
        let mut plain = ServeReport::default();
        plain.note_submitted(1);
        complete_batch(vec![req(1, "det", 1e9)], &[1.0], 0.0, &tx, &mut plain, None);
        plain.exec_time.push(99.0);
        plain.peak_shard_depth = vec![42];
        assert_eq!(c.digest(), plain.digest());
    }

    #[test]
    fn report_lane_folds_past_the_tenant_cap() {
        let mut r = ServeReport::default();
        for t in 0..MAX_TENANTS as u32 {
            r.lane(t).submitted += 1;
        }
        r.lane(5_000_000).submitted += 1;
        r.lane(6_000_000).submitted += 1;
        assert_eq!(r.per_tenant.len(), MAX_TENANTS + 1);
        assert_eq!(r.per_tenant.get(&OVERFLOW_TENANT).unwrap().submitted, 2);
    }

    #[test]
    fn run_batch_sheds_expired_before_engine_lookup() {
        // Under an empty synthetic backend every engine lookup errors —
        // but a batch that is entirely expired must shed (answered per
        // request) before any engine work, not fail.
        let mut ex = SyntheticExec::new();
        let (tx, rx) = std::sync::mpsc::channel();
        let mut report = ServeReport::default();
        let cfgs = HashMap::new();
        let batch = vec![req(1, "det", -1.0), req(2, "det", -1.0)];
        run_batch(&mut ex, "det", &cfgs, batch, &tx, &mut report, None);
        assert_eq!(report.shed, 2);
        assert_eq!(report.failed, 0, "shedding is not an engine failure");
        let r: Vec<Response> = rx.try_iter().collect();
        assert_eq!(r.len(), 2);
        assert!(r.iter().all(|x| x.error.as_deref()
            == Some("shed: deadline exceeded")));
    }

    #[test]
    fn run_batch_isolates_unknown_models() {
        // A backend with no models errors on every lookup — exactly the
        // unknown-model shape. run_batch must degrade to fail_batch
        // instead of propagating (the old `?` aborted the whole session).
        let mut ex = SyntheticExec::new();
        let (tx, rx) = std::sync::mpsc::channel();
        let mut report = ServeReport::default();
        let cfgs = HashMap::new();
        run_batch(&mut ex, "ghost", &cfgs, vec![req(1, "ghost", 10.0)], &tx, &mut report, None);
        assert_eq!(report.failed, 1);
        let r: Vec<Response> = rx.try_iter().collect();
        assert_eq!(r.len(), 1);
        assert!(r[0].error.is_some());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_satisfies_the_exec_backend_trait() {
        // The stub Runtime errors on every call, but it must still *be*
        // an ExecBackend so serve_with compiles against both variants.
        let mut rt = Runtime { manifest: Default::default() };
        let backend: &mut dyn ExecBackend = &mut rt;
        assert!(backend.per_in("det", 4).is_err());
        assert!(backend.execute_padded("det", 4, 1, &[0.0; 4]).is_err());
    }
}
