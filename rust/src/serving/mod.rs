//! The real serving path: a request router + per-model dynamic batchers +
//! a PJRT executor, all in Rust, driven purely by the AOT artifacts.
//! This is what `examples/e2e_serve.rs` and `octopinf serve` run — Python
//! is never involved.
//!
//! Threading: clients submit [`Request`]s over an mpsc channel from any
//! thread; a single executor thread owns the PJRT [`Runtime`] (XLA handles
//! are not `Send`) and drives batching + execution; responses flow back
//! over a channel with full timing.

pub mod batcher;

pub use batcher::DynamicBatcher;

use std::collections::HashMap;
use std::path::Path;
use std::sync::mpsc::{Receiver, Sender};
use std::time::Instant;

use crate::runtime::Runtime;
use crate::util::error::Result;
use crate::util::stats::QuantileSketch;

/// One inference request (a frame or a crop, row-major f32).
pub struct Request {
    pub id: u64,
    pub model: String,
    pub data: Vec<f32>,
    pub slo_ms: f64,
    pub submitted: Instant,
}

/// Completion record returned to the client.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub model: String,
    pub output: Vec<f32>,
    pub latency_ms: f64,
    pub batch_size: usize,
    pub on_time: bool,
    /// `Some` when the request failed (unknown model, engine error): the
    /// request is answered and dropped instead of killing the session.
    pub error: Option<String>,
}

/// Per-model serving configuration (CWD's chosen batch + wait bound).
#[derive(Clone, Debug)]
pub struct ModelServeCfg {
    pub batch: usize,
    pub max_wait_ms: f64,
    /// Admission cap of the model's batcher queue: requests arriving at a
    /// full queue are rejected with a retry-after hint instead of queueing
    /// unboundedly (graceful degradation under overload).
    pub queue_cap: usize,
}

impl ModelServeCfg {
    /// Standard config: queue bounded at 8 assembled batches.
    pub fn new(batch: usize, max_wait_ms: f64) -> ModelServeCfg {
        ModelServeCfg { batch, max_wait_ms, queue_cap: batch.max(1) * 8 }
    }
}

/// Aggregate report of one serving session.
#[derive(Debug, Default)]
pub struct ServeReport {
    pub served: u64,
    pub on_time: u64,
    /// Requests answered with an error `Response` (unknown model / engine
    /// failure) — isolated per batch, never fatal to the session.
    pub failed: u64,
    /// Requests shed at dequeue because their SLO deadline had already
    /// passed — executing them could only waste a batch slot.
    pub shed: u64,
    /// Requests rejected at admission (queue full): answered with an
    /// explicit retry-after error instead of queueing unboundedly.
    pub rejected: u64,
    pub per_model: HashMap<String, u64>,
    /// Streaming latency sketch: O(1) recording on the executor thread.
    pub latency: QuantileSketch,
    /// Executed batches by size: one count per *batch*, not per request
    /// (a batch of 8 adds 1 to bucket 8).
    pub batch_hist: HashMap<usize, u64>,
    pub wall_ms: f64,
}

impl ServeReport {
    pub fn effective_throughput(&self) -> f64 {
        if self.wall_ms <= 0.0 {
            0.0
        } else {
            self.on_time as f64 * 1000.0 / self.wall_ms
        }
    }

    pub fn slo_attainment(&self) -> f64 {
        if self.served == 0 {
            0.0
        } else {
            self.on_time as f64 / self.served as f64
        }
    }
}

/// The executor loop: drains `rx` until it closes, batches per model, runs
/// PJRT, and reports each completion on `tx`.
///
/// Returns the aggregate report when the request stream ends.
pub fn serve(
    artifacts_dir: &Path,
    cfgs: &HashMap<String, ModelServeCfg>,
    rx: Receiver<Request>,
    tx: Sender<Response>,
) -> Result<ServeReport> {
    let mut rt = Runtime::new(artifacts_dir)?;
    let mut batchers: HashMap<String, DynamicBatcher<Request>> = cfgs
        .iter()
        .map(|(m, c)| {
            (m.clone(), DynamicBatcher::bounded(c.batch, c.max_wait_ms, c.queue_cap))
        })
        .collect();
    // Pre-compile engines so the first request doesn't eat compile time.
    for (m, c) in cfgs {
        rt.engine(m, c.batch)?;
    }

    let mut report = ServeReport::default();
    let session_start = Instant::now();
    let mut open = true;
    while open || batchers.values().any(|b| !b.is_empty()) {
        if open {
            // Sleep until the earliest pending flush deadline (or an idle
            // cap) instead of busy-spinning a 1 ms poll; an incoming
            // request or a closed channel wakes the receiver immediately.
            let now = now_ms(session_start);
            let wait_ms = batchers
                .values()
                .filter_map(|b| b.next_deadline_ms())
                .min_by(f64::total_cmp)
                .map(|d| (d - now).max(0.0))
                .unwrap_or(IDLE_WAIT_MS)
                .min(IDLE_WAIT_MS);
            match rx.recv_timeout(std::time::Duration::from_secs_f64(wait_ms / 1e3)) {
                Ok(req) => {
                    let model = req.model.clone();
                    let b = batchers
                        .entry(model.clone())
                        .or_insert_with(|| DynamicBatcher::bounded(1, 5.0, 8));
                    if b.is_full() {
                        // Explicit backpressure: answer now with a retry
                        // hint instead of queueing unboundedly.
                        let retry = b.retry_after_ms(now_ms(session_start));
                        reject_request(req, retry, &tx, &mut report);
                    } else if let Some(batch) = b.push(req, now_ms(session_start))
                    {
                        // A push that fills the batch releases it here.
                        run_batch(&mut rt, &model, cfgs, batch, &tx, &mut report);
                    }
                }
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => open = false,
            }
        }
        // Flush ready batches.
        let now = now_ms(session_start);
        for (model, b) in batchers.iter_mut() {
            // When the stream closed, force-flush leftovers.
            let ready = if open { b.poll(now) } else { b.flush() };
            let Some(batch) = ready else { continue };
            run_batch(&mut rt, model, cfgs, batch, &tx, &mut report);
        }
    }
    report.wall_ms = session_start.elapsed().as_secs_f64() * 1e3;
    Ok(report)
}

/// Receive wait when no flush deadline is pending (bounds how long a
/// disconnect or a misestimated deadline can stall the loop).
const IDLE_WAIT_MS: f64 = 50.0;

fn now_ms(start: Instant) -> f64 {
    start.elapsed().as_secs_f64() * 1e3
}

/// Execute one batch. Engine failures (a model absent from the manifest,
/// a PJRT error) are isolated to this batch: its requests are answered
/// with error `Response`s and the session keeps serving everyone else —
/// they used to propagate out of `serve` and kill every client.
fn run_batch(
    rt: &mut Runtime,
    model: &str,
    cfgs: &HashMap<String, ModelServeCfg>,
    batch: Vec<Request>,
    tx: &Sender<Response>,
    report: &mut ServeReport,
) {
    // Deadline-aware shedding before any engine work: a request whose SLO
    // already expired at dequeue time cannot be served on time — running
    // it would only delay everyone behind it.
    let batch = shed_expired(batch, tx, report);
    if batch.is_empty() {
        return;
    }
    let bz = cfgs.get(model).map(|c| c.batch).unwrap_or(1);
    let n = batch.len();
    let per_in: usize = match rt.engine(model, bz) {
        Ok(e) => e.meta.input_shape.iter().product(),
        Err(e) => return fail_batch(batch, &e.to_string(), tx, report),
    };
    let mut input = Vec::with_capacity(n * per_in);
    for r in &batch {
        debug_assert_eq!(r.data.len(), per_in);
        input.extend_from_slice(&r.data);
    }
    let out = match rt.execute_padded(model, bz, n, &input) {
        Ok(o) => o,
        Err(e) => return fail_batch(batch, &e.to_string(), tx, report),
    };
    complete_batch(batch, &out, tx, report);
}

/// Account one *successful* executed batch and answer its requests.
fn complete_batch(
    batch: Vec<Request>,
    out: &[f32],
    tx: &Sender<Response>,
    report: &mut ServeReport,
) {
    let n = batch.len();
    let per_out = out.len() / n.max(1);
    // One histogram entry per executed batch — not per request (the old
    // per-request increment made a batch of 8 add 8 to bucket 8).
    *report.batch_hist.entry(n).or_default() += 1;
    for (i, req) in batch.into_iter().enumerate() {
        let latency_ms = req.submitted.elapsed().as_secs_f64() * 1e3;
        let on_time = latency_ms <= req.slo_ms;
        report.served += 1;
        if on_time {
            report.on_time += 1;
        }
        *report.per_model.entry(req.model.clone()).or_default() += 1;
        report.latency.push(latency_ms);
        // Client may be gone (fire-and-forget benchmarks) — ignore errors.
        let _ = tx.send(Response {
            id: req.id,
            model: req.model,
            output: out[i * per_out..(i + 1) * per_out].to_vec(),
            latency_ms,
            batch_size: n,
            on_time,
            error: None,
        });
    }
}

/// Drop already-expired requests from a dequeued batch, answering each
/// with an error `Response` (counted in `report.shed`), and return the
/// still-viable remainder.
fn shed_expired(
    batch: Vec<Request>,
    tx: &Sender<Response>,
    report: &mut ServeReport,
) -> Vec<Request> {
    let mut live = Vec::with_capacity(batch.len());
    for req in batch {
        let latency_ms = req.submitted.elapsed().as_secs_f64() * 1e3;
        if latency_ms > req.slo_ms {
            report.shed += 1;
            let _ = tx.send(Response {
                id: req.id,
                model: req.model,
                output: Vec::new(),
                latency_ms,
                batch_size: 0,
                on_time: false,
                error: Some("shed: deadline exceeded".to_string()),
            });
        } else {
            live.push(req);
        }
    }
    live
}

/// Answer a request rejected at admission (full queue) with an explicit
/// retry-after hint — bounded queues are the serving path's backpressure.
fn reject_request(
    req: Request,
    retry_after_ms: f64,
    tx: &Sender<Response>,
    report: &mut ServeReport,
) {
    report.rejected += 1;
    let _ = tx.send(Response {
        id: req.id,
        model: req.model,
        output: Vec::new(),
        latency_ms: req.submitted.elapsed().as_secs_f64() * 1e3,
        batch_size: 0,
        on_time: false,
        error: Some(format!(
            "queue full; retry after {:.0} ms",
            retry_after_ms.ceil()
        )),
    });
}

/// Answer every request of a failed batch with an error `Response`.
fn fail_batch(
    batch: Vec<Request>,
    err: &str,
    tx: &Sender<Response>,
    report: &mut ServeReport,
) {
    let n = batch.len();
    for req in batch {
        let latency_ms = req.submitted.elapsed().as_secs_f64() * 1e3;
        report.failed += 1;
        let _ = tx.send(Response {
            id: req.id,
            model: req.model,
            output: Vec::new(),
            latency_ms,
            batch_size: n,
            on_time: false,
            error: Some(err.to_string()),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, model: &str, slo_ms: f64) -> Request {
        Request {
            id,
            model: model.into(),
            data: vec![0.0; 4],
            slo_ms,
            submitted: Instant::now(),
        }
    }

    #[test]
    fn batch_hist_counts_batches_not_requests() {
        let (tx, rx) = std::sync::mpsc::channel();
        let mut report = ServeReport::default();
        let batch: Vec<Request> =
            (0..8).map(|i| req(i, "classifier", 1e9)).collect();
        let out = vec![0.5f32; 8 * 2];
        complete_batch(batch, &out, &tx, &mut report);
        assert_eq!(report.batch_hist.get(&8), Some(&1), "one batch, bucket 8");
        assert_eq!(report.served, 8);
        assert_eq!(report.on_time, 8);
        assert_eq!(rx.try_iter().count(), 8);

        let batch: Vec<Request> = (0..3).map(|i| req(i, "embedder", 1e9)).collect();
        complete_batch(batch, &vec![0.0f32; 3 * 2], &tx, &mut report);
        assert_eq!(report.batch_hist.get(&3), Some(&1));
        assert_eq!(report.batch_hist.values().sum::<u64>(), 2, "two batches total");
        assert_eq!(report.latency.count(), report.served);
    }

    #[test]
    fn failed_batch_answers_clients_without_killing_the_session() {
        let (tx, rx) = std::sync::mpsc::channel();
        let mut report = ServeReport::default();
        let batch: Vec<Request> = (0..4).map(|i| req(i, "no_such_model", 50.0)).collect();
        fail_batch(batch, "engine missing", &tx, &mut report);
        assert_eq!(report.failed, 4);
        assert_eq!(report.served, 0, "failures are not completions");
        assert_eq!(report.latency.count(), 0);
        assert!(report.batch_hist.is_empty(), "failed batches never executed");
        let responses: Vec<Response> = rx.try_iter().collect();
        assert_eq!(responses.len(), 4, "every client must still get an answer");
        for r in &responses {
            assert!(!r.on_time);
            assert!(r.output.is_empty());
            assert_eq!(r.error.as_deref(), Some("engine missing"));
        }
    }

    #[test]
    fn expired_requests_are_shed_with_an_answer() {
        let (tx, rx) = std::sync::mpsc::channel();
        let mut report = ServeReport::default();
        // Negative SLO: expired the instant it was created.
        let batch = vec![req(1, "det", -1.0), req(2, "det", 1e9)];
        let live = shed_expired(batch, &tx, &mut report);
        assert_eq!(live.len(), 1);
        assert_eq!(live[0].id, 2);
        assert_eq!(report.shed, 1);
        assert_eq!(report.served, 0, "shed requests are not completions");
        let r: Vec<Response> = rx.try_iter().collect();
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].id, 1);
        assert!(!r[0].on_time);
        assert_eq!(r[0].error.as_deref(), Some("shed: deadline exceeded"));
    }

    #[test]
    fn rejected_request_carries_retry_after() {
        let (tx, rx) = std::sync::mpsc::channel();
        let mut report = ServeReport::default();
        reject_request(req(7, "det", 100.0), 12.3, &tx, &mut report);
        assert_eq!(report.rejected, 1);
        let r: Vec<Response> = rx.try_iter().collect();
        assert_eq!(r.len(), 1, "rejected client must still get an answer");
        let err = r[0].error.as_deref().unwrap();
        assert!(err.contains("queue full"), "{err}");
        assert!(err.contains("13 ms"), "{err}");
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn run_batch_sheds_expired_before_engine_lookup() {
        // Under the stub Runtime every engine lookup errors — but a batch
        // that is entirely expired must shed (answered per request) before
        // any engine work, not fail.
        let mut rt = Runtime { manifest: Default::default() };
        let (tx, rx) = std::sync::mpsc::channel();
        let mut report = ServeReport::default();
        let cfgs = HashMap::new();
        let batch = vec![req(1, "det", -1.0), req(2, "det", -1.0)];
        run_batch(&mut rt, "det", &cfgs, batch, &tx, &mut report);
        assert_eq!(report.shed, 2);
        assert_eq!(report.failed, 0, "shedding is not an engine failure");
        let r: Vec<Response> = rx.try_iter().collect();
        assert_eq!(r.len(), 2);
        assert!(r.iter().all(|x| x.error.as_deref()
            == Some("shed: deadline exceeded")));
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn run_batch_isolates_unknown_models() {
        // The stub Runtime errors on every engine lookup — exactly the
        // unknown-model shape. run_batch must degrade to fail_batch
        // instead of propagating (the old `?` aborted the whole session).
        let mut rt = Runtime { manifest: Default::default() };
        let (tx, rx) = std::sync::mpsc::channel();
        let mut report = ServeReport::default();
        let cfgs = HashMap::new();
        run_batch(&mut rt, "ghost", &cfgs, vec![req(1, "ghost", 10.0)], &tx, &mut report);
        assert_eq!(report.failed, 1);
        let r: Vec<Response> = rx.try_iter().collect();
        assert_eq!(r.len(), 1);
        assert!(r[0].error.is_some());
    }
}
