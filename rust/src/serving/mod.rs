//! The real serving path: a request router + per-model dynamic batchers +
//! a PJRT executor, all in Rust, driven purely by the AOT artifacts.
//! This is what `examples/e2e_serve.rs` and `octopinf serve` run — Python
//! is never involved.
//!
//! Threading: clients submit [`Request`]s over an mpsc channel from any
//! thread; a single executor thread owns the PJRT [`Runtime`] (XLA handles
//! are not `Send`) and drives batching + execution; responses flow back
//! over a channel with full timing.

pub mod batcher;

pub use batcher::DynamicBatcher;

use std::collections::HashMap;
use std::path::Path;
use std::sync::mpsc::{Receiver, Sender};
use std::time::Instant;

use crate::runtime::Runtime;
use crate::util::error::Result;
use crate::util::stats::QuantileSketch;

/// One inference request (a frame or a crop, row-major f32).
pub struct Request {
    pub id: u64,
    pub model: String,
    pub data: Vec<f32>,
    pub slo_ms: f64,
    pub submitted: Instant,
}

/// Completion record returned to the client.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub model: String,
    pub output: Vec<f32>,
    pub latency_ms: f64,
    pub batch_size: usize,
    pub on_time: bool,
}

/// Per-model serving configuration (CWD's chosen batch + wait bound).
#[derive(Clone, Debug)]
pub struct ModelServeCfg {
    pub batch: usize,
    pub max_wait_ms: f64,
}

/// Aggregate report of one serving session.
#[derive(Debug, Default)]
pub struct ServeReport {
    pub served: u64,
    pub on_time: u64,
    pub per_model: HashMap<String, u64>,
    /// Streaming latency sketch: O(1) recording on the executor thread.
    pub latency: QuantileSketch,
    pub batch_hist: HashMap<usize, u64>,
    pub wall_ms: f64,
}

impl ServeReport {
    pub fn effective_throughput(&self) -> f64 {
        if self.wall_ms <= 0.0 {
            0.0
        } else {
            self.on_time as f64 * 1000.0 / self.wall_ms
        }
    }

    pub fn slo_attainment(&self) -> f64 {
        if self.served == 0 {
            0.0
        } else {
            self.on_time as f64 / self.served as f64
        }
    }
}

/// The executor loop: drains `rx` until it closes, batches per model, runs
/// PJRT, and reports each completion on `tx`.
///
/// Returns the aggregate report when the request stream ends.
pub fn serve(
    artifacts_dir: &Path,
    cfgs: &HashMap<String, ModelServeCfg>,
    rx: Receiver<Request>,
    tx: Sender<Response>,
) -> Result<ServeReport> {
    let mut rt = Runtime::new(artifacts_dir)?;
    let mut batchers: HashMap<String, DynamicBatcher<Request>> = cfgs
        .iter()
        .map(|(m, c)| (m.clone(), DynamicBatcher::new(c.batch, c.max_wait_ms)))
        .collect();
    // Pre-compile engines so the first request doesn't eat compile time.
    for (m, c) in cfgs {
        rt.engine(m, c.batch)?;
    }

    let mut report = ServeReport::default();
    let session_start = Instant::now();
    let mut open = true;
    while open || batchers.values().any(|b| !b.is_empty()) {
        // Pull with a short timeout so flush timers fire.
        match rx.recv_timeout(std::time::Duration::from_millis(1)) {
            Ok(req) => {
                let b = batchers
                    .entry(req.model.clone())
                    .or_insert_with(|| DynamicBatcher::new(1, 5.0));
                b.push(req, now_ms(session_start));
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => open = false,
        }
        // Flush ready batches.
        let now = now_ms(session_start);
        for (model, b) in batchers.iter_mut() {
            // When the stream closed, force-flush leftovers.
            let ready = if open { b.poll(now) } else { b.flush() };
            let Some(batch) = ready else { continue };
            run_batch(&mut rt, model, cfgs, batch, &tx, &mut report)?;
        }
    }
    report.wall_ms = session_start.elapsed().as_secs_f64() * 1e3;
    Ok(report)
}

fn now_ms(start: Instant) -> f64 {
    start.elapsed().as_secs_f64() * 1e3
}

fn run_batch(
    rt: &mut Runtime,
    model: &str,
    cfgs: &HashMap<String, ModelServeCfg>,
    batch: Vec<Request>,
    tx: &Sender<Response>,
    report: &mut ServeReport,
) -> Result<()> {
    let bz = cfgs.get(model).map(|c| c.batch).unwrap_or(1);
    let n = batch.len();
    let per_in: usize = rt
        .engine(model, bz)?
        .meta
        .input_shape
        .iter()
        .product();
    let mut input = Vec::with_capacity(n * per_in);
    for r in &batch {
        debug_assert_eq!(r.data.len(), per_in);
        input.extend_from_slice(&r.data);
    }
    let out = rt.execute_padded(model, bz, n, &input)?;
    let per_out = out.len() / n.max(1);
    for (i, req) in batch.into_iter().enumerate() {
        let latency_ms = req.submitted.elapsed().as_secs_f64() * 1e3;
        let on_time = latency_ms <= req.slo_ms;
        report.served += 1;
        if on_time {
            report.on_time += 1;
        }
        *report.per_model.entry(req.model.clone()).or_default() += 1;
        report.latency.push(latency_ms);
        *report.batch_hist.entry(n).or_default() += 1;
        // Client may be gone (fire-and-forget benchmarks) — ignore errors.
        let _ = tx.send(Response {
            id: req.id,
            model: req.model,
            output: out[i * per_out..(i + 1) * per_out].to_vec(),
            latency_ms,
            batch_size: n,
            on_time,
        });
    }
    Ok(())
}
