//! Execution backend abstraction for the serving path.
//!
//! [`serve_with`](crate::serving::serve_with) drives any [`ExecBackend`]:
//! the PJRT [`Runtime`] in production, or [`SyntheticExec`] — a
//! deterministic in-process model that mirrors the engine's padding
//! contract (`n > batch` is an error) — in tests and the stub-runtime
//! front-door experiment. The abstraction is what makes the whole
//! admission / batching / backpressure machinery testable without XLA.

use std::collections::HashMap;

use crate::anyhow;
use crate::runtime::Runtime;
use crate::util::error::Result;

/// What the executor needs from an inference engine: input width per
/// sample (to assemble row-major batches) and padded batch execution.
pub trait ExecBackend {
    /// Elements per input row for `(model, batch)`; errors when the model
    /// has no compiled artifact at that batch size.
    fn per_in(&mut self, model: &str, batch: usize) -> Result<usize>;

    /// Execute `n` real rows (`input.len() == n * per_in`) padded up to
    /// `batch`; returns only the real rows' outputs. Must error when
    /// `n > batch` — the engine was compiled for exactly `batch` rows.
    fn execute_padded(
        &mut self,
        model: &str,
        batch: usize,
        n: usize,
        input: &[f32],
    ) -> Result<Vec<f32>>;
}

impl ExecBackend for Runtime {
    fn per_in(&mut self, model: &str, batch: usize) -> Result<usize> {
        Ok(self.engine(model, batch)?.meta.input_shape.iter().product())
    }

    fn execute_padded(
        &mut self,
        model: &str,
        batch: usize,
        n: usize,
        input: &[f32],
    ) -> Result<Vec<f32>> {
        // Delegates to the inherent method (stub or PJRT variant).
        Runtime::execute_padded(self, model, batch, n, input)
    }
}

/// One synthetic model: fixed row widths plus a nominal per-batch service
/// time (used by [`SyntheticExec::sleep`] and the logical-clock harness).
#[derive(Clone, Debug)]
pub struct SyntheticModel {
    pub per_in: usize,
    pub per_out: usize,
    pub service_ms: f64,
}

/// Deterministic stand-in engine for tests and stub-runtime experiments.
///
/// Semantics mirror the PJRT runtime exactly where the serving path can
/// observe them: unknown models error at `per_in` (admission-time
/// rejection shape), and `execute_padded` errors on `n > batch` or a
/// mis-sized input — so the shutdown-flush regression test exercises the
/// same contract the real engine enforces.
#[derive(Debug, Default)]
pub struct SyntheticExec {
    models: HashMap<String, SyntheticModel>,
    /// When set, `execute_padded` sleeps `service_ms` per call so threaded
    /// tests get a genuinely slow executor (reachable backpressure).
    pub sleep: bool,
    /// Batches executed (all models).
    pub batches: u64,
    /// Accumulated nominal service time — the harness's logical busy clock.
    pub busy_ms: f64,
    /// The same busy clock split per model, so harnesses can attribute
    /// executor occupancy to the workload that caused it.
    pub busy_by_model: HashMap<String, f64>,
}

impl SyntheticExec {
    pub fn new() -> SyntheticExec {
        SyntheticExec::default()
    }

    pub fn with_model(
        mut self,
        name: &str,
        per_in: usize,
        per_out: usize,
        service_ms: f64,
    ) -> SyntheticExec {
        self.models.insert(
            name.to_string(),
            SyntheticModel { per_in, per_out, service_ms },
        );
        self
    }

    pub fn model(&self, name: &str) -> Option<&SyntheticModel> {
        self.models.get(name)
    }

    fn lookup(&self, model: &str) -> Result<&SyntheticModel> {
        self.models
            .get(model)
            .ok_or_else(|| anyhow!("no artifact for model {model}"))
    }
}

impl ExecBackend for SyntheticExec {
    fn per_in(&mut self, model: &str, _batch: usize) -> Result<usize> {
        Ok(self.lookup(model)?.per_in)
    }

    fn execute_padded(
        &mut self,
        model: &str,
        batch: usize,
        n: usize,
        input: &[f32],
    ) -> Result<Vec<f32>> {
        let m = self.lookup(model)?.clone();
        if n > batch || input.len() != n * m.per_in {
            return Err(anyhow!(
                "execute_padded: n={n} batch={batch} input={}",
                input.len()
            ));
        }
        self.batches += 1;
        self.busy_ms += m.service_ms;
        *self.busy_by_model.entry(model.to_string()).or_default() += m.service_ms;
        if self.sleep && m.service_ms > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(
                m.service_ms / 1e3,
            ));
        }
        // Deterministic per-row output: every output element is the row's
        // checksum, so tests can verify routing (right answer to the right
        // request) without modelling a real network.
        let mut out = Vec::with_capacity(n * m.per_out);
        for row in 0..n {
            let sum: f32 =
                input[row * m.per_in..(row + 1) * m.per_in].iter().sum();
            out.extend(std::iter::repeat(sum).take(m.per_out));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_exec_mirrors_engine_padding_contract() {
        let mut ex = SyntheticExec::new().with_model("det", 4, 2, 10.0);
        // n > batch errors, exactly like the compiled engine.
        let err = ex.execute_padded("det", 2, 3, &[0.0; 12]).unwrap_err();
        assert!(format!("{err}").contains("n=3 batch=2"), "{err}");
        // Mis-sized input errors.
        assert!(ex.execute_padded("det", 4, 2, &[0.0; 7]).is_err());
        // Unknown model errors at per_in (admission shape).
        assert!(ex.per_in("ghost", 4).is_err());
        assert_eq!(ex.batches, 0, "failed calls never count as executed");
    }

    #[test]
    fn synthetic_exec_output_routes_per_row() {
        let mut ex = SyntheticExec::new().with_model("det", 2, 3, 5.0);
        let input = [1.0, 2.0, 10.0, 20.0]; // rows sum to 3 and 30
        let out = ex.execute_padded("det", 4, 2, &input).unwrap();
        assert_eq!(out, vec![3.0, 3.0, 3.0, 30.0, 30.0, 30.0]);
        assert_eq!(ex.batches, 1);
        assert_eq!(ex.busy_ms, 5.0);
        assert_eq!(ex.busy_by_model.get("det"), Some(&5.0));
    }
}
