//! Dynamic batcher: accumulates requests and releases a batch when full or
//! when the oldest request has waited `max_wait_ms` (the latency/throughput
//! balance CWD tunes per model — §III-B).

use std::collections::VecDeque;

/// Generic over the request type so it is unit-testable without PJRT.
#[derive(Debug)]
pub struct DynamicBatcher<T> {
    batch: usize,
    max_wait_ms: f64,
    /// Admission cap: `push` callers should check [`is_full`] first and
    /// reject with backpressure instead of queueing unboundedly.
    cap: usize,
    queue: VecDeque<(f64, T)>,
}

impl<T> DynamicBatcher<T> {
    pub fn new(batch: usize, max_wait_ms: f64) -> Self {
        Self::bounded(batch, max_wait_ms, usize::MAX)
    }

    /// A batcher with an explicit admission cap (bounded per-model queue).
    /// A cap below the batch size binds on every push cycle; a larger cap
    /// bounds buildup whenever releases stall behind admissions.
    pub fn bounded(batch: usize, max_wait_ms: f64, cap: usize) -> Self {
        DynamicBatcher {
            batch: batch.max(1),
            max_wait_ms: max_wait_ms.max(0.0),
            cap: cap.max(1),
            queue: VecDeque::new(),
        }
    }

    /// The queue is at its admission cap: new work should be rejected
    /// with a retry-after hint rather than queued.
    pub fn is_full(&self) -> bool {
        self.queue.len() >= self.cap
    }

    /// Backpressure hint: milliseconds until the next scheduled release
    /// frees queue space. With at least one full batch queued the head's
    /// deadline is its *arrival* time — always in the past, which used to
    /// make this return "retry after 0 ms" against a queue that is still
    /// full. Space then frees only when the executor completes a release
    /// cycle, so quote the wait bound (the time scale of one cycle).
    pub fn retry_after_ms(&self, now_ms: f64) -> f64 {
        if self.queue.len() >= self.batch {
            return self.max_wait_ms.max(1.0);
        }
        self.next_deadline_ms()
            .map(|d| (d - now_ms).max(0.0))
            .unwrap_or(0.0)
    }

    /// Add a request at `now_ms`; returns a full batch if one is ready.
    pub fn push(&mut self, item: T, now_ms: f64) -> Option<Vec<T>> {
        self.queue.push_back((now_ms, item));
        (self.queue.len() >= self.batch).then(|| self.take(self.batch))
    }

    /// Timer poll: release a partial batch if the head has waited too long.
    pub fn poll(&mut self, now_ms: f64) -> Option<Vec<T>> {
        if self.queue.len() >= self.batch {
            return Some(self.take(self.batch));
        }
        match self.queue.front() {
            Some(&(t0, _)) if now_ms - t0 >= self.max_wait_ms => {
                Some(self.take(self.queue.len()))
            }
            _ => None,
        }
    }

    /// Force-release queued work (shutdown path), at most one engine batch
    /// per call — callers re-poll until empty. Draining the whole queue as
    /// a single release used to hand `execute_padded` more rows than the
    /// engine was compiled for (`n > batch` is an error there), failing
    /// every leftover request at session close whenever the backlog
    /// exceeded the configured batch.
    pub fn flush(&mut self) -> Option<Vec<T>> {
        (!self.queue.is_empty())
            .then(|| self.take(self.queue.len().min(self.batch)))
    }

    /// Absolute time (same clock as `push`/`poll`) when the pending queue
    /// next needs service: immediately for a full batch, at the head's
    /// wait bound otherwise, `None` when empty. Lets the executor sleep
    /// until min(deadline, next request) instead of busy-polling.
    pub fn next_deadline_ms(&self) -> Option<f64> {
        let &(t0, _) = self.queue.front()?;
        if self.queue.len() >= self.batch {
            Some(t0)
        } else {
            Some(t0 + self.max_wait_ms)
        }
    }

    fn take(&mut self, n: usize) -> Vec<T> {
        self.queue.drain(..n).map(|(_, x)| x).collect()
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn releases_full_batch_on_push() {
        let mut b = DynamicBatcher::new(3, 100.0);
        assert!(b.push(1, 0.0).is_none());
        assert!(b.push(2, 1.0).is_none());
        let batch = b.push(3, 2.0).unwrap();
        assert_eq!(batch, vec![1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn poll_times_out_partial() {
        let mut b = DynamicBatcher::new(4, 50.0);
        b.push('a', 0.0);
        b.push('b', 10.0);
        assert!(b.poll(40.0).is_none());
        let batch = b.poll(51.0).unwrap();
        assert_eq!(batch, vec!['a', 'b']);
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = DynamicBatcher::new(2, 10.0);
        b.push(10, 0.0);
        let out = b.push(20, 1.0).unwrap();
        assert_eq!(out, vec![10, 20]);
    }

    #[test]
    fn flush_empties() {
        let mut b = DynamicBatcher::new(8, 1000.0);
        b.push(1, 0.0);
        b.push(2, 0.0);
        assert_eq!(b.flush().unwrap().len(), 2);
        assert!(b.flush().is_none());
    }

    #[test]
    fn flush_never_exceeds_the_engine_batch() {
        // Regression: a 20-deep backlog at shutdown must drain as chunks
        // of <= batch (the engine errors on n > batch), not one release.
        let mut b = DynamicBatcher::new(8, 1000.0);
        for i in 0..20 {
            b.push(i, 0.0);
        }
        assert_eq!(b.flush().unwrap().len(), 8);
        assert_eq!(b.flush().unwrap().len(), 8);
        assert_eq!(b.flush().unwrap(), vec![16, 17, 18, 19]);
        assert!(b.flush().is_none());
    }

    #[test]
    fn full_queue_retry_hint_is_never_zero() {
        // Regression: with a full batch queued the old hint quoted the
        // head's arrival time — already in the past — so clients were told
        // "retry after 0 ms" against a queue that stayed full.
        let mut b = DynamicBatcher::bounded(4, 50.0, 8);
        for i in 0..6 {
            b.push(i, 0.0);
        }
        assert!(b.len() >= b.batch_size());
        assert!(b.retry_after_ms(100.0) > 0.0);
        assert_eq!(b.retry_after_ms(100.0), 50.0, "quotes the wait bound");
    }

    #[test]
    fn oversize_wait_handles_empty_queue() {
        let mut b: DynamicBatcher<u8> = DynamicBatcher::new(4, 50.0);
        assert!(b.poll(1e9).is_none());
    }

    #[test]
    fn batch_of_one_is_immediate() {
        let mut b = DynamicBatcher::new(1, 0.0);
        assert_eq!(b.push(7, 0.0).unwrap(), vec![7]);
    }

    #[test]
    fn bounded_batcher_reports_full_and_retry_hint() {
        // Cap below the batch size: admission binds before a full batch
        // can ever assemble, so only timer flushes free space.
        let mut b = DynamicBatcher::bounded(8, 50.0, 2);
        assert!(!b.is_full());
        b.push('a', 0.0);
        assert!(!b.is_full());
        b.push('b', 1.0);
        assert!(b.is_full());
        // Head entered at 0.0, bound 50: space frees at the timer flush.
        assert_eq!(b.retry_after_ms(10.0), 40.0);
        assert_eq!(b.retry_after_ms(80.0), 0.0, "overdue flush: retry now");
        let empty: DynamicBatcher<u8> = DynamicBatcher::bounded(4, 50.0, 8);
        assert_eq!(empty.retry_after_ms(0.0), 0.0);
    }

    #[test]
    fn default_batcher_is_unbounded() {
        let mut b = DynamicBatcher::new(4, 50.0);
        for i in 0..3 {
            b.push(i, 0.0);
        }
        assert!(!b.is_full());
    }

    #[test]
    fn next_deadline_tracks_the_head_wait_bound() {
        let mut b = DynamicBatcher::new(4, 50.0);
        assert_eq!(b.next_deadline_ms(), None);
        b.push('a', 10.0);
        b.push('b', 20.0);
        // Head entered at 10, bound 50: due at 60 regardless of later pushes.
        assert_eq!(b.next_deadline_ms(), Some(60.0));
        // The deadline agrees with poll: not ready just before, ready at it.
        assert!(b.poll(59.9).is_none());
        assert!(b.poll(60.0).is_some());
        assert_eq!(b.next_deadline_ms(), None);
    }
}
