//! Weighted-fair tenant-aware batcher: the dequeue half of SLO isolation.
//!
//! Same release contract as [`DynamicBatcher`](super::batcher): a batch
//! releases when full or when the oldest queued item has waited
//! `max_wait_ms`. The difference is *which* items fill it — batch slots
//! are granted per tenant lane by smallest virtual time
//! (`serviced / weight`, classic WFQ), so a tenant flooding the queue
//! only ever holds its weighted share of each assembled batch while any
//! other lane has work queued. With `fair == false` assembly degrades to
//! global FIFO across lanes — the no-isolation baseline the `frontdoor`
//! experiment measures against.

use std::collections::{HashMap, VecDeque};

use super::admission::{fold_tenant, MAX_TENANTS};

#[derive(Debug)]
struct Lane<T> {
    tenant: u32,
    weight: f64,
    /// Slots granted so far, in units of one request: the WFQ virtual
    /// time for this lane is `serviced / weight`.
    serviced: f64,
    q: VecDeque<(f64, T)>,
}

/// Tenant-aware bounded batcher with weighted-fair batch assembly.
#[derive(Debug)]
pub struct FairBatcher<T> {
    batch: usize,
    max_wait_ms: f64,
    /// Admission cap over ALL lanes (the model's queue bound).
    cap: usize,
    fair: bool,
    len: usize,
    lanes: Vec<Lane<T>>,
    index: HashMap<u32, usize>,
}

impl<T> FairBatcher<T> {
    pub fn new(batch: usize, max_wait_ms: f64, cap: usize, fair: bool) -> Self {
        FairBatcher {
            batch: batch.max(1),
            max_wait_ms: max_wait_ms.max(0.0),
            cap: cap.max(1),
            fair,
            len: 0,
            lanes: Vec::new(),
            index: HashMap::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn is_full(&self) -> bool {
        self.len >= self.cap
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Backpressure hint, same semantics as
    /// [`DynamicBatcher::retry_after_ms`](super::batcher::DynamicBatcher::retry_after_ms):
    /// with at least one full batch queued, space frees on the time scale
    /// of one release cycle (the wait bound), never "0 ms".
    pub fn retry_after_ms(&self, now_ms: f64) -> f64 {
        if self.len >= self.batch {
            return self.max_wait_ms.max(1.0);
        }
        self.next_deadline_ms()
            .map(|d| (d - now_ms).max(0.0))
            .unwrap_or(0.0)
    }

    /// Enqueue one item on its tenant's lane. Never assembles — release
    /// is pull-only via [`poll`]/[`flush`], so a bounded ring downstream
    /// naturally gates assembly (backpressure reaches admission).
    ///
    /// [`poll`]: FairBatcher::poll
    /// [`flush`]: FairBatcher::flush
    pub fn push(&mut self, tenant: u32, weight: f64, item: T, now_ms: f64) {
        let lane = self.lane_mut(tenant, weight);
        self.lanes[lane].q.push_back((now_ms, item));
        self.len += 1;
    }

    /// Release a batch if one is due: full, or the oldest queued item has
    /// waited out the bound (then a partial releases).
    pub fn poll(&mut self, now_ms: f64) -> Option<Vec<T>> {
        if self.len >= self.batch {
            return Some(self.assemble(self.batch));
        }
        let head = self.oldest_head()?;
        (now_ms - head >= self.max_wait_ms)
            .then(|| self.assemble(self.len))
    }

    /// Shutdown drain: at most one engine batch per call (the engine
    /// errors on `n > batch`); callers re-call until `None`.
    pub fn flush(&mut self) -> Option<Vec<T>> {
        (self.len > 0).then(|| self.assemble(self.len.min(self.batch)))
    }

    /// When the queue next needs service, on the push/poll clock:
    /// immediately (oldest head) for a full batch, the oldest head plus
    /// the wait bound otherwise.
    pub fn next_deadline_ms(&self) -> Option<f64> {
        let head = self.oldest_head()?;
        if self.len >= self.batch {
            Some(head)
        } else {
            Some(head + self.max_wait_ms)
        }
    }

    fn oldest_head(&self) -> Option<f64> {
        self.lanes
            .iter()
            .filter_map(|l| l.q.front().map(|&(t, _)| t))
            .min_by(f64::total_cmp)
    }

    fn lane_mut(&mut self, tenant: u32, weight: f64) -> usize {
        let tenant = fold_tenant(tenant, self.lanes.len().min(MAX_TENANTS));
        if let Some(&i) = self.index.get(&tenant) {
            return i;
        }
        // A lane joining late starts at the current minimum virtual time
        // (scaled by its weight) — it competes fairly from now on instead
        // of monopolizing batches to "catch up" on slots it never wanted.
        let min_vt = self
            .lanes
            .iter()
            .map(|l| l.serviced / l.weight)
            .min_by(f64::total_cmp)
            .unwrap_or(0.0);
        let weight = weight.max(1e-6);
        self.lanes.push(Lane {
            tenant,
            weight,
            serviced: min_vt * weight,
            q: VecDeque::new(),
        });
        self.index.insert(tenant, self.lanes.len() - 1);
        self.lanes.len() - 1
    }

    /// Grant `n` slots one at a time. Fair mode: each slot goes to the
    /// non-empty lane with the smallest virtual time (ties → lower tenant
    /// id, deterministic). FIFO mode: each slot goes to the globally
    /// oldest queued item.
    fn assemble(&mut self, n: usize) -> Vec<T> {
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let pick = if self.fair {
                self.lanes
                    .iter()
                    .enumerate()
                    .filter(|(_, l)| !l.q.is_empty())
                    .min_by(|(_, a), (_, b)| {
                        (a.serviced / a.weight)
                            .total_cmp(&(b.serviced / b.weight))
                            .then(a.tenant.cmp(&b.tenant))
                    })
                    .map(|(i, _)| i)
            } else {
                self.lanes
                    .iter()
                    .enumerate()
                    .filter_map(|(i, l)| l.q.front().map(|&(t, _)| (t, l.tenant, i)))
                    .min_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)))
                    .map(|(_, _, i)| i)
            };
            let Some(i) = pick else { break };
            let (_, item) = self.lanes[i].q.pop_front().unwrap();
            self.lanes[i].serviced += 1.0;
            self.len -= 1;
            out.push(item);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain_counts(batch: &[(u32, u64)]) -> HashMap<u32, usize> {
        let mut c = HashMap::new();
        for &(t, _) in batch {
            *c.entry(t).or_insert(0) += 1;
        }
        c
    }

    #[test]
    fn fair_assembly_splits_slots_across_tenants() {
        let mut b: FairBatcher<(u32, u64)> = FairBatcher::new(8, 50.0, 64, true);
        // Tenant 1 floods 20 items first; tenant 2 queues 10 after.
        for i in 0..20 {
            b.push(1, 1.0, (1, i), 0.0);
        }
        for i in 0..10 {
            b.push(2, 1.0, (2, i), 1.0);
        }
        let counts = drain_counts(&b.poll(2.0).unwrap());
        assert_eq!(counts.get(&1), Some(&4), "equal weights: equal slots");
        assert_eq!(counts.get(&2), Some(&4));
    }

    #[test]
    fn weights_skew_the_split() {
        let mut b: FairBatcher<(u32, u64)> = FairBatcher::new(8, 50.0, 64, true);
        for i in 0..20 {
            b.push(1, 3.0, (1, i), 0.0);
            b.push(2, 1.0, (2, i), 0.0);
        }
        let counts = drain_counts(&b.poll(1.0).unwrap());
        assert_eq!(counts.get(&1), Some(&6), "weight 3 vs 1: 6/2 split");
        assert_eq!(counts.get(&2), Some(&2));
    }

    #[test]
    fn fifo_mode_ignores_tenancy() {
        let mut b: FairBatcher<(u32, u64)> = FairBatcher::new(4, 50.0, 64, false);
        for i in 0..4 {
            b.push(1, 1.0, (1, i), i as f64);
        }
        b.push(2, 1.0, (2, 0), 10.0);
        let batch = b.poll(11.0).unwrap();
        assert_eq!(
            batch,
            vec![(1, 0), (1, 1), (1, 2), (1, 3)],
            "FIFO: the flood's head-of-line wins every slot"
        );
    }

    #[test]
    fn fifo_order_is_preserved_within_a_lane() {
        let mut b: FairBatcher<u64> = FairBatcher::new(3, 50.0, 64, true);
        b.push(1, 1.0, 10, 0.0);
        b.push(1, 1.0, 11, 1.0);
        b.push(1, 1.0, 12, 2.0);
        assert_eq!(b.poll(3.0).unwrap(), vec![10, 11, 12]);
    }

    #[test]
    fn push_never_assembles_release_is_pull_only() {
        let mut b: FairBatcher<u64> = FairBatcher::new(2, 50.0, 64, true);
        for i in 0..10 {
            b.push(1, 1.0, i, 0.0);
        }
        assert_eq!(b.len(), 10, "push queues; only poll/flush release");
        assert_eq!(b.poll(0.0).unwrap().len(), 2);
        assert_eq!(b.len(), 8);
    }

    #[test]
    fn partial_releases_on_wait_bound() {
        let mut b: FairBatcher<u64> = FairBatcher::new(8, 50.0, 64, true);
        b.push(1, 1.0, 1, 0.0);
        b.push(2, 1.0, 2, 10.0);
        assert!(b.poll(49.0).is_none());
        assert_eq!(b.poll(50.0).unwrap().len(), 2);
        assert!(b.is_empty());
    }

    #[test]
    fn flush_chunks_to_engine_batch() {
        let mut b: FairBatcher<u64> = FairBatcher::new(4, 1e6, 64, true);
        for i in 0..11 {
            b.push(i as u32 % 3, 1.0, i, 0.0);
        }
        assert_eq!(b.flush().unwrap().len(), 4);
        assert_eq!(b.flush().unwrap().len(), 4);
        assert_eq!(b.flush().unwrap().len(), 3);
        assert!(b.flush().is_none());
    }

    #[test]
    fn full_queue_retry_hint_is_never_zero() {
        let mut b: FairBatcher<u64> = FairBatcher::new(4, 50.0, 8, true);
        for i in 0..6 {
            b.push(1, 1.0, i, 0.0);
        }
        assert!(b.retry_after_ms(1e6) > 0.0);
    }

    #[test]
    fn late_joining_lane_does_not_catch_up_monopolize() {
        let mut b: FairBatcher<(u32, u64)> = FairBatcher::new(4, 50.0, 256, true);
        // Tenant 1 runs alone for 40 slots.
        for i in 0..40 {
            b.push(1, 1.0, (1, i), 0.0);
        }
        for _ in 0..10 {
            b.poll(0.0).unwrap();
        }
        // Tenant 2 joins. If its lane started at virtual time 0 it would
        // take every slot of the next 10 batches; starting at the current
        // minimum it takes its fair half.
        for i in 0..20 {
            b.push(1, 1.0, (1, 100 + i), 1.0);
            b.push(2, 1.0, (2, i), 1.0);
        }
        let counts = drain_counts(&b.poll(2.0).unwrap());
        assert_eq!(counts.get(&1), Some(&2), "late joiner gets a share, not all");
        assert_eq!(counts.get(&2), Some(&2));
    }

    #[test]
    fn deadline_tracks_oldest_across_lanes() {
        let mut b: FairBatcher<u64> = FairBatcher::new(8, 50.0, 64, true);
        assert_eq!(b.next_deadline_ms(), None);
        b.push(5, 1.0, 1, 30.0);
        b.push(1, 1.0, 2, 10.0);
        assert_eq!(b.next_deadline_ms(), Some(60.0), "oldest head + bound");
    }
}
