//! Content-aware frontend: frame-difference filtering + a short-TTL
//! result cache keyed by content hash.
//!
//! The EVA survey (Xu et al.) names sampling/filtering/caching as the
//! cheapest effective-throughput lever in edge video analytics: most
//! surveillance frames are near-identical to their predecessor, so the
//! front door answers them from the previous result and the engine only
//! sees frames whose content actually changed. Two mechanisms, checked
//! in order:
//!
//! 1. **Frame-diff filter** (per stream): a strided 16-bucket mean
//!    signature; if the new frame's signature is within `diff_threshold`
//!    of the last *engine-processed* frame's, answer with that frame's
//!    output. The reference signature is NOT advanced on a hit, so slow
//!    drift cannot tunnel under the threshold, and every
//!    [`REFRESH_EVERY`] consecutive hits one frame is forced through the
//!    engine anyway (staleness bound).
//! 2. **Result cache** (cross-stream): exact content hash with a TTL —
//!    two cameras staring at the same test pattern share one engine pass.
//!
//! All eviction orders are deterministic (sorted by `(stamp, key)`,
//! never raw `HashMap` iteration), so the sharded serving path stays
//! reproducible under a fixed seed.

use std::collections::HashMap;

/// Signature buckets per frame.
const SIG_BUCKETS: usize = 16;
/// Force an engine pass after this many consecutive filter hits on one
/// stream, bounding how stale a reused result can get.
pub const REFRESH_EVERY: u32 = 30;
/// Sample cap for signatures/hashes: inputs longer than this are strided.
const SAMPLE_CAP: usize = 1024;

/// Strided per-bucket means — cheap, order-sensitive, resolution-free.
pub fn signature(data: &[f32]) -> [f32; SIG_BUCKETS] {
    let mut sig = [0.0f32; SIG_BUCKETS];
    if data.is_empty() {
        return sig;
    }
    let stride = (data.len() / SAMPLE_CAP).max(1);
    let mut counts = [0u32; SIG_BUCKETS];
    let mut i = 0;
    while i < data.len() {
        let b = i * SIG_BUCKETS / data.len();
        sig[b.min(SIG_BUCKETS - 1)] += data[i];
        counts[b.min(SIG_BUCKETS - 1)] += 1;
        i += stride;
    }
    for b in 0..SIG_BUCKETS {
        if counts[b] > 0 {
            sig[b] /= counts[b] as f32;
        }
    }
    sig
}

/// Mean absolute distance between two signatures.
pub fn sig_distance(a: &[f32; SIG_BUCKETS], b: &[f32; SIG_BUCKETS]) -> f64 {
    let sum: f64 = a
        .iter()
        .zip(b.iter())
        .map(|(x, y)| (x - y).abs() as f64)
        .sum();
    sum / SIG_BUCKETS as f64
}

/// Strided FNV-1a over the f32 bit patterns: exact-content identity for
/// the cross-stream result cache.
pub fn content_hash(data: &[f32]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    let stride = (data.len() / SAMPLE_CAP).max(1);
    let mut i = 0;
    while i < data.len() {
        for byte in data[i].to_bits().to_le_bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        i += stride;
    }
    h ^= data.len() as u64;
    h.wrapping_mul(0x100000001b3)
}

/// Frontend knobs (all per serve session).
#[derive(Clone, Debug)]
pub struct FilterCfg {
    /// Frame-diff threshold on the mean-abs signature distance.
    pub diff_threshold: f64,
    /// Result-cache entry lifetime.
    pub cache_ttl_ms: f64,
    /// Result-cache capacity (entries).
    pub cache_cap: usize,
    /// Max tracked streams (per-stream filter states).
    pub stream_cap: usize,
}

impl Default for FilterCfg {
    fn default() -> FilterCfg {
        FilterCfg {
            diff_threshold: 1e-3,
            cache_ttl_ms: 1000.0,
            cache_cap: 4096,
            stream_cap: 4096,
        }
    }
}

#[derive(Debug)]
struct StreamState {
    /// Signature of the last frame that actually went through the engine.
    sig: [f32; SIG_BUCKETS],
    /// That frame's output — the answer reused on filter hits.
    output: Vec<f32>,
    last_used: f64,
    hits_since_refresh: u32,
}

/// The front-door content filter: per-stream frame-diff states, the
/// cross-stream result cache, and the pending table that routes engine
/// outputs back into both.
#[derive(Debug)]
pub struct ContentFilter {
    cfg: FilterCfg,
    streams: HashMap<u64, StreamState>,
    /// content hash -> (installed_at_ms, output)
    cache: HashMap<u64, (f64, Vec<f32>)>,
    /// request id -> (stream, signature, content hash) for in-flight
    /// engine passes; resolved by [`record`](ContentFilter::record).
    pending: HashMap<u64, (u64, [f32; SIG_BUCKETS], u64)>,
}

impl ContentFilter {
    pub fn new(cfg: FilterCfg) -> ContentFilter {
        ContentFilter {
            cfg,
            streams: HashMap::new(),
            cache: HashMap::new(),
            pending: HashMap::new(),
        }
    }

    /// Look at one arriving frame. `Some((output, from_cache))` answers it
    /// immediately (frame-diff hit → `from_cache == false`, content-cache
    /// hit → `true`); `None` means the frame must go through the engine —
    /// the caller later feeds the engine output back via [`record`]
    /// (matched by request id).
    pub fn observe(
        &mut self,
        id: u64,
        stream: u64,
        data: &[f32],
        now_ms: f64,
    ) -> Option<(Vec<f32>, bool)> {
        let sig = signature(data);
        if let Some(st) = self.streams.get_mut(&stream) {
            if sig_distance(&st.sig, &sig) <= self.cfg.diff_threshold
                && st.hits_since_refresh < REFRESH_EVERY
            {
                st.last_used = now_ms;
                st.hits_since_refresh += 1;
                return Some((st.output.clone(), false));
            }
        }
        let hash = content_hash(data);
        if let Some((t0, out)) = self.cache.get(&hash) {
            if now_ms - t0 <= self.cfg.cache_ttl_ms {
                let out = out.clone();
                // A cache hit is also a valid frame-diff reference: the
                // output genuinely describes this exact content.
                self.install_stream(stream, sig, out.clone(), now_ms);
                return Some((out, true));
            }
        }
        self.pending.insert(id, (stream, sig, hash));
        None
    }

    /// Feed one engine result back: installs the stream's new reference
    /// frame and a cache entry. Unmatched ids (filter inactive when the
    /// request was admitted) are ignored.
    pub fn record(&mut self, id: u64, output: &[f32], now_ms: f64) {
        let Some((stream, sig, hash)) = self.pending.remove(&id) else {
            return;
        };
        self.install_stream(stream, sig, output.to_vec(), now_ms);
        if self.cache.len() >= self.cfg.cache_cap {
            self.evict_cache();
        }
        self.cache.insert(hash, (now_ms, output.to_vec()));
    }

    /// Drop the pending entry for a request that failed/was shed — its
    /// output will never arrive.
    pub fn abandon(&mut self, id: u64) {
        self.pending.remove(&id);
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    fn install_stream(
        &mut self,
        stream: u64,
        sig: [f32; SIG_BUCKETS],
        output: Vec<f32>,
        now_ms: f64,
    ) {
        if !self.streams.contains_key(&stream)
            && self.streams.len() >= self.cfg.stream_cap
        {
            self.evict_stream();
        }
        self.streams.insert(
            stream,
            StreamState { sig, output, last_used: now_ms, hits_since_refresh: 0 },
        );
    }

    /// Deterministic LRU: evict the stream with the smallest
    /// `(last_used, id)` — never raw map order.
    fn evict_stream(&mut self) {
        let victim = self
            .streams
            .iter()
            .map(|(k, v)| (v.last_used, *k))
            .min_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)))
            .map(|(_, k)| k);
        if let Some(k) = victim {
            self.streams.remove(&k);
        }
    }

    /// Deterministic oldest-first cache eviction by `(installed, key)`.
    fn evict_cache(&mut self) {
        let victim = self
            .cache
            .iter()
            .map(|(k, (t, _))| (*t, *k))
            .min_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)))
            .map(|(_, k)| k);
        if let Some(k) = victim {
            self.cache.remove(&k);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(level: f32, n: usize) -> Vec<f32> {
        (0..n).map(|i| level + (i % 7) as f32 * 0.01).collect()
    }

    #[test]
    fn near_identical_consecutive_frames_are_filtered() {
        let mut f = ContentFilter::new(FilterCfg::default());
        let a = frame(0.5, 64);
        assert!(f.observe(1, 9, &a, 0.0).is_none(), "first frame: engine");
        f.record(1, &[42.0], 1.0);
        // Identical frame → frame-diff hit, answered from the last output.
        let (out, cached) = f.observe(2, 9, &a, 2.0).unwrap();
        assert_eq!(out, vec![42.0]);
        assert!(!cached, "frame-diff hit, not a cache hit");
        // A genuinely different frame goes to the engine.
        assert!(f.observe(3, 9, &frame(0.9, 64), 3.0).is_none());
    }

    #[test]
    fn reference_frame_does_not_drift_under_the_threshold() {
        let cfg = FilterCfg { diff_threshold: 0.05, ..FilterCfg::default() };
        let mut f = ContentFilter::new(cfg);
        let base = frame(0.5, 64);
        assert!(f.observe(1, 1, &base, 0.0).is_none());
        f.record(1, &[1.0], 0.0);
        // Creep upward in sub-threshold steps: each step is within 0.05 of
        // the *reference*, until the cumulative drift exceeds it.
        let mut hits = 0;
        for (i, step) in (1..=4).enumerate() {
            let drifted = frame(0.5 + step as f32 * 0.03, 64);
            match f.observe(10 + i as u64, 1, &drifted, i as f64) {
                Some(_) => hits += 1,
                None => break,
            }
        }
        // 0.03 within, 0.06/0.09/0.12 beyond: exactly one hit.
        assert_eq!(hits, 1, "cumulative drift must re-trigger the engine");
    }

    #[test]
    fn staleness_cap_forces_periodic_refresh() {
        let mut f = ContentFilter::new(FilterCfg::default());
        let a = frame(0.25, 32);
        assert!(f.observe(0, 3, &a, 0.0).is_none());
        f.record(0, &[7.0], 0.0);
        let mut engine_passes = 0;
        for i in 1..=(REFRESH_EVERY + 5) {
            // Same content hash every time — kill the cache with TTL 0 so
            // only the frame-diff path can answer.
            match f.observe(i as u64, 3, &a, 1e9 + i as f64) {
                Some(_) => {}
                None => {
                    engine_passes += 1;
                    f.record(i as u64, &[7.0], 1e9 + i as f64);
                }
            }
        }
        assert!(engine_passes >= 1, "refresh cap must force an engine pass");
    }

    #[test]
    fn cross_stream_cache_hit_within_ttl() {
        let mut f = ContentFilter::new(FilterCfg::default());
        let a = frame(0.1, 48);
        assert!(f.observe(1, 100, &a, 0.0).is_none());
        f.record(1, &[3.5], 5.0);
        // A *different* stream with identical content: cache hit.
        let (out, cached) = f.observe(2, 200, &a, 10.0).unwrap();
        assert_eq!(out, vec![3.5]);
        assert!(cached);
        // Past the TTL the entry is dead (and stream 300 has no reference).
        assert!(f.observe(3, 300, &a, 5000.0).is_none());
    }

    #[test]
    fn abandon_clears_pending() {
        let mut f = ContentFilter::new(FilterCfg::default());
        assert!(f.observe(1, 1, &frame(0.3, 16), 0.0).is_none());
        assert_eq!(f.pending_len(), 1);
        f.abandon(1);
        assert_eq!(f.pending_len(), 0);
        // A record for an abandoned id is a no-op.
        f.record(1, &[1.0], 1.0);
        assert!(f.observe(2, 1, &frame(0.3, 16), 2.0).is_none(), "no state installed");
    }

    #[test]
    fn caps_bound_state_deterministically() {
        let cfg = FilterCfg { cache_cap: 2, stream_cap: 2, ..FilterCfg::default() };
        let mut f = ContentFilter::new(cfg);
        for s in 0..4u64 {
            let data = frame(s as f32, 16);
            assert!(f.observe(s, s, &data, s as f64).is_none());
            f.record(s, &[s as f32], s as f64);
        }
        assert!(f.streams.len() <= 2);
        assert!(f.cache.len() <= 2);
        // Newest survive: stream 3's reference is intact.
        let (out, _) = f.observe(9, 3, &frame(3.0, 16), 10.0).unwrap();
        assert_eq!(out, vec![3.0]);
    }

    #[test]
    fn signatures_separate_different_content() {
        let a = signature(&frame(0.2, 256));
        let b = signature(&frame(0.8, 256));
        assert!(sig_distance(&a, &b) > 0.1);
        assert_eq!(sig_distance(&a, &a), 0.0);
        assert_ne!(content_hash(&frame(0.2, 256)), content_hash(&frame(0.8, 256)));
        // Length-sensitive even when strided samples collide.
        assert_ne!(content_hash(&[0.0; 8]), content_hash(&[0.0; 9]));
    }
}
