//! The serving front door: sharded batchers + per-tenant admission +
//! content-aware filtering, in front of the executor.
//!
//! Models hash onto N batcher shards; the executor side dequeues with
//! work-stealing (a round-robin scan that takes the earliest-due batch
//! from *any* shard, so one hot shard cannot idle the engine while
//! another has work due). The door never executes anything — it turns
//! each arriving request into an [`Offer`], and assembled batches are
//! pulled via [`FrontDoor::poll`]/[`flush`] by whoever owns the ring to
//! the executor.

use std::collections::HashMap;

use super::admission::{TenantAdmission, TenantPolicy};
use super::fair::FairBatcher;
use super::filter::{ContentFilter, FilterCfg};
use super::{ModelServeCfg, Request};

/// Front-door configuration (shard count, ring depth, tenancy, filter).
#[derive(Clone, Debug)]
pub struct FrontDoorCfg {
    /// Batcher shards (models hash across them).
    pub shards: usize,
    /// Bounded-ring depth between the front door and the executor: how
    /// many assembled batches admission may run ahead of execution.
    pub ring_depth: usize,
    pub tenants: TenantPolicy,
    /// `Some` enables the content-aware frontend.
    pub filter: Option<FilterCfg>,
}

impl Default for FrontDoorCfg {
    fn default() -> FrontDoorCfg {
        FrontDoorCfg {
            shards: 2,
            ring_depth: 2,
            tenants: TenantPolicy::default(),
            filter: None,
        }
    }
}

/// What the front door decided about one arriving request.
pub enum Offer {
    /// Queued on its model's shard; an engine batch will carry it.
    Queued,
    /// Answered immediately by the content frontend (filter or cache) —
    /// no engine work. `cached` distinguishes cache from frame-diff hits.
    Answered { req: Request, output: Vec<f32>, cached: bool },
    /// Throttled at tenant admission (token bucket dry).
    Throttled { req: Request, retry_after_ms: f64 },
    /// The model's queue is at its admission cap.
    QueueFull { req: Request, retry_after_ms: f64 },
    /// Not a configured model: rejected without allocating any state
    /// (the old path permanently grew the batcher map per unknown name).
    Unknown { req: Request },
}

/// One batcher shard: the (model → batcher) slice that hashed onto it,
/// kept sorted by model name for deterministic iteration.
struct Shard {
    batchers: Vec<(String, FairBatcher<Request>)>,
    /// High-water mark of this shard's total queued depth — report-only
    /// state, never consulted by any scheduling decision.
    peak: u64,
}

impl Shard {
    fn get_mut(&mut self, model: &str) -> Option<&mut FairBatcher<Request>> {
        self.batchers
            .iter_mut()
            .find(|(m, _)| m == model)
            .map(|(_, b)| b)
    }

    fn depth(&self) -> u64 {
        self.batchers.iter().map(|(_, b)| b.len() as u64).sum()
    }
}

/// The assembled front door. Single-threaded by design — it lives on the
/// front thread; concurrency comes from the bounded ring behind it.
pub struct FrontDoor {
    shards: Vec<Shard>,
    shard_of: HashMap<String, usize>,
    admission: TenantAdmission,
    filter: Option<ContentFilter>,
    /// Work-stealing scan cursor: rotates so no shard gets structural
    /// priority when several batches are due at once.
    steal_rr: usize,
}

fn fnv(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

impl FrontDoor {
    pub fn new(cfgs: &HashMap<String, ModelServeCfg>, cfg: &FrontDoorCfg) -> FrontDoor {
        let n = cfg.shards.max(1);
        let mut shards: Vec<Shard> =
            (0..n).map(|_| Shard { batchers: Vec::new(), peak: 0 }).collect();
        let mut shard_of = HashMap::new();
        // Sorted model order so shard contents are deterministic.
        let mut models: Vec<&String> = cfgs.keys().collect();
        models.sort();
        for m in models {
            let c = &cfgs[m];
            let s = (fnv(m) % n as u64) as usize;
            shard_of.insert(m.clone(), s);
            shards[s].batchers.push((
                m.clone(),
                FairBatcher::new(
                    c.batch,
                    c.max_wait_ms,
                    c.queue_cap,
                    cfg.tenants.isolation,
                ),
            ));
        }
        FrontDoor {
            shards,
            shard_of,
            admission: TenantAdmission::new(cfg.tenants.clone()),
            filter: cfg.filter.clone().map(ContentFilter::new),
            steal_rr: 0,
        }
    }

    /// Decide one arriving request: filter/cache answer, throttle,
    /// queue-full rejection, unknown-model rejection, or enqueue.
    pub fn offer(&mut self, req: Request, now_ms: f64) -> Offer {
        let Some(&shard) = self.shard_of.get(&req.model) else {
            return Offer::Unknown { req };
        };
        // Content frontend first: a filtered frame costs no tokens and no
        // queue space — that is the whole point.
        if let Some(f) = self.filter.as_mut() {
            if let Some((output, cached)) =
                f.observe(req.id, req.stream, &req.data, now_ms)
            {
                return Offer::Answered { req, output, cached };
            }
        }
        if let Err(retry_after_ms) = self.admission.admit(req.tenant, now_ms) {
            if let Some(f) = self.filter.as_mut() {
                f.abandon(req.id);
            }
            return Offer::Throttled { req, retry_after_ms };
        }
        let weight = self.admission.policy().weight(req.tenant);
        let lane = self.admission.lane(req.tenant);
        let b = self.shards[shard].get_mut(&req.model).unwrap();
        if b.is_full() {
            let retry_after_ms = b.retry_after_ms(now_ms);
            if let Some(f) = self.filter.as_mut() {
                f.abandon(req.id);
            }
            return Offer::QueueFull { req, retry_after_ms };
        }
        b.push(lane, weight, req, now_ms);
        let s = &mut self.shards[shard];
        let depth = s.depth();
        s.peak = s.peak.max(depth);
        Offer::Queued
    }

    /// Work-stealing dequeue: scan every shard from a rotating cursor and
    /// release the earliest-due ready batch, if any.
    pub fn poll(&mut self, now_ms: f64) -> Option<(String, Vec<Request>)> {
        let n = self.shards.len();
        let mut best: Option<(f64, usize, usize)> = None;
        for off in 0..n {
            let s = (self.steal_rr + off) % n;
            for (bi, (_, b)) in self.shards[s].batchers.iter().enumerate() {
                let Some(due) = b.next_deadline_ms() else { continue };
                if due <= now_ms
                    && best.map_or(true, |(bd, _, _)| due < bd)
                {
                    best = Some((due, s, bi));
                }
            }
        }
        let (_, s, bi) = best?;
        self.steal_rr = (s + 1) % n;
        let (model, b) = &mut self.shards[s].batchers[bi];
        b.poll(now_ms).map(|batch| (model.clone(), batch))
    }

    /// Shutdown drain: one ≤ batch chunk per call, scanning shards in
    /// order; callers re-call until `None`.
    pub fn flush(&mut self) -> Option<(String, Vec<Request>)> {
        for s in &mut self.shards {
            for (model, b) in &mut s.batchers {
                if let Some(batch) = b.flush() {
                    return Some((model.clone(), batch));
                }
            }
        }
        None
    }

    /// Earliest deadline across every shard (for the front thread's
    /// receive timeout).
    pub fn next_deadline_ms(&self) -> Option<f64> {
        self.shards
            .iter()
            .flat_map(|s| s.batchers.iter())
            .filter_map(|(_, b)| b.next_deadline_ms())
            .min_by(f64::total_cmp)
    }

    pub fn is_empty(&self) -> bool {
        self.shards
            .iter()
            .all(|s| s.batchers.iter().all(|(_, b)| b.is_empty()))
    }

    pub fn queued(&self) -> usize {
        self.shards
            .iter()
            .flat_map(|s| s.batchers.iter())
            .map(|(_, b)| b.len())
            .sum()
    }

    /// Feed an engine result back into the content frontend (installs the
    /// stream reference + cache entry). No-op when the filter is off.
    pub fn record_result(&mut self, id: u64, output: &[f32], now_ms: f64) {
        if let Some(f) = self.filter.as_mut() {
            f.record(id, output, now_ms);
        }
    }

    /// Drop the filter's pending entry for a request that died downstream
    /// (shed or failed) — its output will never arrive.
    pub fn abandon_result(&mut self, id: u64) {
        if let Some(f) = self.filter.as_mut() {
            f.abandon(id);
        }
    }

    /// Peak queued depth each shard has seen since construction — the
    /// `ServeReport::peak_shard_depth` snapshot behind
    /// `serve --metrics-out`.
    pub fn peak_shard_depths(&self) -> Vec<u64> {
        self.shards.iter().map(|s| s.peak).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    fn req(id: u64, model: &str, tenant: u32) -> Request {
        Request {
            id,
            model: model.into(),
            data: vec![id as f32; 4],
            slo_ms: 1e9,
            tenant,
            stream: tenant as u64,
            submitted: Instant::now(),
        }
    }

    fn cfgs() -> HashMap<String, ModelServeCfg> {
        let mut m = HashMap::new();
        m.insert("det".to_string(), ModelServeCfg::new(4, 25.0));
        m.insert("cls".to_string(), ModelServeCfg::new(2, 10.0));
        m
    }

    #[test]
    fn unknown_models_are_rejected_without_allocating_state() {
        let mut door = FrontDoor::new(&cfgs(), &FrontDoorCfg::default());
        let before: usize =
            door.shards.iter().map(|s| s.batchers.len()).sum();
        for i in 0..100 {
            match door.offer(req(i, &format!("ghost{i}"), 0), 0.0) {
                Offer::Unknown { .. } => {}
                _ => panic!("unknown model must be rejected"),
            }
        }
        let after: usize = door.shards.iter().map(|s| s.batchers.len()).sum();
        assert_eq!(before, after, "no batcher growth on unknown names");
    }

    #[test]
    fn models_spread_across_shards_and_poll_steals_work() {
        let cfg = FrontDoorCfg { shards: 4, ..FrontDoorCfg::default() };
        let mut door = FrontDoor::new(&cfgs(), &cfg);
        // Fill both models to a full batch each.
        for i in 0..4 {
            assert!(matches!(door.offer(req(i, "det", 0), 0.0), Offer::Queued));
        }
        for i in 10..12 {
            assert!(matches!(door.offer(req(i, "cls", 0), 0.0), Offer::Queued));
        }
        // Two polls drain both models regardless of which shards they
        // hashed to — the dequeue side sees every shard.
        let mut models = Vec::new();
        while let Some((m, batch)) = door.poll(0.0) {
            assert!(!batch.is_empty());
            models.push(m);
        }
        models.sort();
        assert_eq!(models, vec!["cls", "det"]);
        assert!(door.is_empty());
    }

    #[test]
    fn queue_full_rejects_with_nonzero_retry() {
        let mut cfgs = cfgs();
        cfgs.get_mut("det").unwrap().queue_cap = 6;
        let mut door = FrontDoor::new(&cfgs, &FrontDoorCfg::default());
        let mut rejected = 0;
        for i in 0..10 {
            match door.offer(req(i, "det", 0), 0.0) {
                Offer::Queued => {}
                Offer::QueueFull { retry_after_ms, .. } => {
                    rejected += 1;
                    assert!(retry_after_ms > 0.0, "retry hint must be > 0");
                }
                _ => panic!("unexpected offer"),
            }
        }
        assert_eq!(rejected, 4, "cap 6 of 10 pushes");
        assert_eq!(door.queued(), 6);
    }

    #[test]
    fn throttled_tenant_gets_retry_hint() {
        let mut fd = FrontDoorCfg::default();
        fd.tenants.rate_per_s = 10.0;
        fd.tenants.burst = 2.0;
        let mut door = FrontDoor::new(&cfgs(), &fd);
        let mut throttled = 0;
        for i in 0..5 {
            match door.offer(req(i, "det", 1), 0.0) {
                Offer::Queued => {}
                Offer::Throttled { retry_after_ms, .. } => {
                    throttled += 1;
                    assert!(retry_after_ms > 0.0);
                }
                _ => panic!("unexpected offer"),
            }
        }
        assert_eq!(throttled, 3, "burst 2 admits 2 of 5");
    }

    #[test]
    fn filter_answers_repeat_frames_without_queueing() {
        let fd = FrontDoorCfg {
            filter: Some(FilterCfg::default()),
            ..FrontDoorCfg::default()
        };
        let mut door = FrontDoor::new(&cfgs(), &fd);
        let mut r1 = req(1, "det", 0);
        r1.data = vec![0.5; 4];
        assert!(matches!(door.offer(r1, 0.0), Offer::Queued));
        let (_, batch) = door.poll(100.0).expect("wait bound passed");
        assert_eq!(batch.len(), 1);
        door.record_result(1, &[9.0], 100.0);
        // Same stream, same content → answered, never queued.
        let mut r2 = req(2, "det", 0);
        r2.data = vec![0.5; 4];
        r2.stream = 0;
        match door.offer(r2, 101.0) {
            Offer::Answered { output, cached, .. } => {
                assert_eq!(output, vec![9.0]);
                assert!(!cached, "same-stream repeat is a frame-diff hit");
            }
            _ => panic!("repeat frame must be answered by the filter"),
        }
        assert!(door.is_empty());
    }

    #[test]
    fn peak_shard_depth_survives_the_drain() {
        let cfg = FrontDoorCfg { shards: 2, ..FrontDoorCfg::default() };
        let mut door = FrontDoor::new(&cfgs(), &cfg);
        for i in 0..3 {
            assert!(matches!(door.offer(req(i, "det", 0), 0.0), Offer::Queued));
        }
        let peaks = door.peak_shard_depths();
        assert_eq!(peaks.len(), 2);
        assert_eq!(peaks.iter().sum::<u64>(), 3, "all three queued on det's shard");
        while door.flush().is_some() {}
        assert!(door.is_empty());
        assert_eq!(
            door.peak_shard_depths(),
            peaks,
            "high-water mark is monotone, not current depth"
        );
    }

    #[test]
    fn flush_drains_every_shard_in_engine_sized_chunks() {
        let cfg = FrontDoorCfg { shards: 3, ..FrontDoorCfg::default() };
        let mut door = FrontDoor::new(&cfgs(), &cfg);
        for i in 0..9 {
            door.offer(req(i, "det", 0), 0.0);
        }
        for i in 20..23 {
            door.offer(req(i, "cls", 0), 0.0);
        }
        let mut total = 0;
        while let Some((m, batch)) = door.flush() {
            let cap = if m == "det" { 4 } else { 2 };
            assert!(batch.len() <= cap, "flush chunk exceeds engine batch");
            total += batch.len();
        }
        assert_eq!(total, 12);
    }
}
