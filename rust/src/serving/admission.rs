//! Per-tenant admission control: token buckets at the front door.
//!
//! One tenant's flash crowd must not starve another tenant's SLO (He et
//! al., "Adaptive Scheduling for Edge-Assisted DNN Serving"). Admission
//! is the first half of that isolation — each tenant refills a private
//! token bucket and a burst beyond it is throttled with a retry-after
//! hint *before* it can occupy queue space. The second half, weighted-
//! fair dequeue at batch assembly, lives in [`fair`](super::fair).

use std::collections::HashMap;

/// Tenants beyond this many distinct ids share one overflow bucket/lane
/// (id [`OVERFLOW_TENANT`]) so an adversarial client cycling tenant ids
/// cannot grow per-tenant state unboundedly.
pub const MAX_TENANTS: usize = 1024;

/// The shared overflow lane id for tenants past [`MAX_TENANTS`].
pub const OVERFLOW_TENANT: u32 = u32::MAX;

/// Classic token bucket over the serve session's ms clock.
#[derive(Clone, Debug)]
pub struct TokenBucket {
    rate_per_s: f64,
    burst: f64,
    tokens: f64,
    last_ms: f64,
}

impl TokenBucket {
    /// `rate_per_s` may be `f64::INFINITY` (never throttles); `burst` is
    /// the bucket depth — the largest instantaneous spike admitted.
    pub fn new(rate_per_s: f64, burst: f64) -> TokenBucket {
        let burst = burst.max(1.0);
        TokenBucket { rate_per_s: rate_per_s.max(0.0), burst, tokens: burst, last_ms: 0.0 }
    }

    fn refill(&mut self, now_ms: f64) {
        if now_ms > self.last_ms {
            if self.rate_per_s.is_infinite() {
                self.tokens = self.burst;
            } else {
                self.tokens = (self.tokens
                    + self.rate_per_s * (now_ms - self.last_ms) / 1e3)
                    .min(self.burst);
            }
            self.last_ms = now_ms;
        }
    }

    /// Take one token, or say how many ms until one accrues.
    pub fn admit(&mut self, now_ms: f64) -> Result<(), f64> {
        self.refill(now_ms);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            Ok(())
        } else if self.rate_per_s <= 0.0 {
            Err(f64::INFINITY)
        } else {
            Err(((1.0 - self.tokens) * 1e3 / self.rate_per_s).max(1.0))
        }
    }
}

/// Session-wide tenancy policy: isolation switch, default bucket shape,
/// optional per-tenant rate overrides and fair-dequeue weights.
#[derive(Clone, Debug)]
pub struct TenantPolicy {
    /// Off = no admission throttling and FIFO dequeue — the baseline the
    /// `frontdoor` experiment compares against.
    pub isolation: bool,
    /// Default per-tenant admission rate (requests/s). Infinite by
    /// default: isolation then still applies *fair dequeue*, but never
    /// throttles at admission.
    pub rate_per_s: f64,
    /// Default bucket depth (largest admitted spike).
    pub burst: f64,
    /// Per-tenant `(rate_per_s, burst)` overrides.
    pub overrides: HashMap<u32, (f64, f64)>,
    /// Per-tenant fair-dequeue weights (default 1.0).
    pub weights: HashMap<u32, f64>,
}

impl Default for TenantPolicy {
    fn default() -> TenantPolicy {
        TenantPolicy {
            isolation: true,
            rate_per_s: f64::INFINITY,
            burst: 64.0,
            overrides: HashMap::new(),
            weights: HashMap::new(),
        }
    }
}

impl TenantPolicy {
    pub fn weight(&self, tenant: u32) -> f64 {
        self.weights.get(&tenant).copied().unwrap_or(1.0).max(1e-6)
    }

    fn bucket(&self, tenant: u32) -> TokenBucket {
        let (rate, burst) = self
            .overrides
            .get(&tenant)
            .copied()
            .unwrap_or((self.rate_per_s, self.burst));
        TokenBucket::new(rate, burst)
    }
}

/// Fold a raw tenant id onto its accounting/bucket lane: ids keep their
/// identity up to [`MAX_TENANTS`] distinct tenants, then share overflow.
pub fn fold_tenant(tenant: u32, known: usize) -> u32 {
    if known >= MAX_TENANTS && tenant >= MAX_TENANTS as u32 {
        OVERFLOW_TENANT
    } else {
        tenant
    }
}

/// Stateful per-tenant admission: a lazily-built bucket per tenant lane.
#[derive(Debug, Default)]
pub struct TenantAdmission {
    policy: TenantPolicy,
    buckets: HashMap<u32, TokenBucket>,
}

impl TenantAdmission {
    pub fn new(policy: TenantPolicy) -> TenantAdmission {
        TenantAdmission { policy, buckets: HashMap::new() }
    }

    pub fn policy(&self) -> &TenantPolicy {
        &self.policy
    }

    /// Map a request's tenant id onto its lane (identity or overflow).
    pub fn lane(&self, tenant: u32) -> u32 {
        if self.buckets.contains_key(&tenant) {
            tenant
        } else {
            fold_tenant(tenant, self.buckets.len())
        }
    }

    /// Admit or throttle one request; `Err(retry_ms)` when the tenant's
    /// bucket is dry. With isolation off everything is admitted.
    pub fn admit(&mut self, tenant: u32, now_ms: f64) -> Result<(), f64> {
        if !self.policy.isolation {
            return Ok(());
        }
        let lane = self.lane(tenant);
        if !self.buckets.contains_key(&lane) {
            let b = self.policy.bucket(lane);
            self.buckets.insert(lane, b);
        }
        self.buckets.get_mut(&lane).unwrap().admit(now_ms)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_admits_burst_then_throttles_at_rate() {
        let mut b = TokenBucket::new(10.0, 4.0);
        for _ in 0..4 {
            assert!(b.admit(0.0).is_ok());
        }
        let retry = b.admit(0.0).unwrap_err();
        assert!(retry >= 1.0 && retry <= 100.0, "{retry}");
        // 10/s refills one token per 100 ms.
        assert!(b.admit(50.0).is_err());
        assert!(b.admit(101.0).is_ok());
        assert!(b.admit(102.0).is_err(), "only one token accrued");
    }

    #[test]
    fn infinite_rate_never_throttles() {
        let mut b = TokenBucket::new(f64::INFINITY, 2.0);
        for t in 0..100 {
            assert!(b.admit(t as f64).is_ok());
        }
    }

    #[test]
    fn zero_rate_throttles_after_burst_forever() {
        let mut b = TokenBucket::new(0.0, 1.0);
        assert!(b.admit(0.0).is_ok());
        assert_eq!(b.admit(1e9).unwrap_err(), f64::INFINITY);
    }

    #[test]
    fn isolation_off_admits_everything() {
        let policy = TenantPolicy {
            isolation: false,
            rate_per_s: 0.0,
            burst: 1.0,
            ..TenantPolicy::default()
        };
        let mut adm = TenantAdmission::new(policy);
        for i in 0..50 {
            assert!(adm.admit(7, i as f64).is_ok());
        }
    }

    #[test]
    fn per_tenant_buckets_are_independent() {
        let policy = TenantPolicy {
            rate_per_s: 0.0,
            burst: 2.0,
            ..TenantPolicy::default()
        };
        let mut adm = TenantAdmission::new(policy);
        assert!(adm.admit(1, 0.0).is_ok());
        assert!(adm.admit(1, 0.0).is_ok());
        assert!(adm.admit(1, 0.0).is_err(), "tenant 1 dry");
        assert!(adm.admit(2, 0.0).is_ok(), "tenant 2 unaffected");
    }

    #[test]
    fn overrides_take_precedence() {
        let mut policy = TenantPolicy::default();
        policy.rate_per_s = 0.0;
        policy.burst = 1.0;
        policy.overrides.insert(9, (f64::INFINITY, 8.0));
        let mut adm = TenantAdmission::new(policy);
        assert!(adm.admit(1, 0.0).is_ok());
        assert!(adm.admit(1, 0.0).is_err(), "default bucket binds");
        for t in 0..20 {
            assert!(adm.admit(9, t as f64).is_ok(), "override never throttles");
        }
    }

    #[test]
    fn tenant_ids_fold_to_overflow_past_the_cap() {
        let policy = TenantPolicy {
            rate_per_s: 0.0,
            burst: 1.0,
            ..TenantPolicy::default()
        };
        let mut adm = TenantAdmission::new(policy);
        // Fill the table with MAX_TENANTS distinct small ids.
        for t in 0..MAX_TENANTS as u32 {
            let _ = adm.admit(t, 0.0);
        }
        assert_eq!(adm.buckets.len(), MAX_TENANTS);
        // Large ids now share the overflow lane instead of growing state.
        let _ = adm.admit(5_000_000, 0.0);
        let _ = adm.admit(6_000_000, 0.0);
        assert_eq!(adm.buckets.len(), MAX_TENANTS + 1);
        assert_eq!(adm.lane(7_000_000), OVERFLOW_TENANT);
        // Small already-known ids keep their identity.
        assert_eq!(adm.lane(3), 3);
    }
}
