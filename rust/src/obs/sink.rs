//! The trace sink: the `Option`-flagged hook the engine records into.
//!
//! Mirrors the `InvariantChecker` pattern exactly: the engine holds an
//! `Option<Box<Tracer>>`, every hook site is one `if let`, and a `None`
//! tracer costs a branch. Two modes:
//!
//! * **Ring** — only the [`FlightRecorder`] ring is fed. This is what
//!   `enable_invariants` arms, so every fuzz/chaos run has violation
//!   context for free.
//! * **Full** — every event is additionally appended to an unbounded
//!   log for Chrome-trace export (`--trace out.json`).
//!
//! The contract (see `sim/mod.rs`): a tracer observes, it never steers.
//! Hooks take no RNG draws, push no simulator events, and return nothing
//! the engine branches on — results with tracing on are bit-identical to
//! tracing off.

use crate::Ms;

use super::recorder::FlightRecorder;
use super::span::{
    MarkKind, Phase, PlanTrigger, RoundPath, SpanKind, TraceEvent,
};

/// How much the tracer retains.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceMode {
    /// Flight-recorder ring only.
    Ring,
    /// Ring plus the full event log for export.
    Full,
}

/// Per-partition trace sink.
#[derive(Clone, Debug)]
pub struct Tracer {
    /// `Some` in [`TraceMode::Full`]: the complete, in-order event log.
    full: Option<Vec<TraceEvent>>,
    ring: FlightRecorder,
}

impl Tracer {
    pub fn new(mode: TraceMode) -> Tracer {
        Tracer {
            full: match mode {
                TraceMode::Ring => None,
                TraceMode::Full => Some(Vec::new()),
            },
            ring: FlightRecorder::new(),
        }
    }

    pub fn is_full_mode(&self) -> bool {
        self.full.is_some()
    }

    #[inline]
    pub fn record(&mut self, ev: TraceEvent) {
        if let Some(log) = self.full.as_mut() {
            log.push(ev);
        }
        self.ring.record(ev);
    }

    #[inline]
    pub fn span(
        &mut self,
        t: Ms,
        qid: u64,
        kind: SpanKind,
        phase: Phase,
        pipeline: usize,
        model: usize,
    ) {
        self.record(TraceEvent::Span {
            t,
            qid,
            kind,
            phase,
            pipeline: pipeline as u16,
            model: model as u16,
        });
    }

    #[inline]
    pub fn mark(
        &mut self,
        t: Ms,
        qid: u64,
        kind: MarkKind,
        pipeline: usize,
        model: usize,
    ) {
        self.record(TraceEvent::Mark {
            t,
            qid,
            kind,
            pipeline: pipeline as u16,
            model: model as u16,
        });
    }

    #[inline]
    pub fn batch(&mut self, t: Ms, pipeline: usize, model: usize, gpu: usize, n: usize) {
        self.record(TraceEvent::Batch {
            t,
            pipeline: pipeline as u16,
            model: model as u16,
            gpu: gpu as u16,
            n: n.min(u16::MAX as usize) as u16,
        });
    }

    #[inline]
    pub fn gpu_width(&mut self, t: Ms, gpu: usize, width: f64) {
        self.record(TraceEvent::GpuWidth { t, gpu: gpu as u16, width });
    }

    #[inline]
    pub fn plan(&mut self, t: Ms, trigger: PlanTrigger, path: RoundPath, migrations: usize) {
        self.record(TraceEvent::Plan {
            t,
            trigger,
            path,
            migrations: migrations.min(u32::MAX as usize) as u32,
        });
    }

    pub fn ring(&self) -> &FlightRecorder {
        &self.ring
    }

    /// Close every still-open span at `horizon` so the exported log has
    /// balanced `B`/`E` pairs even for queries in flight at the end of
    /// the run. Spans on one query lane are strictly sequential, so a
    /// lane has at most one open span — the last unmatched `Begin`.
    /// Synthesized `End`s are appended in ascending-qid order, which is a
    /// pure function of the log, keeping the export deterministic.
    pub fn close_open_spans(&mut self, horizon: Ms) {
        let Some(log) = self.full.as_mut() else { return };
        let mut open: std::collections::BTreeMap<u64, (SpanKind, u16, u16)> =
            std::collections::BTreeMap::new();
        for ev in log.iter() {
            if let TraceEvent::Span { qid, kind, phase, pipeline, model, .. } = *ev {
                match phase {
                    Phase::Begin => {
                        open.insert(qid, (kind, pipeline, model));
                    }
                    Phase::End => {
                        open.remove(&qid);
                    }
                }
            }
        }
        for (qid, (kind, pipeline, model)) in open {
            log.push(TraceEvent::Span {
                t: horizon,
                qid,
                kind,
                phase: Phase::End,
                pipeline,
                model,
            });
        }
    }

    /// Drain the full event log (empty in ring-only mode).
    pub fn take_events(&mut self) -> Vec<TraceEvent> {
        self.full.take().unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_mode_records_nothing_exportable_but_feeds_the_ring() {
        let mut tr = Tracer::new(TraceMode::Ring);
        tr.mark(1.0, 1, MarkKind::Capture, 0, 0);
        assert!(!tr.is_full_mode());
        assert_eq!(tr.ring().len(), 1);
        assert!(tr.take_events().is_empty());
    }

    #[test]
    fn close_open_spans_balances_in_flight_lanes() {
        let mut tr = Tracer::new(TraceMode::Full);
        // q1 completes its transfer; q2 is left open; q3 opens and closes
        // a queue wait, then opens exec.
        tr.span(1.0, 1, SpanKind::Transfer, Phase::Begin, 0, 0);
        tr.span(2.0, 1, SpanKind::Transfer, Phase::End, 0, 0);
        tr.span(1.5, 2, SpanKind::Queue, Phase::Begin, 0, 1);
        tr.span(3.0, 3, SpanKind::Queue, Phase::Begin, 1, 0);
        tr.span(4.0, 3, SpanKind::Queue, Phase::End, 1, 0);
        tr.span(4.0, 3, SpanKind::Exec, Phase::Begin, 1, 0);
        tr.close_open_spans(100.0);
        let evs = tr.take_events();
        // Balanced now: every Begin has an End on its lane.
        let mut open = std::collections::HashMap::new();
        for ev in &evs {
            if let TraceEvent::Span { qid, phase, .. } = ev {
                match phase {
                    Phase::Begin => *open.entry(qid).or_insert(0) += 1,
                    Phase::End => *open.entry(qid).or_insert(0) -= 1,
                }
            }
        }
        assert!(open.values().all(|&v| v == 0), "{open:?}");
        // Synthesized closes land at the horizon, lanes in qid order.
        let tail: Vec<_> = evs[evs.len() - 2..].to_vec();
        assert!(matches!(
            tail[0],
            TraceEvent::Span { t, qid: 2, kind: SpanKind::Queue, phase: Phase::End, .. }
                if t == 100.0
        ));
        assert!(matches!(
            tail[1],
            TraceEvent::Span { t, qid: 3, kind: SpanKind::Exec, phase: Phase::End, .. }
                if t == 100.0
        ));
    }
}
