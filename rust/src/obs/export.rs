//! Chrome-trace / Perfetto JSON export of a per-partition trace.
//!
//! Layout: one Chrome *process* per simulator partition (`pid` =
//! partition index), and within it lane `tid 0` for the control plane
//! (planner rounds), `tid 1 + g` for GPU `g` (width counters + batch
//! marks), and `tid QUERY_TID_BASE + qid` for each query's lifecycle
//! spans. Timestamps are the sim clock in microseconds — Chrome's native
//! unit — rendered with `f64`'s shortest-round-trip `Display`, so the
//! byte output is a pure function of the event list. Partitions are
//! emitted in partition order; within a partition, events in recorded
//! order: the whole file is byte-identical at any `--sim-jobs`.
//!
//! The in-tree [`validate_json`] parser (no external crates by design)
//! backs the well-formedness tests and the CLI's post-write check.

use std::fmt::Write as _;

use super::span::{Phase, TraceEvent};

/// Query lanes start here, leaving tids below for control + GPU lanes.
pub const QUERY_TID_BASE: u64 = 1000;

fn push_common(s: &mut String, name: &str, ph: &str, t_ms: f64, pid: usize, tid: u64) {
    let _ = write!(
        s,
        "{{\"name\":\"{name}\",\"ph\":\"{ph}\",\"ts\":{},\"pid\":{pid},\"tid\":{tid}",
        t_ms * 1000.0
    );
}

/// Render per-partition event lists as one Chrome-trace JSON document.
pub fn chrome_trace(partitions: &[Vec<TraceEvent>]) -> String {
    let mut s = String::from("{\"traceEvents\":[");
    let mut first = true;
    let mut sep = |s: &mut String, first: &mut bool| {
        if *first {
            *first = false;
        } else {
            s.push(',');
        }
        s.push('\n');
    };
    for (pid, events) in partitions.iter().enumerate() {
        // Process + named-lane metadata, derived from the events so the
        // header is as deterministic as the payload.
        sep(&mut s, &mut first);
        let _ = write!(
            s,
            "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{pid},\"args\":{{\"name\":\"partition {pid}\"}}}}"
        );
        sep(&mut s, &mut first);
        let _ = write!(
            s,
            "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":{pid},\"tid\":0,\"args\":{{\"name\":\"control plane\"}}}}"
        );
        let mut gpus: Vec<u16> = events
            .iter()
            .filter_map(|ev| match *ev {
                TraceEvent::Batch { gpu, .. } | TraceEvent::GpuWidth { gpu, .. } => {
                    Some(gpu)
                }
                _ => None,
            })
            .collect();
        gpus.sort_unstable();
        gpus.dedup();
        for g in gpus {
            sep(&mut s, &mut first);
            let _ = write!(
                s,
                "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":{pid},\"tid\":{},\"args\":{{\"name\":\"gpu {g}\"}}}}",
                1 + g as u64
            );
        }
        for ev in events {
            sep(&mut s, &mut first);
            match *ev {
                TraceEvent::Span { t, qid, kind, phase, pipeline, model } => {
                    let ph = match phase {
                        Phase::Begin => "B",
                        Phase::End => "E",
                    };
                    push_common(&mut s, kind.label(), ph, t, pid, QUERY_TID_BASE + qid);
                    let _ = write!(
                        s,
                        ",\"cat\":\"query\",\"args\":{{\"p\":{pipeline},\"m\":{model}}}}}"
                    );
                }
                TraceEvent::Mark { t, qid, kind, pipeline, model } => {
                    push_common(&mut s, kind.label(), "i", t, pid, QUERY_TID_BASE + qid);
                    let _ = write!(
                        s,
                        ",\"s\":\"t\",\"cat\":\"query\",\"args\":{{\"p\":{pipeline},\"m\":{model}}}}}"
                    );
                }
                TraceEvent::Batch { t, pipeline, model, gpu, n } => {
                    push_common(&mut s, "batch", "i", t, pid, 1 + gpu as u64);
                    let _ = write!(
                        s,
                        ",\"s\":\"t\",\"cat\":\"gpu\",\"args\":{{\"p\":{pipeline},\"m\":{model},\"n\":{n}}}}}"
                    );
                }
                TraceEvent::GpuWidth { t, gpu, width } => {
                    push_common(
                        &mut s,
                        &format!("gpu{gpu} width"),
                        "C",
                        t,
                        pid,
                        1 + gpu as u64,
                    );
                    let _ = write!(s, ",\"args\":{{\"width\":{width}}}}}");
                }
                TraceEvent::Plan { t, trigger, path, migrations } => {
                    push_common(&mut s, "plan", "i", t, pid, 0);
                    let _ = write!(
                        s,
                        ",\"s\":\"t\",\"cat\":\"control\",\"args\":{{\"trigger\":\"{}\",\"path\":\"{}\",\"migrations\":{migrations}}}}}",
                        trigger.label(),
                        path.label()
                    );
                }
            }
        }
    }
    s.push_str("\n],\"displayTimeUnit\":\"ms\"}\n");
    s
}

/// Check that every `Begin` on a query lane is matched by a later `End`
/// of the same kind on the same lane, with no `End` before its `Begin`
/// and no nested spans on one lane. Returns the first offence found.
pub fn check_balanced(events: &[TraceEvent]) -> Result<(), String> {
    use std::collections::HashMap;
    let mut open: HashMap<u64, super::span::SpanKind> = HashMap::new();
    for ev in events {
        if let TraceEvent::Span { qid, kind, phase, t, .. } = *ev {
            match phase {
                Phase::Begin => {
                    if let Some(prev) = open.insert(qid, kind) {
                        return Err(format!(
                            "q={qid}: {} opened at t={t} while {} still open",
                            kind.label(),
                            prev.label()
                        ));
                    }
                }
                Phase::End => match open.remove(&qid) {
                    Some(k) if k == kind => {}
                    Some(k) => {
                        return Err(format!(
                            "q={qid}: {} closed at t={t} but {} was open",
                            kind.label(),
                            k.label()
                        ))
                    }
                    None => {
                        return Err(format!(
                            "q={qid}: {} closed at t={t} with nothing open",
                            kind.label()
                        ))
                    }
                },
            }
        }
    }
    if !open.is_empty() {
        // Deterministic pick for the message: smallest qid.
        let qid = *open.keys().min().unwrap();
        return Err(format!("q={qid}: {} never closed", open[&qid].label()));
    }
    Ok(())
}

/// Minimal strict JSON validator (objects, arrays, strings, numbers,
/// bools, null) — enough to certify the exporter's output parses.
pub fn validate_json(s: &str) -> Result<(), String> {
    let b = s.as_bytes();
    let mut i = 0usize;
    let r = parse_value(b, &mut i)?;
    skip_ws(b, &mut i);
    if i != b.len() {
        return Err(format!("trailing bytes at offset {i}"));
    }
    let _ = r;
    Ok(())
}

fn skip_ws(b: &[u8], i: &mut usize) {
    while *i < b.len() && matches!(b[*i], b' ' | b'\t' | b'\n' | b'\r') {
        *i += 1;
    }
}

fn parse_value(b: &[u8], i: &mut usize) -> Result<(), String> {
    skip_ws(b, i);
    match b.get(*i) {
        Some(b'{') => parse_object(b, i),
        Some(b'[') => parse_array(b, i),
        Some(b'"') => parse_string(b, i),
        Some(b't') => parse_lit(b, i, "true"),
        Some(b'f') => parse_lit(b, i, "false"),
        Some(b'n') => parse_lit(b, i, "null"),
        Some(c) if c.is_ascii_digit() || *c == b'-' => parse_number(b, i),
        Some(c) => Err(format!("unexpected byte {:?} at offset {i}", *c as char)),
        None => Err("unexpected end of input".to_string()),
    }
}

fn parse_lit(b: &[u8], i: &mut usize, lit: &str) -> Result<(), String> {
    if b[*i..].starts_with(lit.as_bytes()) {
        *i += lit.len();
        Ok(())
    } else {
        Err(format!("bad literal at offset {i}"))
    }
}

fn parse_string(b: &[u8], i: &mut usize) -> Result<(), String> {
    debug_assert_eq!(b[*i], b'"');
    *i += 1;
    while *i < b.len() {
        match b[*i] {
            b'"' => {
                *i += 1;
                return Ok(());
            }
            b'\\' => {
                *i += 2; // exporter only emits simple escapes
            }
            _ => *i += 1,
        }
    }
    Err("unterminated string".to_string())
}

fn parse_number(b: &[u8], i: &mut usize) -> Result<(), String> {
    let start = *i;
    if b.get(*i) == Some(&b'-') {
        *i += 1;
    }
    let mut digits = 0;
    while *i < b.len() && b[*i].is_ascii_digit() {
        *i += 1;
        digits += 1;
    }
    if digits == 0 {
        return Err(format!("bad number at offset {start}"));
    }
    if b.get(*i) == Some(&b'.') {
        *i += 1;
        let mut frac = 0;
        while *i < b.len() && b[*i].is_ascii_digit() {
            *i += 1;
            frac += 1;
        }
        if frac == 0 {
            return Err(format!("bad fraction at offset {start}"));
        }
    }
    if matches!(b.get(*i), Some(b'e') | Some(b'E')) {
        *i += 1;
        if matches!(b.get(*i), Some(b'+') | Some(b'-')) {
            *i += 1;
        }
        let mut exp = 0;
        while *i < b.len() && b[*i].is_ascii_digit() {
            *i += 1;
            exp += 1;
        }
        if exp == 0 {
            return Err(format!("bad exponent at offset {start}"));
        }
    }
    Ok(())
}

fn parse_array(b: &[u8], i: &mut usize) -> Result<(), String> {
    *i += 1; // '['
    skip_ws(b, i);
    if b.get(*i) == Some(&b']') {
        *i += 1;
        return Ok(());
    }
    loop {
        parse_value(b, i)?;
        skip_ws(b, i);
        match b.get(*i) {
            Some(b',') => {
                *i += 1;
            }
            Some(b']') => {
                *i += 1;
                return Ok(());
            }
            _ => return Err(format!("expected ',' or ']' at offset {i}")),
        }
    }
}

fn parse_object(b: &[u8], i: &mut usize) -> Result<(), String> {
    *i += 1; // '{'
    skip_ws(b, i);
    if b.get(*i) == Some(&b'}') {
        *i += 1;
        return Ok(());
    }
    loop {
        skip_ws(b, i);
        if b.get(*i) != Some(&b'"') {
            return Err(format!("expected object key at offset {i}"));
        }
        parse_string(b, i)?;
        skip_ws(b, i);
        if b.get(*i) != Some(&b':') {
            return Err(format!("expected ':' at offset {i}"));
        }
        *i += 1;
        parse_value(b, i)?;
        skip_ws(b, i);
        match b.get(*i) {
            Some(b',') => {
                *i += 1;
            }
            Some(b'}') => {
                *i += 1;
                return Ok(());
            }
            _ => return Err(format!("expected ',' or '}}' at offset {i}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::span::{MarkKind, PlanTrigger, RoundPath, SpanKind};
    use super::*;

    fn sample() -> Vec<Vec<TraceEvent>> {
        vec![
            vec![
                TraceEvent::Mark {
                    t: 0.5,
                    qid: 1,
                    kind: MarkKind::Capture,
                    pipeline: 0,
                    model: 0,
                },
                TraceEvent::Span {
                    t: 0.5,
                    qid: 1,
                    kind: SpanKind::Transfer,
                    phase: Phase::Begin,
                    pipeline: 0,
                    model: 0,
                },
                TraceEvent::Span {
                    t: 2.25,
                    qid: 1,
                    kind: SpanKind::Transfer,
                    phase: Phase::End,
                    pipeline: 0,
                    model: 0,
                },
                TraceEvent::Batch { t: 3.0, pipeline: 0, model: 0, gpu: 2, n: 4 },
                TraceEvent::GpuWidth { t: 3.0, gpu: 2, width: 0.75 },
                TraceEvent::Plan {
                    t: 10.0,
                    trigger: PlanTrigger::Initial,
                    path: RoundPath::Full,
                    migrations: 0,
                },
            ],
            vec![TraceEvent::Mark {
                t: 1.0,
                qid: 1,
                kind: MarkKind::Sink,
                pipeline: 0,
                model: 1,
            }],
        ]
    }

    #[test]
    fn export_is_valid_json_and_partition_ordered() {
        let json = chrome_trace(&sample());
        validate_json(&json).unwrap();
        assert!(json.contains("\"pid\":0"));
        assert!(json.contains("\"pid\":1"));
        assert!(
            json.find("\"pid\":0").unwrap() < json.find("\"pid\":1").unwrap(),
            "partition 0 events precede partition 1"
        );
        // Sim-clock ms become Chrome µs.
        assert!(json.contains("\"ts\":2250"), "{json}");
        assert!(json.contains("partition 1"));
        assert!(json.contains("gpu 2"));
    }

    #[test]
    fn export_is_deterministic() {
        let a = chrome_trace(&sample());
        let b = chrome_trace(&sample());
        assert_eq!(a, b);
    }

    #[test]
    fn balance_checker_flags_each_offence() {
        let ok = sample();
        check_balanced(&ok[0]).unwrap();
        let unclosed = vec![TraceEvent::Span {
            t: 1.0,
            qid: 9,
            kind: SpanKind::Exec,
            phase: Phase::Begin,
            pipeline: 0,
            model: 0,
        }];
        assert!(check_balanced(&unclosed).unwrap_err().contains("never closed"));
        let orphan = vec![TraceEvent::Span {
            t: 1.0,
            qid: 9,
            kind: SpanKind::Exec,
            phase: Phase::End,
            pipeline: 0,
            model: 0,
        }];
        assert!(check_balanced(&orphan).unwrap_err().contains("nothing open"));
    }

    #[test]
    fn validator_rejects_malformed_documents() {
        validate_json("{\"a\":[1,2.5,-3e2,\"x\",true,null]}").unwrap();
        assert!(validate_json("{\"a\":1,}").is_err());
        assert!(validate_json("[1 2]").is_err());
        assert!(validate_json("{\"a\":01x}").is_err());
        assert!(validate_json("\"unterminated").is_err());
        assert!(validate_json("{} trailing").is_err());
    }
}
