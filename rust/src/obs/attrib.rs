//! SLO-miss attribution: exact decomposition of end-to-end latency.
//!
//! Every completed query's latency is split into the three lifecycle
//! segments the tracer also spans — link **transfer**, **queue** wait,
//! and GPU **exec** — with the hard guarantee that the canonical fold
//! `(transfer + queue) + exec` equals the reported end-to-end latency
//! **bit-for-bit** (enforced by `InvariantChecker::on_attrib`). The
//! segments are measured as differences of the same event-clock stamps
//! the latency itself is computed from, so they agree to fp rounding;
//! [`close_exact`] then retires that last-ulp residue deterministically.
//! A residue too large to be rounding is a bookkeeping bug (a segment
//! was skipped), and is deliberately left in place for the invariant
//! hook to trip on.

use crate::util::stats::QuantileSketch;

/// Relative residue budget: honest fp rounding across a handful of
/// additions is ~1e-16 relative; anything past 1e-9 is a lost segment.
const RESIDUE_TOL: f64 = 1e-9;

/// Latency component, in dominant-cause order of report.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Component {
    Transfer,
    Queue,
    Exec,
}

impl Component {
    pub fn label(&self) -> &'static str {
        match self {
            Component::Transfer => "transfer",
            Component::Queue => "queue",
            Component::Exec => "exec",
        }
    }
}

/// The canonical fold the exactness contract is stated over. Everything
/// that checks or reports the decomposition must sum in this order.
#[inline]
pub fn fold(transfer: f64, queue: f64, exec: f64) -> f64 {
    (transfer + queue) + exec
}

fn next_up(x: f64) -> f64 {
    // Positive finite domain only (latency segments).
    f64::from_bits(x.to_bits() + 1)
}

fn next_down(x: f64) -> f64 {
    if x == 0.0 {
        return -f64::MIN_POSITIVE;
    }
    f64::from_bits(x.to_bits() - 1)
}

/// Return `exec` adjusted so that [`fold`]`(transfer, queue, exec)`
/// equals `latency` bit-for-bit, absorbing the fp rounding residue of
/// the measured segments into the exec term (the largest one for any
/// query that actually ran). When the residue exceeds the rounding
/// budget the raw `exec` is returned unchanged, leaving the mismatch
/// visible to the invariant engine.
pub fn close_exact(latency: f64, transfer: f64, queue: f64, exec: f64) -> f64 {
    let s = transfer + queue;
    let residue = latency - (s + exec);
    if residue == 0.0 {
        return exec;
    }
    if !residue.is_finite() || residue.abs() > RESIDUE_TOL * latency.abs().max(1.0) {
        return exec;
    }
    // Fast path: one correction step almost always lands exactly.
    let ex = exec + residue;
    if s + ex == latency {
        return ex;
    }
    // Guaranteed fallback. The reals y with fl(s + y) == latency form
    // latency's rounding interval shifted by s: half-width ulp(latency)/2
    // around the exact value latency - s. The rounded remainder
    // fl(latency - s) is within ulp/2 of that center, so it sits inside
    // the interval — or exactly on its boundary when a round-to-even tie
    // pushes `s + cand` to the neighbouring f64, in which case the grid
    // point one ulp inward folds exactly. Walk a few ulps to cover it.
    let cand = latency - s;
    if s + cand == latency {
        return cand;
    }
    let (mut lo, mut hi) = (cand, cand);
    for _ in 0..4 {
        lo = next_down(lo);
        hi = next_up(hi);
        if s + lo == latency {
            return lo;
        }
        if s + hi == latency {
            return hi;
        }
    }
    exec // unreachable for rounding-sized residue; leave mismatch visible
}

/// Per-component latency sketches plus the dominant-cause breakdown of
/// SLO misses. Lives on `RunMetrics`; merged across partitions, kept
/// **out** of `RunMetrics::digest` so pre-existing digests are
/// byte-identical with or without this PR's instrumentation.
#[derive(Clone, Debug, Default)]
pub struct Attribution {
    pub transfer: QuantileSketch,
    pub queue: QuantileSketch,
    pub exec: QuantileSketch,
    /// SLO-missed units (same unit as `RunMetrics::late`: objects) by
    /// dominant component. Their sum equals `late` exactly — checked by
    /// `InvariantChecker::finish`.
    pub miss_transfer: u64,
    pub miss_queue: u64,
    pub miss_exec: u64,
}

impl Attribution {
    /// Record one completed query: `n` units (objects) with the given
    /// exact decomposition; `missed` marks an SLO miss.
    pub fn record(&mut self, transfer: f64, queue: f64, exec: f64, n: u64, missed: bool) {
        self.transfer.push_n(transfer, n);
        self.queue.push_n(queue, n);
        self.exec.push_n(exec, n);
        if missed {
            match Self::dominant(transfer, queue, exec) {
                Component::Transfer => self.miss_transfer += n,
                Component::Queue => self.miss_queue += n,
                Component::Exec => self.miss_exec += n,
            }
        }
    }

    /// Largest component wins; ties resolve in declaration order
    /// (transfer, then queue, then exec) so the breakdown is
    /// deterministic.
    pub fn dominant(transfer: f64, queue: f64, exec: f64) -> Component {
        if transfer >= queue && transfer >= exec {
            Component::Transfer
        } else if queue >= exec {
            Component::Queue
        } else {
            Component::Exec
        }
    }

    pub fn merge(&mut self, other: &Attribution) {
        self.transfer.merge(&other.transfer);
        self.queue.merge(&other.queue);
        self.exec.merge(&other.exec);
        self.miss_transfer += other.miss_transfer;
        self.miss_queue += other.miss_queue;
        self.miss_exec += other.miss_exec;
    }

    pub fn misses(&self) -> u64 {
        self.miss_transfer + self.miss_queue + self.miss_exec
    }

    /// `"queue 12 / exec 3 / transfer 0"`-style dominant-cause summary,
    /// largest bucket first (ties in declaration order).
    pub fn miss_breakdown(&self) -> String {
        let mut parts = [
            (self.miss_transfer, "transfer"),
            (self.miss_queue, "queue"),
            (self.miss_exec, "exec"),
        ];
        parts.sort_by(|a, b| b.0.cmp(&a.0));
        parts
            .iter()
            .map(|(c, l)| format!("{l} {c}"))
            .collect::<Vec<_>>()
            .join(" / ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn close_exact_retires_rounding_residue_bit_for_bit() {
        // Segments measured as stamp differences: honest accounting.
        let (t0, t1, t2, t3) = (3.1, 7.77, 123.456789, 5000.000123);
        let (tr, qu, ex) = (t1 - t0, t2 - t1, t3 - t2);
        let latency = t3 - t0;
        let ex2 = close_exact(latency, tr, qu, ex);
        assert_eq!(fold(tr, qu, ex2).to_bits(), latency.to_bits());
        // And across a seeded sweep of awkward magnitudes.
        let mut x = 0.1234567_f64;
        for i in 0..2000 {
            x = (x * 1.0000931 + 0.013) % 1.0e4;
            let a = x;
            let b = x * 0.37 + 0.001 * i as f64;
            let c = x * 1.91 + 7.3;
            let lat = (a + b) + c + (x * 1e-13 - 5e-14); // inject residue
            let got = close_exact(lat, a, b, c);
            assert_eq!(
                fold(a, b, got).to_bits(),
                lat.to_bits(),
                "i={i} a={a} b={b} c={c} lat={lat}"
            );
        }
    }

    #[test]
    fn close_exact_refuses_to_hide_a_lost_segment() {
        // A whole missing queue segment is far beyond rounding: exec must
        // come back unchanged so the invariant hook sees the mismatch.
        let (tr, qu, ex) = (10.0, 0.0, 30.0);
        let latency = 55.0; // 15 ms unaccounted
        let got = close_exact(latency, tr, qu, ex);
        assert_eq!(got, ex);
        assert_ne!(fold(tr, qu, got).to_bits(), latency.to_bits());
    }

    #[test]
    fn dominant_cause_and_breakdown_are_deterministic() {
        assert_eq!(Attribution::dominant(5.0, 5.0, 1.0), Component::Transfer);
        assert_eq!(Attribution::dominant(1.0, 5.0, 5.0), Component::Queue);
        assert_eq!(Attribution::dominant(1.0, 2.0, 5.0), Component::Exec);
        let mut a = Attribution::default();
        a.record(1.0, 8.0, 2.0, 3, true); // queue-dominant miss, 3 units
        a.record(1.0, 2.0, 9.0, 1, true); // exec-dominant miss
        a.record(1.0, 2.0, 9.0, 4, false); // on time: no miss bucket
        assert_eq!(a.misses(), 4);
        assert_eq!(a.miss_breakdown(), "queue 3 / exec 1 / transfer 0");
        assert_eq!(a.transfer.count(), 8);
    }

    #[test]
    fn merge_adds_counters_and_sketches() {
        let mut a = Attribution::default();
        a.record(1.0, 2.0, 3.0, 2, true);
        let mut b = Attribution::default();
        b.record(4.0, 1.0, 1.0, 5, true);
        a.merge(&b);
        assert_eq!(a.miss_exec, 2);
        assert_eq!(a.miss_transfer, 5);
        assert_eq!(a.queue.count(), 7);
    }
}
