//! Deterministic observability: span tracing, SLO-miss attribution, and
//! the violation flight recorder.
//!
//! Three facilities, one discipline — observation never perturbs the
//! observed run:
//!
//! * **Span tracing** ([`sink::Tracer`], [`span`], [`export`]) — an
//!   `Option`-flagged hook (same pattern as the invariant engine) that
//!   records typed, sim-clock-stamped events per query (capture, link
//!   transfer, queue wait, batch assembly, GPU exec, sink), per GPU
//!   (width counters, batch marks), and per planner round (trigger,
//!   repair-vs-full path, migration count). `octopinf simulate|fuzz
//!   --trace out.json` exports Chrome-trace/Perfetto JSON, merged in
//!   partition order so the bytes are identical at any `--sim-jobs`.
//! * **SLO-miss attribution** ([`attrib`]) — every completed query's
//!   latency decomposed into transfer/queue/exec terms whose canonical
//!   fold equals the end-to-end latency bit-for-bit, reconciled by
//!   `InvariantChecker::on_attrib` and surfaced through `RunMetrics`,
//!   `octopinf simulate`, and `octopinf why --repro <string>`.
//! * **Flight recorder** ([`recorder::FlightRecorder`]) — a fixed ring
//!   of recent trace events per partition, armed automatically with the
//!   invariant engine and dumped (with the one-line repro string) when a
//!   check trips, so a violation arrives with its event context.
//!
//! [`promtext`] is the serving-path counterpart: the `ServeReport` →
//! Prometheus text-exposition snapshot behind `serve --metrics-out`.

pub mod attrib;
pub mod export;
pub mod promtext;
pub mod recorder;
pub mod sink;
pub mod span;

pub use attrib::{close_exact, Attribution, Component};
pub use export::{check_balanced, chrome_trace, validate_json};
pub use recorder::FlightRecorder;
pub use sink::{TraceMode, Tracer};
pub use span::{MarkKind, Phase, PlanTrigger, RoundPath, SpanKind, TraceEvent};
