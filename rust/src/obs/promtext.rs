//! Prometheus text-exposition rendering of a serving-session report —
//! the scrape surface behind `octopinf serve --metrics-out <path>`.
//!
//! Writer and parser are both in-tree (zero-dependency build); the
//! parser exists so the format round-trip is testable, and doubles as a
//! reader for anything downstream that wants the snapshot back as
//! numbers. Only the subset of the exposition format we emit is parsed:
//! `# HELP`/`# TYPE` comments, and `name{label="v",...} value` samples.

use std::fmt::Write as _;

use crate::serving::ServeReport;

/// One parsed sample line.
#[derive(Clone, Debug, PartialEq)]
pub struct Sample {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub value: f64,
}

impl Sample {
    pub fn label(&self, key: &str) -> Option<&str> {
        self.labels
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }
}

fn header(out: &mut String, name: &str, kind: &str, help: &str) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {kind}");
}

/// Render one [`ServeReport`] in Prometheus text exposition format.
/// Map-valued series are emitted in sorted key order, so the snapshot is
/// deterministic for a given report.
pub fn render_serve_report(r: &ServeReport) -> String {
    let mut out = String::new();
    header(
        &mut out,
        "octopinf_requests_total",
        "counter",
        "Requests by terminal outcome.",
    );
    for (outcome, v) in [
        ("submitted", r.submitted),
        ("served", r.served),
        ("on_time", r.on_time),
        ("filtered", r.filtered),
        ("throttled", r.throttled),
        ("rejected", r.rejected),
        ("shed", r.shed),
        ("failed", r.failed),
    ] {
        let _ = writeln!(out, "octopinf_requests_total{{outcome=\"{outcome}\"}} {v}");
    }
    header(
        &mut out,
        "octopinf_cache_hits_total",
        "counter",
        "Filtered answers served from the cross-stream result cache.",
    );
    let _ = writeln!(out, "octopinf_cache_hits_total {}", r.cache_hits);

    header(
        &mut out,
        "octopinf_model_requests_total",
        "counter",
        "Engine-served requests per model.",
    );
    let mut models: Vec<_> = r.per_model.iter().collect();
    models.sort();
    for (m, c) in models {
        let _ = writeln!(out, "octopinf_model_requests_total{{model=\"{m}\"}} {c}");
    }

    header(
        &mut out,
        "octopinf_tenant_requests_total",
        "counter",
        "Per-tenant requests by terminal outcome.",
    );
    for (t, lane) in &r.per_tenant {
        for (outcome, v) in [
            ("submitted", lane.submitted),
            ("served", lane.served),
            ("on_time", lane.on_time),
            ("filtered", lane.filtered),
            ("throttled", lane.throttled),
            ("rejected", lane.rejected),
            ("shed", lane.shed),
            ("failed", lane.failed),
        ] {
            let _ = writeln!(
                out,
                "octopinf_tenant_requests_total{{tenant=\"{t}\",outcome=\"{outcome}\"}} {v}"
            );
        }
    }

    header(
        &mut out,
        "octopinf_batches_total",
        "counter",
        "Executed batches by assembled size.",
    );
    let mut hist: Vec<_> = r.batch_hist.iter().collect();
    hist.sort();
    for (b, c) in hist {
        let _ = writeln!(out, "octopinf_batches_total{{size=\"{b}\"}} {c}");
    }

    for (name, help, sketch) in [
        (
            "octopinf_request_latency_ms",
            "End-to-end request latency quantiles (engine-served).",
            &r.latency,
        ),
        (
            "octopinf_queue_wait_ms",
            "Front-door queue wait quantiles (dequeue minus submit).",
            &r.queue_wait,
        ),
        (
            "octopinf_exec_ms",
            "Engine batch execution time quantiles.",
            &r.exec_time,
        ),
    ] {
        header(&mut out, name, "gauge", help);
        if !sketch.is_empty() {
            for (q, v) in [
                (0.5, sketch.p50()),
                (0.95, sketch.p95()),
                (0.99, sketch.p99()),
            ] {
                let _ = writeln!(out, "{name}{{quantile=\"{q}\"}} {v}");
            }
        }
    }

    header(
        &mut out,
        "octopinf_shard_peak_depth",
        "gauge",
        "Peak queued requests observed per batcher shard.",
    );
    for (s, d) in r.peak_shard_depth.iter().enumerate() {
        let _ = writeln!(out, "octopinf_shard_peak_depth{{shard=\"{s}\"}} {d}");
    }

    header(
        &mut out,
        "octopinf_slo_attainment",
        "gauge",
        "On-time fraction of answered requests.",
    );
    let _ = writeln!(out, "octopinf_slo_attainment {}", r.slo_attainment());
    header(
        &mut out,
        "octopinf_wall_ms",
        "gauge",
        "Serving session wall-clock duration.",
    );
    let _ = writeln!(out, "octopinf_wall_ms {}", r.wall_ms);
    out
}

/// Parse the exposition subset [`render_serve_report`] emits.
pub fn parse(text: &str) -> Result<Vec<Sample>, String> {
    let mut out = Vec::new();
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (series, value) = line
            .rsplit_once(' ')
            .ok_or_else(|| format!("line {}: no value", ln + 1))?;
        let value: f64 = value
            .parse()
            .map_err(|e| format!("line {}: bad value ({e})", ln + 1))?;
        let (name, labels) = match series.split_once('{') {
            None => (series.to_string(), Vec::new()),
            Some((name, rest)) => {
                let body = rest
                    .strip_suffix('}')
                    .ok_or_else(|| format!("line {}: unterminated labels", ln + 1))?;
                let mut labels = Vec::new();
                for pair in body.split(',').filter(|p| !p.is_empty()) {
                    let (k, v) = pair
                        .split_once('=')
                        .ok_or_else(|| format!("line {}: bad label {pair:?}", ln + 1))?;
                    let v = v
                        .strip_prefix('"')
                        .and_then(|v| v.strip_suffix('"'))
                        .ok_or_else(|| {
                            format!("line {}: unquoted label value {v:?}", ln + 1)
                        })?;
                    labels.push((k.to_string(), v.to_string()));
                }
                (name.to_string(), labels)
            }
        };
        if name.is_empty()
            || !name
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
        {
            return Err(format!("line {}: bad metric name {name:?}", ln + 1));
        }
        out.push(Sample { name, labels, value });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> ServeReport {
        let mut r = ServeReport::default();
        r.submitted = 100;
        r.served = 80;
        r.on_time = 75;
        r.filtered = 10;
        r.cache_hits = 4;
        r.throttled = 5;
        r.rejected = 3;
        r.shed = 1;
        r.failed = 1;
        r.per_model.insert("det".into(), 60);
        r.per_model.insert("cls".into(), 20);
        r.lane(1).served = 40;
        r.lane(1).submitted = 50;
        *r.batch_hist.entry(8).or_default() += 3;
        for i in 0..20 {
            r.latency.push(5.0 + i as f64);
            r.queue_wait.push(1.0 + i as f64 * 0.1);
            r.exec_time.push(3.0);
        }
        r.peak_shard_depth = vec![7, 2];
        r.wall_ms = 1234.5;
        r
    }

    #[test]
    fn round_trips_through_the_parser() {
        let r = report();
        let text = render_serve_report(&r);
        let samples = parse(&text).unwrap();
        let get = |name: &str, key: &str, val: &str| -> f64 {
            samples
                .iter()
                .find(|s| s.name == name && s.label(key) == Some(val))
                .unwrap_or_else(|| panic!("missing {name}{{{key}={val}}}"))
                .value
        };
        assert_eq!(get("octopinf_requests_total", "outcome", "submitted"), 100.0);
        assert_eq!(get("octopinf_requests_total", "outcome", "served"), 80.0);
        assert_eq!(get("octopinf_model_requests_total", "model", "det"), 60.0);
        assert_eq!(get("octopinf_tenant_requests_total", "outcome", "served"), 40.0);
        assert_eq!(get("octopinf_batches_total", "size", "8"), 3.0);
        assert_eq!(get("octopinf_shard_peak_depth", "shard", "0"), 7.0);
        let wall = samples
            .iter()
            .find(|s| s.name == "octopinf_wall_ms")
            .unwrap();
        assert_eq!(wall.value, 1234.5);
        let p50 = get("octopinf_request_latency_ms", "quantile", "0.5");
        assert!(p50 > 0.0);
        // Rendering a parsed-equal report again is byte-identical.
        assert_eq!(text, render_serve_report(&r));
    }

    #[test]
    fn parser_rejects_malformed_lines() {
        assert!(parse("octopinf_x{a=b} 1").is_err(), "unquoted label value");
        assert!(parse("octopinf_x 1 2 3").is_err(), "bad value");
        assert!(parse("bad name 1").is_err());
        assert!(parse("octopinf_x{a=\"1\" 2").is_err(), "unterminated labels");
        // Comments and blanks are fine.
        assert_eq!(parse("# TYPE x counter\n\n").unwrap().len(), 0);
    }
}
