//! The violation flight recorder: a fixed-size ring of the most recent
//! trace events per partition.
//!
//! The ring is always armed alongside the invariant engine, so when a
//! conservation check (or the chaos harness) trips, the report is not a
//! bare "violation at t=…" line but the event context that led up to it
//! — plus the one-line repro string that replays the scenario. Recording
//! is an index bump and a `Copy` store: cheap enough to ride every
//! fuzz/chaos run without showing up in the dispatch hot path.

use super::span::TraceEvent;

/// Ring capacity: enough to cover several scheduling epochs of a busy
/// partition while keeping the per-partition footprint a few KiB.
pub const RING_CAP: usize = 256;

/// Fixed-size overwrite ring of recent [`TraceEvent`]s.
#[derive(Clone, Debug, Default)]
pub struct FlightRecorder {
    buf: Vec<TraceEvent>,
    /// Next slot to overwrite once the ring is full.
    next: usize,
    /// Total events ever recorded (≥ `buf.len()`).
    total: u64,
}

impl FlightRecorder {
    pub fn new() -> FlightRecorder {
        FlightRecorder::default()
    }

    #[inline]
    pub fn record(&mut self, ev: TraceEvent) {
        self.total += 1;
        if self.buf.len() < RING_CAP {
            self.buf.push(ev);
        } else {
            self.buf[self.next] = ev;
            self.next = (self.next + 1) % RING_CAP;
        }
    }

    /// Events currently held (≤ [`RING_CAP`]).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Total events ever recorded, including overwritten ones.
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Retained events, oldest first.
    pub fn events(&self) -> Vec<TraceEvent> {
        let mut out = Vec::with_capacity(self.buf.len());
        out.extend_from_slice(&self.buf[self.next..]);
        out.extend_from_slice(&self.buf[..self.next]);
        out
    }

    /// Render the ring as the violation postscript: a header carrying the
    /// repro string, then one line per retained event, oldest first.
    pub fn dump(&self, repro: &str) -> String {
        use std::fmt::Write;
        let mut s = format!(
            "flight recorder: {} of {} trace events (repro: {repro})",
            self.len(),
            self.total()
        );
        for ev in self.events() {
            let _ = write!(s, "\n  {}", ev.describe());
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::super::span::{MarkKind, TraceEvent};
    use super::*;

    fn mark(t: f64, qid: u64) -> TraceEvent {
        TraceEvent::Mark { t, qid, kind: MarkKind::Capture, pipeline: 0, model: 0 }
    }

    #[test]
    fn ring_keeps_the_most_recent_events_in_order() {
        let mut r = FlightRecorder::new();
        let n = RING_CAP as u64 + 10;
        for i in 0..n {
            r.record(mark(i as f64, i));
        }
        assert_eq!(r.len(), RING_CAP);
        assert_eq!(r.total(), n);
        let evs = r.events();
        // Oldest retained is event 10; newest is n-1, strictly in order.
        assert_eq!(evs.first().unwrap().t(), 10.0);
        assert_eq!(evs.last().unwrap().t(), (n - 1) as f64);
        assert!(evs.windows(2).all(|w| w[0].t() < w[1].t()));
    }

    #[test]
    fn dump_carries_the_repro_string_and_every_retained_event() {
        let mut r = FlightRecorder::new();
        for i in 0..3u64 {
            r.record(mark(i as f64, i));
        }
        let d = r.dump("fuzz:v1:seed=42:faults=2");
        assert!(d.starts_with("flight recorder: 3 of 3 trace events"));
        assert!(d.contains("repro: fuzz:v1:seed=42:faults=2"));
        assert_eq!(d.lines().count(), 4, "header + one line per event");
    }
}
