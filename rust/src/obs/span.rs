//! Typed trace events: the vocabulary of the observability layer.
//!
//! One event is one `Copy` record stamped with the **simulation clock**
//! (never wall time), so a trace is a pure function of the scenario
//! config and replays byte-identical at any `--sim-jobs`. Events are
//! deliberately flat — no heap payloads — so the flight-recorder ring
//! can overwrite slots without allocation on the engine hot path.

use crate::Ms;

/// Lifecycle segment of one query, traced as a Chrome `B`/`E` span pair
/// on the query's lane. The three kinds tile a query's life exactly:
/// every completed query is `Transfer → Queue → Exec` (repeated once per
/// pipeline stage), and the same three segments are what
/// [`SLO-miss attribution`](crate::obs::attrib) decomposes latency into.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// On a link (or loopback) between the source / previous stage and
    /// the device hosting the next model instance.
    Transfer,
    /// Waiting in a group queue for batch assembly.
    Queue,
    /// Riding a dispatched batch on a GPU.
    Exec,
}

impl SpanKind {
    pub fn label(&self) -> &'static str {
        match self {
            SpanKind::Transfer => "transfer",
            SpanKind::Queue => "queue",
            SpanKind::Exec => "exec",
        }
    }
}

/// Span boundary: Chrome trace phase `B` or `E`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Begin,
    End,
}

/// Instantaneous mark on a query lane.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MarkKind {
    /// Frame captured at the source: the query is born.
    Capture,
    /// Reached its sink: end-to-end latency is final.
    Sink,
    /// Dropped (queue overflow, dead link, or expired deadline).
    Drop,
    /// Lost to a fault (dead source or a doomed in-flight batch).
    Lost,
}

impl MarkKind {
    pub fn label(&self) -> &'static str {
        match self {
            MarkKind::Capture => "capture",
            MarkKind::Sink => "sink",
            MarkKind::Drop => "drop",
            MarkKind::Lost => "lost",
        }
    }
}

/// What caused a planner round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanTrigger {
    /// First plan at simulation start.
    Initial,
    /// The 6-minute scheduling period.
    Periodic,
    /// Deferred round released by a controller-outage end.
    CatchUp,
    /// Drift detector fired.
    Drift,
    /// Device crash / recovery notification.
    Fault,
}

impl PlanTrigger {
    pub fn label(&self) -> &'static str {
        match self {
            PlanTrigger::Initial => "initial",
            PlanTrigger::Periodic => "periodic",
            PlanTrigger::CatchUp => "catch-up",
            PlanTrigger::Drift => "drift",
            PlanTrigger::Fault => "fault",
        }
    }
}

/// How a planner round was satisfied: the incremental CWD-subset +
/// CORAL-repair path, or a full CWD+CORAL pass (baselines and fallback
/// rounds). Purely observational — returned by
/// [`Scheduler::round_path`](crate::coordinator::Scheduler::round_path)
/// for tracing; it must never steer scheduling.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoundPath {
    Full,
    Repair,
}

impl RoundPath {
    pub fn label(&self) -> &'static str {
        match self {
            RoundPath::Full => "full",
            RoundPath::Repair => "repair",
        }
    }
}

/// One trace record. `qid` lanes carry query lifecycles, GPU lanes carry
/// width counters and batch marks, and the control lane (tid 0 in the
/// export) carries planner rounds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TraceEvent {
    /// Span boundary on query lane `qid`, annotated with the pipeline
    /// stage (`pipeline`, `model`) the segment belongs to.
    Span { t: Ms, qid: u64, kind: SpanKind, phase: Phase, pipeline: u16, model: u16 },
    /// Instantaneous mark on query lane `qid`.
    Mark { t: Ms, qid: u64, kind: MarkKind, pipeline: u16, model: u16 },
    /// Batch assembled: `n` queries of `(pipeline, model)` dispatched to
    /// GPU `gpu`.
    Batch { t: Ms, pipeline: u16, model: u16, gpu: u16, n: u16 },
    /// Busy-width sample on GPU `gpu` (Chrome counter event).
    GpuWidth { t: Ms, gpu: u16, width: f64 },
    /// Planner round on the control lane.
    Plan { t: Ms, trigger: PlanTrigger, path: RoundPath, migrations: u32 },
}

impl TraceEvent {
    /// Sim-clock timestamp of the event.
    pub fn t(&self) -> Ms {
        match *self {
            TraceEvent::Span { t, .. }
            | TraceEvent::Mark { t, .. }
            | TraceEvent::Batch { t, .. }
            | TraceEvent::GpuWidth { t, .. }
            | TraceEvent::Plan { t, .. } => t,
        }
    }

    /// One-line human rendering, used by the flight-recorder dump.
    pub fn describe(&self) -> String {
        match *self {
            TraceEvent::Span { t, qid, kind, phase, pipeline, model } => {
                let ph = match phase {
                    Phase::Begin => "B",
                    Phase::End => "E",
                };
                format!(
                    "[{t:>12.3} ms] {ph} {:<8} q={qid} stage={pipeline}/{model}",
                    kind.label()
                )
            }
            TraceEvent::Mark { t, qid, kind, pipeline, model } => format!(
                "[{t:>12.3} ms] i {:<8} q={qid} stage={pipeline}/{model}",
                kind.label()
            ),
            TraceEvent::Batch { t, pipeline, model, gpu, n } => format!(
                "[{t:>12.3} ms] i batch    gpu={gpu} stage={pipeline}/{model} n={n}"
            ),
            TraceEvent::GpuWidth { t, gpu, width } => {
                format!("[{t:>12.3} ms] C gpu{gpu} width={width}")
            }
            TraceEvent::Plan { t, trigger, path, migrations } => format!(
                "[{t:>12.3} ms] i plan     trigger={} path={} migrations={migrations}",
                trigger.label(),
                path.label()
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn describe_is_single_line_and_carries_the_ids() {
        let evs = [
            TraceEvent::Span {
                t: 12.5,
                qid: 7,
                kind: SpanKind::Queue,
                phase: Phase::Begin,
                pipeline: 1,
                model: 2,
            },
            TraceEvent::Mark {
                t: 13.0,
                qid: 7,
                kind: MarkKind::Sink,
                pipeline: 1,
                model: 2,
            },
            TraceEvent::Batch { t: 14.0, pipeline: 0, model: 0, gpu: 3, n: 8 },
            TraceEvent::GpuWidth { t: 14.0, gpu: 3, width: 1.5 },
            TraceEvent::Plan {
                t: 15.0,
                trigger: PlanTrigger::Drift,
                path: RoundPath::Repair,
                migrations: 2,
            },
        ];
        for ev in evs {
            let d = ev.describe();
            assert!(!d.contains('\n'), "{d:?}");
        }
        assert!(evs[0].describe().contains("q=7"));
        assert!(evs[4].describe().contains("path=repair"));
        assert_eq!(evs[2].t(), 14.0);
    }
}
