//! Experiment configuration: a small INI/TOML-subset format (`key = value`
//! with `[section]` headers — no serde in the offline registry) plus
//! validated experiment presets for every figure.

use std::collections::HashMap;

use crate::coordinator::{ReplanMode, SchedulerKind};
use crate::network::TraceKind;
use crate::sim::faults::CrashPolicy;

/// Raw parsed config: section -> key -> value.
#[derive(Clone, Debug, Default)]
pub struct RawConfig {
    sections: HashMap<String, HashMap<String, String>>,
}

impl RawConfig {
    /// Parse the INI-like text. Lines: `[section]`, `key = value`, `#`/`;`
    /// comments, blank lines.
    pub fn parse(text: &str) -> Result<RawConfig, String> {
        let mut cfg = RawConfig::default();
        let mut section = String::from("general");
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') || line.starts_with(';') {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unclosed section", ln + 1))?;
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", ln + 1))?;
            cfg.sections
                .entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), v.trim().trim_matches('"').to_string());
        }
        Ok(cfg)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(String::as_str)
    }

    pub fn get_f64(&self, section: &str, key: &str) -> Option<f64> {
        self.get(section, key)?.parse().ok()
    }

    pub fn get_u64(&self, section: &str, key: &str) -> Option<u64> {
        self.get(section, key)?.parse().ok()
    }

    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        match self.get(section, key)? {
            "true" | "1" | "yes" => Some(true),
            "false" | "0" | "no" => Some(false),
            _ => None,
        }
    }
}

/// Fully-resolved experiment configuration.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Number of edge devices with cameras (paper: 9).
    pub n_sources: usize,
    /// Cameras per device (Fig. 8 doubles this to 2).
    pub cameras_per_device: usize,
    /// Trace kind for edge uplinks.
    pub trace: TraceKind,
    /// Simulated duration, ms (paper main runs: 30 min).
    pub duration_ms: f64,
    /// SLO tightening (subtracted from each pipeline's SLO; Fig. 9).
    pub slo_reduction_ms: f64,
    /// Scheduler under test.
    pub scheduler: SchedulerKind,
    /// Root RNG seed.
    pub seed: u64,
    /// Use the 13-hour diurnal content profile (Fig. 11) instead of the
    /// 30-min segment profile.
    pub diurnal: bool,
    /// Replanning policy: fixed 6-min rounds only, or rounds plus
    /// drift-triggered incremental replans (`--replan drift`).
    pub replan: ReplanMode,
    /// Number of injected fault windows (0 disarms fault injection;
    /// repro-string modifier `:faults=M`).
    pub faults: u32,
    /// Same-time event permutation seed (0 keeps insertion order;
    /// repro-string modifier `:order=K`).
    pub order_seed: u64,
    /// Failure-aware recovery: replan around crashes via
    /// `Scheduler::on_fault` and force a fresh round when a controller
    /// outage ends. Off = the data plane degrades open-loop.
    pub recovery: bool,
    /// What happens to a crashed device's queued queries.
    pub crash_policy: CrashPolicy,
    /// Content-aware frontend: per-pipeline frame-difference filtering in
    /// the sim, so schedulers plan against the *filtered* workload (the
    /// serving path's `FrontDoor` filter, modelled at the scene level).
    pub frontend: bool,
    /// Mean static-scene run length in frames for the frontend model
    /// (larger = more consecutive near-identical frames get filtered).
    pub scene_static_frames: f64,
    /// Independent edge clusters (sim partitions). Partition 0 runs this
    /// exact config; replicas re-derive their workload from
    /// splitmix-separated seeds (`sim::partition_seed`). Part of the
    /// workload definition — unlike `--sim-jobs`, which only picks how
    /// many threads tick the partitions.
    pub clusters: usize,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            n_sources: 9,
            cameras_per_device: 1,
            trace: TraceKind::FiveG,
            duration_ms: 30.0 * 60.0 * 1000.0,
            slo_reduction_ms: 0.0,
            scheduler: SchedulerKind::OctopInf,
            seed: 42,
            diurnal: false,
            replan: ReplanMode::Periodic,
            faults: 0,
            order_seed: 0,
            recovery: true,
            crash_policy: CrashPolicy::Reroute,
            frontend: false,
            scene_static_frames: 120.0,
            clusters: 1,
        }
    }
}

impl ExperimentConfig {
    /// Load from the INI-subset format.
    pub fn from_text(text: &str) -> Result<ExperimentConfig, String> {
        let raw = RawConfig::parse(text)?;
        let mut cfg = ExperimentConfig::default();
        if let Some(v) = raw.get_u64("experiment", "n_sources") {
            cfg.n_sources = v as usize;
        }
        if let Some(v) = raw.get_u64("experiment", "cameras_per_device") {
            cfg.cameras_per_device = v as usize;
        }
        if let Some(v) = raw.get("experiment", "trace") {
            cfg.trace = match v {
                "5g" | "fiveg" => TraceKind::FiveG,
                "lte" => TraceKind::Lte,
                "constant" => TraceKind::Constant,
                other => return Err(format!("unknown trace {other:?}")),
            };
        }
        if let Some(v) = raw.get_f64("experiment", "duration_min") {
            cfg.duration_ms = v * 60_000.0;
        }
        if let Some(v) = raw.get_f64("experiment", "slo_reduction_ms") {
            cfg.slo_reduction_ms = v;
        }
        if let Some(v) = raw.get("experiment", "scheduler") {
            cfg.scheduler = SchedulerKind::parse(v)
                .ok_or_else(|| format!("unknown scheduler {v:?}"))?;
        }
        if let Some(v) = raw.get_u64("experiment", "seed") {
            cfg.seed = v;
        }
        if let Some(v) = raw.get_bool("experiment", "diurnal") {
            cfg.diurnal = v;
        }
        if let Some(v) = raw.get("experiment", "replan") {
            cfg.replan = ReplanMode::parse(v)
                .ok_or_else(|| format!("unknown replan mode {v:?}"))?;
        }
        if let Some(v) = raw.get_u64("experiment", "faults") {
            cfg.faults = v as u32;
        }
        if let Some(v) = raw.get_u64("experiment", "order") {
            cfg.order_seed = v;
        }
        if let Some(v) = raw.get_bool("experiment", "recovery") {
            cfg.recovery = v;
        }
        if let Some(v) = raw.get("experiment", "crash_policy") {
            cfg.crash_policy = CrashPolicy::parse(v)
                .ok_or_else(|| format!("unknown crash policy {v:?}"))?;
        }
        if let Some(v) = raw.get_bool("experiment", "frontend") {
            cfg.frontend = v;
        }
        if let Some(v) = raw.get_f64("experiment", "scene_static_frames") {
            cfg.scene_static_frames = v;
        }
        if let Some(v) = raw.get_u64("experiment", "clusters") {
            cfg.clusters = v as usize;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.n_sources == 0 || self.n_sources > 9 {
            return Err(format!("n_sources {} not in 1..=9", self.n_sources));
        }
        if self.cameras_per_device == 0 || self.cameras_per_device > 4 {
            return Err("cameras_per_device must be 1..=4".into());
        }
        if self.duration_ms <= 0.0 {
            return Err("duration must be positive".into());
        }
        if self.slo_reduction_ms < 0.0 || self.slo_reduction_ms >= 150.0 {
            return Err("slo_reduction_ms must be in [0, 150)".into());
        }
        if self.faults > 64 {
            return Err(format!("faults {} not in 0..=64", self.faults));
        }
        if !self.scene_static_frames.is_finite() || self.scene_static_frames < 0.0 {
            return Err(format!(
                "scene_static_frames {} must be finite and >= 0",
                self.scene_static_frames
            ));
        }
        if self.clusters == 0 || self.clusters > 64 {
            return Err(format!("clusters {} not in 1..=64", self.clusters));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_ini_subset() {
        let raw = RawConfig::parse(
            "# comment\n[experiment]\nn_sources = 4\ntrace = \"lte\"\n\n[x]\nk=v\n",
        )
        .unwrap();
        assert_eq!(raw.get("experiment", "n_sources"), Some("4"));
        assert_eq!(raw.get("experiment", "trace"), Some("lte"));
        assert_eq!(raw.get("x", "k"), Some("v"));
        assert_eq!(raw.get("x", "missing"), None);
    }

    #[test]
    fn rejects_malformed() {
        assert!(RawConfig::parse("[unclosed\n").is_err());
        assert!(RawConfig::parse("novalue\n").is_err());
    }

    #[test]
    fn experiment_from_text() {
        let cfg = ExperimentConfig::from_text(
            "[experiment]\nn_sources = 3\nscheduler = rim\nduration_min = 5\ntrace = lte\n",
        )
        .unwrap();
        assert_eq!(cfg.n_sources, 3);
        assert_eq!(cfg.scheduler, SchedulerKind::Rim);
        assert_eq!(cfg.duration_ms, 300_000.0);
        assert_eq!(cfg.trace, TraceKind::Lte);
    }

    #[test]
    fn validation_bounds() {
        let mut cfg = ExperimentConfig::default();
        cfg.n_sources = 0;
        assert!(cfg.validate().is_err());
        cfg.n_sources = 10;
        assert!(cfg.validate().is_err());
        cfg = ExperimentConfig::default();
        cfg.slo_reduction_ms = 200.0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn unknown_scheduler_is_error() {
        assert!(ExperimentConfig::from_text("[experiment]\nscheduler = foo\n")
            .is_err());
    }

    #[test]
    fn fault_knobs_parse_and_validate() {
        let d = ExperimentConfig::default();
        assert_eq!(d.faults, 0);
        assert_eq!(d.order_seed, 0);
        assert!(d.recovery);
        assert_eq!(d.crash_policy, CrashPolicy::Reroute);
        let cfg = ExperimentConfig::from_text(
            "[experiment]\nfaults = 3\norder = 99\nrecovery = no\ncrash_policy = drop\n",
        )
        .unwrap();
        assert_eq!(cfg.faults, 3);
        assert_eq!(cfg.order_seed, 99);
        assert!(!cfg.recovery);
        assert_eq!(cfg.crash_policy, CrashPolicy::Drop);
        assert!(ExperimentConfig::from_text("[experiment]\nfaults = 65\n").is_err());
        assert!(
            ExperimentConfig::from_text("[experiment]\ncrash_policy = explode\n")
                .is_err()
        );
    }

    #[test]
    fn frontend_knobs_parse_and_validate() {
        let d = ExperimentConfig::default();
        assert!(!d.frontend, "frontend defaults off");
        assert_eq!(d.scene_static_frames, 120.0);
        let cfg = ExperimentConfig::from_text(
            "[experiment]\nfrontend = yes\nscene_static_frames = 240\n",
        )
        .unwrap();
        assert!(cfg.frontend);
        assert_eq!(cfg.scene_static_frames, 240.0);
        assert!(ExperimentConfig::from_text(
            "[experiment]\nscene_static_frames = -5\n"
        )
        .is_err());
    }

    #[test]
    fn clusters_parse_and_validate() {
        assert_eq!(ExperimentConfig::default().clusters, 1);
        let cfg =
            ExperimentConfig::from_text("[experiment]\nclusters = 4\n").unwrap();
        assert_eq!(cfg.clusters, 4);
        assert!(ExperimentConfig::from_text("[experiment]\nclusters = 0\n")
            .is_err());
        assert!(ExperimentConfig::from_text("[experiment]\nclusters = 65\n")
            .is_err());
    }

    #[test]
    fn replan_mode_parses_and_defaults_to_periodic() {
        assert_eq!(ExperimentConfig::default().replan, ReplanMode::Periodic);
        let cfg =
            ExperimentConfig::from_text("[experiment]\nreplan = drift\n").unwrap();
        assert_eq!(cfg.replan, ReplanMode::Drift);
        assert!(ExperimentConfig::from_text("[experiment]\nreplan = bogus\n")
            .is_err());
    }
}
