//! Artifact manifest parser (`artifacts/manifest.tsv`, written by
//! `python -m compile.aot`). TSV because the offline rust dependency set
//! has no JSON parser — the JSON flavor next to it is for humans.

use std::collections::HashMap;
use std::path::Path;

use crate::anyhow;
use crate::util::error::{Context, Result};

/// Metadata of one compiled (model, batch) artifact.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub model: String,
    pub batch: usize,
    pub file: String,
    /// Per-sample input shape (without batch dim).
    pub input_shape: Vec<usize>,
    /// Per-sample output shape.
    pub output_shape: Vec<usize>,
    pub flops_per_sample: u64,
    pub param_count: u64,
}

/// All artifacts, indexed by (model, batch).
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    by_key: HashMap<(String, usize), ArtifactMeta>,
}

fn parse_shape(s: &str) -> Result<Vec<usize>> {
    s.split('x')
        .map(|d| d.parse::<usize>().map_err(|e| anyhow!("shape {s:?}: {e}")))
        .collect()
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Manifest::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let mut m = Manifest::default();
        for (i, line) in text.lines().enumerate() {
            if i == 0 && line.starts_with("model\t") {
                continue;
            }
            if line.trim().is_empty() {
                continue;
            }
            let c: Vec<&str> = line.split('\t').collect();
            if c.len() != 7 {
                return Err(anyhow!("manifest row {}: {} cols", i + 1, c.len()));
            }
            let meta = ArtifactMeta {
                model: c[0].to_string(),
                batch: c[1].parse().context("batch")?,
                file: c[2].to_string(),
                input_shape: parse_shape(c[3])?,
                output_shape: parse_shape(c[4])?,
                flops_per_sample: c[5].parse().context("flops")?,
                param_count: c[6].parse().context("params")?,
            };
            m.by_key.insert((meta.model.clone(), meta.batch), meta);
        }
        if m.by_key.is_empty() {
            return Err(anyhow!("empty manifest"));
        }
        Ok(m)
    }

    pub fn get(&self, model: &str, batch: usize) -> Option<&ArtifactMeta> {
        self.by_key.get(&(model.to_string(), batch))
    }

    /// Distinct model names, sorted.
    pub fn models(&self) -> Vec<&str> {
        let mut v: Vec<&str> =
            self.by_key.keys().map(|(m, _)| m.as_str()).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Available batch sizes for a model, ascending.
    pub fn batches(&self, model: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .by_key
            .keys()
            .filter(|(m, _)| m == model)
            .map(|&(_, b)| b)
            .collect();
        v.sort_unstable();
        v
    }

    pub fn len(&self) -> usize {
        self.by_key.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_key.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "model\tbatch\tfile\tinput_shape\toutput_shape\tflops_per_sample\tparam_count\n\
det_s\t1\tdet_s_b1.hlo.txt\t96x96x3\t108x9\t15386112\t62267\n\
det_s\t4\tdet_s_b4.hlo.txt\t96x96x3\t108x9\t15386112\t62267\n\
classifier\t8\tclassifier_b8.hlo.txt\t32x32x3\t8\t2500000\t7000\n";

    #[test]
    fn parses_rows() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.len(), 3);
        let a = m.get("det_s", 4).unwrap();
        assert_eq!(a.input_shape, vec![96, 96, 3]);
        assert_eq!(a.output_shape, vec![108, 9]);
        assert_eq!(m.models(), vec!["classifier", "det_s"]);
        assert_eq!(m.batches("det_s"), vec![1, 4]);
    }

    #[test]
    fn rejects_bad_rows() {
        assert!(Manifest::parse("model\tbatch\nonly\ttwo\n").is_err());
        assert!(Manifest::parse("").is_err());
        assert!(Manifest::parse(
            "a\tnot_a_number\tf\t1x1\t1\t0\t0\n"
        )
        .is_err());
    }
}
