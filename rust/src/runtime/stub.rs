//! Build-time stand-in for the PJRT runtime when the `pjrt` feature is
//! off: the same API surface, with every entry point failing cleanly so
//! callers (CLI `profile`/`serve`, the e2e example) report a clear error
//! instead of the crate failing to build without the `xla` dependency.
//! The simulator/scheduler stack never touches this module.

use std::path::Path;

use crate::anyhow;
use crate::runtime::{ArtifactMeta, Manifest};
use crate::util::error::Result;

const NO_PJRT: &str = "octopinf was built without the `pjrt` feature; \
    real PJRT execution is unavailable (rebuild with `--features pjrt` \
    and the `xla` dependency — simulation paths are unaffected)";

/// A compiled executable for one (model, batch) — stub.
pub struct Engine {
    pub meta: ArtifactMeta,
}

impl Engine {
    pub fn execute(&self, _input: &[f32]) -> Result<Vec<f32>> {
        Err(anyhow!("{NO_PJRT}"))
    }

    /// Output element count per batch.
    pub fn output_len(&self) -> usize {
        self.meta.batch * self.meta.output_shape.iter().product::<usize>()
    }
}

/// Loads and caches engines for every artifact in a directory — stub.
pub struct Runtime {
    pub manifest: Manifest,
}

impl Runtime {
    pub fn new(_artifacts_dir: &Path) -> Result<Runtime> {
        Err(anyhow!("{NO_PJRT}"))
    }

    pub fn engine(&mut self, model: &str, batch: usize) -> Result<&Engine> {
        Err(anyhow!("{NO_PJRT} (requested {model}_b{batch})"))
    }

    pub fn execute_padded(
        &mut self,
        model: &str,
        batch: usize,
        _n: usize,
        _input: &[f32],
    ) -> Result<Vec<f32>> {
        Err(anyhow!("{NO_PJRT} (requested {model}_b{batch})"))
    }

    pub fn profile(&mut self, model: &str, batch: usize, _reps: usize) -> Result<f64> {
        Err(anyhow!("{NO_PJRT} (requested {model}_b{batch})"))
    }

    pub fn models(&self) -> Vec<&str> {
        self.manifest.models()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_runtime_fails_cleanly() {
        let err = Runtime::new(Path::new("artifacts")).err().unwrap();
        assert!(format!("{err}").contains("pjrt"), "{err}");
    }
}
