//! XLA/PJRT-backed runtime (the `pjrt` feature). Requires the `xla` crate
//! from the offline registry; see Cargo.toml.
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO **text** is the
//! interchange format (jax >= 0.5 emits 64-bit-id protos that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::anyhow;
use crate::runtime::{ArtifactMeta, Manifest};
use crate::util::error::{Context, Result};

/// A compiled executable for one (model, batch).
pub struct Engine {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl Engine {
    /// Run one batch. `input` must contain exactly
    /// `batch * prod(input_shape)` f32s (pad partial batches first).
    pub fn execute(&self, input: &[f32]) -> Result<Vec<f32>> {
        let want: usize =
            self.meta.batch * self.meta.input_shape.iter().product::<usize>();
        if input.len() != want {
            return Err(anyhow!(
                "{}_b{}: input len {} != expected {}",
                self.meta.model,
                self.meta.batch,
                input.len(),
                want
            ));
        }
        let mut dims: Vec<i64> = vec![self.meta.batch as i64];
        dims.extend(self.meta.input_shape.iter().map(|&d| d as i64));
        let lit = xla::Literal::vec1(input).reshape(&dims)?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True -> 1-tuple.
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Output element count per batch.
    pub fn output_len(&self) -> usize {
        self.meta.batch * self.meta.output_shape.iter().product::<usize>()
    }
}

/// Loads and caches engines for every artifact in a directory.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    engines: HashMap<(String, usize), Engine>,
}

impl Runtime {
    /// Create a CPU-PJRT runtime over an artifacts directory.
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(&artifacts_dir.join("manifest.tsv"))
            .with_context(|| {
                format!(
                    "loading manifest from {} (run `make artifacts` first)",
                    artifacts_dir.display()
                )
            })?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            dir: artifacts_dir.to_path_buf(),
            manifest,
            engines: HashMap::new(),
        })
    }

    /// Compile (and cache) the engine for (model, batch).
    pub fn engine(&mut self, model: &str, batch: usize) -> Result<&Engine> {
        let key = (model.to_string(), batch);
        if !self.engines.contains_key(&key) {
            let meta = self
                .manifest
                .get(model, batch)
                .ok_or_else(|| anyhow!("no artifact {model}_b{batch}"))?
                .clone();
            let path = self.dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("bad path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.engines.insert(key.clone(), Engine { meta, exe });
        }
        Ok(&self.engines[&key])
    }

    /// Execute with automatic padding of a partial batch: `n` real samples
    /// in `input` (row-major); returns only the real samples' outputs.
    pub fn execute_padded(
        &mut self,
        model: &str,
        batch: usize,
        n: usize,
        input: &[f32],
    ) -> Result<Vec<f32>> {
        let engine = self.engine(model, batch)?;
        let per_in: usize = engine.meta.input_shape.iter().product();
        let per_out: usize = engine.meta.output_shape.iter().product();
        if n > batch || input.len() != n * per_in {
            return Err(anyhow!(
                "execute_padded: n={n} batch={batch} input={}",
                input.len()
            ));
        }
        let mut padded = input.to_vec();
        padded.resize(batch * per_in, 0.0);
        let out = engine.execute(&padded)?;
        Ok(out[..n * per_out].to_vec())
    }

    /// Wall-clock profile: run (model, batch) `reps` times, return the
    /// median batch latency in ms. Feeds `ProfileStore::load_tsv`.
    pub fn profile(&mut self, model: &str, batch: usize, reps: usize) -> Result<f64> {
        let engine = self.engine(model, batch)?;
        let per_in: usize = engine.meta.input_shape.iter().product();
        let input = vec![0.5f32; batch * per_in];
        // Warmup.
        engine.execute(&input)?;
        let mut times: Vec<f64> = (0..reps.max(1))
            .map(|_| {
                let t0 = std::time::Instant::now();
                engine.execute(&input).map(|_| t0.elapsed().as_secs_f64() * 1e3)
            })
            .collect::<Result<_>>()?;
        times.sort_by(|a, b| a.total_cmp(b));
        Ok(times[times.len() / 2])
    }

    pub fn models(&self) -> Vec<&str> {
        self.manifest.models()
    }
}
