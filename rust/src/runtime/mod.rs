//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`, produced
//! once by `make artifacts`) and executes them on the XLA CPU client. This
//! is the *only* inference path — Python never runs at request time.
//!
//! The XLA-backed implementation lives in [`pjrt`] behind the `pjrt`
//! cargo feature (the offline registry's `xla` closure is the crate's one
//! external dependency, and it is opt-in — see Cargo.toml). The default
//! build substitutes [`stub`], which exposes the identical API and fails
//! cleanly at `Runtime::new`, keeping the whole scheduler/simulator stack
//! buildable without PJRT.

mod manifest;

pub use manifest::{ArtifactMeta, Manifest};

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{Engine, Runtime};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{Engine, Runtime};

use std::path::PathBuf;

/// Default artifacts dir: `$OCTOPINF_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("OCTOPINF_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}
