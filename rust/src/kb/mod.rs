//! Knowledge Base: the in-memory time-series store standing in for the
//! paper's PostgreSQL KB (§III-A). Device Agents push container metrics;
//! the Controller queries windows for scheduling (rates, burstiness,
//! bandwidth, utilization).

use std::collections::HashMap;

use crate::util::stats::Summary;
use crate::Ms;

/// One metric sample.
#[derive(Clone, Copy, Debug)]
pub struct Sample {
    pub t_ms: Ms,
    pub value: f64,
}

/// A named, bounded time series.
#[derive(Clone, Debug, Default)]
pub struct Series {
    samples: std::collections::VecDeque<Sample>,
    cap: usize,
}

impl Series {
    fn new(cap: usize) -> Series {
        Series { samples: Default::default(), cap }
    }

    fn push(&mut self, s: Sample) {
        self.samples.push_back(s);
        while self.samples.len() > self.cap {
            self.samples.pop_front();
        }
    }

    /// Samples within the trailing window ending at `now_ms`.
    pub fn window(&self, now_ms: Ms, window_ms: Ms) -> impl Iterator<Item = &Sample> {
        let lo = now_ms - window_ms;
        self.samples.iter().filter(move |s| s.t_ms >= lo && s.t_ms <= now_ms)
    }

    pub fn latest(&self) -> Option<Sample> {
        self.samples.back().copied()
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }
}

/// Metric key: (entity, metric-name), e.g. ("traffic0/object_det", "rate").
pub type Key = (String, String);

/// The Knowledge Base.
#[derive(Clone, Debug, Default)]
pub struct KnowledgeBase {
    series: HashMap<Key, Series>,
    default_cap: usize,
}

impl KnowledgeBase {
    pub fn new() -> KnowledgeBase {
        KnowledgeBase { series: HashMap::new(), default_cap: 4096 }
    }

    pub fn push(&mut self, entity: &str, metric: &str, t_ms: Ms, value: f64) {
        let cap = self.default_cap;
        self.series
            .entry((entity.to_string(), metric.to_string()))
            .or_insert_with(|| Series::new(cap))
            .push(Sample { t_ms, value });
    }

    pub fn series(&self, entity: &str, metric: &str) -> Option<&Series> {
        self.series.get(&(entity.to_string(), metric.to_string()))
    }

    /// Mean of a metric over the trailing window.
    pub fn window_mean(
        &self,
        entity: &str,
        metric: &str,
        now_ms: Ms,
        window_ms: Ms,
    ) -> Option<f64> {
        let s = self.series(entity, metric)?;
        let mut sum = Summary::new();
        for smp in s.window(now_ms, window_ms) {
            sum.push(smp.value);
        }
        (sum.count() > 0).then(|| sum.mean())
    }

    /// CV of a metric over the trailing window (burstiness queries).
    pub fn window_cv(
        &self,
        entity: &str,
        metric: &str,
        now_ms: Ms,
        window_ms: Ms,
    ) -> Option<f64> {
        let s = self.series(entity, metric)?;
        let mut sum = Summary::new();
        for smp in s.window(now_ms, window_ms) {
            sum.push(smp.value);
        }
        (sum.count() > 1).then(|| sum.cv())
    }

    pub fn latest(&self, entity: &str, metric: &str) -> Option<f64> {
        self.series(entity, metric)?.latest().map(|s| s.value)
    }

    /// All entities carrying a given metric.
    pub fn entities_with(&self, metric: &str) -> Vec<&str> {
        let mut v: Vec<&str> = self
            .series
            .keys()
            .filter(|(_, m)| m == metric)
            .map(|(e, _)| e.as_str())
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_query() {
        let mut kb = KnowledgeBase::new();
        for i in 0..10 {
            kb.push("p0/det", "rate", i as f64 * 1000.0, i as f64);
        }
        assert_eq!(kb.latest("p0/det", "rate"), Some(9.0));
        let mean = kb.window_mean("p0/det", "rate", 9000.0, 4000.0).unwrap();
        assert!((mean - 7.0).abs() < 1e-9); // samples 5..=9 avg
    }

    #[test]
    fn window_excludes_old() {
        let mut kb = KnowledgeBase::new();
        kb.push("e", "m", 0.0, 100.0);
        kb.push("e", "m", 10_000.0, 1.0);
        let mean = kb.window_mean("e", "m", 10_000.0, 500.0).unwrap();
        assert_eq!(mean, 1.0);
    }

    #[test]
    fn missing_series_is_none() {
        let kb = KnowledgeBase::new();
        assert!(kb.window_mean("x", "y", 0.0, 1.0).is_none());
        assert!(kb.latest("x", "y").is_none());
    }

    #[test]
    fn capacity_bounded() {
        let mut kb = KnowledgeBase::new();
        for i in 0..10_000 {
            kb.push("e", "m", i as f64, 0.0);
        }
        assert!(kb.series("e", "m").unwrap().len() <= 4096);
    }

    #[test]
    fn entities_listing() {
        let mut kb = KnowledgeBase::new();
        kb.push("b", "rate", 0.0, 1.0);
        kb.push("a", "rate", 0.0, 1.0);
        kb.push("a", "util", 0.0, 1.0);
        assert_eq!(kb.entities_with("rate"), vec!["a", "b"]);
    }
}
