//! EVA pipelines: DAGs of DNN model stages with SLOs (paper §II, Fig. 2).

mod dag;
mod presets;
mod spec;

pub use dag::{ModelNode, PipelineDag};
pub use presets::{surveillance_pipeline, traffic_pipeline, standard_pipelines};
pub use spec::{ModelKind, ModelSpec};
