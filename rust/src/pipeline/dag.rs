//! Pipeline DAG: models + edges + SLO, with traversal helpers used by the
//! schedulers (topological order, downstream rate propagation).

use super::spec::ModelSpec;
use crate::Ms;

/// One node in the pipeline DAG.
#[derive(Clone, Debug)]
pub struct ModelNode {
    pub spec: ModelSpec,
    /// Indices of downstream models fed by this node's output.
    pub downstream: Vec<usize>,
    /// Fraction of this node's output routed to each downstream (sums <= 1;
    /// e.g. a detector routes car boxes to the car classifier and person
    /// boxes to the face embedder).
    pub routing: Vec<f64>,
}

/// A DAG of DNN stages with an end-to-end SLO (paper §II).
#[derive(Clone, Debug)]
pub struct PipelineDag {
    pub name: String,
    pub slo_ms: Ms,
    pub models: Vec<ModelNode>,
    /// Device id hosting this pipeline's data source (camera).
    pub source_device: usize,
    /// Frames per second entering model 0.
    pub source_fps: f64,
}

impl PipelineDag {
    pub fn new(name: &str, slo_ms: Ms, source_device: usize, fps: f64) -> Self {
        PipelineDag {
            name: name.to_string(),
            slo_ms,
            models: Vec::new(),
            source_device,
            source_fps: fps,
        }
    }

    /// Append a model; returns its index.
    pub fn add(&mut self, spec: ModelSpec) -> usize {
        self.models.push(ModelNode { spec, downstream: Vec::new(), routing: Vec::new() });
        self.models.len() - 1
    }

    /// Connect `from` -> `to`, routing `frac` of from's output.
    pub fn connect(&mut self, from: usize, to: usize, frac: f64) {
        assert!(from < self.models.len() && to < self.models.len());
        assert!(from != to, "self-loop");
        assert!(to > from, "edges must go forward (indices are topo order)");
        self.models[from].downstream.push(to);
        self.models[from].routing.push(frac);
    }

    pub fn len(&self) -> usize {
        self.models.len()
    }

    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }

    /// Upstream of each node (None for the entry model).
    pub fn upstream(&self, idx: usize) -> Option<usize> {
        self.models
            .iter()
            .enumerate()
            .find(|(_, n)| n.downstream.contains(&idx))
            .map(|(i, _)| i)
    }

    /// Per-model request rates (queries/s) given the source fps, propagating
    /// detector fanout and routing fractions downstream.
    pub fn request_rates(&self, fanout_scale: f64) -> Vec<f64> {
        let mut rates = vec![0.0; self.models.len()];
        if self.models.is_empty() {
            return rates;
        }
        rates[0] = self.source_fps;
        for i in 0..self.models.len() {
            let out_rate =
                rates[i] * self.models[i].spec.fanout_mean * fanout_scale.max(0.0);
            for (d, &ds) in self.models[i].downstream.iter().enumerate() {
                rates[ds] += out_rate * self.models[i].routing[d];
            }
        }
        rates
    }

    /// Indices in topological order (construction enforces forward edges, so
    /// this is just 0..n — kept as a named helper for clarity at call sites).
    pub fn topo_order(&self) -> Vec<usize> {
        (0..self.models.len()).collect()
    }

    /// The longest path (in hops) — sanity metric used in tests.
    pub fn depth(&self) -> usize {
        let mut depth = vec![1usize; self.models.len()];
        for i in (0..self.models.len()).rev() {
            for &d in &self.models[i].downstream {
                depth[i] = depth[i].max(1 + depth[d]);
            }
        }
        depth.first().copied().unwrap_or(0)
    }

    /// Validate structural invariants; returns an error description.
    pub fn validate(&self) -> Result<(), String> {
        if self.models.is_empty() {
            return Err("pipeline has no models".into());
        }
        if self.slo_ms <= 0.0 {
            return Err("SLO must be positive".into());
        }
        for (i, n) in self.models.iter().enumerate() {
            if n.downstream.len() != n.routing.len() {
                return Err(format!("model {i}: routing/downstream mismatch"));
            }
            let total: f64 = n.routing.iter().sum();
            if total > 1.0 + 1e-9 {
                return Err(format!("model {i}: routing sums to {total} > 1"));
            }
            for &d in &n.downstream {
                if d <= i || d >= self.models.len() {
                    return Err(format!("model {i}: bad edge -> {d}"));
                }
            }
        }
        // Reachability: every non-entry model must have an upstream.
        for i in 1..self.models.len() {
            if self.upstream(i).is_none() {
                return Err(format!("model {i} is unreachable"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::spec::ModelSpec;

    fn toy() -> PipelineDag {
        let mut p = PipelineDag::new("toy", 200.0, 0, 15.0);
        let det = p.add(ModelSpec::detector("det", 1, 128));
        let cls = p.add(ModelSpec::classifier("cls"));
        let emb = p.add(ModelSpec::embedder("emb"));
        p.connect(det, cls, 0.6);
        p.connect(det, emb, 0.4);
        p
    }

    #[test]
    fn validates() {
        assert!(toy().validate().is_ok());
    }

    #[test]
    fn rates_propagate_fanout() {
        let p = toy();
        let r = p.request_rates(1.0);
        assert!((r[0] - 15.0).abs() < 1e-9);
        // detector fanout 6.0 -> 90 obj/s split 60/40
        assert!((r[1] - 54.0).abs() < 1e-9);
        assert!((r[2] - 36.0).abs() < 1e-9);
    }

    #[test]
    fn rates_scale_with_content() {
        let p = toy();
        let lo = p.request_rates(0.5);
        let hi = p.request_rates(2.0);
        assert!((hi[1] / lo[1] - 4.0).abs() < 1e-9);
    }

    #[test]
    fn upstream_lookup() {
        let p = toy();
        assert_eq!(p.upstream(0), None);
        assert_eq!(p.upstream(1), Some(0));
        assert_eq!(p.upstream(2), Some(0));
    }

    #[test]
    fn rejects_overcommitted_routing() {
        let mut p = PipelineDag::new("bad", 100.0, 0, 15.0);
        let a = p.add(ModelSpec::detector("d", 0, 96));
        let b = p.add(ModelSpec::classifier("c"));
        let c = p.add(ModelSpec::classifier("c2"));
        p.connect(a, b, 0.9);
        p.connect(a, c, 0.9);
        assert!(p.validate().is_err());
    }

    #[test]
    #[should_panic(expected = "forward")]
    fn rejects_backward_edge() {
        let mut p = PipelineDag::new("bad", 100.0, 0, 15.0);
        let a = p.add(ModelSpec::detector("d", 0, 96));
        let b = p.add(ModelSpec::classifier("c"));
        let _ = (a, b);
        p.connect(1, 0, 1.0);
    }

    #[test]
    fn depth_of_chain() {
        let mut p = PipelineDag::new("chain", 300.0, 0, 15.0);
        let a = p.add(ModelSpec::detector("d", 0, 96));
        let b = p.add(ModelSpec::classifier("c"));
        let c = p.add(ModelSpec::embedder("e"));
        p.connect(a, b, 1.0);
        p.connect(b, c, 1.0);
        assert_eq!(p.depth(), 3);
    }
}
