//! Static per-model descriptions: IO sizes, memory, compute — the inputs
//! the paper's profiler supplies to the Controller (§III-A, Table II).

use crate::Bytes;

/// Functional role of a stage; maps to the AOT artifact families.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ModelKind {
    /// Frame-level object detector (TinyDet variants).
    Detector,
    /// Crop classifier (car type, gender/age...).
    Classifier,
    /// Crop embedder (plate recog, face recog, re-id...).
    Embedder,
}

impl ModelKind {
    /// Name of the AOT artifact family implementing this stage on the real
    /// serving path (`artifacts/<family>_b<batch>.hlo.txt`).
    pub fn artifact_family(&self, variant: usize) -> &'static str {
        match self {
            ModelKind::Detector => ["det_s", "det_m", "det_l"][variant.min(2)],
            ModelKind::Classifier => "classifier",
            ModelKind::Embedder => "embedder",
        }
    }
}

/// Static profile of one pipeline stage.
///
/// `W_m` / `I_m` (Eq. 4) are the weight and per-query intermediate memory;
/// `util_width` is the fraction of a GPU's compute the stage occupies while
/// executing (the "width" of its CORAL portion); `fanout_mean` is the mean
/// number of downstream queries produced per input query (objects per
/// frame for a detector, 1 for crop models).
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    pub kind: ModelKind,
    /// Detector resolution variant (0 = S, 1 = M, 2 = L); ignored otherwise.
    pub variant: usize,
    /// Bytes entering the stage per query (frame or crop).
    pub input_bytes: Bytes,
    /// Bytes leaving the stage per produced query.
    pub output_bytes: Bytes,
    /// Mean downstream queries per input query.
    pub fanout_mean: f64,
    /// Persistent weight memory, MB (W_m).
    pub weight_mem_mb: f64,
    /// Intermediate memory per query in a running batch, MB (I_m).
    pub inter_mem_mb: f64,
    /// Fraction of GPU compute consumed while executing (portion width).
    pub util_width: f64,
    /// FLOPs per sample (for roofline accounting).
    pub flops_per_sample: f64,
}

impl ModelSpec {
    /// IO ratio used by CWD's `ToEdge` test (Insight 2): expected output
    /// traffic per input query, relative to input size.
    pub fn io_ratio(&self) -> f64 {
        if self.input_bytes <= 0.0 {
            return f64::INFINITY;
        }
        self.fanout_mean * self.output_bytes / self.input_bytes
    }

    /// Total memory for an instance serving batch `bz` (Eq. 4 contribution).
    pub fn memory_mb(&self, bz: u32) -> f64 {
        self.weight_mem_mb + self.inter_mem_mb * bz as f64
    }
}

/// Convenience constructors matched to the paper's two pipelines.
impl ModelSpec {
    pub fn detector(name: &str, variant: usize, resolution: u32) -> ModelSpec {
        let _ = resolution; // kept for API clarity; bytes use stream size
        ModelSpec {
            name: name.to_string(),
            kind: ModelKind::Detector,
            variant,
            // What crosses the network is the encoded camera stream frame
            // (720p-class), resized per detector variant — this is what
            // makes LTE uplinks a real bottleneck, as in the paper.
            input_bytes: 80_000.0 + 30_000.0 * variant as f64,
            // Per detected object: crop + box metadata.
            output_bytes: 32.0 * 32.0 * 3.0 + 64.0,
            fanout_mean: 6.0, // calibrated at runtime from KB
            weight_mem_mb: 120.0 + 40.0 * variant as f64,
            inter_mem_mb: 18.0 + 8.0 * variant as f64,
            util_width: 0.35 + 0.10 * variant as f64,
            flops_per_sample: 15.4e6 * (1.0 + variant as f64),
        }
    }

    pub fn classifier(name: &str) -> ModelSpec {
        ModelSpec {
            name: name.to_string(),
            kind: ModelKind::Classifier,
            variant: 0,
            input_bytes: 32.0 * 32.0 * 3.0 + 64.0,
            output_bytes: 96.0, // label + confidence record
            fanout_mean: 1.0,
            weight_mem_mb: 45.0,
            inter_mem_mb: 6.0,
            util_width: 0.15,
            flops_per_sample: 2.5e6,
        }
    }

    pub fn embedder(name: &str) -> ModelSpec {
        ModelSpec {
            name: name.to_string(),
            kind: ModelKind::Embedder,
            variant: 0,
            input_bytes: 32.0 * 32.0 * 3.0 + 64.0,
            output_bytes: 64.0 * 4.0 + 32.0, // f32 embedding + id
            fanout_mean: 1.0,
            weight_mem_mb: 50.0,
            inter_mem_mb: 6.0,
            util_width: 0.15,
            flops_per_sample: 2.7e6,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn detector_io_ratio_grows_with_fanout() {
        let mut d = ModelSpec::detector("det", 1, 128);
        let r1 = d.io_ratio();
        d.fanout_mean *= 2.0;
        assert!((d.io_ratio() - 2.0 * r1).abs() < 1e-9);
    }

    #[test]
    fn classifier_shrinks_data() {
        let c = ModelSpec::classifier("cls");
        assert!(c.io_ratio() < 1.0, "classifier must compress its input");
    }

    #[test]
    fn memory_scales_with_batch() {
        let d = ModelSpec::detector("det", 0, 96);
        assert!(d.memory_mb(8) > d.memory_mb(1));
        assert!((d.memory_mb(0) - d.weight_mem_mb).abs() < 1e-9);
    }

    #[test]
    fn artifact_family_mapping() {
        assert_eq!(ModelKind::Detector.artifact_family(0), "det_s");
        assert_eq!(ModelKind::Detector.artifact_family(2), "det_l");
        assert_eq!(ModelKind::Classifier.artifact_family(0), "classifier");
    }
}
