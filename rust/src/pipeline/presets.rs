//! The paper's two experiment pipelines (Fig. 2, §IV-A2):
//!
//! - **traffic** (SLO 200 ms): `ObjectDet -> {CarClassify, PlateDet(emb)}`
//! - **surveillance** (SLO 300 ms): `ObjectDet -> {FaceEmb, GenderCls}`

use super::dag::PipelineDag;
use super::spec::ModelSpec;

/// Traffic-monitoring pipeline: detector feeds a car-type classifier and a
/// plate embedder (standing in for Plate Det -> Plate Recog).
pub fn traffic_pipeline(source_device: usize, fps: f64) -> PipelineDag {
    let mut p = PipelineDag::new("traffic", 200.0, source_device, fps);
    let det = p.add(ModelSpec::detector("object_det", 1, 128));
    let cls = p.add(ModelSpec::classifier("car_classify"));
    let plate = p.add(ModelSpec::embedder("plate_recog"));
    // ~65 % of detected objects are vehicles -> classifier; 35 % get plate
    // lookup (front-facing vehicles).
    p.connect(det, cls, 0.65);
    p.connect(det, plate, 0.35);
    p
}

/// Building-surveillance pipeline: detector feeds face embedding and
/// gender/age classification.
pub fn surveillance_pipeline(source_device: usize, fps: f64) -> PipelineDag {
    let mut p = PipelineDag::new("surveillance", 300.0, source_device, fps);
    let det = p.add(ModelSpec::detector("object_det", 1, 128));
    let face = p.add(ModelSpec::embedder("face_recog"));
    let gender = p.add(ModelSpec::classifier("gender_classify"));
    p.connect(det, face, 0.5);
    p.connect(det, gender, 0.5);
    // Surveillance scenes have fewer, larger targets than traffic.
    p.models[det].spec.fanout_mean = 3.5;
    p
}

/// The paper's standard 9-source deployment: 6 traffic + 3 surveillance
/// cameras, one per edge device (§IV-A3), 15 fps each.
pub fn standard_pipelines(n_devices: usize) -> Vec<PipelineDag> {
    let fps = 15.0;
    (0..n_devices)
        .map(|d| {
            if d % 3 == 2 {
                surveillance_pipeline(d, fps)
            } else {
                traffic_pipeline(d, fps)
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        assert!(traffic_pipeline(0, 15.0).validate().is_ok());
        assert!(surveillance_pipeline(0, 15.0).validate().is_ok());
    }

    #[test]
    fn paper_slos() {
        assert_eq!(traffic_pipeline(0, 15.0).slo_ms, 200.0);
        assert_eq!(surveillance_pipeline(0, 15.0).slo_ms, 300.0);
    }

    #[test]
    fn standard_mix_is_two_thirds_traffic() {
        let ps = standard_pipelines(9);
        let traffic = ps.iter().filter(|p| p.name == "traffic").count();
        assert_eq!(traffic, 6);
        assert_eq!(ps.len(), 9);
        for (d, p) in ps.iter().enumerate() {
            assert_eq!(p.source_device, d);
        }
    }
}
