//! Time-source layer: a hierarchical calendar queue ("timing wheel") for
//! the simulator's event stream, plus the outage-skip table `FifoLink`
//! uses to jump bandwidth blackouts in O(1).
//!
//! The wheel replaces the engine's former global `BinaryHeap<TimedEvent>`
//! (flagged the hottest remaining structure since PR 1). The contract is
//! exact: events pop in ascending `(t, tie, seq)` order — `total_cmp` on
//! time, then the same-time permutation key, then the insertion sequence —
//! bit-for-bit identical to the heap, including the seeded `:order=K`
//! same-time shuffle. The win is structural: the near future lives in
//! fixed-width buckets (push is O(1) bucket append for the common case —
//! frames, flushes, exec completions all land within the window), and only
//! the currently-draining bucket pays a heap's `log n`. Far-future events
//! (control-plane clocks, fault schedules) overflow into a small heap and
//! migrate forward as the window advances.
//!
//! Determinism notes:
//! - Bucketing never reorders anything: buckets partition events by
//!   `floor(t / WIDTH)`, strictly coarser than the `(t, tie, seq)` order,
//!   and the active bucket is itself a heap on the full key.
//! - `iter` walks every queued event in unspecified order — it exists for
//!   the engine's in-flight census, which only counts.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::Ms;

/// splitmix64 finalizer: a bijection on u64, so distinct `seq` values can
/// never collide on `tie` (the `seq` tiebreak below is then unreachable,
/// but kept as a total-order backstop).
#[inline]
pub fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// One scheduled event: timestamp, same-time ordering key, insertion
/// sequence, payload. With `order_seed == 0` the engine sets `tie = seq`
/// (insertion order, the historical behavior); otherwise `tie` is a seeded
/// bijective permutation of `seq`, so events sharing a timestamp pop in a
/// shuffled — but fully reproducible — order. Scheduler-independent
/// quantities must not depend on it.
pub struct WheelEntry<E> {
    pub t: Ms,
    pub tie: u64,
    pub seq: u64,
    pub ev: E,
}

impl<E> PartialEq for WheelEntry<E> {
    fn eq(&self, o: &Self) -> bool {
        self.cmp(o) == Ordering::Equal
    }
}
impl<E> Eq for WheelEntry<E> {}
impl<E> PartialOrd for WheelEntry<E> {
    fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}
impl<E> Ord for WheelEntry<E> {
    fn cmp(&self, o: &Self) -> Ordering {
        // Reversed for a min-heap on (t, tie, seq). total_cmp gives NaN
        // timestamps a fixed (last) position instead of silently
        // comparing Equal and corrupting event order.
        o.t.total_cmp(&self.t)
            .then(o.tie.cmp(&self.tie))
            .then(o.seq.cmp(&self.seq))
    }
}

/// Ring slots in the near-future window.
const NB: usize = 256;
/// Bucket width in ms. NB × WIDTH = 4.096 s of window: frames (tens of
/// ms apart), flush timers (≤ SLO/2) and exec completions (ms-scale) all
/// land inside it; only the 5–60 s control clocks and fault schedules
/// overflow.
const WIDTH: Ms = 16.0;

/// Calendar queue over [`WheelEntry`]s with the exact pop order of a
/// `BinaryHeap` on `(t, tie, seq)`.
pub struct EventWheel<E> {
    /// Absolute index of the bucket currently being drained.
    cur_idx: u64,
    /// Events of the active bucket (plus any pushed at or before it),
    /// ordered on the full key.
    current: BinaryHeap<WheelEntry<E>>,
    /// Near-future ring: slot `i % NB` holds the events of absolute bucket
    /// `i` for `cur_idx < i < cur_idx + NB` (unsorted — sorted lazily when
    /// the bucket becomes active).
    ring: Vec<Vec<WheelEntry<E>>>,
    ring_count: usize,
    /// Far future (bucket ≥ cur_idx + NB at push time); migrates into the
    /// active bucket as the window advances past it.
    overflow: BinaryHeap<WheelEntry<E>>,
    len: usize,
}

#[inline]
fn bucket_of(t: Ms) -> u64 {
    // Saturating f64→u64 cast: negatives clamp to bucket 0, +inf / NaN to
    // u64::MAX (parked in overflow until everything finite drains).
    (t / WIDTH) as u64
}

impl<E> EventWheel<E> {
    pub fn new() -> EventWheel<E> {
        EventWheel {
            cur_idx: 0,
            current: BinaryHeap::new(),
            ring: (0..NB).map(|_| Vec::new()).collect(),
            ring_count: 0,
            overflow: BinaryHeap::new(),
            len: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Schedule an event. `tie` and `seq` come from the caller (the engine
    /// owns the sequence counter and the `:order=K` permutation).
    pub fn push(&mut self, t: Ms, tie: u64, seq: u64, ev: E) {
        let idx = bucket_of(t);
        let entry = WheelEntry { t, tie, seq, ev };
        self.len += 1;
        if idx <= self.cur_idx {
            // At (or, defensively, before) the active bucket: join the
            // ordered drain directly — always safe, the heap re-sorts.
            self.current.push(entry);
        } else if idx - self.cur_idx < NB as u64 {
            self.ring[(idx % NB as u64) as usize].push(entry);
            self.ring_count += 1;
        } else {
            self.overflow.push(entry);
        }
    }

    /// Advance `cur_idx` to the earliest non-empty bucket and pull its
    /// events into `current`. Caller guarantees `current` is empty and at
    /// least one event is queued in the ring or overflow.
    fn advance(&mut self) {
        let mut next: Option<u64> = None;
        if self.ring_count > 0 {
            // Ring entries all live in (cur_idx, cur_idx + NB): the first
            // non-empty slot in that scan order is the earliest bucket.
            for j in 1..NB as u64 {
                let idx = self.cur_idx + j;
                if !self.ring[(idx % NB as u64) as usize].is_empty() {
                    next = Some(idx);
                    break;
                }
            }
        }
        if let Some(top) = self.overflow.peek() {
            let o = bucket_of(top.t);
            next = Some(match next {
                Some(r) => r.min(o),
                None => o,
            });
        }
        let Some(next_idx) = next else { return };
        self.cur_idx = next_idx;
        // The slot for `next_idx` holds only events of that absolute bucket
        // (the window is exactly NB wide), so draining it is exact.
        let slot = (next_idx % NB as u64) as usize;
        for e in self.ring[slot].drain(..) {
            self.ring_count -= 1;
            self.current.push(e);
        }
        // Overflow events whose bucket has arrived migrate in with it.
        while let Some(top) = self.overflow.peek() {
            if bucket_of(top.t) != next_idx {
                break;
            }
            self.current.push(self.overflow.pop().unwrap());
        }
    }

    /// Earliest queued event, if any. `&mut` because reaching it may
    /// rotate the window forward (no event is consumed).
    pub fn peek(&mut self) -> Option<&WheelEntry<E>> {
        while self.current.is_empty() {
            if self.len == 0 {
                return None;
            }
            self.advance();
        }
        self.current.peek()
    }

    /// Pop the earliest event in `(t, tie, seq)` order.
    pub fn pop(&mut self) -> Option<WheelEntry<E>> {
        self.peek()?;
        self.len -= 1;
        self.current.pop()
    }

    /// Walk every queued event (unspecified order) — the engine's
    /// in-flight conservation census only counts, it never orders.
    pub fn iter(&self) -> impl Iterator<Item = &WheelEntry<E>> {
        self.current
            .iter()
            .chain(self.ring.iter().flatten())
            .chain(self.overflow.iter())
    }
}

impl<E> Default for EventWheel<E> {
    fn default() -> Self {
        EventWheel::new()
    }
}

/// Outage-skip table for a looping 1-second bandwidth trace: the same
/// calendar idea (one slot per second) applied to `FifoLink`'s blackout
/// deferral, replacing the second-by-second rescan on every send.
#[derive(Clone, Debug)]
pub struct OutageSkip {
    /// `next_up[i]` = smallest k ≥ 0 with `samples[(i + k) % len] > 0`,
    /// or `u32::MAX` when the trace is permanently dark.
    next_up: Vec<u32>,
}

impl OutageSkip {
    pub fn build(samples: &[f64]) -> OutageSkip {
        let n = samples.len();
        let mut next_up = vec![u32::MAX; n];
        // One reverse pass over the doubled index space handles the wrap
        // (the trace loops: `idx % len`).
        let mut dist = u32::MAX;
        for i in (0..2 * n).rev() {
            let idx = i % n;
            if samples[idx] > 0.0 {
                dist = 0;
            } else if dist != u32::MAX {
                dist += 1;
            }
            if i < n {
                next_up[idx] = dist;
            }
        }
        OutageSkip { next_up }
    }

    /// Whole seconds from sample slot `idx` to the next slot with
    /// bandwidth (0 when the slot itself is bright); `None` when the trace
    /// has no bright second at all.
    pub fn to_next_bright(&self, idx: usize) -> Option<u32> {
        let d = self.next_up[idx % self.next_up.len()];
        (d != u32::MAX).then_some(d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(w: &mut EventWheel<u32>) -> Vec<(f64, u64, u64)> {
        let mut out = Vec::new();
        while let Some(e) = w.pop() {
            out.push((e.t, e.tie, e.seq));
        }
        out
    }

    #[test]
    fn pops_in_time_then_tie_order() {
        let mut w = EventWheel::new();
        w.push(50.0, 3, 3, 0);
        w.push(10.0, 1, 1, 0);
        w.push(50.0, 2, 2, 0);
        w.push(10.0, 4, 4, 0);
        let order = drain(&mut w);
        assert_eq!(
            order,
            vec![(10.0, 1, 1), (10.0, 4, 4), (50.0, 2, 2), (50.0, 3, 3)]
        );
    }

    #[test]
    fn far_future_overflow_migrates_forward() {
        let mut w = EventWheel::new();
        w.push(600_000.0, 2, 2, 0); // far beyond the ring window
        w.push(5.0, 1, 1, 0);
        w.push(1_200_000.0, 3, 3, 0);
        assert_eq!(w.len(), 3);
        assert_eq!(w.pop().unwrap().t, 5.0);
        assert_eq!(w.pop().unwrap().t, 600_000.0);
        // Push into the (now advanced) near window between pops.
        w.push(600_100.0, 4, 4, 0);
        assert_eq!(w.pop().unwrap().t, 600_100.0);
        assert_eq!(w.pop().unwrap().t, 1_200_000.0);
        assert!(w.pop().is_none());
        assert!(w.is_empty());
    }

    #[test]
    fn interleaved_push_pop_keeps_order() {
        let mut w = EventWheel::new();
        w.push(100.0, 1, 1, 0);
        w.push(40_000.0, 2, 2, 0);
        assert_eq!(w.pop().unwrap().t, 100.0);
        // Now at bucket of t=100; push later events, including same-bucket.
        w.push(105.0, 3, 3, 0);
        w.push(20_000.0, 4, 4, 0);
        assert_eq!(w.pop().unwrap().t, 105.0);
        assert_eq!(w.pop().unwrap().t, 20_000.0);
        assert_eq!(w.pop().unwrap().t, 40_000.0);
    }

    #[test]
    fn infinite_timestamps_park_in_overflow() {
        let mut w = EventWheel::new();
        w.push(f64::INFINITY, 2, 2, 0);
        w.push(1.0, 1, 1, 0);
        assert_eq!(w.iter().count(), 2);
        assert_eq!(w.pop().unwrap().t, 1.0);
        assert_eq!(w.peek().unwrap().t, f64::INFINITY);
        assert_eq!(w.len(), 1);
    }

    #[test]
    fn iter_sees_every_region() {
        let mut w = EventWheel::new();
        w.push(1.0, 1, 1, 0); // current-ish bucket
        w.push(1000.0, 2, 2, 0); // ring
        w.push(900_000.0, 3, 3, 0); // overflow
        assert_eq!(w.iter().count(), 3);
        let _ = w.pop();
        assert_eq!(w.iter().count(), 2);
    }

    #[test]
    fn outage_skip_matches_linear_scan() {
        let samples = [0.0, 0.0, 3.0, 0.0, 1.0, 0.0];
        let skip = OutageSkip::build(&samples);
        for i in 0..samples.len() {
            let expect = (0..samples.len() as u32)
                .find(|&k| samples[(i + k as usize) % samples.len()] > 0.0);
            assert_eq!(skip.to_next_bright(i), expect, "slot {i}");
        }
        // Wrap: slot 5 is dark, next bright is slot 2 of the next loop.
        assert_eq!(skip.to_next_bright(5), Some(3));
    }

    #[test]
    fn all_dark_trace_has_no_bright_second() {
        let skip = OutageSkip::build(&[0.0, 0.0, 0.0]);
        for i in 0..3 {
            assert_eq!(skip.to_next_bright(i), None);
        }
    }

    #[test]
    fn mix64_is_deterministic_and_spreads() {
        assert_eq!(mix64(1), mix64(1));
        assert_ne!(mix64(1), mix64(2));
        assert_eq!(mix64(0), 0); // the finalizer's one fixed point
    }
}
