//! Runtime invariant checking for the simulator — the validation substrate
//! behind the differential conformance suite (`rust/tests/conformance.rs`).
//!
//! The checker is carried by the engine as an `Option<Box<InvariantChecker>>`
//! and every hook site is a single `if let Some(..)` on that flag, so the
//! disabled (default) configuration costs one never-taken branch per hook —
//! no counters, no allocation. Enabled, every hook is O(1); only the final
//! conservation census walks the remaining event heap once.
//!
//! Invariants asserted (violations are *collected*, not panicked, so a
//! fuzzing run can report the seed-based repro string of every failure):
//!
//! 1. **Conservation** — every admitted query terminates exactly once:
//!    completed at a sink, consumed by the router at a non-sink stage
//!    (fanning out into child queries, themselves created-counted),
//!    dropped, or destroyed by an injected fault (`lost_to_fault`:
//!    in-flight batches on a crashed device, queues lost under
//!    `CrashPolicy::Drop`, frames from a dead source) — or it is still in
//!    flight (queued / executing / in transit) when the horizon cuts the
//!    run. A fault may destroy work, but never unaccountably.
//! 2. **Monotone clock** — processed event timestamps are finite and
//!    non-decreasing. (Causality of link transfers is subsumed: an arrival
//!    pushed into the past would pop out of order.)
//! 3. **Batch bound** — no dispatched batch exceeds the stage's configured
//!    batch size; every dispatched batch is non-empty.
//! 4. **Queue bound** — no batcher queue ever exceeds its admission cap.
//! 5. **Plan shape** — each (pipeline, model) is assigned exactly once;
//!    every instance has a binding on its assigned device with a valid GPU
//!    index and a width in (0, 1]; batches come from the compiled
//!    `BATCH_SIZES`; reserved (temporal) slots have positive duty cycles
//!    that contain their portions.
//! 6. **GPU memory** — per GPU, reserved weights plus per-stream peak
//!    intermediates fit in device memory; per (GPU, stream), the peak
//!    reserved width respects the utilization cap (CORAL Eq. 4/5 budgets;
//!    spatial-only baselines carry no reservations so the check is
//!    vacuous for them by design).
//! 7. **SLO bookkeeping** — sink outcomes agree with `latency <= slo`,
//!    latencies are finite and non-negative, and the engine-side counts
//!    reconcile exactly with [`RunMetrics`] (completions, drops, and the
//!    latency-sketch population).
//! 8. **Latency attribution** — each completed query's
//!    transfer/queue/exec decomposition folds back to its end-to-end
//!    latency **bit-for-bit** (`obs::attrib::fold`), components are
//!    non-negative, and at the end of the run the attribution sketches
//!    hold exactly one sample per completed unit with the dominant-cause
//!    miss buckets summing to the `late` counter.

use crate::cluster::Cluster;
use crate::coordinator::{GpuId, Plan};
use crate::metrics::RunMetrics;
use crate::pipeline::PipelineDag;
use crate::profiles::BATCH_SIZES;
use crate::Ms;

/// Violations recorded per run are capped so a systematically broken
/// scheduler cannot balloon a fuzzing report.
const MAX_VIOLATIONS: usize = 16;

/// Streaming invariant checker the engine drives through its event loop.
#[derive(Clone, Debug, Default)]
pub struct InvariantChecker {
    last_event_ms: Ms,
    events: u64,
    frames: u64,
    objects_total: u64,
    filtered_queries: u64,
    filtered_units: u64,
    created: u64,
    dropped: u64,
    lost_to_fault: u64,
    routed: u64,
    vanished: u64,
    completed_queries: u64,
    completed_objects: u64,
    in_flight: u64,
    plans: u64,
    migrations: u64,
    attrib_units: u64,
    suppressed: u64,
    violations: Vec<String>,
}

impl InvariantChecker {
    pub fn new() -> InvariantChecker {
        InvariantChecker::default()
    }

    fn violation(&mut self, msg: String) {
        if self.violations.len() < MAX_VIOLATIONS {
            self.violations.push(msg);
        } else {
            self.suppressed += 1;
        }
    }

    /// A timestamped event is about to be processed.
    #[inline]
    pub fn on_event(&mut self, t: Ms) {
        self.events += 1;
        // `!(t >= last)` also catches NaN timestamps.
        if !t.is_finite() || !(t >= self.last_event_ms) {
            self.violation(format!(
                "clock not monotone: event at t={t} after t={}",
                self.last_event_ms
            ));
        } else {
            self.last_event_ms = t;
        }
    }

    /// A source frame entered the system as one query carrying `objects`.
    #[inline]
    pub fn on_frame(&mut self, objects: u32) {
        self.frames += 1;
        self.objects_total += objects as u64;
        self.created += 1;
    }

    /// A source frame was answered by the content-aware frontend without
    /// entering the pipeline: it counts toward the scheduler-independent
    /// workload fingerprint (frames, objects) but is never `created`, so
    /// query conservation is untouched. `units` is what the frontend
    /// credited to `RunMetrics::filtered` for this frame.
    #[inline]
    pub fn on_filtered_frame(&mut self, objects: u32, units: u64) {
        self.frames += 1;
        self.objects_total += objects as u64;
        self.filtered_queries += 1;
        self.filtered_units += units;
    }

    /// A downstream child query was spawned by the router.
    #[inline]
    pub fn on_spawn(&mut self) {
        self.created += 1;
    }

    /// One query finished execution at a non-sink stage and was consumed
    /// by the router (its terminal event; children are spawn-counted).
    #[inline]
    pub fn on_routed(&mut self) {
        self.routed += 1;
    }

    /// An object fell into the unrouted residue (routing fractions < 1) —
    /// it never became a query, so it is outside query conservation.
    #[inline]
    pub fn on_vanish(&mut self) {
        self.vanished += 1;
    }

    /// `n` queries were dropped (queue overflow, lazy deadline drop, or a
    /// permanently dark link).
    #[inline]
    pub fn on_drop(&mut self, n: u64) {
        self.dropped += n;
    }

    /// `n` queries were destroyed by an injected fault (crashed device's
    /// in-flight batch, a queue lost under `CrashPolicy::Drop`, a frame
    /// captured while its source device was down).
    #[inline]
    pub fn on_lost(&mut self, n: u64) {
        self.lost_to_fault += n;
    }

    /// A batch of `len` queries was dispatched at configured max `max`.
    #[inline]
    pub fn on_batch(&mut self, len: usize, max: u32) {
        if len == 0 {
            self.violation("empty batch dispatched".to_string());
        }
        if len > max as usize {
            self.violation(format!("batch {len} exceeds configured max {max}"));
        }
    }

    /// A batcher queue holds `len` entries under admission cap `cap`.
    #[inline]
    pub fn on_queue_depth(&mut self, len: usize, cap: usize) {
        if len > cap {
            self.violation(format!("queue depth {len} exceeds cap {cap}"));
        }
    }

    /// One query reached its sink carrying `objects` completions.
    #[inline]
    pub fn on_sink(&mut self, latency: Ms, objects: u64, on_time: bool, slo: Ms) {
        self.completed_queries += 1;
        self.completed_objects += objects;
        if !latency.is_finite() || latency < 0.0 {
            self.violation(format!("completion with bad latency {latency}"));
        } else if on_time != (latency <= slo) {
            self.violation(format!(
                "SLO bookkeeping: latency {latency} vs slo {slo} marked on_time={on_time}"
            ));
        }
    }

    /// One completed query's latency decomposition, `n` units (objects).
    /// The canonical fold of the measured components must reproduce the
    /// end-to-end latency bit-for-bit — `obs::close_exact` retires the
    /// last-ulp rounding residue, so any surviving mismatch means a
    /// lifecycle segment was skipped or double-counted.
    #[inline]
    pub fn on_attrib(&mut self, transfer: Ms, queue: Ms, exec: Ms, latency: Ms, n: u64) {
        self.attrib_units += n;
        if crate::obs::attrib::fold(transfer, queue, exec).to_bits() != latency.to_bits() {
            self.violation(format!(
                "attribution fold ({transfer} + {queue}) + {exec} != \
                 latency {latency} bit-for-bit"
            ));
        }
        if !(transfer >= 0.0 && queue >= 0.0 && exec >= 0.0) {
            self.violation(format!(
                "negative attribution component: transfer {transfer} \
                 queue {queue} exec {exec}"
            ));
        }
    }

    /// The GPU-run tracker's incremental Σwidth against an exact
    /// recompute over its heap. The O(1) aggregate feeds the interference
    /// multiplier on every contended dispatch, so silent float drift here
    /// would skew every contended latency in the run.
    #[inline]
    pub fn on_width_sum(&mut self, incremental: f64, exact: f64) {
        if (incremental - exact).abs() > 1e-6 * exact.abs().max(1.0) {
            self.violation(format!(
                "gpu width sum drifted: incremental {incremental} vs \
                 recomputed {exact}"
            ));
        }
    }

    /// An epoch barrier closed at `epoch_end`: every event this partition
    /// processed must lie at or before it — a partition that ran ahead of
    /// the driver's clock could observe (or miss) cross-partition traffic
    /// non-deterministically.
    #[inline]
    pub fn on_barrier(&mut self, epoch_end: Ms) {
        if self.last_event_ms > epoch_end {
            self.violation(format!(
                "partition ran past the epoch barrier: last event at {} > {}",
                self.last_event_ms, epoch_end
            ));
        }
    }

    /// A plan swap migrated the live deployment: the engine's in-flight
    /// census (queued + executing + in transit) taken immediately before
    /// and after the install must balance. Today's install path preserves
    /// queues and the event heap by construction, so this is a regression
    /// tripwire — any future migration step that flushes, drops, or
    /// re-admits queued work trips it at the exact swap instead of as an
    /// unattributable end-of-run conservation failure. (Double-dispatch
    /// protection is structural: redeploys carry busy flags so in-flight
    /// batches keep their instance slots.)
    #[inline]
    pub fn on_plan_swap(&mut self, in_flight_before: u64, in_flight_after: u64) {
        self.migrations += 1;
        if in_flight_before != in_flight_after {
            self.violation(format!(
                "plan migration broke conservation: {in_flight_before} queries \
                 in flight before the swap, {in_flight_after} after"
            ));
        }
    }

    /// A plan was installed; check its structural and budget invariants.
    pub fn on_plan(&mut self, plan: &Plan, cluster: &Cluster, pipelines: &[PipelineDag]) {
        self.plans += 1;
        // Coverage: exactly one assignment per (pipeline, model).
        let mut seen: Vec<Vec<u32>> =
            pipelines.iter().map(|p| vec![0u32; p.len()]).collect();
        // Per-GPU reserved weight memory; per-(GPU, stream) peak reserved
        // intermediate memory and width — CORAL's Eq. 4/5 budget recompute.
        use std::collections::HashMap;
        let mut weight: HashMap<GpuId, f64> = HashMap::new();
        let mut inter: HashMap<(GpuId, usize), f64> = HashMap::new();
        let mut width: HashMap<(GpuId, usize), f64> = HashMap::new();

        for a in &plan.assignments {
            if a.pipeline >= pipelines.len() || a.model >= pipelines[a.pipeline].len() {
                self.violation(format!(
                    "assignment for unknown stage {}/{}",
                    a.pipeline, a.model
                ));
                continue;
            }
            seen[a.pipeline][a.model] += 1;
            if a.cfg.device >= cluster.devices.len() {
                self.violation(format!(
                    "stage {}/{} assigned to unknown device {}",
                    a.pipeline, a.model, a.cfg.device
                ));
                continue;
            }
            if !BATCH_SIZES.contains(&a.cfg.batch) {
                self.violation(format!(
                    "stage {}/{} batch {} outside compiled sizes",
                    a.pipeline, a.model, a.cfg.batch
                ));
            }
            if a.cfg.instances == 0 || a.bindings.len() != a.cfg.instances as usize {
                self.violation(format!(
                    "stage {}/{}: {} bindings for {} instances",
                    a.pipeline,
                    a.model,
                    a.bindings.len(),
                    a.cfg.instances
                ));
            }
            let spec = &pipelines[a.pipeline].models[a.model].spec;
            for b in &a.bindings {
                if b.gpu.device != a.cfg.device
                    || b.gpu.gpu >= cluster.device(a.cfg.device).gpus.len()
                {
                    self.violation(format!(
                        "stage {}/{} binding on {:?} but device {}",
                        a.pipeline, a.model, b.gpu, a.cfg.device
                    ));
                    continue;
                }
                if !(b.width > 0.0 && b.width <= 1.0 + 1e-9) {
                    self.violation(format!(
                        "stage {}/{} binding width {} outside (0, 1]",
                        a.pipeline, a.model, b.width
                    ));
                }
                if let Some(t) = b.temporal {
                    if !(t.duty_cycle_ms > 0.0)
                        || t.duration_ms < 0.0
                        || t.start_ms < -1e-9
                        || t.start_ms + t.duration_ms > t.duty_cycle_ms + 1e-6
                    {
                        self.violation(format!(
                            "stage {}/{} slot [{}, {}+{}] escapes duty cycle {}",
                            a.pipeline,
                            a.model,
                            t.start_ms,
                            t.start_ms,
                            t.duration_ms,
                            t.duty_cycle_ms
                        ));
                    }
                    *weight.entry(b.gpu).or_default() += spec.weight_mem_mb;
                    let e = inter.entry((b.gpu, t.stream)).or_default();
                    *e = e.max(spec.inter_mem_mb * a.cfg.batch as f64);
                    let w = width.entry((b.gpu, t.stream)).or_default();
                    *w = w.max(b.width);
                }
            }
        }
        for (p, row) in seen.iter().enumerate() {
            for (m, &n) in row.iter().enumerate() {
                if n != 1 {
                    self.violation(format!("stage {p}/{m} assigned {n} times"));
                }
            }
        }
        for d in &cluster.devices {
            for (gi, g) in d.gpus.iter().enumerate() {
                let id = GpuId { device: d.id, gpu: gi };
                let wsum = weight.get(&id).copied().unwrap_or(0.0);
                let isum: f64 = inter
                    .iter()
                    .filter(|((g2, _), _)| *g2 == id)
                    .map(|(_, v)| v)
                    .sum();
                if wsum + isum > g.mem_mb + 1e-6 {
                    self.violation(format!(
                        "{id:?} reserved memory {wsum:.1}+{isum:.1} exceeds {} MB",
                        g.mem_mb
                    ));
                }
                let usum: f64 = width
                    .iter()
                    .filter(|((g2, _), _)| *g2 == id)
                    .map(|(_, v)| v)
                    .sum();
                if usum > g.util_cap + 1e-6 {
                    self.violation(format!(
                        "{id:?} reserved width {usum:.3} exceeds cap {}",
                        g.util_cap
                    ));
                }
            }
        }
    }

    /// End of run: reconcile conservation and the metrics bookkeeping.
    /// `in_flight` is the engine's census of queries still queued, in a
    /// running batch, or in transit when the horizon was reached.
    pub fn finish(&mut self, in_flight: u64, metrics: &RunMetrics) {
        self.in_flight = in_flight;
        let accounted = self.completed_queries
            + self.routed
            + self.dropped
            + self.lost_to_fault
            + in_flight;
        if accounted != self.created {
            self.violation(format!(
                "conservation: created {} != completed {} + routed {} + \
                 dropped {} + lost-to-fault {} + in-flight {}",
                self.created,
                self.completed_queries,
                self.routed,
                self.dropped,
                self.lost_to_fault,
                in_flight
            ));
        }
        if metrics.dropped != self.dropped {
            self.violation(format!(
                "metrics dropped {} != engine dropped {}",
                metrics.dropped, self.dropped
            ));
        }
        if metrics.lost_to_fault != self.lost_to_fault {
            self.violation(format!(
                "metrics lost-to-fault {} != engine lost-to-fault {}",
                metrics.lost_to_fault, self.lost_to_fault
            ));
        }
        if metrics.completed() != self.completed_objects {
            self.violation(format!(
                "metrics completions {} != engine sink objects {}",
                metrics.completed(),
                self.completed_objects
            ));
        }
        if metrics.latency.count() != metrics.completed() {
            self.violation(format!(
                "latency sketch holds {} samples for {} completions",
                metrics.latency.count(),
                metrics.completed()
            ));
        }
        if metrics.filtered != self.filtered_units {
            self.violation(format!(
                "metrics filtered {} != engine frontend units {}",
                metrics.filtered, self.filtered_units
            ));
        }
        // Attribution reconciliation — only once the engine actually
        // attributed completions (the hook is engine-driven; a bare
        // checker unit test never arms it).
        if self.attrib_units > 0 {
            if self.attrib_units != self.completed_objects {
                self.violation(format!(
                    "attribution covered {} units for {} completed objects",
                    self.attrib_units, self.completed_objects
                ));
            }
            let a = &metrics.attrib;
            for (name, count) in [
                ("transfer", a.transfer.count()),
                ("queue", a.queue.count()),
                ("exec", a.exec.count()),
            ] {
                if count != self.attrib_units {
                    self.violation(format!(
                        "attribution {name} sketch holds {count} samples \
                         for {} attributed units",
                        self.attrib_units
                    ));
                }
            }
            if a.misses() != metrics.late {
                self.violation(format!(
                    "dominant-cause miss buckets sum to {} for {} late units",
                    a.misses(),
                    metrics.late
                ));
            }
        }
    }

    /// Whether any violation has been recorded so far — the engine's
    /// flight-recorder trigger (dump the ring the moment a run turns
    /// from clean to violating).
    pub fn has_violations(&self) -> bool {
        !self.violations.is_empty() || self.suppressed > 0
    }

    /// Consume the checker into its report.
    pub fn into_report(self) -> InvariantReport {
        InvariantReport {
            events: self.events,
            frames: self.frames,
            objects_total: self.objects_total,
            filtered_queries: self.filtered_queries,
            filtered_units: self.filtered_units,
            created: self.created,
            dropped: self.dropped,
            lost_to_fault: self.lost_to_fault,
            routed: self.routed,
            vanished: self.vanished,
            completed_queries: self.completed_queries,
            completed_objects: self.completed_objects,
            in_flight: self.in_flight,
            plans: self.plans,
            migrations: self.migrations,
            suppressed: self.suppressed,
            violations: self.violations,
        }
    }
}

/// Outcome of one invariant-checked run.
#[derive(Clone, Debug)]
pub struct InvariantReport {
    pub events: u64,
    /// Source frames emitted — scheduler-independent for a fixed scenario.
    pub frames: u64,
    /// Total objects the content processes produced — also
    /// scheduler-independent (per-pipeline RNG streams are isolated).
    pub objects_total: u64,
    /// Frames the content-aware frontend answered without admission.
    pub filtered_queries: u64,
    /// Work units the frontend credited for those frames (>= queries).
    pub filtered_units: u64,
    pub created: u64,
    pub dropped: u64,
    /// Queries destroyed by injected faults — conservation's fault term.
    pub lost_to_fault: u64,
    /// Queries consumed by the router at non-sink stages.
    pub routed: u64,
    /// Objects lost to the unrouted residue (routing fractions < 1).
    pub vanished: u64,
    pub completed_queries: u64,
    pub completed_objects: u64,
    pub in_flight: u64,
    pub plans: u64,
    /// Plan swaps that migrated a live deployment (drift replans and
    /// mid-run periodic rounds; the initial install is not a migration).
    pub migrations: u64,
    /// Violations beyond the reporting cap.
    pub suppressed: u64,
    pub violations: Vec<String>,
}

impl InvariantReport {
    pub fn ok(&self) -> bool {
        self.violations.is_empty() && self.suppressed == 0
    }

    /// Fold another partition's report into this one (the driver merges
    /// reports in partition order). Counters add; violations concatenate
    /// under the same reporting cap, overflow counted as suppressed.
    pub fn merge(&mut self, other: InvariantReport) {
        self.events += other.events;
        self.frames += other.frames;
        self.objects_total += other.objects_total;
        self.filtered_queries += other.filtered_queries;
        self.filtered_units += other.filtered_units;
        self.created += other.created;
        self.dropped += other.dropped;
        self.lost_to_fault += other.lost_to_fault;
        self.routed += other.routed;
        self.vanished += other.vanished;
        self.completed_queries += other.completed_queries;
        self.completed_objects += other.completed_objects;
        self.in_flight += other.in_flight;
        self.plans += other.plans;
        self.migrations += other.migrations;
        self.suppressed += other.suppressed;
        for v in other.violations {
            if self.violations.len() < MAX_VIOLATIONS {
                self.violations.push(v);
            } else {
                self.suppressed += 1;
            }
        }
    }

    /// Scheduler-independent fingerprint for differential cross-checks:
    /// exact (frames, objects) counts. Trace integrals are fingerprinted
    /// scenario-side (see `experiments::fuzz`).
    pub fn workload_fingerprint(&self) -> (u64, u64) {
        (self.frames, self.objects_total)
    }

    /// One-line human summary for fuzz tables.
    pub fn summary(&self) -> String {
        format!(
            "events={} frames={} objects={} filtered={} created={} done={} \
             routed={} dropped={} lost={} unrouted={} in-flight={} \
             violations={}",
            self.events,
            self.frames,
            self.objects_total,
            self.filtered_queries,
            self.created,
            self.completed_queries,
            self.routed,
            self.dropped,
            self.lost_to_fault,
            self.vanished,
            self.in_flight,
            self.violations.len() as u64 + self.suppressed,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_run_reports_ok() {
        let mut c = InvariantChecker::new();
        c.on_event(0.0);
        c.on_event(5.0);
        // One frame with 3 objects: the frame query is routed into two
        // children (one object unrouted); both children complete at sinks.
        c.on_frame(3);
        c.on_routed();
        c.on_spawn();
        c.on_spawn();
        c.on_vanish();
        c.on_batch(2, 4);
        c.on_queue_depth(2, 1024);
        c.on_sink(50.0, 1, true, 200.0);
        c.on_sink(250.0, 1, false, 200.0);
        c.on_drop(0);
        let mut m = RunMetrics::new(1000.0);
        m.record(crate::metrics::Outcome::OnTime, 50.0);
        m.record(crate::metrics::Outcome::Late, 250.0);
        // created 3 = completed 2 + routed 1 + dropped 0 + in-flight 0.
        c.finish(0, &m);
        let r = c.into_report();
        assert!(r.ok(), "{:?}", r.violations);
        assert_eq!(r.workload_fingerprint(), (1, 3));
    }

    #[test]
    fn filtered_frames_fingerprint_without_creating_queries() {
        let mut c = InvariantChecker::new();
        c.on_frame(2);
        c.on_sink(10.0, 2, true, 200.0);
        // Two frames the frontend answered (3 and 1 objects; min 1 unit each).
        c.on_filtered_frame(3, 3);
        c.on_filtered_frame(0, 1);
        let mut m = RunMetrics::new(1000.0);
        m.record(crate::metrics::Outcome::OnTime, 10.0);
        m.record_filtered(4);
        c.finish(0, &m);
        let r = c.into_report();
        assert!(r.ok(), "{:?}", r.violations);
        assert_eq!(r.workload_fingerprint(), (3, 5), "filtered frames count");
        assert_eq!(r.created, 1, "filtered frames never become queries");
        assert_eq!((r.filtered_queries, r.filtered_units), (2, 4));
    }

    #[test]
    fn filtered_metrics_mismatch_is_flagged() {
        let mut c = InvariantChecker::new();
        c.on_filtered_frame(2, 2);
        let m = RunMetrics::new(1000.0); // filtered left at 0
        c.finish(0, &m);
        let r = c.into_report();
        assert!(!r.ok());
        assert!(
            r.violations.iter().any(|v| v.contains("filtered")),
            "{:?}",
            r.violations
        );
    }

    #[test]
    fn conservation_leak_is_flagged() {
        let mut c = InvariantChecker::new();
        c.on_frame(1);
        c.on_spawn(); // 2 created, nothing terminal
        let m = RunMetrics::new(1000.0);
        c.finish(1, &m); // one in flight: one query leaked
        let r = c.into_report();
        assert!(!r.ok());
        assert!(r.violations[0].contains("conservation"), "{}", r.violations[0]);
    }

    #[test]
    fn fault_losses_balance_conservation() {
        let mut c = InvariantChecker::new();
        c.on_frame(1);
        c.on_frame(1);
        c.on_sink(10.0, 1, true, 200.0);
        c.on_lost(1); // the other query died with its device
        let mut m = RunMetrics::new(1000.0);
        m.record(crate::metrics::Outcome::OnTime, 10.0);
        m.lost_to_fault = 1;
        c.finish(0, &m);
        let r = c.into_report();
        assert!(r.ok(), "{:?}", r.violations);
        assert_eq!(r.lost_to_fault, 1);
    }

    #[test]
    fn unaccounted_fault_loss_is_flagged() {
        // Engine lost a query to a fault but the metrics side never heard:
        // the reconciliation must trip even though conservation balances.
        let mut c = InvariantChecker::new();
        c.on_frame(1);
        c.on_lost(1);
        let m = RunMetrics::new(1000.0); // lost_to_fault left at 0
        c.finish(0, &m);
        let r = c.into_report();
        assert!(!r.ok());
        assert!(
            r.violations.iter().any(|v| v.contains("lost-to-fault")),
            "{:?}",
            r.violations
        );
    }

    #[test]
    fn clock_regression_is_flagged() {
        let mut c = InvariantChecker::new();
        c.on_event(10.0);
        c.on_event(9.0);
        c.on_event(f64::NAN);
        let r = c.into_report();
        assert_eq!(r.violations.len(), 2);
    }

    #[test]
    fn oversized_batch_and_queue_flagged() {
        let mut c = InvariantChecker::new();
        c.on_batch(9, 8);
        c.on_batch(0, 8);
        c.on_queue_depth(2000, 1024);
        assert_eq!(c.clone().into_report().violations.len(), 3);
    }

    #[test]
    fn slo_bookkeeping_mismatch_flagged() {
        let mut c = InvariantChecker::new();
        c.on_sink(300.0, 1, true, 200.0); // marked on-time but late
        c.on_sink(f64::INFINITY, 1, false, 200.0);
        assert_eq!(c.into_report().violations.len(), 2);
    }

    #[test]
    fn balanced_plan_swap_is_clean_but_counted() {
        let mut c = InvariantChecker::new();
        c.on_plan_swap(17, 17);
        c.on_plan_swap(0, 0);
        let r = c.into_report();
        assert!(r.ok(), "{:?}", r.violations);
        assert_eq!(r.migrations, 2);
    }

    #[test]
    fn lossy_plan_swap_is_flagged() {
        let mut c = InvariantChecker::new();
        c.on_plan_swap(17, 12); // 5 queries vanished in the migration
        c.on_plan_swap(3, 4); // one double-counted
        let r = c.into_report();
        assert_eq!(r.violations.len(), 2);
        assert!(r.violations[0].contains("migration"), "{}", r.violations[0]);
    }

    #[test]
    fn attribution_fold_must_be_bit_exact() {
        let mut c = InvariantChecker::new();
        // A close_exact-retired decomposition folds clean.
        let (tr, qu, raw_ex) = (3.0_f64, 7.5_f64, 19.25_f64);
        let lat = (tr + qu) + raw_ex;
        c.on_attrib(tr, qu, crate::obs::close_exact(lat, tr, qu, raw_ex), lat, 1);
        assert!(!c.has_violations());
        // A lost segment (15 ms unaccounted) trips the fold check.
        c.on_attrib(10.0, 0.0, 30.0, 55.0, 1);
        assert!(c.has_violations());
        // A negative component trips even when the fold balances.
        let mut c2 = InvariantChecker::new();
        c2.on_attrib(-1.0, 2.0, 54.0, 55.0, 1);
        assert!(c2.has_violations());
    }

    #[test]
    fn attribution_reconciliation_flags_missing_metrics() {
        let mut c = InvariantChecker::new();
        c.on_frame(1);
        c.on_sink(10.0, 1, true, 200.0);
        c.on_attrib(1.0, 2.0, 7.0, 10.0, 1);
        let mut m = RunMetrics::new(1000.0);
        m.record(crate::metrics::Outcome::OnTime, 10.0);
        // Engine attributed the completion but RunMetrics never heard.
        c.finish(0, &m);
        let r = c.into_report();
        assert!(!r.ok());
        assert!(
            r.violations.iter().any(|v| v.contains("attribution")),
            "{:?}",
            r.violations
        );
    }

    #[test]
    fn attribution_reconciles_cleanly_end_to_end() {
        let mut c = InvariantChecker::new();
        c.on_frame(1);
        c.on_frame(1);
        c.on_sink(10.0, 1, true, 200.0);
        c.on_sink(250.0, 1, false, 200.0);
        c.on_attrib(1.0, 2.0, 7.0, (1.0 + 2.0) + 7.0, 1);
        c.on_attrib(50.0, 150.0, 50.0, (50.0 + 150.0) + 50.0, 1);
        let mut m = RunMetrics::new(1000.0);
        m.record(crate::metrics::Outcome::OnTime, 10.0);
        m.record(crate::metrics::Outcome::Late, 250.0);
        m.record_attrib(1.0, 2.0, 7.0, 1, false);
        m.record_attrib(50.0, 150.0, 50.0, 1, true);
        c.finish(0, &m);
        let r = c.into_report();
        assert!(r.ok(), "{:?}", r.violations);
    }

    #[test]
    fn violation_flood_is_capped_but_counted() {
        let mut c = InvariantChecker::new();
        for _ in 0..100 {
            c.on_batch(0, 8);
        }
        let r = c.into_report();
        assert_eq!(r.violations.len(), MAX_VIOLATIONS);
        assert_eq!(r.suppressed, 100 - MAX_VIOLATIONS as u64);
        assert!(!r.ok());
    }
}
