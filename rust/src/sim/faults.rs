//! Deterministic fault injection: seeded system-level failures woven into
//! the simulator's event heap.
//!
//! The fuzz families perturb the *workload* (flash crowds, blackouts,
//! churn); this module perturbs the *system*. A [`FaultPlan`] — sampled
//! from the same repro seed that drives everything else — schedules typed
//! [`FaultEv`]s:
//!
//! - **device crash / recover**: in-flight batches on the dead device are
//!   lost (accounted as `lost_to_fault`, never silently vanished); queued
//!   queries are dropped or survive for re-routing per [`CrashPolicy`].
//! - **GPU straggler**: a per-GPU latency multiplier window that composes
//!   multiplicatively with the interference model.
//! - **controller outage**: replan / drift-check bodies are skipped while
//!   the window is open — the data plane runs open-loop on the stale plan.
//! - **telemetry freeze**: the drift detector and CWD see rate/bandwidth
//!   snapshots frozen at fault start, so they must plan against lies.
//!
//! Everything is derived from `seed ^ FAULT_PLAN_TAG`, so a repro string
//! carrying `:faults=M` replays the exact same storm byte-for-byte.
//!
//! Under multi-cluster partitioning (`cfg.clusters > 1`) each
//! [`SimPartition`](crate::sim) samples its *own* plan from its partition
//! seed (`sim::partition_seed`): replica clusters see statistically
//! similar but uncorrelated storms, and the `:faults=M` axis stays a pure
//! function of `(seed, clusters)`. An explicitly injected plan
//! (`Simulator::set_fault_plan`) targets partition 0 only — the cluster
//! targeted storms are written against.

use crate::cluster::Cluster;
use crate::util::Rng;
use crate::Ms;

/// Stream tag for fault-plan sampling (disjoint from the engine, fuzz
/// sampler, and trace stream tags).
pub const FAULT_PLAN_TAG: u64 = 0xFA_117_5EED;

/// What happens to a crashed device's queued queries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CrashPolicy {
    /// The queue dies with the device; every queued query is accounted as
    /// `lost_to_fault` at crash time.
    Drop,
    /// The logical stage queue survives: recovery replanning can migrate
    /// the group (queue and all) to a survivor, or the queue resumes in
    /// place when the device comes back.
    Reroute,
}

impl CrashPolicy {
    pub fn label(self) -> &'static str {
        match self {
            CrashPolicy::Drop => "drop",
            CrashPolicy::Reroute => "reroute",
        }
    }

    pub fn parse(s: &str) -> Option<CrashPolicy> {
        match s.trim().to_ascii_lowercase().as_str() {
            "drop" => Some(CrashPolicy::Drop),
            "reroute" => Some(CrashPolicy::Reroute),
            _ => None,
        }
    }
}

impl Default for CrashPolicy {
    fn default() -> Self {
        CrashPolicy::Reroute
    }
}

/// A typed fault event. Faults come in start/end pairs sharing one
/// sampled window; an end event whose start never fired (or vice versa)
/// is a no-op, so windows may extend past the horizon safely.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultEv {
    DeviceCrash { device: usize },
    DeviceRecover { device: usize },
    StragglerStart { device: usize, gpu: usize, factor: f64 },
    StragglerEnd { device: usize, gpu: usize, factor: f64 },
    ControllerOutageStart,
    ControllerOutageEnd,
    TelemetryFreezeStart,
    TelemetryFreezeEnd,
}

impl FaultEv {
    pub fn label(&self) -> &'static str {
        match self {
            FaultEv::DeviceCrash { .. } => "device_crash",
            FaultEv::DeviceRecover { .. } => "device_recover",
            FaultEv::StragglerStart { .. } => "straggler_start",
            FaultEv::StragglerEnd { .. } => "straggler_end",
            FaultEv::ControllerOutageStart => "controller_outage_start",
            FaultEv::ControllerOutageEnd => "controller_outage_end",
            FaultEv::TelemetryFreezeStart => "telemetry_freeze_start",
            FaultEv::TelemetryFreezeEnd => "telemetry_freeze_end",
        }
    }
}

/// A deterministic schedule of fault events, sorted by time.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    pub events: Vec<(Ms, FaultEv)>,
}

impl FaultPlan {
    /// Sample `n` fault windows over `[0, horizon_ms)`.
    ///
    /// Crashes target only the first `hot_devices` edge devices (the ones
    /// hosting cameras, hence the only non-server devices placement ever
    /// uses); the server never crashes — a headless cluster has no
    /// survivors to degrade onto. Stragglers may hit any GPU, including
    /// the server's.
    pub fn sample(
        seed: u64,
        n: u32,
        horizon_ms: Ms,
        cluster: &Cluster,
        hot_devices: usize,
    ) -> FaultPlan {
        let mut rng = Rng::new(seed ^ FAULT_PLAN_TAG);
        let hot = hot_devices.min(cluster.devices.len().saturating_sub(1)).max(1);
        let mut events = Vec::with_capacity(2 * n as usize);
        for _ in 0..n {
            let start = rng.range(0.05, 0.70) * horizon_ms;
            let end = start + rng.range(0.05, 0.35) * horizon_ms;
            match rng.below(4) {
                0 => {
                    let device = 1 + rng.below(hot);
                    events.push((start, FaultEv::DeviceCrash { device }));
                    events.push((end, FaultEv::DeviceRecover { device }));
                }
                1 => {
                    let device = rng.below(cluster.devices.len());
                    let gpu = rng.below(cluster.device(device).gpus.len().max(1));
                    let factor = rng.range(1.5, 4.0);
                    events.push((start, FaultEv::StragglerStart { device, gpu, factor }));
                    events.push((end, FaultEv::StragglerEnd { device, gpu, factor }));
                }
                2 => {
                    events.push((start, FaultEv::ControllerOutageStart));
                    events.push((end, FaultEv::ControllerOutageEnd));
                }
                _ => {
                    events.push((start, FaultEv::TelemetryFreezeStart));
                    events.push((end, FaultEv::TelemetryFreezeEnd));
                }
            }
        }
        events.sort_by(|a, b| a.0.total_cmp(&b.0));
        FaultPlan { events }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampling_is_deterministic() {
        let c = Cluster::paper_testbed();
        let a = FaultPlan::sample(77, 6, 30_000.0, &c, 4);
        let b = FaultPlan::sample(77, 6, 30_000.0, &c, 4);
        assert_eq!(a.events.len(), b.events.len());
        for (x, y) in a.events.iter().zip(&b.events) {
            assert_eq!(x.0.to_bits(), y.0.to_bits());
            assert_eq!(x.1, y.1);
        }
        let d = FaultPlan::sample(78, 6, 30_000.0, &c, 4);
        assert!(a.events.iter().zip(&d.events).any(|(x, y)| x != y));
    }

    #[test]
    fn windows_are_paired_sorted_and_in_range() {
        let c = Cluster::paper_testbed();
        let plan = FaultPlan::sample(1234, 16, 60_000.0, &c, 9);
        assert_eq!(plan.len(), 32);
        let mut starts = 0usize;
        let mut ends = 0usize;
        for w in plan.events.windows(2) {
            assert!(w[0].0 <= w[1].0, "events not sorted");
        }
        for (t, ev) in &plan.events {
            assert!(*t >= 0.0);
            match ev {
                FaultEv::DeviceCrash { device } => {
                    assert!((1..=9).contains(device), "crash hit device {device}");
                    starts += 1;
                }
                FaultEv::StragglerStart { factor, .. } => {
                    assert!((1.5..=4.0).contains(factor));
                    starts += 1;
                }
                FaultEv::ControllerOutageStart | FaultEv::TelemetryFreezeStart => starts += 1,
                _ => ends += 1,
            }
        }
        assert_eq!(starts, 16);
        assert_eq!(ends, 16);
    }

    #[test]
    fn crash_policy_parse_roundtrip() {
        for p in [CrashPolicy::Drop, CrashPolicy::Reroute] {
            assert_eq!(CrashPolicy::parse(p.label()), Some(p));
        }
        assert_eq!(CrashPolicy::parse("explode"), None);
        assert_eq!(CrashPolicy::default(), CrashPolicy::Reroute);
    }
}
