//! Discrete-event simulator of the edge fleet: frame sources, per-model
//! dynamic batchers, GPU executors with a co-location interference model,
//! FIFO network links driven by bandwidth traces, periodic rescheduling,
//! and the autoscaler — the substrate every figure of §IV runs on.
//!
//! The simulator consumes the same [`Plan`](crate::coordinator::Plan)s the
//! real serving stack does, so schedulers are compared end-to-end under
//! identical mechanics.
//!
//! # Engine layering
//!
//! The engine is split into three layers:
//!
//! 1. **Time source** ([`wheel`]): a calendar-queue [`wheel::EventWheel`]
//!    holding each partition's pending events in exact `(t, tie, seq)`
//!    order — `f64::total_cmp` on time, then the seeded `:order=K`
//!    same-time permutation key, then insertion sequence. Bit-for-bit
//!    the order the old global `BinaryHeap` produced.
//! 2. **Component** ([`engine::SimPartition`], via the [`Component`]
//!    trait): one self-contained edge cluster — devices, links, batchers,
//!    GPU executors, scheduler, autoscaler, fault plan — advancing only
//!    inside `tick(until)`. A partition never reads another partition's
//!    state.
//! 3. **Orchestration** ([`Simulator`], in `driver`): owns time. It steps
//!    every partition to the same epoch boundary (10 s), fans the ticks
//!    across `std::thread::scope` workers, and merges results **in
//!    partition order** at each barrier.
//!
//! # Determinism contract
//!
//! Simulation output — `RunMetrics`, workload fingerprints, fuzz/chaos
//! digests, invariant reports — is a pure function of the scenario config
//! (seed, `:order=K`, `:faults=M`, `clusters`, …). `--sim-jobs` is a
//! wall-clock knob only: partitions share nothing while ticking, and
//! cross-partition traffic moves only at epoch barriers, in partition
//! order, so any worker count produces byte-identical results. A
//! one-cluster run is additionally byte-identical to the pre-partition
//! single-loop engine: partition 0 uses the scenario seed untouched, the
//! epoch slicing pops the same events in the same order as one pass to
//! the horizon, and merging one partition's metrics is the identity. The
//! invariant engine stays armed across barriers (`on_barrier` asserts no
//! partition ran past the driver's clock; conservation censuses span the
//! wheel, including events beyond the current epoch).
//!
//! # Observability contract
//!
//! The tracing subsystem ([`crate::obs`]) rides the same hook pattern as
//! the invariant engine: an `Option`-flagged sink the engine writes into
//! at lifecycle boundaries. **Trace hooks may never influence
//! scheduling** — they draw no RNG, push no simulator events, allocate
//! no qids conditionally (ids are a bare counter, ticking identically
//! with tracing on or off), and return nothing the engine branches on.
//! Consequences, all asserted by tests:
//!
//! * metrics/digests with tracing **off** are byte-identical to the
//!   pre-tracing engine, and tracing **on** never changes them;
//! * the exported trace is a pure function of the scenario config —
//!   byte-identical at any `--sim-jobs` (per-partition logs merge in
//!   partition order, timestamps are sim-clock);
//! * SLO-miss attribution (transfer/queue/exec per query) is always on —
//!   plain `f64` accumulation on the query struct — and each completed
//!   query's components sum to its end-to-end latency **bit-for-bit**
//!   ([`crate::obs::close_exact`]; invariant #8 enforces it);
//! * the flight recorder (ring of recent trace events, armed with the
//!   invariant engine) dumps with a repro string on violation without
//!   touching any digested output.

mod driver;
mod engine;
pub mod faults;
pub mod invariants;
mod link;
pub mod scenario;
pub mod wheel;

pub use driver::{partition_seed, Simulator};
pub use engine::InterferenceModel;
pub use faults::{CrashPolicy, FaultEv, FaultPlan};
pub use invariants::{InvariantChecker, InvariantReport};
pub use link::FifoLink;
pub use scenario::{
    preset, scenario_env_bw, FuzzClass, FuzzSpec, Scenario, ScenarioGen,
};

use crate::metrics::RunMetrics;
use crate::coordinator::SchedulerKind;
use crate::obs::TraceEvent;
use crate::Ms;

/// Narrow advancement surface of the component layer: the driver steps
/// anything implementing this — today the per-cluster partitions — and
/// never reaches into component state between barriers.
pub(crate) trait Component {
    /// Earliest pending event time, if any (drained components return
    /// `None`). `&mut` because reaching the head may rotate the wheel's
    /// window forward; no event is consumed.
    fn next_tick(&mut self) -> Option<Ms>;
    /// Process every pending event with `t <= until`.
    fn tick(&mut self, until: Ms);
}

/// A typed cross-partition message, exchanged only at epoch barriers in
/// partition order. Uninhabited until the federation layer (ROADMAP
/// item 1) defines pipeline migrations / global-balancer traffic — the
/// exchange points and their ordering are already fixed and asserted, so
/// adding variants cannot perturb single-cluster determinism.
pub(crate) enum CrossMsg {}

/// Run one scheduler over a scenario and return its metrics
/// (single-threaded partition fan-out; see [`run_with`]).
pub fn run(scenario: &Scenario, kind: SchedulerKind) -> RunMetrics {
    run_with(scenario, kind, 1)
}

/// Run one scheduler with `sim_jobs` worker threads over the scenario's
/// cluster partitions (0 = one per hardware thread). Byte-identical to
/// `sim_jobs = 1` at any value.
pub fn run_with(
    scenario: &Scenario,
    kind: SchedulerKind,
    sim_jobs: usize,
) -> RunMetrics {
    let mut sim = Simulator::new(scenario, kind);
    sim.set_sim_jobs(sim_jobs);
    sim.run()
}

/// Run one scheduler with the invariant engine armed; returns the metrics
/// together with the invariant report (conformance/fuzz harness entry).
pub fn run_checked(
    scenario: &Scenario,
    kind: SchedulerKind,
) -> (RunMetrics, InvariantReport) {
    run_checked_with(scenario, kind, 1)
}

/// [`run_checked`] with `sim_jobs` partition workers; reports from every
/// partition are merged in partition order.
pub fn run_checked_with(
    scenario: &Scenario,
    kind: SchedulerKind,
    sim_jobs: usize,
) -> (RunMetrics, InvariantReport) {
    let mut sim = Simulator::new(scenario, kind);
    sim.set_sim_jobs(sim_jobs);
    sim.enable_invariants();
    let metrics = sim.run();
    let report = sim
        .take_invariant_report()
        .expect("invariants were enabled before run");
    (metrics, report)
}

/// Run one scheduler with the full tracer armed; returns the metrics and
/// the per-partition trace logs in partition order (`--trace` entry).
/// Tracing never perturbs the run: the metrics are byte-identical to
/// [`run_with`], and the trace itself is byte-identical at any
/// `sim_jobs` (see the observability contract above).
pub fn run_traced_with(
    scenario: &Scenario,
    kind: SchedulerKind,
    sim_jobs: usize,
) -> (RunMetrics, Vec<Vec<TraceEvent>>) {
    let mut sim = Simulator::new(scenario, kind);
    sim.set_sim_jobs(sim_jobs);
    sim.enable_tracing();
    let metrics = sim.run();
    let trace = sim.take_trace();
    (metrics, trace)
}
