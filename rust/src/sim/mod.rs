//! Discrete-event simulator of the edge cluster: frame sources, per-model
//! dynamic batchers, GPU executors with a co-location interference model,
//! FIFO network links driven by bandwidth traces, periodic rescheduling,
//! and the autoscaler — the substrate every figure of §IV runs on.
//!
//! The simulator consumes the same [`Plan`](crate::coordinator::Plan)s the
//! real serving stack does, so schedulers are compared end-to-end under
//! identical mechanics.

mod engine;
pub mod faults;
pub mod invariants;
mod link;
pub mod scenario;

pub use engine::{InterferenceModel, Simulator};
pub use faults::{CrashPolicy, FaultEv, FaultPlan};
pub use invariants::{InvariantChecker, InvariantReport};
pub use link::FifoLink;
pub use scenario::{
    preset, scenario_env_bw, FuzzClass, FuzzSpec, Scenario, ScenarioGen,
};

use crate::metrics::RunMetrics;
use crate::coordinator::SchedulerKind;

/// Run one scheduler over a scenario and return its metrics.
pub fn run(scenario: &Scenario, kind: SchedulerKind) -> RunMetrics {
    let mut sim = Simulator::new(scenario, kind);
    sim.run()
}

/// Run one scheduler with the invariant engine armed; returns the metrics
/// together with the invariant report (conformance/fuzz harness entry).
pub fn run_checked(
    scenario: &Scenario,
    kind: SchedulerKind,
) -> (RunMetrics, InvariantReport) {
    let mut sim = Simulator::new(scenario, kind);
    sim.enable_invariants();
    let metrics = sim.run();
    let report = sim
        .take_invariant_report()
        .expect("invariants were enabled before run");
    (metrics, report)
}
