//! Scenario construction: turns an [`ExperimentConfig`] into the concrete
//! cluster, pipelines, traces, and content generators of one experiment.

use crate::cluster::Cluster;
use crate::config::ExperimentConfig;
use crate::network::{BwTrace, TraceKind};
use crate::pipeline::PipelineDag;
use crate::profiles::ProfileStore;
use crate::util::Rng;
use crate::workload::{ContentDynamics, ContentProfile};

/// A fully-instantiated experiment.
pub struct Scenario {
    pub cfg: ExperimentConfig,
    pub cluster: Cluster,
    pub profiles: ProfileStore,
    pub pipelines: Vec<PipelineDag>,
    /// Uplink trace per device id (index 0 = server, unused).
    pub traces: Vec<BwTrace>,
    /// Content process per pipeline.
    pub content: Vec<ContentDynamics>,
}

impl Scenario {
    /// Build the paper's standard deployment for `cfg`.
    pub fn build(cfg: ExperimentConfig) -> Scenario {
        let mut rng = Rng::new(cfg.seed);
        let cluster = Cluster::paper_testbed();

        // One pipeline per camera; cameras_per_device > 1 (Fig. 8) adds
        // extra pipelines on the same source devices.
        let mut pipelines = Vec::new();
        for cam in 0..cfg.cameras_per_device {
            for s in 0..cfg.n_sources {
                let device = 1 + s; // devices 1..=9 host cameras
                let mut p = if s % 3 == 2 {
                    crate::pipeline::surveillance_pipeline(device, 15.0)
                } else {
                    crate::pipeline::traffic_pipeline(device, 15.0)
                };
                p.name = format!("{}{}c{}", p.name, s, cam);
                p.slo_ms = (p.slo_ms - cfg.slo_reduction_ms).max(20.0);
                pipelines.push(p);
            }
        }

        // Uplink traces: one per device (server's entry unused).
        let traces: Vec<BwTrace> = (0..cluster.devices.len())
            .map(|d| {
                let mut r = rng.fork(1000 + d as u64);
                if d == 0 {
                    BwTrace::constant(10_000.0)
                } else {
                    BwTrace::generate(cfg.trace, cfg.duration_ms.max(60_000.0), &mut r)
                }
            })
            .collect();

        // Content processes: traffic vs surveillance profiles; the Fig. 11
        // run uses the diurnal curve, short runs use a flat profile whose
        // mean matches mid-day content.
        let content: Vec<ContentDynamics> = pipelines
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let r = rng.fork(2000 + i as u64);
                let profile = if cfg.diurnal {
                    if p.name.starts_with("traffic") {
                        ContentProfile::traffic()
                    } else {
                        ContentProfile::surveillance()
                    }
                } else {
                    let mut pr = if p.name.starts_with("traffic") {
                        ContentProfile::traffic()
                    } else {
                        ContentProfile::surveillance()
                    };
                    // 30-min segment at mid-day intensity (paper extracts
                    // segments from three times of day; seed varies pick).
                    pr.shape = crate::workload::DiurnalShape::Flat;
                    pr.peak_objects *= 0.55 + 0.2 * (i % 3) as f64;
                    pr
                };
                ContentDynamics::new(profile, r)
            })
            .collect();

        Scenario { cfg, cluster, profiles: ProfileStore::analytic(), pipelines, traces, content }
    }
}

/// Bandwidth snapshot (Mbit/s per device) at time `t` for scheduler input.
pub fn scenario_env_bw(sc: &Scenario, t_ms: f64) -> Vec<f64> {
    sc.traces.iter().map(|tr| tr.bandwidth_mbps(t_ms)).collect()
}

/// Convenience preset mapping for benches/CLI.
pub fn preset(name: &str) -> Option<ExperimentConfig> {
    let mut cfg = ExperimentConfig::default();
    match name {
        "standard" => {}
        "lte" => cfg.trace = TraceKind::Lte,
        "double" => cfg.cameras_per_device = 2,
        "slo50" => cfg.slo_reduction_ms = 50.0,
        "slo100" => cfg.slo_reduction_ms = 100.0,
        "longterm" => {
            cfg.diurnal = true;
            cfg.duration_ms = 13.0 * 3600.0 * 1000.0;
        }
        "smoke" => {
            cfg.n_sources = 2;
            cfg.duration_ms = 60_000.0;
        }
        _ => return None,
    }
    Some(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_scenario_shape() {
        let sc = Scenario::build(ExperimentConfig::default());
        assert_eq!(sc.pipelines.len(), 9);
        assert_eq!(sc.traces.len(), 10);
        assert_eq!(sc.content.len(), 9);
        for p in &sc.pipelines {
            assert!(p.validate().is_ok());
            assert!(p.source_device >= 1);
        }
    }

    #[test]
    fn double_camera_doubles_pipelines() {
        let cfg = preset("double").unwrap();
        let sc = Scenario::build(cfg);
        assert_eq!(sc.pipelines.len(), 18);
    }

    #[test]
    fn slo_reduction_applies() {
        let cfg = preset("slo100").unwrap();
        let sc = Scenario::build(cfg);
        assert!((sc.pipelines[0].slo_ms - 100.0).abs() < 1e-9); // 200-100
        assert!((sc.pipelines[2].slo_ms - 200.0).abs() < 1e-9); // 300-100
    }

    #[test]
    fn all_presets_resolve() {
        for name in ["standard", "lte", "double", "slo50", "slo100", "longterm", "smoke"] {
            assert!(preset(name).is_some(), "{name}");
        }
        assert!(preset("bogus").is_none());
    }

    #[test]
    fn deterministic_build() {
        let a = Scenario::build(ExperimentConfig::default());
        let b = Scenario::build(ExperimentConfig::default());
        assert_eq!(
            scenario_env_bw(&a, 12_345.0),
            scenario_env_bw(&b, 12_345.0)
        );
    }
}
