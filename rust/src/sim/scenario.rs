//! Scenario construction: turns an [`ExperimentConfig`] into the concrete
//! cluster, pipelines, traces, and content generators of one experiment.

use crate::cluster::Cluster;
use crate::config::ExperimentConfig;
use crate::coordinator::ReplanMode;
use crate::network::{BwTrace, TraceKind};
use crate::pipeline::PipelineDag;
use crate::profiles::ProfileStore;
use crate::util::Rng;
use crate::workload::{ContentDynamics, ContentProfile};

/// A fully-instantiated experiment.
pub struct Scenario {
    pub cfg: ExperimentConfig,
    pub cluster: Cluster,
    pub profiles: ProfileStore,
    pub pipelines: Vec<PipelineDag>,
    /// Uplink trace per device id (index 0 = server, unused).
    pub traces: Vec<BwTrace>,
    /// Content process per pipeline.
    pub content: Vec<ContentDynamics>,
}

impl Scenario {
    /// Build the paper's standard deployment for `cfg`.
    pub fn build(cfg: ExperimentConfig) -> Scenario {
        let mut rng = Rng::new(cfg.seed);
        let cluster = Cluster::paper_testbed();

        // One pipeline per camera; cameras_per_device > 1 (Fig. 8) adds
        // extra pipelines on the same source devices.
        let mut pipelines = Vec::new();
        for cam in 0..cfg.cameras_per_device {
            for s in 0..cfg.n_sources {
                let device = 1 + s; // devices 1..=9 host cameras
                let mut p = if s % 3 == 2 {
                    crate::pipeline::surveillance_pipeline(device, 15.0)
                } else {
                    crate::pipeline::traffic_pipeline(device, 15.0)
                };
                p.name = format!("{}{}c{}", p.name, s, cam);
                p.slo_ms = (p.slo_ms - cfg.slo_reduction_ms).max(20.0);
                pipelines.push(p);
            }
        }

        // Uplink traces: one per device (server's entry unused).
        let traces: Vec<BwTrace> = (0..cluster.devices.len())
            .map(|d| {
                let mut r = rng.fork(1000 + d as u64);
                if d == 0 {
                    BwTrace::constant(10_000.0)
                } else {
                    BwTrace::generate(cfg.trace, cfg.duration_ms.max(60_000.0), &mut r)
                }
            })
            .collect();

        // Content processes: traffic vs surveillance profiles; the Fig. 11
        // run uses the diurnal curve, short runs use a flat profile whose
        // mean matches mid-day content.
        let content: Vec<ContentDynamics> = pipelines
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let r = rng.fork(2000 + i as u64);
                let profile = if cfg.diurnal {
                    if p.name.starts_with("traffic") {
                        ContentProfile::traffic()
                    } else {
                        ContentProfile::surveillance()
                    }
                } else {
                    let mut pr = if p.name.starts_with("traffic") {
                        ContentProfile::traffic()
                    } else {
                        ContentProfile::surveillance()
                    };
                    // 30-min segment at mid-day intensity (paper extracts
                    // segments from three times of day; seed varies pick).
                    pr.shape = crate::workload::DiurnalShape::Flat;
                    pr.peak_objects *= 0.55 + 0.2 * (i % 3) as f64;
                    pr
                };
                ContentDynamics::new(profile, r)
            })
            .collect();

        Scenario { cfg, cluster, profiles: ProfileStore::analytic(), pipelines, traces, content }
    }
}

/// Bandwidth snapshot (Mbit/s per device) at time `t` for scheduler input.
pub fn scenario_env_bw(sc: &Scenario, t_ms: f64) -> Vec<f64> {
    sc.traces.iter().map(|tr| tr.bandwidth_mbps(t_ms)).collect()
}

// ---------------------------------------------------------------------------
// Scenario fuzzer: adversarial edge dynamics beyond the paper's presets.
// ---------------------------------------------------------------------------

/// Adversarial scenario family sampled by the fuzzer. Each family stresses
/// one regime the paper claims robustness in (EdgeVision and the adaptive
/// edge-serving literature stress the same axes): workload spikes, diurnal
/// drift, bandwidth collapse, device churn, SLO pressure, and skewed
/// camera fan-out.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FuzzClass {
    /// Flat content with frequent strong burst episodes (crowd events).
    FlashCrowd,
    /// Diurnal intensity curve entered at a random time of day.
    DiurnalShift,
    /// Forced zero-bandwidth windows punched into uplink traces.
    Blackout,
    /// Devices dark for long alternating stretches (hot-join / departure).
    DeviceChurn,
    /// Tightened and heterogeneous per-pipeline SLOs + fps jitter.
    TightSlo,
    /// Few devices hosting many cameras with cranked detector fan-out.
    SkewedFanout,
    /// Two or more of the above composed.
    Mixed,
    /// A base workload family (derived from the same seed) composed with
    /// a seeded [`FaultPlan`](crate::sim::faults::FaultPlan): device
    /// crashes, GPU stragglers, controller outages, telemetry freezes.
    FaultStorm,
    /// Long-horizon composite: the diurnal curve (entered at a seeded time
    /// of day) with light blackouts *and* device churn layered on, run for
    /// an explicit multi-hour/multi-day horizon (`:horizon=H` seconds).
    /// Hundreds of replan rounds in one scenario — the soak family for
    /// drift-triggered replanning and partition barriers.
    LongHaul,
}

impl FuzzClass {
    /// The seven pure *workload* families the sampler draws from.
    /// [`FuzzClass::FaultStorm`] and [`FuzzClass::LongHaul`] are
    /// deliberately not in this array: they are orthogonal axes layered
    /// onto a base seed by [`FuzzSpec::sample_storm`] /
    /// [`FuzzSpec::sample_long_haul`] or the `:faults=M` / `:horizon=H`
    /// repro modifiers, so adding them here would re-roll every existing
    /// corpus seed.
    pub const ALL: [FuzzClass; 7] = [
        FuzzClass::FlashCrowd,
        FuzzClass::DiurnalShift,
        FuzzClass::Blackout,
        FuzzClass::DeviceChurn,
        FuzzClass::TightSlo,
        FuzzClass::SkewedFanout,
        FuzzClass::Mixed,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            FuzzClass::FlashCrowd => "flash_crowd",
            FuzzClass::DiurnalShift => "diurnal_shift",
            FuzzClass::Blackout => "blackout",
            FuzzClass::DeviceChurn => "device_churn",
            FuzzClass::TightSlo => "tight_slo",
            FuzzClass::SkewedFanout => "skewed_fanout",
            FuzzClass::Mixed => "mixed",
            FuzzClass::FaultStorm => "fault_storm",
            FuzzClass::LongHaul => "long_haul",
        }
    }
}

/// Deterministic description of one fuzzed experiment. Every field derives
/// from `seed` alone, so the one-line repro string (`fuzz:v1:seed=N`)
/// reconstructs the exact scenario — generator, traces, content, SLOs.
#[derive(Clone, Debug)]
pub struct FuzzSpec {
    pub seed: u64,
    pub class: FuzzClass,
    pub cfg: ExperimentConfig,
}

/// Stream tag separating spec sampling from scenario mutation draws.
const FUZZ_SAMPLE_TAG: u64 = 0xFAB1_0FF5;
const FUZZ_MUTATE_TAG: u64 = 0x5EED_CAFE;
/// Stream tag for the storm axis (fault count + ordering seed draws).
const FUZZ_STORM_TAG: u64 = 0x57AB_F417;
/// Stream tag for the long-haul composite's mutation draws (its own
/// stream so the composite never aliases the single-family mutations of
/// the same seed).
const FUZZ_LONGHAUL_TAG: u64 = 0x10A6_4A01_D1A2_57EE;

/// Longest long-haul horizon, seconds (3 simulated days ≈ 480 six-minute
/// replan rounds — far past "hundreds" while keeping trace memory and
/// runtime bounded).
pub const MAX_HORIZON_S: u64 = 259_200;

impl FuzzSpec {
    /// Sample a structurally-valid spec from `seed` (total function: every
    /// u64 yields a runnable scenario).
    pub fn sample(seed: u64) -> FuzzSpec {
        let mut rng = Rng::new(seed ^ FUZZ_SAMPLE_TAG);
        let class = FuzzClass::ALL[rng.below(FuzzClass::ALL.len())];
        let mut cfg = ExperimentConfig::default();
        cfg.seed = seed;
        // Short horizons keep a 50-scenario x 5-scheduler sweep in CI
        // budget while still crossing many batching/autoscale periods.
        cfg.duration_ms = rng.range(12_000.0, 30_000.0).floor();
        cfg.n_sources = 1 + rng.below(4);
        cfg.cameras_per_device = 1;
        cfg.trace = if rng.chance(0.5) { TraceKind::Lte } else { TraceKind::FiveG };
        match class {
            FuzzClass::DiurnalShift => cfg.diurnal = true,
            FuzzClass::TightSlo => {
                cfg.slo_reduction_ms = rng.range(40.0, 145.0).floor();
            }
            FuzzClass::SkewedFanout => {
                cfg.n_sources = 1 + rng.below(2);
                cfg.cameras_per_device = 2 + rng.below(3);
            }
            FuzzClass::Mixed => {
                cfg.slo_reduction_ms = rng.range(0.0, 100.0).floor();
                if rng.chance(0.5) {
                    cfg.cameras_per_device = 2;
                }
            }
            _ => {}
        }
        debug_assert!(cfg.validate().is_ok());
        FuzzSpec { seed, class, cfg }
    }

    /// Sample the eighth family: a base workload spec from the same seed
    /// with a fault storm layered on top (and, half the time, a non-zero
    /// same-time event ordering seed, so storms also exercise the
    /// permutation axis).
    pub fn sample_storm(seed: u64) -> FuzzSpec {
        let mut spec = FuzzSpec::sample(seed);
        let mut rng = Rng::new(seed ^ FUZZ_STORM_TAG);
        spec.class = FuzzClass::FaultStorm;
        spec.cfg.faults = 1 + rng.below(4) as u32;
        if rng.chance(0.5) {
            spec.cfg.order_seed = rng.next_u64();
        }
        spec
    }

    /// Sample the long-haul composite: the same base spec `seed` yields
    /// (no extra RNG draws — existing corpus seeds replay unchanged),
    /// stretched to an explicit `horizon_s`-second run on the diurnal
    /// curve. `horizon_s` is clamped to [1, [`MAX_HORIZON_S`]]. Equivalent
    /// to the `:horizon=H` repro modifier.
    pub fn sample_long_haul(seed: u64, horizon_s: u64) -> FuzzSpec {
        let mut spec = FuzzSpec::sample(seed);
        spec.class = FuzzClass::LongHaul;
        spec.cfg.duration_ms = horizon_s.clamp(1, MAX_HORIZON_S) as f64 * 1000.0;
        spec.cfg.diurnal = true;
        spec
    }

    /// One-line repro string; feed back through [`FuzzSpec::from_repro`]
    /// (or `octopinf fuzz --repro <string>`) to replay deterministically.
    /// Every non-default axis is part of the repro — a drift-mode,
    /// fault-storm, long-haul, or permuted-ordering failure must not
    /// silently replay without it. Grammar:
    /// `fuzz:v1:seed=N[:replan=drift][:faults=M][:order=K][:horizon=H][:clusters=C]`.
    pub fn repro(&self) -> String {
        let mut s = format!("fuzz:v1:seed={}", self.seed);
        if self.cfg.replan != ReplanMode::Periodic {
            s.push_str(&format!(":replan={}", self.cfg.replan.label()));
        }
        if self.cfg.faults > 0 {
            s.push_str(&format!(":faults={}", self.cfg.faults));
        }
        if self.cfg.order_seed != 0 {
            s.push_str(&format!(":order={}", self.cfg.order_seed));
        }
        if self.class == FuzzClass::LongHaul {
            s.push_str(&format!(":horizon={}", (self.cfg.duration_ms / 1000.0) as u64));
        }
        if self.cfg.clusters > 1 {
            s.push_str(&format!(":clusters={}", self.cfg.clusters));
        }
        s
    }

    /// Parse a repro string back into the identical spec. Unknown
    /// modifiers are rejected (a typo must fail loudly, not replay the
    /// wrong scenario).
    pub fn from_repro(s: &str) -> Option<FuzzSpec> {
        let rest = s.trim().strip_prefix("fuzz:v1:seed=")?;
        let mut parts = rest.split(':');
        let seed = parts.next()?.parse::<u64>().ok()?;
        let mut spec = FuzzSpec::sample(seed);
        for part in parts {
            let (key, val) = part.split_once('=')?;
            match key {
                "replan" => spec.cfg.replan = ReplanMode::parse(val)?,
                "faults" => {
                    spec.cfg.faults = val.parse::<u32>().ok()?;
                    // LongHaul wins: a long-haul run with faults stays
                    // long-haul (the storm rides in on cfg.faults), and
                    // modifier order on input is free.
                    if spec.cfg.faults > 0 && spec.class != FuzzClass::LongHaul {
                        spec.class = FuzzClass::FaultStorm;
                    }
                }
                "order" => spec.cfg.order_seed = val.parse::<u64>().ok()?,
                "horizon" => {
                    let h = val.parse::<u64>().ok()?;
                    if h == 0 || h > MAX_HORIZON_S {
                        return None;
                    }
                    spec.class = FuzzClass::LongHaul;
                    spec.cfg.duration_ms = h as f64 * 1000.0;
                    spec.cfg.diurnal = true;
                }
                "clusters" => spec.cfg.clusters = val.parse::<usize>().ok()?,
                _ => return None,
            }
        }
        spec.cfg.validate().ok()?;
        Some(spec)
    }

    /// Instantiate the scenario: the standard deployment for `cfg`, then
    /// the class-specific adversarial mutation.
    pub fn build(&self) -> Scenario {
        if self.class == FuzzClass::FaultStorm {
            // Storms compose with the base workload family the same seed
            // samples; the fault windows themselves ride into the engine
            // on `cfg.faults`. `sample` never returns FaultStorm, so this
            // recursion terminates after one step.
            let mut base = self.clone();
            base.class = FuzzSpec::sample(self.seed).class;
            return base.build();
        }
        if self.class == FuzzClass::LongHaul {
            // The soak composite: diurnal drift × light blackouts × churn,
            // on its own mutation stream so it never aliases the
            // single-family scenarios of the same seed.
            let mut sc = Scenario::build(self.cfg.clone());
            let mut rng = Rng::new(self.seed ^ FUZZ_LONGHAUL_TAG);
            diurnal_shift(&mut sc, &mut rng);
            blackout(&mut sc, &mut rng, true);
            device_churn(&mut sc, &mut rng);
            for p in &sc.pipelines {
                debug_assert!(p.validate().is_ok(), "{}", p.name);
            }
            return sc;
        }
        let mut sc = Scenario::build(self.cfg.clone());
        let mut rng = Rng::new(self.seed ^ FUZZ_MUTATE_TAG);
        match self.class {
            FuzzClass::FlashCrowd => flash_crowd(&mut sc, &mut rng),
            FuzzClass::DiurnalShift => diurnal_shift(&mut sc, &mut rng),
            FuzzClass::Blackout => blackout(&mut sc, &mut rng, false),
            FuzzClass::DeviceChurn => device_churn(&mut sc, &mut rng),
            FuzzClass::TightSlo => tight_slo(&mut sc, &mut rng),
            FuzzClass::SkewedFanout => skewed_fanout(&mut sc, &mut rng),
            FuzzClass::Mixed => {
                flash_crowd(&mut sc, &mut rng);
                blackout(&mut sc, &mut rng, true);
                if rng.chance(0.5) {
                    tight_slo(&mut sc, &mut rng);
                }
            }
            FuzzClass::FaultStorm | FuzzClass::LongHaul => {
                unreachable!("handled above")
            }
        }
        for p in &sc.pipelines {
            debug_assert!(p.validate().is_ok(), "{}", p.name);
        }
        sc
    }
}

impl std::fmt::Display for FuzzSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} [{}: {}src x {}cam, {:.0}s, {:?}, slo-{:.0}ms{}]",
            self.repro(),
            self.class.label(),
            self.cfg.n_sources,
            self.cfg.cameras_per_device,
            self.cfg.duration_ms / 1000.0,
            self.cfg.trace,
            self.cfg.slo_reduction_ms,
            if self.cfg.diurnal { ", diurnal" } else { "" },
        )
    }
}

/// Workload spike: flat base intensity, strong frequent bursts.
fn flash_crowd(sc: &mut Scenario, rng: &mut Rng) {
    for (i, slot) in sc.content.iter_mut().enumerate() {
        let mut pr = ContentProfile::flash_crowd(
            rng.range(3.0, 10.0),
            rng.range(3.0, 7.0),
        );
        pr.calm_dwell_ms = rng.range(8_000.0, 25_000.0);
        pr.burst_dwell_ms = rng.range(3_000.0, 12_000.0);
        *slot = ContentDynamics::new(pr, rng.fork(7000 + i as u64));
    }
}

/// Enter the diurnal curve at a random time of day (night, rush hour...).
fn diurnal_shift(sc: &mut Scenario, rng: &mut Rng) {
    let offset = rng.range(0.0, 24.0 * 3_600_000.0);
    for (i, (slot, p)) in
        sc.content.iter_mut().zip(&sc.pipelines).enumerate()
    {
        let mut pr = if p.name.starts_with("traffic") {
            ContentProfile::traffic()
        } else {
            ContentProfile::surveillance()
        };
        pr.day_offset_ms = offset;
        *slot = ContentDynamics::new(pr, rng.fork(8000 + i as u64));
    }
}

/// Seconds of the trace the simulation actually plays (traces are
/// generated with a 60 s floor, so windows must be sampled against the
/// sim horizon or they land beyond everything the run observes).
fn horizon_s(sc: &Scenario) -> usize {
    ((sc.cfg.duration_ms / 1000.0).ceil() as usize).max(2)
}

/// Punch zero-bandwidth windows into camera-hosting uplinks (devices
/// `1..=n_sources` — the only links the run observes), inside the sim
/// horizon. `light` softens the dose for composition inside
/// [`FuzzClass::Mixed`].
fn blackout(sc: &mut Scenario, rng: &mut Rng, light: bool) {
    let p_hit = if light { 0.35 } else { 0.7 };
    let len_s = horizon_s(sc);
    let n = sc.cfg.n_sources;
    for (d, tr) in sc.traces.iter_mut().enumerate().skip(1).take(n) {
        // Guarantee at least one active uplink is hit per scenario: the
        // first camera device is always mutated, the rest by chance.
        if d > 1 && !rng.chance(p_hit) {
            continue;
        }
        let windows = 1 + rng.below(3);
        for _ in 0..windows {
            let start = rng.below(len_s);
            let dark = 3 + rng.below(25);
            tr.zero_window(start, start + dark);
        }
    }
}

/// Long dark stretches with the join/departure transition *inside* the
/// sim horizon: a camera device joining late (dark, then alive) or
/// departing (alive, then dark) — churn as the link layer sees it.
fn device_churn(sc: &mut Scenario, rng: &mut Rng) {
    let len_s = horizon_s(sc);
    let n = sc.cfg.n_sources;
    for (d, tr) in sc.traces.iter_mut().enumerate().skip(1).take(n) {
        if d > 1 && !rng.chance(0.8) {
            continue;
        }
        // Transition somewhere in the middle 60 % of the run.
        let cut = (len_s / 5 + rng.below((3 * len_s / 5).max(1))).clamp(1, len_s - 1);
        if rng.chance(0.5) {
            tr.zero_window(0, cut); // hot-join: dark until `cut`
        } else {
            tr.zero_window(cut, len_s); // departure: dark after `cut`
        }
    }
}

/// Heterogeneous SLO pressure and frame-rate jitter.
fn tight_slo(sc: &mut Scenario, rng: &mut Rng) {
    for p in sc.pipelines.iter_mut() {
        p.slo_ms = (p.slo_ms * rng.range(0.5, 1.2)).max(25.0);
        p.source_fps = rng.range(8.0, 24.0);
    }
}

/// Dense scenes (high real per-frame fan-out), misestimated scheduler
/// fan-out, and under-routed residue (routing fractions summing < 1
/// exercise the conservation path for vanished objects).
fn skewed_fanout(sc: &mut Scenario, rng: &mut Rng) {
    for p in sc.pipelines.iter_mut() {
        p.models[0].spec.fanout_mean = rng.range(4.0, 9.0);
        if rng.chance(0.5) {
            let scale = rng.range(0.55, 0.95);
            for frac in p.models[0].routing.iter_mut() {
                *frac *= scale;
            }
        }
    }
    // The engine's *real* fan-out comes from the content process (objects
    // per frame), not `fanout_mean` (which only feeds the schedulers' rate
    // estimates and is deliberately desynchronized above so planners also
    // face misestimation): crank the scenes dense.
    for (i, slot) in sc.content.iter_mut().enumerate() {
        let pr = ContentProfile::flat(rng.range(8.0, 16.0));
        *slot = ContentDynamics::new(pr, rng.fork(9000 + i as u64));
    }
}

/// Deterministic enumerator over fuzz seeds: `seed0, seed0+1, ...` so any
/// scenario in a sweep is reproducible from its position alone.
pub struct ScenarioGen {
    next_seed: u64,
}

impl ScenarioGen {
    pub fn new(seed0: u64) -> ScenarioGen {
        ScenarioGen { next_seed: seed0 }
    }
}

impl Iterator for ScenarioGen {
    type Item = FuzzSpec;

    fn next(&mut self) -> Option<FuzzSpec> {
        let spec = FuzzSpec::sample(self.next_seed);
        self.next_seed = self.next_seed.wrapping_add(1);
        Some(spec)
    }
}

/// Convenience preset mapping for benches/CLI.
pub fn preset(name: &str) -> Option<ExperimentConfig> {
    let mut cfg = ExperimentConfig::default();
    match name {
        "standard" => {}
        "lte" => cfg.trace = TraceKind::Lte,
        "double" => cfg.cameras_per_device = 2,
        "slo50" => cfg.slo_reduction_ms = 50.0,
        "slo100" => cfg.slo_reduction_ms = 100.0,
        "longterm" => {
            cfg.diurnal = true;
            cfg.duration_ms = 13.0 * 3600.0 * 1000.0;
        }
        "smoke" => {
            cfg.n_sources = 2;
            cfg.duration_ms = 60_000.0;
        }
        "static" => {
            // Surveillance-style mostly-static scenes: the content-aware
            // frontend answers long static runs without admission. Small
            // and short so CI can afford an on/off comparison.
            cfg.frontend = true;
            cfg.scene_static_frames = 240.0;
            cfg.n_sources = 3;
            cfg.duration_ms = 120_000.0;
        }
        _ => return None,
    }
    Some(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_scenario_shape() {
        let sc = Scenario::build(ExperimentConfig::default());
        assert_eq!(sc.pipelines.len(), 9);
        assert_eq!(sc.traces.len(), 10);
        assert_eq!(sc.content.len(), 9);
        for p in &sc.pipelines {
            assert!(p.validate().is_ok());
            assert!(p.source_device >= 1);
        }
    }

    #[test]
    fn double_camera_doubles_pipelines() {
        let cfg = preset("double").unwrap();
        let sc = Scenario::build(cfg);
        assert_eq!(sc.pipelines.len(), 18);
    }

    #[test]
    fn slo_reduction_applies() {
        let cfg = preset("slo100").unwrap();
        let sc = Scenario::build(cfg);
        assert!((sc.pipelines[0].slo_ms - 100.0).abs() < 1e-9); // 200-100
        assert!((sc.pipelines[2].slo_ms - 200.0).abs() < 1e-9); // 300-100
    }

    #[test]
    fn all_presets_resolve() {
        for name in [
            "standard", "lte", "double", "slo50", "slo100", "longterm",
            "smoke", "static",
        ] {
            assert!(preset(name).is_some(), "{name}");
        }
        assert!(preset("bogus").is_none());
        let st = preset("static").unwrap();
        assert!(st.frontend);
        assert_eq!(st.scene_static_frames, 240.0);
    }

    #[test]
    fn deterministic_build() {
        let a = Scenario::build(ExperimentConfig::default());
        let b = Scenario::build(ExperimentConfig::default());
        assert_eq!(
            scenario_env_bw(&a, 12_345.0),
            scenario_env_bw(&b, 12_345.0)
        );
    }

    #[test]
    fn fuzz_specs_valid_and_repro_roundtrips() {
        for seed in 0..40u64 {
            let a = FuzzSpec::sample(seed);
            assert!(a.cfg.validate().is_ok(), "seed {seed}: {:?}", a.cfg);
            let b = FuzzSpec::from_repro(&a.repro()).expect("repro parses");
            assert_eq!(a.seed, b.seed);
            assert_eq!(a.class, b.class);
            let (sa, sb) = (a.build(), b.build());
            assert_eq!(sa.pipelines.len(), sb.pipelines.len());
            for (pa, pb) in sa.pipelines.iter().zip(&sb.pipelines) {
                assert!(pa.validate().is_ok(), "seed {seed} {}", pa.name);
                assert_eq!(pa.slo_ms, pb.slo_ms, "seed {seed}");
                assert_eq!(pa.source_fps, pb.source_fps, "seed {seed}");
            }
            assert_eq!(
                scenario_env_bw(&sa, 5_000.0),
                scenario_env_bw(&sb, 5_000.0),
                "seed {seed}: traces diverge between identical specs"
            );
        }
        assert!(FuzzSpec::from_repro("fuzz:v2:seed=1").is_none());
        assert!(FuzzSpec::from_repro("garbage").is_none());
    }

    #[test]
    fn repro_string_carries_the_replan_mode() {
        let mut spec = FuzzSpec::sample(9);
        assert_eq!(spec.repro(), "fuzz:v1:seed=9");
        spec.cfg.replan = ReplanMode::Drift;
        assert_eq!(spec.repro(), "fuzz:v1:seed=9:replan=drift");
        let back = FuzzSpec::from_repro(&spec.repro()).unwrap();
        assert_eq!(back.seed, 9);
        assert_eq!(back.cfg.replan, ReplanMode::Drift);
        let bare = FuzzSpec::from_repro("fuzz:v1:seed=9").unwrap();
        assert_eq!(bare.cfg.replan, ReplanMode::Periodic);
        assert!(FuzzSpec::from_repro("fuzz:v1:seed=9:replan=bogus").is_none());
        assert!(FuzzSpec::from_repro("fuzz:v1:seed=9:bogus=drift").is_none());
    }

    #[test]
    fn repro_string_carries_faults_and_order() {
        let mut spec = FuzzSpec::sample(11);
        spec.cfg.faults = 3;
        spec.cfg.order_seed = 77;
        assert_eq!(spec.repro(), "fuzz:v1:seed=11:faults=3:order=77");
        let back = FuzzSpec::from_repro(&spec.repro()).unwrap();
        assert_eq!(back.cfg.faults, 3);
        assert_eq!(back.cfg.order_seed, 77);
        assert_eq!(back.class, FuzzClass::FaultStorm);
        // Modifier order is free on input; unknown keys still fail.
        let alt = FuzzSpec::from_repro("fuzz:v1:seed=11:order=77:faults=3:replan=drift")
            .unwrap();
        assert_eq!(alt.cfg.faults, 3);
        assert_eq!(alt.cfg.order_seed, 77);
        assert_eq!(alt.cfg.replan, ReplanMode::Drift);
        assert!(FuzzSpec::from_repro("fuzz:v1:seed=11:faults=nope").is_none());
        assert!(FuzzSpec::from_repro("fuzz:v1:seed=11:faults=3:bogus=1").is_none());
        assert!(FuzzSpec::from_repro("fuzz:v1:seed=11:faults=900").is_none());
    }

    #[test]
    fn storm_specs_roundtrip_and_compose_a_base_family() {
        let mut saw_order = false;
        for seed in 0..24u64 {
            let a = FuzzSpec::sample_storm(seed);
            assert_eq!(a.class, FuzzClass::FaultStorm);
            assert!(a.cfg.faults >= 1 && a.cfg.faults <= 4, "seed {seed}");
            saw_order |= a.cfg.order_seed != 0;
            let b = FuzzSpec::from_repro(&a.repro()).expect("storm repro parses");
            assert_eq!(b.class, FuzzClass::FaultStorm);
            assert_eq!(a.cfg.faults, b.cfg.faults);
            assert_eq!(a.cfg.order_seed, b.cfg.order_seed);
            // The built scenario is the base family's (storms perturb the
            // system, not the workload construction).
            let base = FuzzSpec::sample(seed);
            let (sa, sb) = (a.build(), base.build());
            assert_eq!(sa.pipelines.len(), sb.pipelines.len(), "seed {seed}");
            for (pa, pb) in sa.pipelines.iter().zip(&sb.pipelines) {
                assert_eq!(pa.slo_ms, pb.slo_ms, "seed {seed}");
            }
        }
        assert!(saw_order, "no storm sampled a non-zero ordering seed");
    }

    #[test]
    fn long_haul_repro_roundtrips() {
        let a = FuzzSpec::sample_long_haul(13, 7_200);
        assert_eq!(a.class, FuzzClass::LongHaul);
        assert_eq!(a.cfg.duration_ms, 7_200_000.0);
        assert!(a.cfg.diurnal, "long haul rides the diurnal curve");
        assert_eq!(a.repro(), "fuzz:v1:seed=13:horizon=7200");
        let b = FuzzSpec::from_repro(&a.repro()).expect("horizon parses");
        assert_eq!(b.class, FuzzClass::LongHaul);
        assert_eq!(b.cfg.duration_ms, a.cfg.duration_ms);
        assert!(b.cfg.diurnal);
        // Horizon bounds: zero and beyond 3 days fail loudly.
        assert!(FuzzSpec::from_repro("fuzz:v1:seed=13:horizon=0").is_none());
        assert!(
            FuzzSpec::from_repro("fuzz:v1:seed=13:horizon=259201").is_none()
        );
        assert_eq!(
            FuzzSpec::sample_long_haul(13, u64::MAX).cfg.duration_ms,
            MAX_HORIZON_S as f64 * 1000.0,
            "sampler clamps instead of failing"
        );
    }

    #[test]
    fn long_haul_composes_with_faults_and_clusters() {
        // Faults + horizon stay LongHaul regardless of modifier order; the
        // storm rides in on cfg.faults.
        for s in [
            "fuzz:v1:seed=5:faults=3:horizon=1800:clusters=2",
            "fuzz:v1:seed=5:horizon=1800:clusters=2:faults=3",
        ] {
            let spec = FuzzSpec::from_repro(s).expect("composite parses");
            assert_eq!(spec.class, FuzzClass::LongHaul, "{s}");
            assert_eq!(spec.cfg.faults, 3, "{s}");
            assert_eq!(spec.cfg.clusters, 2, "{s}");
            assert_eq!(spec.cfg.duration_ms, 1_800_000.0, "{s}");
        }
        let spec = FuzzSpec::from_repro(
            "fuzz:v1:seed=5:faults=3:horizon=1800:clusters=2",
        )
        .unwrap();
        assert_eq!(
            spec.repro(),
            "fuzz:v1:seed=5:faults=3:horizon=1800:clusters=2",
            "canonical emission order"
        );
        // Cluster bounds ride the config validator.
        assert!(FuzzSpec::from_repro("fuzz:v1:seed=5:clusters=0").is_none());
        assert!(FuzzSpec::from_repro("fuzz:v1:seed=5:clusters=65").is_none());
        let c = FuzzSpec::from_repro("fuzz:v1:seed=5:clusters=4").unwrap();
        assert_eq!(c.cfg.clusters, 4);
        assert_ne!(c.class, FuzzClass::LongHaul, "clusters alone is not a class");
    }

    #[test]
    fn long_haul_build_darkens_links_and_keeps_pipelines_valid() {
        // Short horizon keeps the build cheap; the composite mutations
        // still apply (device 1 is always churned, so some in-horizon
        // second must be dark).
        let spec = FuzzSpec::sample_long_haul(3, 600);
        let sc = spec.build();
        for p in &sc.pipelines {
            assert!(p.validate().is_ok(), "{}", p.name);
        }
        let (dark, bright) = in_horizon_profile(&sc, 1);
        assert!(dark > 0, "churn/blackout left device 1 untouched");
        assert!(dark + bright == 600);
        // Same repro, same scenario.
        let again = FuzzSpec::from_repro(&spec.repro()).unwrap().build();
        assert_eq!(
            scenario_env_bw(&sc, 123_000.0),
            scenario_env_bw(&again, 123_000.0)
        );
    }

    #[test]
    fn scenario_gen_covers_many_classes() {
        use std::collections::HashSet;
        let classes: HashSet<&'static str> = ScenarioGen::new(0)
            .take(60)
            .map(|s| s.class.label())
            .collect();
        assert!(classes.len() >= 5, "only {classes:?}");
    }

    /// Per-second link state over the *sim horizon* (not the 60 s trace
    /// floor): (dark seconds, bright seconds).
    fn in_horizon_profile(sc: &Scenario, device: usize) -> (usize, usize) {
        let secs = (sc.cfg.duration_ms / 1000.0).ceil() as usize;
        let mut dark = 0;
        let mut bright = 0;
        for s in 0..secs {
            if sc.traces[device].bandwidth_mbps(s as f64 * 1000.0) <= 0.0 {
                dark += 1;
            } else {
                bright += 1;
            }
        }
        (dark, bright)
    }

    #[test]
    fn blackout_scenarios_darken_links_inside_the_horizon() {
        // Deterministically find blackout-class seeds and confirm the
        // mutation darkens at least one uplink *within the run*.
        let mut found = 0;
        for spec in ScenarioGen::new(0).take(200) {
            if spec.class != FuzzClass::Blackout {
                continue;
            }
            let sc = spec.build();
            let hit = (1..=sc.cfg.n_sources)
                .any(|d| in_horizon_profile(&sc, d).0 >= 3);
            if hit {
                found += 1;
            }
            if found >= 3 {
                return;
            }
        }
        panic!("no blackout scenario darkened a link inside the horizon");
    }

    #[test]
    fn device_churn_transitions_inside_the_horizon() {
        // The churn family must produce an actual join/departure edge the
        // run can observe: a device that is both dark and alive for
        // meaningful stretches of the simulated window.
        for spec in ScenarioGen::new(0).take(300) {
            if spec.class != FuzzClass::DeviceChurn {
                continue;
            }
            let sc = spec.build();
            let secs = (sc.cfg.duration_ms / 1000.0).ceil() as usize;
            if (1..=sc.cfg.n_sources).any(|d| {
                let (dark, bright) = in_horizon_profile(&sc, d);
                dark * 5 >= secs && bright * 5 >= secs
            }) {
                return; // dark >= 20% and alive >= 20% of the run
            }
        }
        panic!("no churn scenario produced an in-horizon transition");
    }
}
