//! The discrete-event engine — component layer.
//!
//! One [`SimPartition`] is a self-contained edge cluster: mechanics shared
//! by every scheduler (identical comparison substrate): frame sources ->
//! per-(pipeline, model) dynamic batchers -> GPU executors ->
//! routing/fanout -> sinks; FIFO uplinks; periodic rescheduling (paper:
//! 6 min); autoscaler ticks for the OctopInf variants; lazy dropping of
//! already-late queries at dispatch. Time lives in a
//! [`crate::sim::wheel::EventWheel`]; the partition only advances when
//! the orchestration layer ([`crate::sim::Simulator`]) calls
//! `tick(until)` — see the determinism contract in [`crate::sim`].
//!
//! CORAL-reserved instances execute interference-free inside their duty
//! cycle (the reservation is the paper's point); spatial-only instances
//! suffer the co-location interference model when executions overlap.

use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};

use crate::coordinator::autoscaler::{AutoScaler, AutoScalerParams, ScaleAction};
use crate::coordinator::controller::{make_scheduler, SCHEDULING_PERIOD_MS};
use crate::coordinator::drift::{DriftDetector, DriftParams, ReplanMode};
use crate::coordinator::{
    GpuId, ModelObs, Plan, SchedEnv, Scheduler, SchedulerKind, StageCfg,
};
use crate::metrics::{Outcome, RunMetrics};
use crate::obs::{
    close_exact, MarkKind, Phase, PlanTrigger, SpanKind, TraceEvent,
    TraceMode, Tracer,
};
use crate::sim::faults::{CrashPolicy, FaultEv, FaultPlan};
use crate::sim::invariants::{InvariantChecker, InvariantReport};
use crate::sim::link::FifoLink;
use crate::sim::scenario::Scenario;
use crate::sim::wheel::{mix64, EventWheel};
use crate::sim::{Component, CrossMsg};
use crate::util::Rng;
use crate::workload::{ArrivalWindow, ContentDynamics, SceneFilter};
use crate::Ms;

/// Co-location interference: latency multiplier when executions overlap on
/// a GPU without a temporal reservation (§II: "unpredictable performance
/// degradations"; calibrated so the w/o-CORAL ablation loses ~10 % —
/// Fig. 10 — and Rim's edge stuffing hurts badly — Fig. 6b).
#[derive(Clone, Copy, Debug)]
pub struct InterferenceModel {
    /// Penalty per co-running execution (kernel-level timeslicing cost).
    pub per_corunner: f64,
    /// Exponent applied to (total width / capacity) when oversubscribed.
    pub oversub_exp: f64,
}

impl Default for InterferenceModel {
    fn default() -> Self {
        // Calibrated against the co-location literature the paper cites
        // (HiTDL, Masa): 5-10 co-resident DNNs on one GPU degrade latency
        // multi-x; CUDA timeslices kernels with no model-level coordination.
        InterferenceModel { per_corunner: 0.35, oversub_exp: 2.0 }
    }
}

impl InterferenceModel {
    /// Multiplier given total overlapping width (incl. self), capacity, and
    /// number of co-runners (excl. self).
    pub fn multiplier(&self, total_width: f64, cap: f64, co_runners: usize) -> f64 {
        let base = 1.0 + self.per_corunner * co_runners as f64;
        if total_width <= cap {
            base
        } else {
            base * (total_width / cap).powf(self.oversub_exp)
        }
    }
}

/// A query flowing through a pipeline (a frame, then per-object crops).
///
/// Beyond identity and deadline, a query carries its own latency
/// decomposition: `mark_ms` stamps the last lifecycle boundary, and the
/// three accumulators absorb each closed segment (transfer at arrival,
/// queue wait at dispatch, execution at completion). The segments
/// telescope — every boundary both closes one segment and opens the
/// next — so at the sink `transfer + queue + exec` equals end-to-end
/// latency up to fp rounding of the adds, which
/// [`close_exact`] folds away to make the sum bit-exact. Children
/// inherit the parent's accumulators (end-to-end attribution spans
/// the whole pipeline), restarting the clock at the spawn stamp.
#[derive(Clone, Copy, Debug)]
struct Query {
    created_ms: Ms,
    deadline_ms: Ms,
    /// Objects carried (frames: detected count; crops: 1).
    objects: u16,
    /// Partition-local trace identity (a bare counter: allocation order
    /// is a pure function of the event sequence, so qids are stable
    /// across `--sim-jobs` and tracing on/off).
    qid: u64,
    /// Sim-clock stamp of the last lifecycle boundary.
    mark_ms: Ms,
    /// Accumulated uplink/routing transfer time.
    transfer_ms: Ms,
    /// Accumulated batching-queue wait.
    queue_ms: Ms,
    /// Accumulated GPU execution (incl. interference inflation).
    exec_ms: Ms,
}

/// Instance-group runtime state for one (pipeline, model).
struct Group {
    /// Own coordinates in the deployment grid (group-local lookups).
    pipeline: usize,
    model: usize,
    cfg: StageCfg,
    bindings: Vec<crate::coordinator::GpuBinding>,
    busy: Vec<bool>,
    queue: VecDeque<Query>,
    window: ArrivalWindow,
    /// Pending flush-timer deadline (dedup of Flush events).
    flush_at: Option<Ms>,
    /// Deployment generation of this group. Pending `Portion` clocks carry
    /// the epoch they were armed under; a plan swap that actually changes
    /// the group bumps it, invalidating the stale clocks — while groups a
    /// migration leaves untouched keep theirs running (plan-diff install).
    epoch: u64,
}

impl Group {
    /// Sustainable rate of the group: reserved instances chain full
    /// batches through stream gaps (0.8 × curve); contended instances are
    /// curve-bound.
    fn capacity_qps(&self, sc: &ScenarioData) -> f64 {
        let spec = &sc.pipelines[self.pipeline].models[self.model].spec;
        let class = sc.cluster.device(self.cfg.device).class;
        let curve_cap = sc.profiles.curve(spec, class).throughput(self.cfg.batch);
        self.bindings
            .iter()
            .map(|b| if b.temporal.is_some() { curve_cap * 0.8 } else { curve_cap })
            .sum()
    }
}

enum Ev {
    Frame { pipeline: usize },
    Arrive { pipeline: usize, model: usize, query: Query },
    Flush { pipeline: usize, model: usize },
    /// CORAL duty-cycle occurrence of one reserved instance: execute
    /// whatever queued (paper Fig. 5: GPU access cycles back each duty).
    Portion { pipeline: usize, model: usize, binding: usize, epoch: u64 },
    ExecDone { pipeline: usize, model: usize, binding: usize, queries: Vec<Query> },
    Reschedule,
    AutoScale,
    /// Drift-mode only: compare live observations against the active
    /// plan's envelope and incrementally replan the drifted pipelines.
    DriftCheck,
    /// Injected system fault (crash/recover, straggler, outage, freeze).
    Fault(FaultEv),
    Tick,
}

// Scheduled engine events are `WheelEntry<Ev>`: the `(t, tie, seq)`
// ordering key and the seeded same-time permutation live in the
// time-source layer (`crate::sim::wheel`); the engine only owns the
// sequence counter feeding it.

/// One running execution on a GPU (for overlap queries).
#[derive(Clone, Copy)]
struct GpuRun {
    end_ms: Ms,
    width: f64,
}

impl PartialEq for GpuRun {
    fn eq(&self, o: &Self) -> bool {
        self.end_ms.total_cmp(&o.end_ms) == Ordering::Equal
    }
}
impl Eq for GpuRun {}
impl PartialOrd for GpuRun {
    fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for GpuRun {
    fn cmp(&self, o: &Self) -> Ordering {
        // Reversed: BinaryHeap becomes a min-heap on end time.
        o.end_ms.total_cmp(&self.end_ms)
    }
}

/// Active executions on one GPU with O(1) aggregate queries.
///
/// Replaces the per-dispatch `Vec::retain` scan: finished runs are popped
/// lazily from a min-heap on end time (amortized O(log n) per run over its
/// lifetime), while the total active width is maintained incrementally so
/// the interference multiplier needs no iteration at all.
struct GpuRuns {
    /// Min-heap on `end_ms` (reverse-ordered entries).
    heap: BinaryHeap<GpuRun>,
    /// Σ width of entries still in the heap.
    width_sum: f64,
}

impl GpuRuns {
    fn new() -> GpuRuns {
        GpuRuns { heap: BinaryHeap::new(), width_sum: 0.0 }
    }

    /// Lazily drop runs that ended at or before `now` (same boundary as
    /// the old `retain(|r| r.end_ms > now)`).
    fn expire(&mut self, now: Ms) {
        while let Some(top) = self.heap.peek() {
            if top.end_ms > now {
                break;
            }
            let run = self.heap.pop().unwrap();
            self.width_sum -= run.width;
        }
        if self.heap.is_empty() {
            self.width_sum = 0.0; // kill fp residue from the subtractions
        }
    }

    fn push(&mut self, end_ms: Ms, width: f64) {
        self.width_sum += width;
        self.heap.push(GpuRun { end_ms, width });
    }

    fn active_count(&self) -> usize {
        self.heap.len()
    }

    fn active_width(&self) -> f64 {
        self.width_sum
    }

    /// Exact Σ width over the heap — the reference the invariant engine
    /// audits the incremental `width_sum` against (the O(1) aggregate
    /// feeds the interference multiplier on every dispatch, so silent
    /// float drift here would skew every contended latency).
    fn recompute_width_sum(&self) -> f64 {
        self.heap.iter().map(|r| r.width).sum()
    }
}

/// Does the live group already run this assignment? Exact match keeps the
/// group untouched; so does the assignment plus trailing contended clones
/// the autoscaler added since the plan was cut (the autoscaler only ever
/// appends `temporal: None` tails) — a migration must not silently revert
/// a mid-surge scale-up of a pipeline the scheduler didn't even touch.
fn group_matches(g: &Group, a: &crate::coordinator::Assignment) -> bool {
    let cfg_matches = g.cfg.device == a.cfg.device
        && g.cfg.batch == a.cfg.batch
        && g.cfg.instances >= a.cfg.instances;
    cfg_matches
        && g.bindings.len() >= a.bindings.len()
        && g.bindings.len() == g.cfg.instances as usize
        && g.bindings.iter().zip(&a.bindings).all(|(x, y)| x.bit_eq(y))
        && g.bindings[a.bindings.len()..]
            .iter()
            .all(|b| b.temporal.is_none())
}

/// First occurrence of a duty-cycle slot at or after `now`.
fn next_occurrence(now: Ms, start_ms: Ms, duty_ms: Ms) -> Ms {
    let duty = duty_ms.max(1.0);
    if now <= start_ms {
        return start_ms;
    }
    let k = ((now - start_ms) / duty).ceil();
    start_ms + k * duty
}

pub struct SimPartition {
    kind: SchedulerKind,
    sched: Box<dyn Scheduler>,
    // Scenario data (owned copies; content processes are stateful).
    sc: ScenarioData,
    content: Vec<ContentDynamics>,
    links: Vec<FifoLink>,
    // Event machinery (time-source layer).
    events: EventWheel<Ev>,
    seq: u64,
    now: Ms,
    // Deployment.
    /// Dense per-(pipeline, model) state — indexed, not hashed,
    /// because every simulated event touches it.
    groups: Vec<Vec<Group>>,
    plan: Plan,
    /// Flat per-GPU state; `gpu_offset[device] + gpu` indexes both.
    gpu_offset: Vec<usize>,
    gpu_runs: Vec<GpuRuns>,
    gpu_busy_width_ms: Vec<f64>,
    /// Free-list of batch buffers recycled across `ExecDone` events so the
    /// dispatch hot path never heap-allocates in steady state.
    buf_pool: Vec<Vec<Query>>,
    // Metrics.
    metrics: RunMetrics,
    rng: Rng,
    minute_workload: f64,
    minute_effective: f64,
    /// Content-aware frontend: per-pipeline scene filter (`None` per slot
    /// when `cfg.frontend` is off). Each filter draws from its own forked
    /// RNG stream, so the filter decision sequence — and with it the
    /// workload fingerprint — is independent of scheduler and fault
    /// choices.
    frontend: Vec<Option<SceneFilter>>,
    interference: InterferenceModel,
    /// Monotone source of per-group deployment epochs (see `Group::epoch`).
    epoch_counter: u64,
    /// Replan policy: fixed 6-min rounds, or rounds plus drift triggers.
    mode: ReplanMode,
    /// Drift detector holding the active plan's envelope (drift mode).
    drift: DriftDetector,
    /// Shared autoscaler implementation — the same `decide` (thresholds
    /// AND cooldown hysteresis) the real `Controller.autoscaler` runs, so
    /// the sim path cannot silently diverge from it again.
    autoscaler: AutoScaler,
    /// Invariant engine (conformance runs only). `None` in normal runs, so
    /// every hook site is a single never-taken branch — see
    /// [`crate::sim::invariants`].
    checker: Option<Box<InvariantChecker>>,
    /// Trace sink, mirroring the checker's `Option`-flag pattern: `None`
    /// in plain runs, ring-only when the invariant engine arms the flight
    /// recorder, full when `--trace` asks for an export. A tracer
    /// observes, it never steers — hooks draw no RNG, push no events, and
    /// return nothing the engine branches on (see [`crate::obs`]).
    tracer: Option<Box<Tracer>>,
    /// Next query trace id (allocation order == event order).
    next_qid: u64,
    /// Exact repro string for flight-recorder dumps, when the caller knows
    /// it (fuzz replays). `None` falls back to a cfg-derived string.
    repro: Option<String>,
    // Fault injection (empty / all-zero unless cfg.faults > 0).
    /// Scheduled fault events, seeded into the heap at run start.
    faults: Vec<(Ms, FaultEv)>,
    /// Whether the control plane reacts to faults (crash/recover replans,
    /// post-outage catch-up). Off = pure graceful-degradation baseline.
    recovery: bool,
    crash_policy: CrashPolicy,
    /// Same-time event permutation seed (0 = insertion order).
    order_seed: u64,
    /// Per-device crash depth (overlapping windows nest safely).
    device_down: Vec<u32>,
    /// Active straggler windows as (flat gpu index, factor).
    stragglers: Vec<(usize, f64)>,
    /// Per-GPU latency multiplier — product of active straggler factors,
    /// recomputed from `stragglers` on every window edge so no float
    /// divide-residue accumulates.
    gpu_slow: Vec<f64>,
    outage_depth: u32,
    freeze_depth: u32,
    /// Telemetry snapshot captured when a freeze window opened.
    frozen_env: Option<(Vec<Vec<ModelObs>>, Vec<f64>)>,
    /// In-flight batches doomed by a device crash: their `ExecDone` events
    /// account the queries as `lost_to_fault` instead of completing them.
    doomed: Vec<(usize, usize, usize)>,
    /// Autoscale actions applied while the controller was out — their
    /// cooldowns are handed back if post-recovery replanning supersedes
    /// the stale-telemetry decision (redeploys the group).
    outage_scaled: Vec<(usize, usize)>,
    /// Recycled scheduler-environment buffers: `build_env` fills these,
    /// and each replan site hands them back once the scheduler returns,
    /// so steady-state control rounds reuse the telemetry rows.
    env_obs: Vec<Vec<ModelObs>>,
    env_bw: Vec<f64>,
    /// `dag.request_rates(1.0)` per pipeline — time-invariant structure,
    /// computed once (the telemetry fallback for thin arrival windows).
    structural_rates: Vec<Vec<f64>>,
}

/// Owned subset of `Scenario` the engine needs (the borrow-free core).
struct ScenarioData {
    cfg: crate::config::ExperimentConfig,
    cluster: crate::cluster::Cluster,
    profiles: crate::profiles::ProfileStore,
    pipelines: Vec<crate::pipeline::PipelineDag>,
    traces: Vec<crate::network::BwTrace>,
}

const QUEUE_CAP: usize = 1024;
const AUTOSCALE_PERIOD_MS: Ms = 10_000.0;
const TICK_MS: Ms = 60_000.0;
/// Seed tag for the frontend scene filters' dedicated RNG stream.
const FRONTEND_TAG: u64 = 0xF117E2;

impl SimPartition {
    pub fn new(scenario: &Scenario, kind: SchedulerKind) -> SimPartition {
        let sc = ScenarioData {
            cfg: scenario.cfg.clone(),
            cluster: scenario.cluster.clone(),
            profiles: scenario.profiles.clone(),
            pipelines: scenario.pipelines.clone(),
            traces: scenario.traces.clone(),
        };
        let links = sc
            .traces
            .iter()
            .map(|t| FifoLink::new(t.clone(), 20.0))
            .collect();
        let duration = sc.cfg.duration_ms;
        let mut gpu_offset = Vec::with_capacity(sc.cluster.devices.len());
        let mut n_gpus = 0;
        for d in &sc.cluster.devices {
            gpu_offset.push(n_gpus);
            n_gpus += d.gpus.len();
        }
        let structural_rates =
            sc.pipelines.iter().map(|d| d.request_rates(1.0)).collect();
        let mut front_rng = Rng::new(sc.cfg.seed ^ FRONTEND_TAG);
        let frontend = (0..sc.pipelines.len())
            .map(|i| {
                sc.cfg.frontend.then(|| {
                    SceneFilter::new(
                        sc.cfg.scene_static_frames,
                        front_rng.fork(i as u64),
                    )
                })
            })
            .collect();
        SimPartition {
            kind,
            sched: make_scheduler(kind, scenario.cfg.seed ^ 0xC0FFEE),
            content: scenario.content.clone(),
            links,
            events: EventWheel::new(),
            seq: 0,
            now: 0.0,
            groups: Vec::new(),
            plan: Plan::default(),
            gpu_offset,
            gpu_runs: (0..n_gpus).map(|_| GpuRuns::new()).collect(),
            gpu_busy_width_ms: vec![0.0; n_gpus],
            buf_pool: Vec::new(),
            metrics: RunMetrics::new(duration),
            rng: Rng::new(scenario.cfg.seed ^ 0x51A7ED),
            minute_workload: 0.0,
            minute_effective: 0.0,
            frontend,
            interference: InterferenceModel::default(),
            epoch_counter: 0,
            mode: scenario.cfg.replan,
            drift: DriftDetector::new(DriftParams::default()),
            autoscaler: AutoScaler::new(AutoScalerParams::default()),
            checker: None,
            tracer: None,
            next_qid: 0,
            repro: None,
            faults: if scenario.cfg.faults > 0 {
                FaultPlan::sample(
                    scenario.cfg.seed,
                    scenario.cfg.faults,
                    duration,
                    &scenario.cluster,
                    scenario.cfg.n_sources,
                )
                .events
            } else {
                Vec::new()
            },
            recovery: scenario.cfg.recovery,
            crash_policy: scenario.cfg.crash_policy,
            order_seed: scenario.cfg.order_seed,
            device_down: vec![0; scenario.cluster.devices.len()],
            stragglers: Vec::new(),
            gpu_slow: vec![1.0; n_gpus],
            outage_depth: 0,
            freeze_depth: 0,
            frozen_env: None,
            doomed: Vec::new(),
            outage_scaled: Vec::new(),
            env_obs: Vec::new(),
            env_bw: Vec::new(),
            structural_rates,
            sc,
        }
    }

    /// Override the sampled fault schedule (tests and targeted chaos runs).
    /// Must be called before `run`.
    pub fn set_fault_plan(&mut self, plan: FaultPlan) {
        self.faults = plan.events;
    }

    /// Arm the invariant engine before `run` (conformance/fuzz harness).
    /// Also arms the ring-only flight recorder, so every checked run has
    /// violation context for free.
    pub fn enable_invariants(&mut self) {
        self.checker = Some(Box::new(InvariantChecker::new()));
        self.enable_flight_recorder();
    }

    /// Take the invariant report after `run` (None unless enabled).
    pub fn take_invariant_report(&mut self) -> Option<InvariantReport> {
        self.checker.take().map(|c| c.into_report())
    }

    /// Arm the full tracer before `run` (`--trace`): every lifecycle event
    /// is retained for Chrome-trace export. Upgrades a ring-only recorder.
    pub fn enable_tracing(&mut self) {
        self.tracer = Some(Box::new(Tracer::new(TraceMode::Full)));
    }

    /// Arm the ring-only flight recorder (no-op when a tracer — either
    /// mode — is already armed; full mode feeds the ring too).
    pub fn enable_flight_recorder(&mut self) {
        if self.tracer.is_none() {
            self.tracer = Some(Box::new(Tracer::new(TraceMode::Ring)));
        }
    }

    /// Record the exact repro string for flight-recorder dumps (fuzz
    /// replays know it; ad-hoc runs fall back to a cfg-derived one).
    pub fn set_repro(&mut self, repro: String) {
        self.repro = Some(repro);
    }

    /// Take the full trace after `run` (empty unless `enable_tracing`).
    pub fn take_trace(&mut self) -> Vec<TraceEvent> {
        self.tracer
            .as_deref_mut()
            .map(Tracer::take_events)
            .unwrap_or_default()
    }

    /// Repro string identifying this run, mirroring the
    /// `fuzz:v1:seed=N[:...]` grammar from every axis the config carries.
    /// (The long-haul `:horizon=` modifier is class-level state the config
    /// does not record; fuzz replays pass the exact string via
    /// [`set_repro`](Self::set_repro) instead.)
    fn repro_string(&self) -> String {
        if let Some(r) = &self.repro {
            return r.clone();
        }
        let cfg = &self.sc.cfg;
        let mut s = format!("fuzz:v1:seed={}", cfg.seed);
        if cfg.replan != ReplanMode::Periodic {
            s.push_str(&format!(":replan={}", cfg.replan.label()));
        }
        if cfg.faults > 0 {
            s.push_str(&format!(":faults={}", cfg.faults));
        }
        if cfg.order_seed != 0 {
            s.push_str(&format!(":order={}", cfg.order_seed));
        }
        if cfg.clusters > 1 {
            s.push_str(&format!(":clusters={}", cfg.clusters));
        }
        s
    }

    /// The flight-recorder postmortem: `Some(dump)` when the invariant
    /// engine saw a violation, rendering the last ring of trace events
    /// with the repro string. Call after `run` (before taking the report).
    pub fn flight_dump(&self) -> Option<String> {
        let violated =
            self.checker.as_deref().is_some_and(InvariantChecker::has_violations);
        if !violated {
            return None;
        }
        let tr = self.tracer.as_deref()?;
        Some(tr.ring().dump(&self.repro_string()))
    }

    /// Allocate the next query trace id. Unconditional (tracing on or
    /// off), so ids never perturb behavior and traces from separate runs
    /// of one scenario line up query-for-query.
    #[inline]
    fn alloc_qid(&mut self) -> u64 {
        let q = self.next_qid;
        self.next_qid += 1;
        q
    }

    /// Stamp batch assembly on every query leaving a queue and emit the
    /// dispatch trace events: each query's queue span closes and its exec
    /// span opens, the batch mark lands on the GPU lane, and (contended
    /// dispatch only) the GPU width counter samples the post-dispatch
    /// active width.
    fn note_dispatch(
        &mut self,
        batch: &mut [Query],
        pipeline: usize,
        model: usize,
        gpu: usize,
        width: Option<f64>,
    ) {
        let now = self.now;
        for q in batch.iter_mut() {
            q.queue_ms += now - q.mark_ms;
            q.mark_ms = now;
        }
        if let Some(tr) = self.tracer.as_deref_mut() {
            for q in batch.iter() {
                tr.span(now, q.qid, SpanKind::Queue, Phase::End, pipeline, model);
                tr.span(now, q.qid, SpanKind::Exec, Phase::Begin, pipeline, model);
            }
            tr.batch(now, pipeline, model, gpu, batch.len());
            if let Some(w) = width {
                tr.gpu_width(now, gpu, w);
            }
        }
    }

    /// Queries still queued, inside a running batch, or in transit —
    /// everything the conservation invariant counts as in flight when the
    /// horizon cuts the run. Walks the remaining event wheel once.
    fn in_flight_census(&self) -> u64 {
        let mut n: u64 = self
            .groups
            .iter()
            .flatten()
            .map(|g| g.queue.len() as u64)
            .sum();
        for te in self.events.iter() {
            match &te.ev {
                Ev::Arrive { .. } => n += 1,
                Ev::ExecDone { queries, .. } => n += queries.len() as u64,
                _ => {}
            }
        }
        n
    }

    #[inline]
    fn gpu_idx(&self, g: GpuId) -> usize {
        self.gpu_offset[g.device] + g.gpu
    }

    fn push(&mut self, t: Ms, ev: Ev) {
        self.seq += 1;
        let tie = if self.order_seed == 0 {
            self.seq
        } else {
            mix64(self.seq ^ self.order_seed)
        };
        self.events.push(t, tie, self.seq, ev);
    }

    /// Build the scheduler environment: live telemetry, unless a freeze
    /// window is open — then the snapshot taken at freeze start (the
    /// control plane plans against lies). Device liveness is heartbeat-
    /// driven, not telemetry-driven, so crashed devices report zero
    /// bandwidth even under a freeze.
    fn build_env(&mut self) -> (Vec<Vec<ModelObs>>, Vec<f64>) {
        // Recycled buffers: the replan sites hand these back after the
        // scheduler returns (see `reschedule` and friends).
        let mut obs = std::mem::take(&mut self.env_obs);
        let mut bw = std::mem::take(&mut self.env_bw);
        match &self.frozen_env {
            Some((fo, fb)) => {
                obs.clone_from(fo);
                bw.clone_from(fb);
            }
            None => self.fill_live_env(&mut obs, &mut bw),
        }
        for (d, &down) in self.device_down.iter().enumerate() {
            if down > 0 {
                if let Some(b) = bw.get_mut(d) {
                    *b = 0.0;
                }
            }
        }
        (obs, bw)
    }

    /// Raw (unfrozen) observations and link bandwidths (allocating; the
    /// freeze snapshot is the one caller that keeps the buffers).
    fn live_env(&self) -> (Vec<Vec<ModelObs>>, Vec<f64>) {
        let mut obs = Vec::new();
        let mut bw = Vec::new();
        self.fill_live_env(&mut obs, &mut bw);
        (obs, bw)
    }

    /// Fill `obs`/`bw` with the live telemetry, reusing their rows.
    fn fill_live_env(&self, obs: &mut Vec<Vec<ModelObs>>, bw: &mut Vec<f64>) {
        obs.resize_with(self.sc.pipelines.len(), Vec::new);
        for (p, dag) in self.sc.pipelines.iter().enumerate() {
            let structural = &self.structural_rates[p];
            let row = &mut obs[p];
            row.clear();
            for m in 0..dag.len() {
                let g = self.groups.get(p).and_then(|r| r.get(m));
                let (rate, cv) = match g {
                    Some(g) if g.window.len() >= 10 => {
                        (g.window.rate_qps(), g.window.burstiness())
                    }
                    _ => (structural[m], if m == 0 { 0.1 } else { 1.2 }),
                };
                row.push(ModelObs { rate_qps: rate.max(0.05), burstiness: cv });
            }
        }
        bw.clear();
        bw.extend(self.sc.traces.iter().map(|t| t.bandwidth_mbps(self.now)));
    }

    /// Run the scheduler and (re)install the plan, preserving queues.
    /// `trigger` is trace-only provenance (what woke the control plane).
    fn reschedule(&mut self, trigger: PlanTrigger) {
        let (obs, bw) = self.build_env();
        let env = SchedEnv {
            cluster: &self.sc.cluster,
            profiles: &self.sc.profiles,
            pipelines: &self.sc.pipelines,
            obs,
            bw_mbps: bw,
            alpha: 1.2,
        };
        let plan = self.sched.plan(&env);
        // Rearm before installing: `install_plan` never reads the drift
        // state, and rearming while `env` is alive lets its buffers be
        // handed back for the next round.
        if self.mode == ReplanMode::Drift {
            self.drift.rearm(&plan, env.pipelines, &env.obs, &env.bw_mbps);
        }
        let path = self.sched.round_path();
        let SchedEnv { obs, bw_mbps, .. } = env;
        self.env_obs = obs;
        self.env_bw = bw_mbps;
        let migrations = self.install_plan(plan);
        if let Some(tr) = self.tracer.as_deref_mut() {
            tr.plan(self.now, trigger, path, migrations);
        }
    }

    /// Drift-mode check: if live rates or link bandwidth left the active
    /// plan's envelope, incrementally replan just the drifted pipelines.
    fn drift_check(&mut self) {
        let (obs, bw) = self.build_env();
        let drifted = self.drift.check(self.now, &obs, &bw);
        if drifted.is_empty() {
            self.env_obs = obs;
            self.env_bw = bw;
            return;
        }
        let env = SchedEnv {
            cluster: &self.sc.cluster,
            profiles: &self.sc.profiles,
            pipelines: &self.sc.pipelines,
            obs,
            bw_mbps: bw,
            alpha: 1.2,
        };
        let plan = self.sched.replan(&env, &self.plan, &drifted);
        let path = self.sched.round_path();
        self.drift.rearm(&plan, env.pipelines, &env.obs, &env.bw_mbps);
        let SchedEnv { obs, bw_mbps, .. } = env;
        self.env_obs = obs;
        self.env_bw = bw_mbps;
        let migrations = self.install_plan(plan);
        if let Some(tr) = self.tracer.as_deref_mut() {
            tr.plan(self.now, PlanTrigger::Drift, path, migrations);
        }
    }

    /// Failure-aware replan: let the scheduler re-place work around the
    /// crashed (or just-recovered) device, installing via the same
    /// plan-diff migration as every other swap — unaffected groups keep
    /// their queues and clocks bit-for-bit.
    fn fault_replan(&mut self, device: usize) {
        let (obs, bw) = self.build_env();
        let env = SchedEnv {
            cluster: &self.sc.cluster,
            profiles: &self.sc.profiles,
            pipelines: &self.sc.pipelines,
            obs,
            bw_mbps: bw,
            alpha: 1.2,
        };
        let plan = self.sched.on_fault(&env, &self.plan, device);
        let path = self.sched.round_path();
        if self.mode == ReplanMode::Drift {
            self.drift.rearm(&plan, env.pipelines, &env.obs, &env.bw_mbps);
        }
        let SchedEnv { obs, bw_mbps, .. } = env;
        self.env_obs = obs;
        self.env_bw = bw_mbps;
        let migrations = self.install_plan(plan);
        if let Some(tr) = self.tracer.as_deref_mut() {
            tr.plan(self.now, PlanTrigger::Fault, path, migrations);
        }
    }

    /// Account `n` queries destroyed by a fault (metrics + checker move
    /// together — the invariant engine reconciles them exactly).
    fn lose_to_fault(&mut self, n: u64) {
        if n == 0 {
            return;
        }
        self.metrics.lost_to_fault += n;
        if let Some(c) = self.checker.as_deref_mut() {
            c.on_lost(n);
        }
    }

    /// Recompute a GPU's slowdown as the product of its active straggler
    /// windows (rebuilt from scratch so window exits leave no residue).
    fn recompute_gpu_slow(&mut self, gi: usize) {
        self.gpu_slow[gi] = self
            .stragglers
            .iter()
            .filter(|(g, _)| *g == gi)
            .map(|(_, f)| f)
            .product();
    }

    fn on_fault_event(&mut self, ev: FaultEv) {
        match ev {
            FaultEv::DeviceCrash { device } => {
                self.device_down[device] += 1;
                if self.device_down[device] > 1 {
                    return; // nested window: already down
                }
                // In-flight batches on the device die with it; their
                // pending ExecDone events account the queries as lost.
                for row in &self.groups {
                    for g in row {
                        if g.cfg.device != device {
                            continue;
                        }
                        for (bi, &busy) in g.busy.iter().enumerate() {
                            if busy {
                                self.doomed.push((g.pipeline, g.model, bi));
                            }
                        }
                    }
                }
                if self.crash_policy == CrashPolicy::Drop {
                    let now = self.now;
                    let mut lost = 0u64;
                    for p in 0..self.groups.len() {
                        for m in 0..self.groups[p].len() {
                            let g = &mut self.groups[p][m];
                            if g.cfg.device == device {
                                lost += g.queue.len() as u64;
                                if let Some(tr) = self.tracer.as_deref_mut() {
                                    for q in &g.queue {
                                        tr.span(now, q.qid, SpanKind::Queue, Phase::End, p, m);
                                        tr.mark(now, q.qid, MarkKind::Lost, p, m);
                                    }
                                }
                                g.queue.clear();
                                g.flush_at = None;
                            }
                        }
                    }
                    self.lose_to_fault(lost);
                }
                if self.recovery && self.outage_depth == 0 {
                    self.fault_replan(device);
                }
            }
            FaultEv::DeviceRecover { device } => {
                if self.device_down[device] == 0 {
                    return; // unmatched end (window started before t=0)
                }
                self.device_down[device] -= 1;
                if self.device_down[device] > 0 {
                    return;
                }
                if self.recovery && self.outage_depth == 0 {
                    self.fault_replan(device);
                }
                // Kick every group with queued work: flush timers that
                // fired into a dead device left queues with no pending
                // trigger, and migrated-back groups should drain now.
                for p in 0..self.groups.len() {
                    for m in 0..self.groups[p].len() {
                        if !self.groups[p][m].queue.is_empty() {
                            self.try_dispatch(p, m);
                        }
                    }
                }
            }
            FaultEv::StragglerStart { device, gpu, factor } => {
                let gi = self.gpu_offset[device] + gpu;
                self.stragglers.push((gi, factor));
                self.recompute_gpu_slow(gi);
            }
            FaultEv::StragglerEnd { device, gpu, factor } => {
                let gi = self.gpu_offset[device] + gpu;
                if let Some(pos) = self
                    .stragglers
                    .iter()
                    .position(|&(g, f)| g == gi && f == factor)
                {
                    self.stragglers.remove(pos);
                    self.recompute_gpu_slow(gi);
                }
            }
            FaultEv::ControllerOutageStart => {
                self.outage_depth += 1;
            }
            FaultEv::ControllerOutageEnd => {
                self.outage_depth = self.outage_depth.saturating_sub(1);
                if self.outage_depth == 0 && self.recovery {
                    // Catch-up round: replan against everything that
                    // happened while the controller was dark.
                    self.reschedule(PlanTrigger::CatchUp);
                }
            }
            FaultEv::TelemetryFreezeStart => {
                self.freeze_depth += 1;
                if self.freeze_depth == 1 {
                    self.frozen_env = Some(self.live_env());
                }
            }
            FaultEv::TelemetryFreezeEnd => {
                self.freeze_depth = self.freeze_depth.saturating_sub(1);
                if self.freeze_depth == 0 {
                    self.frozen_env = None;
                }
            }
        }
    }

    /// Install a plan by diffing it against the live deployment: groups
    /// whose configuration and bindings are unchanged keep everything —
    /// queues, arrival windows, busy flags, and pending `Portion` clocks —
    /// while changed groups are re-deployed under a fresh epoch. Queues
    /// and windows always survive (in-flight work continues across a
    /// swap); the invariant hook asserts the migration neither lost nor
    /// double-counted a single in-flight query. Returns the number of
    /// groups actually re-deployed (the migration count on Plan trace
    /// events).
    fn install_plan(&mut self, plan: Plan) -> usize {
        let migrating = !self.plan.assignments.is_empty();
        let census_before = (self.checker.is_some() && migrating)
            .then(|| self.in_flight_census());
        if let Some(c) = self.checker.as_deref_mut() {
            c.on_plan(&plan, &self.sc.cluster, &self.sc.pipelines);
        }
        let mem = plan.total_memory_mb(&self.sc.pipelines);
        self.metrics.peak_memory_mb = self.metrics.peak_memory_mb.max(mem);
        if self.groups.is_empty() {
            self.groups = self
                .sc
                .pipelines
                .iter()
                .enumerate()
                .map(|(p, dag)| {
                    (0..dag.len())
                        .map(|m| Group {
                            pipeline: p,
                            model: m,
                            cfg: StageCfg { device: 0, batch: 1, instances: 0 },
                            bindings: Vec::new(),
                            busy: Vec::new(),
                            queue: VecDeque::new(),
                            window: ArrivalWindow::new(60_000.0),
                            flush_at: None,
                            epoch: 0,
                        })
                        .collect()
                })
                .collect();
        }
        let mut ticks = Vec::new();
        let mut changed: Vec<(usize, usize)> = Vec::new();
        for a in &plan.assignments {
            if group_matches(&self.groups[a.pipeline][a.model], a) {
                continue; // live migration: nothing to redeploy
            }
            changed.push((a.pipeline, a.model));
            self.epoch_counter += 1;
            let epoch = self.epoch_counter;
            let entry = &mut self.groups[a.pipeline][a.model];
            entry.cfg = a.cfg;
            entry.bindings = a.bindings.clone();
            // Queue and window survive rescheduling (containers are
            // re-deployed, in-flight work continues) — and so do busy
            // flags, index-carried: a binding mid-execution keeps its slot
            // occupied until its ExecDone lands, otherwise every migration
            // would let one instance run overlapping batches and model
            // phantom capacity exactly while drift replans fire.
            let mut busy = std::mem::take(&mut entry.busy);
            busy.resize(a.bindings.len(), false);
            entry.busy = busy;
            entry.epoch = epoch;
            for (bi, b) in entry.bindings.iter().enumerate() {
                if let Some(slot) = b.temporal {
                    let t =
                        next_occurrence(self.now, slot.start_ms, slot.duty_cycle_ms);
                    ticks.push((t, a.pipeline, a.model, bi, epoch));
                }
            }
        }
        let n_migrated = changed.len();
        self.plan = plan;
        // Scale decisions taken on stale telemetry during a controller
        // outage hand their cooldown back once post-recovery replanning
        // supersedes them (redeploys the group) — otherwise the phantom
        // action would suppress the next legitimate scale-up for 25 s.
        if self.outage_depth == 0 && !self.outage_scaled.is_empty() {
            for key in std::mem::take(&mut self.outage_scaled) {
                if changed.contains(&key) {
                    self.autoscaler.cancel(key);
                }
            }
        }
        // Seed portion clocks for the re-deployed reserved instances only.
        for (t, p, m, bi, epoch) in ticks {
            self.push(t, Ev::Portion { pipeline: p, model: m, binding: bi, epoch });
        }
        if let Some(before) = census_before {
            let after = self.in_flight_census();
            if let Some(c) = self.checker.as_deref_mut() {
                c.on_plan_swap(before, after);
            }
        }
        n_migrated
    }

    /// Execute one duty-cycle occurrence of a reserved instance.
    fn portion_tick(&mut self, pipeline: usize, model: usize, binding: usize) {
        let now = self.now;
        let g = &mut self.groups[pipeline][model];
        let Some(b) = g.bindings.get(binding).copied() else { return };
        let Some(slot) = b.temporal else { return };
        // Re-arm the clock first (under the group's current epoch), so
        // the duty cycle survives a crash window and resumes on recovery.
        let next = now + slot.duty_cycle_ms.max(1.0);
        let epoch = g.epoch;
        self.push(next, Ev::Portion { pipeline, model, binding, epoch });

        let g = &mut self.groups[pipeline][model];
        if self.device_down[g.cfg.device] > 0 {
            return; // device dark: the portion fires into the void
        }
        if g.busy[binding] {
            return; // previous batch overran its cycle
        }
        // Lazy-drop late queries, then take up to one batch.
        let mut dropped = 0u64;
        while let Some(q) = g.queue.front().copied() {
            if q.deadline_ms >= now {
                break;
            }
            g.queue.pop_front();
            dropped += 1;
            if let Some(tr) = self.tracer.as_deref_mut() {
                tr.span(now, q.qid, SpanKind::Queue, Phase::End, pipeline, model);
                tr.mark(now, q.qid, MarkKind::Drop, pipeline, model);
            }
        }
        let take = g.cfg.batch.min(g.queue.len() as u32) as usize;
        if take > 0 {
            g.busy[binding] = true;
        }
        let cfg = g.cfg;
        self.metrics.record_n(Outcome::Dropped, 0.0, dropped);
        if let Some(c) = self.checker.as_deref_mut() {
            c.on_drop(dropped);
        }
        if take == 0 {
            return; // idle cycle: GPU time returned (temporal sharing win)
        }
        if let Some(c) = self.checker.as_deref_mut() {
            c.on_batch(take, cfg.batch);
        }
        let mut batch = self.buf_pool.pop().unwrap_or_default();
        batch.extend(self.groups[pipeline][model].queue.drain(..take));
        let gi = self.gpu_idx(b.gpu);
        self.note_dispatch(&mut batch, pipeline, model, gi, None);
        let spec = &self.sc.pipelines[pipeline].models[model].spec;
        let class = self.sc.cluster.device(cfg.device).class;
        // Reservation: interference-free — but a hardware straggler slows
        // even reserved portions (the fault is below the scheduler).
        let dur = self.sc.profiles.batch_latency(spec, class, cfg.batch)
            * self.gpu_slow[gi];
        let end = now + dur;
        self.gpu_busy_width_ms[gi] += dur * b.width;
        self.push(end, Ev::ExecDone { pipeline, model, binding, queries: batch });
    }

    /// Autoscaler tick (OctopInf variants only, §III-D).
    fn autoscale(&mut self) {
        if !matches!(
            self.kind,
            SchedulerKind::OctopInf
                | SchedulerKind::OctopInfNoCoral
                | SchedulerKind::OctopInfStaticBatch
                | SchedulerKind::OctopInfServerOnly
        ) {
            return;
        }
        let keys: Vec<(usize, usize)> = (0..self.groups.len())
            .flat_map(|p| (0..self.groups[p].len()).map(move |m| (p, m)))
            .collect();
        for key in keys {
            let (rate, cap, instances) = {
                let g = &self.groups[key.0][key.1];
                (g.window.rate_qps(), g.capacity_qps(&self.sc), g.cfg.instances)
            };
            // One hysteresis implementation for both worlds: this is the
            // same `AutoScaler::decide` the real `Controller.autoscaler`
            // runs — thresholds AND the cooldown (the inline reimplementation
            // this replaced silently dropped the cooldown, letting the sim
            // autoscaler flap on every 10 s tick).
            let action = self.autoscaler.decide(key, self.now, rate, cap, instances);
            let g = &mut self.groups[key.0][key.1];
            // Track whether the decision was actually applied: a rejected
            // action must hand its cooldown back (`AutoScaler::cancel`) or
            // a phantom Down would suppress the next legitimate scale-up.
            let mut applied = true;
            match action {
                ScaleAction::Up => {
                    if let Some(last) = g.bindings.last().copied() {
                        g.cfg.instances += 1;
                        // Clone runs contended until the next CORAL round.
                        g.bindings.push(crate::coordinator::GpuBinding {
                            temporal: None,
                            ..last
                        });
                        g.busy.push(false);
                    } else {
                        applied = false;
                    }
                }
                ScaleAction::Down => {
                    // Scale-in must not shift binding indices: pending
                    // Portion events address reserved instances by index,
                    // so removing from the middle re-aims their duty-cycle
                    // clocks at the wrong binding (or none, starving the
                    // queue). Up appends contended clones at the tail, so
                    // Down only pops the tail — and only when it is idle
                    // and unreserved.
                    let last = g.bindings.len().wrapping_sub(1);
                    if g.bindings.len() > 1
                        && g.cfg.instances > 1
                        && !g.busy[last]
                        && g.bindings[last].temporal.is_none()
                    {
                        g.cfg.instances -= 1;
                        g.bindings.pop();
                        g.busy.pop();
                    } else {
                        applied = false;
                    }
                }
                ScaleAction::Hold => {}
            }
            if !applied {
                self.autoscaler.cancel(key);
            } else if self.outage_depth > 0
                && !matches!(action, ScaleAction::Hold)
            {
                // Applied on stale telemetry while the controller was out:
                // remember the key so post-recovery replanning can hand
                // the cooldown back if it supersedes this decision.
                self.outage_scaled.push(key);
            }
        }
    }

    /// Max time a query may wait in this stage's batcher before flushing.
    ///
    /// OctopInf bounds waiting SLO-awarely (its contended clones flush at
    /// SLO/(2·depth); reserved instances are portion-clocked anyway). The
    /// baselines run their published policy — wait for the static batch to
    /// fill, give up only near the SLO — which is exactly the "clunky
    /// latency chunks" failure mode of §IV-C4.
    fn max_wait_ms(&self, pipeline: usize, _model: usize) -> Ms {
        let dag = &self.sc.pipelines[pipeline];
        match self.kind {
            SchedulerKind::OctopInf
            | SchedulerKind::OctopInfNoCoral
            | SchedulerKind::OctopInfStaticBatch
            | SchedulerKind::OctopInfServerOnly => {
                dag.slo_ms / (2.0 * dag.depth().max(1) as f64)
            }
            SchedulerKind::Distream
            | SchedulerKind::Jellyfish
            | SchedulerKind::Rim => dag.slo_ms / 2.0,
        }
    }

    fn arrive(&mut self, pipeline: usize, model: usize, mut query: Query) {
        let now = self.now;
        // The uplink transfer ends at the arrival stamp; queue wait begins.
        query.transfer_ms += now - query.mark_ms;
        query.mark_ms = now;
        let max_wait = self.max_wait_ms(pipeline, model);
        let g = &mut self.groups[pipeline][model];
        g.window.record(now);
        let overflow = g.queue.len() >= QUEUE_CAP;
        let victim = if overflow {
            self.metrics.record(Outcome::Dropped, 0.0);
            g.queue.pop_front()
        } else {
            None
        };
        if let Some(tr) = self.tracer.as_deref_mut() {
            tr.span(now, query.qid, SpanKind::Transfer, Phase::End, pipeline, model);
            tr.span(now, query.qid, SpanKind::Queue, Phase::Begin, pipeline, model);
            if let Some(v) = &victim {
                tr.span(now, v.qid, SpanKind::Queue, Phase::End, pipeline, model);
                tr.mark(now, v.qid, MarkKind::Drop, pipeline, model);
            }
        }
        g.queue.push_back(query);
        let full = g.queue.len() >= g.cfg.batch as usize;
        let need_timer = g.flush_at.is_none();
        let depth = g.queue.len();
        if let Some(c) = self.checker.as_deref_mut() {
            if overflow {
                c.on_drop(1);
            }
            c.on_queue_depth(depth, QUEUE_CAP);
        }
        if full {
            // Full batches get immediate service: contended instances
            // dispatch normally; reserved ones stack an extra portion into
            // their stream's free time (§III-C2 gap minimization).
            let reserved_idle: Option<usize> = {
                let g = &self.groups[pipeline][model];
                g.bindings
                    .iter()
                    .enumerate()
                    .position(|(i, b)| b.temporal.is_some() && !g.busy[i])
            };
            if let Some(bi) = reserved_idle {
                self.chain_reserved(pipeline, model, bi);
            }
            self.try_dispatch(pipeline, model);
        } else if need_timer {
            let t = now + max_wait;
            self.groups[pipeline][model].flush_at = Some(t);
            self.push(t, Ev::Flush { pipeline, model });
        }
    }

    /// Attempt to dispatch batches while a free instance and work exist.
    fn try_dispatch(&mut self, pipeline: usize, model: usize) {
        loop {
            let now = self.now;
            let g = &mut self.groups[pipeline][model];
            if g.queue.is_empty() {
                return;
            }
            if self.device_down[g.cfg.device] > 0 {
                return; // device dark: queue holds for reroute/recovery
            }
            // Only contended (non-reserved) instances dispatch here;
            // CORAL-reserved instances are driven by Portion events.
            let Some(binding_idx) = g
                .bindings
                .iter()
                .enumerate()
                .position(|(i, b)| !g.busy[i] && b.temporal.is_none())
            else {
                return; // all eligible instances busy (or all reserved)
            };
            // Lazy dropping: discard queries already past their deadline.
            let mut dropped = 0u64;
            while let Some(q) = g.queue.front().copied() {
                if q.deadline_ms >= now {
                    break;
                }
                g.queue.pop_front();
                dropped += 1;
                if let Some(tr) = self.tracer.as_deref_mut() {
                    tr.span(now, q.qid, SpanKind::Queue, Phase::End, pipeline, model);
                    tr.mark(now, q.qid, MarkKind::Drop, pipeline, model);
                }
            }
            let empty = g.queue.is_empty();
            self.metrics.record_n(Outcome::Dropped, 0.0, dropped);
            if let Some(c) = self.checker.as_deref_mut() {
                c.on_drop(dropped);
            }
            if empty {
                return;
            }
            let g = &mut self.groups[pipeline][model];
            let take = g.cfg.batch.min(g.queue.len() as u32) as usize;
            // Not full yet: wait for the flush timer unless it already fired.
            if take < g.cfg.batch as usize {
                if let Some(t) = g.flush_at {
                    if t > now {
                        return;
                    }
                }
            }
            let mut batch = self.buf_pool.pop().unwrap_or_default();
            let g = &mut self.groups[pipeline][model];
            batch.extend(g.queue.drain(..take));
            g.flush_at = None;
            g.busy[binding_idx] = true;
            let binding = g.bindings[binding_idx];
            let cfg = g.cfg;
            if let Some(c) = self.checker.as_deref_mut() {
                c.on_batch(batch.len(), cfg.batch);
            }

            // Execution timing.
            let spec = &self.sc.pipelines[pipeline].models[model].spec;
            let class = self.sc.cluster.device(cfg.device).class;
            let base_lat = self.sc.profiles.batch_latency(spec, class, cfg.batch);
            let cap = 1.0; // util_cap of every GPU in this build
            let gi = self.gpu_idx(binding.gpu);
            let runs = &mut self.gpu_runs[gi];
            runs.expire(now);
            if let Some(c) = self.checker.as_deref_mut() {
                c.on_width_sum(runs.active_width(), runs.recompute_width_sum());
            }
            let total = runs.active_width() + binding.width;
            let mult =
                self.interference.multiplier(total, cap, runs.active_count());
            // Straggler windows compose multiplicatively with interference.
            let dur = base_lat * mult * self.gpu_slow[gi];
            let end = now + dur;
            runs.push(end, binding.width);
            self.gpu_busy_width_ms[gi] += dur * binding.width;
            self.note_dispatch(&mut batch, pipeline, model, gi, Some(total));
            self.push(
                end,
                Ev::ExecDone { pipeline, model, binding: binding_idx, queries: batch },
            );
        }
    }

    /// A reserved instance with a *full* batch queued may immediately run
    /// again in its stream's free time — CORAL "stacks execution portions
    /// one after another to minimize gaps, which waste resources"
    /// (§III-C2). Partial batches still wait for the next duty tick.
    fn chain_reserved(&mut self, pipeline: usize, model: usize, binding: usize) {
        let now = self.now;
        let g = &mut self.groups[pipeline][model];
        let Some(b) = g.bindings.get(binding).copied() else { return };
        if b.temporal.is_none() || binding >= g.busy.len() || g.busy[binding] {
            return;
        }
        if self.device_down[g.cfg.device] > 0 {
            return; // device dark
        }
        if g.queue.len() < g.cfg.batch as usize {
            return;
        }
        let take = g.cfg.batch as usize;
        let mut batch = self.buf_pool.pop().unwrap_or_default();
        let g = &mut self.groups[pipeline][model];
        batch.extend(g.queue.drain(..take));
        g.busy[binding] = true;
        let cfg = g.cfg;
        if let Some(c) = self.checker.as_deref_mut() {
            c.on_batch(batch.len(), cfg.batch);
        }
        let gi = self.gpu_idx(b.gpu);
        self.note_dispatch(&mut batch, pipeline, model, gi, None);
        let spec = &self.sc.pipelines[pipeline].models[model].spec;
        let class = self.sc.cluster.device(cfg.device).class;
        let dur = self.sc.profiles.batch_latency(spec, class, cfg.batch)
            * self.gpu_slow[gi];
        let end = now + dur;
        self.gpu_busy_width_ms[gi] += dur * b.width;
        self.push(end, Ev::ExecDone { pipeline, model, binding, queries: batch });
    }

    fn exec_done(
        &mut self,
        pipeline: usize,
        model: usize,
        binding: usize,
        mut queries: Vec<Query>,
    ) {
        let now = self.now;
        {
            let g = &mut self.groups[pipeline][model];
            if binding < g.busy.len() {
                g.busy[binding] = false;
            }
        }
        // The execution segment ends here for every query in the batch —
        // doomed or not, the exec span closes at the batch end stamp.
        for q in queries.iter_mut() {
            q.exec_ms += now - q.mark_ms;
            q.mark_ms = now;
        }
        if let Some(tr) = self.tracer.as_deref_mut() {
            for q in queries.iter() {
                tr.span(now, q.qid, SpanKind::Exec, Phase::End, pipeline, model);
            }
        }
        // A batch doomed by a device crash: the queries died with the
        // hardware — account them as lost (never silently vanished) and
        // free the instance slot without routing or completing anything.
        if let Some(pos) = self
            .doomed
            .iter()
            .position(|&(p, m, b)| p == pipeline && m == model && b == binding)
        {
            self.doomed.remove(pos);
            self.lose_to_fault(queries.len() as u64);
            if let Some(tr) = self.tracer.as_deref_mut() {
                for q in queries.iter() {
                    tr.mark(now, q.qid, MarkKind::Lost, pipeline, model);
                }
            }
            if self.buf_pool.len() < 64 {
                queries.clear();
                self.buf_pool.push(queries);
            }
            self.chain_reserved(pipeline, model, binding);
            self.try_dispatch(pipeline, model);
            return;
        }
        let dag = &self.sc.pipelines[pipeline];
        let slo = dag.slo_ms;
        let downstream = dag.models[model].downstream.clone();
        let routing = dag.models[model].routing.clone();
        let group_dev =
            self.groups[pipeline][model].cfg.device;

        if downstream.is_empty() {
            // Sink: account one completion per carried object (bulk — one
            // metrics update per query, not per object).
            for q in &queries {
                let latency = now - q.created_ms;
                let n = q.objects.max(1) as u64;
                let on_time = latency <= slo;
                if on_time {
                    self.minute_effective += n as f64;
                }
                let outcome = if on_time { Outcome::OnTime } else { Outcome::Late };
                self.metrics.record_n(outcome, latency, n);
                // Attribution: the lifecycle segments telescoped over the
                // whole pipeline; fold the fp residue of the adds into the
                // exec component so transfer + queue + exec == latency
                // bit-for-bit (the invariant engine asserts it).
                let exec = close_exact(latency, q.transfer_ms, q.queue_ms, q.exec_ms);
                self.metrics.record_attrib(q.transfer_ms, q.queue_ms, exec, n, !on_time);
                if let Some(c) = self.checker.as_deref_mut() {
                    c.on_sink(latency, n, on_time, slo);
                    c.on_attrib(q.transfer_ms, q.queue_ms, exec, latency, n);
                }
                if let Some(tr) = self.tracer.as_deref_mut() {
                    tr.mark(now, q.qid, MarkKind::Sink, pipeline, model);
                }
            }
        } else {
            // Route objects to downstream stages. The parent query
            // terminates here (consumed by the router); each routed
            // object becomes a freshly-created child query.
            for q in &queries {
                if let Some(c) = self.checker.as_deref_mut() {
                    c.on_routed();
                }
                let n_objects = q.objects as usize;
                for _ in 0..n_objects {
                    // Choose downstream by routing fraction.
                    let x = self.rng.f64();
                    let mut acc = 0.0;
                    let mut chosen = None;
                    for (i, &frac) in routing.iter().enumerate() {
                        acc += frac;
                        if x < acc {
                            chosen = Some(downstream[i]);
                            break;
                        }
                    }
                    let Some(d) = chosen else {
                        // Unrouted residue (routing fractions sum < 1).
                        if let Some(c) = self.checker.as_deref_mut() {
                            c.on_vanish();
                        }
                        continue;
                    };
                    if let Some(c) = self.checker.as_deref_mut() {
                        c.on_spawn();
                    }
                    // The child inherits the parent's accumulated segments
                    // (end-to-end attribution spans the whole pipeline) and
                    // restarts the clock here: the routing hop is transfer.
                    let next = Query {
                        created_ms: q.created_ms,
                        deadline_ms: q.deadline_ms,
                        objects: 1,
                        qid: self.alloc_qid(),
                        mark_ms: now,
                        transfer_ms: q.transfer_ms,
                        queue_ms: q.queue_ms,
                        exec_ms: q.exec_ms,
                    };
                    let dst_dev = self.groups[pipeline][d].cfg.device;
                    let arrive_t = self.transfer_time(
                        group_dev,
                        dst_dev,
                        self.sc.pipelines[pipeline].models[d].spec.input_bytes,
                    );
                    if arrive_t.is_finite() {
                        if let Some(tr) = self.tracer.as_deref_mut() {
                            tr.span(now, next.qid, SpanKind::Transfer, Phase::Begin, pipeline, d);
                        }
                        self.push(arrive_t, Ev::Arrive { pipeline, model: d, query: next });
                    } else {
                        self.metrics.record(Outcome::Dropped, 0.0);
                        if let Some(c) = self.checker.as_deref_mut() {
                            c.on_drop(1);
                        }
                        if let Some(tr) = self.tracer.as_deref_mut() {
                            tr.mark(now, next.qid, MarkKind::Drop, pipeline, d);
                        }
                    }
                }
            }
        }
        // Recycle the batch buffer into the free-list (bounded so a burst
        // of in-flight batches can't pin memory forever).
        if self.buf_pool.len() < 64 {
            queries.clear();
            self.buf_pool.push(queries);
        }
        // Free instance may pick up queued work: reserved instances chain
        // full batches into stream gaps; contended ones dispatch normally.
        self.chain_reserved(pipeline, model, binding);
        self.try_dispatch(pipeline, model);
    }

    /// Absolute arrival time for a payload sent now between devices.
    fn transfer_time(&mut self, from: usize, to: usize, bytes: f64) -> Ms {
        if from == to {
            return self.now + crate::network::LOCAL_TRANSFER_MS;
        }
        let edge = if from == 0 { to } else { from };
        self.links[edge].send(self.now, bytes)
    }

    fn frame(&mut self, pipeline: usize) {
        let now = self.now;
        let dag = &self.sc.pipelines[pipeline];
        let fps = dag.source_fps;
        let slo = dag.slo_ms;
        let src = dag.source_device;
        let det_bytes = dag.models[0].spec.input_bytes;
        let objects = self.content[pipeline].objects_in_frame(now);
        self.minute_workload += objects as f64;
        // Content-aware frontend: the scene filter advances EVERY frame (its
        // dedicated RNG stream keeps the decision sequence independent of
        // scheduler and fault choices), but a dead source wins — a frame the
        // camera cannot ship is lost, never "filtered".
        let scene_static = self.frontend[pipeline]
            .as_mut()
            .map_or(false, |f| f.filter_frame());
        if scene_static && self.device_down[src] == 0 {
            // The frontend answers the frame from the previous result: the
            // objects count toward the effective timeline and
            // `RunMetrics::filtered` (min 1 unit — an empty static frame is
            // still an answered frame), but no query is ever created.
            let units = (objects as u64).max(1);
            self.minute_effective += units as f64;
            self.metrics.record_filtered(units);
            if let Some(c) = self.checker.as_deref_mut() {
                c.on_filtered_frame(objects, units);
            }
            self.push(now + 1000.0 / fps, Ev::Frame { pipeline });
            return;
        }
        if let Some(c) = self.checker.as_deref_mut() {
            c.on_frame(objects);
        }
        let q = Query {
            created_ms: now,
            deadline_ms: now + slo,
            objects: objects.min(u16::MAX as u32) as u16,
            qid: self.alloc_qid(),
            mark_ms: now,
            transfer_ms: 0.0,
            queue_ms: 0.0,
            exec_ms: 0.0,
        };
        // A dead source device still captures frames (the camera is a
        // separate box) but cannot ship them: the query is lost at birth.
        // Counting the frame first keeps frames/objects — the
        // scheduler-independent fingerprint — identical across schedulers
        // and across fault policies.
        if self.device_down[src] > 0 {
            if let Some(tr) = self.tracer.as_deref_mut() {
                tr.mark(now, q.qid, MarkKind::Capture, pipeline, 0);
                tr.mark(now, q.qid, MarkKind::Lost, pipeline, 0);
            }
            self.lose_to_fault(1);
            self.push(now + 1000.0 / fps, Ev::Frame { pipeline });
            return;
        }
        let det_dev =
            self.groups[pipeline][0].cfg.device;
        let arrive_t = self.transfer_time(src, det_dev, det_bytes);
        if arrive_t.is_finite() {
            if let Some(tr) = self.tracer.as_deref_mut() {
                tr.mark(now, q.qid, MarkKind::Capture, pipeline, 0);
                tr.span(now, q.qid, SpanKind::Transfer, Phase::Begin, pipeline, 0);
            }
            self.push(arrive_t, Ev::Arrive { pipeline, model: 0, query: q });
        } else {
            self.metrics.record(Outcome::Dropped, 0.0);
            if let Some(c) = self.checker.as_deref_mut() {
                c.on_drop(1);
            }
            if let Some(tr) = self.tracer.as_deref_mut() {
                tr.mark(now, q.qid, MarkKind::Capture, pipeline, 0);
                tr.mark(now, q.qid, MarkKind::Drop, pipeline, 0);
            }
        }
        // Next frame.
        self.push(now + 1000.0 / fps, Ev::Frame { pipeline });
    }

    /// Install the initial plan and seed every event stream (frame
    /// sources, control-plane clocks, the fault schedule). Called exactly
    /// once, before the first `tick`.
    pub fn start(&mut self) {
        self.reschedule(PlanTrigger::Initial);
        for p in 0..self.sc.pipelines.len() {
            // Stagger sources a little so frames don't align pathologically.
            let jitter = (p as f64) * 7.0;
            self.push(jitter, Ev::Frame { pipeline: p });
        }
        self.push(SCHEDULING_PERIOD_MS, Ev::Reschedule);
        self.push(AUTOSCALE_PERIOD_MS, Ev::AutoScale);
        if self.mode == ReplanMode::Drift {
            self.push(self.drift.params.check_period_ms, Ev::DriftCheck);
        }
        self.push(TICK_MS, Ev::Tick);
        // Injected fault schedule (empty unless faults are armed, so the
        // default event stream — and seq numbering — is untouched).
        let fault_events = std::mem::take(&mut self.faults);
        for &(t, fe) in &fault_events {
            self.push(t, Ev::Fault(fe));
        }
        self.faults = fault_events;
    }

    /// Advance the partition through every event with `t <= until` — the
    /// component-layer tick the driver calls between epoch barriers.
    /// Events beyond `until` stay queued (the conservation census still
    /// sees their in-flight queries), so slicing a run into any sequence
    /// of increasing `until`s pops the same events in the same order as
    /// one pass to the horizon.
    fn tick_until(&mut self, until: Ms) {
        loop {
            // Peek before popping: events beyond the slice stay queued.
            match self.events.peek() {
                Some(te) if te.t <= until => {}
                _ => break,
            }
            let te = self.events.pop().unwrap();
            self.now = te.t;
            if let Some(c) = self.checker.as_deref_mut() {
                c.on_event(te.t);
            }
            match te.ev {
                Ev::Frame { pipeline } => self.frame(pipeline),
                Ev::Arrive { pipeline, model, query } => {
                    self.arrive(pipeline, model, query)
                }
                Ev::Flush { pipeline, model } => {
                    self.groups[pipeline][model].flush_at = None;
                    self.try_dispatch(pipeline, model);
                }
                Ev::Portion { pipeline, model, binding, epoch } => {
                    if epoch == self.groups[pipeline][model].epoch {
                        self.portion_tick(pipeline, model, binding);
                    }
                }
                Ev::ExecDone { pipeline, model, binding, queries } => {
                    self.exec_done(pipeline, model, binding, queries)
                }
                Ev::Reschedule => {
                    // A controller outage skips the round's body but keeps
                    // the clock re-arming: the data plane runs open-loop.
                    if self.outage_depth == 0 {
                        self.reschedule(PlanTrigger::Periodic);
                    }
                    self.push(self.now + SCHEDULING_PERIOD_MS, Ev::Reschedule);
                }
                Ev::AutoScale => {
                    self.autoscale();
                    self.push(self.now + AUTOSCALE_PERIOD_MS, Ev::AutoScale);
                }
                Ev::DriftCheck => {
                    if self.outage_depth == 0 {
                        self.drift_check();
                    }
                    let period = self.drift.params.check_period_ms;
                    self.push(self.now + period, Ev::DriftCheck);
                }
                Ev::Fault(fe) => self.on_fault_event(fe),
                Ev::Tick => {
                    self.metrics.timeline.push((
                        self.minute_workload / 60.0,
                        self.minute_effective / 60.0,
                    ));
                    self.minute_workload = 0.0;
                    self.minute_effective = 0.0;
                    self.push(self.now + TICK_MS, Ev::Tick);
                }
            }
        }
    }

    /// Epoch barrier closed at `epoch_end`: hand the invariant engine its
    /// chance to catch a partition that ran ahead of the driver's clock.
    pub fn barrier(&mut self, epoch_end: Ms) {
        if let Some(c) = self.checker.as_deref_mut() {
            c.on_barrier(epoch_end);
        }
    }

    /// Cross-partition traffic produced this epoch. Uninhabited until the
    /// federation layer (ROADMAP item 1) gives clusters something to say
    /// to each other — the *when* (only at epoch barriers, in partition
    /// order) is fixed here, so adding the *what* cannot perturb
    /// single-cluster determinism.
    pub fn drain_outbox(&mut self) -> Vec<CrossMsg> {
        Vec::new()
    }

    /// Deliver cross-partition traffic merged at the barrier.
    pub fn deliver(&mut self, msgs: Vec<CrossMsg>) {
        for msg in msgs {
            match msg {} // uninhabited — nothing to route yet
        }
    }

    /// Close out the run at the scenario horizon: GPU utilization, the
    /// final conservation census, debug dump, metrics snapshot.
    pub fn finalize(&mut self) -> RunMetrics {
        let horizon = self.sc.cfg.duration_ms;
        // Mean GPU utilization over the run.
        let total_width_ms: f64 = self.gpu_busy_width_ms.iter().sum();
        let n_gpus = self.sc.cluster.n_gpus() as f64;
        self.metrics.mean_gpu_util =
            (total_width_ms / (horizon * n_gpus)).min(1.0);
        if self.checker.is_some() {
            let in_flight = self.in_flight_census();
            if let Some(c) = self.checker.as_deref_mut() {
                c.finish(in_flight, &self.metrics);
            }
        }
        // Balance the trace: queries still in flight at the horizon get
        // their open span closed at the cut (export-side bookkeeping; the
        // ring keeps the raw record).
        if let Some(tr) = self.tracer.as_deref_mut() {
            tr.close_open_spans(horizon);
        }
        // Flight recorder: a violated run dumps its last ring of trace
        // events with the repro string (stderr — diagnostics, never part
        // of any digested output).
        if let Some(dump) = self.flight_dump() {
            eprintln!("{dump}");
        }
        if std::env::var("OCTOPINF_SIM_DEBUG").is_ok() {
            let keys: Vec<(usize, usize)> = (0..self.groups.len())
                .flat_map(|p| (0..self.groups[p].len()).map(move |m| (p, m)))
                .collect();
            for (p, m) in keys {
                let g = &self.groups[p][m];
                eprintln!(
                    "group p{p}/m{m}: dev={} bz={} inst={} q={} rate={:.1} cap={:.1} temporal={} busy={:?} flush_at={:?}",
                    g.cfg.device,
                    g.cfg.batch,
                    g.cfg.instances,
                    g.queue.len(),
                    g.window.rate_qps(),
                    g.capacity_qps(&self.sc),
                    g.bindings.iter().filter(|b| b.temporal.is_some()).count(),
                    g.busy,
                    g.flush_at,
                );
            }
        }
        self.metrics.clone()
    }

    /// Single-partition convenience: execute the scenario to completion
    /// and return metrics — exactly `start` + one `tick` to the horizon +
    /// `finalize`, which is also what the driver's epoch slicing reduces
    /// to for one cluster.
    pub fn run(&mut self) -> RunMetrics {
        self.start();
        let horizon = self.sc.cfg.duration_ms;
        self.tick_until(horizon);
        self.finalize()
    }
}

impl Component for SimPartition {
    fn next_tick(&mut self) -> Option<Ms> {
        self.events.peek().map(|te| te.t)
    }

    fn tick(&mut self, until: Ms) {
        self.tick_until(until);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::sim::scenario::{preset, Scenario};

    fn smoke_cfg() -> ExperimentConfig {
        preset("smoke").unwrap()
    }

    #[test]
    fn interference_model_shape() {
        let m = InterferenceModel::default();
        assert!((m.multiplier(0.5, 1.0, 0) - 1.0).abs() < 1e-9);
        assert!(m.multiplier(1.5, 1.0, 2) > 1.5);
        assert!(m.multiplier(0.9, 1.0, 3) > 1.0);
    }

    #[test]
    fn smoke_run_produces_throughput() {
        let sc = Scenario::build(smoke_cfg());
        let m = crate::sim::run(&sc, SchedulerKind::OctopInf);
        assert!(m.on_time > 0, "no on-time completions");
        assert!(m.effective_throughput() > 1.0);
        assert!(m.peak_memory_mb > 0.0);
        assert!(!m.timeline.is_empty());
    }

    #[test]
    fn all_schedulers_complete_smoke() {
        let sc = Scenario::build(smoke_cfg());
        for kind in SchedulerKind::all_main() {
            let m = crate::sim::run(&sc, kind);
            assert!(
                m.on_time + m.late + m.dropped > 0,
                "{:?} produced nothing",
                kind
            );
        }
    }

    #[test]
    fn deterministic_runs() {
        // Buffer pooling, the lazily-compacted GPU-run tracking, and the
        // streaming latency sketch must not perturb determinism: repeated
        // runs agree on every exported metric.
        let sc1 = Scenario::build(smoke_cfg());
        let sc2 = Scenario::build(smoke_cfg());
        let a = crate::sim::run(&sc1, SchedulerKind::OctopInf);
        let b = crate::sim::run(&sc2, SchedulerKind::OctopInf);
        assert_eq!(a.on_time, b.on_time);
        assert_eq!(a.late, b.late);
        assert_eq!(a.dropped, b.dropped);
        assert_eq!(a.peak_memory_mb, b.peak_memory_mb);
        assert_eq!(a.mean_gpu_util, b.mean_gpu_util);
        assert_eq!(a.timeline, b.timeline);
        for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(a.latency.quantile(q), b.latency.quantile(q), "q={q}");
        }
    }

    #[test]
    fn latencies_within_sanity() {
        let sc = Scenario::build(smoke_cfg());
        let m = crate::sim::run(&sc, SchedulerKind::OctopInf);
        let p99 = m.latency.p99();
        assert!(p99 > 0.0 && p99 < 5_000.0, "p99 {p99}");
    }

    /// Flood group (0, 0)'s arrival window so its observed rate dwarfs any
    /// plausible capacity (forces a surge verdict regardless of the plan).
    fn saturate(sim: &mut SimPartition, now: Ms) {
        for i in 0..20_000 {
            sim.groups[0][0].window.record(now - 2000.0 + i as f64 * 0.1);
        }
    }

    #[test]
    fn sim_autoscaler_shares_controller_cooldown() {
        // Regression: the sim used to reimplement the scale thresholds
        // inline and silently drop `AutoScaler`'s cooldown, flapping on
        // every 10 s tick. Both paths now share `AutoScaler::decide`.
        let sc = Scenario::build(smoke_cfg());
        let mut sim = SimPartition::new(&sc, SchedulerKind::OctopInf);
        sim.reschedule(PlanTrigger::Initial);
        sim.now = 60_000.0;
        saturate(&mut sim, sim.now);
        let base = sim.groups[0][0].cfg.instances;
        sim.autoscale();
        assert_eq!(
            sim.groups[0][0].cfg.instances,
            base + 1,
            "saturated group must scale up"
        );
        // Next two ticks fall inside the 25 s cooldown: hold.
        for _ in 0..2 {
            sim.now += AUTOSCALE_PERIOD_MS;
            saturate(&mut sim, sim.now);
            sim.autoscale();
            assert_eq!(
                sim.groups[0][0].cfg.instances,
                base + 1,
                "cooldown must suppress back-to-back scaling"
            );
        }
        // Past the cooldown the (still saturated) group scales again.
        sim.now += AUTOSCALE_PERIOD_MS;
        saturate(&mut sim, sim.now);
        sim.autoscale();
        assert_eq!(sim.groups[0][0].cfg.instances, base + 2);
    }

    #[test]
    fn plan_diff_migration_keeps_unchanged_groups_live() {
        let sc = Scenario::build(smoke_cfg());
        let mut sim = SimPartition::new(&sc, SchedulerKind::OctopInf);
        sim.reschedule(PlanTrigger::Initial);
        let epoch0 = sim.groups[0][0].epoch;
        sim.groups[0][0].queue.push_back(Query {
            created_ms: 0.0,
            deadline_ms: 1e9,
            objects: 1,
            qid: 0,
            mark_ms: 0.0,
            transfer_ms: 0.0,
            queue_ms: 0.0,
            exec_ms: 0.0,
        });
        // Reinstalling the identical plan is a pure no-op migration: no
        // epoch bumps (portion clocks keep ticking), queues intact.
        let plan = sim.plan.clone();
        sim.install_plan(plan);
        assert_eq!(sim.groups[0][0].epoch, epoch0, "unchanged group redeployed");
        assert_eq!(sim.groups[0][0].queue.len(), 1, "queue lost in migration");

        // Changing one group's config re-deploys exactly that group.
        let mut plan2 = sim.plan.clone();
        let idx = plan2
            .assignments
            .iter()
            .position(|a| a.pipeline == 0 && a.model == 0)
            .unwrap();
        plan2.assignments[idx].cfg.batch =
            if plan2.assignments[idx].cfg.batch == 1 { 2 } else { 1 };
        let other = plan2
            .assignments
            .iter()
            .position(|a| a.pipeline == 1 && a.model == 0)
            .unwrap();
        let other = (plan2.assignments[other].pipeline, plan2.assignments[other].model);
        let other_epoch = sim.groups[other.0][other.1].epoch;
        sim.install_plan(plan2);
        assert_ne!(sim.groups[0][0].epoch, epoch0, "changed group must redeploy");
        assert_eq!(
            sim.groups[other.0][other.1].epoch,
            other_epoch,
            "untouched group must not redeploy"
        );
        assert_eq!(sim.groups[0][0].queue.len(), 1, "queue lost in redeploy");
    }

    #[test]
    fn redeploy_carries_in_flight_busy_flags() {
        // A binding mid-execution keeps its slot across a redeploy: the
        // pending ExecDone clears it later. Resetting it would let the
        // same instance run overlapping batches right after a migration.
        let sc = Scenario::build(smoke_cfg());
        let mut sim = SimPartition::new(&sc, SchedulerKind::OctopInf);
        sim.reschedule(PlanTrigger::Initial);
        assert!(!sim.groups[0][0].busy.is_empty());
        sim.groups[0][0].busy[0] = true; // simulate an in-flight batch
        let mut plan2 = sim.plan.clone();
        let idx = plan2
            .assignments
            .iter()
            .position(|a| a.pipeline == 0 && a.model == 0)
            .unwrap();
        plan2.assignments[idx].cfg.batch =
            if plan2.assignments[idx].cfg.batch == 1 { 2 } else { 1 };
        sim.install_plan(plan2);
        assert!(
            sim.groups[0][0].busy[0],
            "redeploy must keep the executing binding occupied"
        );
        assert_eq!(
            sim.groups[0][0].busy.len(),
            sim.groups[0][0].bindings.len()
        );
    }

    #[test]
    fn migration_preserves_live_autoscaler_clones() {
        // The autoscaler appends contended clones to live groups without
        // touching self.plan; a replan that leaves the pipeline's
        // assignment unchanged must not revert that surge capacity.
        let sc = Scenario::build(smoke_cfg());
        let mut sim = SimPartition::new(&sc, SchedulerKind::OctopInf);
        sim.reschedule(PlanTrigger::Initial);
        sim.now = 60_000.0;
        saturate(&mut sim, sim.now);
        let base = sim.groups[0][0].cfg.instances;
        sim.autoscale();
        assert_eq!(sim.groups[0][0].cfg.instances, base + 1);
        let epoch = sim.groups[0][0].epoch;
        let plan = sim.plan.clone();
        sim.install_plan(plan);
        assert_eq!(
            sim.groups[0][0].cfg.instances,
            base + 1,
            "migration reverted the autoscaled clone"
        );
        assert_eq!(sim.groups[0][0].epoch, epoch, "group was redeployed");
    }

    #[test]
    fn device_crash_losses_are_accounted_exactly() {
        let sc = Scenario::build(smoke_cfg());
        let mut sim = SimPartition::new(&sc, SchedulerKind::OctopInf);
        // Crash source device 1 for 15 s mid-run: frames captured during
        // the window are lost at birth; any in-flight batches die too.
        sim.set_fault_plan(FaultPlan {
            events: vec![
                (10_000.0, FaultEv::DeviceCrash { device: 1 }),
                (25_000.0, FaultEv::DeviceRecover { device: 1 }),
            ],
        });
        sim.enable_invariants();
        let m = sim.run();
        let r = sim.take_invariant_report().unwrap();
        assert!(r.ok(), "{:?}", r.violations);
        assert!(m.lost_to_fault > 0, "crashed source device lost nothing");
        assert_eq!(m.lost_to_fault, r.lost_to_fault);
        assert!(m.on_time > 0, "survivors produced nothing");
    }

    #[test]
    fn straggler_outage_and_freeze_keep_conservation() {
        let sc = Scenario::build(smoke_cfg());
        let mut sim = SimPartition::new(&sc, SchedulerKind::OctopInf);
        sim.set_fault_plan(FaultPlan {
            events: vec![
                (5_000.0, FaultEv::TelemetryFreezeStart),
                (8_000.0, FaultEv::StragglerStart { device: 0, gpu: 0, factor: 3.0 }),
                (12_000.0, FaultEv::ControllerOutageStart),
                (20_000.0, FaultEv::StragglerEnd { device: 0, gpu: 0, factor: 3.0 }),
                (28_000.0, FaultEv::ControllerOutageEnd),
                (30_000.0, FaultEv::TelemetryFreezeEnd),
            ],
        });
        sim.enable_invariants();
        let m = sim.run();
        let r = sim.take_invariant_report().unwrap();
        assert!(r.ok(), "{:?}", r.violations);
        // None of these faults destroy work — only slow or mislead.
        assert_eq!(m.lost_to_fault, 0);
        assert!(m.on_time > 0);
    }

    #[test]
    fn fault_storm_runs_are_deterministic() {
        let mut cfg = smoke_cfg();
        cfg.faults = 4;
        let sc1 = Scenario::build(cfg.clone());
        let sc2 = Scenario::build(cfg);
        let a = crate::sim::run(&sc1, SchedulerKind::OctopInf);
        let b = crate::sim::run(&sc2, SchedulerKind::OctopInf);
        assert_eq!(a.on_time, b.on_time);
        assert_eq!(a.late, b.late);
        assert_eq!(a.dropped, b.dropped);
        assert_eq!(a.lost_to_fault, b.lost_to_fault);
        assert_eq!(a.timeline, b.timeline);
    }

    #[test]
    fn drift_mode_produces_work_and_is_deterministic() {
        let mut cfg = smoke_cfg();
        cfg.replan = ReplanMode::Drift;
        let sc1 = Scenario::build(cfg.clone());
        let sc2 = Scenario::build(cfg);
        let a = crate::sim::run(&sc1, SchedulerKind::OctopInf);
        let b = crate::sim::run(&sc2, SchedulerKind::OctopInf);
        assert!(a.on_time > 0, "drift mode completed nothing");
        assert_eq!(a.on_time, b.on_time);
        assert_eq!(a.late, b.late);
        assert_eq!(a.dropped, b.dropped);
        assert_eq!(a.timeline, b.timeline);
    }

    #[test]
    fn trace_is_balanced_and_attribution_reconciles() {
        let sc = Scenario::build(smoke_cfg());
        let mut sim = SimPartition::new(&sc, SchedulerKind::OctopInf);
        sim.enable_invariants();
        sim.enable_tracing();
        let m = sim.run();
        let events = sim.take_trace();
        assert!(!events.is_empty(), "traced run produced no events");
        crate::obs::check_balanced(&events).unwrap();
        // Plan events carry provenance: at least the initial full round.
        assert!(events.iter().any(|e| matches!(
            e,
            TraceEvent::Plan { trigger: PlanTrigger::Initial, .. }
        )));
        // The invariant engine verified every sink's fold bit-for-bit and
        // reconciled the sketches against the completion counters.
        let r = sim.take_invariant_report().unwrap();
        assert!(r.ok(), "{:?}", r.violations);
        assert_eq!(m.attrib.transfer.count(), m.completed());
        assert!(m.attrib.transfer.mean() > 0.0, "no transfer time attributed");
    }

    #[test]
    fn tracing_never_perturbs_the_run() {
        // The observability contract: hooks observe, never steer. A traced
        // run and a plain run of the same scenario are metric-identical.
        let sc = Scenario::build(smoke_cfg());
        let mut plain = SimPartition::new(&sc, SchedulerKind::OctopInf);
        let a = plain.run();
        let mut traced = SimPartition::new(&sc, SchedulerKind::OctopInf);
        traced.enable_tracing();
        let b = traced.run();
        assert_eq!(a.digest(), b.digest(), "tracing changed the metrics digest");
    }

    #[test]
    fn violations_dump_the_flight_recorder_with_a_repro() {
        let sc = Scenario::build(smoke_cfg());
        let mut sim = SimPartition::new(&sc, SchedulerKind::OctopInf);
        sim.enable_invariants();
        sim.run();
        assert!(sim.flight_dump().is_none(), "clean run must not dump");
        // Poison the checker the way a broken engine would (a batch wider
        // than its configured size), then ask for the postmortem.
        if let Some(c) = sim.checker.as_deref_mut() {
            c.on_batch(99, 8);
        }
        let dump = sim.flight_dump().expect("violation must dump the ring");
        assert!(dump.contains("fuzz:v1:seed="), "{dump}");
        let sketched = sim.metrics.attrib.transfer.count();
        assert!(sketched > 0, "run attributed nothing");
        // An exact repro provided by the harness wins over the fallback.
        sim.set_repro("fuzz:v1:seed=7:faults=2".into());
        let dump = sim.flight_dump().unwrap();
        assert!(dump.contains("fuzz:v1:seed=7:faults=2"), "{dump}");
    }
}
