//! Orchestration layer: [`Simulator`] owns time and steps per-cluster
//! [`SimPartition`]s through fixed epoch barriers, fanning the ticks
//! across scoped worker threads with the same deterministic-merge
//! discipline as the experiment runner (`util::par`).
//!
//! Determinism: partitions share nothing while ticking — each owns its
//! cluster, links, scheduler, RNG streams, and event wheel — so ticking
//! them concurrently is observationally identical to ticking them one by
//! one. Everything that crosses a partition boundary (mailbox traffic,
//! metric/report merging) happens on the driver thread, in partition
//! order, at a barrier. `--sim-jobs` therefore changes wall-clock only;
//! see the contract in [`crate::sim`].

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::coordinator::SchedulerKind;
use crate::metrics::RunMetrics;
use crate::obs::TraceEvent;
use crate::sim::engine::SimPartition;
use crate::sim::faults::FaultPlan;
use crate::sim::invariants::InvariantReport;
use crate::sim::scenario::Scenario;
use crate::sim::Component;
use crate::util::par::effective_jobs;
use crate::Ms;

/// Barrier cadence. Cross-partition state may only move at these
/// boundaries — matching the control-plane cadence (autoscale period),
/// well below the 6-min scheduling rounds a future global balancer would
/// act on, and coarse enough that barrier overhead is noise.
const EPOCH_MS: Ms = 10_000.0;

/// Tag mixed into replica-cluster seeds (`partition_seed`).
const PARTITION_TAG: u64 = 0x9A87_171D_0E5F_3C4B;

/// Seed for cluster partition `k`. Partition 0 keeps the scenario seed
/// untouched — a one-cluster run is bit-identical to the pre-partition
/// engine — and replicas get splitmix-separated streams so no RNG draw
/// correlates across clusters.
pub fn partition_seed(seed: u64, k: usize) -> u64 {
    if k == 0 {
        return seed;
    }
    seed ^ crate::sim::wheel::mix64(PARTITION_TAG ^ k as u64)
}

/// The top-level simulator: one [`SimPartition`] per cluster
/// (`cfg.clusters`, default 1), advanced in lockstep epochs.
pub struct Simulator {
    parts: Vec<SimPartition>,
    horizon: Ms,
    sim_jobs: usize,
}

impl Simulator {
    pub fn new(scenario: &Scenario, kind: SchedulerKind) -> Simulator {
        let clusters = scenario.cfg.clusters.max(1);
        let horizon = scenario.cfg.duration_ms;
        let mut parts = Vec::with_capacity(clusters);
        // Partition 0 is built from the caller's scenario verbatim (its
        // content processes and traces included), so `clusters = 1`
        // reproduces the historical single-engine run byte-for-byte.
        parts.push(SimPartition::new(scenario, kind));
        for k in 1..clusters {
            let mut cfg = scenario.cfg.clone();
            cfg.seed = partition_seed(scenario.cfg.seed, k);
            let replica = Scenario::build(cfg);
            parts.push(SimPartition::new(&replica, kind));
        }
        Simulator { parts, horizon, sim_jobs: 1 }
    }

    /// Worker threads for the partition fan-out (0 = one per hardware
    /// thread). Purely a wall-clock knob — never part of repro strings or
    /// fingerprints; results are byte-identical at any value.
    pub fn set_sim_jobs(&mut self, jobs: usize) {
        self.sim_jobs = jobs;
    }

    /// Override the sampled fault schedule (tests and targeted chaos
    /// runs). Applies to partition 0 — the cluster targeted storms are
    /// written against; replica clusters keep their seeded plans. Must be
    /// called before `run`.
    pub fn set_fault_plan(&mut self, plan: FaultPlan) {
        self.parts[0].set_fault_plan(plan);
    }

    /// Arm the invariant engine in every partition before `run`.
    pub fn enable_invariants(&mut self) {
        for p in &mut self.parts {
            p.enable_invariants();
        }
    }

    /// Arm the full tracer (`--trace`) in every partition before `run`.
    pub fn enable_tracing(&mut self) {
        for p in &mut self.parts {
            p.enable_tracing();
        }
    }

    /// Arm the ring-only flight recorder in every partition before `run`.
    pub fn enable_flight_recorder(&mut self) {
        for p in &mut self.parts {
            p.enable_flight_recorder();
        }
    }

    /// Record the exact repro string every partition's flight-recorder
    /// dump should carry (fuzz replays know it).
    pub fn set_repro(&mut self, repro: &str) {
        for p in &mut self.parts {
            p.set_repro(repro.to_string());
        }
    }

    /// Take the per-partition traces after `run` (empty vecs unless
    /// tracing was enabled). Always in partition order — the export
    /// merge is a pure function of this, independent of `--sim-jobs`.
    pub fn take_trace(&mut self) -> Vec<Vec<TraceEvent>> {
        self.parts.iter_mut().map(SimPartition::take_trace).collect()
    }

    /// Take the merged invariant report after `run` (None unless
    /// enabled). Partition reports fold together in partition order.
    pub fn take_invariant_report(&mut self) -> Option<InvariantReport> {
        let mut merged: Option<InvariantReport> = None;
        for p in &mut self.parts {
            let Some(r) = p.take_invariant_report() else { continue };
            match merged.as_mut() {
                Some(m) => m.merge(r),
                None => merged = Some(r),
            }
        }
        merged
    }

    /// Execute every partition to the horizon and return the fleet
    /// metrics (counters and sketches merged across clusters; GPU
    /// utilization averaged).
    pub fn run(&mut self) -> RunMetrics {
        for p in &mut self.parts {
            p.start();
        }
        let mut t: Ms = 0.0;
        loop {
            let until = (t + EPOCH_MS).min(self.horizon);
            self.tick_all(until);
            // Barrier: cross-partition traffic moves here, in partition
            // order, on the driver thread — the only place partitions may
            // observe each other. Outboxes are empty until federation
            // (ROADMAP item 1); the exchange points and their ordering
            // are what this layer pins down.
            for i in 0..self.parts.len() {
                let outbox = self.parts[i].drain_outbox();
                debug_assert!(
                    outbox.is_empty(),
                    "cross-partition traffic has no routing table yet"
                );
                self.parts[i].deliver(outbox);
            }
            for p in &mut self.parts {
                p.barrier(until);
            }
            t = until;
            if t >= self.horizon {
                break;
            }
        }
        let n = self.parts.len() as f64;
        let mut finals = self.parts.iter_mut().map(|p| p.finalize());
        let mut merged = finals.next().expect("at least one partition");
        let mut util_sum = merged.mean_gpu_util;
        for m in finals {
            util_sum += m.mean_gpu_util;
            merged.merge(&m);
        }
        // Utilization is a fleet *mean*, not a sum (x / 1.0 is exact, so
        // the one-cluster path stays bit-identical).
        merged.mean_gpu_util = util_sum / n;
        merged
    }

    /// Tick every partition to `until`, `sim_jobs` at a time. Work-steals
    /// partitions off an atomic cursor under `std::thread::scope`, the
    /// same discipline as `util::par::par_map` — partitions are mutated
    /// in place (no results to merge), so a Mutex per slot hands each
    /// worker exclusive access.
    fn tick_all(&mut self, until: Ms) {
        let jobs = effective_jobs(self.sim_jobs, self.parts.len());
        if jobs <= 1 || self.parts.len() <= 1 {
            for p in &mut self.parts {
                p.tick(until);
            }
            return;
        }
        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<&mut SimPartition>> =
            self.parts.iter_mut().map(Mutex::new).collect();
        std::thread::scope(|scope| {
            for _ in 0..jobs {
                let next = &next;
                let slots = &slots;
                scope.spawn(move || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= slots.len() {
                        break;
                    }
                    // Uncontended by construction: the cursor hands each
                    // index to exactly one worker.
                    let mut part = slots[i].lock().expect("partition mutex");
                    part.tick(until);
                });
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::scenario::preset;

    #[test]
    fn partition_seeds_are_stable_and_distinct() {
        assert_eq!(partition_seed(42, 0), 42);
        assert_ne!(partition_seed(42, 1), 42);
        assert_ne!(partition_seed(42, 1), partition_seed(42, 2));
        assert_eq!(partition_seed(42, 3), partition_seed(42, 3));
        // Different base seeds never alias onto the same replica seed.
        assert_ne!(partition_seed(1, 1), partition_seed(2, 1));
    }

    #[test]
    fn multi_cluster_run_merges_fleet_metrics() {
        let mut cfg = preset("smoke").unwrap();
        cfg.clusters = 2;
        let sc1 = Scenario::build(cfg.clone());
        let one = {
            let mut c = cfg.clone();
            c.clusters = 1;
            crate::sim::run(&Scenario::build(c), SchedulerKind::OctopInf)
        };
        let two = crate::sim::run(&sc1, SchedulerKind::OctopInf);
        // Two independent clusters complete roughly twice the work of one
        // (partition 0 is the identical scenario; the replica adds its
        // own) and report a fleet-summed memory peak.
        assert!(two.on_time > one.on_time, "replica cluster added nothing");
        assert!(two.peak_memory_mb > one.peak_memory_mb);
        assert!(two.mean_gpu_util <= 1.0);
    }

    #[test]
    fn sim_jobs_is_a_pure_wall_clock_knob() {
        let mut cfg = preset("smoke").unwrap();
        cfg.clusters = 3;
        cfg.faults = 2;
        for jobs in [2usize, 4, 8] {
            let a = {
                let sc = Scenario::build(cfg.clone());
                let mut s = Simulator::new(&sc, SchedulerKind::OctopInf);
                s.set_sim_jobs(1);
                s.run()
            };
            let b = {
                let sc = Scenario::build(cfg.clone());
                let mut s = Simulator::new(&sc, SchedulerKind::OctopInf);
                s.set_sim_jobs(jobs);
                s.run()
            };
            assert_eq!(a.digest(), b.digest(), "sim-jobs={jobs} diverged");
        }
    }

    #[test]
    fn invariants_hold_across_partition_barriers() {
        let mut cfg = preset("smoke").unwrap();
        cfg.clusters = 2;
        cfg.faults = 3;
        let sc = Scenario::build(cfg);
        let (m, r) = crate::sim::run_checked_with(&sc, SchedulerKind::OctopInf, 4);
        assert!(r.ok(), "{:?}", r.violations);
        assert_eq!(m.lost_to_fault, r.lost_to_fault);
        assert!(m.on_time > 0);
    }
}
