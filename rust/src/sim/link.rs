//! FIFO network link: serializes transfers at the trace's instantaneous
//! bandwidth, modelling both serialization delay and queueing backlog
//! (Obs. 2: unstable networks become the pipeline bottleneck).

use crate::network::BwTrace;
use crate::{Bytes, Ms};

/// One edge<->server uplink with FIFO queueing.
#[derive(Clone, Debug)]
pub struct FifoLink {
    trace: BwTrace,
    rtt_ms: Ms,
    /// Time the link finishes its currently queued transfers.
    free_at_ms: Ms,
}

impl FifoLink {
    pub fn new(trace: BwTrace, rtt_ms: Ms) -> FifoLink {
        FifoLink { trace, rtt_ms, free_at_ms: 0.0 }
    }

    pub fn bandwidth_mbps(&self, t_ms: Ms) -> f64 {
        self.trace.bandwidth_mbps(t_ms)
    }

    /// Enqueue a transfer at `now`; returns arrival time at the far end.
    /// During an outage the transfer waits for the next second with
    /// non-zero bandwidth (bounded scan; trace loops).
    pub fn send(&mut self, now: Ms, bytes: Bytes) -> Ms {
        let mut start = now.max(self.free_at_ms);
        // Skip outage seconds (bounded to 10 minutes of scanning).
        let mut guard = 0;
        let mut bw = self.bandwidth_mbps(start);
        while bw <= 0.0 && guard < 600 {
            start = (start / 1000.0).floor() * 1000.0 + 1000.0;
            bw = self.bandwidth_mbps(start);
            guard += 1;
        }
        if bw <= 0.0 {
            // Permanently dark link: deliver never (caller drops on deadline).
            self.free_at_ms = start;
            return f64::INFINITY;
        }
        let ser_ms = bytes * 8.0 / (bw * 1000.0);
        self.free_at_ms = start + ser_ms;
        self.free_at_ms + self.rtt_ms / 2.0
    }

    /// Backlog depth (ms of queued serialization) at `now`.
    pub fn backlog_ms(&self, now: Ms) -> Ms {
        (self.free_at_ms - now).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::TraceKind;
    use crate::util::Rng;

    #[test]
    fn fifo_serializes() {
        let mut l = FifoLink::new(BwTrace::constant(80.0), 0.0);
        // 1 MB at 80 Mbit/s = 100 ms each.
        let a1 = l.send(0.0, 1_000_000.0);
        let a2 = l.send(0.0, 1_000_000.0);
        assert!((a1 - 100.0).abs() < 1.0, "a1 {a1}");
        assert!((a2 - 200.0).abs() < 1.0, "a2 {a2}");
    }

    #[test]
    fn backlog_drains() {
        let mut l = FifoLink::new(BwTrace::constant(80.0), 0.0);
        l.send(0.0, 1_000_000.0);
        assert!(l.backlog_ms(0.0) > 90.0);
        assert_eq!(l.backlog_ms(200.0), 0.0);
    }

    #[test]
    fn outage_defers_to_next_good_second() {
        let trace = BwTrace::from_csv("0,0\n1,0\n2,50\n").unwrap();
        let mut l = FifoLink::new(trace, 0.0);
        let arrival = l.send(0.0, 10_000.0);
        assert!(arrival >= 2000.0, "arrival {arrival}");
        assert!(arrival < 2010.0);
    }

    #[test]
    fn generated_trace_links_work() {
        let mut rng = Rng::new(5);
        let trace = BwTrace::generate(TraceKind::Lte, 60_000.0, &mut rng);
        let mut l = FifoLink::new(trace, 20.0);
        let mut t = 0.0;
        for i in 0..100 {
            let a = l.send(i as f64 * 500.0, 50_000.0);
            assert!(a >= t || a.is_infinite());
            if a.is_finite() {
                t = a;
            }
        }
    }
}
