//! FIFO network link: serializes transfers at the trace's instantaneous
//! bandwidth, modelling both serialization delay and queueing backlog
//! (Obs. 2: unstable networks become the pipeline bottleneck).

use crate::network::BwTrace;
use crate::sim::wheel::OutageSkip;
use crate::{Bytes, Ms};

/// How far past the deferral's first second boundary a send will wait for
/// bandwidth before giving up (the historical 600-iteration scan cap:
/// boundaries `b0 .. b0 + 599 s` are eligible, a later reopen is "never").
const MAX_DEFER_S: u32 = 599;

/// One edge<->server uplink with FIFO queueing.
#[derive(Clone, Debug)]
pub struct FifoLink {
    trace: BwTrace,
    /// Distance-to-next-bright-second per trace slot, precomputed once —
    /// outage deferral is an O(1) calendar lookup instead of a
    /// second-by-second rescan on every send into a blackout.
    skip: OutageSkip,
    rtt_ms: Ms,
    /// Time the link finishes its currently queued transfers.
    free_at_ms: Ms,
}

impl FifoLink {
    pub fn new(trace: BwTrace, rtt_ms: Ms) -> FifoLink {
        let skip = OutageSkip::build(trace.samples());
        FifoLink { trace, skip, rtt_ms, free_at_ms: 0.0 }
    }

    pub fn bandwidth_mbps(&self, t_ms: Ms) -> f64 {
        self.trace.bandwidth_mbps(t_ms)
    }

    /// Enqueue a transfer at `now`; returns arrival time at the far end.
    /// During an outage the transfer jumps straight to the next second
    /// with non-zero bandwidth via the skip table — same boundaries, same
    /// 10-minute cap, and bit-identical arrival times as the old
    /// second-by-second scan (traces loop, boundaries are exact multiples
    /// of 1000 ms).
    pub fn send(&mut self, now: Ms, bytes: Bytes) -> Ms {
        let mut start = now.max(self.free_at_ms);
        let mut bw = self.bandwidth_mbps(start);
        if bw <= 0.0 {
            // First candidate boundary: the next whole second after
            // `start` (matching the scan, which always stepped once).
            let b0 = (start / 1000.0).floor() * 1000.0 + 1000.0;
            let slot = (b0 / 1000.0).max(0.0) as usize;
            match self.skip.to_next_bright(slot) {
                Some(d) if d <= MAX_DEFER_S => {
                    start = b0 + d as f64 * 1000.0;
                    bw = self.bandwidth_mbps(start);
                }
                _ => {
                    // Dark past the cap (or forever): deliver never —
                    // the caller drops on deadline. Park free_at where
                    // the old scan's guard ran out.
                    self.free_at_ms = b0 + MAX_DEFER_S as f64 * 1000.0;
                    return f64::INFINITY;
                }
            }
        }
        let ser_ms = bytes * 8.0 / (bw * 1000.0);
        self.free_at_ms = start + ser_ms;
        self.free_at_ms + self.rtt_ms / 2.0
    }

    /// Backlog depth (ms of queued serialization) at `now`.
    pub fn backlog_ms(&self, now: Ms) -> Ms {
        (self.free_at_ms - now).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::TraceKind;
    use crate::util::Rng;

    #[test]
    fn fifo_serializes() {
        let mut l = FifoLink::new(BwTrace::constant(80.0), 0.0);
        // 1 MB at 80 Mbit/s = 100 ms each.
        let a1 = l.send(0.0, 1_000_000.0);
        let a2 = l.send(0.0, 1_000_000.0);
        assert!((a1 - 100.0).abs() < 1.0, "a1 {a1}");
        assert!((a2 - 200.0).abs() < 1.0, "a2 {a2}");
    }

    #[test]
    fn backlog_drains() {
        let mut l = FifoLink::new(BwTrace::constant(80.0), 0.0);
        l.send(0.0, 1_000_000.0);
        assert!(l.backlog_ms(0.0) > 90.0);
        assert_eq!(l.backlog_ms(200.0), 0.0);
    }

    #[test]
    fn outage_defers_to_next_good_second() {
        let trace = BwTrace::from_csv("0,0\n1,0\n2,50\n").unwrap();
        let mut l = FifoLink::new(trace, 0.0);
        let arrival = l.send(0.0, 10_000.0);
        assert!(arrival >= 2000.0, "arrival {arrival}");
        assert!(arrival < 2010.0);
    }

    #[test]
    fn generated_trace_links_work() {
        let mut rng = Rng::new(5);
        let trace = BwTrace::generate(TraceKind::Lte, 60_000.0, &mut rng);
        let mut l = FifoLink::new(trace, 20.0);
        let mut t = 0.0;
        for i in 0..100 {
            let a = l.send(i as f64 * 500.0, 50_000.0);
            assert!(a >= t || a.is_infinite());
            if a.is_finite() {
                t = a;
            }
        }
    }
}
