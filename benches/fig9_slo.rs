//! Regenerates Fig. 9: effective throughput under stricter SLOs
//! (-0 / -50 / -100 ms from the 200/300 ms defaults).
//!
//! `cargo bench --bench fig9_slo`

mod common;

use octopinf::experiments;

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let jobs = common::jobs_from_env();
    common::bench("fig9_strict_slo", || {
        experiments::fig9_slo(quick, jobs).to_markdown()
    });
}
