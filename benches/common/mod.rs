#![allow(dead_code)]
//! Shared mini bench harness (the offline registry has no criterion):
//! wall-clock the figure regenerators, print their tables, and emit a
//! `name ... elapsed` summary line per bench for bench_output.txt.

use std::time::Instant;

pub fn bench<F: FnOnce() -> String>(name: &str, f: F) {
    let t0 = Instant::now();
    let output = f();
    let dt = t0.elapsed().as_secs_f64();
    println!("\n===== bench: {name} =====\n");
    println!("{output}");
    println!("\n[bench {name}: {dt:.2}s]");
}

/// Micro-benchmark: run `f` `iters` times, report ns/iter stats.
pub fn micro<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    // Warmup.
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let total = t0.elapsed().as_secs_f64();
    let per = total / iters as f64;
    let (value, unit) = if per >= 1.0 {
        (per, "s")
    } else if per >= 1e-3 {
        (per * 1e3, "ms")
    } else if per >= 1e-6 {
        (per * 1e6, "us")
    } else {
        (per * 1e9, "ns")
    };
    println!("micro {name:<40} {value:>10.2} {unit}/iter  ({iters} iters)");
}
