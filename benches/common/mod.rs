#![allow(dead_code)]
//! Shared mini bench harness (the offline registry has no criterion):
//! wall-clock the figure regenerators, print their tables, and emit a
//! `name ... elapsed` summary line per bench for bench_output.txt.
//!
//! A [`Recorder`] additionally captures every measurement and writes a
//! machine-readable `BENCH_<name>.json` (name, iters, ns/op) next to the
//! human output, so bench trajectories can be tracked across PRs without
//! scraping stdout. JSON is hand-rendered — no serde in the registry.

use std::time::Instant;

/// Worker count for parallel experiment grids: `JOBS` env var, defaulting
/// to 0 ("one worker per hardware thread" — see `experiments::runner`).
pub fn jobs_from_env() -> usize {
    std::env::var("JOBS").ok().and_then(|v| v.parse().ok()).unwrap_or(0)
}

pub fn bench<F: FnOnce() -> String>(name: &str, f: F) {
    let t0 = Instant::now();
    let output = f();
    let dt = t0.elapsed().as_secs_f64();
    println!("\n===== bench: {name} =====\n");
    println!("{output}");
    println!("\n[bench {name}: {dt:.2}s]");
}

/// Micro-benchmark: run `f` `iters` times, report ns/iter stats.
/// Returns the measured ns/iter so callers can record it.
pub fn micro<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    // Warmup.
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let total = t0.elapsed().as_secs_f64();
    let per = total / iters as f64;
    let (value, unit) = if per >= 1.0 {
        (per, "s")
    } else if per >= 1e-3 {
        (per * 1e3, "ms")
    } else if per >= 1e-6 {
        (per * 1e6, "us")
    } else {
        (per * 1e9, "ns")
    };
    println!("micro {name:<40} {value:>10.2} {unit}/iter  ({iters} iters)");
    per * 1e9
}

/// One recorded measurement.
struct Entry {
    name: String,
    iters: usize,
    ns_per_iter: f64,
}

/// Collects micro-bench results and writes `BENCH_<bench>.json`.
pub struct Recorder {
    bench: String,
    entries: Vec<Entry>,
}

impl Recorder {
    pub fn new(bench: &str) -> Recorder {
        Recorder { bench: bench.to_string(), entries: Vec::new() }
    }

    /// Run and record a micro-benchmark (same output as [`micro`]).
    pub fn micro<F: FnMut()>(&mut self, name: &str, iters: usize, f: F) {
        let ns_per_iter = micro(name, iters, f);
        self.entries.push(Entry { name: name.to_string(), iters, ns_per_iter });
    }

    /// Render the collected entries as JSON.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"bench\": \"{}\",\n", escape(&self.bench)));
        out.push_str("  \"results\": [\n");
        for (i, e) in self.entries.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"name\": \"{}\", \"iters\": {}, \"ns_per_iter\": {:.1}}}{}\n",
                escape(&e.name),
                e.iters,
                e.ns_per_iter,
                if i + 1 < self.entries.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Write `BENCH_<bench>.json` into the working directory (the repo
    /// root under `cargo bench`). Prints the path on success.
    pub fn write(&self) {
        let path = format!("BENCH_{}.json", self.bench);
        match std::fs::write(&path, self.to_json()) {
            Ok(()) => println!("\nwrote {path}"),
            Err(e) => eprintln!("could not write {path}: {e}"),
        }
    }

    /// Merge the collected entries into an existing `BENCH_<bench>.json`
    /// (same-name entries are replaced, others preserved), or write a
    /// fresh file if none exists. Lets several bench binaries contribute
    /// to one tracked file — the planner bench records into
    /// `BENCH_hotpath.json` so the perf_regression gate covers both.
    pub fn write_merged(&self) {
        let path = format!("BENCH_{}.json", self.bench);
        let mut entries: Vec<Entry> = Vec::new();
        if let Ok(text) = std::fs::read_to_string(&path) {
            for e in parse_entries(&text) {
                if !self.entries.iter().any(|n| n.name == e.name) {
                    entries.push(e);
                }
            }
        }
        for e in &self.entries {
            entries.push(Entry {
                name: e.name.clone(),
                iters: e.iters,
                ns_per_iter: e.ns_per_iter,
            });
        }
        let all = Recorder { bench: self.bench.clone(), entries };
        match std::fs::write(&path, all.to_json()) {
            Ok(()) => println!("\nmerged into {path}"),
            Err(e) => eprintln!("could not write {path}: {e}"),
        }
    }
}

/// Parse a Recorder JSON back into entries — the inverse of `to_json`
/// (one result object per line; names are plain ASCII, no serde needed).
fn parse_entries(text: &str) -> Vec<Entry> {
    let mut out = Vec::new();
    for line in text.lines() {
        let Some(npos) = line.find("\"name\": \"") else { continue };
        let rest = &line[npos + 9..];
        let Some(endq) = rest.find('"') else { continue };
        let name = rest[..endq].to_string();
        let grab = |key: &str| -> Option<f64> {
            let p = line.find(key)?;
            let tail = &line[p + key.len()..];
            let num: String = tail
                .chars()
                .take_while(|c| c.is_ascii_digit() || *c == '.' || *c == '-')
                .collect();
            num.parse().ok()
        };
        let (Some(iters), Some(ns)) =
            (grab("\"iters\": "), grab("\"ns_per_iter\": "))
        else {
            continue;
        };
        out.push(Entry { name, iters: iters as usize, ns_per_iter: ns });
    }
    out
}

/// Minimal JSON string escaping (names are plain ASCII identifiers).
fn escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}
