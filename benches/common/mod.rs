#![allow(dead_code)]
//! Shared mini bench harness (the offline registry has no criterion):
//! wall-clock the figure regenerators, print their tables, and emit a
//! `name ... elapsed` summary line per bench for bench_output.txt.
//!
//! A [`Recorder`] additionally captures every measurement and writes a
//! machine-readable `BENCH_<name>.json` (name, iters, ns/op) next to the
//! human output, so bench trajectories can be tracked across PRs without
//! scraping stdout. JSON is hand-rendered — no serde in the registry.

use std::time::Instant;

/// Worker count for parallel experiment grids: `JOBS` env var, defaulting
/// to 0 ("one worker per hardware thread" — see `experiments::runner`).
pub fn jobs_from_env() -> usize {
    std::env::var("JOBS").ok().and_then(|v| v.parse().ok()).unwrap_or(0)
}

pub fn bench<F: FnOnce() -> String>(name: &str, f: F) {
    let t0 = Instant::now();
    let output = f();
    let dt = t0.elapsed().as_secs_f64();
    println!("\n===== bench: {name} =====\n");
    println!("{output}");
    println!("\n[bench {name}: {dt:.2}s]");
}

/// Micro-benchmark: run `f` `iters` times, report ns/iter stats.
/// Returns the measured ns/iter so callers can record it.
pub fn micro<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    // Warmup.
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let total = t0.elapsed().as_secs_f64();
    let per = total / iters as f64;
    let (value, unit) = if per >= 1.0 {
        (per, "s")
    } else if per >= 1e-3 {
        (per * 1e3, "ms")
    } else if per >= 1e-6 {
        (per * 1e6, "us")
    } else {
        (per * 1e9, "ns")
    };
    println!("micro {name:<40} {value:>10.2} {unit}/iter  ({iters} iters)");
    per * 1e9
}

/// One recorded measurement.
struct Entry {
    name: String,
    iters: usize,
    ns_per_iter: f64,
}

/// Collects micro-bench results and writes `BENCH_<bench>.json`.
pub struct Recorder {
    bench: String,
    entries: Vec<Entry>,
}

impl Recorder {
    pub fn new(bench: &str) -> Recorder {
        Recorder { bench: bench.to_string(), entries: Vec::new() }
    }

    /// Run and record a micro-benchmark (same output as [`micro`]).
    pub fn micro<F: FnMut()>(&mut self, name: &str, iters: usize, f: F) {
        let ns_per_iter = micro(name, iters, f);
        self.entries.push(Entry { name: name.to_string(), iters, ns_per_iter });
    }

    /// Render the collected entries as JSON.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"bench\": \"{}\",\n", escape(&self.bench)));
        out.push_str("  \"results\": [\n");
        for (i, e) in self.entries.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"name\": \"{}\", \"iters\": {}, \"ns_per_iter\": {:.1}}}{}\n",
                escape(&e.name),
                e.iters,
                e.ns_per_iter,
                if i + 1 < self.entries.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Write `BENCH_<bench>.json` into the working directory (the repo
    /// root under `cargo bench`). Prints the path on success.
    pub fn write(&self) {
        let path = format!("BENCH_{}.json", self.bench);
        match std::fs::write(&path, self.to_json()) {
            Ok(()) => println!("\nwrote {path}"),
            Err(e) => eprintln!("could not write {path}: {e}"),
        }
    }
}

/// Minimal JSON string escaping (names are plain ASCII identifiers).
fn escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}
