//! Control-plane micro-benchmarks: full CWD+CORAL planning, subset
//! repair, and placement alone, each next to its retained naive
//! reference so the incremental-workspace speedup is visible in the
//! numbers. Records into `BENCH_hotpath.json` (merged, not clobbered)
//! so the perf_regression gate tracks planner entries too — run the
//! `hotpath` bench first; it writes the file this one merges into.

mod common;

use octopinf::cluster::Cluster;
use octopinf::coordinator::coral::{coral_repair_ws, coral_ws};
use octopinf::coordinator::cwd::{cwd_subset_ws, cwd_ws, CwdParams};
use octopinf::coordinator::reference::{
    coral_reference, coral_repair_reference, cwd_reference,
    cwd_subset_reference,
};
use octopinf::coordinator::{PlannerWorkspace, SchedEnv, StageCfg};
use octopinf::pipeline::standard_pipelines;
use octopinf::profiles::ProfileStore;

fn main() {
    let mut rec = common::Recorder::new("hotpath");

    // Paper testbed (server + 9 edge boxes) under a heavy tenant count:
    // 24 pipelines, sources cycling over the edge devices.
    let cluster = Cluster::paper_testbed();
    let profiles = ProfileStore::analytic();
    let pipelines: Vec<_> = standard_pipelines(24)
        .into_iter()
        .enumerate()
        .map(|(i, mut p)| {
            p.source_device = 1 + (i % (cluster.devices.len() - 1));
            p
        })
        .collect();
    let bws = vec![100.0; cluster.devices.len()];
    let env = SchedEnv::bootstrap(&cluster, &profiles, &pipelines, bws.clone());
    let params = CwdParams::default();

    let mut ws = PlannerWorkspace::new();
    let mut out: Vec<(usize, Vec<StageCfg>)> = Vec::new();

    // Full round: CWD over all pipelines, then CORAL placement.
    rec.micro("planner full plan 24p", 200, || {
        cwd_ws(&env, &params, &mut ws, &mut out);
        let cfgs: Vec<Vec<StageCfg>> =
            out.drain(..).map(|(_, c)| c).collect();
        std::hint::black_box(coral_ws(&env, &cfgs, &mut ws));
    });
    rec.micro("planner full plan 24p reference", 50, || {
        let cfgs: Vec<Vec<StageCfg>> = cwd_reference(&env, &params)
            .into_iter()
            .map(|r| r.cfg)
            .collect();
        std::hint::black_box(coral_reference(&env, &cfgs));
    });

    // Fixtures for the subset / placement entries.
    cwd_ws(&env, &params, &mut ws, &mut out);
    let cfgs: Vec<Vec<StageCfg>> = out.drain(..).map(|(_, c)| c).collect();
    let plan = coral_ws(&env, &cfgs, &mut ws);

    // One pipeline surges; replan it alone against the standing plan.
    let target = 7usize;
    let mut surged =
        SchedEnv::bootstrap(&cluster, &profiles, &pipelines, bws);
    for o in surged.obs[target].iter_mut() {
        o.rate_qps *= 2.5;
    }
    let kept: Vec<(usize, Vec<StageCfg>)> = cfgs
        .iter()
        .enumerate()
        .filter(|&(p, _)| p != target)
        .map(|(p, c)| (p, c.clone()))
        .collect();
    let targets = [target];
    rec.micro("planner subset repair 1of24", 500, || {
        cwd_subset_ws(&surged, &params, &targets, &kept, &mut ws, &mut out);
        std::hint::black_box(coral_repair_ws(&surged, &plan, &out, &mut ws));
    });
    rec.micro("planner subset repair 1of24 reference", 100, || {
        let sub = cwd_subset_reference(&surged, &params, &targets, &kept);
        std::hint::black_box(coral_repair_reference(&surged, &plan, &sub));
    });

    // Placement alone (CORAL stream packing, no CWD).
    rec.micro("planner placement 24p", 500, || {
        std::hint::black_box(coral_ws(&env, &cfgs, &mut ws));
    });
    rec.micro("planner placement 24p reference", 100, || {
        std::hint::black_box(coral_reference(&env, &cfgs));
    });

    rec.write_merged();
}
