//! Regenerates Fig. 6 (a-d): overall comparison of OctopInf vs Distream,
//! Jellyfish, Rim on the standard 9-camera / 5G / 30-min scenario, plus
//! OctopInf's workload-tracking timeline.
//!
//! `cargo bench --bench fig6_overall` (QUICK=1 for a 5-min version,
//! JOBS=N to bound the parallel grid; default: all hardware threads).

mod common;

use octopinf::experiments;

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let jobs = common::jobs_from_env();
    common::bench("fig6a-c_overall_comparison", || {
        experiments::fig6_overall(quick, jobs).to_markdown()
    });
    common::bench("fig6d_workload_tracking", || {
        experiments::fig6_timeline(quick).to_markdown()
    });
}
