//! Regenerates Fig. 11: long-term (13-hour) diurnal run — effective
//! throughput tracks the circadian workload curve.
//!
//! `cargo bench --bench fig11_longterm` (QUICK=1 runs 2 h / 3 sources).

mod common;

use octopinf::experiments;

fn main() {
    // Default to the quick variant unless FULL=1: the full 13-hour
    // 9-source simulation is a multi-minute run.
    let quick = !std::env::var("FULL").is_ok();
    common::bench("fig11_longterm_diurnal", || {
        experiments::fig11_longterm(quick).to_markdown()
    });
}
