//! Hot-path micro-benchmarks for the §Perf pass: the operations that
//! dominate the simulator and the serving loop. Emits the human summary
//! plus machine-readable `BENCH_hotpath.json` for trajectory tracking.

mod common;

use std::collections::BinaryHeap;

use octopinf::config::ExperimentConfig;
use octopinf::coordinator::SchedulerKind;
use octopinf::network::{BwTrace, TraceKind};
use octopinf::serving::DynamicBatcher;
use octopinf::sim::wheel::{EventWheel, WheelEntry};
use octopinf::sim::{run, run_traced_with, FifoLink, Scenario, Simulator};
use octopinf::util::stats::{burstiness, QuantileSketch};
use octopinf::util::Rng;
use octopinf::workload::{ArrivalWindow, ContentDynamics, ContentProfile};

fn main() {
    let mut rec = common::Recorder::new("hotpath");

    // End-to-end simulator throughput: events/s over a 2-minute scenario.
    let mut cfg = ExperimentConfig::default();
    cfg.duration_ms = 2.0 * 60_000.0;
    let sc = Scenario::build(cfg);
    rec.micro("sim 2min standard octopinf", 3, || {
        std::hint::black_box(run(&sc, SchedulerKind::OctopInf));
    });

    // Same run with the observability layer armed: ring-only flight
    // recorder (what `enable_invariants` adds), then the full tracer
    // (`--trace`, every span/mark/batch event retained). The spread over
    // the plain entry above is the cost of the trace hooks.
    rec.micro("sim 2min octopinf flight-recorder", 3, || {
        let mut s = Simulator::new(&sc, SchedulerKind::OctopInf);
        s.enable_flight_recorder();
        std::hint::black_box(s.run());
    });
    rec.micro("sim 2min octopinf full-trace", 3, || {
        std::hint::black_box(run_traced_with(&sc, SchedulerKind::OctopInf, 1));
    });

    // Batcher push/poll cycle.
    let mut b: DynamicBatcher<u64> = DynamicBatcher::new(8, 20.0);
    let mut i = 0u64;
    rec.micro("batcher push+drain", 1_000_000, || {
        i += 1;
        if let Some(v) = b.push(i, i as f64) {
            std::hint::black_box(v);
        }
    });

    // Arrival-window burstiness estimation (O(1) incremental aggregates).
    let mut w = ArrivalWindow::new(60_000.0);
    let mut t = 0.0;
    let mut rng = Rng::new(1);
    for _ in 0..2000 {
        t += rng.exp(0.05);
        w.record(t);
    }
    rec.micro("arrival window rate+cv", 20_000, || {
        std::hint::black_box((w.rate_qps(), w.burstiness()));
    });

    // Arrival-window steady-state record (eviction churn included).
    let mut wr = ArrivalWindow::new(1_000.0);
    let mut tr = 0.0;
    let mut rngr = Rng::new(5);
    rec.micro("arrival window record", 1_000_000, || {
        tr += rngr.exp(0.1);
        wr.record(tr);
    });

    // Content generator.
    let mut cd = ContentDynamics::new(ContentProfile::traffic(), Rng::new(2));
    let mut ft = 0.0;
    rec.micro("content objects_in_frame", 1_000_000, || {
        ft += 66.7;
        std::hint::black_box(cd.objects_in_frame(ft));
    });

    // Percentile extraction on a large latency set (streaming sketch —
    // the type RunMetrics/ServeReport record latencies through).
    let mut rng2 = Rng::new(3);
    let samples: Vec<f64> = (0..500_000).map(|_| rng2.range(0.0, 400.0)).collect();
    rec.micro("percentiles 500k samples", 5, || {
        let mut p = QuantileSketch::new();
        for &s in &samples {
            p.push(s);
        }
        std::hint::black_box((p.p50(), p.p95(), p.p99()));
    });

    // Event queue: the sim's time source. Same seeded (time, tie) stream
    // through the calendar wheel and through the old global-BinaryHeap
    // discipline, insert+pop in engine-like order (mostly near-future
    // pushes, monotone pops).
    let keys: Vec<(f64, u64)> = {
        let mut r = Rng::new(6);
        let mut t = 0.0;
        (0..10_000u64)
            .map(|s| {
                t += r.exp(0.5); // ~2 ms mean gap, many same-bucket entries
                (t + r.range(0.0, 50.0), r.next_u64())
            })
            .collect()
    };
    rec.micro("event wheel insert+pop 10k", 200, || {
        let mut w: EventWheel<u64> = EventWheel::new();
        for (s, &(t, tie)) in keys.iter().enumerate() {
            w.push(t, tie, s as u64, s as u64);
        }
        while let Some(e) = w.pop() {
            std::hint::black_box(e.ev);
        }
    });
    rec.micro("event binaryheap insert+pop 10k", 200, || {
        let mut h: BinaryHeap<WheelEntry<u64>> = BinaryHeap::new();
        for (s, &(t, tie)) in keys.iter().enumerate() {
            h.push(WheelEntry { t, tie, seq: s as u64, ev: s as u64 });
        }
        while let Some(e) = h.pop() {
            std::hint::black_box(e.ev);
        }
    });

    // FifoLink::send on a live trace, and into a blackout window (the
    // outage path is an O(1) skip-table lookup, not a per-second scan).
    let lte = {
        let mut r = Rng::new(8);
        BwTrace::generate(TraceKind::Lte, 120_000.0, &mut r)
    };
    let mut link = FifoLink::new(lte, 20.0);
    let mut now = 0.0;
    rec.micro("fifolink send lte", 500_000, || {
        now = (now + 0.2) % 100_000.0;
        std::hint::black_box(link.send(now, 20_000.0));
    });
    let dark = {
        let mut r = Rng::new(9);
        let mut t = BwTrace::generate(TraceKind::FiveG, 600_000.0, &mut r);
        t.zero_window(10, 400); // 390 s mid-trace outage
        FifoLink::new(t, 20.0)
    };
    rec.micro("fifolink clone+send into blackout", 200_000, || {
        // Clone resets free_at so every iteration takes the deferral path
        // (the pre-wheel engine re-scanned those 385 dark seconds here).
        let mut l = dark.clone();
        std::hint::black_box(l.send(15_000.0, 20_000.0));
    });

    // Burstiness over a large arrival vector.
    let arrivals: Vec<f64> = {
        let mut t = 0.0;
        let mut r = Rng::new(4);
        (0..100_000)
            .map(|_| {
                t += r.exp(0.1);
                t
            })
            .collect()
    };
    rec.micro("burstiness 100k arrivals", 50, || {
        std::hint::black_box(burstiness(&arrivals));
    });

    rec.write();
}
