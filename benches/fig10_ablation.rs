//! Regenerates Fig. 10: component ablation — full OctopInf vs w/o CORAL
//! vs static batches vs server-only, with Distream/Jellyfish for context.
//!
//! `cargo bench --bench fig10_ablation`

mod common;

use octopinf::experiments;

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let jobs = common::jobs_from_env();
    common::bench("fig10_ablation", || {
        experiments::fig10_ablation(quick, jobs).to_markdown()
    });
}
