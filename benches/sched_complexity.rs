//! Scheduler-cost scaling bench (paper §V-1): CWD is O(D·M·BZ) and CORAL
//! O(M·PT); wall-clock both as pipeline count grows to confirm near-linear
//! scaling — the property that makes real-time rescheduling viable.

mod common;

use octopinf::cluster::Cluster;
use octopinf::coordinator::coral::coral;
use octopinf::coordinator::cwd::{cwd, CwdParams};
use octopinf::coordinator::{SchedEnv, StageCfg};
use octopinf::pipeline::standard_pipelines;
use octopinf::profiles::ProfileStore;

fn main() {
    let cluster = Cluster::paper_testbed();
    let profiles = ProfileStore::analytic();
    for &n in &[1usize, 3, 9, 18, 36] {
        let pipelines: Vec<_> = standard_pipelines(n)
            .into_iter()
            .enumerate()
            .map(|(i, mut p)| {
                p.source_device = 1 + (i % 9);
                p
            })
            .collect();
        let env = SchedEnv::bootstrap(
            &cluster,
            &profiles,
            &pipelines,
            vec![25.0; cluster.devices.len()],
        );
        common::micro(&format!("cwd n_pipelines={n}"), 20, || {
            std::hint::black_box(cwd(&env, &CwdParams::default()));
        });
        let cfgs: Vec<Vec<StageCfg>> =
            cwd(&env, &CwdParams::default()).into_iter().map(|r| r.cfg).collect();
        common::micro(&format!("coral n_pipelines={n}"), 20, || {
            std::hint::black_box(coral(&env, &cfgs));
        });
    }
}
