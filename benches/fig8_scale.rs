//! Regenerates Fig. 8: doubled per-device workload (two cameras per edge
//! device) — effective-throughput ratios and hardware usage.
//!
//! `cargo bench --bench fig8_scale`

mod common;

use octopinf::experiments;

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let jobs = common::jobs_from_env();
    common::bench("fig8_double_workload", || {
        experiments::fig8_scale(quick, jobs).to_markdown()
    });
}
