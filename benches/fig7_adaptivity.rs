//! Regenerates Fig. 7: per-source workload / bandwidth / throughput
//! adaptivity under LTE traces (with outages).
//!
//! `cargo bench --bench fig7_adaptivity` (QUICK=1 for fewer sources).

mod common;

use octopinf::experiments;

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let jobs = common::jobs_from_env();
    for (name, table) in experiments::fig7_adaptivity(quick, jobs) {
        common::bench(&format!("fig7_{name}"), || table.to_markdown());
    }
}
