#!/usr/bin/env bash
# CI gate for the octopinf reproduction.
#
#   tier-1:     cargo build --release && cargo test -q
#               (cargo test includes the 50-scenario x 5-scheduler
#               differential conformance sweep, rust/tests/conformance.rs)
#   fuzz smoke: ~30 s extra sweep through the CLI path; fixed default
#               seed (override with FUZZ_SEED0 to rotate the corpus)
#   chaos smoke: fault-storm recovery comparison in both replan modes
#               (override CHAOS_SEED0 to rotate the storms)
#   partition determinism: fuzz + chaos smokes re-run at --sim-jobs 1 and
#               --sim-jobs 4 over 2-cluster scenarios; the printed digest
#               lines must match byte-for-byte or CI exits non-zero
#   obs smoke:  trace export byte-stable across --sim-jobs, digest parity
#               with tracing on/off, traced fuzz replay + `why` postmortem
#   perf:       cargo bench --bench hotpath -> BENCH_hotpath.json, then
#               cargo bench --bench planner merges its control-plane
#               entries into the same file; the first run captures
#               BENCH_hotpath.baseline.json (commit it), later runs gate
#               >25 % per-entry regressions
#               (rust/tests/perf_regression.rs). SKIP_BENCH=1 to skip.
set -euo pipefail
cd "$(dirname "$0")"

cargo build --release
cargo test -q

# Fuzz smoke: a dozen scenarios through all five schedulers, CLI path
# (also exercises the repro-string plumbing end to end).
cargo run --release --quiet -- fuzz --scenarios 12 --seed0 "${FUZZ_SEED0:-12648430}"

# Drift smoke: the same CLI path with drift-triggered incremental
# replanning, so mid-run plan migrations run under the invariant engine
# on every CI pass (conservation across each swap is a hard failure).
cargo run --release --quiet -- fuzz --scenarios 8 --replan drift --seed0 "${FUZZ_SEED0:-12648430}"

# Chaos smoke: fault-storm comparison (recovery on vs off, invariants
# armed on every run) in both replan modes; any unaccounted fault loss
# or conservation violation exits non-zero.
cargo run --release --quiet -- chaos --storms 3 --seed0 "${CHAOS_SEED0:-3298844397}"
cargo run --release --quiet -- chaos --storms 3 --replan drift --seed0 "${CHAOS_SEED0:-3298844397}"

# Partition-determinism gate: the same sweeps at --sim-jobs 1 vs 4 over
# two-cluster scenarios must emit identical digest lines — `--sim-jobs`
# is a wall-clock knob, never a result axis.
det_gate() {
  local label="$1"; shift
  local a b
  a=$("$@" --sim-jobs 1 | grep '^digest:' || true)
  b=$("$@" --sim-jobs 4 | grep '^digest:' || true)
  if [ -z "$a" ] || [ -z "$b" ]; then
    echo "ci.sh: $label smoke printed no digest line" >&2
    exit 1
  fi
  if [ "$a" != "$b" ]; then
    echo "ci.sh: $label digests diverged across --sim-jobs" >&2
    echo "  --sim-jobs 1: $a" >&2
    echo "  --sim-jobs 4: $b" >&2
    exit 1
  fi
  echo "$label digest stable across --sim-jobs (clusters=2): ${a#digest: }"
}
det_gate fuzz cargo run --release --quiet -- fuzz \
  --scenarios 6 --clusters 2 --seed0 "${FUZZ_SEED0:-12648430}"
det_gate chaos cargo run --release --quiet -- chaos \
  --storms 2 --clusters 2 --seed0 "${CHAOS_SEED0:-3298844397}"

# Front-door smoke: filter/isolation/sim-frontend comparisons with hard
# acceptance bars (filter gain >= 3x, tenant-B attainment pinned above
# the open-admission baseline, request conservation, fingerprint parity)
# — any missed bar exits non-zero.
cargo run --release --quiet -- frontdoor --quick

# Observability smoke: arming the tracer must not move the digest line,
# the exported Chrome-trace JSON must be byte-identical across --sim-jobs
# (the binary validates the JSON before writing), and the traced fuzz
# replay plus the `why` postmortem must run clean end to end.
OBS_TMP=$(mktemp -d)
trap 'rm -rf "$OBS_TMP"' EXIT
obs_digest() {
  cargo run --release --quiet -- simulate --scenario smoke --clusters 2 "$@" \
    | grep '^digest:'
}
d_plain=$(obs_digest)
d_traced=$(obs_digest --trace "$OBS_TMP/t1.json")
if [ -z "$d_plain" ] || [ "$d_plain" != "$d_traced" ]; then
  echo "ci.sh: --trace moved the simulate digest" >&2
  echo "  off: $d_plain" >&2
  echo "  on:  $d_traced" >&2
  exit 1
fi
obs_digest --trace "$OBS_TMP/t4.json" --sim-jobs 4 >/dev/null
if ! cmp -s "$OBS_TMP/t1.json" "$OBS_TMP/t4.json"; then
  echo "ci.sh: trace bytes diverged across --sim-jobs 1 vs 4" >&2
  exit 1
fi
[ -s "$OBS_TMP/t1.json" ] || { echo "ci.sh: empty trace export" >&2; exit 1; }
echo "trace export stable across --sim-jobs; digest unmoved: ${d_plain#digest: }"
OBS_REPRO="fuzz:v1:seed=${FUZZ_SEED0:-12648430}:clusters=2"
cargo run --release --quiet -- fuzz --repro "$OBS_REPRO" \
  --trace "$OBS_TMP/replay.json" >/dev/null
cargo run --release --quiet -- why --repro "$OBS_REPRO" --sim-jobs 2 >/dev/null
echo "obs smoke green: traced replay + postmortem clean on $OBS_REPRO"

if [ "${SKIP_BENCH:-0}" != "1" ]; then
  # Order matters: hotpath writes BENCH_hotpath.json fresh, planner
  # merges its entries into it; only then is the file baseline-complete.
  cargo bench --bench hotpath
  cargo bench --bench planner
  if [ ! -f BENCH_hotpath.baseline.json ]; then
    cp BENCH_hotpath.json BENCH_hotpath.baseline.json
    echo "captured new hot-path baseline: BENCH_hotpath.baseline.json (commit it)"
  fi
  cargo test -q --test perf_regression -- --ignored
fi

echo "ci.sh: all green"
