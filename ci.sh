#!/usr/bin/env bash
# CI gate for the octopinf reproduction.
#
#   tier-1:     cargo build --release && cargo test -q
#               (cargo test includes the 50-scenario x 5-scheduler
#               differential conformance sweep, rust/tests/conformance.rs)
#   fuzz smoke: ~30 s extra sweep through the CLI path; fixed default
#               seed (override with FUZZ_SEED0 to rotate the corpus)
#   chaos smoke: fault-storm recovery comparison in both replan modes
#               (override CHAOS_SEED0 to rotate the storms)
#   perf:       cargo bench --bench hotpath -> BENCH_hotpath.json; the
#               first run captures BENCH_hotpath.baseline.json (commit it),
#               later runs gate >25 % per-entry regressions
#               (rust/tests/perf_regression.rs). SKIP_BENCH=1 to skip.
set -euo pipefail
cd "$(dirname "$0")"

cargo build --release
cargo test -q

# Fuzz smoke: a dozen scenarios through all five schedulers, CLI path
# (also exercises the repro-string plumbing end to end).
cargo run --release --quiet -- fuzz --scenarios 12 --seed0 "${FUZZ_SEED0:-12648430}"

# Drift smoke: the same CLI path with drift-triggered incremental
# replanning, so mid-run plan migrations run under the invariant engine
# on every CI pass (conservation across each swap is a hard failure).
cargo run --release --quiet -- fuzz --scenarios 8 --replan drift --seed0 "${FUZZ_SEED0:-12648430}"

# Chaos smoke: fault-storm comparison (recovery on vs off, invariants
# armed on every run) in both replan modes; any unaccounted fault loss
# or conservation violation exits non-zero.
cargo run --release --quiet -- chaos --storms 3 --seed0 "${CHAOS_SEED0:-3298844397}"
cargo run --release --quiet -- chaos --storms 3 --replan drift --seed0 "${CHAOS_SEED0:-3298844397}"

# Front-door smoke: filter/isolation/sim-frontend comparisons with hard
# acceptance bars (filter gain >= 3x, tenant-B attainment pinned above
# the open-admission baseline, request conservation, fingerprint parity)
# — any missed bar exits non-zero.
cargo run --release --quiet -- frontdoor --quick

if [ "${SKIP_BENCH:-0}" != "1" ]; then
  cargo bench --bench hotpath
  if [ ! -f BENCH_hotpath.baseline.json ]; then
    cp BENCH_hotpath.json BENCH_hotpath.baseline.json
    echo "captured new hot-path baseline: BENCH_hotpath.baseline.json (commit it)"
  fi
  cargo test -q --test perf_regression -- --ignored
fi

echo "ci.sh: all green"
