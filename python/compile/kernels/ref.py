"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth the L1 kernels are tested against (pytest +
hypothesis in python/tests/). They intentionally share *no* code with the
kernels beyond the activation names and the WH clip constant.
"""

import jax
import jax.numpy as jnp

from .postprocess import WH_CLIP


def ref_fused_matmul(a, b, bias, act: str = "none"):
    """act(a @ b + bias), plain jnp."""
    out = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32))
    out = out + bias.astype(jnp.float32)[None, :]
    if act == "relu":
        out = jnp.maximum(out, 0.0)
    elif act == "sigmoid":
        out = jax.nn.sigmoid(out)
    elif act != "none":
        raise ValueError(f"unknown activation {act!r}")
    return out


def ref_decode_detections(head, meta, stride: int = 16):
    """Detector-head decode, plain jnp; head (N,B,5+C), meta (B,4)."""
    head = head.astype(jnp.float32)
    meta = meta.astype(jnp.float32)
    xy = jax.nn.sigmoid(head[..., 0:2])
    x = (xy[..., 0] + meta[None, :, 0]) * float(stride)
    y = (xy[..., 1] + meta[None, :, 1]) * float(stride)
    wh = jnp.exp(jnp.clip(head[..., 2:4], -WH_CLIP, WH_CLIP))
    w = wh[..., 0] * meta[None, :, 2]
    h = wh[..., 1] * meta[None, :, 3]
    scores = jax.nn.sigmoid(head[..., 4:])
    return jnp.concatenate(
        [x[..., None], y[..., None], w[..., None], h[..., None], scores],
        axis=-1,
    )
