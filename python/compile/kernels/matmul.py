"""Tiled GEMM Pallas kernel with fused bias + activation.

This is the compute hot-spot of every model in the repo: convolutions are
lowered to im2col + this GEMM, and the FC/embedding heads call it directly.

TPU mapping (DESIGN.md §Hardware-Adaptation): the paper's models run under
TensorRT on GPUs; instead of porting CUDA threadblock tiling we tile for a
VMEM-resident accumulator. The grid is (M/bm, N/bn, K/bk) with the K axis
innermost ("arbitrary" semantics): each (i, j) output tile stays resident in
VMEM across the K loop while (bm, bk) LHS and (bk, bn) RHS panels stream in
from HBM — exactly the schedule BlockSpec expresses below. Block defaults
of 128 match the MXU's 128x128 systolic tile; f32 accumulation.

Bias-add and activation are fused into the last K step so the output tile is
written to HBM exactly once, already activated.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Activation codes shared with ref.py and model.py.
ACT_NONE = "none"
ACT_RELU = "relu"
ACT_SIGMOID = "sigmoid"
_ACTS = (ACT_NONE, ACT_RELU, ACT_SIGMOID)


def _apply_act(x, act):
    if act == ACT_RELU:
        return jnp.maximum(x, 0.0)
    if act == ACT_SIGMOID:
        return jax.nn.sigmoid(x)
    return x


def _matmul_kernel(a_ref, b_ref, bias_ref, o_ref, *, act, k_steps):
    """One (bm, bn) output tile; K axis is the innermost grid dim."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # MXU-shaped partial product, f32 accumulation.
    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == k_steps - 1)
    def _epilogue():
        acc = o_ref[...] + bias_ref[...]
        o_ref[...] = _apply_act(acc, act)


def _pad_to(x, axis, mult):
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


@functools.partial(
    jax.jit, static_argnames=("act", "block_m", "block_n", "block_k")
)
def fused_matmul(
    a,
    b,
    bias,
    act: str = ACT_NONE,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
):
    """act(a @ b + bias) with a (M,K), b (K,N), bias (N,).

    Shapes are padded to block multiples outside the kernel and the result is
    sliced back, so arbitrary (M, N, K) are accepted.
    """
    if act not in _ACTS:
        raise ValueError(f"unknown activation {act!r}; expected one of {_ACTS}")
    if a.ndim != 2 or b.ndim != 2 or bias.ndim != 1:
        raise ValueError("fused_matmul expects a:(M,K) b:(K,N) bias:(N,)")
    if a.shape[1] != b.shape[0] or b.shape[1] != bias.shape[0]:
        raise ValueError(
            f"shape mismatch: a{a.shape} @ b{b.shape} + bias{bias.shape}"
        )

    m, k = a.shape
    _, n = b.shape
    a32 = _pad_to(_pad_to(a.astype(jnp.float32), 0, block_m), 1, block_k)
    b32 = _pad_to(_pad_to(b.astype(jnp.float32), 0, block_k), 1, block_n)
    bias32 = _pad_to(bias.astype(jnp.float32), 0, block_n)

    mp, kp = a32.shape
    _, np_ = b32.shape
    k_steps = kp // block_k
    grid = (mp // block_m, np_ // block_n, k_steps)

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, act=act, k_steps=k_steps),
        grid=grid,
        in_specs=[
            # LHS panel: new (bm, bk) block per (i, k); j is irrelevant.
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            # RHS panel: new (bk, bn) block per (k, j).
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            # Bias row for the j-th output column block.
            pl.BlockSpec((block_n,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,  # CPU-PJRT executable; Mosaic only on real TPU
    )(a32, b32, bias32)
    return out[:m, :n]


def vmem_bytes(block_m: int, block_n: int, block_k: int) -> int:
    """Estimated VMEM residency of one grid step (f32)."""
    lhs = block_m * block_k
    rhs = block_k * block_n
    acc = block_m * block_n
    bias = block_n
    return 4 * (lhs + rhs + acc + bias)


def mxu_utilization(m: int, n: int, k: int, block_m: int, block_n: int,
                    block_k: int, mxu: int = 128) -> float:
    """Fraction of MXU issue slots doing useful work for a padded GEMM.

    Padding waste is the only structural inefficiency of this schedule: every
    128x128x128 MXU pass over padded regions is wasted. Used by DESIGN.md
    §Perf to pick block shapes (interpret-mode wallclock is NOT a TPU proxy).
    """
    def rup(x, b):
        return ((x + b - 1) // b) * b

    useful = m * n * k
    issued = rup(m, max(block_m, mxu)) * rup(n, max(block_n, mxu)) * rup(
        k, max(block_k, mxu)
    )
    return useful / issued if issued else 0.0
