"""Layer-1 Pallas kernels for OctopInf (interpret=True; see DESIGN.md)."""
from .matmul import fused_matmul
from .postprocess import decode_detections, head_meta
__all__ = ["fused_matmul", "decode_detections", "head_meta"]
