"""Fused detector-head decode as a Pallas kernel.

The raw detection head emits (N, B, 5 + C) logits per image: B = G*G*A
candidate boxes, each row [tx, ty, tw, th, obj, cls...]. Decoding applies

    x = (sigmoid(tx) + grid_x) * stride        y likewise
    w = exp(clip(tw)) * anchor_w               h likewise
    obj = sigmoid(obj)                         cls = sigmoid(cls)

The paper's pipelines (Fig. 2) run this on every frame between the detector
and its downstream classifiers, so it sits on the hot path; fusing the whole
decode into one pass keeps each (rows, 5+C) tile resident in VMEM instead of
materializing five intermediate HBM tensors.

Grid/anchor metadata is passed as a per-row (B, 4) table
[grid_x, grid_y, anchor_w, anchor_h] so the kernel itself is shape-generic.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# exp() clip bound — keeps wh finite for adversarial logits (same in ref.py).
WH_CLIP = 8.0


def _decode_kernel(head_ref, meta_ref, o_ref, *, stride):
    rows = head_ref[...]  # (bb, 5 + C)
    meta = meta_ref[...]  # (bb, 4)
    xy = jax.nn.sigmoid(rows[:, 0:2])
    x = (xy[:, 0] + meta[:, 0]) * stride
    y = (xy[:, 1] + meta[:, 1]) * stride
    wh = jnp.exp(jnp.clip(rows[:, 2:4], -WH_CLIP, WH_CLIP))
    w = wh[:, 0] * meta[:, 2]
    h = wh[:, 1] * meta[:, 3]
    scores = jax.nn.sigmoid(rows[:, 4:])
    o_ref[...] = jnp.concatenate(
        [x[:, None], y[:, None], w[:, None], h[:, None], scores], axis=1
    )


@functools.partial(jax.jit, static_argnames=("stride", "block_rows"))
def decode_detections(head, meta, stride: int = 16, block_rows: int = 128):
    """Decode raw head logits (N, B, 5+C) into boxes+scores (N, B, 5+C).

    `meta` is (B, 4): [grid_x, grid_y, anchor_w, anchor_h] per candidate.
    """
    if head.ndim != 3:
        raise ValueError(f"head must be (N, B, 5+C), got {head.shape}")
    if meta.shape != (head.shape[1], 4):
        raise ValueError(f"meta must be ({head.shape[1]}, 4), got {meta.shape}")

    n, b, ch = head.shape
    flat = head.astype(jnp.float32).reshape(n * b, ch)
    meta_full = jnp.tile(meta.astype(jnp.float32), (n, 1))

    rows = n * b
    pad = (-rows) % block_rows
    if pad:
        flat = jnp.pad(flat, ((0, pad), (0, 0)))
        meta_full = jnp.pad(meta_full, ((0, pad), (0, 0)))
    padded_rows = rows + pad

    out = pl.pallas_call(
        functools.partial(_decode_kernel, stride=float(stride)),
        grid=(padded_rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, ch), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 4), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, ch), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded_rows, ch), jnp.float32),
        interpret=True,  # CPU-PJRT executable
    )(flat, meta_full)
    return out[:rows].reshape(n, b, ch)


def head_meta(grid: int, anchors) -> jnp.ndarray:
    """Build the (G*G*A, 4) [gx, gy, aw, ah] table for a square grid."""
    a = jnp.asarray(anchors, dtype=jnp.float32)  # (A, 2)
    gy, gx = jnp.meshgrid(
        jnp.arange(grid, dtype=jnp.float32),
        jnp.arange(grid, dtype=jnp.float32),
        indexing="ij",
    )
    gxy = jnp.stack([gx.ravel(), gy.ravel()], axis=1)  # (G*G, 2)
    gxy = jnp.repeat(gxy, a.shape[0], axis=0)  # (G*G*A, 2)
    awh = jnp.tile(a, (grid * grid, 1))  # (G*G*A, 2)
    return jnp.concatenate([gxy, awh], axis=1)
