"""AOT compile path: lower every (model, batch) pair to HLO *text*.

HLO text — not ``lowered.compile()`` output and not ``.serialize()`` — is
the interchange format: jax >= 0.5 emits HloModuleProtos with 64-bit
instruction ids which the rust `xla` crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Outputs (per artifact):
    artifacts/<model>_b<batch>.hlo.txt
plus a manifest in two flavors:
    artifacts/manifest.json  — human/tooling
    artifacts/manifest.tsv   — consumed by rust/src/runtime (no JSON parser
                               in the offline rust dependency set)

Python runs ONCE at build time (`make artifacts`); the rust binary is
self-contained afterwards.
"""

import argparse
import hashlib
import json
import os
import time

import jax
from jax._src.lib import xla_client as xc

from .model import ALL_MODELS, build_model

BATCH_SIZES = (1, 2, 4, 8, 16, 32)


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model(name: str, batch: int) -> tuple:
    """Lower one (model, batch); returns (spec, hlo_text)."""
    spec, fwd = build_model(name)
    arg = jax.ShapeDtypeStruct((batch, *spec.input_shape), jax.numpy.float32)
    lowered = jax.jit(lambda x: (fwd(x),)).lower(arg)
    return spec, to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", nargs="*", default=list(ALL_MODELS))
    ap.add_argument("--batches", nargs="*", type=int,
                    default=list(BATCH_SIZES))
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = []
    t0 = time.time()
    for name in args.models:
        for batch in args.batches:
            spec, text = lower_model(name, batch)
            fname = f"{name}_b{batch}.hlo.txt"
            path = os.path.join(args.out_dir, fname)
            with open(path, "w") as f:
                f.write(text)
            digest = hashlib.sha256(text.encode()).hexdigest()[:16]
            manifest.append(
                dict(
                    model=name,
                    batch=batch,
                    file=fname,
                    input_shape=list(spec.input_shape),
                    output_shape=list(spec.output_shape),
                    flops_per_sample=spec.flops_per_sample,
                    param_count=spec.param_count,
                    sha256_16=digest,
                )
            )
            print(f"  {fname}: {len(text) / 1024:.0f} KiB sha={digest}")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    # TSV flavor for the rust loader: one row per artifact,
    # shapes are 'x'-joined.
    with open(os.path.join(args.out_dir, "manifest.tsv"), "w") as f:
        f.write("model\tbatch\tfile\tinput_shape\toutput_shape"
                "\tflops_per_sample\tparam_count\n")
        for m in manifest:
            f.write(
                "{model}\t{batch}\t{file}\t{ins}\t{outs}"
                "\t{flops_per_sample}\t{param_count}\n".format(
                    ins="x".join(map(str, m["input_shape"])),
                    outs="x".join(map(str, m["output_shape"])),
                    **m,
                )
            )
    print(f"wrote {len(manifest)} artifacts in {time.time() - t0:.1f}s "
          f"-> {args.out_dir}")


if __name__ == "__main__":
    main()
