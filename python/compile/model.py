"""Layer-2 JAX model definitions for OctopInf's EVA pipelines.

Three model families stand in for the paper's pipeline stages (Fig. 2):

- ``TinyDet`` — a single-scale YOLO-style object detector, in three input
  resolutions (96/128/160). The three variants play the role of Jellyfish's
  "multiple DNN versions" as well as the paper's Object Det stage.
- ``TinyCls`` — a small CNN crop classifier (Car-Type / Gender-Age stage).
- ``CropEmbed`` — a small CNN embedder (Plate-Recog / Face-Recog / ReID
  stage); emits an L2-normalized embedding.

Every convolution is lowered to im2col + the L1 Pallas fused GEMM
(`kernels.fused_matmul`), and the detector head decode runs through the L1
`kernels.decode_detections` Pallas kernel — so the entire FLOP budget of
every artifact flows through Layer 1.

Weights are deterministic (seeded He init) and baked into the lowered HLO as
constants: each artifact is a self-contained ``f(images) -> outputs``
computation, mirroring a compiled TensorRT engine per (model, batch).
"""

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from .kernels import decode_detections, fused_matmul, head_meta

# Anchor boxes (pixels) shared by all detector variants, YOLO-ish.
ANCHORS = ((12.0, 16.0), (28.0, 36.0), (60.0, 80.0))
NUM_ANCHORS = len(ANCHORS)
DET_CLASSES = 4  # person / car / bike / other — the paper's target mix
CLS_CLASSES = 8  # car types or demographic buckets
EMBED_DIM = 64
CROP_SIZE = 32


# --------------------------------------------------------------------------
# conv = im2col + Pallas GEMM
# --------------------------------------------------------------------------

def conv2d(x, w, b, stride: int = 1, act: str = "relu"):
    """NHWC conv via im2col + the L1 fused GEMM kernel.

    x: (N, H, W, Cin); w: (KH, KW, Cin, Cout); b: (Cout,).
    SAME padding. Returns (N, OH, OW, Cout).
    """
    n, h, wid, cin = x.shape
    kh, kw, _, cout = w.shape
    # Patches arrive as (N, OH, OW, Cin*KH*KW) with channel-major layout;
    # reorder the filter to match.
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    _, oh, ow, patch_dim = patches.shape
    a = patches.reshape(n * oh * ow, patch_dim)
    wmat = jnp.transpose(w, (2, 0, 1, 3)).reshape(patch_dim, cout)
    out = fused_matmul(a, wmat, b, act=act)
    return out.reshape(n, oh, ow, cout)


def linear(x, w, b, act: str = "none"):
    """FC layer on the Pallas GEMM; x (N, D), w (D, O), b (O,)."""
    return fused_matmul(x, w, b, act=act)


# --------------------------------------------------------------------------
# deterministic parameter construction
# --------------------------------------------------------------------------

def _he(key, shape):
    fan_in = 1
    for d in shape[:-1]:
        fan_in *= d
    return jax.random.normal(key, shape, dtype=jnp.float32) * jnp.sqrt(
        2.0 / fan_in
    )


def _conv_params(key, kh, kw, cin, cout):
    wkey, _ = jax.random.split(key)
    return _he(wkey, (kh, kw, cin, cout)), jnp.zeros((cout,), jnp.float32)


def _linear_params(key, din, dout):
    wkey, _ = jax.random.split(key)
    return _he(wkey, (din, dout)), jnp.zeros((dout,), jnp.float32)


def param_bytes(params) -> int:
    return sum(4 * p.size for p in jax.tree_util.tree_leaves(params))


# --------------------------------------------------------------------------
# model specs
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """Static description of one AOT-compilable model variant."""

    name: str
    input_shape: tuple  # per-sample, NHWC without N
    output_shape: tuple  # per-sample
    flops_per_sample: int
    param_count: int


_DET_CHANNELS: Sequence[int] = (16, 32, 64, 64)


def _det_params(key):
    keys = jax.random.split(key, len(_DET_CHANNELS) + 1)
    layers = []
    cin = 3
    for i, cout in enumerate(_DET_CHANNELS):
        layers.append(_conv_params(keys[i], 3, 3, cin, cout))
        cin = cout
    head = _conv_params(keys[-1], 1, 1, cin, NUM_ANCHORS * (5 + DET_CLASSES))
    return layers, head


def detector_fwd(images, layers, head, resolution: int):
    """TinyDet forward: conv stack (stride 2 each) + decoded head."""
    x = images
    for w, b in layers:
        x = conv2d(x, w, b, stride=2, act="relu")
    hw, hb = head
    raw = conv2d(x, hw, hb, stride=1, act="none")  # (N, G, G, A*(5+C))
    n, g, _, _ = raw.shape
    raw = raw.reshape(n, g * g * NUM_ANCHORS, 5 + DET_CLASSES)
    stride = resolution // g
    meta = head_meta(g, ANCHORS)
    return decode_detections(raw, meta, stride=stride)


def _cls_params(key):
    k = jax.random.split(key, 3)
    c1 = _conv_params(k[0], 3, 3, 3, 16)
    c2 = _conv_params(k[1], 3, 3, 16, 32)
    fc = _linear_params(k[2], 32, CLS_CLASSES)
    return c1, c2, fc


def classifier_fwd(crops, params):
    """TinyCls forward: 2 conv + GAP + FC logits; crops (N,32,32,3)."""
    (w1, b1), (w2, b2), (fw, fb) = params
    x = conv2d(crops, w1, b1, stride=2, act="relu")
    x = conv2d(x, w2, b2, stride=2, act="relu")
    x = jnp.mean(x, axis=(1, 2))  # GAP -> (N, 32)
    return linear(x, fw, fb, act="none")


def _embed_params(key):
    k = jax.random.split(key, 3)
    c1 = _conv_params(k[0], 3, 3, 3, 16)
    c2 = _conv_params(k[1], 3, 3, 16, 32)
    fc = _linear_params(k[2], 32, EMBED_DIM)
    return c1, c2, fc


def embedder_fwd(crops, params):
    """CropEmbed forward: 2 conv + GAP + FC + L2 norm; crops (N,32,32,3)."""
    (w1, b1), (w2, b2), (fw, fb) = params
    x = conv2d(crops, w1, b1, stride=2, act="relu")
    x = conv2d(x, w2, b2, stride=2, act="relu")
    x = jnp.mean(x, axis=(1, 2))
    e = linear(x, fw, fb, act="none")
    return e / (jnp.linalg.norm(e, axis=-1, keepdims=True) + 1e-6)


# --------------------------------------------------------------------------
# registry: name -> (spec, batch-closed fwd fn)
# --------------------------------------------------------------------------

def _conv_flops(h, w, kh, kw, cin, cout, stride):
    oh, ow = (h + stride - 1) // stride, (w + stride - 1) // stride
    return 2 * oh * ow * kh * kw * cin * cout


def _det_flops(res):
    f, s, cin = 0, res, 3
    for cout in _DET_CHANNELS:
        f += _conv_flops(s, s, 3, 3, cin, cout, 2)
        s, cin = (s + 1) // 2, cout
    f += _conv_flops(s, s, 1, 1, cin, NUM_ANCHORS * (5 + DET_CLASSES), 1)
    return f


def _crop_flops(dout):
    f = _conv_flops(CROP_SIZE, CROP_SIZE, 3, 3, 3, 16, 2)
    f += _conv_flops(16, 16, 3, 3, 16, 32, 2)
    f += 2 * 32 * dout
    return f


DET_RESOLUTIONS = {"det_s": 96, "det_m": 128, "det_l": 160}

_SEED = 20250710  # deterministic weights across AOT runs


def build_model(name: str):
    """Return (ModelSpec, fwd) where fwd(images) closes over baked weights."""
    key = jax.random.PRNGKey(_SEED)
    if name in DET_RESOLUTIONS:
        res = DET_RESOLUTIONS[name]
        layers, head = _det_params(jax.random.fold_in(key, res))
        grid = res // 16
        nboxes = grid * grid * NUM_ANCHORS
        spec = ModelSpec(
            name=name,
            input_shape=(res, res, 3),
            output_shape=(nboxes, 5 + DET_CLASSES),
            flops_per_sample=_det_flops(res),
            param_count=param_bytes((layers, head)) // 4,
        )
        fwd = functools.partial(detector_fwd, layers=layers, head=head,
                                resolution=res)
        return spec, fwd
    if name == "classifier":
        params = _cls_params(jax.random.fold_in(key, 1001))
        spec = ModelSpec(
            name=name,
            input_shape=(CROP_SIZE, CROP_SIZE, 3),
            output_shape=(CLS_CLASSES,),
            flops_per_sample=_crop_flops(CLS_CLASSES),
            param_count=param_bytes(params) // 4,
        )
        return spec, functools.partial(classifier_fwd, params=params)
    if name == "embedder":
        params = _embed_params(jax.random.fold_in(key, 1002))
        spec = ModelSpec(
            name=name,
            input_shape=(CROP_SIZE, CROP_SIZE, 3),
            output_shape=(EMBED_DIM,),
            flops_per_sample=_crop_flops(EMBED_DIM),
            param_count=param_bytes(params) // 4,
        )
        return spec, functools.partial(embedder_fwd, params=params)
    raise KeyError(f"unknown model {name!r}")


ALL_MODELS = tuple(DET_RESOLUTIONS) + ("classifier", "embedder")
