"""AOT path tests: lowering emits parseable, deterministic HLO text with
the entry signature the rust runtime expects."""

import jax
import pytest

from compile.aot import lower_model, BATCH_SIZES
from compile.model import ALL_MODELS

jax.config.update("jax_platform_name", "cpu")


def test_lower_emits_hlo_text():
    spec, text = lower_model("classifier", 2)
    assert "HloModule" in text
    assert "ENTRY" in text
    # Batch-2 input of 32x32x3 must appear as a parameter shape.
    assert "f32[2,32,32,3]" in text
    assert spec.name == "classifier"


def test_lowering_is_deterministic():
    _, a = lower_model("det_s", 1)
    _, b = lower_model("det_s", 1)
    assert a == b


@pytest.mark.parametrize("batch", [1, 8])
def test_batch_appears_in_entry_shape(batch):
    _, text = lower_model("embedder", batch)
    assert f"f32[{batch},32,32,3]" in text


def test_output_is_tuple():
    """aot lowers with return_tuple=True — the rust side calls to_tuple1."""
    _, text = lower_model("classifier", 1)
    # The entry computation layout's result side must be a tuple type.
    header = text.splitlines()[0]
    assert "->(" in header.replace(" ", ""), header


def test_registry_covers_all_models():
    assert set(ALL_MODELS) == {"det_s", "det_m", "det_l", "classifier", "embedder"}
    assert list(BATCH_SIZES) == [1, 2, 4, 8, 16, 32]
