"""L2 model shape/behaviour tests: every registry entry builds, runs at all
batch sizes, and produces deterministic, finite outputs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    ALL_MODELS,
    CLS_CLASSES,
    DET_CLASSES,
    DET_RESOLUTIONS,
    EMBED_DIM,
    NUM_ANCHORS,
    build_model,
    conv2d,
)

jax.config.update("jax_platform_name", "cpu")


@pytest.mark.parametrize("name", ALL_MODELS)
def test_model_builds_and_runs(name):
    spec, fwd = build_model(name)
    x = jnp.zeros((2, *spec.input_shape), jnp.float32)
    out = jax.jit(fwd)(x)
    assert out.shape == (2, *spec.output_shape)
    assert bool(jnp.all(jnp.isfinite(out)))


@pytest.mark.parametrize("name,res", DET_RESOLUTIONS.items())
def test_detector_box_count(name, res):
    spec, _ = build_model(name)
    grid = res // 16
    assert spec.output_shape == (grid * grid * NUM_ANCHORS, 5 + DET_CLASSES)


def test_classifier_and_embedder_heads():
    spec_c, _ = build_model("classifier")
    spec_e, _ = build_model("embedder")
    assert spec_c.output_shape == (CLS_CLASSES,)
    assert spec_e.output_shape == (EMBED_DIM,)


def test_embedder_is_l2_normalized():
    _, fwd = build_model("embedder")
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 32, 32, 3))
    norms = jnp.linalg.norm(jax.jit(fwd)(x), axis=-1)
    np.testing.assert_allclose(norms, 1.0, atol=1e-3)


def test_weights_are_deterministic():
    """Same registry name -> identical baked weights across builds."""
    _, fwd1 = build_model("det_s")
    _, fwd2 = build_model("det_s")
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 96, 96, 3))
    np.testing.assert_array_equal(jax.jit(fwd1)(x), jax.jit(fwd2)(x))


def test_variants_differ():
    _, fwd_c = build_model("classifier")
    _, fwd_e = build_model("embedder")
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 32, 32, 3))
    assert jax.jit(fwd_c)(x).shape != jax.jit(fwd_e)(x).shape


def test_conv2d_same_padding_shape():
    x = jnp.zeros((1, 17, 23, 3))
    w = jnp.zeros((3, 3, 3, 8))
    b = jnp.zeros((8,))
    assert conv2d(x, w, b, stride=2).shape == (1, 9, 12, 8)
    assert conv2d(x, w, b, stride=1).shape == (1, 17, 23, 8)


def test_conv2d_matches_lax_conv():
    """im2col + Pallas GEMM must equal XLA's native convolution."""
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (2, 12, 12, 3))
    w = jax.random.normal(jax.random.fold_in(key, 1), (3, 3, 3, 5))
    b = jax.random.normal(jax.random.fold_in(key, 2), (5,))
    got = conv2d(x, w, b, stride=2, act="none")
    want = (
        jax.lax.conv_general_dilated(
            x, w, (2, 2), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )
        + b
    )
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_unknown_model_raises():
    with pytest.raises(KeyError):
        build_model("resnet152")
